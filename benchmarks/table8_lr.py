"""Paper Table 8 (Appendix E.1): learning-rate γ_inv sweep.

Validates the paper's stability window: γ_inv too small → divergence
(unstable), γ_inv = 512 optimal, γ_inv too large → updates truncate to
zero (no learning)."""

from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_paper_config
from repro.core import les
from repro.data import synthetic


def run(steps: int = 150, batch: int = 64):
    ds = synthetic.make_image_dataset("tiles32", n_train=1024, n_test=256)
    base = get_paper_config("vgg8b", scale=0.125)
    for gamma in (128, 512, 2048, 16384):
        cfg = replace(base, gamma_inv=gamma, eta_fw=0, eta_lr=0)
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg))
        correct = total = 0
        diverged = False
        k = 0
        while k < steps:
            for x, y in synthetic.batches(ds.x_train, ds.y_train, batch, seed=k):
                if k >= steps:
                    break
                state, m = step(state, x=jnp.asarray(x), labels=jnp.asarray(y),
                                key=jax.random.PRNGKey(k))
                if k >= steps - 16:  # accuracy over the last epoch's steps
                    correct += int(m.correct)
                    total += batch
                k += 1
            mx = max(int(jnp.abs(p).max())
                     for p in jax.tree_util.tree_leaves(state.params))
            if mx > 2**20:
                diverged = True
                break
        status = "unstable" if diverged else f"train_acc={correct/max(total,1):.4f}"
        emit(f"table8/gamma_inv={gamma}", 0.0, status)


if __name__ == "__main__":
    run()
