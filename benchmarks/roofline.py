"""Roofline table collector: reads the dry-run JSONs and prints the
per-cell three-term roofline summary (EXPERIMENTS.md §Roofline source)."""

from __future__ import annotations

import glob
import json
import os

from benchmarks.common import emit

RESULTS_DIR = os.environ.get("DRYRUN_DIR", "results/dryrun")


def run():
    files = sorted(glob.glob(os.path.join(RESULTS_DIR, "*.json")))
    if not files:
        emit("roofline/none", 0.0, "no dry-run results found (run dryrun --sweep)")
        return
    for f in files:
        with open(f) as fh:
            r = json.load(fh)
        tag = f"roofline/{r['arch']}/{r['shape']}" + ("/pod2" if r.get("multi_pod") else "")
        if r.get("skipped"):
            emit(tag, 0.0, f"SKIP:{r['reason'][:60]}")
            continue
        t = r["roofline"]
        emit(
            tag,
            t["roofline_bound_s"] * 1e6,
            f"dominant={t['dominant']};compute_s={t['compute_s']:.3f};"
            f"memory_s={t['memory_s']:.3f};collective_s={t['collective_s']:.3f};"
            f"model/hlo={t['model_over_hlo_flops']:.3f};"
            f"roofline_frac={t['roofline_fraction']:.4f}",
        )


if __name__ == "__main__":
    run()
