"""Shared benchmark utilities: timing, CSV emission, the CI smoke config.

The timing primitives now live in ``repro.kernels.autotune.measure`` (the
autotuner searches with exactly the measurement discipline the benchmarks
report with); they are re-exported here under their historical names so
every benchmark keeps importing from one place.
"""

from __future__ import annotations

from repro.kernels.autotune.measure import time_fn, time_paired  # noqa: F401 — re-export


def tiny_smoke_cfg():
    """The shared ``--smoke`` topology: one conv + one linear block at 8×8.

    Used by the train-step and fleet-serving benchmark smokes so both CI
    gates exercise the same model (a drifted copy would smoke different
    models under one name).
    """
    from repro.core.blocks import BlockSpec
    from repro.core.model import NitroConfig

    return NitroConfig(
        blocks=(BlockSpec("conv", 8, pool=True, d_lr=64),
                BlockSpec("linear", 16)),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        name="tiny-smoke",
    )


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
