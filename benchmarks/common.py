"""Shared benchmark utilities: timing, CSV emission, the CI smoke config."""

from __future__ import annotations

import time

import jax


def tiny_smoke_cfg():
    """The shared ``--smoke`` topology: one conv + one linear block at 8×8.

    Used by the train-step and fleet-serving benchmark smokes so both CI
    gates exercise the same model (a drifted copy would smoke different
    models under one name).
    """
    from repro.core.blocks import BlockSpec
    from repro.core.model import NitroConfig

    return NitroConfig(
        blocks=(BlockSpec("conv", 8, pool=True, d_lr=64),
                BlockSpec("linear", 16)),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        name="tiny-smoke",
    )


def time_fn(fn, *args, iters: int = 10, warmup: int = 2, **kw) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_paired(fns: dict, *args, iters: int, **kw) -> dict:
    """Contention-robust paired timing: interleaved min-of-N per variant.

    This container's CPU swings ~2× with co-tenant load; timing each
    variant in its own block lets that drift masquerade as a speedup (or
    a regression).  Every round therefore times each variant once,
    back-to-back, alternating the order between rounds (ABBA) to cancel
    first-mover cache effects.  Per variant the *minimum* over rounds is
    reported — the timeit rationale: the minimum bounds the intrinsic
    cost, while co-tenant interference only ever inflates a sample.
    (All variants are jit-warmed before the first round.)
    """
    for fn in fns.values():  # jit warm-up
        jax.block_until_ready(fn(*args, **kw))
    names = list(fns)
    best = {m: float("inf") for m in names}
    for i in range(iters):
        for m in names if i % 2 == 0 else reversed(names):
            t0 = time.perf_counter()
            jax.block_until_ready(fns[m](*args, **kw))
            best[m] = min(best[m], (time.perf_counter() - t0) * 1e6)
    return best


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
