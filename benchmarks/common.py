"""Shared benchmark utilities: timing, CSV emission, the CI smoke config."""

from __future__ import annotations

import time

import jax


def tiny_smoke_cfg():
    """The shared ``--smoke`` topology: one conv + one linear block at 8×8.

    Used by the train-step and fleet-serving benchmark smokes so both CI
    gates exercise the same model (a drifted copy would smoke different
    models under one name).
    """
    from repro.core.blocks import BlockSpec
    from repro.core.model import NitroConfig

    return NitroConfig(
        blocks=(BlockSpec("conv", 8, pool=True, d_lr=64),
                BlockSpec("linear", 16)),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        name="tiny-smoke",
    )


def time_fn(fn, *args, iters: int = 10, warmup: int = 2, **kw) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def emit(name: str, us_per_call: float, derived: str):
    print(f"{name},{us_per_call:.1f},{derived}")
