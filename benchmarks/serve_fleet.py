"""Fleet serving benchmark: continuous vs static batching, A/B routing.

Head-to-head of the two vision schedulers on one frozen CNN at equal
compiled batch size, **closed-loop load**: ``n_clients = batch/2``
synchronous clients, each submitting its next request only after the
previous answer arrives — the regime real serving traffic looks like
(every user waits for their result), and the one where the schedulers
structurally differ:

  * ``static``     — ``VisionEngine``: with fewer concurrent clients
                     than ``batch_size`` the queue never fills, so EVERY
                     batch stalls for the full ``max_wait_ms`` before
                     launching, then host and device serialise
                     (stack → launch → block);
  * ``continuous`` — ``FleetEngine``: the in-flight batch is the wait
                     timer; from idle only the ~1 ms coalescing window
                     applies, and host work overlaps device execution.

Per batch the static engine pays ``max_wait_ms + compute`` against the
continuous engine's ``coalesce_ms + compute`` — the measured speedup is
that ratio, not scheduler noise.  (Fully-saturated offline load is the
regime where the two converge for compute-bound models: with a full
queue the static engine never waits either.)

Before timing, the two paths are checked to produce bit-identical logits
on a probe batch — the benchmark never compares two computations that
disagree.  Each scheduler is run ``reps`` times and the best wall clock
is kept (min-of-N: scheduling noise only ever slows a run down).

A second section serves a two-model fleet through a 90/10 A/B split to
record the router + weighted-round-robin overhead next to the
single-model numbers.

The production arm carries an ``Slo(deadline_ms=50)``: every run reports
its per-arm p99-vs-SLO roll-up (``slo_summary``) — p99 latency, slack
against the deadline, violation count — and the fleet runs exercise the
live SLO-attribution path (``serve_request_deadline_seconds`` /
``serve_slo_violations_total`` on a real MetricRegistry).  The candidate
arm deliberately has no SLO, covering the mixed-fleet case.

Emits the usual ``name,us_per_call,derived`` CSV rows on stdout *and*
machine-readable ``BENCH_serve.json`` in the CWD.

    PYTHONPATH=src python -m benchmarks.serve_fleet [--quick] [--smoke]

``--smoke`` runs a tiny 8×8 config in seconds — the CI gate
(tools/ci_check.sh) uses it to keep the fleet path exercised on every
commit.
"""

from __future__ import annotations

import argparse
import json
import threading
import time

import jax
import numpy as np

from benchmarks.common import emit, tiny_smoke_cfg

JSON_PATH = "BENCH_serve.json"

# Production-arm serving objective: generous against the ~10 ms batch
# compute + 5 ms static stall, so healthy runs meet it and the reported
# violations measure scheduler pathology, not an impossible target.
SLO_MS = 50.0

# (arch, scale, engine batch) — paper topology at a scale where one batch
# computes in ~10 ms on CPU: big enough to be a real model, small enough
# that the schedulers' structural per-batch difference (static's
# max_wait_ms stall vs the ~1 ms coalescing window) is not drowned by
# compute-time noise on a shared machine
CONFIGS = [
    ("vgg8b", 0.03125, 16),
]


def _freeze_random(cfg, seed: int):
    from repro.core import les
    from repro.infer import freeze

    return freeze(les.create_train_state(jax.random.PRNGKey(seed), cfg), cfg)


def _closed_loop(submit, images, n_clients: int):
    """Drive ``submit(image, index) -> Future`` from n_clients synchronous
    clients (each waits for its answer before sending the next request);
    returns (wall_s, results)."""
    results = [None] * len(images)

    def client(w):
        for i in range(w, len(images), n_clients):
            results[i] = submit(images[i], i).result()

    threads = [threading.Thread(target=client, args=(w,))
               for w in range(n_clients)]
    t0 = time.perf_counter()
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    return time.perf_counter() - t0, results


def _drain_static(plan, images, batch: int, max_wait_ms: float,
                  n_clients: int):
    from repro.serving import VisionEngine, snapshot_delta

    with VisionEngine(plan, batch_size=batch,
                      max_wait_ms=max_wait_ms) as engine:
        engine.classify(images[:1])  # compile outside the clock
        pre = engine.stats.snapshot()
        wall, results = _closed_loop(
            lambda img, i: engine.submit(img), images, n_clients)
        snap = snapshot_delta(pre, engine.stats.snapshot())
    return wall, results, snap


def _drain_continuous(registry, target, router, images, batch: int,
                      n_clients: int):
    from repro.serving import FleetEngine, fleet_snapshot_delta

    with FleetEngine(registry, batch_size=batch, router=router) as engine:
        for mid in registry.ids():  # compile every arm outside the clock
            engine.classify(images[:1], model=mid)
        pre = engine.snapshot()  # warmup must not count in fill/arm stats
        wall, results = _closed_loop(
            lambda img, i: engine.submit(img, model=target,
                                         request_id=f"req-{i}"),
            images, n_clients)
        snap = fleet_snapshot_delta(pre, engine.snapshot())
    return wall, results, snap


def _summary(name, wall, results, fill, n_requests):
    from repro.serving import latency_summary_ms

    return {
        "scheduler": name,
        "requests": n_requests,
        "wall_s": wall,
        "requests_per_s": n_requests / wall,
        "batch_fill": fill,
        "latency_ms": latency_summary_ms(r.latency_s for r in results),
    }


def _bench_config(cfg, batch: int, n_requests: int, reps: int,
                  results: list) -> None:
    from repro.infer import compile_plan
    from repro.obs.metrics import MetricRegistry
    from repro.serving import ModelRegistry, Router, Slo, slo_summary

    slo = Slo(deadline_ms=SLO_MS)
    fm = _freeze_random(cfg, seed=0)
    plan = compile_plan(fm)
    # real metric registry: the timed runs exercise the live SLO
    # attribution (deadline histograms + violation counters), not a stub
    registry = ModelRegistry(metrics=MetricRegistry())
    registry.register("prod", fm, slo=slo)

    rng = np.random.default_rng(1)
    images = [rng.integers(-127, 128, cfg.input_shape).astype(np.int32)
              for _ in range(n_requests)]
    # closed loop at half the batch size: a partially-filled steady state,
    # where the static engine's max_wait stall is on every batch's clock
    n_clients = max(2, batch // 2)

    # ---- parity gate: fleet-routed ≡ static ≡ raw plan ------------------
    probe = images[: min(8, n_requests)]
    _, static_res, _ = _drain_static(plan, probe, batch, max_wait_ms=2.0,
                                     n_clients=2)
    _, fleet_res, _ = _drain_continuous(registry, "prod", Router(), probe,
                                        batch, n_clients=2)
    direct = np.asarray(jax.device_get(plan.logits(np.stack(probe))))
    for i, (s, f) in enumerate(zip(static_res, fleet_res)):
        np.testing.assert_array_equal(s.logits, f.logits)
        np.testing.assert_array_equal(f.logits, direct[i])

    # ---- timed head-to-head (best of reps) ------------------------------
    best = {}
    for _ in range(reps):
        wall, res, snap = _drain_static(plan, images, batch, max_wait_ms=5.0,
                                        n_clients=n_clients)
        if "static" not in best or wall < best["static"][0]:
            best["static"] = (wall, res, snap["avg_batch_fill"])
        wall, res, snap = _drain_continuous(registry, "prod", Router(),
                                            images, batch,
                                            n_clients=n_clients)
        if "continuous" not in best or wall < best["continuous"][0]:
            best["continuous"] = (wall, res,
                                  snap["fleet"]["avg_batch_fill"])

    runs = {
        name: _summary(name, wall, res, fill, n_requests)
        for name, (wall, res, fill) in best.items()
    }
    # same objective scored on both schedulers: the "prod" arm's SLO
    for name, (_, res, _) in best.items():
        runs[name]["slo"] = slo_summary([r.latency_s for r in res], slo)
    speedup = (runs["continuous"]["requests_per_s"]
               / runs["static"]["requests_per_s"])
    for name, run_ in runs.items():
        s = run_["slo"]
        emit(f"serve/{cfg.name}/{name}",
             run_["wall_s"] / n_requests * 1e6,
             f"{run_['requests_per_s']:.1f} req/s; "
             f"fill {run_['batch_fill']:.2f}; "
             f"p99 {s['p99_ms']:.1f}ms vs slo {s['slo_ms']:.0f}ms "
             f"({'meets' if s['meets_slo'] else 'MISSES'})")
    emit(f"serve/{cfg.name}/speedup", 0.0,
         f"{speedup:.2f}x continuous/static")

    # ---- two-model A/B fleet through the router -------------------------
    # fresh registry: per-model stats live on registry entries, so reusing
    # the drained one would fold the single-model runs into the arm counts
    ab_registry = ModelRegistry(metrics=MetricRegistry())
    ab_registry.register("prod", fm, slo=slo)
    # no SLO on the candidate: the mixed fleet (objective on one arm
    # only) is the case the attribution path must handle
    ab_registry.register("candidate", _freeze_random(cfg, seed=1))
    router = Router({"split": {"prod": 0.9, "candidate": 0.1}})
    wall, res, snap = _drain_continuous(ab_registry, "split", router, images,
                                        batch, n_clients=n_clients)
    arm_requests = {mid: m["requests"]
                    for mid, m in snap["models"].items()}
    ab = _summary("continuous-ab", wall, res,
                  snap["fleet"]["avg_batch_fill"], n_requests)
    ab["split"] = {"prod": 0.9, "candidate": 0.1}
    ab["arm_requests"] = arm_requests
    # per-arm p99-vs-SLO: the router's hash split is pure, so each
    # request re-resolves to its arm post-hoc
    arm_lats: dict[str, list[float]] = {}
    for i, r in enumerate(res):
        mid = router.resolve("split", f"req-{i}")
        arm_lats.setdefault(mid, []).append(r.latency_s)
    ab["arms"] = {
        mid: slo_summary(lats, ab_registry.get(mid).slo)
        for mid, lats in sorted(arm_lats.items())
    }
    emit(f"serve/{cfg.name}/ab", wall / n_requests * 1e6,
         f"{n_requests / wall:.1f} req/s; arms {arm_requests}")

    results.append({
        "arch": cfg.name,
        "engine_batch": batch,
        "closed_loop_clients": n_clients,
        "backend": plan.backend,
        "bit_exact": True,  # asserted above before timing
        "slo_ms": SLO_MS,
        "speedup_continuous_over_static": speedup,
        "runs": [runs["static"], runs["continuous"], ab],
    })


def run(quick: bool = False, smoke: bool = False) -> None:
    from repro.configs import paper

    n_requests = 64 if smoke else (160 if quick else 384)
    reps = 1 if smoke else 5
    results: list[dict] = []
    if smoke:
        _bench_config(tiny_smoke_cfg(), batch=8, n_requests=n_requests,
                      reps=reps, results=results)
    else:
        for arch, scale, batch in CONFIGS:
            _bench_config(paper.get(arch, scale=scale), batch=batch,
                          n_requests=n_requests, reps=reps, results=results)
    payload = {
        "benchmark": "serve_fleet",
        "backend": jax.default_backend(),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("serve/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer requests/reps")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config only (CI import-and-run gate)")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
