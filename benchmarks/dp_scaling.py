"""Data-parallel scaling benchmark: per-step time vs device count.

Times one jit-compiled training step at device counts {1, 2, 4} — the
single-device ``les.train_step`` baseline against the sharded
``dp.dp_train_step`` under each reducer (``psum`` / ``ring`` /
``compress``).  Because forced host devices only exist if ``XLA_FLAGS``
is set before backend init, each device count runs in a *worker
subprocess* (``--worker``); the parent aggregates.

Before timing, every variant is **parity-gated**: one step of each
reducer must produce bitwise-identical parameters to the single-device
step on the full batch (the suite's core claim — the benchmark never
times two computations that disagree).  Timing is interleaved min-of-N
with ABBA ordering (``common.time_paired``): co-tenant CPU noise only
inflates samples, so the per-variant minimum bounds the intrinsic cost.

On CPU host devices the "scaling" is honest about being a *semantics*
demo: shards share the same socket, so don't expect linear speedup —
the interesting outputs are the reducer overheads relative to psum and
the parity gate itself.  Real scaling needs real chips; the numbers
here track the *relative* cost of the three exact reduction schedules.

Emits ``name,us_per_call,derived`` CSV rows and ``BENCH_parallel.json``.

    PYTHONPATH=src python -m benchmarks.dp_scaling [--quick] [--smoke]

``--smoke`` (tiny config, devices {1, 2}) is the CI import-and-run gate.
"""

from __future__ import annotations

import argparse
import json
import os
import subprocess
import sys
import tempfile

JSON_PATH = "BENCH_parallel.json"

# (config name, batch) — batch must divide by every device count
CONFIGS = [("tiny", 8), ("vgg8b", 16)]
DEVICE_COUNTS = [1, 2, 4]


def _build(config: str, batch: int):
    import jax
    import jax.numpy as jnp
    import numpy as np

    from benchmarks.common import tiny_smoke_cfg
    from repro.core import les

    if config == "tiny":
        cfg = tiny_smoke_cfg()
    else:
        from repro.configs import paper
        cfg = paper.get("vgg8b", scale=0.0625, input_shape=(16, 16, 3))
    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, batch), jnp.int32)
    return cfg, state, x, labels


def _worker(out_path: str, config: str, batch: int, devices: int,
            iters: int) -> None:
    """Runs inside a subprocess whose XLA_FLAGS already forced ``devices``
    host devices; writes the timing dict as JSON to ``out_path``."""
    import functools

    import jax
    import numpy as np

    from benchmarks.common import time_paired
    from repro.core import les
    from repro.parallel import dp

    assert jax.device_count() == devices, (jax.device_count(), devices)
    cfg, state, x, labels = _build(config, batch)
    key = jax.random.PRNGKey(2)

    steps = {"single": jax.jit(functools.partial(les.train_step, cfg=cfg))}
    mesh = dp.data_mesh(devices)
    for reducer in dp.REDUCERS:
        steps[reducer] = dp.make_dp_train_step(cfg, mesh, dp_reduce=reducer)

    # parity gate: every reducer's post-step params ≡ the single-device step
    ref = jax.tree_util.tree_leaves(
        steps["single"](state, x=x, labels=labels, key=key)[0].params)
    for reducer in dp.REDUCERS:
        got = jax.tree_util.tree_leaves(
            steps[reducer](state, x=x, labels=labels, key=key)[0].params)
        for a, b in zip(got, ref):
            assert np.asarray(a).dtype == np.asarray(b).dtype
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b),
                                          err_msg=f"{reducer} @ {devices}dev")

    us = time_paired(steps, state, x=x, labels=labels, key=key, iters=iters)
    with open(out_path, "w") as f:
        json.dump({"us_per_step": us, "bit_exact": True}, f)


def run(quick: bool = False, smoke: bool = False) -> None:
    from benchmarks.common import emit

    iters = 3 if (quick or smoke) else 10
    configs = [("tiny", 8)] if smoke else CONFIGS
    device_counts = [1, 2] if smoke else DEVICE_COUNTS
    results: list[dict] = []
    for config, batch in configs:
        base_us = None
        for devices in device_counts:
            env = dict(os.environ)
            env["XLA_FLAGS"] = (
                env.get("XLA_FLAGS", "").replace(
                    "--xla_force_host_platform_device_count", "--removed") +
                f" --xla_force_host_platform_device_count={devices}"
            ).strip()
            with tempfile.NamedTemporaryFile(suffix=".json",
                                             delete=False) as tf:
                out_path = tf.name
            try:
                proc = subprocess.run(
                    [sys.executable, "-m", "benchmarks.dp_scaling",
                     "--worker", "--out", out_path, "--config", config,
                     "--batch", str(batch), "--devices", str(devices),
                     "--iters", str(iters)],
                    env=env, capture_output=True, text=True, timeout=1800)
                if proc.returncode != 0:
                    raise RuntimeError(
                        f"dp_scaling worker ({config}, {devices}dev) "
                        f"failed:\n{proc.stdout}\n{proc.stderr}")
                with open(out_path) as f:
                    us = json.load(f)["us_per_step"]
            finally:
                os.unlink(out_path)
            if base_us is None:
                base_us = us["single"]
            for variant, t in sorted(us.items()):
                emit(f"parallel/{config}/{devices}dev/{variant}", t,
                     f"batch {batch}; {base_us / t:.2f}x vs 1dev single")
            results.append({
                "config": config, "devices": devices, "batch": batch,
                "us_per_step": us,
                "speedup_vs_single_1dev":
                    {m: base_us / t for m, t in us.items()},
                "bit_exact": True,  # parity-gated in the worker
            })
    payload = {
        "benchmark": "dp_scaling",
        "reducers": ["psum", "ring", "compress"],
        "timing": "interleaved min-of-N (ABBA) per worker subprocess; "
                  "every reducer parity-gated bitwise against the "
                  "single-device step before timing",
        "note": "CPU host devices share one socket — relative reducer "
                "cost is meaningful, absolute scaling needs real chips",
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("parallel/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer timing iters")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config, 1-2 devices (CI gate)")
    ap.add_argument("--worker", action="store_true")
    ap.add_argument("--out")
    ap.add_argument("--config", default="tiny")
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--devices", type=int, default=1)
    ap.add_argument("--iters", type=int, default=3)
    args = ap.parse_args()
    if args.worker:
        _worker(args.out, args.config, args.batch, args.devices, args.iters)
    else:
        run(quick=args.quick, smoke=args.smoke)
