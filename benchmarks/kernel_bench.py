"""Kernel microbenchmarks: fused nitro_matmul / integer_sgd vs the unfused
reference pipeline.

Wall-clock here is the CPU oracle path (Pallas interpret mode is a Python
interpreter, not a perf signal); the TPU-relevant derived metric is the
HBM-traffic ratio of fused vs unfused, which is architectural:

    unfused: write z(int32) + read z + write z* + read z* + write act
    fused  : write act only                     (plus the same A/W reads)
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn
from repro.core.activations import nitro_relu
from repro.core.numerics import int_matmul
from repro.core.scaling import linear_scale_factor, scale_forward
from repro.kernels.integer_sgd.ref import integer_sgd_ref
from repro.kernels.nitro_matmul.ref import nitro_matmul_ref


def run():
    rng = np.random.default_rng(0)
    for m, k, n in ((256, 512, 256), (512, 1024, 512)):
        x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int32)
        sf = linear_scale_factor(k)

        @jax.jit
        def unfused(x, w):
            z = int_matmul(x, w)
            z_star = scale_forward(z, sf)
            return nitro_relu(z_star)

        @jax.jit
        def fused(x, w):
            return nitro_matmul_ref(x, w, sf=sf)

        us_unfused = time_fn(unfused, x, w)
        us_fused = time_fn(fused, x, w)
        # HBM bytes for the z-tensor round-trips (int32) vs fused epilogue
        unfused_z_bytes = m * n * 4 * 4 + m * n * 4  # z w/r, z* w/r, act w
        fused_z_bytes = m * n * 4
        emit(f"kernel/nitro_matmul/{m}x{k}x{n}/unfused", us_unfused,
             f"z_hbm_bytes={unfused_z_bytes}")
        emit(f"kernel/nitro_matmul/{m}x{k}x{n}/fused", us_fused,
             f"z_hbm_bytes={fused_z_bytes};traffic_ratio="
             f"{unfused_z_bytes / fused_z_bytes:.1f}x")

    # IntegerSGD fused update
    w_t = jnp.asarray(rng.integers(-30000, 30000, (1 << 20,)), jnp.int32)
    g_t = jnp.asarray(rng.integers(-(1 << 22), 1 << 22, (1 << 20,)), jnp.int32)
    upd = jax.jit(lambda w, g: integer_sgd_ref(w, g, 512, 3000))
    us = time_fn(upd, w_t, g_t)
    emit("kernel/integer_sgd/1M-params", us,
         "fused_streams=3;unfused_streams=5;traffic_ratio=1.67x")


if __name__ == "__main__":
    run()
