"""Autotuner gain benchmark: tuned-vs-default tiles + int8-vs-int32 MXU path.

Two questions, answered on both paper CNN topologies:

  1. **Tile search** — for every tunable fused problem of the compiled
     inference plan (``kernels.autotune.plan_shapes``), what did the
     tile search win over ``DEFAULT_TILES``?  Default and candidates are
     timed in ONE interleaved (ABBA min-of-N) ``time_paired`` session and
     the winner is the argmin, so ``tuned_us <= default_us`` by
     construction — the recorded gain is the search's own measurement,
     not a re-run that co-tenant noise could flip.  Every candidate is
     parity-gated bitwise against the reference oracle *inside*
     ``tune()`` before it may be timed.

  2. **int8 MXU path** — for every ``operand_dtype='auto'``-eligible plan
     step (int8-narrowed incoming activation × int8 frozen weight), the
     same int8-stored operands are timed through ``operand_dtype='int8'``
     (dots issued on int8 operands, int32 accumulation) against the
     ``'int32'`` escape hatch (operands lifted first).  Outputs are
     asserted bit-identical before timing.  The whole-plan comparison
     (``compile_plan(operand_dtype='auto')`` vs ``'int32'``) rides along.

Also proves the cache contract: after tuning, a second whole-plan
resolution is measurement-free (every key already in the cache) and every
per-problem ``resolve_tiles`` is a counter-verified cache hit.

Emits ``name,us_per_call,derived`` CSV rows on stdout *and*
``BENCH_autotune.json`` in the CWD.

    PYTHONPATH=src python -m benchmarks.autotune_gain [--quick] [--smoke]
"""

from __future__ import annotations

import argparse
import functools
import json
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_paired, tiny_smoke_cfg

JSON_PATH = "BENCH_autotune.json"

# (arch, scale, batch) — the two paper CNN topologies, matching
# benchmarks/conv_stream.py so the suites describe the same models
CONFIGS = [
    ("vgg8b", 0.5, 8),
    ("vgg11b", 0.5, 4),
]

#: row count for the per-layer int8-vs-int32 linear timings — the plan
#: batch gives single-digit GEMM rows, far below timer resolution, so
#: linear layers are timed at a serving-sized row count (recorded per row)
LINEAR_INT8_ROWS = 1024


def _counter(reg, name: str) -> int:
    fam = reg.json_snapshot()[name]
    return sum(int(s["value"]) for s in fam["samples"])


def _tile_search(plan, batch: int, cache, iters: int, rows: list) -> None:
    from repro.kernels.autotune import plan_shapes, tune

    for p in plan_shapes(plan, batch):
        winner, times = tune(
            p["op"], p["shape"], dtype=p["dtype"], backend=plan.backend,
            conv_mode=p["conv_mode"], fuse_bwd=p["fuse_bwd"], cache=cache,
            iters=iters)
        if winner is None:
            continue  # no tile knobs on this (op, backend, mode)
        default_us = next(iter(times.values()))  # default probes first
        tuned_us = times[winner]
        emit(f"autotune/{plan.name}/{p['op']}/{'x'.join(map(str, p['shape']))}",
             tuned_us,
             f"default {default_us:.1f} us, {len(times)} configs, "
             f"{default_us / tuned_us:.2f}x")
        rows.append({
            "op": p["op"],
            "shape": list(p["shape"]),
            "conv_mode": p["conv_mode"] or None,
            "configs_timed": len(times),
            "default_us": default_us,
            "tuned_us": tuned_us,
            "speedup_tuned_over_default": default_us / tuned_us,
            # argmin over a pool that includes the default, one session
            "tuned_no_worse_than_default": tuned_us <= default_us,
            "winner": {k: v for k, v in winner.to_json().items()},
            "bit_exact": True,  # parity-gated inside tune()
        })


def _cache_proof(plan, batch: int, cache, iters: int) -> dict:
    """Second resolution must be measurement-free: all keys hit the cache."""
    from repro.kernels.autotune import (configure, plan_shapes, resolve_tiles,
                                        set_metrics, tune_plan)
    from repro.obs.metrics import MetricRegistry

    retuned = tune_plan(plan, batch, cache=cache, iters=iters)
    reg = MetricRegistry()
    set_metrics(reg)
    configure(cache)
    try:
        for p in plan_shapes(plan, batch):
            resolve_tiles(p["op"], p["shape"], dtype=p["dtype"],
                          backend=plan.backend, conv_mode=p["conv_mode"],
                          fuse_bwd=p["fuse_bwd"])
        hits = _counter(reg, "kernel_tile_cache_hits_total")
        misses = _counter(reg, "kernel_tile_cache_misses_total")
    finally:
        configure(None)
        set_metrics(None)
    return {
        "entries": len(cache),
        "second_resolution_hits": hits,
        "second_resolution_misses_untunable": misses,
        # tune_plan skips measurement for cached keys; every tunable key
        # was cached by the first pass, so the re-tune returned the same
        # winners without timing a single candidate
        "second_resolution_measurement_free": all(
            k in cache for k in retuned),
    }


def _int8_layers(plan, batch: int, iters: int, rows: list) -> None:
    from repro.kernels.nitro_conv.ops import fused_conv
    from repro.kernels.nitro_matmul.ops import fused_matmul

    rng = np.random.default_rng(2)
    shape = tuple(int(d) for d in plan.input_shape)
    for i, (w, meta) in enumerate(zip(plan.weights, plan.metas)):
        if meta.kind == "conv":
            in_shape = (batch, *shape)
            h, w_sp, _ = shape
            f = int(w.shape[-1])
            shape = (h // 2, w_sp // 2, f) if meta.pool else (h, w_sp, f)
        else:
            feat = 1
            for d in shape:
                feat *= d
            in_shape = (LINEAR_INT8_ROWS, feat)
            shape = (int(w.shape[-1]),)
        if meta.operand_dtype != "int8":
            continue
        x8 = jnp.asarray(rng.integers(-127, 128, in_shape), jnp.int8)
        if meta.kind == "conv":
            run = functools.partial(
                fused_conv, sf=meta.sf, alpha_inv=meta.alpha_inv,
                apply_relu=meta.apply_relu, pool=meta.pool,
                out_dtype=jnp.dtype(meta.out_dtype), backend=plan.backend,
                conv_mode=meta.conv_mode)
        else:
            run = functools.partial(
                fused_matmul, sf=meta.sf, alpha_inv=meta.alpha_inv,
                apply_relu=meta.apply_relu,
                out_dtype=jnp.dtype(meta.out_dtype), backend=plan.backend)
        fns = {
            od: jax.jit(functools.partial(run, operand_dtype=od))
            for od in ("int8", "int32")
        }
        out8, out32 = fns["int8"](x8, w), fns["int32"](x8, w)
        np.testing.assert_array_equal(np.asarray(out8), np.asarray(out32))
        us = time_paired(fns, x8, w, iters=iters)
        emit(f"autotune/{plan.name}/int8/step{i}-{meta.kind}", us["int8"],
             f"int32 {us['int32']:.1f} us, "
             f"{us['int32'] / us['int8']:.2f}x, alpha_inv={meta.alpha_inv}")
        rows.append({
            "step": i,
            "kind": meta.kind,
            "alpha_inv": meta.alpha_inv,
            "operand_shape": list(in_shape),
            "weight_shape": [int(d) for d in w.shape],
            "int8_us": us["int8"],
            "int32_us": us["int32"],
            "speedup_int8_over_int32": us["int32"] / us["int8"],
            "int8_wins": us["int8"] <= us["int32"],
            "bit_exact": True,  # asserted above before timing
        })


def _bench_config(cfg, batch: int, iters: int, results: list) -> None:
    from repro.core import les, model as M
    from repro.infer.export import freeze
    from repro.infer.plan import compile_plan
    from repro.kernels.autotune import TileCache

    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    fm = freeze(state, cfg)
    cache = TileCache(os.path.join(tempfile.mkdtemp(prefix="autotune_"),
                                   "tile_cache.json"))

    plans = {
        od: compile_plan(fm, operand_dtype=od) for od in ("auto", "int32")
    }
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)
    oracle = M.frozen_forward(state.params, cfg, x)
    for plan in plans.values():  # parity gate before any timing
        np.testing.assert_array_equal(np.asarray(plan.logits(x)),
                                      np.asarray(oracle))

    tile_rows: list[dict] = []
    _tile_search(plans["auto"], batch, cache, iters, tile_rows)
    cache_stats = _cache_proof(plans["auto"], batch, cache, iters)

    int8_rows: list[dict] = []
    _int8_layers(plans["auto"], batch, iters, int8_rows)
    plan_us = time_paired({od: p.logits for od, p in plans.items()},
                          x, iters=iters)
    emit(f"autotune/{cfg.name}/plan/int8-auto", plan_us["auto"],
         f"int32 escape hatch {plan_us['int32']:.1f} us, "
         f"{plan_us['int32'] / plan_us['auto']:.2f}x")

    results.append({
        "arch": cfg.name,
        "batch": batch,
        "backend": plans["auto"].backend,
        "tiles": tile_rows,
        "tuned_no_worse_everywhere": all(
            r["tuned_no_worse_than_default"] for r in tile_rows),
        "cache": cache_stats,
        "int8_layers": int8_rows,
        "int8_eligible_steps": sum(
            1 for m in plans["auto"].metas if m.operand_dtype == "int8"),
        "int8_win_layers": sum(1 for r in int8_rows if r["int8_wins"]),
        "plan_us": plan_us,
        "plan_speedup_int8_over_int32": plan_us["int32"] / plan_us["auto"],
        "bit_exact": True,  # every comparison above parity-gated first
    })


def run(quick: bool = False, smoke: bool = False) -> None:
    from repro.configs import paper
    from repro.kernels.nitro_matmul.ops import resolve_backend

    iters = 2 if (quick or smoke) else 5
    results: list[dict] = []
    if smoke:
        _bench_config(tiny_smoke_cfg(), batch=8, iters=iters, results=results)
    else:
        for arch, scale, batch in CONFIGS:
            cfg = paper.get(arch, scale=scale)
            _bench_config(cfg, batch=batch, iters=iters, results=results)
    payload = {
        "benchmark": "autotune_gain",
        "backend": jax.default_backend(),
        "kernel_backend_auto": resolve_backend("auto"),
        "speedup_estimator": (
            "interleaved min-of-N, ABBA order, default + candidates in one "
            "paired session — the tuned result is the argmin of a pool "
            "containing the default, so tuned_us <= default_us structurally; "
            "int8-vs-int32 rows time the SAME int8-stored operands through "
            "both operand paths after asserting bitwise-equal outputs"
        ),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("autotune/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer timing iters")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config only (CI import-and-run gate)")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
