"""Paper Fig. 2 + Table 9 ablations: weight-decay rates and the d_lr knob.

Fig 2-left: η_inv^fw / η_inv^lr jointly shrink the mean |W| of the forward
conv layers (strong decay < weak decay < no decay).
Fig 2-right / Table 9: d_lr under/overfitting trade-off (reduced sweep).
"""

from __future__ import annotations

import functools
from dataclasses import replace

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_paper_config
from repro.core import les
from repro.data import synthetic


def _mean_abs_fw_weight(state) -> float:
    w = state.params["blocks"][0]["fw"]["w"].astype(jnp.float32)
    return float(jnp.mean(jnp.abs(w)))


def run(steps: int = 120, batch: int = 64):
    ds = synthetic.make_image_dataset("tiles32", n_train=1024, n_test=256)
    base = get_paper_config("vgg8b", scale=0.125)

    # Fig 2-left: decay sweep
    for name, eta_fw, eta_lr in (
        ("no-decay", 0, 0),
        ("weak", 30000, 8000),
        ("strong", 8000, 2000),
    ):
        cfg = replace(base, eta_fw=eta_fw, eta_lr=eta_lr)
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg))
        k = 0
        while k < steps:
            for x, y in synthetic.batches(ds.x_train, ds.y_train, batch, seed=k):
                if k >= steps:
                    break
                state, _ = step(state, x=jnp.asarray(x), labels=jnp.asarray(y),
                                key=jax.random.PRNGKey(k))
                k += 1
        emit(f"fig2-left/decay={name}", 0.0,
             f"mean_abs_fw_weight={_mean_abs_fw_weight(state):.1f}")

    # Fig 2-right: d_lr sweep
    for d_lr in (64, 512, 4096):
        blocks = tuple(
            replace(b, d_lr=d_lr) if b.kind == "conv" else b
            for b in base.blocks
        )
        cfg = replace(base, blocks=blocks)
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg))
        k = 0
        while k < steps:
            for x, y in synthetic.batches(ds.x_train, ds.y_train, batch, seed=k):
                if k >= steps:
                    break
                state, _ = step(state, x=jnp.asarray(x), labels=jnp.asarray(y),
                                key=jax.random.PRNGKey(k))
                k += 1
        correct = sum(
            int(les.eval_step(state, cfg, jnp.asarray(ds.x_test[i:i+batch]),
                              jnp.asarray(ds.y_test[i:i+batch])))
            for i in range(0, len(ds.x_test) - batch + 1, batch))
        n = (len(ds.x_test) // batch) * batch
        emit(f"fig2-right/d_lr={d_lr}", 0.0, f"test_acc={correct/n:.4f}")


if __name__ == "__main__":
    run()
