"""Training-step benchmark: fused vs unfused forward, δ path, and optimiser.

Times one jit-compiled ``les.train_step`` (the full fwd+bwd step) in
four variants at a CPU-feasible scale of the paper's VGG8B/VGG11B
configs:

  * ``fused_opt``   — everything fused *including the optimiser*
                      (``fuse_opt=True``): the IntegerSGD update runs as
                      the grad_W kernels' flush epilogue, so grad_W never
                      materialises in HBM — 3 HBM streams per forward-layer
                      weight update (x, δ in; W′ out) instead of the split
                      path's 5+ (grad_W out, then W + grad_W in, W′ out);
  * ``fused``       — fused forward + fused backward (``fuse_bwd=True``):
                      the default path, with the NITRO-ReLU-bwd/STE
                      prologue inside the gradient kernels, optimiser
                      applied from the materialised gradient;
  * ``bwd_unfused`` — fused forward, unfused δ path (``fuse_bwd=False``):
                      the jnp ReLU-bwd + STE materialise the masked δ
                      before the gradient matmuls;
  * ``unfused``     — the fully unfused matmul → Scaling → ReLU reference
                      composition on both passes.

Timing is interleaved min-of-N with ABBA ordering (``common.time_paired``)
— this container's CPU swings ~2× with co-tenant load, and the minimum
bounds the intrinsic cost while interference only inflates samples.
Before timing, all variants are checked to produce bit-identical
parameters after one step — the benchmark never compares two computations
that disagree.

Emits the usual ``name,us_per_call,derived`` CSV rows on stdout *and*
machine-readable ``BENCH_train.json`` in the CWD (the artifact README's
training-speed claims reference).

    PYTHONPATH=src python -m benchmarks.train_step [--quick] [--smoke]

``--smoke`` runs a tiny 8×8 config in seconds — the CI gate
(tools/ci_check.sh) uses it to keep the benchmark import-and-run path
exercised on every commit.
"""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_paired, tiny_smoke_cfg

JSON_PATH = "BENCH_train.json"

# (arch, scale, batch) — paper CNN topologies at CI-feasible width
CONFIGS = [
    ("vgg8b", 0.0625, 16),
    ("vgg11b", 0.0625, 8),
]

# variant → (fused forward, fused backward, fused optimiser)
VARIANTS = {
    "fused_opt": (True, True, True),
    "fused": (True, True, False),
    "bwd_unfused": (True, False, False),
    "unfused": (False, False, False),
}

#: HBM tensor streams per forward-layer weight update: the fused epilogue
#: reads x/δ and writes W′ (W is read inside the same kernel pass); the
#: split path additionally writes grad_W and re-reads W + grad_W in the
#: standalone update.  Structural counts, not measurements.
HBM_STREAMS = {"fused_opt": 3, "unfused_opt": 5}


def _bench_config(cfg, batch: int, iters: int, results: list) -> None:
    from repro.core import les, model as M

    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, batch), jnp.int32)
    key = jax.random.PRNGKey(2)

    steps = {
        mode: jax.jit(functools.partial(
            les.train_step, cfg=cfg, fused=fwd, fuse_bwd=bwd, fuse_opt=fopt))
        for mode, (fwd, bwd, fopt) in VARIANTS.items()
    }

    # parity gate: one step, bit-identical parameters across all variants
    out = {m: fn(state, x=x, labels=labels, key=key) for m, fn in steps.items()}
    ref = jax.tree_util.tree_leaves(out["fused"][0].params)
    for m, (st, _) in out.items():
        for pv, pr in zip(jax.tree_util.tree_leaves(st.params), ref):
            np.testing.assert_array_equal(np.asarray(pv), np.asarray(pr),
                                          err_msg=m)
    del out  # keep the timed heap free of three full parameter trees

    us = time_paired(steps, state, x=x, labels=labels, key=key, iters=iters)
    speedup = us["unfused"] / us["fused"] if us["fused"] else 0.0
    bwd_speedup = us["bwd_unfused"] / us["fused"] if us["fused"] else 0.0
    opt_speedup = us["fused"] / us["fused_opt"] if us["fused_opt"] else 0.0
    for m in VARIANTS:
        emit(f"train/{cfg.name}/{m}", us[m],
             f"batch {batch}; {us[m] / batch:.1f} us/sample")
    emit(f"train/{cfg.name}/speedup", 0.0,
         f"{speedup:.2f}x fused/unfused (interleaved min-of-N)")
    emit(f"train/{cfg.name}/bwd_speedup", 0.0,
         f"{bwd_speedup:.2f}x fused-δ/unfused-δ path")
    emit(f"train/{cfg.name}/opt_speedup", 0.0,
         f"{opt_speedup:.2f}x fused-opt/split-opt path")

    results.append({
        "arch": cfg.name,
        "batch": batch,
        "params": M.count_params(state.params),
        "us_per_step": {m: us[m] for m in us},
        "us_per_sample": {m: us[m] / batch for m in us},
        "speedup_fused_over_unfused": speedup,
        "speedup_fused_bwd_over_unfused_bwd": bwd_speedup,
        "speedup_fused_opt_over_fused": opt_speedup,
        "hbm_streams_per_weight_update": dict(HBM_STREAMS),
        # timing outcome — shape-checked only (like meets_target), never
        # value-pinned: machine contention can legitimately flip it
        "fused_opt_no_worse_than_unfused": us["fused_opt"] <= us["fused"],
        "bit_exact": True,  # asserted above before timing
    })


def run(quick: bool = False, smoke: bool = False) -> None:
    from repro.configs import paper
    from repro.kernels.nitro_matmul.ops import resolve_backend

    iters = 3 if (quick or smoke) else 10
    results: list[dict] = []
    if smoke:
        _bench_config(tiny_smoke_cfg(), batch=8, iters=iters, results=results)
    else:
        for arch, scale, batch in CONFIGS:
            cfg = paper.get(arch, scale=scale)
            _bench_config(cfg, batch=batch, iters=iters, results=results)
    payload = {
        "benchmark": "train_step",
        "backend": jax.default_backend(),
        "kernel_backend_auto": resolve_backend("auto"),
        "variants": {m: {"fused_fwd": f, "fuse_bwd": b, "fuse_opt": o}
                     for m, (f, b, o) in VARIANTS.items()},
        "speedup_estimator": (
            "interleaved min-of-N, ABBA order — co-tenant CPU noise only "
            "inflates samples, so the per-variant minimum bounds the "
            "intrinsic step cost; on CPU all variants resolve to the "
            "reference backend and land near parity, while the structural "
            "win (no HBM round-trip of the post-ReLU-bwd δ) shows on the "
            "TPU kernel path"
        ),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("train/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer timing iters")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config only (CI import-and-run gate)")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
