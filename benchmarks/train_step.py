"""Training-step benchmark: fused vs unfused forward on the paper CNNs.

Times one jit-compiled ``les.train_step`` with the forward pass routed
through the fused ``nitro_matmul`` entry point (``fused=True``, the
default) against the unfused matmul → NITRO Scaling → NITRO-ReLU
reference composition (``fused=False``), at a CPU-feasible scale of the
paper's VGG8B/VGG11B configs.  Before timing, the two paths are checked
to produce bit-identical parameters after one step — the benchmark never
compares two computations that disagree.

Emits the usual ``name,us_per_call,derived`` CSV rows on stdout *and*
machine-readable ``BENCH_train.json`` in the CWD (the artifact README's
training-speed claims reference).

    PYTHONPATH=src python -m benchmarks.train_step [--quick] [--smoke]

``--smoke`` runs a tiny 8×8 config in seconds — the CI gate
(tools/ci_check.sh) uses it to keep the benchmark import-and-run path
exercised on every commit.
"""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_fn, tiny_smoke_cfg

JSON_PATH = "BENCH_train.json"

# (arch, scale, batch) — paper CNN topologies at CI-feasible width
CONFIGS = [
    ("vgg8b", 0.0625, 16),
    ("vgg11b", 0.0625, 8),
]


def _bench_config(cfg, batch: int, iters: int, results: list) -> None:
    from repro.core import les, model as M

    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, batch), jnp.int32)
    key = jax.random.PRNGKey(2)

    steps = {
        mode: jax.jit(functools.partial(les.train_step, cfg=cfg, fused=f))
        for mode, f in (("fused", True), ("unfused", False))
    }

    # parity gate: one step, bit-identical parameters
    out = {m: fn(state, x=x, labels=labels, key=key) for m, fn in steps.items()}
    for pf, pu in zip(jax.tree_util.tree_leaves(out["fused"][0].params),
                      jax.tree_util.tree_leaves(out["unfused"][0].params)):
        np.testing.assert_array_equal(np.asarray(pf), np.asarray(pu))

    us = {
        m: time_fn(fn, state, x=x, labels=labels, key=key,
                   iters=iters, warmup=1)
        for m, fn in steps.items()
    }
    speedup = us["unfused"] / us["fused"] if us["fused"] else 0.0
    for m in ("fused", "unfused"):
        emit(f"train/{cfg.name}/{m}", us[m],
             f"batch {batch}; {us[m] / batch:.1f} us/sample")
    emit(f"train/{cfg.name}/speedup", 0.0, f"{speedup:.2f}x fused/unfused")

    results.append({
        "arch": cfg.name,
        "batch": batch,
        "params": M.count_params(state.params),
        "us_per_step": {m: us[m] for m in us},
        "us_per_sample": {m: us[m] / batch for m in us},
        "speedup_fused_over_unfused": speedup,
        "bit_exact": True,  # asserted above before timing
    })


def run(quick: bool = False, smoke: bool = False) -> None:
    from repro.configs import paper
    from repro.kernels.nitro_matmul.ops import resolve_backend

    iters = 3 if (quick or smoke) else 10
    results: list[dict] = []
    if smoke:
        _bench_config(tiny_smoke_cfg(), batch=8, iters=iters, results=results)
    else:
        for arch, scale, batch in CONFIGS:
            cfg = paper.get(arch, scale=scale)
            _bench_config(cfg, batch=batch, iters=iters, results=results)
    payload = {
        "benchmark": "train_step",
        "backend": jax.default_backend(),
        "kernel_backend_auto": resolve_backend("auto"),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("train/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer timing iters")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config only (CI import-and-run gate)")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
