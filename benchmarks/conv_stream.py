"""Streaming vs materialised conv benchmark: train forward + infer plan.

Times the two conv data paths the ``kernels.nitro_conv`` dispatcher
offers — ``conv_mode='stream'`` (implicit im2col: patch blocks formed
band-by-band, the (N·H·W, K²·C) HBM patch matrix never exists) against
``conv_mode='materialise'`` (explicit im2col + fused ``nitro_matmul``) —
through both consumers:

  * the fused *training forward* (``model.forward(fused=True)``);
  * the compiled *inference plan* (``infer.plan.ExecutionPlan``).

Before timing, both paths are checked bit-identical (activations, cached
z*, and plan logits vs the independent ``frozen_forward`` oracle) — the
benchmark never compares two computations that disagree.  The per-layer
HBM-traffic estimates from ``plan.summary()`` are aggregated into the
JSON so the ~K² conv-input saving is machine-checkable next to the wall
times.

Emits ``name,us_per_call,derived`` CSV rows on stdout *and*
``BENCH_conv.json`` in the CWD.

    PYTHONPATH=src python -m benchmarks.conv_stream [--quick] [--smoke]

``--smoke`` runs the shared tiny 8×8 config in seconds — the CI gate
(tools/ci_check.sh) uses it to keep this path exercised on every commit.
"""

from __future__ import annotations

import argparse
import functools
import json

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_paired, tiny_smoke_cfg

JSON_PATH = "BENCH_conv.json"

# (arch, scale, batch) — paper CNN topologies at CPU-feasible width
CONFIGS = [
    ("vgg8b", 0.5, 8),
    ("vgg11b", 0.5, 4),
]

MODES = ("stream", "materialise")


def _assert_trees_equal(a, b) -> None:
    for la, lb in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_array_equal(np.asarray(la), np.asarray(lb))


def _bench_config(cfg, batch: int, iters: int, results: list) -> None:
    from repro.core import les, model as M
    from repro.infer.export import freeze
    from repro.infer.plan import compile_plan

    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)

    # ---- training forward -------------------------------------------------
    fwds = {
        mode: jax.jit(functools.partial(
            M.forward, cfg=cfg, train=False, fused=True, conv_mode=mode))
        for mode in MODES
    }
    out = {m: fn(state.params, x=x) for m, fn in fwds.items()}
    # parity gate: logits, activations AND the cached z* bit-identical
    _assert_trees_equal(out["stream"][:3], out["materialise"][:3])
    del out  # both modes' full caches would otherwise sit on the heap
    # (hundreds of MB at scale 0.5) and distort the timing below

    fwd_us = time_paired(fwds, state.params, x=x, iters=iters)
    fwd_speedup = fwd_us["materialise"] / fwd_us["stream"]

    # ---- inference plan ---------------------------------------------------
    fm = freeze(state, cfg)
    plans = {m: compile_plan(fm, conv_mode=m) for m in MODES}
    oracle = M.frozen_forward(state.params, cfg, x)
    for m, plan in plans.items():
        np.testing.assert_array_equal(
            np.asarray(plan.logits(x)), np.asarray(oracle))
    plan_us = time_paired(
        {m: plans[m].logits for m in MODES}, x, iters=iters
    )
    plan_speedup = plan_us["materialise"] / plan_us["stream"]

    # ---- HBM-traffic estimate (per sample, conv steps) --------------------
    hbm = {"stream": 0, "materialise": 0}
    conv_ratios = []
    for row in plans["stream"].summary():
        per_sample = row["hbm_per_sample_bytes"]
        hbm["stream"] += per_sample["stream"]
        hbm["materialise"] += per_sample["materialise"]
        if row["kind"] == "conv":
            conv_ratios.append(row["stream_saving_ratio"])

    for part, us, speedup in (("train_fwd", fwd_us, fwd_speedup),
                              ("plan", plan_us, plan_speedup)):
        for m in MODES:
            emit(f"conv/{cfg.name}/{part}/{m}", us[m],
                 f"batch {batch}; {us[m] / batch:.1f} us/sample")
        emit(f"conv/{cfg.name}/{part}/speedup", 0.0,
             f"{speedup:.2f}x stream/materialise (interleaved min-of-N)")
    emit(f"conv/{cfg.name}/hbm", 0.0,
         f"{hbm['materialise']}B->{hbm['stream']}B per sample; "
         f"conv-layer ratios {conv_ratios}")

    results.append({
        "arch": cfg.name,
        "batch": batch,
        "train_fwd_us": fwd_us,
        "train_fwd_speedup_stream_over_materialise": fwd_speedup,
        "plan_us": plan_us,
        "plan_speedup_stream_over_materialise": plan_speedup,
        "hbm_per_sample_bytes": hbm,
        "hbm_saving_ratio": hbm["materialise"] / max(hbm["stream"], 1),
        "conv_layer_saving_ratios": conv_ratios,
        "bit_exact": True,  # asserted above before timing
    })


def run(quick: bool = False, smoke: bool = False) -> None:
    from repro.configs import paper
    from repro.kernels.nitro_matmul.ops import resolve_backend

    iters = 3 if (quick or smoke) else 30
    results: list[dict] = []
    if smoke:
        _bench_config(tiny_smoke_cfg(), batch=8, iters=iters, results=results)
    else:
        for arch, scale, batch in CONFIGS:
            cfg = paper.get(arch, scale=scale)
            _bench_config(cfg, batch=batch, iters=iters, results=results)
    payload = {
        "benchmark": "conv_stream",
        "backend": jax.default_backend(),
        "kernel_backend_auto": resolve_backend("auto"),
        "speedup_estimator": (
            "interleaved min-of-N, ABBA order — this container's CPU "
            "swings ~2x with co-tenant load, and the minimum bounds the "
            "intrinsic cost (interference only inflates samples); on CPU "
            "the two conv modes run the same GEMMs and land at parity, "
            "while the hbm_per_sample_bytes column is the structural ~K^2 "
            "input-traffic cut the TPU kernel path realises"
        ),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("conv/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer timing iters")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config only (CI import-and-run gate)")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
