"""Paper Table 2: CNN architectures — NITRO-D vs FP LES vs FP BP on VGG8B.

CIFAR-10 stand-in: ``tiles32``.  Width-scaled VGG8B (CPU budget); the
relative ordering (FP BP ≥ FP LES ≥ NITRO-D, gaps of a few points) is the
paper's Table-2 claim.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_paper_config
from repro.core import fp_baselines as fp
from repro.core import les
from repro.data import synthetic


def run(steps: int = 250, scale: float = 0.25, batch: int = 64):
    ds = synthetic.make_image_dataset("tiles32", n_train=2048, n_test=512)
    cfg = get_paper_config("vgg8b", scale=scale)

    # --- NITRO-D (integer-only; needs a longer step budget — paper trains
    # 150 epochs; plateau lr schedule applied late) ---
    nitro_steps = steps * 6
    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(functools.partial(les.train_step, cfg=cfg))
    k = 0
    while k < nitro_steps:
        for x, y in synthetic.batches(ds.x_train, ds.y_train, batch, seed=k):
            if k >= nitro_steps:
                break
            state, _ = step(state, x=jnp.asarray(x), labels=jnp.asarray(y),
                            key=jax.random.PRNGKey(k))
            k += 1
            if k in (int(nitro_steps * 0.6), int(nitro_steps * 0.85)):
                state = les.reduce_lr_on_plateau(state, True)
    nitro_correct = 0
    for i in range(0, len(ds.x_test) - batch + 1, batch):
        nitro_correct += int(les.eval_step(
            state, cfg, jnp.asarray(ds.x_test[i:i+batch]),
            jnp.asarray(ds.y_test[i:i+batch])))
    n_eval = (len(ds.x_test) // batch) * batch
    nitro_acc = nitro_correct / n_eval
    us = time_fn(step, state, x=jnp.asarray(ds.x_train[:batch]),
                 labels=jnp.asarray(ds.y_train[:batch]),
                 key=jax.random.PRNGKey(0), iters=3)
    emit(f"table2/vgg8b-s{scale}/nitro-d", us, f"test_acc={nitro_acc:.4f}")

    xs = jnp.asarray(ds.x_train, jnp.float32) / 64.0
    xt = jnp.asarray(ds.x_test, jnp.float32) / 64.0

    # --- FP LES ---
    params = fp.init_fp_params(jax.random.PRNGKey(0), cfg)
    step_les = jax.jit(functools.partial(fp.train_step_les, cfg=cfg, lr=2e-2))
    for k in range(steps):
        i = (k * batch) % (len(ds.x_train) - batch)
        params, _ = step_les(params, x=xs[i:i+batch],
                             labels=jnp.asarray(ds.y_train[i:i+batch]),
                             key=jax.random.PRNGKey(k))
    les_correct = sum(
        int(fp.accuracy_fp(params, cfg, xt[i:i+batch],
                           jnp.asarray(ds.y_test[i:i+batch])))
        for i in range(0, len(ds.x_test) - batch + 1, batch))
    les_acc = les_correct / n_eval
    us_les = time_fn(step_les, params, x=xs[:batch],
                     labels=jnp.asarray(ds.y_train[:batch]),
                     key=jax.random.PRNGKey(0), iters=3)
    emit(f"table2/vgg8b-s{scale}/fp-les", us_les, f"test_acc={les_acc:.4f}")

    # --- FP BP ---
    params = fp.init_fp_params(jax.random.PRNGKey(1), cfg)
    opt_state = fp.adam_init(params)
    step_bp = jax.jit(functools.partial(fp.train_step_bp, cfg=cfg))
    for k in range(steps):
        i = (k * batch) % (len(ds.x_train) - batch)
        params, opt_state, _ = step_bp(params, opt_state, x=xs[i:i+batch],
                                       labels=jnp.asarray(ds.y_train[i:i+batch]),
                                       key=jax.random.PRNGKey(k))
    bp_correct = sum(
        int(fp.accuracy_fp(params, cfg, xt[i:i+batch],
                           jnp.asarray(ds.y_test[i:i+batch])))
        for i in range(0, len(ds.x_test) - batch + 1, batch))
    bp_acc = bp_correct / n_eval
    us_bp = time_fn(step_bp, params, opt_state, x=xs[:batch],
                    labels=jnp.asarray(ds.y_train[:batch]),
                    key=jnp.asarray(jax.random.PRNGKey(0)), iters=3)
    emit(f"table2/vgg8b-s{scale}/fp-bp", us_bp, f"test_acc={bp_acc:.4f}")
    emit(f"table2/vgg8b-s{scale}/degradation-vs-les", 0.0,
         f"acc_gap={les_acc - nitro_acc:+.4f}")


if __name__ == "__main__":
    run()
