"""Inference serving benchmark: engine throughput + latency percentiles.

Measures the full deploy path — freeze → fused plan → VisionEngine — for
the paper CNN configs at a CPU-feasible scale.  Two load shapes per
config:

  * ``offline``  — all requests submitted at once (max batching, the
                   throughput ceiling);
  * ``trickle``  — a handful of concurrent synchronous clients (the
                   latency-bound regime real traffic looks like).

Emits the usual ``name,us_per_call,derived`` CSV rows on stdout *and*
machine-readable ``BENCH_infer.json`` next to the CWD, so downstream
tooling doesn't have to parse the CSV.
"""

from __future__ import annotations

import json
import threading
import time

import jax
import numpy as np

from benchmarks.common import emit
from repro.serving.stats import latency_summary_ms

JSON_PATH = "BENCH_infer.json"

# (arch, scale, engine batch) — small enough for CI, same topology as paper
CONFIGS = [
    ("vgg8b", 0.0625, 16),
    ("vgg11b", 0.0625, 16),
]


def _bench_config(arch: str, scale: float, batch: int, n_requests: int,
                  results: list):
    from repro.configs import paper
    from repro.core import les
    from repro.infer import compile_plan, freeze
    from repro.serving.vision import VisionEngine

    cfg = paper.get(arch, scale=scale)
    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    fm = freeze(state, cfg)
    plan = compile_plan(fm)
    rng = np.random.default_rng(1)
    images = [rng.integers(-127, 128, cfg.input_shape).astype(np.int32)
              for _ in range(n_requests)]

    # ---- offline: submit everything, drain ------------------------------
    with VisionEngine(plan, batch_size=batch, max_wait_ms=2.0) as engine:
        engine.classify(images[:1])  # compile outside the clock
        t0 = time.perf_counter()
        futs = [engine.submit(img) for img in images]
        lats = sorted(f.result().latency_s for f in futs)
        wall = time.perf_counter() - t0
        fill = engine.stats.avg_batch_fill
    rps = n_requests / wall
    emit(f"infer/{arch}/offline", wall / n_requests * 1e6,
         f"{rps:.1f} req/s; fill {fill:.2f}")
    offline = {
        "mode": "offline", "requests": n_requests, "wall_s": wall,
        "requests_per_s": rps, "batch_fill": fill,
        "latency_ms": latency_summary_ms(lats),
    }

    # ---- trickle: 4 sync clients ----------------------------------------
    n_clients = 4
    lat_lock = threading.Lock()
    client_lats: list[float] = []

    with VisionEngine(plan, batch_size=batch, max_wait_ms=2.0) as engine:
        engine.classify(images[:1])

        def client(w):
            for i in range(w, n_requests, n_clients):
                lat = engine.submit(images[i]).result().latency_s
                with lat_lock:
                    client_lats.append(lat)

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(w,))
                   for w in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        fill = engine.stats.avg_batch_fill
    summary = latency_summary_ms(client_lats)
    emit(f"infer/{arch}/trickle", wall / n_requests * 1e6,
         f"p50 {summary['p50']:.1f}ms; p99 {summary['p99']:.1f}ms")
    trickle = {
        "mode": "trickle", "clients": n_clients, "requests": n_requests,
        "wall_s": wall, "requests_per_s": n_requests / wall,
        "batch_fill": fill,
        "latency_ms": summary,
    }

    results.append({
        "arch": arch, "scale": scale, "engine_batch": batch,
        "backend": plan.backend,
        "weight_bytes": fm.num_bytes(),
        "runs": [offline, trickle],
    })


def run(quick: bool = False) -> None:
    n_requests = 48 if quick else 160
    results: list[dict] = []
    for arch, scale, batch in CONFIGS:
        _bench_config(arch, scale, batch, n_requests, results)
    payload = {
        "benchmark": "serve_infer",
        "backend": jax.default_backend(),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("infer/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    run()
