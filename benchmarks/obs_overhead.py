"""Observability overhead benchmark: telemetry + tracing must stay cheap.

Two gates, both parity-checked before any clock starts:

  * **train telemetry** — one jit-compiled ``les.train_step`` with
    ``telemetry=True`` vs off, interleaved min-of-N with ABBA ordering
    (``common.time_paired``).  The parity gate asserts the two produce
    bit-identical parameters first — the benchmark never times a
    computation that changed results.  The headline number is the
    overhead **at the default sampling cadence** (``--telemetry-every
    50``: only every 50th step pays the telemetry cost, so the effective
    overhead is raw/50) with the raw every-step overhead reported
    alongside;
  * **fleet tracing** — a burst of requests through two ``FleetEngine``
    instances over one shared registry (same compiled plan, so jit cost
    is paid once at warmup), one with a ``Tracer`` attached and one
    without, alternating which engine is timed first per round
    (min-of-N).  The parity gate asserts both return identical labels.

Emits the usual CSV rows on stdout and machine-readable
``BENCH_obs.json`` in the CWD; the target recorded there is **< 3%
overhead at default sampling** for telemetry and for tracing.

    PYTHONPATH=src python -m benchmarks.obs_overhead [--quick] [--smoke]

``--smoke`` runs the tiny 8×8 config in seconds — the CI gate
(tools/ci_check.sh) uses it to keep this path exercised on every commit.
"""

from __future__ import annotations

import argparse
import functools
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from benchmarks.common import emit, time_paired, tiny_smoke_cfg

JSON_PATH = "BENCH_obs.json"

# launch/train.py's suggested --telemetry-every cadence: the effective
# overhead of sampled telemetry is raw/DEFAULT_SAMPLING
DEFAULT_SAMPLING = 50
OVERHEAD_TARGET = 0.03  # <3% at default sampling

# (arch, scale, batch) — same CI-feasible paper scales as train_step
CONFIGS = [
    ("vgg8b", 0.0625, 16),
]


def _overhead(us_on: float, us_off: float) -> float:
    return (us_on - us_off) / us_off if us_off else 0.0


def _bench_train(cfg, batch: int, iters: int, results: list) -> None:
    from repro.core import les

    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(1)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)
    labels = jnp.asarray(rng.integers(0, cfg.num_classes, batch), jnp.int32)
    key = jax.random.PRNGKey(2)

    steps = {
        "telemetry_off": jax.jit(functools.partial(les.train_step, cfg=cfg)),
        "telemetry_on": jax.jit(functools.partial(
            les.train_step, cfg=cfg, telemetry=True)),
    }

    # parity gate: telemetry must not perturb the trajectory
    st_off, _ = steps["telemetry_off"](state, x=x, labels=labels, key=key)
    st_on, _, _ = steps["telemetry_on"](state, x=x, labels=labels, key=key)
    for pv, pr in zip(jax.tree_util.tree_leaves(st_on.params),
                      jax.tree_util.tree_leaves(st_off.params)):
        np.testing.assert_array_equal(np.asarray(pv), np.asarray(pr),
                                      err_msg="telemetry changed the step")
    del st_off, st_on

    us = time_paired(steps, state, x=x, labels=labels, key=key, iters=iters)
    raw = _overhead(us["telemetry_on"], us["telemetry_off"])
    sampled = raw / DEFAULT_SAMPLING
    emit(f"obs/train/{cfg.name}/telemetry_off", us["telemetry_off"],
         f"batch {batch}")
    emit(f"obs/train/{cfg.name}/telemetry_on", us["telemetry_on"],
         f"raw overhead {raw * 100:.2f}%")
    emit(f"obs/train/{cfg.name}/overhead", 0.0,
         f"{sampled * 100:.3f}% at 1/{DEFAULT_SAMPLING} sampling "
         f"(target <{OVERHEAD_TARGET * 100:.0f}%)")
    results.append({
        "kind": "train_telemetry",
        "arch": cfg.name,
        "batch": batch,
        "us_per_step": us,
        "overhead_raw": raw,
        "sampling_interval": DEFAULT_SAMPLING,
        "overhead_at_default_sampling": sampled,
        "meets_target": sampled < OVERHEAD_TARGET,
        "bit_exact": True,  # asserted above before timing
    })


def _bench_fleet(cfg, iters: int, requests: int, results: list) -> None:
    from repro.core import les
    from repro.infer import freeze
    from repro.obs import Tracer
    from repro.serving import FleetEngine, ModelRegistry

    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    fm = freeze(state, cfg)
    registry = ModelRegistry()
    registry.register("m", fm)
    rng = np.random.default_rng(3)
    images = [rng.integers(-127, 128, cfg.input_shape).astype(np.int32)
              for _ in range(requests)]

    tracer = Tracer()
    engines = {
        "trace_off": FleetEngine(registry, batch_size=8),
        "trace_on": FleetEngine(registry, batch_size=8, tracer=tracer),
    }
    try:
        # warmup (jit compile — the plan is shared, so this pays once)
        # doubles as the parity gate: tracing must not change results
        labels = {m: e.classify(images[:8], model="m")
                  for m, e in engines.items()}
        assert labels["trace_on"] == labels["trace_off"], \
            "tracing changed the served labels"

        names = list(engines)
        best = {m: float("inf") for m in names}
        for i in range(iters):
            for m in names if i % 2 == 0 else reversed(names):
                t0 = time.perf_counter()
                engines[m].classify(images, model="m")
                best[m] = min(best[m], (time.perf_counter() - t0) * 1e6)
    finally:
        for e in engines.values():
            e.close()

    raw = _overhead(best["trace_on"], best["trace_off"])
    emit(f"obs/fleet/{cfg.name}/trace_off", best["trace_off"],
         f"{requests} requests")
    emit(f"obs/fleet/{cfg.name}/trace_on", best["trace_on"],
         f"overhead {raw * 100:.2f}%; {tracer.recorded} spans recorded")
    results.append({
        "kind": "fleet_tracing",
        "arch": cfg.name,
        "requests": requests,
        "us_per_burst": best,
        "overhead": raw,
        "meets_target": raw < OVERHEAD_TARGET,
        "spans_recorded": tracer.recorded,
        "labels_identical": True,  # asserted above before timing
    })


def run(quick: bool = False, smoke: bool = False) -> None:
    from repro.configs import paper

    iters = 3 if (quick or smoke) else 10
    requests = 32 if (quick or smoke) else 256
    results: list[dict] = []
    if smoke:
        cfg = tiny_smoke_cfg()
        _bench_train(cfg, batch=8, iters=iters, results=results)
        _bench_fleet(cfg, iters=iters, requests=requests, results=results)
    else:
        for arch, scale, batch in CONFIGS:
            cfg = paper.get(arch, scale=scale)
            _bench_train(cfg, batch=batch, iters=iters, results=results)
            _bench_fleet(cfg, iters=iters, requests=requests, results=results)
    payload = {
        "benchmark": "obs_overhead",
        "backend": jax.default_backend(),
        "sampling_interval": DEFAULT_SAMPLING,
        "overhead_target": OVERHEAD_TARGET,
        "estimator": (
            "interleaved min-of-N, ABBA order — co-tenant CPU noise only "
            "inflates samples, so the per-variant minimum bounds the "
            "intrinsic cost; telemetry overhead is reported raw "
            "(every step) and at the default 1/50 sampling cadence, "
            "which is what launch/train.py --telemetry-every actually "
            "pays"
        ),
        "results": results,
    }
    with open(JSON_PATH, "w") as f:
        json.dump(payload, f, indent=2)
    emit("obs/json", 0.0, JSON_PATH)


if __name__ == "__main__":
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true", help="fewer timing iters")
    ap.add_argument("--smoke", action="store_true",
                    help="tiny config only (CI import-and-run gate)")
    args = ap.parse_args()
    run(quick=args.quick, smoke=args.smoke)
