"""Paper Table 1: MLP architectures — NITRO-D (integer-only) vs FP BP.

Offline stand-in for MNIST/FashionMNIST: the procedural ``digits28`` set
(DESIGN.md §7).  The paper's claim validated here is *relative*: NITRO-D
trains MLPs to within a few points of float backprop using only integers.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit, time_fn
from repro.configs import get_paper_config
from repro.core import fp_baselines as fp
from repro.core import les
from repro.data import synthetic


def _train_nitro(cfg, ds, steps, batch=64):
    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(functools.partial(les.train_step, cfg=cfg))
    k = 0
    while k < steps:
        for x, y in synthetic.batches(ds.x_train, ds.y_train, batch, seed=k):
            if k >= steps:
                break
            state, _ = step(state, x=jnp.asarray(x), labels=jnp.asarray(y),
                            key=jax.random.PRNGKey(k))
            k += 1
            # paper Appendix D: reduce lr ×3 on plateau (fixed late-train
            # schedule points stand in for the accuracy-plateau trigger)
            if k in (int(steps * 0.6), int(steps * 0.85)):
                state = les.reduce_lr_on_plateau(state, True)
    correct = 0
    for i in range(0, len(ds.x_test) - batch + 1, batch):
        correct += int(les.eval_step(state, cfg, jnp.asarray(ds.x_test[i:i+batch]),
                                     jnp.asarray(ds.y_test[i:i+batch])))
    n = (len(ds.x_test) // batch) * batch
    us = time_fn(step, state, x=jnp.asarray(ds.x_train[:batch]),
                 labels=jnp.asarray(ds.y_train[:batch]),
                 key=jax.random.PRNGKey(0), iters=5)
    return correct / n, us


def _train_fp_bp(cfg, ds, steps, batch=64):
    params = fp.init_fp_params(jax.random.PRNGKey(0), cfg)
    opt_state = fp.adam_init(params)
    step = jax.jit(functools.partial(fp.train_step_bp, cfg=cfg))
    xs = jnp.asarray(ds.x_train, jnp.float32) / 64.0
    xt = jnp.asarray(ds.x_test, jnp.float32) / 64.0
    k = 0
    while k < steps:
        for i in range(0, len(ds.x_train) - batch + 1, batch):
            if k >= steps:
                break
            params, opt_state, _ = step(
                params, opt_state, x=xs[i:i+batch],
                labels=jnp.asarray(ds.y_train[i:i+batch]),
                key=jax.random.PRNGKey(k))
            k += 1
    correct = 0
    for i in range(0, len(ds.x_test) - batch + 1, batch):
        correct += int(fp.accuracy_fp(params, cfg, xt[i:i+batch],
                                      jnp.asarray(ds.y_test[i:i+batch])))
    n = (len(ds.x_test) // batch) * batch
    us = time_fn(step, params, opt_state, x=xs[:batch],
                 labels=jnp.asarray(ds.y_train[:batch]),
                 key=jax.random.PRNGKey(0), iters=5)
    return correct / n, us


def run(steps: int = 600):
    """``steps`` scales the whole table; integer SGD needs many more steps
    than Adam (the paper trains 150 epochs) — per-arch budgets below."""
    ds = synthetic.make_image_dataset("digits28", n_train=4096, n_test=1024)
    ds = synthetic.flatten_for_mlp(ds)
    budgets = {"mlp1": steps * 16, "mlp3": steps * 5}
    for arch in ("mlp1", "mlp3"):
        cfg = get_paper_config(arch)
        acc, us = _train_nitro(cfg, ds, budgets[arch])
        emit(f"table1/{arch}/nitro-d", us,
             f"test_acc={acc:.4f};steps={budgets[arch]}")
        acc_fp, us_fp = _train_fp_bp(cfg, ds, steps)
        emit(f"table1/{arch}/fp-bp", us_fp, f"test_acc={acc_fp:.4f};steps={steps}")
        emit(f"table1/{arch}/gap", 0.0, f"acc_gap={acc_fp - acc:+.4f}")


if __name__ == "__main__":
    run()
