"""Paper Fig. 3 / §E.3: trained weights fit int16; intermediates fit int32.

Trains a reduced VGG8B and reports the max |w| per layer group plus the
peak pre-activation magnitude observed — the memory-footprint claim that
motivates int16 weight storage.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from benchmarks.common import emit
from repro.configs import get_paper_config
from repro.core import les, model
from repro.data import synthetic


def run(steps: int = 200, batch: int = 64):
    ds = synthetic.make_image_dataset("tiles32", n_train=2048, n_test=256)
    cfg = get_paper_config("vgg8b", scale=0.25)
    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    step = jax.jit(functools.partial(les.train_step, cfg=cfg))
    k = 0
    while k < steps:
        for x, y in synthetic.batches(ds.x_train, ds.y_train, batch, seed=k):
            if k >= steps:
                break
            state, _ = step(state, x=jnp.asarray(x), labels=jnp.asarray(y),
                            key=jax.random.PRNGKey(k))
            k += 1

    int16_ok = True
    for i, block in enumerate(state.params["blocks"]):
        fw = int(jnp.abs(block["fw"]["w"]).max())
        lr = int(jnp.abs(block["lr"]["w"]).max())
        int16_ok &= fw < 2**15 and lr < 2**15
        emit(f"fig3/block{i}", 0.0, f"max_abs_fw={fw};max_abs_lr={lr}")
    out_w = int(jnp.abs(state.params["output"]["w"]).max())
    int16_ok &= out_w < 2**15
    emit("fig3/output", 0.0, f"max_abs_w={out_w}")
    emit("fig3/int16_claim", 0.0, f"holds={int16_ok}")

    # intermediates stay within int32: probe pre-activations on a batch
    _, acts, _, _ = model.forward(
        state.params, cfg, jnp.asarray(ds.x_train[:batch]), train=False
    )
    peak = max(int(jnp.abs(a).max()) for a in acts)
    emit("fig3/peak_activation", 0.0, f"value={peak};int8_range={peak <= 127}")


if __name__ == "__main__":
    run()
