"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

    PYTHONPATH=src python -m benchmarks.run [--quick] [--only tableX]
"""

from __future__ import annotations

import argparse
import sys
import time
import traceback


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--quick", action="store_true",
                    help="fewer steps (CI-speed smoke of every table)")
    ap.add_argument("--only", default=None)
    args = ap.parse_args()

    from benchmarks import (
        ablations,
        autotune_gain,
        conv_stream,
        dp_scaling,
        kernel_bench,
        obs_overhead,
        roofline,
        serve_fleet,
        serve_infer,
        table1_mlp,
        table2_cnn,
        table8_lr,
        train_step,
        weight_range,
    )

    q = args.quick
    suites = [
        ("kernel", lambda: kernel_bench.run()),
        ("train", lambda: train_step.run(quick=q)),
        ("conv", lambda: conv_stream.run(quick=q)),
        ("autotune", lambda: autotune_gain.run(quick=q)),
        ("infer", lambda: serve_infer.run(quick=q)),
        ("serve", lambda: serve_fleet.run(quick=q)),
        ("obs", lambda: obs_overhead.run(quick=q)),
        ("parallel", lambda: dp_scaling.run(quick=q)),
        ("table1", lambda: table1_mlp.run(steps=150 if q else 600)),
        ("table2", lambda: table2_cnn.run(steps=80 if q else 250)),
        ("table8", lambda: table8_lr.run(steps=60 if q else 150)),
        ("ablations", lambda: ablations.run(steps=50 if q else 120)),
        ("fig3", lambda: weight_range.run(steps=60 if q else 200)),
        ("roofline", lambda: roofline.run()),
    ]
    print("name,us_per_call,derived")
    failures = 0
    for name, fn in suites:
        if args.only and args.only not in name:
            continue
        t0 = time.monotonic()
        try:
            fn()
        except Exception:
            failures += 1
            traceback.print_exc()
            print(f"{name}/ERROR,0.0,exception")
        print(f"{name}/elapsed,{(time.monotonic()-t0)*1e6:.0f},wall_time")
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
