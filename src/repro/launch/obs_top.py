"""obs_top — live terminal dashboard over the repro.obs surfaces.

``htop`` for an integer-only training run: one screen that answers "is
this run healthy *right now*" without grepping JSONL.  Three panels,
each fed by an existing observability surface (this tool adds **no** new
instrumentation — it is a pure reader):

  * **train health** — tails the run's ``metrics.jsonl`` (what
    ``launch/train.py --telemetry-every N`` appends): per-layer bit-
    occupancy sparklines, msb/int32-headroom, saturation fractions,
    dead-unit fractions, optimiser scalars;
  * **alerts** — the tail is replayed through the same
    ``obs.health.default_rules()`` engine the trainer runs, so the
    active-alert list here is exactly what the run printed;
  * **fleet** — scrapes a serving process's ``/metrics.json``
    (``--fleet-url``, e.g. ``serve_vision --metrics-port``) or reads a
    dumped snapshot (``--fleet-json``): per-model queue depth, batch
    fill, and p99-vs-SLO from the deadline-slack histograms.

Modes:

  * ``--once`` — render one deterministic plain-text frame and exit
    (post-mortem over a finished run; golden-file tested, so the frame
    contains no wall-clock);
  * live (default) — redraw every ``--interval`` seconds, with curses
    when stdout is a tty and a plain scrolling fallback otherwise.

Usage::

    python -m repro.launch.obs_top --metrics ckpt/metrics.jsonl --once
    python -m repro.launch.obs_top --metrics ckpt/metrics.jsonl \
        --fleet-url http://127.0.0.1:9100/metrics.json
"""

from __future__ import annotations

import argparse
import json
import math
import sys
import time
import urllib.request

from repro.obs import health as H

#: Eight-level bar glyphs for bit-occupancy sparklines.
SPARK = "▁▂▃▄▅▆▇█"

#: Sampled-step window the rule engine replays over (matches the
#: largest default rule window so hysteresis state is exact).
TAIL_STEPS = 64


def sparkline(counts) -> str:
    """Counts → one glyph per bucket, log-scaled (telemetry histograms
    span orders of magnitude; linear scaling flattens everything but the
    mode).  Zero stays visually empty (a space), so the *occupied
    envelope* — the thing the NITRO-D eye looks for — reads directly."""
    logs = [math.log1p(c) for c in counts]
    top = max(logs) or 1.0
    return "".join(
        " " if not v else SPARK[min(int(v / top * (len(SPARK) - 1)),
                                    len(SPARK) - 1)]
        for v in logs
    )


def read_jsonl_tail(path: str, *, steps: int = TAIL_STEPS) -> list[dict]:
    """The last ``steps`` sampled steps' rows from a telemetry JSONL."""
    with open(path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    grouped = H.group_steps(records)
    keep = {step for step, _ in grouped[-steps:]}
    return [r for r in records if int(r.get("step", -1)) in keep]


# ---------------------------------------------------------------------------
# Train panel
# ---------------------------------------------------------------------------


def render_train_panel(records: list[dict],
                       monitor: H.HealthMonitor) -> list[str]:
    """Per-layer table + optimiser scalars for the latest sampled step."""
    grouped = H.group_steps(records)
    if not grouped:
        return ["train: no telemetry rows yet"]
    step, rows = grouped[-1]
    lines = [
        f"train health — step {step} "
        f"({len(grouped)} sampled step(s) in window)",
        f"{'layer':<10} {'kind':<7} {'w.msb':>5} {'g.msb':>5} "
        f"{'hdrm':>4} {'sat8%':>6} {'dead%':>6}  act bits 0..32",
    ]
    for layer in sorted(rows):
        row = rows[layer]
        if layer.startswith("_"):
            continue
        w, g, act = row.get("weight"), row.get("grad"), row.get("act")
        msbs = [t["msb"] for t in (w, g, act) if t]
        hdrm = H.INT32_BITS - max(msbs) if msbs else "-"
        sat8 = (f"{100 * act['sat_int8_frac']:.1f}" if act else "    -")
        dead = (f"{100 * row['dead_frac']:.1f}"
                if "dead_frac" in row else "    -")
        spark = sparkline(act["bit_hist"]) if act else ""
        lines.append(
            f"{layer:<10} {row.get('kind', '?'):<7} "
            f"{w['msb'] if w else '-':>5} {g['msb'] if g else '-':>5} "
            f"{hdrm:>4} {sat8:>6} {dead:>6}  {spark}"
        )
    opt = rows.get("_opt")
    if opt:
        scalars = " ".join(f"{k}={opt[k]}" for k in sorted(opt)
                           if k not in ("step", "layer"))
        lines.append(f"opt: {scalars}")
    dp = rows.get("_dp")
    if dp:
        fits = "yes" if dp.get("grad_fits_int16") else "NO"
        lines.append(f"dp:  shards={dp.get('shards')} "
                     f"grads fit int16 limbs: {fits}")
    return lines


def render_alerts_panel(monitor: H.HealthMonitor) -> list[str]:
    active = monitor.active_alerts()
    by_sev = monitor.summary()["by_severity"]
    fired = ", ".join(f"{k}={v}" for k, v in by_sev.items() if v) or "none"
    lines = [f"alerts — fired: {fired}; active: {len(active)}"]
    for a in active:
        lines.append(f"  {a.format()}")
    return lines


# ---------------------------------------------------------------------------
# Fleet panel (from a MetricRegistry JSON snapshot)
# ---------------------------------------------------------------------------


def quantile_from_buckets(buckets, count: int, q: float) -> float | None:
    """Upper-bound estimate of a quantile from cumulative buckets.

    The smallest bucket upper bound whose cumulative count reaches
    ``ceil(q·count)`` — the standard scrape-side histogram estimate
    (exact at bucket resolution; +Inf falls back to the last finite
    bound).  ``buckets`` is the JSON exposition: [[ub|"+Inf", cum], …].
    """
    if not count:
        return None
    rank = max(math.ceil(q * count), 1)
    last_finite = None
    for ub, cum in buckets:
        if ub == "+Inf":
            break
        last_finite = float(ub)
        if cum >= rank:
            return float(ub)
    return last_finite


def _samples(snapshot: dict, name: str) -> list[dict]:
    fam = snapshot.get(name)
    return fam["samples"] if fam else []


def _by_model(snapshot: dict, name: str) -> dict[str, dict]:
    return {s["labels"].get("model", ""): s
            for s in _samples(snapshot, name)}


def render_fleet_panel(snapshot: dict) -> list[str]:
    """Queue depth / batch fill / p99-vs-SLO from a ``json_snapshot``."""
    depth = _by_model(snapshot, "serve_queue_depth")
    requests = _by_model(snapshot, "serve_requests_total")
    deadlines = _by_model(snapshot, "serve_slo_deadline_seconds")
    slack = _by_model(snapshot, "serve_request_deadline_seconds")
    violations = _by_model(snapshot, "serve_slo_violations_total")

    lines = ["fleet"]
    fill = _samples(snapshot, "serve_batch_fill")
    if fill:
        s = fill[0]
        avg = s["sum"] / s["count"] if s["count"] else 0.0
        lines.append(f"batches: {s['count']}  avg fill {avg:.2f}")

    models = sorted(set(depth) | set(requests) | set(deadlines))
    models = [m for m in models if m]
    if models:
        lines.append(f"{'model':<12} {'queue':>5} {'reqs':>7} "
                     f"{'slo_ms':>7} {'p99_ms':>7} {'viol':>6}")
    for m in models:
        q = depth.get(m, {}).get("value", 0)
        n = requests.get(m, {}).get("value", 0)
        slo_s = deadlines.get(m, {}).get("value")
        slo_ms = f"{1e3 * slo_s:.1f}" if slo_s is not None else "-"
        p99_ms, viol = "-", "-"
        sl = slack.get(m)
        if sl and sl.get("count"):
            # p99 latency = 1st-percentile slack: latency = deadline − slack
            s01 = quantile_from_buckets(sl["buckets"], sl["count"], 0.01)
            if s01 is not None and slo_s is not None:
                p99_ms = f"{1e3 * (slo_s - s01):.1f}"
            v = violations.get(m, {}).get("value", 0)
            viol = f"{v}/{sl['count']}"
        lines.append(f"{m:<12} {q:>5} {n:>7} {slo_ms:>7} {p99_ms:>7} "
                     f"{viol:>6}")
    if len(lines) == 1:
        lines.append("no serving metrics in snapshot")
    return lines


def fetch_fleet_snapshot(url: str | None, path: str | None) -> dict | None:
    if path:
        with open(path) as f:
            return json.load(f)
    if url:
        with urllib.request.urlopen(url, timeout=5) as resp:
            return json.loads(resp.read().decode())
    return None


# ---------------------------------------------------------------------------
# Frame assembly + modes
# ---------------------------------------------------------------------------


def render_frame(metrics_path: str | None, fleet: dict | None) -> str:
    """One full dashboard frame as plain text (the golden-tested unit).

    Deliberately wall-clock-free: everything in the frame derives from
    the inputs, so the same jsonl + snapshot always render the same
    frame (what the golden-file test and ``--once`` rely on).
    """
    sections: list[list[str]] = []
    if metrics_path:
        records = read_jsonl_tail(metrics_path)
        monitor = H.HealthMonitor()
        monitor.observe_records(records)
        sections.append(render_train_panel(records, monitor))
        sections.append(render_alerts_panel(monitor))
    if fleet is not None:
        sections.append(render_fleet_panel(fleet))
    if not sections:
        sections.append(["nothing to show: pass --metrics and/or "
                         "--fleet-url/--fleet-json"])
    rule = "-" * 72
    body = f"\n{rule}\n".join("\n".join(s) for s in sections)
    return f"{rule}\n{body}\n{rule}"


def _live_loop(args) -> None:
    """Redraw loop: curses when interactive, scrolling frames otherwise."""

    def frame() -> str:
        try:
            fleet = fetch_fleet_snapshot(args.fleet_url, args.fleet_json)
        except OSError as e:
            fleet = None
            return render_frame(args.metrics, None) + f"\nfleet: {e}"
        return render_frame(args.metrics, fleet)

    if not sys.stdout.isatty():
        while True:
            print(frame(), flush=True)
            time.sleep(args.interval)

    import curses

    def ui(stdscr):
        curses.curs_set(0)
        stdscr.nodelay(True)
        while True:
            stdscr.erase()
            maxy, maxx = stdscr.getmaxyx()
            for y, line in enumerate(frame().splitlines()[:maxy - 1]):
                stdscr.addnstr(y, 0, line, maxx - 1)
            stdscr.addnstr(maxy - 1, 0, "q to quit", maxx - 1,
                           curses.A_REVERSE)
            stdscr.refresh()
            t_end = time.monotonic() + args.interval
            while time.monotonic() < t_end:
                if stdscr.getch() in (ord("q"), ord("Q")):
                    return
                time.sleep(0.05)

    curses.wrapper(ui)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="obs_top", description="live dashboard over repro.obs")
    ap.add_argument("--metrics",
                    help="telemetry JSONL from launch/train.py "
                         "--telemetry-every (tailed each frame)")
    ap.add_argument("--fleet-url",
                    help="a serving /metrics.json URL to scrape "
                         "(serve_vision --metrics-port)")
    ap.add_argument("--fleet-json",
                    help="a dumped /metrics.json snapshot file "
                         "(post-mortem alternative to --fleet-url)")
    ap.add_argument("--once", action="store_true",
                    help="render one frame and exit (deterministic "
                         "plain text; post-mortem mode)")
    ap.add_argument("--interval", type=float, default=2.0,
                    help="live-mode refresh seconds (default 2)")
    args = ap.parse_args(argv)

    if args.once:
        print(render_frame(args.metrics,
                           fetch_fleet_snapshot(args.fleet_url,
                                                args.fleet_json)))
        return 0
    try:
        _live_loop(args)
    except KeyboardInterrupt:
        pass
    return 0


if __name__ == "__main__":
    sys.exit(main())
