"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (not a module-level constant) so that
importing this module never touches JAX device state — the dry-run sets
``XLA_FLAGS=--xla_force_host_platform_device_count=512`` before any JAX
import, and tests/benches must keep seeing 1 device.

Topology (TPU v5e pods):
  single-pod : (data=16, model=16)            = 256 chips
  multi-pod  : (pod=2, data=16, model=16)     = 512 chips; the ``pod`` axis
               is pure data parallelism over DCN.
"""

from __future__ import annotations

import jax


# jax < 0.5 has neither jax.sharding.AxisType nor make_mesh(axis_types=…);
# newer jax wants the explicit Auto axis type.  One compat entry point.
_HAS_AXIS_TYPES = hasattr(jax.sharding, "AxisType")


def make_mesh(shape, axes):
    """Version-compat ``jax.make_mesh`` with Auto axis types when supported."""
    if _HAS_AXIS_TYPES:
        return jax.make_mesh(
            shape, axes,
            axis_types=(jax.sharding.AxisType.Auto,) * len(axes),
        )
    return jax.make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_test_mesh(data: int = 1, model: int = 1):
    """Tiny mesh over however many (host) devices the test session has."""
    return make_mesh((data, model), ("data", "model"))


def mesh_num_chips(mesh) -> int:
    n = 1
    for s in mesh.devices.shape:
        n *= s
    return n
