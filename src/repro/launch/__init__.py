"""Launchers: production mesh, multi-pod dry-run, train/serve CLIs
(`serve` = LM engine, `serve_vision` = integer CNN engine)."""
