import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    + os.environ.get("XLA_FLAGS", "")
)
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count at first init).  Do not reorder.

"""Multi-pod dry-run (deliverable e).

For every (architecture × input-shape × mesh) cell:

    with mesh:
        lowered  = jax.jit(step, in_shardings=…, out_shardings=…).lower(**specs)
        compiled = lowered.compile()
        print(compiled.memory_analysis())   # proves it fits per-chip HBM
        print(compiled.cost_analysis())     # raw XLA cost model numbers

plus the while-aware HLO analysis (hlo_analysis.py) that feeds the
EXPERIMENTS.md §Roofline table.  Results are written as one JSON per cell.

Usage:
    python -m repro.launch.dryrun --arch qwen3-32b --shape train_4k \
        [--multi-pod] [--causal-mode triangle] [--out results/...json]
    python -m repro.launch.dryrun --sweep [--multi-pod] --out-dir results/dryrun
"""

import argparse
import json
import subprocess
import sys
import time
import traceback

HBM_PER_CHIP = 16 * 1024**3  # v5e: 16 GiB


def make_rules(cfg, *, mode: str, multi_pod: bool, batch: int) -> dict:
    from repro.parallel.sharding import serve_rules, train_rules
    from repro.train.trainer import resolved_rules

    base = train_rules(multi_pod) if mode == "train" else serve_rules(multi_pod)
    if cfg.dp_only and mode == "train":
        base["batch"] = ("pod", "data") if multi_pod else ("data", "model")
        base["p_fsdp"] = ("data", "model")
        base["seq_sp"] = None      # model axis is consumed by the batch
        base["expert_cap"] = None
    rules = resolved_rules(cfg, base)
    if batch == 1:
        rules["batch"] = None  # long_500k: single request, nothing to shard
    return rules


def _serving_params_struct(cfg):
    """Abstract params for serving cells: fp32 master weights are cast to
    bf16 at serving load (production convention) — halves weight HBM."""
    import jax
    import jax.numpy as jnp

    from repro.models import transformer as T

    p = jax.eval_shape(lambda: T.init_params(jax.random.PRNGKey(0), cfg))
    return jax.tree_util.tree_map(
        lambda s: jax.ShapeDtypeStruct(
            s.shape, jnp.bfloat16 if s.dtype == jnp.float32 else s.dtype
        ),
        p,
    )


def lower_cell(arch: str, shape_name: str, *, multi_pod: bool,
               causal_mode: str = "masked", return_rules: bool = False,
               cfg_overrides: dict | None = None,
               rule_patch: dict | None = None):
    """Build + lower + compile one cell; returns (compiled, info dict).

    ``cfg_overrides``: dataclass field replacements (hillclimb levers like
    int8_matmul / les_groups / remat).  ``rule_patch``: logical-axis rule
    replacements applied after the arch's own overrides.
    """
    import dataclasses

    import jax

    from repro.configs import get_config
    from repro.launch import shapes as S
    from repro.launch.mesh import make_production_mesh, mesh_num_chips
    from repro.models import transformer as T
    from repro.train import trainer

    cfg = get_config(arch)
    if cfg_overrides:
        cfg = dataclasses.replace(cfg, **cfg_overrides)
    cell = S.cell(cfg, shape_name)
    if not cell.applicable:
        return None, {"arch": arch, "shape": shape_name,
                      "skipped": True, "reason": cell.skip_reason}

    mesh = make_production_mesh(multi_pod=multi_pod)
    rules = make_rules(cfg, mode=cell.kind if cell.kind == "train" else "serve",
                       multi_pod=multi_pod, batch=cell.batch)
    if rule_patch:
        rules.update(rule_patch)

    t0 = time.monotonic()
    if cell.kind == "train":
        specs = S.train_batch_specs(cfg, cell.batch, cell.seq)
        shapes_arg = {k: v.shape for k, v in specs.items()}
        with mesh:
            fn = trainer.build_train_step(
                cfg, mesh, rules, shapes=shapes_arg, causal_mode=causal_mode
            )
            state = trainer.abstract_state(jax.random.PRNGKey(0), cfg)
            lowered = fn.lower(state, specs)
    elif cell.kind == "prefill":
        specs = S.prefill_batch_specs(cfg, cell.batch, cell.seq)
        shapes_arg = {k: v.shape for k, v in specs.items()}
        cache = S.abstract_cache(cfg, cell.batch, cell.seq)
        with mesh:
            fn = trainer.build_prefill(cfg, mesh, rules, shapes=shapes_arg)
            params = _serving_params_struct(cfg)
            lowered = fn.lower(params, specs, cache)
    else:  # decode
        cache = S.abstract_cache(cfg, cell.batch, cell.seq)
        toks = S.decode_token_specs(cell.batch)
        enc = S.enc_out_specs(cfg, cell.batch)
        with mesh:
            fn = trainer.build_decode_step(cfg, mesh, rules, has_enc=enc is not None)
            params = _serving_params_struct(cfg)
            args = (params, toks, cache) + ((enc,) if enc is not None else ())
            lowered = fn.lower(*args)
    lower_s = time.monotonic() - t0

    t0 = time.monotonic()
    compiled = lowered.compile()
    compile_s = time.monotonic() - t0

    info = {
        "arch": arch, "shape": shape_name, "multi_pod": multi_pod,
        "kind": cell.kind, "seq": cell.seq, "batch": cell.batch,
        "chips": mesh_num_chips(mesh), "causal_mode": causal_mode,
        "lower_s": round(lower_s, 1), "compile_s": round(compile_s, 1),
        "skipped": False,
        "_rules": rules,
    }
    return compiled, info


def _analytic_temp_bytes(cfg, info: dict, rules: dict) -> float:
    """First-principles per-chip workspace estimate for the TPU target.

    The XLA CPU backend stages bf16 buffers through f32 and materialises
    scatter index maps (neither exists on TPU), so its temp number is a
    conservative upper bound.  This estimate covers the real live set:
    per-layer carry saves (remat), gradient buffers, and a flat workspace.
    """
    shape = (2, 16, 16) if info["multi_pod"] else (16, 16)
    names = ("pod", "data", "model") if info["multi_pod"] else ("data", "model")
    size = dict(zip(names, shape))

    def shards(rule_key):
        axes = rules.get(rule_key)
        if axes is None:
            return 1
        if isinstance(axes, str):
            axes = (axes,)
        n = 1
        for a in axes:
            n *= size.get(a, 1)
        return n

    if info["kind"] == "train":
        carry = (
            cfg.num_layers * info["batch"] * info["seq"] * cfg.d_model * 2
            / shards("batch") / shards("seq_sp")
        )
        n_chips = 1
        for s in shape:
            n_chips *= s
        grads = 4.0 * cfg.param_count() / n_chips  # FSDP-sharded fp32 grads
        workspace = 2.0 * 1024**3
        return carry + grads + workspace
    return 2.0 * 1024**3  # serve: block workspace only (cache is an arg)


def analyze_cell(compiled, info: dict, rules: dict | None = None) -> dict:
    """memory_analysis + cost_analysis + while-aware roofline terms."""
    from repro.configs import get_config
    from repro.launch.hlo_analysis import analyze, roofline_terms

    ma = compiled.memory_analysis()
    per_chip = {
        "arguments_gib": ma.argument_size_in_bytes / 1024**3,
        "outputs_gib": ma.output_size_in_bytes / 1024**3,
        "temp_gib": ma.temp_size_in_bytes / 1024**3,
        "alias_gib": ma.alias_size_in_bytes / 1024**3,
    }
    # donated (aliased) buffers don't double-count against HBM
    live = (
        ma.argument_size_in_bytes + ma.output_size_in_bytes
        + ma.temp_size_in_bytes - ma.alias_size_in_bytes
    )
    per_chip["live_gib"] = live / 1024**3
    per_chip["fits_16gib_hbm"] = bool(live < HBM_PER_CHIP)
    if rules is not None:
        cfg_ = get_config(info["arch"])
        analytic = (
            ma.argument_size_in_bytes + ma.output_size_in_bytes
            - ma.alias_size_in_bytes
            + _analytic_temp_bytes(cfg_, info, rules)
        )
        per_chip["analytic_live_gib"] = analytic / 1024**3
        per_chip["analytic_fits_16gib"] = bool(analytic < HBM_PER_CHIP)
        per_chip["note"] = (
            "XLA temp is CPU-backend-conservative (bf16→f32 staging, "
            "scatter index maps); analytic_live is the TPU-target estimate"
        )

    ca = compiled.cost_analysis() or {}
    xla_cost = {
        "flops_once": float(ca.get("flops", -1.0)),
        "bytes_accessed_once": float(ca.get("bytes accessed", -1.0)),
        "note": "XLA cost_analysis counts while bodies once; see hlo_analysis",
    }

    costs = analyze(compiled.as_text())
    terms = roofline_terms(costs)

    cfg = get_config(info["arch"])
    n_active = cfg.active_param_count()
    tokens = info["batch"] * (info["seq"] if info["kind"] != "decode" else 1)
    fl_per_tok = 6 if info["kind"] == "train" else 2
    model_flops = fl_per_tok * n_active * tokens
    hlo_total = terms["flops_by_dtype"]
    hlo_global = sum(hlo_total.values()) * info["chips"]
    terms["model_flops"] = model_flops
    terms["model_over_hlo_flops"] = (
        model_flops / hlo_global if hlo_global else 0.0
    )
    terms["roofline_bound_s"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"]
    )
    # roofline fraction: useful model FLOP-time over the per-chip bound
    useful_s = (model_flops / info["chips"]) / 197e12
    terms["roofline_fraction"] = (
        useful_s / terms["roofline_bound_s"] if terms["roofline_bound_s"] else 0.0
    )
    return {**info, "memory": per_chip, "xla_cost": xla_cost, "roofline": terms}


def run_cell(arch: str, shape_name: str, *, multi_pod: bool,
             causal_mode: str = "masked", out: str | None = None,
             cfg_overrides: dict | None = None,
             rule_patch: dict | None = None) -> dict:
    compiled, info = lower_cell(
        arch, shape_name, multi_pod=multi_pod, causal_mode=causal_mode,
        cfg_overrides=cfg_overrides, rule_patch=rule_patch,
    )
    if cfg_overrides:
        info["cfg_overrides"] = {k: str(v) for k, v in cfg_overrides.items()}
    if rule_patch:
        info["rule_patch"] = {k: str(v) for k, v in rule_patch.items()}
    if compiled is None:
        result = info
    else:
        rules = info.pop("_rules", None)
        result = analyze_cell(compiled, info, rules)
        print(compiled.memory_analysis())
        if out:  # cache the HLO so analyzer upgrades re-parse, not recompile
            import gzip

            os.makedirs(os.path.dirname(out), exist_ok=True)
            with gzip.open(out.replace(".json", ".hlo.gz"), "wt") as f:
                f.write(compiled.as_text())
    if out:
        os.makedirs(os.path.dirname(out), exist_ok=True)
        with open(out, "w") as f:
            json.dump(result, f, indent=1)
    print(json.dumps({k: v for k, v in result.items() if k != "xla_cost"},
                     indent=1, default=str))
    return result


def sweep(out_dir: str, *, multi_pod: bool, archs=None, shapes=None,
          causal_mode: str = "masked", timeout: int = 3600):
    """Subprocess-per-cell sweep (isolation: one OOM/crash ≠ dead sweep)."""
    from repro.configs import list_archs
    from repro.launch.shapes import SHAPES

    archs = archs or list_archs()
    shapes = shapes or list(SHAPES)
    results = []
    for arch in archs:
        for shape_name in shapes:
            tag = f"{arch}_{shape_name}_{'pod2' if multi_pod else 'pod1'}"
            out = os.path.join(out_dir, tag + ".json")
            if os.path.exists(out):
                print(f"[skip] {tag} (exists)")
                continue
            cmd = [
                sys.executable, "-m", "repro.launch.dryrun",
                "--arch", arch, "--shape", shape_name, "--out", out,
                "--causal-mode", causal_mode,
            ]
            if multi_pod:
                cmd.append("--multi-pod")
            print(f"[run ] {tag}")
            try:
                proc = subprocess.run(
                    cmd, capture_output=True, text=True, timeout=timeout
                )
                if proc.returncode != 0:
                    print(f"[FAIL] {tag}:\n{proc.stdout[-2000:]}\n{proc.stderr[-2000:]}")
                    results.append({"cell": tag, "ok": False})
                else:
                    results.append({"cell": tag, "ok": True})
            except subprocess.TimeoutExpired:
                print(f"[TIME] {tag}")
                results.append({"cell": tag, "ok": False, "timeout": True})
    return results


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch")
    ap.add_argument("--shape")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--causal-mode", default="masked",
                    choices=["masked", "triangle"])
    ap.add_argument("--out")
    ap.add_argument("--sweep", action="store_true")
    ap.add_argument("--out-dir", default="results/dryrun")
    ap.add_argument("--int8", action="store_true",
                    help="NITRO int8 numerics on LM matmuls")
    ap.add_argument("--les-groups", type=int, default=0)
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--cast-once", action="store_true",
                    help="cast fp32 params to bf16 once per step")
    ap.add_argument("--moe-shard", action="store_true",
                    help="pin MoE dispatch buffers to the expert sharding")
    ap.add_argument("--rule", action="append", default=[],
                    help="logical=mesh rule patch, e.g. --rule mlp=None")
    args = ap.parse_args()

    if args.sweep:
        sweep(args.out_dir, multi_pod=args.multi_pod,
              causal_mode=args.causal_mode)
        return
    overrides = {}
    if args.int8:
        overrides["int8_matmul"] = True
    if args.les_groups:
        overrides["les_groups"] = args.les_groups
    if args.no_remat:
        overrides["remat"] = False
    if args.cast_once:
        overrides["cast_params_once"] = True
    if args.moe_shard:
        overrides["moe_shard_buffers"] = True
    patch = {}
    for r in args.rule:
        k, v = r.split("=", 1)
        if v == "None":
            patch[k] = None
        elif "," in v:
            patch[k] = tuple(v.split(","))
        else:
            patch[k] = v
    try:
        run_cell(args.arch, args.shape, multi_pod=args.multi_pod,
                 causal_mode=args.causal_mode, out=args.out,
                 cfg_overrides=overrides or None, rule_patch=patch or None)
    except Exception:
        traceback.print_exc()
        sys.exit(1)


if __name__ == "__main__":
    main()
