"""Input-shape cells: every (architecture × shape) pair the dry-run covers.

Per the assignment, LM shapes are seq_len × global_batch:

    train_4k     seq=4096    gb=256   → train_step
    prefill_32k  seq=32768   gb=32    → prefill (serve)
    decode_32k   seq=32768   gb=128   → serve_step (1 new token, 32k cache)
    long_500k    seq=524288  gb=1     → serve_step (sub-quadratic archs only)

``long_500k`` runs only for SSM/hybrid/SWA architectures; pure
full-attention archs skip it (DESIGN.md §4) — a 512k dense-attention KV
decode is quadratic by construction and not serviceable.

``input_specs`` returns ShapeDtypeStruct stand-ins only — weak-type-correct
and shardable, no device allocation.
"""

from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp

from repro.models.config import ModelConfig

SHAPES = {
    "train_4k": {"kind": "train", "seq": 4096, "batch": 256},
    "prefill_32k": {"kind": "prefill", "seq": 32768, "batch": 32},
    "decode_32k": {"kind": "decode", "seq": 32768, "batch": 128},
    "long_500k": {"kind": "decode", "seq": 524288, "batch": 1},
}

# archs with a sub-quadratic path (SSM state / RG-LRU / SWA ring cache)
LONG_CONTEXT_OK = {
    "rwkv6-3b",            # O(1) recurrent state
    "recurrentgemma-9b",   # RG-LRU + 2048-window local attention
    "h2o-danube-1.8b",     # SWA 4096 ring cache
    "mixtral-8x22b",       # SWA 4096 ring cache
}


@dataclass(frozen=True)
class Cell:
    arch: str
    shape: str
    kind: str
    seq: int
    batch: int
    applicable: bool
    skip_reason: str = ""


def cell(cfg: ModelConfig, shape_name: str) -> Cell:
    s = SHAPES[shape_name]
    applicable, reason = True, ""
    if shape_name == "long_500k" and cfg.name.split("-smoke")[0] not in LONG_CONTEXT_OK:
        applicable = False
        reason = (
            "pure full-attention arch: 512k dense KV decode is quadratic "
            "by construction (DESIGN.md §4 skip list)"
        )
    return Cell(
        arch=cfg.name, shape=shape_name, kind=s["kind"], seq=s["seq"],
        batch=s["batch"], applicable=applicable, skip_reason=reason,
    )


def all_cells(cfg: ModelConfig) -> list[Cell]:
    return [cell(cfg, s) for s in SHAPES]


# ---------------------------------------------------------------------------
# Abstract inputs
# ---------------------------------------------------------------------------


def _sds(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def train_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    """ShapeDtypeStructs for one training batch."""
    specs = {"labels": _sds((batch, seq), jnp.int32)}
    if cfg.embeds_input:
        specs["embeds"] = _sds((batch, seq, cfg.d_model), jnp.bfloat16)
        if cfg.mrope_sections is not None:
            specs["positions"] = _sds((3, batch, seq), jnp.int32)
    else:
        specs["tokens"] = _sds((batch, seq), jnp.int32)
    if cfg.encoder_layers:
        specs["enc_embeds"] = _sds(
            (batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16
        )
    return specs


def prefill_batch_specs(cfg: ModelConfig, batch: int, seq: int) -> dict:
    specs = dict(train_batch_specs(cfg, batch, seq))
    del specs["labels"]
    return specs


def decode_token_specs(batch: int) -> jax.ShapeDtypeStruct:
    return _sds((batch,), jnp.int32)


def abstract_cache(cfg: ModelConfig, batch: int, max_seq: int):
    """ShapeDtypeStructs of the cache pytree (no allocation)."""
    from repro.models import transformer as T

    return jax.eval_shape(lambda: T.init_cache(cfg, batch, max_seq))


def enc_out_specs(cfg: ModelConfig, batch: int):
    if not cfg.encoder_layers:
        return None
    return _sds((batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
