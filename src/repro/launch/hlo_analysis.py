"""While-aware HLO cost analyzer for the roofline methodology.

``compiled.cost_analysis()`` counts each while-loop body ONCE (verified in
this container: an 8-step scan of 128³ matmuls reports 1 matmul of FLOPs),
and it reports nothing about collectives.  Since every model here scans
over layers (and flash attention scans over blocks), we analyse the
post-optimisation HLO text directly:

  * computations are parsed into op lists (opcode, result shape, operand
    shapes, attributes);
  * while-loop trip counts are recovered from the loop condition's
    comparison constant (scan lowers to ``compare(iv, constant(N)), LT``);
  * traversal starts at ENTRY and multiplies every enclosing while body's
    costs by its trip count (nested scans compose);
  * FLOPs: exact for ``dot`` (2 · result_elems · contraction_size,
    bucketed by operand dtype — int8 dots hit the MXU at 2× rate, fp32 at
    ¼ rate) plus first-order elementwise counts; ``bytes``: Σ (operands +
    result) of every top-level op — post-fusion, each op ≈ one kernel, so
    this is the standard HBM-traffic roofline approximation; ``collective
    bytes``: per collective kind, with all-reduce counted 2× (ring
    reduce-scatter + all-gather wire cost).
"""

from __future__ import annotations

import math
import re
from collections import defaultdict
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "token": 0, "s4": 1, "u4": 1, "f8e4m3fn": 1, "f8e5m2": 1,
}

_SHAPE_RE = re.compile(r"(\w+)\[([\d,]*)\]")
_OP_RE = re.compile(
    r"^\s*(?:ROOT\s+)?%?([\w.\-]+)\s*=\s*(\([^)]*\)|\w+\[[\d,]*\](?:\{[^}]*\})?)\s*"
    r"([\w\-]+)\((.*)$"
)

COLLECTIVES = (
    "all-reduce", "all-gather", "reduce-scatter", "all-to-all",
    "collective-permute",
)

ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "select",
    "exponential", "tanh", "rsqrt", "sqrt", "log", "negate", "abs", "power",
    "compare", "and", "or", "floor", "ceil", "round-nearest-even", "clamp",
}

SKIP_BYTES = {
    "parameter", "constant", "tuple", "get-tuple-element", "bitcast",
    "while", "conditional", "call", "after-all", "custom-call", "reshape",
    "bitcast-convert", "opt-barrier", "partition-id", "replica-id",
}


def _shape_info(type_str: str) -> list[tuple[str, int]]:
    """'f32[8,128]{1,0}' or '(f32[2], s32[])' → [(dtype, elem_count), ...]."""
    out = []
    for dtype, dims in _SHAPE_RE.findall(type_str):
        if dtype not in _DTYPE_BYTES:
            continue
        elems = 1
        if dims:
            for d in dims.split(","):
                if d:
                    elems *= int(d)
        out.append((dtype, elems))
    return out


def _bytes_of(type_str: str) -> int:
    return sum(_DTYPE_BYTES[d] * n for d, n in _shape_info(type_str))


@dataclass
class Op:
    name: str
    opcode: str
    result_type: str
    rest: str  # operand list + attributes (raw text)

    def _args_region(self) -> str:
        depth = 0
        for i, ch in enumerate(self.rest):
            if ch == "(":
                depth += 1
            elif ch == ")":
                if depth == 0:
                    return self.rest[:i]
                depth -= 1
        return self.rest

    def operand_names(self) -> list[str]:
        return re.findall(r"%([\w.\-]+)", self._args_region())

    def operand_types(self, type_map: dict[str, str]) -> list[str]:
        """Resolve operand types: inline annotations if present, else the
        computation-local name → result-type map (post-opt HLO elides
        operand types)."""
        inline = re.findall(
            r"(\w+\[[\d,]*\])(?:\{[^}]*\})?\s+%", self._args_region()
        )
        if inline:
            return inline
        return [
            type_map[n] for n in self.operand_names() if n in type_map
        ]


@dataclass
class Computation:
    name: str
    ops: list[Op] = field(default_factory=list)

    def type_map(self) -> dict[str, str]:
        return {op.name: op.result_type for op in self.ops}


def parse_hlo(text: str) -> tuple[dict[str, Computation], str]:
    """Parse module text → ({name: Computation}, entry_name)."""
    comps: dict[str, Computation] = {}
    current: Computation | None = None
    entry = None
    for line in text.splitlines():
        stripped = line.strip()
        header = re.match(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->.*{", stripped)
        if header and not stripped.startswith("//") and "=" not in stripped.split("(")[0]:
            current = Computation(name=header.group(2))
            comps[current.name] = current
            if header.group(1):
                entry = current.name
            continue
        if stripped.startswith("}"):
            current = None
            continue
        if current is None:
            continue
        m = _OP_RE.match(line)
        if m:
            current.ops.append(
                Op(name=m.group(1), result_type=m.group(2),
                   opcode=m.group(3), rest=m.group(4))
            )
    return comps, entry or next(iter(comps))


def _attr(rest: str, key: str) -> str | None:
    m = re.search(key + r"=([%\w.\-]+)", rest)
    return m.group(1).lstrip("%") if m else None


def _trip_count(comps: dict[str, Computation], cond_name: str) -> int:
    """Recover scan trip count from the while condition's constant."""
    cond = comps.get(cond_name)
    if cond is None:
        return 1
    consts = []
    for op in cond.ops:
        if op.opcode == "constant":
            m = re.match(r"\s*(\-?\d+)", op.rest)
            if m:
                consts.append(int(m.group(1)))
    return max(consts) if consts else 1


_FLOAT_WIDTH = {"f8e4m3fn": 1, "f8e5m2": 1, "bf16": 2, "f16": 2, "f32": 4, "f64": 8}
_MOVE_FUSION = re.compile(r"(convert|copy|bitcast|transpose|reshape)")
# fusions composed ONLY of data-movement ops (convert_bitcast_fusion, ...)
_PURE_MOVE_FUSION = re.compile(
    r"^(convert|copy|bitcast|transpose|reshape)"
    r"(_(convert|copy|bitcast|transpose|reshape))*(_fusion)?(\.\d+)?$"
)


def _semantic_dtype(
    name: str, comp: "Computation", comps: dict[str, "Computation"] | None = None
) -> str | None:
    """Narrowest float dtype along the value's data-movement chain.

    The XLA CPU backend has no native bf16 GEMM: a semantic bf16 matmul
    input appears as convert(f32→bf16)→convert(bf16→f32) (often fused), so
    the *narrowest* dtype the value passes through — including inside fused
    convert chains — is what the TPU MXU would see.  True-f32 paths (e.g.
    the RWKV gate math) never pass through bf16 and stay classified f32."""
    op_by_name = getattr(comp, "_by_name", None)
    if op_by_name is None:
        op_by_name = {o.name: o for o in comp.ops}
        comp._by_name = op_by_name

    seen: list[str] = []

    def record(type_str: str):
        for d, _ in _shape_info(type_str):
            if d in _FLOAT_WIDTH:
                seen.append(d)

    for _ in range(8):  # follow data-movement chains (incl. fused ones)
        op = op_by_name.get(name)
        if op is None:
            break
        record(op.result_type)
        is_move = op.opcode in (
            "convert", "copy", "bitcast", "reshape", "transpose",
        ) or (op.opcode == "fusion" and _MOVE_FUSION.search(op.name.lower()))
        if not is_move:
            break
        if op.opcode == "fusion" and comps is not None:
            called = _attr(op.rest, "calls")
            body = comps.get(called) if called else None
            if body is not None:  # dtypes the fused chain passes through
                for o in body.ops:
                    record(o.result_type)
        names = op.operand_names()
        if not names:
            break
        name = names[0]
    if not seen:
        return None
    return min(seen, key=lambda d: _FLOAT_WIDTH[d])


def _dot_flops(
    op: Op, type_map: dict[str, str], comp: "Computation",
    comps: dict[str, "Computation"] | None = None,
) -> tuple[float, str]:
    """(flops, dtype bucket) for a dot op."""
    res = _shape_info(op.result_type)
    result_elems = sum(n for _, n in res)
    operands = op.operand_types(type_map)
    if not operands:
        return 0.0, "f32"
    lhs = operands[0]
    lhs_info = _shape_info(lhs)
    lhs_dtype, _ = lhs_info[0]
    dims = _SHAPE_RE.search(lhs)
    lhs_shape = [int(d) for d in dims.group(2).split(",") if d] if dims else []
    m = re.search(r"lhs_contracting_dims=\{([\d,]*)\}", op.rest)
    contract = 1
    if m and lhs_shape:
        for idx in m.group(1).split(","):
            if idx:
                contract *= lhs_shape[int(idx)]
    # classify by semantic (narrowest-along-chain) dtype of both operands
    names = op.operand_names()
    sem = [_semantic_dtype(n, comp, comps) for n in names[:2]]
    sem = [s or lhs_dtype for s in sem]
    if any(s in ("s8", "u8", "s4") for s in sem):
        bucket = "int8"
    elif any(s == "bf16" for s in sem):
        bucket = "bf16"  # bf16-in / f32-accum = full MXU rate on TPU
    elif lhs_dtype in ("f32", "f64"):
        bucket = "f32"
    else:
        bucket = "bf16"
    return 2.0 * result_elems * contract, bucket


@dataclass
class HloCosts:
    flops: dict = field(default_factory=lambda: defaultdict(float))
    hbm_bytes: float = 0.0
    collective_bytes: dict = field(default_factory=lambda: defaultdict(float))
    collective_counts: dict = field(default_factory=lambda: defaultdict(float))

    @property
    def total_flops(self) -> float:
        return sum(self.flops.values())

    @property
    def total_collective_bytes(self) -> float:
        return sum(self.collective_bytes.values())


def analyze(text: str) -> HloCosts:
    comps, entry = parse_hlo(text)
    costs = HloCosts()
    visited_guard: set[tuple[str, float]] = set()

    def visit(comp_name: str, mult: float):
        comp = comps.get(comp_name)
        if comp is None:
            return
        type_map = comp.type_map()
        for op in comp.ops:
            oc = op.opcode
            if oc == "while":
                cond = _attr(op.rest, "condition")
                body = _attr(op.rest, "body")
                trips = _trip_count(comps, cond) if cond else 1
                if body:
                    visit(body, mult * max(trips, 1))
                continue
            if oc == "conditional":
                for branch in re.findall(r"branch_computations=\{([^}]*)\}", op.rest):
                    for b in branch.split(","):
                        visit(b.strip().lstrip("%"), mult)
                tb = _attr(op.rest, "true_computation")
                fb = _attr(op.rest, "false_computation")
                for b in (tb, fb):
                    if b:
                        visit(b, mult)
                continue
            if oc == "call":
                to = _attr(op.rest, "to_apply")
                if to:
                    visit(to, mult)
                continue

            if oc in COLLECTIVES or any(oc.startswith(c) for c in COLLECTIVES):
                kind = next(c for c in COLLECTIVES if oc.startswith(c))
                tensor_bytes = max(
                    _bytes_of(op.result_type),
                    sum(_bytes_of(t) for t in op.operand_types(type_map)) or 0,
                )
                # CPU backend upconverts bf16 payloads to f32 *before* the
                # collective (no native bf16 compute) — on TPU the wire
                # carries the semantic dtype.  Scale by the narrowest dtype
                # the payload passes through.
                names = op.operand_names()
                res_info = _shape_info(op.result_type)
                actual = res_info[0][0] if res_info else None
                if names and actual in _FLOAT_WIDTH:
                    sem = _semantic_dtype(names[0], comp, comps)
                    if sem in _FLOAT_WIDTH and _FLOAT_WIDTH[sem] < _FLOAT_WIDTH[actual]:
                        tensor_bytes *= _FLOAT_WIDTH[sem] / _FLOAT_WIDTH[actual]
                wire = 2.0 * tensor_bytes if kind == "all-reduce" else float(tensor_bytes)
                costs.collective_bytes[kind] += mult * wire
                costs.collective_counts[kind] += mult
                costs.hbm_bytes += mult * 2 * tensor_bytes
                continue

            if oc == "dot":
                fl, bucket = _dot_flops(op, type_map, comp, comps)
                costs.flops[bucket] += mult * fl
            elif oc == "convolution":
                # conservative: treat as dot over the result × window
                res_elems = sum(n for _, n in _shape_info(op.result_type))
                costs.flops["bf16"] += mult * 2.0 * res_elems
            elif oc == "fusion" or oc in ELEMENTWISE or oc in (
                "reduce", "scatter", "gather", "dynamic-slice",
                "dynamic-update-slice", "broadcast", "transpose", "copy",
                "concatenate", "pad", "slice", "sort", "iota", "convert",
                "select-and-scatter", "reduce-window", "rng-bit-generator",
                "exponential-minus-one", "log-plus-one", "cbrt",
            ):
                # first-order elementwise flops: one op per result element
                res_elems = sum(n for _, n in _shape_info(op.result_type))
                if oc == "fusion" or oc in ELEMENTWISE or oc == "reduce":
                    costs.flops["elementwise"] += mult * res_elems

            if oc not in SKIP_BYTES:
                name_l = op.name.lower()
                res_b = _bytes_of(op.result_type)
                if oc in ("convert", "copy") or (
                    oc == "fusion" and _PURE_MOVE_FUSION.match(name_l)
                ):
                    # backend dtype-staging / layout pipes: fused into their
                    # consumers on TPU (no standalone HBM round-trip)
                    continue
                if oc in ("dynamic-slice", "gather", "slice") or (
                    oc == "fusion"
                    and ("dynamic-slice" in name_l or "gather" in name_l
                         or "dynamic_slice" in name_l)
                ):
                    # reads only the sliced region (≈ result), writes result
                    io_bytes = 2 * res_b
                elif oc in ("dynamic-update-slice", "scatter") or (
                    oc == "fusion"
                    and ("dynamic-update-slice" in name_l
                         or "dynamic_update_slice" in name_l
                         or "scatter" in name_l)
                ):
                    # in-place on TPU: read + write of the update region,
                    # which is the smallest non-trivial operand
                    ops_b = [
                        b for b in
                        (_bytes_of(t) for t in op.operand_types(type_map))
                        if b > 4
                    ]
                    io_bytes = 2 * (min(ops_b) if ops_b else res_b)
                else:
                    io_bytes = res_b + sum(
                        _bytes_of(t) for t in op.operand_types(type_map)
                    )
                costs.hbm_bytes += mult * io_bytes

        visited_guard.add((comp_name, mult))

    visit(entry, 1.0)
    return costs


# ---------------------------------------------------------------------------
# Roofline terms (hardware constants from the assignment)
# ---------------------------------------------------------------------------

PEAK_BF16 = 197e12          # FLOP/s per chip
PEAK_INT8 = 394e12          # MXU int8 double rate
PEAK_F32 = PEAK_BF16 / 4.0  # fp32 on the MXU
HBM_BW = 819e9              # B/s per chip
ICI_BW = 50e9               # B/s per link (assignment: ~50 GB/s/link)


def roofline_terms(costs: HloCosts) -> dict:
    """Per-chip time lower bounds, in seconds (the HLO is the per-device
    SPMD program, so no further division by chip count)."""
    compute_s = (
        costs.flops.get("bf16", 0.0) / PEAK_BF16
        + costs.flops.get("int8", 0.0) / PEAK_INT8
        + costs.flops.get("f32", 0.0) / PEAK_F32
        + costs.flops.get("elementwise", 0.0) / PEAK_BF16
    )
    memory_s = costs.hbm_bytes / HBM_BW
    collective_s = costs.total_collective_bytes / ICI_BW
    dominant = max(
        [("compute", compute_s), ("memory", memory_s), ("collective", collective_s)],
        key=lambda kv: kv[1],
    )[0]
    return {
        "compute_s": compute_s,
        "memory_s": memory_s,
        "collective_s": collective_s,
        "dominant": dominant,
        "flops_by_dtype": dict(costs.flops),
        "hbm_bytes": costs.hbm_bytes,
        "collective_bytes": dict(costs.collective_bytes),
        "collective_counts": dict(costs.collective_counts),
    }
