"""Vision serving launcher: freeze → fused plan → batched engine.

    # train briefly, export, then serve synthetic requests:
    PYTHONPATH=src python -m repro.launch.serve_vision --arch vgg8b \
        --scale 0.125 --train-steps 50 --requests 200

    # serve an existing exported model:
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model-dir /tmp/nitro_frozen --requests 200

With ``--train-steps 0`` the model is random-init (throughput smoke).
Prints per-request latency percentiles and the fused-plan summary.
"""

from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np


def _train_and_freeze(arch: str, scale: float, steps: int, batch: int,
                      seed: int):
    from repro.configs import get_paper_config
    from repro.core import les
    from repro.data import synthetic
    from repro.infer import freeze

    ds = synthetic.make_image_dataset("tiles32", n_train=2048, n_test=256,
                                      seed=seed)
    cfg = get_paper_config(arch, scale=scale, input_shape=ds.input_shape)
    state = les.create_train_state(jax.random.PRNGKey(seed), cfg)
    if steps:
        import functools
        step_fn = jax.jit(functools.partial(les.train_step, cfg=cfg))
        it = 0
        while it < steps:
            for x, y in synthetic.batches(ds.x_train, ds.y_train, batch,
                                          seed=it):
                if it >= steps:
                    break
                state, metrics = step_fn(
                    state, x=jnp.asarray(x), labels=jnp.asarray(y),
                    key=jax.random.PRNGKey(it),
                )
                if it % 20 == 0:
                    print(f"[train] step {it:4d} loss={int(metrics.loss)}")
                it += 1
    return freeze(state, cfg), ds


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vgg8b")
    ap.add_argument("--scale", type=float, default=0.125)
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--train-batch", type=int, default=64)
    ap.add_argument("--model-dir", default=None,
                    help="load a frozen model instead of training")
    ap.add_argument("--export-dir", default=None,
                    help="also save the frozen model here")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "reference"])
    ap.add_argument("--batch", type=int, default=32,
                    help="engine compiled batch size")
    ap.add_argument("--max-wait-ms", type=float, default=3.0)
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.infer import compile_plan, load_frozen, save_frozen
    from repro.serving.vision import VisionEngine

    if args.model_dir:
        fm = load_frozen(args.model_dir)
        print(f"[load] {fm.name} from {args.model_dir}")
    else:
        fm, _ = _train_and_freeze(args.arch, args.scale, args.train_steps,
                                  args.train_batch, args.seed)
    if args.export_dir:
        path = save_frozen(args.export_dir, fm)
        print(f"[export] frozen model → {path} ({fm.num_bytes()} weight bytes)")

    plan = compile_plan(fm, backend=args.backend)
    print(f"[plan] backend={plan.backend}")
    for row in plan.summary():
        hbm = row["hbm_bytes_per_out_elem"]
        print(f"  {row['kind']:<7} w={row['weight_shape']} "
              f"({row['weight_dtype']}) sf={row['sf']} "
              f"act={row['activation_dtype']} pool={row['pool']} "
              f"hbm/elem {hbm['unfused']}B→{hbm['fused']}B")

    rng = np.random.default_rng(args.seed)
    images = [rng.integers(-127, 128, fm.input_shape).astype(np.int32)
              for _ in range(args.requests)]
    with VisionEngine(plan, batch_size=args.batch,
                      max_wait_ms=args.max_wait_ms) as engine:
        engine.classify(images[:1])  # warmup compile outside the clock
        t0 = time.perf_counter()
        futs = [engine.submit(img) for img in images]
        results = [f.result() for f in futs]
        wall = time.perf_counter() - t0
        stats = engine.stats

    lats = sorted(r.latency_s for r in results)
    p = lambda q: lats[min(int(q * len(lats)), len(lats) - 1)] * 1e3
    print(f"[serve] {len(results)} requests in {wall:.3f}s "
          f"({len(results) / wall:.1f} req/s)")
    print(f"[serve] latency ms p50={p(0.50):.1f} p90={p(0.90):.1f} "
          f"p99={p(0.99):.1f}")
    print(f"[serve] {stats.batches} batches, "
          f"avg fill {stats.avg_batch_fill:.2f}")


if __name__ == "__main__":
    main()
