"""Vision serving launcher: freeze → registry → fleet engine.

    # train briefly, export, then serve synthetic requests:
    PYTHONPATH=src python -m repro.launch.serve_vision --arch vgg8b \
        --scale 0.125 --train-steps 50 --requests 200

    # serve one exported model:
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model-dir /tmp/nitro_frozen --requests 200

    # A/B-serve two checkpoints, 90/10:
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --model-dir a=/ckpts/prod --model-dir b=/ckpts/candidate \
        --split a=0.9,b=0.1 --requests 500

    # load a whole fleet from a FLEET.json directory:
    PYTHONPATH=src python -m repro.launch.serve_vision \
        --fleet-dir /ckpts/fleet --requests 500

Every ``--model-dir`` is ``NAME=PATH`` (bare ``PATH`` gets the model id
``default``).  Requests route through the continuous-batching
``FleetEngine``; ``--scheduler static`` falls back to the single-model
``VisionEngine`` (requires exactly one model) for A/B-ing the schedulers
themselves.  With ``--train-steps 0`` the model is random-init
(throughput smoke).  Prints per-request latency percentiles and the
per-model stats snapshot.
"""

from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np


def _train_and_freeze(arch: str, scale: float, steps: int, batch: int,
                      seed: int):
    from repro.configs import get_paper_config
    from repro.core import les
    from repro.data import synthetic
    from repro.infer import freeze

    ds = synthetic.make_image_dataset("tiles32", n_train=2048, n_test=256,
                                      seed=seed)
    cfg = get_paper_config(arch, scale=scale, input_shape=ds.input_shape)
    state = les.create_train_state(jax.random.PRNGKey(seed), cfg)
    if steps:
        import functools
        step_fn = jax.jit(functools.partial(les.train_step, cfg=cfg))
        it = 0
        while it < steps:
            for x, y in synthetic.batches(ds.x_train, ds.y_train, batch,
                                          seed=it):
                if it >= steps:
                    break
                state, metrics = step_fn(
                    state, x=jnp.asarray(x), labels=jnp.asarray(y),
                    key=jax.random.PRNGKey(it),
                )
                if it % 20 == 0:
                    print(f"[train] step {it:4d} loss={int(metrics.loss)}")
                it += 1
    return freeze(state, cfg), ds


def _parse_model_dir(spec: str) -> tuple[str, str]:
    """``NAME=PATH`` → (name, path); bare ``PATH`` → ("default", path)."""
    name, sep, path = spec.partition("=")
    if not sep:
        return "default", spec
    if not name or not path:
        raise SystemExit(f"bad --model-dir {spec!r} (want NAME=PATH)")
    return name, path


def _build_registry(args, metrics=None):
    """Resolve --fleet-dir / --model-dir / train-and-freeze into a registry."""
    from repro.infer import load_fleet_manifest, save_frozen
    from repro.serving import ModelRegistry

    if args.export_dir and (args.fleet_dir or args.model_dir):
        raise SystemExit("--export-dir only applies to the train-and-freeze "
                         "path (no --model-dir / --fleet-dir)")
    if args.fleet_dir and args.model_dir:
        raise SystemExit("--fleet-dir and --model-dir are mutually "
                         "exclusive — add extra models to FLEET.json")
    registry = ModelRegistry(backend=args.backend,
                             operand_dtype=args.operand_dtype,
                             metrics=metrics)
    if args.fleet_dir:
        # read FLEET.json exactly once: registering from the parsed dict
        # keeps the printed paths, the splits, and the loaded models all
        # from the same (atomically-replaced) manifest version
        manifest = load_fleet_manifest(args.fleet_dir)
        for mid, path in sorted(manifest["models"].items()):
            registry.load(mid, path)
            print(f"[load] {mid} <- {path}")
        return registry, manifest.get("splits", {})

    if args.model_dir:
        for spec in args.model_dir:
            mid, path = _parse_model_dir(spec)
            entry = registry.load(mid, path)
            print(f"[load] {mid} ({entry.plan.name}) <- {path}")
    else:
        fm, _ = _train_and_freeze(args.arch, args.scale, args.train_steps,
                                  args.train_batch, args.seed)
        if args.export_dir:
            path = save_frozen(args.export_dir, fm)
            print(f"[export] frozen model -> {path} "
                  f"({fm.num_bytes()} weight bytes)")
        registry.register("default", fm)
    return registry, {}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="vgg8b")
    ap.add_argument("--scale", type=float, default=0.125)
    ap.add_argument("--train-steps", type=int, default=0)
    ap.add_argument("--train-batch", type=int, default=64)
    ap.add_argument("--model-dir", action="append", default=None,
                    metavar="NAME=PATH",
                    help="serve a frozen model under NAME (repeatable; "
                         "bare PATH serves as 'default')")
    ap.add_argument("--fleet-dir", default=None,
                    help="serve every model in a FLEET.json directory")
    ap.add_argument("--export-dir", default=None,
                    help="also save the trained frozen model here")
    ap.add_argument("--split", default=None, metavar="a=0.9,b=0.1",
                    help="route traffic through a weighted A/B split "
                         "over the loaded model ids")
    ap.add_argument("--route", default=None,
                    help="routing target: a model id or a split alias "
                         "(needed when a fleet defines several aliases)")
    ap.add_argument("--backend", default="auto",
                    choices=["auto", "pallas", "interpret", "reference"])
    ap.add_argument("--operand-dtype", default="auto",
                    choices=["auto", "int8", "int32"],
                    help="MXU operand path: auto = int8 dots wherever the "
                         "int8 fit is provable (bitwise-identical), int32 "
                         "= escape hatch, int8 = force (error if no step "
                         "qualifies)")
    ap.add_argument("--autotune", action="store_true",
                    help="tune kernel tile configs for every loaded plan "
                         "at this --batch before serving (bitwise "
                         "result-invariant)")
    ap.add_argument("--autotune-cache", default=None,
                    help="tile-cache JSON path (default: tile_cache.json "
                         "in the cwd)")
    ap.add_argument("--scheduler", default="continuous",
                    choices=["continuous", "static"],
                    help="continuous = FleetEngine (double-buffered); "
                         "static = single-model VisionEngine baseline")
    ap.add_argument("--batch", type=int, default=32,
                    help="engine compiled batch size")
    ap.add_argument("--max-wait-ms", type=float, default=3.0,
                    help="static scheduler only")
    ap.add_argument("--requests", type=int, default=200)
    ap.add_argument("--slo", type=float, default=None, metavar="MS",
                    help="serving deadline in ms, applied to every loaded "
                         "model (per-model violation attribution + "
                         "serve_slo_* metrics; continuous scheduler only)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="expose the serving metrics as Prometheus text "
                         "at /metrics on this port (0 = pick an ephemeral "
                         "port and print it)")
    ap.add_argument("--trace-out", default=None,
                    help="write the engine's batch-lifecycle span trace "
                         "(JSONL) here")
    args = ap.parse_args()

    from repro.serving import (
        FleetEngine,
        Router,
        Slo,
        VisionEngine,
        fleet_snapshot_delta,
        latency_summary_ms,
        parse_split,
        snapshot_delta,
    )

    metrics = server = tracer = None
    if args.metrics_port is not None:
        from repro.obs import MetricRegistry, start_metrics_server
        metrics = MetricRegistry()
        server = start_metrics_server(metrics, port=args.metrics_port)
        print(f"[metrics] Prometheus text at {server.url}")
    if args.trace_out:
        from repro.obs import Tracer
        tracer = Tracer()

    registry, manifest_splits = _build_registry(args, metrics=metrics)
    if args.autotune:
        # tune before the engines' warmup calls trace the plans — jit
        # bakes in whatever tiles resolve at trace time
        from repro.kernels import autotune as at
        cache = at.TileCache(args.autotune_cache or at.CACHE_FILENAME)
        if metrics is not None:
            at.set_metrics(metrics)
        tuned = 0
        for mid in registry.ids():
            tuned += len(at.tune_plan(registry.get(mid).plan, args.batch,
                                      cache=cache))
        at.configure(cache)
        print(f"[autotune] {tuned} problems tuned/cached -> {cache.path}")
    if args.slo is not None:
        # one objective for the whole fleet: the launcher serves a single
        # workload, so every arm is scored against the same deadline
        slo = Slo(deadline_ms=args.slo)
        for mid in registry.ids():
            registry.set_slo(mid, slo)
        print(f"[slo] deadline {slo.deadline_ms:.1f} ms on {registry.ids()}")
    if metrics is not None:
        from repro.obs import register_build_info
        register_build_info(
            metrics, backend=registry.get(registry.ids()[0]).plan.backend)

    splits = dict(manifest_splits)
    if args.split:
        splits["split"] = parse_split(args.split)
    router = Router(splits)
    for alias in router.aliases:  # fail at startup, not mid-traffic
        missing = sorted(mid for mid, _ in router.arms(alias)
                         if mid not in registry)
        if missing:
            raise SystemExit(
                f"split {alias!r} routes to unknown models {missing}; "
                f"loaded: {registry.ids()}")
    # routing target: explicit --route, else the CLI --split alias, else
    # the unambiguous option (the sole alias / the sole model) — never a
    # silent guess among several configured aliases
    if args.route:
        if args.route not in registry and args.route not in router.aliases:
            raise SystemExit(
                f"--route {args.route!r} is neither a model id "
                f"{registry.ids()} nor a split alias {router.aliases}")
        target = args.route
    elif args.split:
        target = "split"
    elif len(router.aliases) == 1:
        target = router.aliases[0]
    elif router.aliases:
        raise SystemExit(
            f"fleet defines several split aliases {router.aliases}; "
            f"pick one with --route")
    elif len(registry.ids()) == 1:
        target = registry.ids()[0]
    else:
        raise SystemExit("several models loaded but no --split/--route "
                         "to route by")

    first = registry.get(registry.ids()[0])
    print(f"[plan] backend={first.plan.backend} models={registry.ids()} "
          f"route={target!r}")
    for row in first.plan.summary():
        hbm = row["hbm_bytes_per_out_elem"]
        per_sample = row["hbm_per_sample_bytes"]
        print(f"  {row['kind']:<7} w={row['weight_shape']} "
              f"({row['weight_dtype']}) sf={row['sf']} "
              f"act={row['activation_dtype']} "
              f"operands={row['operand_dtype']} pool={row['pool']} "
              f"hbm/elem {hbm['unfused']}B→{hbm['fused']}B "
              f"hbm/sample {per_sample['materialise']}B→"
              f"{per_sample['stream']}B "
              f"({row['stream_saving_ratio']}x stream saving)")

    # each request's image is shaped for the arm it will land on, so a
    # fleet of heterogeneous input shapes serves without special-casing
    rng = np.random.default_rng(args.seed)

    def make_image(mid):
        return rng.integers(-127, 128,
                            registry.get(mid).input_shape).astype(np.int32)

    request_ids = [f"req-{i}" for i in range(args.requests)]
    images = [make_image(router.resolve(target, rid)) for rid in request_ids]

    if args.scheduler == "static":
        if len(registry.ids()) != 1 or args.split:
            raise SystemExit("--scheduler static serves exactly one model")
        if args.slo is not None:
            raise SystemExit("--slo requires --scheduler continuous "
                             "(SLO attribution lives in the fleet engine)")
        with VisionEngine(first.plan, batch_size=args.batch,
                          max_wait_ms=args.max_wait_ms,
                          metrics=metrics) as engine:
            engine.classify(images[:1])  # warmup compile outside the clock
            pre = engine.stats.snapshot()
            t0 = time.perf_counter()
            futs = [engine.submit(img) for img in images]
            results = [f.result() for f in futs]
            wall = time.perf_counter() - t0
            snapshot = {
                "fleet": snapshot_delta(pre, engine.stats.snapshot()),
                "models": {},
            }
    else:
        with FleetEngine(registry, batch_size=args.batch,
                         router=router, tracer=tracer) as engine:
            for mid in registry.ids():  # warmup compiles outside the clock
                engine.classify([make_image(mid)], model=mid)
            pre = engine.snapshot()
            t0 = time.perf_counter()
            futs = [engine.submit(img, model=target, request_id=rid)
                    for rid, img in zip(request_ids, images)]
            results = [f.result() for f in futs]
            wall = time.perf_counter() - t0
            post = engine.snapshot()
            # report only the timed work: the cumulative snapshot would
            # fold the warmup compile into the batch counters
            snapshot = fleet_snapshot_delta(pre, post)
            for mid, mstats in snapshot["models"].items():
                mstats["version"] = post["models"][mid]["version"]
            # per-model SLO attribution, warmup excluded the same way
            snapshot["slo"] = {}
            for mid, c in post.get("slo", {}).items():
                p = pre.get("slo", {}).get(mid,
                                           {"requests": 0, "violations": 0})
                reqs = c["requests"] - p["requests"]
                viol = c["violations"] - p["violations"]
                snapshot["slo"][mid] = {
                    "requests": reqs, "violations": viol,
                    "violation_frac": viol / reqs if reqs else 0.0,
                }

    pct = latency_summary_ms(r.latency_s for r in results)
    fleet = snapshot["fleet"]
    print(f"[serve] scheduler={args.scheduler} {len(results)} requests in "
          f"{wall:.3f}s ({len(results) / wall:.1f} req/s)")
    print(f"[serve] latency ms p50={pct['p50']:.1f} p90={pct['p90']:.1f} "
          f"p99={pct['p99']:.1f}")
    print(f"[serve] {fleet['batches']} batches, "
          f"avg fill {fleet['avg_batch_fill']:.2f}")
    for mid, mstats in snapshot["models"].items():
        print(f"[serve]   {mid}: {json.dumps(mstats, sort_keys=True)}")
    for mid, sstats in snapshot.get("slo", {}).items():
        print(f"[slo]   {mid}: {sstats['violations']}/{sstats['requests']} "
              f"past deadline ({100 * sstats['violation_frac']:.1f}%)")

    if tracer is not None:
        n_spans = tracer.export_jsonl(args.trace_out)
        print(f"[trace] {n_spans} spans -> {args.trace_out}")
    if server is not None:
        # scrape our own endpoint: proves the full HTTP path end-to-end
        # and shows the headline counters in the run's output
        from urllib.request import urlopen
        text = urlopen(server.url, timeout=5).read().decode()
        samples = [ln for ln in text.splitlines()
                   if ln and not ln.startswith("#")]
        print(f"[metrics] scraped {server.url}: {len(samples)} samples")
        headline = ("serve_requests_total", "serve_queue_depth",
                    "serve_batch_fill_count", "serve_model_version",
                    "serve_model_swaps_total", "serve_slo_violations_total",
                    "repro_build_info")
        for ln in samples:
            if ln.startswith(headline):
                print(f"[metrics]   {ln}")
        server.close()


if __name__ == "__main__":
    main()
