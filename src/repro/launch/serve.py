"""Serving launcher: batched generation through the Engine.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3.2-1b \
        --batch 4 --max-new 16
"""

from __future__ import annotations

import argparse

import jax
import numpy as np


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--max-new", type=int, default=16)
    ap.add_argument("--prompt-len", type=int, default=8)
    ap.add_argument("--max-seq", type=int, default=128)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    from repro.configs import get_smoke_config
    from repro.models import transformer as T
    from repro.serving.engine import Engine, Request

    cfg = get_smoke_config(args.arch)
    params = T.init_params(jax.random.PRNGKey(args.seed), cfg)
    engine = Engine(cfg, params, max_seq=args.max_seq)

    rng = np.random.default_rng(args.seed)
    requests = [
        Request(
            prompt=rng.integers(0, cfg.vocab_size, args.prompt_len).tolist(),
            max_new_tokens=args.max_new,
        )
        for _ in range(args.batch)
    ]
    out = engine.generate(requests)
    for i, r in enumerate(out):
        print(f"request {i}: {len(r.generated)} tokens → {r.generated}")


if __name__ == "__main__":
    main()
