"""Training launcher.

Two trainers behind one CLI:

  * ``--arch <paper arch>``  (mlp1..4, vgg8b, vgg11b) — the NITRO-D
    integer-only LES trainer (the paper's algorithm, core library);
    ``--num-devices N`` shards the batch over a data mesh
    (``repro.parallel.dp``) with a bitwise-identical trajectory,
    ``--dp-reduce`` picks the exact all-reduce (psum/ring/compress);
  * ``--arch <lm arch>``     (qwen3-32b, …) — the sharded LM trainer
    (bf16/fp32 AdamW or LES-groups mode), sized by ``--scale`` for
    CPU-budget runs.

Production behaviours wired in: checkpoint/restart (async, manifest),
preemption checkpointing, straggler logging, deterministic data pipeline.
"""

from __future__ import annotations

import argparse
import functools
import os
import time

import jax
import jax.numpy as jnp
import numpy as np


def train_nitro(arch: str, *, steps: int, batch: int, ckpt_dir: str | None,
                dataset: str, scale: float, seed: int = 0,
                telemetry_every: int = 0, telemetry_out: str | None = None,
                trace_out: str | None = None,
                num_devices: int = 1, dp_reduce: str = "psum",
                fuse_opt: bool = False,
                metrics_port: int | None = None,
                alerts_out: str | None = None,
                autotune: bool = False,
                autotune_cache: str | None = None) -> dict:
    """Integer-only NITRO-D training (paper algorithm).

    ``telemetry_every=N`` runs every N-th step through the
    telemetry-enabled variant of ``les.train_step`` (bitwise-identical
    trajectory — sampling cadence changes cost, never results) and
    appends the per-layer bit-occupancy/saturation records to
    ``telemetry_out`` (default: ``metrics.jsonl`` next to the
    checkpoints).  Each sampled step also feeds the **health monitor**
    (``obs.health.default_rules``): saturation trends, int32 headroom,
    dead-unit growth, optimiser-scalar stall — alerts print inline and
    (with ``alerts_out``) append as JSONL.  ``trace_out`` writes a span
    trace of the run's phases (step / checkpoint / eval) as JSONL.

    ``metrics_port`` (0 = ephemeral) serves the run's metric registry
    over HTTP — ``train_step_seconds`` / ``train_straggler_events_total``
    plus the health gauges and ``repro_build_info`` — at ``/metrics``,
    ``/metrics.json`` and ``/healthz`` (what ``obs_top`` scrapes live).

    ``autotune=True`` searches kernel tile configurations for every fused
    fwd/bwd problem of this (arch, batch) *before* the train step is
    traced — winners persist in ``autotune_cache`` (default:
    ``tile_cache.json`` next to the checkpoints), so a re-run with a warm
    cache resolves them measurement-free.  Tiling is bitwise
    result-invariant; this is purely a throughput knob.

    ``num_devices > 1`` shards the batch over a ``data`` mesh axis via
    ``repro.parallel.dp`` (``dp_reduce`` picks the all-reduce:
    psum / ring / compress) — the trajectory is *bitwise identical* to
    the single-device run, so this is purely a throughput knob.  The
    process must already have that many JAX devices (``main()`` re-execs
    with ``XLA_FLAGS`` to force host devices on CPU).
    """
    from repro.configs import get_paper_config
    from repro.core import les
    from repro.data import synthetic
    from repro.obs import health as H
    from repro.obs.metrics import (MetricRegistry, register_build_info,
                                   start_metrics_server)
    from repro.obs.trace import NULL_TRACER, Tracer
    from repro.train import checkpoint as ckpt
    from repro.train.fault_tolerance import PreemptionGuard, StepTimer, StragglerDetector

    ds = synthetic.make_image_dataset(dataset, n_train=4096, n_test=512, seed=seed)
    cfg = get_paper_config(arch, scale=scale,
                           input_shape=ds.input_shape if arch.startswith("vgg") else None)
    if arch.startswith("mlp"):
        ds = synthetic.flatten_for_mlp(ds)
        d = ds.input_shape[0]
        if cfg.input_shape != (d,):
            from dataclasses import replace
            cfg = replace(cfg, input_shape=(d,))

    state = les.create_train_state(jax.random.PRNGKey(seed), cfg)
    start_step = 0
    checkpointer = ckpt.AsyncCheckpointer(ckpt_dir) if ckpt_dir else None
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        state, start_step = ckpt.restore(ckpt_dir, state)
        print(f"[restore] resumed from step {start_step}")

    if autotune:
        from repro.kernels import autotune as at
        cache = at.TileCache(
            autotune_cache
            or os.path.join(ckpt_dir or ".", at.CACHE_FILENAME))
        tuned = at.tune_training(cfg, batch, cache=cache)
        at.configure(cache)  # dispatchers resolve tiles when jit traces
        print(f"[autotune] {len(tuned)} problems tuned/cached -> "
              f"{cache.path}")

    if num_devices > 1:
        from repro.parallel import dp
        if batch % num_devices:
            raise SystemExit(
                f"--batch {batch} must divide evenly over "
                f"--num-devices {num_devices}")
        mesh = dp.data_mesh(num_devices)
        print(f"[dp] {num_devices}-device data mesh, reduce={dp_reduce} "
              f"(bitwise ≡ single-device)")
        step_fn = dp.make_dp_train_step(cfg, mesh, dp_reduce=dp_reduce,
                                        fuse_opt=fuse_opt)
    else:
        step_fn = jax.jit(functools.partial(les.train_step, cfg=cfg,
                                            fuse_opt=fuse_opt))
    telem_step_fn = None
    if telemetry_every > 0:
        from repro.obs import telemetry as T
        # a second jit cache entry, not a recompile of the first: the
        # trajectory it returns is bitwise-identical (test-enforced)
        if num_devices > 1:
            # telemetry needs the materialised fw gradients, so these
            # steps keep the split path regardless of --fuse-opt —
            # bitwise-identical trajectory either way (test-enforced)
            telem_step_fn = dp.make_dp_train_step(
                cfg, mesh, dp_reduce=dp_reduce, fuse_opt=fuse_opt,
                telemetry=True)
        else:
            telem_step_fn = jax.jit(
                functools.partial(les.train_step, cfg=cfg, telemetry=True))
        if telemetry_out is None:
            telemetry_out = os.path.join(ckpt_dir or ".", "metrics.jsonl")
        print(f"[telemetry] every {telemetry_every} steps -> {telemetry_out}")
    tracer = Tracer() if trace_out else NULL_TRACER
    guard = PreemptionGuard(install=False)
    straggler = StragglerDetector()
    timer = StepTimer()

    # host-side run metrics + health rules: never touch the jit graph,
    # so the bitwise-identity and float-free guarantees are unaffected
    registry = MetricRegistry()
    register_build_info(registry, backend=jax.default_backend())
    if autotune:
        # count trace-time tile resolutions (hits vs default fallbacks)
        from repro.kernels.autotune import set_metrics
        set_metrics(registry)
    step_seconds = registry.histogram(
        "train_step_seconds", "wall time per training step")
    straggler_events = registry.counter(
        "train_straggler_events_total",
        "steps slower than the straggler EWMA threshold")
    sinks = [H.print_sink]
    if alerts_out:
        sinks.append(H.jsonl_sink(alerts_out))
        print(f"[health] alerts -> {alerts_out}")
    monitor = H.HealthMonitor(registry=registry, sinks=sinks)
    server = None
    if metrics_port is not None:
        server = start_metrics_server(registry, port=metrics_port)
        print(f"[metrics] serving {server.url} (+ /metrics.json /healthz)")

    it = 0
    metrics = None
    while it < steps:
        for x, y in synthetic.batches(ds.x_train, ds.y_train, batch, seed=it):
            if it >= steps or guard.requested:
                break
            sampled = telem_step_fn is not None and it % telemetry_every == 0
            with tracer.span("train.step", step=start_step + it,
                             telemetry=sampled):
                if sampled:
                    state, metrics, telem = telem_step_fn(
                        state, x=jnp.asarray(x), labels=jnp.asarray(y),
                        key=jax.random.PRNGKey(start_step + it),
                    )
                    records = T.to_records(telem, cfg=cfg,
                                           step=start_step + it)
                    T.append_jsonl(telemetry_out, records)
                    monitor.observe_records(records)
                else:
                    state, metrics = step_fn(
                        state, x=jnp.asarray(x), labels=jnp.asarray(y),
                        key=jax.random.PRNGKey(start_step + it),
                    )
            dt = timer.lap()
            step_seconds.observe(dt)
            if straggler.record(dt):
                straggler_events.inc()
                print(f"[straggler] step {it}: {dt:.3f}s vs ewma {straggler.ewma:.3f}s")
            if it % 50 == 0:
                print(f"step {it:5d}  loss={int(metrics.loss)}  "
                      f"scaled={metrics.scaled_loss(batch):.4f}  "
                      f"correct={int(metrics.correct)}/{batch}")
            if checkpointer and it > 0 and it % 200 == 0:
                with tracer.span("train.checkpoint", step=start_step + it):
                    checkpointer.save(start_step + it, state)
            it += 1
        if guard.requested:
            break
    if checkpointer:
        with tracer.span("train.checkpoint", step=start_step + it,
                         final=True):
            checkpointer.save(start_step + it, state)
            checkpointer.wait()

    # test accuracy
    correct = 0
    with tracer.span("train.eval"):
        for i in range(0, len(ds.x_test) - batch + 1, batch):
            correct += int(les.eval_step(
                state, cfg, jnp.asarray(ds.x_test[i:i + batch]),
                jnp.asarray(ds.y_test[i:i + batch])))
    n_eval = (len(ds.x_test) // batch) * batch
    acc = correct / max(n_eval, 1)
    if trace_out:
        n_spans = tracer.export_jsonl(trace_out)
        print(f"[trace] {n_spans} spans -> {trace_out}")
    if monitor.alerts:
        counts = monitor.summary()["by_severity"]
        print(f"[health] {len(monitor.alerts)} alert(s) fired "
              f"({', '.join(f'{k}={v}' for k, v in counts.items() if v)}); "
              f"{len(monitor.active_alerts())} still active")
    if server is not None:
        server.close()
    print(f"[done] test accuracy {acc:.4f} over {n_eval} samples")
    out = {"test_accuracy": acc, "steps": it,
           "straggler_events": straggler.incidents,
           "health": monitor.summary()}
    if metrics is not None:
        out["scaled_loss"] = metrics.scaled_loss(batch)
    return out


def train_lm(arch: str, *, steps: int, batch: int, seq: int, scale: float,
             ckpt_dir: str | None, les_groups: int = 0, seed: int = 0) -> dict:
    """Reduced-scale LM training on CPU (same code path as the dry-run)."""
    from dataclasses import replace

    from repro.configs import get_smoke_config
    from repro.data.loader import ShardedLoader, synthetic_lm_generator
    from repro.launch.mesh import make_test_mesh
    from repro.parallel.sharding import train_rules
    from repro.train import checkpoint as ckpt
    from repro.train import trainer

    cfg = get_smoke_config(arch)
    if les_groups:
        cfg = replace(cfg, les_groups=les_groups, num_layers=max(cfg.num_layers, 4))
    mesh = make_test_mesh(1, 1)
    rules = trainer.resolved_rules(cfg, train_rules(False))

    gen = synthetic_lm_generator(cfg.vocab_size, seq, batch, seed=seed)
    loader = ShardedLoader(gen, global_batch=batch,
                           process_index=0, process_count=1)
    shapes = {"tokens": (batch, seq), "labels": (batch, seq)}
    step_fn = trainer.build_train_step(cfg, mesh, rules, shapes=shapes,
                                       donate=False)
    state = trainer.init_state(jax.random.PRNGKey(seed), cfg)

    start = 0
    if ckpt_dir and ckpt.latest_step(ckpt_dir) is not None:
        state, start = ckpt.restore(ckpt_dir, state)
        print(f"[restore] resumed from step {start}")

    losses = []
    for it in range(steps):
        b = next(loader)
        state, metrics = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        losses.append(float(metrics["loss"]))
        if it % 20 == 0:
            print(f"step {it:4d}  loss={losses[-1]:.4f}  "
                  f"gnorm={float(metrics['grad_norm']):.3f}")
    loader.close()
    if ckpt_dir:
        ckpt.save(ckpt_dir, start + steps, state)
    print(f"[done] loss {losses[0]:.4f} → {losses[-1]:.4f}")
    return {"first_loss": losses[0], "last_loss": losses[-1]}


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--scale", type=float, default=1.0)
    ap.add_argument("--dataset", default="tiles32")
    ap.add_argument("--ckpt-dir")
    ap.add_argument("--les-groups", type=int, default=0)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--telemetry-every", type=int, default=0,
                    help="sample integer-numerics telemetry every N steps "
                         "(0 = off) into --telemetry-out")
    ap.add_argument("--telemetry-out",
                    help="telemetry JSONL path (default: metrics.jsonl "
                         "next to the checkpoints)")
    ap.add_argument("--trace-out",
                    help="write a span trace of the run (JSONL)")
    ap.add_argument("--metrics-port", type=int, default=None,
                    help="serve /metrics, /metrics.json and /healthz on "
                         "this port (0 = ephemeral; NITRO archs)")
    ap.add_argument("--alerts-out",
                    help="append health alerts as JSONL (they always "
                         "print inline)")
    ap.add_argument("--num-devices", type=int, default=1,
                    help="data-parallel device count (NITRO archs; "
                         "trajectory is bitwise-identical at any value)")
    ap.add_argument("--dp-reduce", default="psum",
                    choices=("psum", "ring", "compress"),
                    help="gradient all-reduce: XLA psum, hand-scheduled "
                         "ring, or int8-limb compressed (all exact)")
    ap.add_argument("--fuse-opt", action="store_true",
                    help="apply the IntegerSGD update in the grad "
                         "kernels' flush (NITRO archs; single-device "
                         "fast path — DP applies the standalone fused "
                         "kernel post-reduce; bitwise-identical)")
    ap.add_argument("--autotune", action="store_true",
                    help="search kernel tile configs for this (arch, "
                         "batch) before compiling (NITRO archs; bitwise "
                         "result-invariant)")
    ap.add_argument("--autotune-cache",
                    help="tile-cache JSON path (default: tile_cache.json "
                         "next to the checkpoints)")
    args = ap.parse_args()

    if args.num_devices > 1 and jax.device_count() < args.num_devices:
        # XLA only honours forced host devices before backend init — too
        # late in this process (device_count() just initialised it), so
        # re-exec ourselves with the flag set.
        if os.environ.get("_REPRO_DP_REEXEC"):
            raise SystemExit(
                f"--num-devices {args.num_devices}: still only "
                f"{jax.device_count()} devices after forcing XLA_FLAGS")
        import sys
        os.environ["XLA_FLAGS"] = (
            os.environ.get("XLA_FLAGS", "") +
            f" --xla_force_host_platform_device_count={args.num_devices}"
        ).strip()
        os.environ["_REPRO_DP_REEXEC"] = "1"
        os.execv(sys.executable,
                 [sys.executable, "-m", "repro.launch.train"] + sys.argv[1:])

    from repro.configs import ARCHS, PAPER_ARCHS

    if args.arch in PAPER_ARCHS:
        train_nitro(args.arch, steps=args.steps, batch=args.batch,
                    ckpt_dir=args.ckpt_dir, dataset=args.dataset,
                    scale=args.scale, seed=args.seed,
                    telemetry_every=args.telemetry_every,
                    telemetry_out=args.telemetry_out,
                    trace_out=args.trace_out,
                    num_devices=args.num_devices, dp_reduce=args.dp_reduce,
                    fuse_opt=args.fuse_opt,
                    metrics_port=args.metrics_port,
                    alerts_out=args.alerts_out,
                    autotune=args.autotune,
                    autotune_cache=args.autotune_cache)
    elif args.arch in ARCHS:
        train_lm(args.arch, steps=args.steps, batch=args.batch, seq=args.seq,
                 scale=args.scale, ckpt_dir=args.ckpt_dir,
                 les_groups=args.les_groups, seed=args.seed)
    else:
        raise SystemExit(f"unknown arch {args.arch}")


if __name__ == "__main__":
    main()
