"""Fleet serving: continuous batching + A/B routing over a ModelRegistry.

The static ``VisionEngine`` runs one model and serialises host and
device work: wait ``max_wait_ms`` → stack → launch → block on results →
repeat, leaving the device idle during every host phase.  ``FleetEngine``
replaces that loop with a **continuous, double-buffered scheduler** over
every model in a ``ModelRegistry``:

  * requests land on bounded **per-model queues** (backpressure: submit
    blocks when a model's queue is full);
  * one worker drains the queues with **smooth weighted round-robin** —
    a model with weight 3 gets three batches for every one of a
    weight-1 model, with no starvation;
  * the worker keeps **one batch in flight on device while assembling
    the next on host**: the in-flight batch *is* the wait timer — while
    the device is busy, arrivals accumulate toward the next batch for
    free, and a queue that reaches ``batch_size`` mid-flight is stacked
    and padded while the device still computes.  There is no
    ``max_wait_ms``: under load, batches are full without ever sleeping
    on a wall clock; from idle, a request launches after at most one
    sub-ms coalescing window (``coalesce_ms``, which exists only so a
    burst of co-arriving requests shares one padded launch instead of
    each paying a full one).  Because every launch is padded to a fixed
    cost, partial queues are never popped mid-flight — they regroup
    with the requests this flight's delivery unblocks (see
    ``_next_batch``).

``Router`` sits in front of ``submit``: a routing target is either a
concrete model id (passthrough) or a **split alias** whose weighted arms
are chosen by a deterministic hash of the request id — the same request
id always lands on the same arm, across processes and restarts, which is
what makes an A/B experiment analysable.

Numerics are untouched: batches are assembled with the same helpers as
``VisionEngine`` and run the same compiled plans, so fleet-routed logits
are bit-exact with a standalone engine (asserted in tests/test_fleet.py).
"""

from __future__ import annotations

import hashlib
import threading
import time
from collections import deque
from concurrent.futures import Future

import jax
import numpy as np

from repro.obs.metrics import MetricRegistry
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.registry import ModelEntry, ModelRegistry
from repro.serving.stats import (
    REQUEST_DEADLINE_SECONDS,
    SLACK_BUCKETS,
    SLO_DEADLINE_SECONDS,
    SLO_VIOLATIONS_TOTAL,
    EngineStats,
    Slo,
)
from repro.serving.vision import (
    Request,
    VisionResult,
    assemble_batch,
    fail_batch,
    resolve_batch,
)


# ---------------------------------------------------------------------------
# Router — deterministic A/B traffic splitting
# ---------------------------------------------------------------------------


def _hash_fraction(request_id: str) -> float:
    """Deterministic uniform fraction in [0, 1) from a request id."""
    digest = hashlib.sha256(str(request_id).encode()).digest()
    return int.from_bytes(digest[:8], "big") / 2**64


def parse_split(spec: str) -> dict[str, float]:
    """CLI split spec ``"a=0.9,b=0.1"`` → {model_id: weight}."""
    arms: dict[str, float] = {}
    for part in spec.split(","):
        mid, _, w = part.partition("=")
        mid = mid.strip()
        if not mid or not w:
            raise ValueError(f"bad split spec {spec!r} (want a=0.9,b=0.1)")
        arms[mid] = float(w)
    return arms


class Router:
    """Maps routing targets to model ids, with weighted A/B split aliases.

    A target that is not a split alias resolves to itself, so concrete
    model ids route with zero configuration.  Split arms are normalised
    and kept in sorted order: the arm is picked by where the request-id
    hash falls in the cumulative weight line, so the arm choice is a pure
    function of (splits, request id).
    """

    def __init__(self, splits: dict[str, dict[str, float]] | None = None):
        self._splits: dict[str, tuple[tuple[str, float], ...]] = {}
        for alias, arms in (splits or {}).items():
            self.add_split(alias, arms)

    def add_split(self, alias: str, arms: dict[str, float]) -> None:
        if not arms:
            raise ValueError(f"split {alias!r} has no arms")
        total = float(sum(arms.values()))
        if total <= 0:
            raise ValueError(f"split {alias!r} weights must sum > 0")
        if any(w < 0 for w in arms.values()):
            raise ValueError(f"split {alias!r} has a negative weight")
        self._splits[alias] = tuple(
            (mid, w / total) for mid, w in sorted(arms.items())
        )

    def arms(self, alias: str) -> tuple[tuple[str, float], ...]:
        return self._splits[alias]

    @property
    def aliases(self) -> list[str]:
        return sorted(self._splits)

    def resolve(self, target: str, request_id: str) -> str:
        """Routing target + request id → concrete model id."""
        arms = self._splits.get(target)
        if arms is None:
            return target
        frac = _hash_fraction(request_id)
        acc = 0.0
        for mid, w in arms:
            acc += w
            if frac < acc:
                return mid
        return arms[-1][0]  # frac ~ 1.0 lands on the last arm


# ---------------------------------------------------------------------------
# Continuous-batching engine
# ---------------------------------------------------------------------------


class FleetEngine:
    """Multi-model continuous-batching engine over a ModelRegistry.

    One daemon worker serves every registered model; per-model queues are
    drained by smooth weighted round-robin and batches are double-
    buffered (assemble N+1 on host while N runs on device).

    Observability: ``metrics`` (defaulting to the registry's shared
    ``MetricRegistry``, if it has one) adds the fleet-wide counters as
    ``serve_*_total{model="_fleet"}`` plus a per-model
    ``serve_queue_depth`` gauge and a ``serve_batch_fill`` histogram
    (real fraction of every launched batch).  ``tracer`` (an
    ``obs.Tracer``) records one span per batch-lifecycle phase —
    assemble / dispatch / fetch / deliver — tagged with the model id;
    both default to no-ops with zero hot-path cost.

    SLO attribution: a model whose ``ModelEntry`` carries an
    ``Slo(deadline_ms)`` gets every delivered request's deadline slack
    recorded (``serve_request_deadline_seconds{model=…}`` histogram,
    ``serve_slo_violations_total{model=…}`` counter,
    ``serve_slo_deadline_seconds`` gauge for dashboards) plus an
    engine-local roll-up in ``slo_snapshot()`` — see
    ``serving.stats.Slo``.
    """

    def __init__(
        self,
        registry: ModelRegistry,
        *,
        batch_size: int = 32,
        queue_depth: int = 256,
        weights: dict[str, float] | None = None,
        router: Router | None = None,
        coalesce_ms: float = 1.0,
        metrics: MetricRegistry | None = None,
        tracer: Tracer | None = None,
    ):
        self.registry = registry
        self.batch_size = batch_size
        self.queue_depth = queue_depth
        self.coalesce_ms = coalesce_ms
        self.router = router or Router()
        self.tracer = tracer if tracer is not None else NULL_TRACER
        # pre-bound batch-lifecycle spans: the name (and, for attr-less
        # phases, the attrs dict) is resolved once here instead of per
        # batch — the fleet-tracing row of BENCH_obs.json is gated <3%
        self._span_assemble = self.tracer.bind("fleet.assemble")
        self._span_dispatch = self.tracer.bind("fleet.dispatch")
        self._span_fetch = self.tracer.bind("fleet.fetch")
        self._span_deliver = self.tracer.bind("fleet.deliver")
        # per-model SLO accounting (requests, violations) — written only
        # by the worker thread, read by slo_snapshot()
        self._slo_counts: dict[str, list[int]] = {}
        # inherit the registry's shared metrics so a metrics-enabled fleet
        # needs no extra plumbing; an explicit metrics= still wins
        self.metrics = metrics if metrics is not None else registry.metrics
        if self.metrics is not None:
            # fleet-wide counters join the per-model families under a
            # reserved label value (a real id can't be empty, "_fleet" is
            # ours by convention)
            self.stats = EngineStats(registry=self.metrics,
                                     labels={"model": "_fleet"})
            self._depth_gauge = self.metrics.gauge(
                "serve_queue_depth", "queued requests per model",
                labels=("model",),
            )
            self._fill_hist = self.metrics.histogram(
                "serve_batch_fill",
                "real (unpadded) fraction of each launched batch",
                buckets=(0.125, 0.25, 0.375, 0.5, 0.625, 0.75, 0.875, 1.0),
            )
            self._deadline_hist = self.metrics.histogram(
                REQUEST_DEADLINE_SECONDS,
                "per-request deadline slack in seconds "
                "(negative = SLO violated)",
                labels=("model",), buckets=SLACK_BUCKETS,
            )
            self._slo_violations = self.metrics.counter(
                SLO_VIOLATIONS_TOTAL,
                "requests answered after their model's SLO deadline",
                labels=("model",),
            )
            self._slo_deadline = self.metrics.gauge(
                SLO_DEADLINE_SECONDS,
                "configured per-model SLO deadline",
                labels=("model",),
            )
        else:
            self.stats = EngineStats()  # fleet-wide; per-model in entry.stats
            self._depth_gauge = None
            self._fill_hist = None
            self._deadline_hist = None
            self._slo_violations = None
            self._slo_deadline = None
        self._weights = dict(weights or {})
        self._wrr: dict[str, float] = {}
        self._queues: dict[str, deque[Request]] = {}
        self._cond = threading.Condition()
        self._closed = False
        self._auto_id = 0
        self._worker = threading.Thread(target=self._serve_loop, daemon=True)
        self._worker.start()

    # ---- client API -------------------------------------------------------

    def submit(self, image: np.ndarray, *, model: str,
               request_id: str | None = None) -> "Future[VisionResult]":
        """Enqueue one image for ``model`` (a model id or a split alias).

        Blocks only when the target model's queue is full (backpressure).
        ``request_id`` pins A/B routing; omitted ids get a process-local
        sequence number (unique, but not stable across runs — pass real
        ids when the experiment assignment matters).
        """
        if request_id is None:
            with self._cond:
                request_id = f"auto-{self._auto_id}"
                self._auto_id += 1
        model_id = self.router.resolve(model, request_id)
        entry = self.registry.get(model_id)  # raises on unknown id
        if tuple(image.shape) != entry.input_shape:
            raise ValueError(
                f"image shape {tuple(image.shape)} != model "
                f"{model_id!r} input shape {entry.input_shape}"
            )
        req = Request(np.asarray(image, np.int32), Future(),
                      time.perf_counter())
        with self._cond:
            if self._closed:
                raise RuntimeError("engine is closed")
            while True:
                # re-fetched after every wait: the idle housekeeping may
                # have deleted an evicted model's drained queue while we
                # slept, and appending to that orphaned deque would strand
                # the request (the worker only scans self._queues)
                q = self._queues.setdefault(model_id, deque())
                if len(q) < self.queue_depth:
                    break
                self._cond.wait()
                if self._closed:
                    raise RuntimeError("engine is closed")
            q.append(req)
            if self._depth_gauge is not None:
                self._depth_gauge.labels(model=model_id).set(len(q))
            self._cond.notify_all()
        return req.future

    def classify(self, images, *, model: str) -> list[int]:
        """Blocking convenience: a list of images → predicted labels."""
        futs = [self.submit(img, model=model) for img in images]
        return [f.result().label for f in futs]

    def snapshot(self) -> dict:
        """Fleet-wide + per-model stats in one JSON-ready dict."""
        return {"fleet": self.stats.snapshot(),
                "models": self.registry.snapshot(),
                "slo": self.slo_snapshot()}

    def slo_snapshot(self) -> dict:
        """Per-model SLO attribution: {model: requests/violations/frac}.

        Only models with a configured ``Slo`` appear.  Written solely by
        the worker thread; a concurrent read sees some prefix of the
        delivered batches, never a torn one (the two list slots are
        updated under the GIL in one bytecode run).
        """
        return {
            mid: {"requests": c[0], "violations": c[1],
                  "violation_frac": c[1] / c[0] if c[0] else 0.0}
            for mid, c in sorted(self._slo_counts.items())
        }

    def close(self):
        """Drain every queue (all futures resolve) and stop the worker."""
        with self._cond:
            if self._closed:
                return
            self._closed = True
            self._cond.notify_all()
        self._worker.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()

    # ---- worker -----------------------------------------------------------

    def _pick_model(self, *, commit: bool = True, min_items: int = 1,
                    aged_before: float | None = None) -> str | None:
        """Smooth weighted round-robin over models with queued work.

        Every active model's credit grows by its weight each round and
        the highest-credit model pays the round total when picked — the
        classic smooth-WRR invariant: over W rounds a weight-w model is
        picked w/W of the time, and no active model starves.

        ``commit=False`` answers "which model *would* be picked" without
        advancing any credits (the coalescing window peeks at its queue).
        ``min_items`` restricts the round to queues holding at least that
        many requests (the mid-flight full-batches-only grab);
        ``aged_before`` additionally admits a partial queue whose HEAD
        request predates that timestamp — the anti-starvation valve: a
        request that was already waiting when the current in-flight batch
        dispatched has sat out a full scheduling round and must not wait
        behind another model's endless full batches.
        Caller holds ``self._cond``.
        """
        active = [
            mid for mid, q in self._queues.items()
            if len(q) >= min_items
            or (q and aged_before is not None
                and q[0].t_submit < aged_before)
        ]
        if not active:
            return None
        total = 0.0
        best = None
        tentative: dict[str, float] = {}
        for mid in sorted(active):  # sorted: deterministic tie-break
            w = self._weights.get(mid, 1.0)
            tentative[mid] = self._wrr.get(mid, 0.0) + w
            total += w
            if best is None or tentative[mid] > tentative[best]:
                best = mid
        if commit:
            self._wrr.update(tentative)
            self._wrr[best] -= total
        return best

    def _next_batch(self, *, block: bool, aged_before: float | None = None):
        """Pop ≤ batch_size requests from the WRR-chosen model queue.

        ``block=False`` is the double-buffering path: a batch is already
        in flight, so return immediately with whatever is queued (maybe
        nothing) instead of idling the host.  Returns ``None`` when there
        is no work — and the engine is closed, if ``block=True``.

        Every batch is a fixed-cost padded launch, so *when* to pop is a
        fill decision, not just a liveness one:

        * mid-flight (``block=False``) only a **full** queue is popped —
          a full batch cannot grow further, so assembling it early is
          free overlap; a partial batch popped now would fragment its
          cohort across several full-price launches, while leaving it
          queued lets the requests that unblock on this flight's
          delivery regroup with it.  Exception (anti-starvation): a
          partial queue whose head request predates the in-flight
          batch's dispatch (``aged_before``) has already sat out one
          full round and is admitted, so another model's sustained
          full-batch load can delay a sparse model by at most ~two
          flights, never unboundedly;
        * from idle (``block=True``) waking on the *first* arrival would
          launch a one-item batch while its co-arrivals land
          microseconds later, so an idle wake holds a bounded
          **coalescing window** (``coalesce_ms``) for a queue to reach
          ``batch_size`` before popping whatever accumulated.
        """
        with self._cond:
            if not block:
                model_id = self._pick_model(min_items=self.batch_size,
                                            aged_before=aged_before)
                return None if model_id is None else self._pop(model_id)
            # idle housekeeping: drop scheduler state (queue + WRR credit)
            # of evicted models once their queues have drained, or a
            # long-lived engine cycling many transient A/B arms leaks one
            # dead deque per id and scans them all every round
            for mid in [m for m, q in self._queues.items()
                        if not q and m not in self.registry]:
                del self._queues[mid]
                self._wrr.pop(mid, None)
            while not any(self._queues.values()):
                if self._closed:
                    return None
                self._cond.wait()
            if self.coalesce_ms > 0:
                # the window watches the queue WRR would actually pop (a
                # peek, not a committed pick) — another model's full queue
                # must not end the window for a still-near-empty winner
                deadline = time.perf_counter() + self.coalesce_ms / 1e3
                while (not self._closed
                       and len(self._queues[
                           self._pick_model(commit=False)])
                       < self.batch_size):
                    remaining = deadline - time.perf_counter()
                    if remaining <= 0:
                        break
                    self._cond.wait(remaining)
            return self._pop(self._pick_model())

    def _pop(self, model_id: str):
        """Pop ≤ batch_size requests; caller holds ``self._cond``."""
        q = self._queues[model_id]
        items = [q.popleft() for _ in range(min(len(q), self.batch_size))]
        if self._depth_gauge is not None:
            self._depth_gauge.labels(model=model_id).set(len(q))
        self._cond.notify_all()  # free backpressured submitters
        return model_id, items

    def _assemble(self, model_id: str, items: list[Request]):
        """Stack + pad one popped batch; returns (entry, items, batch, plan)
        or None on failure (futures failed in place).

        The guard is broad on purpose: ANY escape here (model evicted
        while queued, or evict+re-register changing the input shape so
        the stack fails) would otherwise kill the engine's only worker
        thread and hang every pending future.
        """
        with self._span_assemble(model=model_id, n=len(items)):
            try:
                entry: ModelEntry = self.registry.get(model_id)
                plan = entry.plan  # read once: hot-swap flips atomically
                pad = self.registry.pad_buffer(plan.input_shape)
                batch = assemble_batch(items, pad, self.batch_size)
            except Exception as e:
                fail_batch(items, RuntimeError(
                    f"cannot assemble batch for model {model_id!r} "
                    f"(evicted, or replaced with an incompatible "
                    f"model?): {e}"))
                return None
        return entry, items, batch, plan

    def _dispatch(self, assembled):
        """Asynchronously launch one assembled batch; returns in-flight
        state (entry, items, device array, t_launch) or None on failure."""
        entry, items, batch, plan = assembled
        with self._span_dispatch(model=entry.model_id, n=len(items)):
            t0 = time.perf_counter()
            try:
                dev = plan.logits(batch)  # async — returns immediately
            except Exception as e:  # trace/compile-time failure
                fail_batch(items, e)
                return None
        return entry, items, dev, t0

    def _fetch(self, inflight):
        """Block until one in-flight batch completes; returns results or
        None on failure (futures failed in place).

        The completion time is stamped HERE — delivery happens after the
        next batch's dispatch, and charging this batch's waiters for that
        dispatch (worst case: a cold jit compile of another model) would
        misattribute seconds to requests already finished on device.
        """
        entry, items, dev, t0 = inflight
        with self._span_fetch(model=entry.model_id):
            try:
                logits = np.asarray(jax.device_get(dev))
            except Exception as e:  # runtime failure surfaces at the fetch
                fail_batch(items, e)
                return None
        return entry, items, logits, t0, time.perf_counter()

    def _deliver(self, fetched) -> None:
        """Record stats, then resolve one completed batch's futures.

        Stats land first: a client that unblocks on its future and
        immediately snapshots must already see this batch counted.
        """
        entry, items, logits, t0, t_done = fetched
        n = len(items)
        with self._span_deliver(model=entry.model_id, n=n):
            entry.stats.record_batch(n, self.batch_size - n, t_done - t0)
            self.stats.record_batch(n, self.batch_size - n, t_done - t0)
            if self._fill_hist is not None:
                self._fill_hist.observe(n / self.batch_size)
            if entry.slo is not None:
                self._attribute_slo(entry, items, t_done)
            resolve_batch(items, logits, t_done)

    def _attribute_slo(self, entry: ModelEntry, items: list[Request],
                       t_done: float) -> None:
        """Per-request deadline attribution for one delivered batch.

        Slack is measured against the request's **end-to-end** latency
        (submit → delivery-ready), not the device batch latency — queueing
        behind other models' batches is exactly the cost the future
        SLO-aware scheduler must see.  Negative slack = violation.
        """
        slo: Slo = entry.slo
        deadline_s = slo.deadline_s
        slacks = [deadline_s - (t_done - req.t_submit) for req in items]
        violations = sum(1 for s in slacks if s < 0)
        counts = self._slo_counts.setdefault(entry.model_id, [0, 0])
        counts[0] += len(items)
        counts[1] += violations
        if self.metrics is not None:
            hist = self._deadline_hist.labels(model=entry.model_id)
            # touch the violation counter even when zero: a scrape must
            # distinguish "no misses" from "never attributed"
            violation_ctr = self._slo_violations.labels(
                model=entry.model_id)
            with self.metrics.lock:  # scrape-atomic per batch
                self._slo_deadline.labels(model=entry.model_id).set(
                    deadline_s)
                for s in slacks:
                    hist.observe(s)
                if violations:
                    violation_ctr.inc(violations)

    def _serve_loop(self):
        # The pipeline keeps exactly ONE batch executing at any moment and
        # hides every piece of host work behind it:
        #
        #   assemble N+1   (overlaps N's device execution)
        #   fetch N        (the only blocking point)
        #   dispatch N+1   (device busy again immediately)
        #   deliver N      (futures/argmax/stats overlap N+1's execution)
        #
        # Dispatching N+1 *before* fetching N would put two executions on
        # the device at once — a win only when the device has spare
        # parallelism; on a CPU backend the two thrash one thread pool.
        # This order never oversubscribes and still keeps the gap between
        # consecutive executions down to one host↔device fetch.
        inflight = None
        while True:
            # with a batch on device, don't wait for arrivals (block=False):
            # grab an already-full (or starving — older than the in-flight
            # dispatch) batch so assembly overlaps device work
            nxt = self._next_batch(
                block=inflight is None,
                aged_before=inflight[3] if inflight is not None else None)
            if nxt is None and inflight is None:
                return  # closed and fully drained
            assembled = self._assemble(*nxt) if nxt is not None else None
            fetched = self._fetch(inflight) if inflight is not None else None
            inflight = self._dispatch(assembled) if assembled else None
            if fetched is not None:
                self._deliver(fetched)
