"""Serving substrate: batched prefill/decode engine over the model zoo."""
