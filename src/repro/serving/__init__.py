"""Serving substrate.

engine.py    batched prefill/decode LM engine over the model zoo
vision.py    static dynamic-batching integer CNN engine over a fused
             repro.infer ExecutionPlan (the NITRO-D deploy path)
stats.py     thread-safe EngineStats over repro.obs.MetricRegistry +
             re-exported nearest-rank latency percentiles
registry.py  ModelRegistry: many FrozenModels compiled + hot-swapped
             under stable model ids, shared padding buffers; pass
             metrics= for scrapeable per-model counters + swap events
fleet.py     FleetEngine: continuous (double-buffered) batching over
             every registered model — per-model queues, weighted
             round-robin, deterministic A/B Router; queue-depth /
             batch-fill metrics and per-phase tracer spans

One model, simplest path:  compile_plan → VisionEngine.
A fleet of models:         ModelRegistry → FleetEngine (+ Router splits).
Data flow in docs/SERVING.md; metric catalogue in docs/OBSERVABILITY.md.
"""

# Lazy re-exports: the LM path (`repro.serving.engine`) deliberately
# imports light, and an eager package init would drag the whole
# fleet -> registry -> infer -> kernels chain into it.
_EXPORTS = {
    "FleetEngine": "repro.serving.fleet",
    "Router": "repro.serving.fleet",
    "parse_split": "repro.serving.fleet",
    "ModelEntry": "repro.serving.registry",
    "ModelRegistry": "repro.serving.registry",
    "EngineStats": "repro.serving.stats",
    "Slo": "repro.serving.stats",
    "fleet_snapshot_delta": "repro.serving.stats",
    "latency_summary_ms": "repro.serving.stats",
    "percentile": "repro.serving.stats",
    "slo_summary": "repro.serving.stats",
    "snapshot_delta": "repro.serving.stats",
    "VisionEngine": "repro.serving.vision",
    "VisionResult": "repro.serving.vision",
}

__all__ = sorted(_EXPORTS)


def __getattr__(name):
    try:
        module = _EXPORTS[name]
    except KeyError:
        raise AttributeError(
            f"module 'repro.serving' has no attribute {name!r}"
        ) from None
    import importlib

    return getattr(importlib.import_module(module), name)
