"""Serving substrate.

engine.py  batched prefill/decode LM engine over the model zoo
vision.py  dynamic-batching integer CNN engine over a fused
           repro.infer ExecutionPlan (the NITRO-D deploy path)
"""
