"""Shared serving statistics: latency percentiles + thread-safe counters.

Since the ``repro.obs`` observability layer landed, this module is the
serving-facing veneer over ``obs.metrics``: the percentile helpers are
re-exported from there (one nearest-rank implementation for the whole
repo) and ``EngineStats`` is built on a ``MetricRegistry`` — the same
counters that ``serve_vision --metrics-port`` exposes over Prometheus
text.

``EngineStats`` keeps its historical surface (``requests`` /
``batches`` / ``padded_slots`` reads, ``record_batch``, ``snapshot()``)
so engines and benchmarks are unchanged.  Two modes:

  * standalone (default): a private registry per instance — exactly the
    old behaviour;
  * shared: pass ``registry=`` + ``labels=`` and the counters become
    children of the shared families (``serve_requests_total{model=…}``
    etc.), which is how ``ModelRegistry`` folds every model's stats into
    one scrapeable registry.

``EngineStats`` is written from an engine's worker thread while clients
read it concurrently: ``record_batch`` holds the registry lock across
all its updates (one acquisition per *batch*, not per request —
negligible next to a device launch), so ``snapshot()`` — which takes the
same lock — never observes a half-applied batch.

This module also owns the **SLO vocabulary**: ``Slo(deadline_ms)`` is
the per-model objective a ``ModelEntry`` carries, ``slo_summary`` the
per-arm p99-vs-SLO roll-up benchmarks report, and the
``serve_request_deadline_seconds`` / ``serve_slo_violations_total``
family names the fleet engine emits under.
"""

from __future__ import annotations

from dataclasses import dataclass

from repro.obs.metrics import (  # noqa: F401 — historical re-export home
    PERCENTILES,
    MetricRegistry,
    latency_summary_ms,
    percentile,
)

# Metric-family names EngineStats registers (shared across every scope).
REQUESTS_TOTAL = "serve_requests_total"
BATCHES_TOTAL = "serve_batches_total"
PADDED_SLOTS_TOTAL = "serve_padded_slots_total"
BATCH_LATENCY_SECONDS = "serve_batch_latency_seconds"

# SLO-attribution families (FleetEngine, per ``model`` label).
REQUEST_DEADLINE_SECONDS = "serve_request_deadline_seconds"
SLO_VIOLATIONS_TOTAL = "serve_slo_violations_total"
SLO_DEADLINE_SECONDS = "serve_slo_deadline_seconds"

# Deadline-slack buckets (seconds): symmetric around 0 so the violating
# tail (negative slack = missed deadline) is as resolvable as the
# healthy side — a latency-shaped all-positive ladder would fold every
# miss into one bucket.
SLACK_BUCKETS = (-1.0, -0.25, -0.1, -0.05, -0.01, 0.0,
                 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0)


@dataclass(frozen=True)
class Slo:
    """A per-model serving objective: answer within ``deadline_ms``.

    Attached to a ``ModelEntry`` (``ModelRegistry.register(..., slo=)``
    or ``set_slo``); ``FleetEngine`` then records every delivered
    request's **deadline slack** (``deadline − end-to-end latency``,
    seconds; negative = violation) into
    ``serve_request_deadline_seconds{model=…}`` and counts misses in
    ``serve_slo_violations_total{model=…}`` — the attribution substrate
    the ROADMAP's SLO-aware scheduler will optimise against.
    """

    deadline_ms: float

    def __post_init__(self):
        if not self.deadline_ms > 0:
            raise ValueError(f"Slo deadline must be > 0 ms, "
                             f"got {self.deadline_ms!r}")

    @property
    def deadline_s(self) -> float:
        return self.deadline_ms / 1e3

    def slack_s(self, latency_s: float) -> float:
        """Signed headroom of one answered request (negative = missed)."""
        return self.deadline_s - latency_s


def slo_summary(latencies_s, slo: Slo | None) -> dict:
    """Per-arm p99-vs-SLO roll-up (the ``BENCH_serve.json`` fields).

    ``latencies_s`` are end-to-end per-request latencies for one model
    arm; with no SLO configured only the p99 is reported.
    """
    lats = sorted(latencies_s)
    p99_ms = percentile(lats, 0.99) * 1e3
    out = {"p99_ms": p99_ms, "slo_ms": None}
    if slo is not None:
        violations = sum(1 for v in lats if v > slo.deadline_s)
        out.update(
            slo_ms=slo.deadline_ms,
            p99_slack_ms=slo.deadline_ms - p99_ms,
            slo_violations=violations,
            violation_frac=violations / len(lats) if lats else 0.0,
            meets_slo=p99_ms <= slo.deadline_ms,
        )
    return out


def snapshot_delta(pre: dict, post: dict) -> dict:
    """Counter difference of two ``EngineStats.snapshot()`` views.

    The standard way to exclude warmup work (compile batches) from
    reported serving stats: snapshot after warmup, snapshot after the
    timed run, report the delta.  The windowed batch-latency percentiles
    are not diffable and are omitted.
    """
    requests = post["requests"] - pre["requests"]
    padded = post["padded_slots"] - pre["padded_slots"]
    total = requests + padded
    return {
        "requests": requests,
        "batches": post["batches"] - pre["batches"],
        "padded_slots": padded,
        "avg_batch_fill": requests / total if total else 0.0,
    }


def fleet_snapshot_delta(pre: dict, post: dict) -> dict:
    """Delta of two ``FleetEngine.snapshot()`` views (fleet + per-model).

    A model registered after ``pre`` was taken is deltaed against zero.
    """
    zero = {"requests": 0, "batches": 0, "padded_slots": 0}
    return {
        "fleet": snapshot_delta(pre["fleet"], post["fleet"]),
        "models": {
            mid: snapshot_delta(pre["models"].get(mid, zero), m)
            for mid, m in post["models"].items()
        },
    }


class EngineStats:
    """Thread-safe per-engine (or per-model) serving counters.

    Backed by ``obs.metrics`` families; ``snapshot()`` is the consistent
    view — it holds the same lock ``record_batch`` writes under, so a
    snapshot never observes a half-applied batch.
    """

    def __init__(self, *, latency_window: int = 1024,
                 registry: MetricRegistry | None = None,
                 labels: dict[str, str] | None = None):
        if registry is None and labels:
            raise ValueError("labels require a shared registry")
        self.registry = registry or MetricRegistry()
        labels = dict(labels or {})
        names = tuple(sorted(labels))
        reg = self.registry
        self._requests = reg.counter(
            REQUESTS_TOTAL, "requests answered", labels=names).labels(**labels)
        self._batches = reg.counter(
            BATCHES_TOTAL, "device batches launched", labels=names,
        ).labels(**labels)
        self._padded = reg.counter(
            PADDED_SLOTS_TOTAL, "zero-padded batch slots", labels=names,
        ).labels(**labels)
        # bounded window: a long-lived engine must not grow host memory
        self._latency = reg.histogram(
            BATCH_LATENCY_SECONDS, "per-batch device latency", labels=names,
            window=latency_window,
        ).labels(**labels)

    def record_batch(self, n: int, padded: int, latency_s: float) -> None:
        with self.registry.lock:  # re-entrant: one atomic multi-metric update
            self._requests.inc(n)
            self._batches.inc()
            self._padded.inc(padded)
            self._latency.observe(latency_s)

    @property
    def requests(self) -> int:
        return self._requests.value

    @property
    def batches(self) -> int:
        return self._batches.value

    @property
    def padded_slots(self) -> int:
        return self._padded.value

    @property
    def batch_latency_s(self):
        """The bounded latency-sample window (read-only compat view)."""
        return self._latency.window

    @property
    def avg_batch_fill(self) -> float:
        with self.registry.lock:
            requests, padded = self._requests.value, self._padded.value
        total = requests + padded
        return requests / total if total else 0.0

    def snapshot(self) -> dict:
        """Consistent JSON-ready view: counters + batch-latency percentiles."""
        with self.registry.lock:
            requests = self._requests.value
            batches = self._batches.value
            padded = self._padded.value
            lats = list(self._latency.window)
        total = requests + padded
        return {
            "requests": requests,
            "batches": batches,
            "padded_slots": padded,
            "avg_batch_fill": requests / total if total else 0.0,
            "batch_latency_ms": latency_summary_ms(lats),
        }
