"""Shared serving statistics: latency percentiles + thread-safe counters.

One home for the percentile math that was previously duplicated across
``benchmarks/serve_infer.py`` and the ``serve_vision`` CLI, plus the
``EngineStats`` record shared by the static ``VisionEngine`` and the
continuous-batching ``FleetEngine``.

``EngineStats`` is written from an engine's worker thread while clients
read it concurrently, so every mutation goes through ``record_batch``
(one lock acquisition per *batch*, not per request — negligible next to
a device launch) and readers take a consistent copy via ``snapshot()``.
"""

from __future__ import annotations

import threading
from collections import deque

# Percentiles every serving surface reports, as (label, quantile).
PERCENTILES = (("p50", 0.50), ("p90", 0.90), ("p95", 0.95), ("p99", 0.99))


def percentile(sorted_vals, q: float) -> float:
    """Nearest-rank percentile of an ascending-sorted sequence (0 if empty)."""
    if not sorted_vals:
        return 0.0
    return sorted_vals[min(int(q * len(sorted_vals)), len(sorted_vals) - 1)]


def latency_summary_ms(latencies_s) -> dict[str, float]:
    """Unsorted per-request latencies in seconds → {p50,p90,p95,p99} in ms."""
    lats = sorted(latencies_s)
    return {label: percentile(lats, q) * 1e3 for label, q in PERCENTILES}


def snapshot_delta(pre: dict, post: dict) -> dict:
    """Counter difference of two ``EngineStats.snapshot()`` views.

    The standard way to exclude warmup work (compile batches) from
    reported serving stats: snapshot after warmup, snapshot after the
    timed run, report the delta.  The windowed batch-latency percentiles
    are not diffable and are omitted.
    """
    requests = post["requests"] - pre["requests"]
    padded = post["padded_slots"] - pre["padded_slots"]
    total = requests + padded
    return {
        "requests": requests,
        "batches": post["batches"] - pre["batches"],
        "padded_slots": padded,
        "avg_batch_fill": requests / total if total else 0.0,
    }


def fleet_snapshot_delta(pre: dict, post: dict) -> dict:
    """Delta of two ``FleetEngine.snapshot()`` views (fleet + per-model).

    A model registered after ``pre`` was taken is deltaed against zero.
    """
    zero = {"requests": 0, "batches": 0, "padded_slots": 0}
    return {
        "fleet": snapshot_delta(pre["fleet"], post["fleet"]),
        "models": {
            mid: snapshot_delta(pre["models"].get(mid, zero), m)
            for mid, m in post["models"].items()
        },
    }


class EngineStats:
    """Thread-safe per-engine (or per-model) serving counters.

    The public counter attributes (``requests``, ``batches``,
    ``padded_slots``) stay plain ints for cheap reads; ``snapshot()``
    is the consistent view — it holds the same lock ``record_batch``
    writes under, so a snapshot never observes a half-applied batch.
    """

    def __init__(self, *, latency_window: int = 1024):
        self._lock = threading.Lock()
        self.requests = 0
        self.batches = 0
        self.padded_slots = 0
        # bounded: a long-lived engine must not grow host memory per batch
        self.batch_latency_s: deque = deque(maxlen=latency_window)

    def record_batch(self, n: int, padded: int, latency_s: float) -> None:
        with self._lock:
            self.requests += n
            self.batches += 1
            self.padded_slots += padded
            self.batch_latency_s.append(latency_s)

    @property
    def avg_batch_fill(self) -> float:
        total = self.requests + self.padded_slots
        return self.requests / total if total else 0.0

    def snapshot(self) -> dict:
        """Consistent JSON-ready view: counters + batch-latency percentiles."""
        with self._lock:
            requests = self.requests
            batches = self.batches
            padded = self.padded_slots
            lats = list(self.batch_latency_s)
        total = requests + padded
        return {
            "requests": requests,
            "batches": batches,
            "padded_slots": padded,
            "avg_batch_fill": requests / total if total else 0.0,
            "batch_latency_ms": latency_summary_ms(lats),
        }
