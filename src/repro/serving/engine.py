"""Minimal batched serving engine: continuous prefill → greedy decode.

Production posture without production scope: fixed-batch synchronous
engine (one prefill per request batch, step-lock decode), the pattern the
decode_32k / long_500k dry-run cells lower.  Request padding, EOS handling
and per-request stop make it usable by the examples; the multi-chip
sharding comes from the same ``build_prefill``/``build_decode_step``
builders the dry-run compiles.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro.models import lm
from repro.models import transformer as T
from repro.models.config import ModelConfig


@dataclass
class Request:
    prompt: list[int]
    max_new_tokens: int = 16
    eos_id: int | None = None
    generated: list[int] = field(default_factory=list)
    done: bool = False


class Engine:
    def __init__(self, cfg: ModelConfig, params, *, max_seq: int = 256):
        self.cfg = cfg
        self.params = params
        self.max_seq = max_seq
        self._decode = jax.jit(
            lambda p, t, c: lm.decode_step(p, cfg, t, c)
        )

    def generate(self, requests: list[Request]) -> list[Request]:
        """Run a batch of requests to completion (greedy)."""
        cfg = self.cfg
        b = len(requests)
        prompt_len = max(len(r.prompt) for r in requests)
        toks = np.zeros((b, prompt_len), np.int32)
        for i, r in enumerate(requests):
            toks[i, prompt_len - len(r.prompt):] = r.prompt  # left-pad

        cache = T.init_cache(cfg, batch=b, max_seq=self.max_seq)
        logits, cache = lm.prefill(
            self.params, cfg, {"tokens": jnp.asarray(toks)}, cache
        )
        steps = max(r.max_new_tokens for r in requests)
        cur = jnp.argmax(logits, -1).astype(jnp.int32)
        for _ in range(steps):
            for i, r in enumerate(requests):
                if not r.done:
                    tok = int(cur[i])
                    r.generated.append(tok)
                    if r.eos_id is not None and tok == r.eos_id:
                        r.done = True
                    if len(r.generated) >= r.max_new_tokens:
                        r.done = True
            if all(r.done for r in requests):
                break
            logits, cache = self._decode(self.params, cur, cache)
            cur = jnp.argmax(logits, -1).astype(jnp.int32)
        return requests
