"""Batched vision serving engine over a fused integer ExecutionPlan.

Dynamic request batching with a fixed compiled batch shape:

  * ``submit`` enqueues one image on a bounded queue (backpressure: the
    caller blocks when the engine is saturated rather than growing host
    memory without bound) and returns a ``concurrent.futures.Future``;
  * a daemon worker drains the queue — it waits at most ``max_wait_ms``
    after the first request of a batch, takes up to ``batch_size``
    requests, zero-pads to exactly ``batch_size`` and runs the plan.
    Padding to one static shape means the plan jit-compiles exactly once;
    at high load batches arrive full and the padding cost vanishes.

The same bounded-queue + daemon-thread structure as ``data.loader``'s
prefetch — the serve-side mirror of the train-side input pipeline.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from concurrent.futures import Future
from dataclasses import dataclass, field

import jax
import numpy as np

from repro.infer.plan import ExecutionPlan


@dataclass
class VisionResult:
    """One classified image: predicted label + integer logits row."""

    label: int
    logits: np.ndarray
    latency_s: float


@dataclass
class EngineStats:
    requests: int = 0
    batches: int = 0
    padded_slots: int = 0
    # bounded: a long-lived engine must not grow host memory per batch
    batch_latency_s: deque = field(default_factory=lambda: deque(maxlen=1024))

    @property
    def avg_batch_fill(self) -> float:
        total = self.requests + self.padded_slots
        return self.requests / total if total else 0.0


class VisionEngine:
    """Dynamic-batching classifier over a compiled ExecutionPlan."""

    _POISON = object()

    def __init__(
        self,
        plan: ExecutionPlan,
        *,
        batch_size: int = 32,
        max_wait_ms: float = 5.0,
        queue_depth: int = 256,
    ):
        self.plan = plan
        self.batch_size = batch_size
        self.max_wait_s = max_wait_ms / 1e3
        self.stats = EngineStats()
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth)
        self._closed = False
        self._lifecycle = threading.Lock()  # orders submit() vs close()
        self._pad = np.zeros(plan.input_shape, np.int32)
        self._worker = threading.Thread(target=self._serve_loop, daemon=True)
        self._worker.start()

    # ---- client API -------------------------------------------------------

    def submit(self, image: np.ndarray) -> "Future[VisionResult]":
        """Enqueue one image; blocks only when the engine is saturated."""
        if self._closed:
            raise RuntimeError("engine is closed")
        if tuple(image.shape) != tuple(self.plan.input_shape):
            raise ValueError(
                f"image shape {tuple(image.shape)} != "
                f"plan input shape {tuple(self.plan.input_shape)}"
            )
        fut: Future = Future()
        # the lock orders this put against close()'s poison pill — without
        # it an item enqueued between the _closed check and put() could land
        # behind the sentinel and its future would never resolve
        with self._lifecycle:
            if self._closed:
                raise RuntimeError("engine is closed")
            self._q.put((np.asarray(image, np.int32), fut,
                         time.perf_counter()))
        return fut

    def classify(self, images) -> list[int]:
        """Blocking convenience: a list of images → predicted labels."""
        futs = [self.submit(img) for img in images]
        return [f.result().label for f in futs]

    def close(self):
        """Drain in-flight work and stop the worker."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
            self._q.put(self._POISON)
        self._worker.join()

    # ---- worker -----------------------------------------------------------

    def _take_batch(self):
        """Block for the first request, then fill until batch_size or the
        max_wait deadline. Returns (items, saw_poison)."""
        first = self._q.get()
        if first is self._POISON:
            return [], True
        items = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(items) < self.batch_size:
            remaining = deadline - time.perf_counter()
            try:
                nxt = self._q.get(block=remaining > 0,
                                  timeout=max(remaining, 1e-4))
            except queue.Empty:
                break
            if nxt is self._POISON:
                return items, True
            items.append(nxt)
        return items, False

    def _serve_loop(self):
        while True:
            items, poisoned = self._take_batch()
            if items:
                self._run_batch(items)
            if poisoned:
                return

    def _run_batch(self, items):
        t0 = time.perf_counter()
        n = len(items)
        batch = np.stack(
            [img for img, _, _ in items]
            + [self._pad] * (self.batch_size - n)
        )
        try:
            logits = np.asarray(jax.device_get(self.plan.logits(batch)))
        except Exception as e:  # surface plan failures on every waiter
            for _, fut, _ in items:
                fut.set_exception(e)
            return
        t1 = time.perf_counter()
        labels = np.argmax(logits[:n], axis=-1)
        for i, (_, fut, t_submit) in enumerate(items):
            fut.set_result(VisionResult(
                label=int(labels[i]),
                logits=logits[i],
                latency_s=t1 - t_submit,
            ))
        self.stats.requests += n
        self.stats.batches += 1
        self.stats.padded_slots += self.batch_size - n
        self.stats.batch_latency_s.append(t1 - t0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
