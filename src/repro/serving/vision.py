"""Batched vision serving engine over a fused integer ExecutionPlan.

Dynamic request batching with a fixed compiled batch shape:

  * ``submit`` enqueues one image on a bounded queue (backpressure: the
    caller blocks when the engine is saturated rather than growing host
    memory without bound) and returns a ``concurrent.futures.Future``;
  * a daemon worker drains the queue — it waits at most ``max_wait_ms``
    after the first request of a batch, takes up to ``batch_size``
    requests, zero-pads to exactly ``batch_size`` and runs the plan.
    Padding to one static shape means the plan jit-compiles exactly once;
    at high load batches arrive full and the padding cost vanishes.

This is the *static* scheduler: batch N+1 is not assembled until batch
N's results are on the host.  The continuous-batching scheduler in
``serving.fleet`` overlaps the two and serves several models from one
worker; it reuses this module's batch assembly/resolution helpers, so
the two schedulers are numerically interchangeable.

The same bounded-queue + daemon-thread structure as ``data.loader``'s
prefetch — the serve-side mirror of the train-side input pipeline.
"""

from __future__ import annotations

import queue
import threading
import time
from concurrent.futures import Future
from dataclasses import dataclass

import jax
import numpy as np

from repro.infer.plan import ExecutionPlan
from repro.serving.stats import EngineStats  # noqa: F401 — re-export (historical home)


@dataclass
class VisionResult:
    """One classified image: predicted label + integer logits row."""

    label: int
    logits: np.ndarray
    latency_s: float


@dataclass
class Request:
    """One queued classification request (engine-internal)."""

    image: np.ndarray
    future: "Future[VisionResult]"
    t_submit: float


def assemble_batch(items: list[Request], pad: np.ndarray,
                   batch_size: int) -> np.ndarray:
    """Stack ≤ batch_size requests and zero-pad to exactly batch_size."""
    return np.stack([r.image for r in items]
                    + [pad] * (batch_size - len(items)))


def resolve_batch(items: list[Request], logits: np.ndarray,
                  t_done: float) -> None:
    """Deliver one device result to every waiter in the batch.

    ``set_running_or_notify_cancel`` guards every delivery: a client may
    have ``cancel()``-ed a still-queued future (client-side timeout), and
    an unguarded ``set_result`` would raise InvalidStateError and kill
    the engine's only worker thread.
    """
    labels = np.argmax(logits[:len(items)], axis=-1)
    for i, req in enumerate(items):
        if req.future.set_running_or_notify_cancel():
            req.future.set_result(VisionResult(
                label=int(labels[i]),
                logits=logits[i],
                latency_s=t_done - req.t_submit,
            ))


def fail_batch(items: list[Request], exc: BaseException) -> None:
    """Surface a plan failure on every waiter (skipping cancelled ones)."""
    for req in items:
        if req.future.set_running_or_notify_cancel():
            req.future.set_exception(exc)


class VisionEngine:
    """Dynamic-batching classifier over a compiled ExecutionPlan.

    ``metrics=`` (a shared ``obs.MetricRegistry``) registers the engine's
    counters as ``serve_*_total{model=<plan name>}`` children of the
    shared families instead of a private registry — the single-model
    equivalent of what ``ModelRegistry(metrics=...)`` does per entry.
    """

    _POISON = object()

    def __init__(
        self,
        plan: ExecutionPlan,
        *,
        batch_size: int = 32,
        max_wait_ms: float = 5.0,
        queue_depth: int = 256,
        metrics=None,
    ):
        self.plan = plan
        self.batch_size = batch_size
        self.max_wait_s = max_wait_ms / 1e3
        if metrics is not None:
            self.stats = EngineStats(registry=metrics,
                                     labels={"model": plan.name})
        else:
            self.stats = EngineStats()
        self._q: queue.Queue = queue.Queue(maxsize=queue_depth)
        self._closed = False
        self._lifecycle = threading.Lock()  # orders submit() vs close()
        self._pad = np.zeros(plan.input_shape, np.int32)
        self._worker = threading.Thread(target=self._serve_loop, daemon=True)
        self._worker.start()

    # ---- client API -------------------------------------------------------

    def submit(self, image: np.ndarray) -> "Future[VisionResult]":
        """Enqueue one image; blocks only when the engine is saturated."""
        if self._closed:
            raise RuntimeError("engine is closed")
        if tuple(image.shape) != tuple(self.plan.input_shape):
            raise ValueError(
                f"image shape {tuple(image.shape)} != "
                f"plan input shape {tuple(self.plan.input_shape)}"
            )
        fut: Future = Future()
        # the lock orders this put against close()'s poison pill — without
        # it an item enqueued between the _closed check and put() could land
        # behind the sentinel and its future would never resolve
        with self._lifecycle:
            if self._closed:
                raise RuntimeError("engine is closed")
            self._q.put(Request(np.asarray(image, np.int32), fut,
                                time.perf_counter()))
        return fut

    def classify(self, images) -> list[int]:
        """Blocking convenience: a list of images → predicted labels."""
        futs = [self.submit(img) for img in images]
        return [f.result().label for f in futs]

    def close(self):
        """Drain in-flight work and stop the worker."""
        with self._lifecycle:
            if self._closed:
                return
            self._closed = True
            self._q.put(self._POISON)
        self._worker.join()

    # ---- worker -----------------------------------------------------------

    def _take_batch(self):
        """Block for the first request, then fill until batch_size or the
        max_wait deadline. Returns (items, saw_poison)."""
        first = self._q.get()
        if first is self._POISON:
            return [], True
        items = [first]
        deadline = time.perf_counter() + self.max_wait_s
        while len(items) < self.batch_size:
            remaining = deadline - time.perf_counter()
            try:
                nxt = self._q.get(block=remaining > 0,
                                  timeout=max(remaining, 1e-4))
            except queue.Empty:
                break
            if nxt is self._POISON:
                return items, True
            items.append(nxt)
        return items, False

    def _serve_loop(self):
        while True:
            items, poisoned = self._take_batch()
            if items:
                self._run_batch(items)
            if poisoned:
                return

    def _run_batch(self, items):
        t0 = time.perf_counter()
        n = len(items)
        batch = assemble_batch(items, self._pad, self.batch_size)
        try:
            logits = np.asarray(jax.device_get(self.plan.logits(batch)))
        except Exception as e:  # surface plan failures on every waiter
            fail_batch(items, e)
            return
        t1 = time.perf_counter()
        # stats before futures: a client unblocking on its result and
        # immediately snapshotting must already see this batch counted
        self.stats.record_batch(n, self.batch_size - n, t1 - t0)
        resolve_batch(items, logits, t1)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
