"""Multi-model registry: FrozenModels compiled once, served by id.

One engine process hosting several models needs a control plane between
"frozen artifact on disk" and "compiled plan on device":

  * ``register`` / ``load`` compile a FrozenModel into an ExecutionPlan
    under a caller-chosen **model id** (per-model ``EngineStats`` created
    alongside);
  * ``swap`` is the **hot-swap**: a new checkpoint replaces the plan under
    a *stable id* — the expensive part (plan compilation) happens outside
    the table lock, then the entry flips atomically, so concurrent
    ``get``/``submit`` always observe either the old or the new plan,
    never a torn one.  Per-model stats survive the swap; ``version``
    increments so callers can tell which weights answered;
  * ``evict`` frees a model; its in-flight batches still resolve because
    schedulers hold the entry (and thus the plan) by reference;
  * **padding buffers are shared**: every model with the same per-sample
    input shape pads partial batches from one zero buffer instead of one
    buffer per model — with dozens of CIFAR-shaped A/B arms that is one
    12 KiB buffer instead of dozens.

``from_manifest`` builds a registry straight from an on-disk
``FLEET.json`` (see ``infer.export.save_fleet_manifest``).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field

import numpy as np

from repro.infer.export import FrozenModel, load_fleet_manifest, load_frozen
from repro.infer.plan import ExecutionPlan, compile_plan
from repro.obs.metrics import MetricRegistry
from repro.serving.stats import EngineStats, Slo


@dataclass
class ModelEntry:
    """One served model: compiled plan + identity + live counters.

    ``plan`` is replaced wholesale on hot-swap (never mutated), so a
    scheduler that read the entry keeps a self-consistent plan for the
    batch it is assembling even while a swap lands.  ``slo`` is the
    model's serving objective (or None): like the stats, it belongs to
    the long-lived model *id*, so hot-swaps preserve it.
    """

    model_id: str
    plan: ExecutionPlan
    version: int = 0
    stats: EngineStats = field(default_factory=EngineStats)
    slo: Slo | None = None

    @property
    def input_shape(self) -> tuple[int, ...]:
        return tuple(self.plan.input_shape)


class ModelRegistry:
    """Thread-safe model-id → ModelEntry table with shared pad buffers.

    Pass ``metrics=`` (a shared ``obs.MetricRegistry``) and the registry
    becomes scrapeable: each model's ``EngineStats`` registers as
    ``serve_*_total{model=<id>}`` children of the shared families, and
    lifecycle events surface as ``serve_model_swaps_total`` /
    ``serve_model_version`` / ``serve_model_events_total`` — the signals
    ``serve_vision --metrics-port`` exposes at ``/metrics``.
    """

    def __init__(self, *, backend: str = "auto",
                 operand_dtype: str = "auto",
                 metrics: MetricRegistry | None = None):
        self.backend = backend
        self.operand_dtype = operand_dtype
        self.metrics = metrics
        self._lock = threading.RLock()
        self._entries: dict[str, ModelEntry] = {}
        self._pads: dict[tuple[int, ...], np.ndarray] = {}
        if metrics is not None:
            self._swaps = metrics.counter(
                "serve_model_swaps_total",
                "checkpoint hot-swaps under a stable model id",
                labels=("model",),
            )
            self._version = metrics.gauge(
                "serve_model_version",
                "version of the checkpoint currently answering a model id",
                labels=("model",),
            )
            self._events = metrics.counter(
                "serve_model_events_total",
                "model lifecycle events (register / swap / evict)",
                labels=("event", "model"),
            )

    def _make_stats(self, model_id: str) -> EngineStats:
        if self.metrics is None:
            return EngineStats()
        return EngineStats(registry=self.metrics,
                           labels={"model": model_id})

    def _record_event(self, event: str, entry: ModelEntry) -> None:
        if self.metrics is not None:
            self._events.labels(event=event, model=entry.model_id).inc()
            self._version.labels(model=entry.model_id).set(entry.version)

    # ---- lifecycle --------------------------------------------------------

    def register(self, model_id: str, fm: FrozenModel, *,
                 backend: str | None = None,
                 operand_dtype: str | None = None,
                 slo: Slo | None = None) -> ModelEntry:
        """Compile ``fm`` and serve it as ``model_id`` (id must be free)."""
        if not model_id:
            raise ValueError("model_id must be non-empty")
        plan = compile_plan(fm, backend=backend or self.backend,
                            operand_dtype=operand_dtype or self.operand_dtype)
        with self._lock:
            if model_id in self._entries:
                raise ValueError(
                    f"model id {model_id!r} already registered — "
                    f"use swap() to hot-swap its checkpoint"
                )
            entry = ModelEntry(model_id=model_id, plan=plan,
                               stats=self._make_stats(model_id), slo=slo)
            self._entries[model_id] = entry
            self._pad_for(plan.input_shape)
        self._record_event("register", entry)
        return entry

    def load(self, model_id: str, model_dir: str, *,
             step: int | None = None,
             backend: str | None = None,
             slo: Slo | None = None) -> ModelEntry:
        """``load_frozen`` + ``register`` in one call."""
        return self.register(model_id, load_frozen(model_dir, step=step),
                             backend=backend, slo=slo)

    def set_slo(self, model_id: str, slo: Slo | None) -> ModelEntry:
        """Attach (or clear) a model's serving objective after load.

        The SLO belongs to the stable id: hot-swaps keep it, and engines
        pick the change up on the next delivered batch (the entry is
        read per batch).
        """
        with self._lock:
            entry = self._require(model_id)
            entry.slo = slo
        return entry

    def swap(self, model_id: str, fm: FrozenModel, *,
             backend: str | None = None,
             operand_dtype: str | None = None) -> ModelEntry:
        """Hot-swap ``model_id``'s checkpoint under its stable id.

        Compiles the incoming model *before* taking the lock — submitters
        are never blocked behind a compile — then atomically flips the
        plan and bumps ``version``.  Stats carry over: the id is the
        long-lived serving identity, the checkpoint is an implementation
        detail behind it.
        """
        plan = compile_plan(fm, backend=backend or self.backend,
                            operand_dtype=operand_dtype or self.operand_dtype)
        with self._lock:
            entry = self._require(model_id)
            if tuple(plan.input_shape) != entry.input_shape:
                raise ValueError(
                    f"hot-swap for {model_id!r} changes input shape "
                    f"{entry.input_shape} -> {tuple(plan.input_shape)}"
                )
            entry.plan = plan
            entry.version += 1
            self._pad_for(plan.input_shape)
        if self.metrics is not None:
            self._swaps.labels(model=model_id).inc()
        self._record_event("swap", entry)
        return entry

    def evict(self, model_id: str) -> None:
        with self._lock:
            entry = self._require(model_id)
            del self._entries[model_id]
        self._record_event("evict", entry)

    # ---- lookup -----------------------------------------------------------

    def get(self, model_id: str) -> ModelEntry:
        with self._lock:
            return self._require(model_id)

    def ids(self) -> list[str]:
        with self._lock:
            return sorted(self._entries)

    def __contains__(self, model_id: str) -> bool:
        with self._lock:
            return model_id in self._entries

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def pad_buffer(self, input_shape) -> np.ndarray:
        """The shared zero buffer for one per-sample input shape."""
        with self._lock:
            return self._pad_for(input_shape)

    def snapshot(self) -> dict:
        """Per-model JSON-ready stats view (id → version + EngineStats)."""
        with self._lock:
            entries = list(self._entries.values())
        return {
            e.model_id: {"version": e.version,
                         "model": e.plan.name,
                         "slo_ms": e.slo.deadline_ms if e.slo else None,
                         **e.stats.snapshot()}
            for e in entries
        }

    # ---- internals --------------------------------------------------------

    def _require(self, model_id: str) -> ModelEntry:
        try:
            return self._entries[model_id]
        except KeyError:
            raise KeyError(
                f"unknown model id {model_id!r}; registered: "
                f"{sorted(self._entries)}"
            ) from None

    def _pad_for(self, input_shape) -> np.ndarray:
        shape = tuple(int(d) for d in input_shape)
        pad = self._pads.get(shape)
        if pad is None:
            pad = np.zeros(shape, np.int32)
            pad.setflags(write=False)  # shared across models: keep immutable
            self._pads[shape] = pad
        return pad

    @classmethod
    def from_manifest(cls, root: str, *, backend: str = "auto",
                      operand_dtype: str = "auto",
                      metrics: MetricRegistry | None = None,
                      ) -> "ModelRegistry":
        """Build a registry from an on-disk ``FLEET.json`` directory."""
        manifest = load_fleet_manifest(root)
        reg = cls(backend=backend, operand_dtype=operand_dtype,
                  metrics=metrics)
        for model_id, model_dir in sorted(manifest["models"].items()):
            reg.load(model_id, model_dir)
        return reg
