"""Deterministic procedural datasets (this container has no network access).

Image sets mimic the paper's benchmarks in shape and integer statistics:

  * ``digits28``  — 28×28×1, 10 classes  (MNIST / FashionMNIST stand-in)
  * ``tiles32``   — 32×32×3, 10 classes  (CIFAR-10 stand-in)

Each class is a smooth procedural template (low-frequency sinusoid mixture
keyed by the class id) plus per-sample integer noise and a random shift —
hard enough that a linear model does not saturate, easy enough that the
paper's relative claims (CNN > MLP, NITRO-D ≈ FP LES) are measurable in a
few hundred steps.  Everything returned is int32 in [-127, 127] after the
paper's own MAD pre-processing.

Token sets for the LM substrate: Zipf-distributed synthetic corpora.
"""

from __future__ import annotations

from typing import NamedTuple

import numpy as np

from repro.core import preprocessing


class Dataset(NamedTuple):
    x_train: np.ndarray
    y_train: np.ndarray
    x_test: np.ndarray
    y_test: np.ndarray
    num_classes: int
    input_shape: tuple[int, ...]


def _class_template(cls: int, h: int, w: int, c: int, rng: np.random.Generator) -> np.ndarray:
    """Low-frequency integer pattern unique to ``cls``."""
    yy, xx = np.mgrid[0:h, 0:w].astype(np.float64)
    t = np.zeros((h, w, c))
    for ch in range(c):
        fx, fy = rng.uniform(0.5, 3.0, 2)
        px, py = rng.uniform(0, 2 * np.pi, 2)
        t[:, :, ch] = (
            np.sin(2 * np.pi * fx * xx / w + px) * np.cos(2 * np.pi * fy * yy / h + py)
        )
    # quantise to integers with class-dependent amplitude/sign structure
    amp = 50 + 7 * (cls % 5)
    return np.round(amp * t).astype(np.int64)


def make_image_dataset(
    name: str = "tiles32",
    n_train: int = 4096,
    n_test: int = 1024,
    num_classes: int = 10,
    noise: int = 45,
    seed: int = 0,
) -> Dataset:
    if name == "digits28":
        h, w, c = 28, 28, 1
    elif name == "tiles32":
        h, w, c = 32, 32, 3
    else:
        raise ValueError(f"unknown dataset {name!r}")
    rng = np.random.default_rng(seed)
    templates = np.stack(
        [_class_template(k, h, w, c, np.random.default_rng(1000 + k)) for k in range(num_classes)]
    )

    def gen(n: int, rng: np.random.Generator):
        y = rng.integers(0, num_classes, n)
        x = templates[y].copy()
        # random circular shift per sample (translation variance)
        for i in range(n):
            sh, sw = rng.integers(-3, 4, 2)
            x[i] = np.roll(x[i], (sh, sw), axis=(0, 1))
        x = x + rng.integers(-noise, noise + 1, x.shape)
        return x, y.astype(np.int32)

    x_tr, y_tr = gen(n_train, rng)
    x_te, y_te = gen(n_test, rng)
    # paper Appendix B.2: integer MAD normalisation with *train* statistics
    mu, omega = preprocessing.integer_statistics(x_tr)
    x_tr = np.asarray(preprocessing.normalize(x_tr, mu, omega))
    x_te = np.asarray(preprocessing.normalize(x_te, mu, omega))
    x_tr = np.clip(x_tr, -127, 127).astype(np.int32)
    x_te = np.clip(x_te, -127, 127).astype(np.int32)
    return Dataset(x_tr, y_tr, x_te, y_te, num_classes, (h, w, c))


def flatten_for_mlp(ds: Dataset) -> Dataset:
    """(N,H,W,C) → (N, H·W·C) for the MLP architectures."""
    d = 1
    for s in ds.input_shape:
        d *= s
    return Dataset(
        ds.x_train.reshape(len(ds.x_train), d),
        ds.y_train,
        ds.x_test.reshape(len(ds.x_test), d),
        ds.y_test,
        ds.num_classes,
        (d,),
    )


def make_token_dataset(
    vocab_size: int, seq_len: int, n_seqs: int, seed: int = 0
) -> np.ndarray:
    """Zipf-distributed token ids, (n_seqs, seq_len) int32."""
    rng = np.random.default_rng(seed)
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = 1.0 / ranks
    probs /= probs.sum()
    return rng.choice(vocab_size, size=(n_seqs, seq_len), p=probs).astype(np.int32)


def batches(x: np.ndarray, y: np.ndarray, batch_size: int, seed: int = 0):
    """Shuffled full-epoch minibatch iterator (drops the ragged tail)."""
    rng = np.random.default_rng(seed)
    order = rng.permutation(len(x))
    for i in range(0, len(x) - batch_size + 1, batch_size):
        idx = order[i : i + batch_size]
        yield x[idx], y[idx]
