"""Host data loading: per-host sharded batching with background prefetch.

On a multi-host deployment each process owns ``1/num_processes`` of the
global batch; ``ShardedLoader`` yields the local slice and
``jax.make_array_from_process_local_data`` assembles the global array.  In
this single-process container the same code path runs with one shard.
Prefetch is a bounded queue filled by a daemon thread (keeps the host input
pipeline off the training critical path).
"""

from __future__ import annotations

import queue
import threading
from typing import Callable, Iterator

import jax
import numpy as np


class ShardedLoader:
    def __init__(
        self,
        generator: Callable[[int], dict],
        *,
        global_batch: int,
        process_index: int | None = None,
        process_count: int | None = None,
        prefetch: int = 2,
    ):
        """``generator(step) -> dict of np arrays`` producing the *global*
        batch; the loader slices out this host's shard and prefetches."""
        self.generator = generator
        self.global_batch = global_batch
        self.pi = jax.process_index() if process_index is None else process_index
        self.pc = jax.process_count() if process_count is None else process_count
        assert global_batch % self.pc == 0
        self.local_batch = global_batch // self.pc
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._step = 0
        self._thread = threading.Thread(target=self._fill, daemon=True)
        self._thread.start()

    def _local_slice(self, batch: dict) -> dict:
        lo = self.pi * self.local_batch
        hi = lo + self.local_batch
        return {k: v[lo:hi] for k, v in batch.items()}

    def _fill(self):
        step = 0
        while not self._stop.is_set():
            try:
                item = self._local_slice(self.generator(step))
                self._q.put(item, timeout=1.0)
                step += 1
            except queue.Full:
                continue

    def __iter__(self) -> Iterator[dict]:
        return self

    def __next__(self) -> dict:
        return self._q.get()

    def close(self):
        self._stop.set()


def synthetic_lm_generator(vocab_size: int, seq_len: int, global_batch: int,
                           seed: int = 0):
    """Zipf token batches with next-token labels."""
    ranks = np.arange(1, vocab_size + 1, dtype=np.float64)
    probs = (1.0 / ranks) / np.sum(1.0 / ranks)

    def gen(step: int) -> dict:
        rng = np.random.default_rng(seed + step)
        toks = rng.choice(vocab_size, size=(global_batch, seq_len + 1), p=probs)
        return {
            "tokens": toks[:, :-1].astype(np.int32),
            "labels": toks[:, 1:].astype(np.int32),
        }

    return gen
