"""Data substrate: synthetic integer datasets + sharded host loading."""
