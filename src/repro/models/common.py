"""Shared model components: norms, RoPE/M-RoPE, initialisers, int8 matmul.

The ``int8_matmul`` path is the NITRO-numerics adaptation for LM matmuls
(DESIGN.md §4): activations are brought to the int8 operational range with
a *static power-of-two* scale (2⁶ — the paper's σ=64 operating point) and
weights are stored int8 against a power-of-two scale frozen at init, so the
de-scale is a shift, not a learned/calibrated FP multiplier.  The MXU then
runs at its double int8 rate.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.parallel.sharding import shard


def rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def head_rms_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6) -> jax.Array:
    """Per-head qk-norm (qwen3): normalise the trailing head_dim."""
    dtype = x.dtype
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    return (xf * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))).astype(dtype)


# ---------------------------------------------------------------------------
# Rotary embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, theta: float) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, jnp.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, H, D), positions: broadcastable to (..., S)."""
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, D/2)
    cos = jnp.cos(angles)[..., None, :]                          # (..., S, 1, D/2)
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def apply_mrope(
    x: jax.Array, positions: jax.Array, theta: float, sections: tuple[int, ...]
) -> jax.Array:
    """Multimodal RoPE (qwen2-vl): the D/2 frequency slots are split into
    (t, h, w) sections, each rotated by its own position stream.

    x: (B, S, H, D);  positions: (3, B, S) integer t/h/w indices.
    """
    d_half = x.shape[-1] // 2
    assert sum(sections) == d_half, (sections, d_half)
    freqs = rope_freqs(x.shape[-1], theta)                       # (D/2,)
    # section id per frequency slot → pick the matching position stream
    sec_ids = jnp.repeat(
        jnp.arange(len(sections)), jnp.asarray(sections), total_repeat_length=d_half
    )                                                            # (D/2,)
    pos = positions.astype(jnp.float32)                          # (3, B, S)
    pos_per_slot = pos[sec_ids]                                  # (D/2, B, S)
    angles = jnp.moveaxis(pos_per_slot, 0, -1) * freqs           # (B, S, D/2)
    cos = jnp.cos(angles)[..., None, :]
    sin = jnp.sin(angles)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Initialisers
# ---------------------------------------------------------------------------


def dense_init(key, shape, fan_in, dtype=jnp.float32):
    scale = 1.0 / jnp.sqrt(jnp.asarray(fan_in, jnp.float32))
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


# ---------------------------------------------------------------------------
# NITRO int8 matmul path for LM layers
# ---------------------------------------------------------------------------

ACT_SHIFT = 6  # static activation scale 2⁶ — the paper's σ=64 operating point


def quantize_weight_int8(w: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Quantise against a *power-of-two* per-tensor scale frozen at call
    time: shift = ceil(log2(max|w|/127)).  Returns (int8 weights, shift)."""
    amax = jnp.max(jnp.abs(w.astype(jnp.float32)))
    shift = jnp.ceil(jnp.log2(jnp.maximum(amax / 127.0, 1e-30)))
    wq = jnp.clip(jnp.round(w.astype(jnp.float32) * 2.0 ** (-shift)), -127, 127)
    return wq.astype(jnp.int8), shift.astype(jnp.float32)


def int8_matmul(x: jax.Array, w_q: jax.Array, w_shift: jax.Array) -> jax.Array:
    """NITRO-numerics matmul: x·2⁶ → int8, int8×int8→int32 on the MXU,
    de-scale by the two power-of-two shifts."""
    xq = jnp.clip(jnp.round(x.astype(jnp.float32) * (1 << ACT_SHIFT)), -127, 127)
    z = jax.lax.dot_general(
        xq.astype(jnp.int8), w_q,
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    scale = jnp.exp2(w_shift - ACT_SHIFT)
    return (z.astype(jnp.float32) * scale).astype(x.dtype)


def matmul(x: jax.Array, w: jax.Array, *, int8: bool = False) -> jax.Array:
    """Project ``x`` by ``w`` in the configured numerics mode (weights are
    cast down to the activation/compute dtype — fp32 master, bf16 compute)."""
    if int8:
        w_q, w_shift = quantize_weight_int8(w)
        return int8_matmul(x, w_q, w_shift)
    return jnp.einsum("...k,kn->...n", x, w.astype(x.dtype))
