"""RWKV6 ("Finch") layer: attention-free token mixing with data-dependent
per-channel decay, plus squared-ReLU channel mixing.

Semantics (per head, key/value dims D)::

    out_t = r_tᵀ ( S_{t-1} + diag(u) k_t v_tᵀ )
    S_t   = diag(w_t) S_{t-1} + k_t v_tᵀ          w_t ∈ (0,1) data-dependent

TPU adaptation (DESIGN.md §2): the token-by-token recurrence is VPU-bound;
we use the *chunked* GLA formulation so all FLOPs run on the MXU.  With
L_t = Σ_{u≤t} log w_u (per channel, chunk-local):

    inter :  out_t += (r_t ⊙ e^{L_{t-1}})ᵀ S₀
    intra :  M_{ts} = (r_t ⊙ e^{L_{t-1}}) · (k_s ⊙ e^{-L_s}),  s < t  (matmul!)
    bonus :  out_t += (r_t ⊙ u · k_t) v_t
    state :  S_C = diag(e^{L_C}) S₀ + (k ⊙ e^{L_C - L})ᵀ v

The per-channel decay folds *inside* the contraction, so intra-chunk work is
two (C×D)·(D×C/D×D) matmuls — exactly what the MXU wants.  Chunk length 64
keeps e^{±L} in fp32 range (decays are products of ≤64 values clamped below
by exp(-36)).  A `lax.scan` carries S across chunks; decode is the naive
single-step update (identical math, C = 1).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard

CHUNK = 64
DECAY_LORA = 64
MIN_LOG_W = -8.0  # clamp: w ≥ e^-8 keeps chunk-local e^{-L} ≤ e^512 ... bounded via chunk reset


class RwkvState(NamedTuple):
    s: jax.Array        # (B, H, Dk, Dv) wkv state
    x_prev_tm: jax.Array  # (B, d) last token (time-mix shift)
    x_prev_cm: jax.Array  # (B, d) last token (channel-mix shift)


def init_rwkv_layer(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    ks = jax.random.split(key, 12)
    return {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        # time-mix lerp coefficients for (r, k, v, g, w)
        "mu": 0.5 * jnp.ones((5, d), jnp.float32),
        "wr": dense_init(ks[0], (d, d), d),
        "wk": dense_init(ks[1], (d, d), d),
        "wv": dense_init(ks[2], (d, d), d),
        "wg": dense_init(ks[3], (d, d), d),
        "wo": dense_init(ks[4], (d, d), d),
        # data-dependent decay: w = exp(-exp(w0 + tanh(x A) B))
        "w0": jnp.full((d,), -2.0, jnp.float32),
        "wA": dense_init(ks[5], (d, DECAY_LORA), d),
        "wB": dense_init(ks[6], (DECAY_LORA, d), DECAY_LORA) * 0.1,
        "u": jnp.zeros((d,), jnp.float32),      # current-token bonus
        "ln_head": jnp.zeros((d,), jnp.float32),
        # channel mix
        "mu_cm": 0.5 * jnp.ones((2, d), jnp.float32),
        "cm_k": dense_init(ks[7], (d, f), d),
        "cm_v": dense_init(ks[8], (f, d), f),
        "cm_r": dense_init(ks[9], (d, d), d),
    }


def rwkv_logical_axes(cfg: ModelConfig) -> dict:
    return {
        "ln1": (None,), "ln2": (None,),
        "mu": (None, None),
        "wr": ("p_fsdp", "p_rnn"), "wk": ("p_fsdp", "p_rnn"),
        "wv": ("p_fsdp", "p_rnn"), "wg": ("p_fsdp", "p_rnn"),
        "wo": ("p_rnn", "p_fsdp"),
        "w0": ("p_rnn",), "wA": ("p_fsdp", None), "wB": (None, "p_rnn"),
        "u": ("p_rnn",), "ln_head": (None,),
        "mu_cm": (None, None),
        "cm_k": ("p_fsdp", "p_mlp"), "cm_v": ("p_mlp", "p_fsdp"),
        "cm_r": ("p_fsdp", "p_rnn"),
    }


def _shift(x: jax.Array, x_prev: jax.Array) -> jax.Array:
    """Token shift: (B,S,d) rolled right by one, front-filled from state."""
    return jnp.concatenate([x_prev[:, None, :], x[:, :-1, :]], axis=1)


def _mix(x, x_shift, mu):
    return x + (x_shift - x) * mu


def _decay_log_w(p, xw: jax.Array) -> jax.Array:
    """log w_t ∈ [MIN_LOG_W, 0): w = exp(-exp(w0 + tanh(x A) B))."""
    lw = -jnp.exp(
        p["w0"].astype(jnp.float32)
        + jnp.tanh(xw.astype(jnp.float32) @ p["wA"].astype(jnp.float32))
        @ p["wB"].astype(jnp.float32)
    )
    return jnp.clip(lw, MIN_LOG_W, -1e-6)


def _wkv_chunk(carry_s, rkvwl, u):
    """One chunk of the GLA recurrence. All (B,H,C,D) fp32."""
    r, k, v, lw = rkvwl
    s0 = carry_s                                   # (B,H,Dk,Dv)
    lcum = jnp.cumsum(lw, axis=2)                  # L_t, inclusive
    l_prev = lcum - lw                             # L_{t-1}
    r_t = r * jnp.exp(l_prev)
    k_t = k * jnp.exp(-lcum)
    # intra-chunk: strictly-lower-triangular attention matrix
    m = jnp.einsum("bhtd,bhsd->bhts", r_t, k_t)
    c = r.shape[2]
    tri = jnp.tril(jnp.ones((c, c), bool), k=-1)
    m = jnp.where(tri, m, 0.0)
    out = jnp.einsum("bhts,bhsv->bhtv", m, v)
    # inter-chunk: contribution of the incoming state
    out = out + jnp.einsum("bhtd,bhdv->bhtv", r_t, s0)
    # current-token bonus (diagonal)
    out = out + jnp.einsum("bhtd,bhtv->bhtv", r * u * k, v)[..., : out.shape[-1]]
    # state update
    l_tot = lcum[:, :, -1:, :]                     # L_C
    s_new = s0 * jnp.exp(l_tot.squeeze(2))[..., None] + jnp.einsum(
        "bhsd,bhsv->bhdv", k * jnp.exp(l_tot - lcum), v
    )
    return s_new, out


def rwkv_time_mix(
    p: dict, cfg: ModelConfig, x: jax.Array, state: RwkvState
) -> tuple[jax.Array, RwkvState]:
    """Token-mixing over a full sequence (train/prefill), chunked."""
    b, s, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    xs = _shift(x, state.x_prev_tm)
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (_mix(x, xs, mu[i]) for i in range(5))

    f32 = jnp.float32
    r = (xr @ p["wr"].astype(x.dtype)).astype(f32).reshape(b, s, h, hd)
    k = (xk @ p["wk"].astype(x.dtype)).astype(f32).reshape(b, s, h, hd)
    v = (xv @ p["wv"].astype(x.dtype)).astype(f32).reshape(b, s, h, hd)
    g = jax.nn.silu((xg @ p["wg"].astype(x.dtype)).astype(f32))
    lw = _decay_log_w(p, xw).reshape(b, s, h, hd)
    u = p["u"].astype(f32).reshape(1, h, 1, hd)

    # (B,S,H,D) → (B,H,S,D), chunked over S
    r, k, v, lw = (jnp.moveaxis(t, 2, 1) for t in (r, k, v, lw))
    r = shard(r, "batch", "rnn", None, None)
    n_chunks = max(s // CHUNK, 1)
    ck = s // n_chunks

    def body(carry, xs_chunk):
        return _wkv_chunk(carry, xs_chunk, u)

    rc, kc, vc, lwc = (
        t.reshape(b, h, n_chunks, ck, hd).transpose(2, 0, 1, 3, 4)
        for t in (r, k, v, lw)
    )
    s_final, outs = jax.lax.scan(body, state.s, (rc, kc, vc, lwc))
    out = outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, hd)
    out = jnp.moveaxis(out, 1, 2).reshape(b, s, d)

    # per-head groupnorm, output gate, projection
    out = rms_norm(out.reshape(b, s, h, hd), jnp.zeros((hd,), f32)).reshape(b, s, d)
    out = out * (1.0 + p["ln_head"].astype(f32))
    out = (out * g).astype(x.dtype) @ p["wo"].astype(x.dtype)
    new_state = RwkvState(s=s_final, x_prev_tm=x[:, -1, :], x_prev_cm=state.x_prev_cm)
    return out, new_state


def rwkv_time_mix_decode(
    p: dict, cfg: ModelConfig, x: jax.Array, state: RwkvState
) -> tuple[jax.Array, RwkvState]:
    """Single-token recurrence (the naive form — C = 1)."""
    b, d = x.shape
    hd = cfg.rwkv_head_dim
    h = d // hd
    xs = state.x_prev_tm
    mu = p["mu"].astype(x.dtype)
    xr, xk, xv, xg, xw = (x + (xs - x) * mu[i] for i in range(5))

    f32 = jnp.float32
    r = (xr @ p["wr"].astype(x.dtype)).astype(f32).reshape(b, h, hd)
    k = (xk @ p["wk"].astype(x.dtype)).astype(f32).reshape(b, h, hd)
    v = (xv @ p["wv"].astype(x.dtype)).astype(f32).reshape(b, h, hd)
    g = jax.nn.silu((xg @ p["wg"].astype(x.dtype)).astype(f32))
    w = jnp.exp(_decay_log_w(p, xw)).reshape(b, h, hd)
    u = p["u"].astype(f32).reshape(1, h, hd)

    kv = jnp.einsum("bhd,bhv->bhdv", k, v)
    out = jnp.einsum("bhd,bhdv->bhv", r, state.s + u[..., None] * kv)
    s_new = state.s * w[..., None] + kv
    out = out.reshape(b, 1, d)
    out = rms_norm(out.reshape(b, 1, h, hd), jnp.zeros((hd,), f32)).reshape(b, 1, d)
    out = out * (1.0 + p["ln_head"].astype(f32))
    out = (out * g[:, None, :].reshape(b, 1, d)).astype(x.dtype) @ p["wo"].astype(x.dtype)
    return out.squeeze(1), RwkvState(s=s_new, x_prev_tm=x, x_prev_cm=state.x_prev_cm)


def rwkv_channel_mix(
    p: dict, cfg: ModelConfig, x: jax.Array, state: RwkvState, *, decode: bool = False
) -> tuple[jax.Array, RwkvState]:
    if decode:
        xs = state.x_prev_cm
        new_prev = x
    else:
        xs = _shift(x, state.x_prev_cm)
        new_prev = x[:, -1, :]
    mu = p["mu_cm"].astype(x.dtype)
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    k = jnp.square(jax.nn.relu(xk @ p["cm_k"].astype(x.dtype)))
    k = shard(k, "batch", "seq", "mlp") if not decode else k
    r = jax.nn.sigmoid(xr @ p["cm_r"].astype(x.dtype))
    out = r * (k @ p["cm_v"].astype(x.dtype))
    return out, RwkvState(s=state.s, x_prev_tm=state.x_prev_tm, x_prev_cm=new_prev)


def init_rwkv_state(cfg: ModelConfig, batch: int) -> RwkvState:
    d = cfg.d_model
    hd = cfg.rwkv_head_dim
    h = d // hd
    return RwkvState(
        s=jnp.zeros((batch, h, hd, hd), jnp.float32),
        x_prev_tm=jnp.zeros((batch, d), jnp.bfloat16),
        x_prev_cm=jnp.zeros((batch, d), jnp.bfloat16),
    )


def rwkv_layer(
    p: dict, cfg: ModelConfig, x: jax.Array, state: RwkvState, *, decode: bool = False
) -> tuple[jax.Array, RwkvState]:
    """Full RWKV6 block: time-mix + channel-mix with pre-norms."""
    if decode:
        h, state = rwkv_time_mix_decode(p, cfg, rms_norm(x, p["ln1"]), state)
    else:
        h, state = rwkv_time_mix(p, cfg, rms_norm(x, p["ln1"]), state)
    x = x + h
    h, state = rwkv_channel_mix(p, cfg, rms_norm(x, p["ln2"]), state, decode=decode)
    return x + h, state
