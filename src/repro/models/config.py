"""Static model configuration covering every assigned architecture family."""

from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any

import jax.numpy as jnp


@dataclass(frozen=True)
class MoESpec:
    num_experts: int
    top_k: int
    d_ff_expert: int
    # True → experts sharded over the model axis (EP, OLMoE: 64/16 = 4/chip);
    # False → every expert TP-sharded on its ffn dim (Mixtral: 16384/16).
    expert_parallel: bool = True
    aux_loss_weight: float = 0.01


@dataclass(frozen=True)
class ModelConfig:
    """One assigned architecture.  Layer layout is ``scan_unit × scan_repeats
    + tail`` so heterogeneous stacks (RecurrentGemma's rec,rec,attn pattern)
    still lower through `lax.scan` with a small HLO."""

    name: str
    family: str                     # dense | ssm | hybrid | moe | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0               # 0 → d_model // num_heads
    qk_norm: bool = False
    mlp_type: str = "swiglu"        # swiglu | gelu
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False

    # attention variants
    sliding_window: int | None = None   # SWA width (h2o-danube, mixtral)
    mrope_sections: tuple[int, ...] | None = None  # qwen2-vl M-RoPE (t,h,w)
    # decode-cache KV duplication factor: stored KV heads = num_kv_heads ×
    # kv_repeat, chosen so the cache shards align with query-head shards on
    # the 16-wide model axis (Megatron KV duplication; DESIGN.md §5)
    kv_repeat: int = 1

    # recurrent families
    rwkv_head_dim: int = 64             # rwkv6
    lru_width: int = 0                  # rg-lru hidden width (recurrentgemma)
    local_attn_window: int = 2048       # recurrentgemma local attention
    scan_unit: tuple[str, ...] = ("attn",)   # layer kinds per scan step
    tail: tuple[str, ...] = ()               # unrolled remainder layers

    # encoder-decoder (whisper): encoder length is the stub frontend's output
    encoder_layers: int = 0
    encoder_seq: int = 1500

    # vlm/audio stub frontend: inputs are precomputed embeddings, not ids
    embeds_input: bool = False

    # MoE
    moe: MoESpec | None = None

    # numerics / paper technique
    dtype: Any = jnp.bfloat16
    int8_matmul: bool = False       # NITRO int8 numerics on MLP/proj matmuls
    les_groups: int = 0             # >0: LES local-loss groups (paper algo)
    # cast fp32 master params to compute dtype ONCE at step entry: the FSDP
    # weight all-gathers and data-axis gradient reductions then move bf16
    # (half the wire bytes) instead of f32 (§Perf hillclimb lever)
    cast_params_once: bool = False
    # constrain the MoE dispatch buffer / expert activations to the expert
    # sharding inside the auto region — keeps EP expert compute local to its
    # model-shard instead of all-reducing the whole buffer (§Perf lever)
    moe_shard_buffers: bool = False

    # training
    remat: bool = True
    learning_rate: float = 3e-4
    weight_decay: float = 0.1

    # per-arch logical→mesh rule tweaks (e.g. TP-MoE vs EP-MoE)
    rule_overrides: tuple[tuple[str, str | tuple | None], ...] = ()
    # small models (rwkv6-3b): no TP — train batch shards over data×model,
    # params FSDP over both axes; serve keeps the default batch rules
    dp_only: bool = False

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)

    @property
    def scan_repeats(self) -> int:
        unit = max(len(self.scan_unit), 1)
        return (self.num_layers - len(self.tail)) // unit

    @property
    def q_per_kv(self) -> int:
        return self.num_heads // self.num_kv_heads

    def param_count(self) -> int:
        """Approximate parameter count (embeddings + stacked layers)."""
        d, v = self.d_model, self.vocab_size
        total = v * d * (1 if self.tie_embeddings else 2)
        kinds = list(self.scan_unit) * self.scan_repeats + list(self.tail)
        hd = self.head_dim
        for kind in kinds:
            if kind in ("attn", "local_attn"):
                total += d * hd * (self.num_heads + 2 * self.num_kv_heads)
                total += self.num_heads * hd * d
                total += self._mlp_params()
            elif kind == "rec":
                w = self.lru_width or d
                total += 2 * d * w + w * d + 3 * w + 2 * w * w // 8  # gates
                total += self._mlp_params()
            elif kind == "rwkv":
                total += 5 * d * d + d * 64 * 2 + 2 * d  # mixing + decay lora
                total += d * self.d_ff + self.d_ff * d   # channel mix
        if self.encoder_layers:
            total += self.encoder_layers * (
                4 * d * hd * self.num_heads + 2 * d * self.d_ff
            )
            # decoder cross-attention
            total += (self.num_layers) * 4 * d * hd * self.num_heads
        return total

    def _mlp_params(self) -> int:
        d = self.d_model
        if self.moe is not None:
            e, f = self.moe.num_experts, self.moe.d_ff_expert
            n_mat = 3 if self.mlp_type == "swiglu" else 2
            return e * n_mat * d * f + d * e
        n_mat = 3 if self.mlp_type == "swiglu" else 2
        return n_mat * d * self.d_ff

    def active_param_count(self) -> int:
        """Active params per token (= total for dense; top-k slice for MoE)."""
        if self.moe is None:
            return self.param_count()
        full = self.param_count()
        e, k = self.moe.num_experts, self.moe.top_k
        n_mat = 3 if self.mlp_type == "swiglu" else 2
        expert_mats = self.num_layers * e * n_mat * self.d_model * self.moe.d_ff_expert
        active_mats = expert_mats * k // e
        return full - expert_mats + active_mats
