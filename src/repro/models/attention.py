"""Attention: GQA flash (chunked, online-softmax, custom VJP), SWA, decode.

Implemented in pure XLA ops (not a Pallas kernel) deliberately: the roofline
methodology reads FLOPs/bytes from the compiled HLO, which treats custom
calls as opaque — attention must stay visible to the cost model.

Memory: the naive differentiation of a chunked-attention scan saves every
online-softmax carry (O(S²) total — measured 140 GiB/chip on the llama
train_4k cell), so ``flash_attention`` carries a **custom VJP** implementing
the FlashAttention-2 backward: scores are *recomputed* blockwise from the
saved (q, k, v, out, logsumexp) — O(S) residuals, O(block²) live.

Matmul numerics: bf16 inputs with fp32 accumulation
(``preferred_element_type``) — full MXU rate, fp32-stable softmax.

Causal handling:
  * ``masked``   — every (q-block, kv-block) pair computed and masked
    (2× the causal-triangle FLOPs).  Baseline.
  * ``triangle`` — scans only the lower-triangle block pairs (exact same
    output, ~half the attention FLOPs).  §Perf hillclimb lever.
  * sliding-window — static-length kv band dynamically sliced at the
    diagonal → true O(S·W) FLOPs for SWA archs.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

NEG_INF = -1e30


class FlashCfg(NamedTuple):
    causal: bool
    window: int | None
    q_block: int
    kv_block: int
    causal_mode: str
    compute_dtype: str = "bf16"   # matmul-input dtype; accumulation is fp32


def _cdt(cfg: FlashCfg):
    return jnp.bfloat16 if cfg.compute_dtype == "bf16" else jnp.float32


def _dot(a, b, dims, dtype):
    return jax.lax.dot_general(
        a.astype(dtype), b.astype(dtype), dimension_numbers=dims,
        preferred_element_type=jnp.float32,
    )


def _scores(cfg, q_blk, k_blk):
    """(B,G,P,bq,D) × (B,G,bk,D) → (B,G,P,bq,bk) fp32 accumulation."""
    return _dot(
        q_blk, k_blk, ((((4,), (3,)), ((0, 1), (0, 1)))), _cdt(cfg)
    )


def _pv(cfg, p_blk, v_blk):
    """(B,G,P,bq,bk) × (B,G,bk,D) → (B,G,P,bq,D)."""
    return _dot(
        p_blk, v_blk, ((((4,), (2,)), ((0, 1), (0, 1)))), _cdt(cfg)
    )


def _mask_for(cfg: FlashCfg, qp, kp):
    mask = jnp.ones((qp.shape[0], kp.shape[0]), bool)
    if cfg.causal:
        mask &= qp[:, None] >= kp[None, :]
    if cfg.window is not None:
        mask &= (qp[:, None] - kp[None, :]) < cfg.window
    return mask


def _band(cfg: FlashCfg, nk: int) -> int:
    if cfg.window is None:
        return nk
    return min(nk, -(-(cfg.window - 1) // cfg.kv_block) + 1)


def _band_start(cfg: FlashCfg, qi, nk: int, n_band: int):
    if cfg.window is None:
        return jnp.asarray(0)
    # diagonal-aligned band (q and kv blocks may differ in size)
    diag = (qi * cfg.q_block) // cfg.kv_block
    return jnp.clip(diag - (n_band - 1), 0, nk - n_band)


# ---------------------------------------------------------------------------
# Forward
# ---------------------------------------------------------------------------


def _flash_forward(cfg: FlashCfg, q, k, v):
    """Returns (out, lse) with lse = logsumexp of each score row."""
    b, g, p, s, d = q.shape
    s_kv = k.shape[2]
    bq, bk = min(cfg.q_block, s), min(cfg.kv_block, s_kv)
    nq, nk = s // bq, s_kv // bk
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    qf = (q.astype(jnp.float32) * scale).reshape(b, g, p, nq, bq, d)
    kf = k.reshape(b, g, nk, bk, d)
    vf = v.reshape(b, g, nk, bk, d)
    q_pos = jnp.arange(s).reshape(nq, bq)
    k_pos = jnp.arange(s_kv).reshape(nk, bk)
    n_band = _band(cfg, nk)

    if cfg.causal and cfg.causal_mode == "triangle" and cfg.window is None:
        return _triangle_forward(cfg, qf, kf, vf, q_pos, k_pos)

    def q_step(_, qi):
        q_blk = jax.lax.dynamic_index_in_dim(qf, qi, axis=3, keepdims=False)
        qp = q_pos[qi]
        j0 = _band_start(cfg, qi, nk, n_band)

        def kv_step(carry, jj):
            m, l, acc = carry
            j = j0 + jj
            k_blk = jax.lax.dynamic_index_in_dim(kf, j, axis=2, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vf, j, axis=2, keepdims=False)
            s_ij = _scores(cfg, q_blk, k_blk)
            kp = jax.lax.dynamic_index_in_dim(k_pos, j, axis=0, keepdims=False)
            s_ij = jnp.where(_mask_for(cfg, qp, kp), s_ij, NEG_INF)
            m_new = jnp.maximum(m, s_ij.max(axis=-1))
            pexp = jnp.exp(s_ij - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + pexp.sum(axis=-1)
            acc_new = acc * corr[..., None] + _pv(cfg, pexp, v_blk)
            return (m_new, l_new, acc_new), None

        m0 = jnp.full((b, g, p, bq), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, g, p, bq), jnp.float32)
        a0 = jnp.zeros((b, g, p, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), jnp.arange(n_band))
        l_safe = jnp.maximum(l, 1e-30)
        return None, (acc / l_safe[..., None], m + jnp.log(l_safe))

    _, (blocks, lses) = jax.lax.scan(q_step, None, jnp.arange(nq))
    out = jnp.moveaxis(blocks, 0, 3).reshape(b, g, p, s, d)
    lse = jnp.moveaxis(lses, 0, 3).reshape(b, g, p, s)
    return out, lse


def _triangle_forward(cfg, qf, kf, vf, q_pos, k_pos):
    """Causal attention over only lower-triangle block pairs."""
    b, g, p, nq, bq, d = qf.shape
    nk = kf.shape[2]
    assert nq == nk, "triangle mode requires equal q/kv block counts"
    pairs = jnp.asarray(
        [(i, j) for i in range(nq) for j in range(i + 1)], jnp.int32
    )

    def step(carry, pair):
        m, l, acc = carry
        i, j = pair[0], pair[1]
        q_blk = jax.lax.dynamic_index_in_dim(qf, i, axis=3, keepdims=False)
        k_blk = jax.lax.dynamic_index_in_dim(kf, j, axis=2, keepdims=False)
        v_blk = jax.lax.dynamic_index_in_dim(vf, j, axis=2, keepdims=False)
        s_ij = _scores(cfg, q_blk, k_blk)
        qp = jax.lax.dynamic_index_in_dim(q_pos, i, axis=0, keepdims=False)
        kp = jax.lax.dynamic_index_in_dim(k_pos, j, axis=0, keepdims=False)
        s_ij = jnp.where(qp[:, None] >= kp[None, :], s_ij, NEG_INF)

        mi = jax.lax.dynamic_index_in_dim(m, i, axis=0, keepdims=False)
        li = jax.lax.dynamic_index_in_dim(l, i, axis=0, keepdims=False)
        ai = jax.lax.dynamic_index_in_dim(acc, i, axis=0, keepdims=False)
        m_new = jnp.maximum(mi, s_ij.max(axis=-1))
        pexp = jnp.exp(s_ij - m_new[..., None])
        corr = jnp.exp(mi - m_new)
        l_new = li * corr + pexp.sum(axis=-1)
        a_new = ai * corr[..., None] + _pv(cfg, pexp, v_blk)
        m = jax.lax.dynamic_update_index_in_dim(m, m_new, i, axis=0)
        l = jax.lax.dynamic_update_index_in_dim(l, l_new, i, axis=0)
        acc = jax.lax.dynamic_update_index_in_dim(acc, a_new, i, axis=0)
        return (m, l, acc), None

    m0 = jnp.full((nq, b, g, p, bq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((nq, b, g, p, bq), jnp.float32)
    a0 = jnp.zeros((nq, b, g, p, bq, d), jnp.float32)
    (m, l, acc), _ = jax.lax.scan(step, (m0, l0, a0), pairs)
    l_safe = jnp.maximum(l, 1e-30)
    out = acc / l_safe[..., None]
    lse = m + jnp.log(l_safe)
    s = nq * bq
    out = jnp.moveaxis(out, 0, 3).reshape(qf.shape[0], qf.shape[1], qf.shape[2], s, qf.shape[5])
    lse = jnp.moveaxis(lse, 0, 3).reshape(qf.shape[0], qf.shape[1], qf.shape[2], s)
    return out, lse


# ---------------------------------------------------------------------------
# Backward (FlashAttention-2): recompute scores blockwise from (q,k,v,lse)
# ---------------------------------------------------------------------------


def _flash_backward(cfg: FlashCfg, res, dout):
    q, k, v, out, lse = res
    b, g, p, s, d = q.shape
    s_kv = k.shape[2]
    bq, bk = min(cfg.q_block, s), min(cfg.kv_block, s_kv)
    nq, nk = s // bq, s_kv // bk
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))

    qf = (q.astype(jnp.float32) * scale).reshape(b, g, p, nq, bq, d)
    kf = k.reshape(b, g, nk, bk, d)
    vf = v.reshape(b, g, nk, bk, d)
    dof = dout.astype(jnp.float32).reshape(b, g, p, nq, bq, d)
    lsef = lse.reshape(b, g, p, nq, bq)
    # D_i = rowsum(dO ⊙ O)
    delta = jnp.sum(
        dout.astype(jnp.float32) * out.astype(jnp.float32), axis=-1
    ).reshape(b, g, p, nq, bq)

    q_pos = jnp.arange(s).reshape(nq, bq)
    k_pos = jnp.arange(s_kv).reshape(nk, bk)
    n_band = _band(cfg, nk)

    def q_step(carry, qi):
        dk_acc, dv_acc = carry
        q_blk = jax.lax.dynamic_index_in_dim(qf, qi, axis=3, keepdims=False)
        do_blk = jax.lax.dynamic_index_in_dim(dof, qi, axis=3, keepdims=False)
        lse_blk = jax.lax.dynamic_index_in_dim(lsef, qi, axis=3, keepdims=False)
        dlt_blk = jax.lax.dynamic_index_in_dim(delta, qi, axis=3, keepdims=False)
        qp = q_pos[qi]
        j0 = _band_start(cfg, qi, nk, n_band)

        def kv_step(inner, jj):
            dq_blk, dk_a, dv_a = inner
            j = j0 + jj
            k_blk = jax.lax.dynamic_index_in_dim(kf, j, axis=2, keepdims=False)
            v_blk = jax.lax.dynamic_index_in_dim(vf, j, axis=2, keepdims=False)
            kp = jax.lax.dynamic_index_in_dim(k_pos, j, axis=0, keepdims=False)
            s_ij = _scores(cfg, q_blk, k_blk)
            s_ij = jnp.where(_mask_for(cfg, qp, kp), s_ij, NEG_INF)
            p_ij = jnp.exp(s_ij - lse_blk[..., None])          # (B,G,P,bq,bk)
            # dV_j += P^T dO     : contract bq
            dv_j = _dot(
                p_ij, do_blk,
                ((((3,), (3,)), ((0, 1, 2), (0, 1, 2)))), _cdt(cfg),
            ).sum(axis=2)                                      # sum P groups
            # dP = dO V^T        : contract d
            dp_ij = _dot(
                do_blk, v_blk, ((((4,), (3,)), ((0, 1), (0, 1)))), _cdt(cfg)
            )
            ds_ij = p_ij * (dp_ij - dlt_blk[..., None])
            # dQ_i += dS K_j     : contract bk
            dq_blk = dq_blk + _dot(
                ds_ij, k_blk, ((((4,), (2,)), ((0, 1), (0, 1)))), _cdt(cfg)
            )
            # dK_j += dS^T Q_i   : contract bq, sum over P
            dk_j = _dot(
                ds_ij, q_blk,
                ((((3,), (3,)), ((0, 1, 2), (0, 1, 2)))), _cdt(cfg),
            ).sum(axis=2)
            prev_k = jax.lax.dynamic_index_in_dim(dk_a, j, axis=2, keepdims=False)
            prev_v = jax.lax.dynamic_index_in_dim(dv_a, j, axis=2, keepdims=False)
            dk_a = jax.lax.dynamic_update_index_in_dim(
                dk_a, prev_k + dk_j, j, axis=2
            )
            dv_a = jax.lax.dynamic_update_index_in_dim(
                dv_a, prev_v + dv_j, j, axis=2
            )
            return (dq_blk, dk_a, dv_a), None

        dq0 = jnp.zeros((b, g, p, bq, d), jnp.float32)
        (dq_blk, dk_acc, dv_acc), _ = jax.lax.scan(
            kv_step, (dq0, dk_acc, dv_acc), jnp.arange(n_band)
        )
        return (dk_acc, dv_acc), dq_blk * scale

    dk0 = jnp.zeros((b, g, nk, bk, d), jnp.float32)
    dv0 = jnp.zeros((b, g, nk, bk, d), jnp.float32)
    (dk_acc, dv_acc), dq_blocks = jax.lax.scan(
        q_step, (dk0, dv0), jnp.arange(nq)
    )
    dq = jnp.moveaxis(dq_blocks, 0, 3).reshape(b, g, p, s, d).astype(q.dtype)
    dk = dk_acc.reshape(b, g, s_kv, d).astype(k.dtype)
    dv = dv_acc.reshape(b, g, s_kv, d).astype(v.dtype)
    return dq, dk, dv


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(cfg: FlashCfg, q, k, v):
    out, _ = _flash_forward(cfg, q, k, v)
    return out


def _flash_fwd_rule(cfg: FlashCfg, q, k, v):
    out, lse = _flash_forward(cfg, q, k, v)
    return out, (q, k, v, out, lse)


_flash.defvjp(_flash_fwd_rule, _flash_backward)


def flash_attention(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    causal: bool = True,
    window: int | None = None,
    q_block: int = 512,
    kv_block: int = 512,
    causal_mode: str = "masked",
    compute_dtype: str = "bf16",
) -> jax.Array:
    """GQA chunked attention with flash custom-VJP.

    q: (B, G, P, S, D) — G kv groups, P q-heads per group
    k, v: (B, G, S_kv, D);  returns (B, G, P, S, D) in q's dtype.
    """
    b, g, p, s, d = q.shape
    s_kv = k.shape[2]

    def divisor_block(n, target):
        c = min(target, n)
        while n % c != 0:
            c -= 1
        return c

    cfg = FlashCfg(
        causal=causal, window=window,
        q_block=divisor_block(s, q_block),
        kv_block=divisor_block(s_kv, kv_block),
        causal_mode=causal_mode, compute_dtype=compute_dtype,
    )
    out = _flash(cfg, q, k, v)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Decode
# ---------------------------------------------------------------------------


def decode_attention(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    t: jax.Array,
    *,
    window: int | None = None,
) -> jax.Array:
    """Single-token attention against a (possibly ring-buffered) KV cache.

    q: (B, G, P, D);  k_cache/v_cache: (B, S_cache, G, D);  t: current step.
    """
    b, g, p, d = q.shape
    s_cache = k_cache.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(d, jnp.float32))
    qf = q.astype(jnp.float32) * scale
    s = jax.lax.dot_general(
        qf.astype(jnp.bfloat16),
        k_cache.astype(jnp.bfloat16),
        (((3,), (3,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32,
    )  # (B, G, P, S)
    slot = jnp.arange(s_cache)
    if window is None:
        valid = slot[None, :] <= t
    else:
        # ring buffer: slot holds position t - ((t - slot) mod S_cache)
        pos = t - ((t - slot) % s_cache)
        valid = (pos >= 0) & (pos > t - window)
        valid = valid[None, :]
    s = jnp.where(valid[:, None, None, :], s, NEG_INF)
    w = jax.nn.softmax(s, axis=-1)
    out = jax.lax.dot_general(
        w.astype(jnp.bfloat16),
        v_cache.astype(jnp.bfloat16),
        (((3,), (1,)), ((0, 1), (0, 2))),
        preferred_element_type=jnp.float32,
    )
    return out.astype(q.dtype)
