"""Dense MLP (SwiGLU/GeLU) and sort-free capacity-based MoE.

MoE uses the scatter/gather dispatch (position-in-expert via one-hot
cumsum): tokens are placed into an (E, C, d) buffer, experts run as one
batched matmul, and results are combined with the router's top-k weights.
Sharding: ``expert_parallel=True`` shards the E dim over the ``model`` axis
(EP — OLMoE's 64 experts, 4/chip at TP16); ``False`` shards each expert's
ffn dim (TP — Mixtral's 8 wide experts).  Overflowing tokens beyond
capacity are dropped (standard capacity-factor semantics), contributing
zero — the combine gather returns zeros for dropped slots.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, matmul
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard

CAPACITY_FACTOR = 1.25


def init_mlp(key, cfg: ModelConfig) -> dict:
    d, f = cfg.d_model, cfg.d_ff
    if cfg.moe is not None:
        e, fe = cfg.moe.num_experts, cfg.moe.d_ff_expert
        ks = jax.random.split(key, 4)
        p = {
            "router": dense_init(ks[0], (d, e), d),
            "wi": dense_init(ks[1], (e, d, fe), d),
            "wo": dense_init(ks[2], (e, fe, d), fe),
        }
        if cfg.mlp_type == "swiglu":
            p["wg"] = dense_init(ks[3], (e, d, fe), d)
        return p
    ks = jax.random.split(key, 3)
    p = {
        "wi": dense_init(ks[0], (d, f), d),
        "wo": dense_init(ks[1], (f, d), f),
    }
    if cfg.mlp_type == "swiglu":
        p["wg"] = dense_init(ks[2], (d, f), d)
    return p


def mlp_logical_axes(cfg: ModelConfig) -> dict:
    if cfg.moe is not None:
        axes = {
            "router": ("p_fsdp", None),
            "wi": ("p_expert", "p_fsdp", "p_mlp_expert"),
            "wo": ("p_expert", "p_mlp_expert", "p_fsdp"),
        }
        if cfg.mlp_type == "swiglu":
            axes["wg"] = ("p_expert", "p_fsdp", "p_mlp_expert")
        return axes
    axes = {"wi": ("p_fsdp", "p_mlp"), "wo": ("p_mlp", "p_fsdp")}
    if cfg.mlp_type == "swiglu":
        axes["wg"] = ("p_fsdp", "p_mlp")
    return axes


def _act(x, kind: str):
    return jax.nn.gelu(x) if kind == "gelu" else jax.nn.silu(x)


def dense_mlp(params: dict, cfg: ModelConfig, x: jax.Array) -> jax.Array:
    h = matmul(x, params["wi"], int8=cfg.int8_matmul)
    if cfg.mlp_type == "swiglu":
        h = _act(h, "silu") * matmul(x, params["wg"], int8=cfg.int8_matmul)
    else:
        h = _act(h, "gelu")
    h = shard(h, "batch", "seq", "mlp")
    return matmul(h, params["wo"], int8=cfg.int8_matmul)


def _moe_local(params, cfg: ModelConfig, xt, gate_vals, expert_idx, capacity):
    """Per-data-shard MoE dispatch → expert compute → combine.

    Runs on each shard's LOCAL tokens (inside shard_map, or globally when no
    mesh is active): the dispatch scatter/gather never crosses shards, so
    the SPMD partitioner never sees an opaque-index scatter.  Capacity is
    per-shard (the standard local-capacity MoE semantics).
    """
    moe = cfg.moe
    n, d = xt.shape
    flat_e = expert_idx.reshape(-1)                            # (n·k,)
    onehot = jax.nn.one_hot(flat_e, moe.num_experts, dtype=jnp.int32)
    pos = (jnp.cumsum(onehot, axis=0) - 1) * onehot
    pos_in_e = pos.sum(axis=-1)
    keep = pos_in_e < capacity

    # row scatter with a single linear index; drops → trash row
    lin = jnp.where(
        keep, flat_e * capacity + pos_in_e, moe.num_experts * capacity
    )
    xk = jnp.repeat(xt, moe.top_k, axis=0)                     # (n·k, d)
    buffer = jnp.zeros((moe.num_experts * capacity + 1, d), xt.dtype)
    buffer = buffer.at[lin].set(xk)
    buffer = buffer[:-1].reshape(moe.num_experts, capacity, d)
    if cfg.moe_shard_buffers:
        # pin the dispatch buffer and expert activations to the expert
        # sharding so EP expert matmuls stay shard-local (one buffer
        # all-to-all at dispatch instead of per-einsum all-reduces)
        buffer = shard(buffer, "expert", None, None)

    # expert compute (model-axis sharding of wi/wo handled by the auto
    # partitioner: TP on the ffn dim for mixtral, EP over experts for olmoe)
    h = jnp.einsum("ecd,edf->ecf", buffer, params["wi"].astype(xt.dtype))
    if cfg.mlp_type == "swiglu":
        g = jnp.einsum("ecd,edf->ecf", buffer, params["wg"].astype(xt.dtype))
        h = jax.nn.silu(h) * g
    else:
        h = jax.nn.gelu(h)
    if cfg.moe_shard_buffers:
        h = shard(h, "expert", None, "mlp_expert")
    y = jnp.einsum("ecf,efd->ecd", h, params["wo"].astype(xt.dtype))
    if cfg.moe_shard_buffers:
        y = shard(y, "expert", None, None)

    # combine: local row gather
    y_flat = y.reshape(moe.num_experts * capacity, d)
    gathered = jnp.take(y_flat, jnp.where(keep, lin, 0), axis=0)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    weighted = gathered.astype(jnp.float32) * gate_vals.reshape(-1)[:, None]
    return weighted.reshape(n, moe.top_k, d).sum(axis=1).astype(xt.dtype)


def moe_mlp(
    params: dict, cfg: ModelConfig, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Top-k MoE. x: (B, S, d) → (out, aux_load_balance_loss)."""
    from jax.sharding import PartitionSpec as P

    from repro.parallel import sharding as shr

    moe = cfg.moe
    b, s, d = x.shape
    n = b * s
    xt = x.reshape(n, d)
    xt = shard(xt, "batch", None)

    logits = (xt.astype(jnp.float32) @ params["router"].astype(jnp.float32))
    probs = jax.nn.softmax(logits, axis=-1)                    # (N, E)
    gate_vals, expert_idx = jax.lax.top_k(probs, moe.top_k)    # (N, k)
    gate_vals = gate_vals / jnp.maximum(
        gate_vals.sum(-1, keepdims=True), 1e-9
    )

    # load-balance aux loss (Switch): E · Σ_e load_e · prob_e
    onehot_n = jax.nn.one_hot(expert_idx, moe.num_experts, dtype=jnp.float32)
    load = onehot_n.sum(axis=(0, 1)) / (n * moe.top_k)
    imp = probs.mean(axis=0)
    aux = moe.num_experts * jnp.sum(load * imp)

    ctx = shr._current()
    if ctx is None:
        capacity = int(CAPACITY_FACTOR * n * moe.top_k / moe.num_experts) + 1
        out = _moe_local(params, cfg, xt, gate_vals, expert_idx, capacity)
    else:
        mesh, rules = ctx
        batch_axes = rules.get("batch")
        axes = (batch_axes,) if isinstance(batch_axes, str) else tuple(batch_axes or ())
        axes = tuple(a for a in axes if a in mesh.axis_names)
        n_shards = 1
        for a in axes:
            n_shards *= mesh.shape[a]
        n_local = n // max(n_shards, 1)
        capacity = int(CAPACITY_FACTOR * n_local * moe.top_k / moe.num_experts) + 1
        if not axes:
            out = _moe_local(params, cfg, xt, gate_vals, expert_idx, capacity)
        else:
            # manual over the batch axes only; expert/ffn sharding of the
            # weights stays with the auto partitioner inside the body
            param_specs = jax.tree_util.tree_map(lambda _: P(), params)
            out = jax.shard_map(
                lambda p, a, g, e: _moe_local(p, cfg, a, g, e, capacity),
                mesh=mesh,
                in_specs=(param_specs, P(axes), P(axes), P(axes)),
                out_specs=P(axes),
                axis_names=frozenset(axes),
                check_vma=False,
            )(params, xt, gate_vals, expert_idx)

    out = shard(out, "batch", None)
    return out.reshape(b, s, d), aux
