"""LM-family model zoo: dense/GQA/SWA transformers, RWKV6, RG-LRU hybrid,
MoE, encoder-decoder, VLM backbone — with NITRO-D technique hooks
(LES local-loss groups, NITRO int8 matmul numerics)."""

from repro.models.config import ModelConfig, MoESpec

__all__ = ["ModelConfig", "MoESpec"]
