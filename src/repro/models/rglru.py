"""RG-LRU recurrent block (RecurrentGemma / Griffin).

    i_t = σ(x_t W_x + b_x)                      (input gate)
    r_t = σ(x_t W_a + b_a)                      (recurrence gate)
    a_t = exp(-c · r_t · softplus(Λ))           (data-dependent decay, c = 8)
    h_t = a_t ⊙ h_{t-1} + √(1 − a_t²) ⊙ (i_t ⊙ x_t)

Gates use block-diagonal weights with 16 blocks — block count chosen so the
block dim shards exactly over the 16-way ``model`` axis (one block per TP
rank, zero-comm gating).  The recurrence is per-channel, so TP over the
lru width is collective-free; the sequence dim is handled by
``lax.associative_scan`` (log-depth on TPU) for train/prefill and a single
fused step for decode.

Full recurrent block (Griffin layout): y = W_out( GeLU(x W_g) ⊙
RG-LRU(conv1d₄(x W_in)) ).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.models.common import dense_init, rms_norm
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard

N_GATE_BLOCKS = 16
RGLRU_C = 8.0
CONV_WIDTH = 4


class RglruState(NamedTuple):
    h: jax.Array       # (B, W) recurrent state
    conv: jax.Array    # (B, CONV_WIDTH-1, W) conv1d tail


def init_rglru_layer(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w = cfg.lru_width or d
    bw = w // N_GATE_BLOCKS
    ks = jax.random.split(key, 6)
    return {
        "ln": jnp.zeros((d,), jnp.float32),
        "w_in": dense_init(ks[0], (d, w), d),
        "w_gate": dense_init(ks[1], (d, w), d),
        "w_out": dense_init(ks[2], (w, d), w),
        "conv_w": dense_init(ks[3], (CONV_WIDTH, w), CONV_WIDTH),
        # block-diagonal input/recurrence gates: (blocks, bw, bw)
        "gate_x": dense_init(ks[4], (N_GATE_BLOCKS, bw, bw), bw),
        "gate_a": dense_init(ks[5], (N_GATE_BLOCKS, bw, bw), bw),
        "lam": jnp.full((w,), 2.0, jnp.float32),  # softplus(Λ) init ≈ 2.1
    }


def rglru_logical_axes(cfg: ModelConfig) -> dict:
    return {
        "ln": (None,),
        "w_in": ("p_fsdp", "p_rnn"),
        "w_gate": ("p_fsdp", "p_rnn"),
        "w_out": ("p_rnn", "p_fsdp"),
        "conv_w": (None, "p_rnn"),
        "gate_x": ("p_rnn_block", None, None),
        "gate_a": ("p_rnn_block", None, None),
        "lam": ("p_rnn",),
    }


def _block_diag(x: jax.Array, w: jax.Array) -> jax.Array:
    """x: (..., W) → (..., W) through (blocks, bw, bw) block-diagonal w."""
    shape = x.shape
    b = w.shape[0]
    xb = x.reshape(*shape[:-1], b, shape[-1] // b)
    out = jnp.einsum("...bi,bij->...bj", xb, w.astype(x.dtype))
    return out.reshape(shape)


def _gates(p, x):
    f32 = jnp.float32
    i_t = jax.nn.sigmoid(_block_diag(x, p["gate_x"]).astype(f32))
    r_t = jax.nn.sigmoid(_block_diag(x, p["gate_a"]).astype(f32))
    log_a = -RGLRU_C * r_t * jax.nn.softplus(p["lam"].astype(f32))
    a_t = jnp.exp(log_a)
    # √(1−a²) via log-space for stability at a → 1
    beta = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12))
    return a_t, beta * i_t * x.astype(f32)


def rglru_scan(p, x: jax.Array, h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Sequence recurrence via associative scan.  x: (B,S,W) → (B,S,W)."""
    a, b = _gates(p, x)                     # (B,S,W) fp32
    a = shard(a, "batch", None, "rnn")
    b = shard(b, "batch", None, "rnn")

    def combine(l, r):
        a_l, b_l = l
        a_r, b_r = r
        return a_l * a_r, b_l * a_r + b_r

    # fold the incoming state into the first step (concat, not scatter —
    # scatters drop the sharding annotation through SPMD)
    first = b[:, :1, :] + a[:, :1, :] * h0.astype(jnp.float32)[:, None, :]
    b = jnp.concatenate([first, b[:, 1:, :]], axis=1)
    _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
    h = shard(h, "batch", None, "rnn")
    return h.astype(x.dtype), h[:, -1, :]


def rglru_step(p, x: jax.Array, h0: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Single decode step.  x: (B,W)."""
    a, b = _gates(p, x[:, None, :])
    h = a[:, 0] * h0.astype(jnp.float32) + b[:, 0]
    return h.astype(x.dtype), h


def _causal_conv(x: jax.Array, w: jax.Array, tail: jax.Array) -> tuple[jax.Array, jax.Array]:
    """Depthwise causal conv1d (width 4).  x: (B,S,W), tail: (B,3,W)."""
    xp = jnp.concatenate([tail.astype(x.dtype), x], axis=1)
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i].astype(x.dtype)
        for i in range(CONV_WIDTH)
    )
    return out, xp[:, -(CONV_WIDTH - 1) :, :]


def rglru_block(
    p: dict, cfg: ModelConfig, x: jax.Array, state: RglruState, *, decode: bool = False
) -> tuple[jax.Array, RglruState]:
    """Full Griffin recurrent block (pre-norm, residual added by caller)."""
    xn = rms_norm(x if not decode else x[:, None, :], p["ln"])
    gate = jax.nn.gelu(jnp.einsum("...d,dw->...w", xn, p["w_gate"].astype(xn.dtype)))
    u = jnp.einsum("...d,dw->...w", xn, p["w_in"].astype(xn.dtype))
    u = shard(u, "batch", None, "rnn") if not decode else u
    if decode:
        conv_in = jnp.concatenate([state.conv.astype(u.dtype), u], axis=1)
        u_c = sum(conv_in[:, i, :] * p["conv_w"][i].astype(u.dtype) for i in range(CONV_WIDTH))
        new_tail = conv_in[:, 1:, :]
        h, h_last = rglru_step(p, u_c, state.h)
        y = h * gate[:, 0]
        out = jnp.einsum("bw,wd->bd", y, p["w_out"].astype(y.dtype))
        return out, RglruState(h=h_last, conv=new_tail)
    u_c, new_tail = _causal_conv(u, p["conv_w"], state.conv)
    h, h_last = rglru_scan(p, u_c, state.h)
    y = h * gate
    y = shard(y, "batch", None, "rnn")
    out = jnp.einsum("bsw,wd->bsd", y, p["w_out"].astype(y.dtype))
    return out, RglruState(h=h_last, conv=new_tail)


def init_rglru_state(cfg: ModelConfig, batch: int) -> RglruState:
    w = cfg.lru_width or cfg.d_model
    return RglruState(
        h=jnp.zeros((batch, w), jnp.float32),
        conv=jnp.zeros((batch, CONV_WIDTH - 1, w), jnp.bfloat16),
    )
