"""Model facade: full forward passes, LM loss, prefill/decode steps.

Three entry points (all pure functions, jit/pjit-able):

  * ``train_loss``  — full-sequence forward + next-token CE (+ MoE aux,
    + LES local-group losses when ``cfg.les_groups > 0``);
  * ``prefill``     — full-sequence forward that also populates the KV /
    recurrent caches and returns last-position logits;
  * ``decode_step`` — single-token step against the caches.

LES mode (the paper's learning algorithm, ported to LMs — DESIGN.md §4):
the scanned stack is split into ``les_groups`` segments with a
``stop_gradient`` boundary between them; each segment gets a local
next-token loss through the shared unembedding.  Gradients are confined to
their segment exactly like NITRO-D's integer local-loss blocks, which (a)
removes the cross-segment backward dependency chain and (b) lets XLA
overlap segment backwards with downstream forwards.
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Stack execution
# ---------------------------------------------------------------------------


def _unit_forward(cfg: ModelConfig, unit_params: dict, x, positions, causal_mode):
    """One scan unit (e.g. ('rec','rec','attn')) over a full sequence.
    Recurrent states start at zero per segment in train mode (standard for
    non-streaming training). Returns (x, aux)."""
    aux = jnp.zeros((), jnp.float32)
    for i, kind in enumerate(cfg.scan_unit):
        p = unit_params[f"u{i}"]
        if kind == "attn":
            x, a = T.attn_layer(
                p, cfg, x, positions, window=cfg.sliding_window,
                causal_mode=causal_mode,
            )
            aux += a
        elif kind == "local_attn":
            x, a = T.attn_layer(
                p, cfg, x, positions, window=cfg.local_attn_window,
                causal_mode=causal_mode,
            )
            aux += a
        elif kind == "rec":
            state = T.rglru_mod.init_rglru_state(cfg, x.shape[0])
            x, _ = T.rec_layer(p, cfg, x, state)
        elif kind == "rwkv":
            state = T.rwkv_mod.init_rwkv_state(cfg, x.shape[0])
            x, _ = T.rwkv_mod.rwkv_layer(p, cfg, x, state)
        else:
            raise ValueError(kind)
    return x, aux


def run_stack(
    params: dict, cfg: ModelConfig, x: jax.Array, positions,
    *, causal_mode: str = "masked", collect_les: bool = False,
):
    """Scan the stacked units + tail.  Returns (x, aux, les_taps)."""

    def body(carry, unit_params):
        h, aux = carry
        h = shard(h, "batch", "seq_sp", None)
        h, a = _unit_forward(cfg, unit_params, h, positions, causal_mode)
        return (h, aux + a), None

    # nothing_saveable: only the (bf16, sequence-sharded) carry survives per
    # layer — without it, partial-eval saves the layer-entry f32 upcast of
    # the residual stream (2× the bytes) instead of the carry itself
    body_fn = (
        jax.checkpoint(body, policy=jax.checkpoint_policies.nothing_saveable)
        if cfg.remat else body
    )

    les_taps = []
    aux = jnp.zeros((), jnp.float32)
    if collect_les and cfg.les_groups > 0:
        reps = cfg.scan_repeats
        per = max(reps // cfg.les_groups, 1)
        offset = 0
        while offset < reps:
            n = min(per, reps - offset)
            seg = jax.tree_util.tree_map(
                lambda t: jax.lax.slice_in_dim(t, offset, offset + n, axis=0),
                params["scan"],
            )
            (x, aux), _ = jax.lax.scan(body_fn, (x, aux), seg)
            les_taps.append(x)
            x = jax.lax.stop_gradient(x)  # confine gradients to the group
            offset += n
    else:
        (x, aux), _ = jax.lax.scan(body_fn, (x, aux), params["scan"])

    for p, kind in zip(params["tail"], cfg.tail):
        x, aux = _tail_forward(cfg, p, kind, x, positions, causal_mode, aux)
    return x, aux, les_taps


def _tail_forward(cfg, p, kind, x, positions, causal_mode, aux):
    if kind in ("attn", "local_attn"):
        window = cfg.sliding_window if kind == "attn" else cfg.local_attn_window
        x, a = T.attn_layer(p, cfg, x, positions, window=window, causal_mode=causal_mode)
        return x, aux + a
    if kind == "rec":
        state = T.rglru_mod.init_rglru_state(cfg, x.shape[0])
        x, _ = T.rec_layer(p, cfg, x, state)
        return x, aux
    if kind == "rwkv":
        state = T.rwkv_mod.init_rwkv_state(cfg, x.shape[0])
        x, _ = T.rwkv_mod.rwkv_layer(p, cfg, x, state)
        return x, aux
    raise ValueError(kind)


def _embed(params, cfg: ModelConfig, tokens_or_embeds):
    if cfg.embeds_input:
        return tokens_or_embeds.astype(cfg.dtype)
    scale = jnp.asarray(jnp.sqrt(cfg.d_model), cfg.dtype)
    return params["embed"].astype(cfg.dtype)[tokens_or_embeds] * scale


def _logits(params, cfg: ModelConfig, x):
    w = params["embed"].T if cfg.tie_embeddings else params["unembed"]
    # bf16 MXU inputs, fp32 accumulation (stable softmax downstream)
    logits = jax.lax.dot_general(
        x.astype(cfg.dtype), w.astype(cfg.dtype),
        dimension_numbers=(((x.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32,
    )
    return shard(logits, "batch", None, "vocab") if logits.ndim == 3 else shard(logits, "batch", "vocab")


def _positions(cfg: ModelConfig, b: int, s: int):
    pos = jnp.arange(s, dtype=jnp.int32)[None, :]
    pos = jnp.broadcast_to(pos, (b, s))
    if cfg.mrope_sections is not None:
        return jnp.broadcast_to(pos[None], (3, b, s))  # text-stream M-RoPE
    return pos


def run_encoder(params, cfg: ModelConfig, enc_embeds: jax.Array):
    """Whisper encoder: non-causal stack over stub frontend embeddings."""

    def body(h, unit_params):
        h = T.attn_layer(
            unit_params["u0"], cfg, h, None, window=None, causal=False
        )[0]
        return h, None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    h, _ = jax.lax.scan(body_fn, enc_embeds.astype(cfg.dtype), params["encoder"])
    return T.rms_norm(h, params["enc_final_ln"])


# ---------------------------------------------------------------------------
# Training loss
# ---------------------------------------------------------------------------


def _xent(logits: jax.Array, labels: jax.Array) -> jax.Array:
    logz = jax.nn.log_softmax(logits.astype(jnp.float32), axis=-1)
    ll = jnp.take_along_axis(logz, labels[..., None], axis=-1)[..., 0]
    return -jnp.mean(ll)


def _pick_chunk(s: int, target: int = 512) -> int:
    c = min(target, s)
    while s % c != 0:
        c -= 1
    return c


def _chunked_xent(params, cfg: ModelConfig, x: jax.Array, labels: jax.Array) -> jax.Array:
    """Next-token CE without materialising (B, S, V) logits.

    The unembedding + softmax run per sequence chunk inside a rematted
    scan: peak logits memory drops from S/chunk× (2.5 GiB/chip for the
    150k-vocab archs at 4k×16) to one chunk.  Backward recomputes each
    chunk's logits (checkpoint) — the standard large-vocab CE treatment.
    """
    b, s, _ = x.shape
    chunk = _pick_chunk(s)
    n_chunks = s // chunk

    def body(acc, idx):
        xc = jax.lax.dynamic_slice_in_dim(x, idx * chunk, chunk, axis=1)
        lc = jax.lax.dynamic_slice_in_dim(labels, idx * chunk, chunk, axis=1)
        logits = _logits(params, cfg, xc)
        logz = jax.nn.log_softmax(logits, axis=-1)
        ll = jnp.take_along_axis(logz, lc[..., None], axis=-1)[..., 0]
        return acc - jnp.sum(ll), None

    loss_sum, _ = jax.lax.scan(
        jax.checkpoint(body), jnp.zeros((), jnp.float32), jnp.arange(n_chunks)
    )
    return loss_sum / (b * s)


def train_loss(
    params: dict, cfg: ModelConfig, batch: dict, *, causal_mode: str = "masked"
) -> tuple[jax.Array, dict]:
    """Next-token CE over the full sequence.

    batch: {"tokens": (B,S) int32  (or "embeds": (B,S,d) for stub-frontend
    archs), "labels": (B,S) int32, optional "positions", "enc_embeds"}.
    """
    inp = batch["embeds"] if cfg.embeds_input else batch["tokens"]
    b, s = batch["labels"].shape
    x = _embed(params, cfg, inp)
    x = shard(x, "batch", "seq_sp", None)
    positions = batch.get("positions")
    if positions is None:
        positions = _positions(cfg, b, s)

    enc_out = None
    if cfg.encoder_layers:
        enc_out = run_encoder(params, cfg, batch["enc_embeds"])

    if cfg.encoder_layers:
        x, aux, les_taps = _run_decoder_with_cross(
            params, cfg, x, positions, enc_out, causal_mode
        )
    else:
        x, aux, les_taps = run_stack(
            params, cfg, x, positions, causal_mode=causal_mode,
            collect_les=cfg.les_groups > 0,
        )

    x = T.rms_norm(x, params["final_ln"])
    loss = _chunked_xent(params, cfg, x, batch["labels"])
    metrics = {"ce": loss, "aux": aux}
    if les_taps:
        # LES: every group (incl. the last) trains through its local head;
        # the main CE then reaches only the output head (x was stop-graded
        # at the last tap) — exactly NITRO-D's output-layer treatment.
        les_loss = jnp.zeros((), jnp.float32)
        for tap in les_taps:
            les_loss += _chunked_xent(
                params, cfg, T.rms_norm(tap, params["final_ln"]), batch["labels"]
            )
        loss = loss + les_loss / len(les_taps)
        metrics["les"] = les_loss
    if cfg.moe is not None:
        loss = loss + cfg.moe.aux_loss_weight * aux / max(cfg.num_layers, 1)
    metrics["loss"] = loss
    return loss, metrics


def _run_decoder_with_cross(params, cfg, x, positions, enc_out, causal_mode):
    """Whisper decoder: self-attn (causal) + cross-attn per layer."""

    def body(carry, unit_params):
        h, aux = carry
        p = unit_params["u0"]
        h, a = T.attn_layer(p, cfg, h, positions, window=None, causal_mode=causal_mode)
        h = T.cross_attn(p, cfg, h, enc_out)
        return (h, aux + a), None

    body_fn = jax.checkpoint(body) if cfg.remat else body
    (x, aux), _ = jax.lax.scan(
        body_fn, (x, jnp.zeros((), jnp.float32)), params["scan"]
    )
    return x, aux, []


# ---------------------------------------------------------------------------
# Serving: prefill + decode
# ---------------------------------------------------------------------------


def prefill(
    params: dict, cfg: ModelConfig, batch: dict, cache: dict
) -> tuple[jax.Array, dict]:
    """Full-sequence forward that fills the caches.

    Implemented as the train-mode forward (cheap matmul-form for recurrent
    archs, flash for attention) plus cache population from the computed
    K/V/state tensors.
    """
    inp = batch["embeds"] if cfg.embeds_input else batch["tokens"]
    b, s = (inp.shape[0], inp.shape[1])
    x = _embed(params, cfg, inp)
    positions = batch.get("positions")
    if positions is None:
        positions = _positions(cfg, b, s)

    enc_out = None
    if cfg.encoder_layers:
        enc_out = run_encoder(params, cfg, batch["enc_embeds"])

    def body(carry, scan_in):
        h = carry
        unit_params, unit_cache = scan_in
        new_unit_cache = {}
        for i, kind in enumerate(cfg.scan_unit):
            p = unit_params[f"u{i}"]
            c = unit_cache[f"u{i}"]
            if kind in ("attn", "local_attn", "attn_cross"):
                window = (
                    cfg.local_attn_window if kind == "local_attn"
                    else cfg.sliding_window
                )
                # cache is filled from the layer *input* (same tensor the
                # in-layer attention projects), before the layer mutates h
                new_unit_cache[f"u{i}"] = _fill_kv_cache(p, cfg, h, positions, c)
                h, _ = T.attn_layer(p, cfg, h, positions, window=window)
                if kind == "attn_cross":
                    h = T.cross_attn(p, cfg, h, enc_out)
            elif kind == "rec":
                state = T.rglru_mod.init_rglru_state(cfg, h.shape[0])
                h, st = T.rec_layer(p, cfg, h, state)
                new_unit_cache[f"u{i}"] = st
            elif kind == "rwkv":
                state = T.rwkv_mod.init_rwkv_state(cfg, h.shape[0])
                h, st = T.rwkv_mod.rwkv_layer(p, cfg, h, state)
                new_unit_cache[f"u{i}"] = st
        return h, new_unit_cache

    x, scan_cache = jax.lax.scan(body, x, (params["scan"], cache["scan"]))

    new_tail = []
    for p, kind, c in zip(params["tail"], cfg.tail, cache["tail"]):
        if kind in ("attn", "local_attn"):
            window = cfg.sliding_window if kind == "attn" else cfg.local_attn_window
            new_tail.append(_fill_kv_cache(p, cfg, x, positions, c))
            x, _ = T.attn_layer(p, cfg, x, positions, window=window)
        elif kind == "rec":
            state = T.rglru_mod.init_rglru_state(cfg, x.shape[0])
            x, st = T.rec_layer(p, cfg, x, state)
            new_tail.append(st)
        elif kind == "rwkv":
            state = T.rwkv_mod.init_rwkv_state(cfg, x.shape[0])
            x, st = T.rwkv_mod.rwkv_layer(p, cfg, x, state)
            new_tail.append(st)

    x = T.rms_norm(x, params["final_ln"])
    logits = _logits(params, cfg, x[:, -1, :])
    new_cache = {"scan": scan_cache, "tail": new_tail, "t": jnp.asarray(s, jnp.int32)}
    return logits, new_cache


def _fill_kv_cache(p, cfg: ModelConfig, x_in, positions, cache: T.LayerCache):
    """Compute K/V from the layer input and lay them into the (ring) cache.
    For windows shorter than the sequence, only the last ``window`` entries
    are kept, rotated so slot ``p % window`` holds position ``p``."""
    xn = T.rms_norm(x_in, p["ln1"])
    _, k, v = T._project_qkv(p, cfg, xn, positions)
    k = T._expand_kv(k, cfg.kv_repeat)
    v = T._expand_kv(v, cfg.kv_repeat)
    s_cache = cache.k.shape[1]
    s = k.shape[1]
    if s >= s_cache:  # keep the last window, ring-ordered
        k_win, v_win = k[:, -s_cache:], v[:, -s_cache:]
        start = (s - s_cache) % s_cache
        k_new = jnp.roll(k_win, start, axis=1)
        v_new = jnp.roll(v_win, start, axis=1)
    else:
        k_new = jax.lax.dynamic_update_slice_in_dim(cache.k, k.astype(cache.k.dtype), 0, axis=1)
        v_new = jax.lax.dynamic_update_slice_in_dim(cache.v, v.astype(cache.v.dtype), 0, axis=1)
    return T.LayerCache(k=k_new.astype(cache.k.dtype), v=v_new.astype(cache.v.dtype))


def decode_step(
    params: dict, cfg: ModelConfig, tokens: jax.Array, cache: dict,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, dict]:
    """One greedy decode step.  tokens: (B,) int32 → (logits, new cache)."""
    b = tokens.shape[0]
    t = cache["t"]
    # decode always consumes token ids (stub-frontend archs emit text too)
    scale = jnp.asarray(jnp.sqrt(cfg.d_model), cfg.dtype)
    x = params["embed"].astype(cfg.dtype)[tokens] * scale

    def body(carry, scan_in):
        h = carry
        unit_params, unit_cache = scan_in
        new_unit_cache = {}
        for i, kind in enumerate(cfg.scan_unit):
            p = unit_params[f"u{i}"]
            c = unit_cache[f"u{i}"]
            if kind in ("attn", "local_attn", "attn_cross"):
                window = (
                    cfg.local_attn_window if kind == "local_attn"
                    else cfg.sliding_window
                )
                h, nc = T.attn_layer_decode(
                    p, cfg, h, t, c, window=window,
                    enc_out=enc_out if kind == "attn_cross" else None,
                )
                new_unit_cache[f"u{i}"] = nc
            elif kind == "rec":
                h, st = T.rec_layer(p, cfg, h, c, decode=True)
                new_unit_cache[f"u{i}"] = st
            elif kind == "rwkv":
                h, st = T.rwkv_mod.rwkv_layer(p, cfg, h, c, decode=True)
                new_unit_cache[f"u{i}"] = st
        return h, new_unit_cache

    x, scan_cache = jax.lax.scan(body, x, (params["scan"], cache["scan"]))

    new_tail = []
    for p, kind, c in zip(params["tail"], cfg.tail, cache["tail"]):
        if kind in ("attn", "local_attn"):
            window = cfg.sliding_window if kind == "attn" else cfg.local_attn_window
            x, nc = T.attn_layer_decode(p, cfg, x, t, c, window=window, enc_out=enc_out)
            new_tail.append(nc)
        elif kind == "rec":
            x, st = T.rec_layer(p, cfg, x, c, decode=True)
            new_tail.append(st)
        elif kind == "rwkv":
            x, st = T.rwkv_mod.rwkv_layer(p, cfg, x, c, decode=True)
            new_tail.append(st)

    x = T.rms_norm(x[:, None, :], params["final_ln"])[:, 0]
    logits = _logits(params, cfg, x)
    return logits, {"scan": scan_cache, "tail": new_tail, "t": t + 1}
