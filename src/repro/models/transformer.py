"""The generic decoder/encoder stack covering every assigned architecture.

Layer layout is ``cfg.scan_unit × cfg.scan_repeats + cfg.tail``: parameters
of each kind are stacked on a leading dim and executed through ``lax.scan``
(small HLO even for 80-layer models); heterogeneous units (RecurrentGemma's
(rec, rec, attn)) scan as one fused step.

GQA sharding strategy (DESIGN.md §5): query heads are sharded over the
``model`` axis; KV heads are *expanded* (repeated) to align with the query
head sharding — Megatron-style KV duplication, collective-free attention.
``cfg.kv_repeat`` (set per config for the 16-wide model axis) controls the
stored-cache duplication so decode cache shards land on the chips that
consume them.
"""

from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.models import mlp as mlp_mod
from repro.models import rglru as rglru_mod
from repro.models import rwkv as rwkv_mod
from repro.models.attention import decode_attention, flash_attention
from repro.models.common import (
    apply_mrope,
    apply_rope,
    dense_init,
    head_rms_norm,
    matmul,
    rms_norm,
)
from repro.models.config import ModelConfig
from repro.parallel.sharding import shard


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def init_attn_layer(key, cfg: ModelConfig, *, cross: bool = False) -> dict:
    d, h, g, hd = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    ks = jax.random.split(key, 8)
    p = {
        "ln1": jnp.zeros((d,), jnp.float32),
        "ln2": jnp.zeros((d,), jnp.float32),
        "wq": dense_init(ks[0], (d, h, hd), d),
        "wk": dense_init(ks[1], (d, g, hd), d),
        "wv": dense_init(ks[2], (d, g, hd), d),
        "wo": dense_init(ks[3], (h, hd, d), h * hd),
        "mlp": mlp_mod.init_mlp(ks[4], cfg),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.zeros((hd,), jnp.float32)
        p["k_norm"] = jnp.zeros((hd,), jnp.float32)
    if cross:
        p["ln_x"] = jnp.zeros((d,), jnp.float32)
        p["xq"] = dense_init(ks[5], (d, h, hd), d)
        p["xk"] = dense_init(ks[6], (d, g, hd), d)
        p["xv"] = dense_init(ks[7], (d, g, hd), d)
        p["xo"] = dense_init(ks[5], (h, hd, d), h * hd)
    return p


def attn_logical_axes(cfg: ModelConfig, *, cross: bool = False) -> dict:
    p = {
        "ln1": (None,), "ln2": (None,),
        "wq": ("p_fsdp", "p_heads", None),
        "wk": ("p_fsdp", "p_kv_heads", None),
        "wv": ("p_fsdp", "p_kv_heads", None),
        "wo": ("p_heads", None, "p_fsdp"),
        "mlp": mlp_mod.mlp_logical_axes(cfg),
    }
    if cfg.qk_norm:
        p["q_norm"] = (None,)
        p["k_norm"] = (None,)
    if cross:
        p.update({
            "ln_x": (None,),
            "xq": ("p_fsdp", "p_heads", None),
            "xk": ("p_fsdp", "p_kv_heads", None),
            "xv": ("p_fsdp", "p_kv_heads", None),
            "xo": ("p_heads", None, "p_fsdp"),
        })
    return p


def init_layer(key, cfg: ModelConfig, kind: str) -> dict:
    if kind in ("attn", "local_attn", "enc_attn"):
        return init_attn_layer(key, cfg)
    if kind == "attn_cross":
        return init_attn_layer(key, cfg, cross=True)
    if kind == "rec":
        p = init_rglru_layer_with_mlp(key, cfg)
        return p
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_layer(key, cfg)
    raise ValueError(f"unknown layer kind {kind!r}")


def init_rglru_layer_with_mlp(key, cfg: ModelConfig) -> dict:
    k1, k2 = jax.random.split(key)
    p = rglru_mod.init_rglru_layer(k1, cfg)
    p["ln2"] = jnp.zeros((cfg.d_model,), jnp.float32)
    p["mlp"] = mlp_mod.init_mlp(k2, cfg)
    return p


def layer_logical_axes(cfg: ModelConfig, kind: str) -> dict:
    if kind in ("attn", "local_attn", "enc_attn"):
        return attn_logical_axes(cfg)
    if kind == "attn_cross":
        return attn_logical_axes(cfg, cross=True)
    if kind == "rec":
        p = rglru_mod.rglru_logical_axes(cfg)
        p["ln2"] = (None,)
        p["mlp"] = mlp_mod.mlp_logical_axes(cfg)
        return p
    if kind == "rwkv":
        return rwkv_mod.rwkv_logical_axes(cfg)
    raise ValueError(kind)


def _stack(trees):
    return jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *trees)


def init_params(key, cfg: ModelConfig) -> dict:
    """Full model parameters: embeddings + scanned stack + tail (+ encoder)."""
    keys = jax.random.split(key, 6)
    d, v = cfg.d_model, cfg.vocab_size
    # gemma-style: σ_embed = 1/√d, inputs rescaled by √d at lookup — keeps
    # tied-unembedding logits O(1) at init
    params: dict[str, Any] = {
        "embed": dense_init(keys[0], (v, d), d),
        "final_ln": jnp.zeros((d,), jnp.float32),
    }
    if not cfg.tie_embeddings:
        params["unembed"] = dense_init(keys[1], (d, v), d)

    unit_keys = jax.random.split(keys[2], cfg.scan_repeats)
    scan_params = []
    for uk in unit_keys:
        layer_keys = jax.random.split(uk, len(cfg.scan_unit))
        scan_params.append(
            {f"u{i}": init_layer(k, cfg, kind)
             for i, (kind, k) in enumerate(zip(cfg.scan_unit, layer_keys))}
        )
    params["scan"] = _stack(scan_params)

    tail_keys = jax.random.split(keys[3], max(len(cfg.tail), 1))
    params["tail"] = [
        init_layer(k, cfg, kind) for kind, k in zip(cfg.tail, tail_keys)
    ]

    if cfg.encoder_layers:
        enc_keys = jax.random.split(keys[4], cfg.encoder_layers)
        params["encoder"] = _stack(
            [{"u0": init_layer(k, cfg, "enc_attn")} for k in enc_keys]
        )
        params["enc_final_ln"] = jnp.zeros((d,), jnp.float32)
    return params


def logical_axes(cfg: ModelConfig) -> dict:
    """Same structure as init_params, leaves = logical axis tuples."""
    axes: dict[str, Any] = {
        "embed": ("p_vocab", "p_embed"),
        "final_ln": (None,),
    }
    if not cfg.tie_embeddings:
        axes["unembed"] = ("p_embed", "p_vocab")

    def stacked(tree):
        return jax.tree_util.tree_map(
            lambda t: ("stack", *t), tree, is_leaf=lambda x: isinstance(x, tuple)
        )

    axes["scan"] = stacked({
        f"u{i}": layer_logical_axes(cfg, kind)
        for i, kind in enumerate(cfg.scan_unit)
    })
    axes["tail"] = [layer_logical_axes(cfg, kind) for kind in cfg.tail]
    if cfg.encoder_layers:
        axes["encoder"] = stacked({"u0": layer_logical_axes(cfg, "enc_attn")})
        axes["enc_final_ln"] = (None,)
    return axes


# ---------------------------------------------------------------------------
# KV / recurrent caches
# ---------------------------------------------------------------------------


class LayerCache(NamedTuple):
    """Cache for one attention layer (decode).  KV heads stored pre-repeated
    ``cfg.kv_repeat``× so the shard layout matches the query-head shards."""

    k: jax.Array  # (B, S_cache, G·R, hd)
    v: jax.Array


def _cache_len(cfg: ModelConfig, kind: str, max_seq: int) -> int:
    if kind == "local_attn":
        return min(max_seq, cfg.local_attn_window)
    if cfg.sliding_window is not None:
        return min(max_seq, cfg.sliding_window)
    return max_seq


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_seq: int):
    if kind in ("attn", "local_attn", "attn_cross"):
        g = cfg.num_kv_heads * cfg.kv_repeat
        s = _cache_len(cfg, kind, max_seq)
        shape = (batch, s, g, cfg.head_dim)
        return LayerCache(
            k=jnp.zeros(shape, cfg.dtype), v=jnp.zeros(shape, cfg.dtype)
        )
    if kind == "rec":
        return rglru_mod.init_rglru_state(cfg, batch)
    if kind == "rwkv":
        return rwkv_mod.init_rwkv_state(cfg, batch)
    raise ValueError(kind)


def init_cache(cfg: ModelConfig, batch: int, max_seq: int) -> dict:
    def unit_cache():
        return {
            f"u{i}": init_layer_cache(cfg, kind, batch, max_seq)
            for i, kind in enumerate(cfg.scan_unit)
        }

    stacked = jax.tree_util.tree_map(
        lambda x: jnp.broadcast_to(x, (cfg.scan_repeats, *x.shape)),
        unit_cache(),
    )
    return {
        "scan": stacked,
        "tail": [
            init_layer_cache(cfg, kind, batch, max_seq) for kind in cfg.tail
        ],
        "t": jnp.zeros((), jnp.int32),
    }


def cache_logical_axes(cfg: ModelConfig) -> dict:
    """Sharding for cache pytrees (kv heads → model via the repeat trick)."""
    def layer_axes(kind, stacked: bool):
        pre = ("stack",) if stacked else ()
        if kind in ("attn", "local_attn", "attn_cross"):
            kv = ("batch", "kv_seq", "kv_cache_heads", None)
            return LayerCache(k=pre + kv, v=pre + kv)
        if kind == "rec":
            return rglru_mod.RglruState(
                h=pre + ("batch", "rnn"), conv=pre + ("batch", None, "rnn")
            )
        if kind == "rwkv":
            return rwkv_mod.RwkvState(
                s=pre + ("batch", "rnn", None, None),
                x_prev_tm=pre + ("batch", None),
                x_prev_cm=pre + ("batch", None),
            )
        raise ValueError(kind)

    return {
        "scan": {
            f"u{i}": layer_axes(kind, True)
            for i, kind in enumerate(cfg.scan_unit)
        },
        "tail": [layer_axes(kind, False) for kind in cfg.tail],
        "t": (),
    }


# ---------------------------------------------------------------------------
# Layer forward
# ---------------------------------------------------------------------------


def _project_qkv(p, cfg: ModelConfig, xn, positions):
    q = jnp.einsum("bsd,dhk->bshk", xn, p["wq"].astype(xn.dtype))
    k = jnp.einsum("bsd,dgk->bsgk", xn, p["wk"].astype(xn.dtype))
    v = jnp.einsum("bsd,dgk->bsgk", xn, p["wv"].astype(xn.dtype))
    if cfg.qk_norm:
        q = head_rms_norm(q, p["q_norm"])
        k = head_rms_norm(k, p["k_norm"])
    if positions is not None:
        if cfg.mrope_sections is not None:
            q = apply_mrope(q, positions, cfg.rope_theta, cfg.mrope_sections)
            k = apply_mrope(k, positions, cfg.rope_theta, cfg.mrope_sections)
        else:
            q = apply_rope(q, positions, cfg.rope_theta)
            k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _expand_kv(k: jax.Array, repeats: int) -> jax.Array:
    """(B,S,G,D) → (B,S,G·r,D): Megatron KV duplication for head-sharding."""
    if repeats == 1:
        return k
    return jnp.repeat(k, repeats, axis=2)


def attn_layer(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    positions,
    *,
    window: int | None,
    causal: bool = True,
    causal_mode: str = "masked",
) -> jax.Array:
    """Full-sequence attention + MLP block (train / prefill / encoder)."""
    b, s, d = x.shape
    h = cfg.num_heads
    xn = rms_norm(x, p["ln1"])
    q, k, v = _project_qkv(p, cfg, xn, positions)
    q = shard(q, "batch", None, "heads", None)
    # expand KV to the full query-head count (collective-free GQA)
    k = _expand_kv(k, cfg.q_per_kv)
    v = _expand_kv(v, cfg.q_per_kv)
    k = shard(k, "batch", None, "heads", None)
    v = shard(v, "batch", None, "heads", None)
    # (B,S,H,D) → (B,H,1,S,D): flash signature (B, groups, per-group, S, D)
    qf = jnp.moveaxis(q, 1, 2)[:, :, None]
    kf = jnp.moveaxis(k, 1, 2)
    vf = jnp.moveaxis(v, 1, 2)
    out = flash_attention(
        qf, kf, vf, causal=causal, window=window, causal_mode=causal_mode
    )
    out = jnp.moveaxis(out[:, :, 0], 1, 2)          # (B,S,H,D)
    out = shard(out, "batch", None, "heads", None)
    attn_out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(out.dtype))
    # row-parallel output lands directly on the sequence-sharded residual:
    # forces reduce-scatter (1× wire) instead of all-reduce-then-slice (2×)
    attn_out = shard(attn_out, "batch", "seq_sp", None)
    x = x + attn_out

    xn2 = rms_norm(x, p["ln2"])
    if cfg.moe is not None:
        mlp_out, aux = mlp_mod.moe_mlp(p["mlp"], cfg, xn2)
    else:
        mlp_out, aux = mlp_mod.dense_mlp(p["mlp"], cfg, xn2), jnp.zeros((), jnp.float32)
    mlp_out = shard(mlp_out, "batch", "seq_sp", None)
    return x + mlp_out, aux


def cross_attn(p: dict, cfg: ModelConfig, x: jax.Array, enc_out: jax.Array) -> jax.Array:
    """Encoder-decoder cross attention (whisper). Non-causal, no cache."""
    xn = rms_norm(x, p["ln_x"])
    q = jnp.einsum("bsd,dhk->bshk", xn, p["xq"].astype(xn.dtype))
    k = jnp.einsum("bsd,dgk->bsgk", enc_out, p["xk"].astype(enc_out.dtype))
    v = jnp.einsum("bsd,dgk->bsgk", enc_out, p["xv"].astype(enc_out.dtype))
    k = _expand_kv(k, cfg.q_per_kv)
    v = _expand_kv(v, cfg.q_per_kv)
    qf = jnp.moveaxis(q, 1, 2)[:, :, None]
    kf = jnp.moveaxis(k, 1, 2)
    vf = jnp.moveaxis(v, 1, 2)
    out = flash_attention(qf, kf, vf, causal=False, window=None)
    out = jnp.moveaxis(out[:, :, 0], 1, 2)
    return x + jnp.einsum("bshk,hkd->bsd", out, p["xo"].astype(out.dtype))


def attn_layer_decode(
    p: dict,
    cfg: ModelConfig,
    x: jax.Array,
    t: jax.Array,
    cache: LayerCache,
    *,
    window: int | None,
    enc_out: jax.Array | None = None,
) -> tuple[jax.Array, LayerCache]:
    """Single-token attention + MLP with cache update.  x: (B, d)."""
    b, d = x.shape
    xn = rms_norm(x[:, None, :], p["ln1"])
    pos = t[None, None].astype(jnp.int32) if cfg.mrope_sections is None else (
        jnp.broadcast_to(t, (3, b, 1)).astype(jnp.int32)
    )
    q, k, v = _project_qkv(p, cfg, xn, pos)
    # write this step's KV (duplicated R×) into the ring slot
    s_cache = cache.k.shape[1]
    slot = t % s_cache
    k_new = _expand_kv(k, cfg.kv_repeat)[:, 0]      # (B, G·R, D)
    v_new = _expand_kv(v, cfg.kv_repeat)[:, 0]
    new_cache = LayerCache(
        k=jax.lax.dynamic_update_index_in_dim(cache.k, k_new, slot, axis=1),
        v=jax.lax.dynamic_update_index_in_dim(cache.v, v_new, slot, axis=1),
    )
    # group query heads onto the duplicated-KV slots
    g_pad = cfg.num_kv_heads * cfg.kv_repeat
    per = cfg.num_heads // g_pad
    qd = q[:, 0].reshape(b, g_pad, per, cfg.head_dim)
    out = decode_attention(
        qd, new_cache.k, new_cache.v, t,
        window=window if window is not None else None,
    )
    out = out.reshape(b, cfg.num_heads, cfg.head_dim)
    attn_out = jnp.einsum("bhk,hkd->bd", out, p["wo"].astype(out.dtype))
    x = x + attn_out
    if enc_out is not None:
        x = cross_attn(p, cfg, x[:, None, :], enc_out)[:, 0]
    xn2 = rms_norm(x[:, None, :], p["ln2"])
    if cfg.moe is not None:
        mlp_out, _ = mlp_mod.moe_mlp(p["mlp"], cfg, xn2)
    else:
        mlp_out = mlp_mod.dense_mlp(p["mlp"], cfg, xn2)
    return x + mlp_out[:, 0], new_cache


def rec_layer(p, cfg, x, state, *, decode=False):
    """RG-LRU block + MLP (recurrentgemma 'rec' layer)."""
    h, new_state = rglru_mod.rglru_block(p, cfg, x, state, decode=decode)
    x = x + h
    xn = rms_norm(x[:, None, :] if decode else x, p["ln2"])
    out = mlp_mod.dense_mlp(p["mlp"], cfg, xn)
    return x + (out[:, 0] if decode else out), new_state
