"""recurrentgemma-9b [hybrid]: 38L d_model=4096 16H (MQA kv=1) d_ff=12288
vocab=256000 — RG-LRU + local attention, pattern (rec, rec, attn).
[arXiv:2402.19427; unverified]

38 = 12 × (rec, rec, attn) + (rec, rec) tail.  lru_width = 4096, local
attention window 2048.  MQA KV (1 head) is stored 16×-duplicated so the
decode cache shards over the model axis (tiny anyway: window-sized).
"""

from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b",
        family="hybrid",
        num_layers=38,
        d_model=4096,
        num_heads=16,
        num_kv_heads=1,
        head_dim=256,
        d_ff=12288,
        vocab_size=256000,
        mlp_type="swiglu",
        rope_theta=10_000.0,
        lru_width=4096,
        local_attn_window=2048,
        scan_unit=("rec", "rec", "attn"),
        tail=("rec", "rec"),
        kv_repeat=16,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="recurrentgemma-9b-smoke",
        family="hybrid",
        num_layers=5,
        d_model=64,
        num_heads=4,
        num_kv_heads=1,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        mlp_type="swiglu",
        lru_width=64,
        local_attn_window=16,
        scan_unit=("rec", "rec", "attn"),
        tail=("rec", "rec"),
        remat=False,
    )
