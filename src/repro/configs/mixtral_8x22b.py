"""mixtral-8x22b [moe]: 56L d_model=6144 48H (GQA kv=8) d_ff=16384
vocab=32768, MoE 8 experts top-2, SWA.  [arXiv:2401.04088; hf]

8 wide experts < 16 TP ranks → each expert is tensor-parallel on its ffn
dim instead of expert-parallel (rule override).
"""

from repro.models.config import ModelConfig, MoESpec

SWA_WINDOW = 4096


def full_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b",
        family="moe",
        num_layers=56,
        d_model=6144,
        num_heads=48,
        num_kv_heads=8,
        head_dim=128,
        d_ff=16384,
        vocab_size=32768,
        mlp_type="swiglu",
        rope_theta=1_000_000.0,
        sliding_window=SWA_WINDOW,
        scan_unit=("attn",),
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=16384, expert_parallel=False),
        kv_repeat=2,
        rule_overrides=(
            ("expert", None), ("p_expert", None),
            ("mlp_expert", "model"), ("p_mlp_expert", "model"),
            # 141B params exceed TP-only serving HBM (17.6 GiB/chip bf16):
            # keep weights FSDP-sharded over data at serve too
            ("p_fsdp", "data"),
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="mixtral-8x22b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        mlp_type="swiglu",
        sliding_window=16,
        scan_unit=("attn",),
        moe=MoESpec(num_experts=4, top_k=2, d_ff_expert=128, expert_parallel=False),
        remat=False,
    )
