"""qwen2-vl-72b [vlm]: 80L d_model=8192 64H (GQA kv=8) d_ff=29568
vocab=152064 — M-RoPE, dynamic resolution; vision frontend STUBBED
(input_specs provides precomputed patch embeddings).
[arXiv:2409.12191; hf]"""

from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b",
        family="vlm",
        num_layers=80,
        d_model=8192,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,
        d_ff=29568,
        vocab_size=152064,
        mlp_type="swiglu",
        rope_theta=1_000_000.0,
        mrope_sections=(16, 24, 24),   # (t, h, w) — sums to head_dim/2
        embeds_input=True,             # stub frontend: patch embeddings in
        scan_unit=("attn",),
        kv_repeat=2,
        # 72B bf16 weights (9 GiB/chip TP-only) + 8.6 GiB cache exceed HBM
        # at decode_32k: keep weights FSDP-sharded over data at serve too
        rule_overrides=(("p_fsdp", "data"),),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen2-vl-72b-smoke",
        family="vlm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        mlp_type="swiglu",
        mrope_sections=(2, 3, 3),
        embeds_input=True,
        scan_unit=("attn",),
        remat=False,
    )
