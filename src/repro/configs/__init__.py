"""Architecture registry: ``get_config(name)`` / ``get_smoke_config(name)``.

Every assigned architecture is a selectable config (``--arch <id>``); each
module exposes ``full_config()`` (the exact published numbers) and
``smoke_config()`` (a reduced same-family config for CPU smoke tests).
"""

from __future__ import annotations

import importlib

ARCHS = {
    "qwen3-32b": "repro.configs.qwen3_32b",
    "llama3.2-1b": "repro.configs.llama3_2_1b",
    "starcoder2-7b": "repro.configs.starcoder2_7b",
    "h2o-danube-1.8b": "repro.configs.h2o_danube_1_8b",
    "rwkv6-3b": "repro.configs.rwkv6_3b",
    "recurrentgemma-9b": "repro.configs.recurrentgemma_9b",
    "olmoe-1b-7b": "repro.configs.olmoe_1b_7b",
    "mixtral-8x22b": "repro.configs.mixtral_8x22b",
    "whisper-large-v3": "repro.configs.whisper_large_v3",
    "qwen2-vl-72b": "repro.configs.qwen2_vl_72b",
}

# paper's own architectures (integer-only NITRO-D models)
PAPER_ARCHS = ("mlp1", "mlp2", "mlp3", "mlp4", "vgg8b", "vgg11b")


def get_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name]).full_config()


def get_smoke_config(name: str):
    if name not in ARCHS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(ARCHS)}")
    return importlib.import_module(ARCHS[name]).smoke_config()


def get_paper_config(name: str, **kw):
    from repro.configs import paper

    return paper.get(name, **kw)


def list_archs() -> list[str]:
    return sorted(ARCHS)
