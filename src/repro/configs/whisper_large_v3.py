"""whisper-large-v3 [audio]: 32L d_model=1280 20H (MHA kv=20) d_ff=5120
vocab=51866 — encoder-decoder, conv frontend STUBBED (input_specs provides
precomputed 1500-frame embeddings).  [arXiv:2212.04356; unverified]

Backbone only per the assignment: 32 encoder + 32 decoder layers (the
published large-v3 layout), GeLU MLPs, MHA.  20 heads don't divide the
16-wide model axis → attention replicated over ``model`` (MLP stays TP);
the serve cache shards on the sequence dim instead (rule override).
RoPE substitutes whisper's learned/sinusoidal positions — backbone-shape
faithful, positional scheme adapted (DESIGN.md §4).
"""

from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3",
        family="audio",
        num_layers=32,            # decoder layers; +32 encoder below
        d_model=1280,
        num_heads=20,
        num_kv_heads=20,
        d_ff=5120,
        vocab_size=51866,
        mlp_type="gelu",
        rope_theta=10_000.0,
        scan_unit=("attn_cross",),  # decoder layers: self-attn + cross-attn
        encoder_layers=32,
        encoder_seq=1500,
        kv_repeat=1,
        rule_overrides=(
            ("heads", None), ("kv_heads", None),
            ("p_heads", None), ("p_kv_heads", None),
            ("kv_cache_heads", None),
            ("kv_seq", "model"),
            # vocab 51866 is not divisible by 16 → replicate the embedding
            ("p_vocab", None), ("vocab", None),
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="whisper-large-v3-smoke",
        family="audio",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        mlp_type="gelu",
        scan_unit=("attn_cross",),
        encoder_layers=2,
        encoder_seq=24,
        remat=False,
    )
