"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) d_ff=1024 vocab=50304,
MoE 64 experts top-8.  [arXiv:2409.02060; hf]

d_ff=1024 is the *per-expert* width.  64 experts shard over the model axis
(expert parallelism, 4 experts/chip at TP16).
"""

from repro.models.config import ModelConfig, MoESpec


def full_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b",
        family="moe",
        num_layers=16,
        d_model=2048,
        num_heads=16,
        num_kv_heads=16,
        d_ff=1024,
        vocab_size=50304,
        qk_norm=True,          # olmoe uses qk-norm
        mlp_type="swiglu",
        rope_theta=10_000.0,
        scan_unit=("attn",),
        moe=MoESpec(num_experts=64, top_k=8, d_ff_expert=1024, expert_parallel=True),
        kv_repeat=1,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="olmoe-1b-7b-smoke",
        family="moe",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=64,
        vocab_size=256,
        qk_norm=True,
        mlp_type="swiglu",
        scan_unit=("attn",),
        moe=MoESpec(num_experts=8, top_k=2, d_ff_expert=64, expert_parallel=True),
        remat=False,
    )
