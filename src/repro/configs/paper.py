"""The paper's own architectures (Appendix C, Tables 4–5) with the exact
hyper-parameters from Appendix D (Tables 6–7).

Input shapes follow the paper's datasets: MLP1/2/3 and VGG8B on 28×28×1
(MNIST/FashionMNIST), MLP4/VGG8B/VGG11B on 32×32×3 (CIFAR-10).  A
``scale`` knob shrinks widths uniformly for CPU-budget training runs in the
benchmarks (the full configs are also constructible, scale=1).
"""

from __future__ import annotations

from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig


def _s(x: int, scale: float) -> int:
    return max(int(round(x * scale)), 8)


def mlp(name: str, widths, input_dim: int, g: int, gamma: int, eta_fw: int,
        eta_lr: int, p_l: float, scale: float = 1.0) -> NitroConfig:
    blocks = tuple(
        BlockSpec("linear", _s(w, scale), dropout=p_l) for w in widths
    )
    return NitroConfig(
        blocks=blocks, input_shape=(input_dim,), num_classes=g,
        gamma_inv=gamma, eta_fw=eta_fw, eta_lr=eta_lr, name=name,
    )


def cnn(name: str, layout, input_shape, g: int, gamma: int, eta_fw: int,
        eta_lr: int, d_lr: int, p_c: float, p_l: float,
        scale: float = 1.0) -> NitroConfig:
    blocks = []
    for kind, width, pool in layout:
        if kind == "conv":
            blocks.append(
                BlockSpec("conv", _s(width, scale), pool=pool,
                          d_lr=_s(d_lr, scale), dropout=p_c)
            )
        else:
            blocks.append(BlockSpec("linear", _s(width, scale), dropout=p_l))
    return NitroConfig(
        blocks=tuple(blocks), input_shape=input_shape, num_classes=g,
        gamma_inv=gamma, eta_fw=eta_fw, eta_lr=eta_lr, name=name,
    )


# (kind, width, maxpool-after) — Table 5; pools follow the listed MaxPool2D
VGG8B_LAYOUT = [
    ("conv", 128, False), ("conv", 256, True),
    ("conv", 256, False), ("conv", 512, True),
    ("conv", 512, True), ("conv", 512, True),
    ("linear", 1024, False),
]
VGG11B_LAYOUT = [
    ("conv", 128, False), ("conv", 128, False), ("conv", 128, False),
    ("conv", 256, True), ("conv", 256, False), ("conv", 512, True),
    ("conv", 512, False), ("conv", 512, True), ("conv", 512, True),
    ("linear", 1024, False),
]


def get(name: str, scale: float = 1.0, input_shape=None) -> NitroConfig:
    """Paper configs with Appendix-D hyper-parameters."""
    if name == "mlp1":    # MNIST: 784→100→50→10, γ=512, η=(12000,3000)
        return mlp("mlp1", [100, 50], 784, 10, 512, 12000, 3000, 0.0, scale)
    if name == "mlp2":    # FashionMNIST: 784→200→100→50→10
        return mlp("mlp2", [200, 100, 50], 784, 10, 512, 10000, 8000, 0.0, scale)
    if name == "mlp3":    # 784→1024×3→10, γ=512, η=(28000,5000)
        return mlp("mlp3", [1024, 1024, 1024], 784, 10, 512, 28000, 5000, 0.0, scale)
    if name == "mlp4":    # CIFAR-10: 3072→3000×3→10, p_l=0.1
        return mlp("mlp4", [3000, 3000, 3000], 3072, 10, 512, 19000, 7500, 0.1, scale)
    if name == "vgg8b":
        shape = input_shape or (32, 32, 3)
        return cnn("vgg8b", VGG8B_LAYOUT, shape, 10, 512, 25000, 3000,
                   4096, 0.0, 0.1, scale)
    if name == "vgg11b":
        shape = input_shape or (32, 32, 3)
        return cnn("vgg11b", VGG11B_LAYOUT, shape, 10, 512, 28000, 4500,
                   4096, 0.0, 0.0, scale)
    raise KeyError(f"unknown paper arch {name!r}")
