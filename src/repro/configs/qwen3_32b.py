"""qwen3-32b [dense]: 64L d_model=5120 64H (GQA kv=8) d_ff=25600
vocab=151936 — qk_norm, GQA.  [hf:Qwen/Qwen3-8B family; hf]"""

from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b",
        family="dense",
        num_layers=64,
        d_model=5120,
        num_heads=64,
        num_kv_heads=8,
        head_dim=128,          # qwen3 uses explicit head_dim 128
        d_ff=25600,
        vocab_size=151936,
        qk_norm=True,
        mlp_type="swiglu",
        rope_theta=1_000_000.0,
        scan_unit=("attn",),
        kv_repeat=2,           # kv 8 → 16 stored heads (model-axis aligned)
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="qwen3-32b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        head_dim=16,
        d_ff=128,
        vocab_size=256,
        qk_norm=True,
        mlp_type="swiglu",
        scan_unit=("attn",),
        remat=False,
    )
