"""h2o-danube-1.8b [dense]: 24L d_model=2560 32H (GQA kv=8) d_ff=6912
vocab=32000 — llama+mistral mix, sliding-window attention.
[arXiv:2401.16818; hf]"""

from repro.models.config import ModelConfig

SWA_WINDOW = 4096


def full_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b",
        family="dense",
        num_layers=24,
        d_model=2560,
        num_heads=32,
        num_kv_heads=8,
        d_ff=6912,
        vocab_size=32000,
        mlp_type="swiglu",
        rope_theta=10_000.0,
        sliding_window=SWA_WINDOW,
        scan_unit=("attn",),
        kv_repeat=2,
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="h2o-danube-1.8b-smoke",
        family="dense",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        mlp_type="swiglu",
        sliding_window=16,
        scan_unit=("attn",),
        remat=False,
    )
