"""starcoder2-7b [dense]: 32L d_model=4608 36H (GQA kv=4) d_ff=18432
vocab=49152 — GQA, RoPE, GeLU MLP.  [arXiv:2402.19173; hf]

36 heads do not divide the 16-wide model axis → attention activations are
replicated over ``model`` (Megatron fallback; MLP stays TP).  Recorded in
DESIGN.md §5 and visible in the roofline as redundant attention compute.
"""

from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b",
        family="dense",
        num_layers=32,
        d_model=4608,
        num_heads=36,
        num_kv_heads=4,
        d_ff=18432,
        vocab_size=49152,
        mlp_type="gelu",
        rope_theta=100_000.0,
        scan_unit=("attn",),
        kv_repeat=1,
        rule_overrides=(
            ("heads", None), ("kv_heads", None),
            ("p_heads", None), ("p_kv_heads", None),
            ("kv_cache_heads", None),
            ("kv_seq", "model"),   # serve: shard the 32k cache on seq instead
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="starcoder2-7b-smoke",
        family="dense",
        num_layers=2,
        d_model=72,
        num_heads=6,          # preserves the non-power-of-two head count
        num_kv_heads=2,
        d_ff=128,
        vocab_size=256,
        mlp_type="gelu",
        scan_unit=("attn",),
        remat=False,
    )
