"""rwkv6-3b [ssm]: 32L d_model=2560 (attention-free) d_ff=8960 vocab=65536
— Finch, data-dependent decay.  [arXiv:2404.05892; hf]

3B params → pure data parallelism over the whole 256-chip pod is the right
strategy (DESIGN.md §5): batch shards over (data × model), parameters are
fully FSDP-sharded over both axes.  The 40 RWKV heads (head_dim 64) need
no TP.
"""

from repro.models.config import ModelConfig


def full_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b",
        family="ssm",
        num_layers=32,
        d_model=2560,
        num_heads=40,          # d_model / rwkv_head_dim
        num_kv_heads=40,
        d_ff=8960,
        vocab_size=65536,
        rwkv_head_dim=64,
        scan_unit=("rwkv",),
        dp_only=True,
        rule_overrides=(
            ("heads", None), ("kv_heads", None), ("rnn", None), ("mlp", None),
            ("p_heads", None), ("p_kv_heads", None), ("p_mlp", None),
            ("p_rnn", None), ("p_vocab", None), ("vocab", None),
        ),
    )


def smoke_config() -> ModelConfig:
    return ModelConfig(
        name="rwkv6-3b-smoke",
        family="ssm",
        num_layers=2,
        d_model=64,
        num_heads=4,
        num_kv_heads=4,
        d_ff=128,
        vocab_size=256,
        rwkv_head_dim=16,
        scan_unit=("rwkv",),
        remat=False,
    )
