"""Fault-tolerance runtime: preemption handling, straggler detection,
elastic restart policy.

On a real multi-host deployment each host runs this supervisor around the
train loop; in this container the same code paths are exercised by the
tests with simulated signals/step-times.

Components:
  * ``PreemptionGuard``    — SIGTERM/SIGINT → set a flag; the train loop
    checkpoints and exits cleanly at the next step boundary (TPU
    maintenance events give ~30 s notice — one checkpoint fits).
  * ``StragglerDetector``  — per-step wall-time EWMA; a step slower than
    ``threshold ×`` the EWMA marks a straggler incident.  Policy knobs:
    log-only, or trigger checkpoint-and-rebalance after K incidents
    (on real clusters the rebalance = restart with the slow host cordoned).
  * ``ElasticPolicy``      — given the surviving device count, picks the
    largest (data × model) mesh compatible with the model's TP requirement
    and the global batch; restore is a resharding load (checkpoint.py).
"""

from __future__ import annotations

import signal
import time
from dataclasses import dataclass, field


class PreemptionGuard:
    def __init__(self, install: bool = True):
        self.requested = False
        self._prev = {}
        if install:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._prev[sig] = signal.signal(sig, self._handler)
                except ValueError:
                    pass  # non-main thread (tests)

    def _handler(self, signum, frame):
        self.requested = True

    def simulate(self):
        """Test hook: behave as if SIGTERM arrived."""
        self.requested = True

    def uninstall(self):
        for sig, prev in self._prev.items():
            signal.signal(sig, prev)


@dataclass
class StragglerDetector:
    """EWMA step-time monitor."""

    alpha: float = 0.1
    threshold: float = 2.5
    warmup_steps: int = 5
    ewma: float = 0.0
    steps: int = 0
    incidents: int = 0
    history: list = field(default_factory=list)

    def record(self, step_time: float) -> bool:
        """Record one step's wall time; True if it was a straggler step."""
        self.steps += 1
        if self.steps <= self.warmup_steps:
            self.ewma = (
                step_time if self.ewma == 0.0
                else (1 - self.alpha) * self.ewma + self.alpha * step_time
            )
            return False
        is_straggler = step_time > self.threshold * self.ewma
        if is_straggler:
            self.incidents += 1
            self.history.append((self.steps, step_time, self.ewma))
        else:
            # stragglers are excluded from the EWMA (they'd poison it)
            self.ewma = (1 - self.alpha) * self.ewma + self.alpha * step_time
        return is_straggler

    def should_rebalance(self, k: int = 3) -> bool:
        return self.incidents >= k


@dataclass(frozen=True)
class ElasticPolicy:
    """Mesh re-selection after losing chips."""

    model_parallel: int = 16      # fixed TP requirement of the arch
    global_batch: int = 256

    def choose_mesh_shape(self, available_chips: int) -> tuple[int, int]:
        """Largest (data, model) with model fixed, data | global_batch."""
        data = available_chips // self.model_parallel
        while data > 0 and self.global_batch % data != 0:
            data -= 1
        if data == 0:
            raise RuntimeError(
                f"cannot build a mesh from {available_chips} chips with "
                f"TP={self.model_parallel}"
            )
        return (data, self.model_parallel)


class StepTimer:
    def __init__(self):
        self.t0 = time.monotonic()

    def lap(self) -> float:
        now = time.monotonic()
        dt = now - self.t0
        self.t0 = now
        return dt
