"""AdamW for the LM substrate (no optax in this container).

State is two fp32 moments with the same sharding as the parameters
(FSDP-sharded under train rules → ZeRO-like optimiser-state sharding for
free).  Includes global-norm clipping and decoupled weight decay.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class AdamWState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(
        mu=jax.tree_util.tree_map(zeros, params),
        nu=jax.tree_util.tree_map(zeros, params),
        count=jnp.zeros((), jnp.int32),
    )


def global_norm(tree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
            for g in jax.tree_util.tree_leaves(tree))
    )


def update(
    params,
    grads,
    state: AdamWState,
    *,
    lr: float | jax.Array,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
):
    """One AdamW step; returns (params, state, grad_norm)."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gnorm, 1e-9))
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads
    )
    count = state.count + 1
    t = count.astype(jnp.float32)
    mu = jax.tree_util.tree_map(
        lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads
    )
    nu = jax.tree_util.tree_map(
        lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads
    )

    def upd(p, m, v):
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        step = mhat / (jnp.sqrt(vhat) + eps) + weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * step).astype(p.dtype)

    new_params = jax.tree_util.tree_map(upd, params, mu, nu)
    return new_params, AdamWState(mu=mu, nu=nu, count=count), gnorm


def lr_schedule(step: jax.Array, *, peak: float, warmup: int = 200,
                total: int = 10_000) -> jax.Array:
    """Linear warmup → cosine decay."""
    s = step.astype(jnp.float32)
    warm = peak * s / max(warmup, 1)
    prog = jnp.clip((s - warmup) / max(total - warmup, 1), 0.0, 1.0)
    cos = peak * 0.5 * (1.0 + jnp.cos(jnp.pi * prog))
    return jnp.where(s < warmup, warm, cos)
