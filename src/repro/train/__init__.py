"""Training substrate: optimiser, sharded step builder, checkpoints,
fault tolerance."""
