"""Checkpointing: sharded-aware save/restore with manifest, async writes,
and elastic restore.

Layout (one directory per step)::

    ckpt_dir/
      step_000100/
        MANIFEST.json        # step, mesh shape, leaf index, status
        leaf_00000.npy ...   # one file per pytree leaf (addressable data)
      LATEST                 # name of the newest COMPLETE checkpoint

Fault-tolerance contract:
  * a checkpoint directory is valid iff its MANIFEST has status=COMPLETE —
    a preempted writer never corrupts LATEST (write manifest last, fsync);
  * ``save_async`` runs in a daemon thread so the train loop keeps stepping
    (the arrays are fetched to host first — snapshot semantics);
  * restore accepts a *different* mesh: leaves are loaded as numpy and
    re-placed with ``jax.device_put`` under the new sharding — elastic
    re-scaling (e.g. 16×16 → 8×16 after losing a slice) is a restore-time
    reshard, no format change.
"""

from __future__ import annotations

import json
import os
import shutil
import threading
import time
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    paths = ["/".join(str(k) for k in path) for path, _ in flat]
    leaves = [leaf for _, leaf in flat]
    return paths, leaves, treedef


def save(ckpt_dir: str, step: int, tree: Any, *, extra: dict | None = None) -> str:
    """Synchronous checkpoint write.  Returns the checkpoint path."""
    name = f"step_{step:08d}"
    path = os.path.join(ckpt_dir, name)
    tmp = path + ".tmp"
    os.makedirs(tmp, exist_ok=True)

    paths, leaves, _ = _flatten_with_paths(tree)
    index = []
    for i, (p, leaf) in enumerate(zip(paths, leaves)):
        arr = np.asarray(jax.device_get(leaf))
        dtype_name = str(arr.dtype)
        if dtype_name == "bfloat16":  # npy has no bf16: store the raw bits
            arr = arr.view(np.uint16)
        fname = f"leaf_{i:05d}.npy"
        np.save(os.path.join(tmp, fname), arr)
        index.append({"path": p, "file": fname, "shape": list(arr.shape),
                      "dtype": dtype_name})

    manifest = {
        "step": step,
        "time": time.time(),
        "leaves": index,
        "extra": extra or {},
        "status": "COMPLETE",
    }
    with open(os.path.join(tmp, "MANIFEST.json"), "w") as f:
        json.dump(manifest, f)
        f.flush()
        os.fsync(f.fileno())
    if os.path.exists(path):
        shutil.rmtree(path)
    os.rename(tmp, path)
    with open(os.path.join(ckpt_dir, "LATEST"), "w") as f:
        f.write(name)
    return path


class AsyncCheckpointer:
    """Snapshot-to-host then write in a daemon thread; at most one inflight
    save — a second request blocks until the first completes (backpressure
    rather than unbounded host memory)."""

    def __init__(self, ckpt_dir: str):
        self.ckpt_dir = ckpt_dir
        self._thread: threading.Thread | None = None
        self.last_path: str | None = None

    def save(self, step: int, tree: Any, *, extra: dict | None = None):
        self.wait()
        host_tree = jax.tree_util.tree_map(
            lambda x: np.asarray(jax.device_get(x)), tree
        )

        def _write():
            self.last_path = save(self.ckpt_dir, step, host_tree, extra=extra)

        self._thread = threading.Thread(target=_write, daemon=True)
        self._thread.start()

    def wait(self):
        if self._thread is not None:
            self._thread.join()
            self._thread = None


def latest_step(ckpt_dir: str) -> int | None:
    marker = os.path.join(ckpt_dir, "LATEST")
    if not os.path.exists(marker):
        return None
    with open(marker) as f:
        name = f.read().strip()
    manifest = os.path.join(ckpt_dir, name, "MANIFEST.json")
    if not os.path.exists(manifest):
        return None
    with open(manifest) as f:
        m = json.load(f)
    return int(m["step"]) if m.get("status") == "COMPLETE" else None


def restore(ckpt_dir: str, tree_like: Any, *, step: int | None = None,
            shardings: Any = None) -> tuple[Any, int]:
    """Restore into the structure of ``tree_like``.

    ``shardings``: optional matching pytree of NamedShardings — pass the
    *new* mesh's shardings to reshard elastically on restore.
    """
    if step is None:
        step = latest_step(ckpt_dir)
        if step is None:
            raise FileNotFoundError(f"no COMPLETE checkpoint in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with open(os.path.join(path, "MANIFEST.json")) as f:
        manifest = json.load(f)
    assert manifest["status"] == "COMPLETE", "refusing to restore partial ckpt"

    by_path = {e["path"]: e for e in manifest["leaves"]}
    paths, leaves, treedef = _flatten_with_paths(tree_like)
    shard_leaves = (
        jax.tree_util.tree_leaves(
            shardings, is_leaf=lambda x: hasattr(x, "spec")
        )
        if shardings is not None
        else [None] * len(leaves)
    )
    restored = []
    for p, like, shd in zip(paths, leaves, shard_leaves):
        entry = by_path[p]
        arr = np.load(os.path.join(path, entry["file"]))
        if entry["dtype"] == "bfloat16":
            arr = arr.view(np.dtype(jax.numpy.bfloat16))
        if shd is not None:
            restored.append(jax.device_put(arr, shd))
        else:
            restored.append(jax.numpy.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, restored), step
