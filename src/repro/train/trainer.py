"""Sharded LM train/serve step builders.

``build_train_step(cfg, mesh, rules)`` returns a jit-compiled function with
explicit in/out shardings derived from the logical-axis tables — the same
object the dry-run lowers for the 256/512-chip meshes and the e2e examples
execute on CPU.  Handles:

  * FSDP+TP parameter shardings from ``transformer.logical_axes``;
  * AdamW with the same shardings for both moments (ZeRO-style);
  * activation rematerialisation (per scan unit, inside the model);
  * cross-pod gradient handling: XLA reduces over ``pod``+``data`` as part
    of the batch-sharded loss gradient (int8-EF compression path available
    via ``parallel.compress`` in the shard_map trainer).
"""

from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.models import lm
from repro.models import transformer as T
from repro.models.config import ModelConfig
from repro.parallel import sharding as shr
from repro.train import optimizer as opt


def resolved_rules(cfg: ModelConfig, base_rules: dict) -> dict:
    rules = dict(base_rules)
    for key, value in cfg.rule_overrides:
        if key in ("serve_batch_data_only",):
            continue  # launcher marker, not a logical axis
        rules[key] = value
    return rules


def param_shardings(cfg: ModelConfig, mesh, rules: dict):
    axes = T.logical_axes(cfg)
    return shr.tree_shardings(mesh, rules, axes)


def batch_spec(cfg: ModelConfig, mesh, rules: dict, *, shapes: dict):
    """NamedShardings for the input batch dict."""
    b_axes = rules.get("batch")

    def spec_for(name, ndim):
        if name == "positions" and cfg.mrope_sections is not None:
            return NamedSharding(mesh, P(None, b_axes, None))
        lead = [b_axes] + [None] * (ndim - 1)
        return NamedSharding(mesh, P(*lead))

    return {k: spec_for(k, len(v)) for k, v in shapes.items()}


def loss_fn(params, cfg: ModelConfig, batch, causal_mode="masked"):
    if cfg.cast_params_once:
        # one explicit cast at the step boundary — the backward of this cast
        # converts bf16 cotangents to fp32 *after* the data-axis reduction,
        # so weight gathers and grad reductions move bf16 on the wire
        params = jax.tree_util.tree_map(
            lambda p: p.astype(cfg.dtype) if p.dtype == jnp.float32 else p,
            params,
        )
    return lm.train_loss(params, cfg, batch, causal_mode=causal_mode)


def train_step(state, cfg: ModelConfig, batch, *, causal_mode="masked",
               total_steps: int = 10_000):
    """Pure step: (params, opt, step) + batch → new state + metrics."""
    params, opt_state, step = state
    (loss, metrics), grads = jax.value_and_grad(
        loss_fn, has_aux=True
    )(params, cfg, batch, causal_mode)
    lr = opt.lr_schedule(step, peak=cfg.learning_rate, total=total_steps)
    new_params, new_opt, gnorm = opt.update(
        params, grads, opt_state,
        lr=lr, weight_decay=cfg.weight_decay,
    )
    metrics = dict(metrics)
    metrics["grad_norm"] = gnorm
    metrics["lr"] = lr
    return (new_params, new_opt, step + 1), metrics


def build_train_step(cfg: ModelConfig, mesh, rules: dict, *, shapes: dict,
                     causal_mode: str = "masked", donate: bool = True):
    """jit-compiled train step with explicit in/out shardings.

    ``shapes``: dict name → shape tuple for the batch inputs (used only to
    build shardings; the returned fn takes (state, batch)).
    """
    p_shard = param_shardings(cfg, mesh, rules)
    opt_shard = opt.AdamWState(
        mu=p_shard, nu=p_shard,
        count=NamedSharding(mesh, P()),
    )
    state_shard = (p_shard, opt_shard, NamedSharding(mesh, P()))
    b_shard = batch_spec(cfg, mesh, rules, shapes=shapes)

    def fn(state, batch):
        # install the logical-axis rules so in-model shard() constraints
        # resolve against this mesh during tracing
        with shr.use_rules(mesh, rules):
            return train_step(state, cfg=cfg, batch=batch,
                              causal_mode=causal_mode)

    return jax.jit(
        fn,
        in_shardings=(state_shard, b_shard),
        out_shardings=(state_shard, None),
        donate_argnums=(0,) if donate else (),
    )


def init_state(key, cfg: ModelConfig):
    params = T.init_params(key, cfg)
    return (params, opt.init(params), jnp.zeros((), jnp.int32))


def abstract_state(key, cfg: ModelConfig):
    """ShapeDtypeStructs for the train state — used by the dry-run (no
    allocation for 72B-parameter models on a CPU host)."""
    return jax.eval_shape(functools.partial(init_state, cfg=cfg), key)


# ---------------------------------------------------------------------------
# Serving steps
# ---------------------------------------------------------------------------


def build_decode_step(cfg: ModelConfig, mesh, rules: dict, *, has_enc: bool = False):
    """jit-compiled single-token decode with cache shardings."""
    p_axes = T.logical_axes(cfg)
    p_shard = shr.tree_shardings(mesh, rules, p_axes)
    c_axes = T.cache_logical_axes(cfg)
    c_shard = shr.tree_shardings(mesh, rules, c_axes)
    b_axes = rules.get("batch")
    tok_shard = NamedSharding(mesh, P(b_axes))
    out_shard = (NamedSharding(mesh, P(b_axes, rules.get("vocab"))), c_shard)

    if has_enc:
        enc_shard = NamedSharding(mesh, P(b_axes, None, None))

        def fn_enc(params, tokens, cache, enc_out):
            with shr.use_rules(mesh, rules):
                return lm.decode_step(params, cfg, tokens, cache, enc_out=enc_out)

        return jax.jit(
            fn_enc,
            in_shardings=(p_shard, tok_shard, c_shard, enc_shard),
            out_shardings=out_shard,
            donate_argnums=(2,),
        )

    def fn(params, tokens, cache):
        with shr.use_rules(mesh, rules):
            return lm.decode_step(params, cfg, tokens, cache)

    return jax.jit(
        fn,
        in_shardings=(p_shard, tok_shard, c_shard),
        out_shardings=out_shard,
        donate_argnums=(2,),
    )


def build_prefill(cfg: ModelConfig, mesh, rules: dict, *, shapes: dict):
    p_axes = T.logical_axes(cfg)
    p_shard = shr.tree_shardings(mesh, rules, p_axes)
    c_axes = T.cache_logical_axes(cfg)
    c_shard = shr.tree_shardings(mesh, rules, c_axes)
    b_shard = batch_spec(cfg, mesh, rules, shapes=shapes)

    def fn(params, batch, cache):
        with shr.use_rules(mesh, rules):
            return lm.prefill(params, cfg, batch, cache)

    return jax.jit(
        fn,
        in_shardings=(p_shard, b_shard, c_shard),
        out_shardings=(
            NamedSharding(mesh, P(rules.get("batch"), rules.get("vocab"))),
            c_shard,
        ),
        donate_argnums=(2,),
    )
