"""Data-parallel NITRO-D training, bitwise-identical at any device count.

NITRO-D's integer arithmetic buys a property float data parallelism can
never have: **the sharded step is an equality, not an approximation**.
Every gradient ``les.compute_gradients`` produces is a *batch sum* of
per-sample int32 contributions (RSS loss and both backward paths are
linear in the batch dimension), and int32 addition is associative and
commutative — so splitting the batch over a ``data`` mesh axis, reducing
per-shard gradients with *any* exact integer all-reduce, and applying
IntegerSGD once reproduces the single-device ``les.train_step`` bit for
bit, at any device count and any reduction order.
``tests/test_data_parallel.py`` enforces this as ``assert_bitwise_equal``
over multi-step ``TrainState`` trajectories across real host-device
counts {1, 2, 4} × every reducer below.

Three interchangeable reducers (``dp_reduce=``):

  * ``"psum"``     — XLA's all-reduce (default; ``compress.exact_integer_psum``)
  * ``"ring"``     — the hand-scheduled chunked ``collectives.ring_all_reduce``
                     (exposes per-chunk steps for comms/compute overlap)
  * ``"compress"`` — ``compress.nitro_compressed_psum``: the same exact sum
                     carried as int8 limb planes on the wire

All three are bitwise-equivalent — that is the point.  The only sampled
operation in the step, IntegerDropout, draws the *global-batch* mask from
the replicated key and slices this shard's rows
(``dp_axis``/``dp_shards`` threading in ``core.layers.dropout_forward``),
so masks match the single-device run exactly.

The batch specs come from ``sharding.train_rules()`` (logical ``"batch"``
axis → ``data`` mesh axis); the step itself is a ``shard_map`` whose
interior stays integer-only — ``assert_jaxpr_integer_only`` descends into
the shard_map sub-jaxpr.

CPU-only sessions simulate devices with
``XLA_FLAGS=--xla_force_host_platform_device_count=N`` set **before the
first JAX import** (``launch/train.py --num-devices`` re-execs itself to
guarantee this; the tests use subprocess workers).  See
``docs/PARALLEL.md``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

from repro.core import les
from repro.core import model as M
from repro.parallel import collectives, compress, sharding

DP_AXIS = "data"

#: Valid ``dp_reduce=`` values, in (default-first) order.
REDUCERS = ("psum", "ring", "compress")


def data_mesh(num_devices: int | None = None) -> Mesh:
    """A 1-D ``("data",)`` mesh over ``num_devices`` (default: all).

    Raises with the ``XLA_FLAGS`` recipe when the session has fewer
    devices than asked — the flag only works before JAX initialises, so
    this cannot be fixed from here.
    """
    avail = jax.device_count()
    n = avail if num_devices is None else num_devices
    if n > avail:
        raise ValueError(
            f"data_mesh: asked for {n} devices but this process has {avail}. "
            f"Set XLA_FLAGS=--xla_force_host_platform_device_count={n} in the "
            f"environment *before the first jax import* (launch/train.py "
            f"--num-devices does this via re-exec)."
        )
    from repro.launch.mesh import make_mesh

    return make_mesh((n,), (DP_AXIS,))


def reduce_gradients(grads, axis_name: str, method: str = "psum"):
    """All-reduce an integer gradient pytree over ``axis_name``.

    Every method computes the exact int32 sum over shards — they differ
    only in schedule/wire format, never in the result (test-enforced
    bitwise).  Must be called inside a shard_map (or vmap) binding
    ``axis_name``.
    """
    if method == "psum":
        return compress.exact_integer_psum(grads, axis_name)
    if method == "ring":
        return jax.tree_util.tree_map(
            lambda g: collectives.ring_all_reduce(g, axis_name), grads
        )
    if method == "compress":
        return compress.nitro_compressed_psum(grads, axis_name)
    raise ValueError(
        f"unknown dp_reduce method {method!r}; expected one of {REDUCERS}"
    )


def _reduce_tensor_telemetry(tt, axis_name: str):
    """Shard-local TensorTelemetry → global: counts sum, envelope maxes."""
    return type(tt)(
        bit_hist=jax.lax.psum(tt.bit_hist, axis_name),
        sat_int8=jax.lax.psum(tt.sat_int8, axis_name),
        sat_int32=jax.lax.psum(tt.sat_int32, axis_name),
        max_abs=jax.lax.pmax(tt.max_abs, axis_name),
    )


def _grads_fit_int16(grads, axis_name: str) -> jax.Array:
    """1 iff every shard-local gradient element fits 2 int8 limbs (int16).

    The exactness precondition of running ``dp_reduce="compress"`` at
    ``num_limbs=2`` — evaluated on the *pre-reduce* shard-local
    gradients (the values that would go on the wire) and pmin-ed so
    every shard reports the global verdict.  Integer-only throughout
    (comparisons → int32), so the float-free jaxpr guarantee holds.
    """
    local = jnp.min(jnp.stack([
        compress.fits_limbs(g, 2).astype(jnp.int32)
        for g in jax.tree_util.tree_leaves(grads)
    ]))
    return jax.lax.pmin(local, axis_name)


def _dp_telemetry(cfg, new_state, aux, grads, state, axis_name: str):
    """Telemetry under sharding, bitwise ≡ the single-device readout.

    Weights, reduced gradients and optimiser scalars are replicated —
    their summaries are already global.  ``z_star``/``act`` live in the
    shard-local caches (local batch rows only), so their histograms,
    saturation and dead-unit *counts* psum across shards and ``max_abs``
    pmaxes — exactly the reductions the single-device pass performs over
    the whole batch, reassociated (integer ops: associativity is exact).
    """
    from repro.obs import telemetry as T

    telem = T.collect_train_telemetry(
        cfg, new_state.params, aux.fw_caches,
        [g["fw"] for g in grads.blocks], grads.output,
        state.opt_lr, state.opt_fw,
    )
    for bt in telem["blocks"]:
        bt["z_star"] = _reduce_tensor_telemetry(bt["z_star"], axis_name)
        bt["act"] = _reduce_tensor_telemetry(bt["act"], axis_name)
        bt["dead"] = jax.lax.psum(bt["dead"], axis_name)
    return telem


def dp_train_step(
    state: les.TrainState,
    cfg: M.NitroConfig,
    x: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    *,
    mesh: Mesh,
    dp_reduce: str = "psum",
    fused: bool = True,
    fuse_bwd: bool = True,
    fuse_opt: bool = False,
    backend: str = "auto",
    conv_mode: str = "stream",
    telemetry: bool = False,
):
    """One data-parallel NITRO-D step — ``les.train_step`` over a mesh.

    Same signature/returns as ``les.train_step`` plus ``mesh`` (a 1-D
    ``data`` mesh from ``data_mesh``) and ``dp_reduce`` (see ``REDUCERS``).
    State and key are replicated; ``x``/``labels`` shard on the batch dim
    per ``sharding.train_rules()``.  Inside the shard_map each shard runs
    ``compute_gradients`` on its batch slice, the integer gradients and
    metrics all-reduce exactly, and every shard applies the identical
    IntegerSGD update — so all outputs are replicated and bitwise equal
    to the single-device step on the full batch.

    ``fuse_opt=True`` applies the post-reduce update with the standalone
    fused IntegerSGD kernel (``les.apply_gradients(fuse_opt=True)``) —
    DP cannot use the grad-kernel flush epilogue because the all-reduce
    *needs* the materialised gradient, but the update itself still fuses.
    Bitwise identical, so cross-device-count trajectory identity holds
    with it on or off (test-enforced).

    ``check_rep=False``: the ring reducer is built from ``ppermute``,
    whose per-device results shard_map cannot prove replicated (they are
    — by the all-gather's construction; the tests prove it bitwise).
    """
    if dp_reduce not in REDUCERS:
        raise ValueError(
            f"unknown dp_reduce method {dp_reduce!r}; expected one of {REDUCERS}"
        )
    n = mesh.shape[DP_AXIS]
    if x.shape[0] % n:
        raise ValueError(
            f"dp_train_step: batch {x.shape[0]} not divisible by the "
            f"{DP_AXIS} mesh axis ({n} shards)"
        )
    with sharding.use_rules(mesh, sharding.train_rules()):
        batch_spec = sharding.resolve(("batch",))

    def _body(state, x, labels, key):
        grads, metrics, aux = les.compute_gradients(
            state, cfg, x, labels, key,
            fused=fused, fuse_bwd=fuse_bwd, backend=backend,
            conv_mode=conv_mode, dp_axis=DP_AXIS, dp_shards=n,
        )
        if telemetry:
            # pre-reduce: the shard-local widths are what hit the wire
            fits16 = _grads_fit_int16(grads, DP_AXIS)
        with jax.named_scope("dp/reduce_gradients"):
            grads = reduce_gradients(grads, DP_AXIS, dp_reduce)
        metrics = les.StepMetrics(
            *(jax.lax.psum(m, DP_AXIS) for m in metrics)
        )
        new_state = les.apply_gradients(
            state, grads, fuse_opt=fuse_opt, backend=backend
        )
        if telemetry:
            telem = _dp_telemetry(
                cfg, new_state, aux, grads, state, DP_AXIS
            )
            # topology-scoped extras: excluded from the cross-topology
            # bitwise-identity comparisons (shard count is not a property
            # of the *training trajectory*), surfaced as the `_dp` row
            telem["dp"] = {
                "grad_fits_int16": fits16,
                "shards": jnp.asarray(n, jnp.int32),
            }
            return new_state, metrics, telem
        return new_state, metrics

    sharded = shard_map(
        _body,
        mesh=mesh,
        in_specs=(P(), batch_spec, batch_spec, P()),
        out_specs=P(),
        check_rep=False,
    )
    return sharded(state, x, labels, key)


def make_dp_train_step(
    cfg: M.NitroConfig,
    mesh: Mesh,
    *,
    dp_reduce: str = "psum",
    fused: bool = True,
    fuse_bwd: bool = True,
    fuse_opt: bool = False,
    backend: str = "auto",
    conv_mode: str = "stream",
    telemetry: bool = False,
):
    """jit-compiled ``dp_train_step`` closure over (cfg, mesh, knobs) —
    the DP analogue of ``jax.jit(partial(les.train_step, cfg=cfg))``."""

    def step(state, x, labels, key):
        return dp_train_step(
            state, cfg, x, labels, key,
            mesh=mesh, dp_reduce=dp_reduce, fused=fused, fuse_bwd=fuse_bwd,
            fuse_opt=fuse_opt, backend=backend, conv_mode=conv_mode,
            telemetry=telemetry,
        )

    return jax.jit(step)
