"""Logical-axis sharding: one rule table maps model-space axis names to mesh
axes, so changing the parallelism strategy is a dict edit, not a model edit.

Models annotate tensors with *logical* axes (``"batch"``, ``"embed"``,
``"heads"``, ``"mlp"``, ``"kv_seq"``, ``"expert"``, …).  A ``ShardingRules``
context installed by the launcher resolves those names against the active
mesh.  Outside any context every annotation is a no-op, so the same model
code runs on 1 CPU device (tests) and on a 512-chip multi-pod mesh
(dry-run/production) unchanged.

Rule sets provided:

  * ``train_rules``  — DP×TP with FSDP-style weight sharding: the TP dim of
    every weight goes to ``model``, the other dim to ``data`` (ZeRO-3-like
    storage; XLA inserts the gather), batch to ``("pod", "data")``.
  * ``serve_rules``  — TP-only weights (replicated over ``data``; no
    optimiser state at inference), batch to ``("pod", "data")``,
    KV-cache heads to ``model``.
"""

from __future__ import annotations

import threading
from contextlib import contextmanager
from typing import Any, Mapping, Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_state = threading.local()


def _current() -> tuple[Mesh, Mapping[str, Any]] | None:
    return getattr(_state, "active", None)


@contextmanager
def use_rules(mesh: Mesh, rules: Mapping[str, Any]):
    """Install (mesh, logical→mesh rules) for the enclosed region."""
    prev = _current()
    _state.active = (mesh, rules)
    try:
        yield
    finally:
        _state.active = prev


def resolve(axes: Sequence[str | None]) -> P:
    """Translate logical axis names to a PartitionSpec under active rules."""
    ctx = _current()
    if ctx is None:
        return P()
    _, rules = ctx
    return P(*[rules.get(a) if a is not None else None for a in axes])


def shard(x: jax.Array, *axes: str | None) -> jax.Array:
    """Annotate ``x`` with logical axes; no-op without an active context."""
    ctx = _current()
    if ctx is None:
        return x
    mesh, _ = ctx
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, resolve(axes))
    )


def named_sharding(mesh: Mesh, rules: Mapping[str, Any], axes: Sequence[str | None]) -> NamedSharding:
    spec = P(*[rules.get(a) if a is not None else None for a in axes])
    return NamedSharding(mesh, spec)


def _is_axes_tuple(x) -> bool:
    """A leaf is a tuple of axis names — NOT a NamedTuple of such tuples
    (caches are NamedTuples whose *fields* are the leaves)."""
    return isinstance(x, tuple) and all(
        isinstance(e, (str, type(None))) for e in x
    )


def tree_shardings(mesh: Mesh, rules: Mapping[str, Any], logical_tree):
    """Map a pytree of logical-axis tuples to a pytree of NamedShardings."""
    return jax.tree_util.tree_map(
        lambda axes: named_sharding(mesh, rules, axes),
        logical_tree,
        is_leaf=_is_axes_tuple,
    )


# ---------------------------------------------------------------------------
# Rule tables.  Only ``"batch"`` is exercised by the wired NITRO-D
# data-parallel path (``repro.parallel.dp``: batch → ``data`` mesh axis);
# the rest cover the generic transformer axes the scaffolding was built
# against and future TP/FSDP experiments.  ``pod`` collapses automatically
# on single-pod meshes: rules reference only axis names present in the mesh.
# ---------------------------------------------------------------------------


def train_rules(multi_pod: bool = False) -> dict[str, Any]:
    batch = ("pod", "data") if multi_pod else ("data",)
    return {
        # activations
        "batch": batch,
        "seq": None,
        "seq_sp": "model",       # sequence-parallel segments between blocks
        "embed": None,
        "heads": "model",
        # KV heads < 16 on most GQA archs: weights/activations replicated
        # (Megatron KV duplication); the *stored cache* is duplicated
        # kv_repeat× to exactly 16 and shards on its own axis below.
        "kv_heads": None,
        "kv_cache_heads": "model",
        "mlp": "model",
        "vocab": "model",
        "expert": "model",        # EP archs (olmoe); overridden to None for TP-MoE
        "mlp_expert": None,       # TP-MoE archs (mixtral) override to "model"
        "expert_cap": "data",     # MoE dispatch-buffer capacity dim
        # parameters: TP dim → model, FSDP storage dim → data
        "p_embed": "data",
        "p_vocab": "model",
        "p_heads": "model",
        "p_kv_heads": None,
        "p_mlp": "model",
        "p_expert": "model",
        "p_mlp_expert": None,
        "p_rnn": "model",
        "p_rnn_block": "model",
        "p_fsdp": "data",
        # recurrent / conv states
        "rnn": "model",
        "kv_seq": None,
        "stack": None,           # scan-stacked layer dim — never sharded
    }


def serve_rules(multi_pod: bool = False) -> dict[str, Any]:
    rules = train_rules(multi_pod)
    rules.update({
        "p_embed": None,   # weights TP-only at inference (replicated on data)
        "p_fsdp": None,
        "seq_sp": None,
    })
    return rules
