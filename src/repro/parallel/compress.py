"""Gradient compression for cross-pod (DCN) all-reduce.

Two regimes (DESIGN.md §5):

  * **FP path** — int8 quantisation against a per-tensor power-of-two scale
    with an error-feedback residual: the quantisation error of step *t* is
    added back into the gradient at step *t+1*, so the compression bias
    vanishes in expectation (standard EF-SGD).  4× less DCN traffic.

  * **NITRO path** — the paper's gradients are *already integers*: cross-pod
    reduction is exact int32 summation.  No compression error exists, and
    data-parallel training is bit-reproducible regardless of reduction
    order (integer addition is associative).  This is a genuine systems
    advantage of integer-only training at scale and is exercised by the
    multi-pod LES trainer.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class EFState(NamedTuple):
    """Error-feedback residual, same pytree structure as the gradients."""

    residual: dict


def ef_init(grads) -> EFState:
    return EFState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )
    )


def _quantize_one(g: jax.Array, r: jax.Array):
    """(int8 payload, pow2 scale, new residual) for one tensor."""
    gf = g.astype(jnp.float32) + r
    amax = jnp.max(jnp.abs(gf))
    shift = jnp.ceil(jnp.log2(jnp.maximum(amax / 127.0, 1e-30)))
    scale = jnp.exp2(shift)
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    new_r = gf - q * scale
    return q.astype(jnp.int8), scale, new_r


def compress(grads, ef: EFState):
    """Quantise a gradient pytree to (int8, scale) pairs + new EF state."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(ef.residual)
    qs, scales, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = _quantize_one(g, r)
        qs.append(q)
        scales.append(s)
        rs.append(nr)
    return (
        jax.tree_util.tree_unflatten(treedef, qs),
        jax.tree_util.tree_unflatten(treedef, scales),
        EFState(residual=jax.tree_util.tree_unflatten(treedef, rs)),
    )


def decompress(qgrads, scales):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, qgrads, scales
    )


def compressed_psum(grads, ef: EFState, axis_name: str):
    """EF-int8 all-reduce over ``axis_name`` (inside shard_map/pmap).

    int8 payloads are summed in int32 (no overflow for ≤ 2^24 replicas);
    per-tensor scales are maxed so every replica dequantises consistently.
    """
    q, s, ef = compress(grads, ef)
    s_max = jax.tree_util.tree_map(
        lambda x: jax.lax.pmax(x, axis_name), s
    )
    # requantise against the global scale so payload sums are consistent
    q = jax.tree_util.tree_map(
        lambda qq, ss, sm: jnp.clip(
            jnp.round(qq.astype(jnp.float32) * ss / sm), -127, 127
        ).astype(jnp.int32),
        q, s, s_max,
    )
    summed = jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x, axis_name), q
    )
    return decompress(summed, s_max), ef


def exact_integer_psum(int_grads, axis_name: str):
    """NITRO path: int32 gradients sum exactly; bit-reproducible DP."""
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g, axis_name), int_grads
    )
