"""Gradient compression for the data-parallel all-reduce.

Two regimes, matching the two kinds of gradient this repo ever ships
across a ``data`` mesh axis (see ``docs/PARALLEL.md``):

  * **NITRO path** — NITRO-D's gradients are *already int32*: cross-device
    reduction is exact integer summation, so data-parallel training is
    bit-reproducible regardless of reduction order (integer addition is
    associative and commutative).  ``exact_integer_psum`` is the plain
    XLA all-reduce; ``nitro_compressed_psum`` is the same exact sum over
    an **int8-limb wire format**: each int32 element is decomposed into
    ``num_limbs`` base-256 digits carried as int8 payloads, the limb
    planes are summed with int32 carry headroom (safe for ≤ 2²⁴
    replicas), and the per-limb sums recombine to the bit-exact int32
    total.  ``num_limbs=4`` encodes any int32 (same bytes as int32 —
    the win is an int8 wire dtype for links with faster int8
    collectives); ``num_limbs=2`` halves the payload and is exact
    whenever every gradient element fits int16 — precisely the bound the
    ``repro.obs`` bit-occupancy telemetry measures per layer.  Both are
    property-tested for exactness and order-invariance.

  * **FP path** — for *float* gradients (the LM trainer; kept as the
    comparison baseline): int8 quantisation against a per-tensor
    power-of-two scale with an error-feedback residual — the quantisation
    error of step *t* is added back into the gradient at step *t+1*, so
    the compression bias vanishes in expectation (standard EF-SGD).
    4× less wire traffic, but *approximate*: this path can never be
    bitwise-deterministic, which is exactly the contrast the NITRO path
    exists to demonstrate.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# NITRO path: exact integer reduction (int32, or int8-limb wire format)
# ---------------------------------------------------------------------------

_LIMB_BITS = 8
_LIMB_BASE = 1 << _LIMB_BITS  # 256
_LIMB_BIAS = 128              # maps an unsigned digit 0..255 onto int8


def exact_integer_psum(int_grads, axis_name: str):
    """NITRO path: int32 gradients sum exactly; bit-reproducible DP."""
    return jax.tree_util.tree_map(
        lambda g: jax.lax.psum(g, axis_name), int_grads
    )


def pack_int8_limbs(g: jax.Array, num_limbs: int = 4) -> jax.Array:
    """int32 tensor → ``(num_limbs, *shape)`` int8 limb planes.

    Little-endian base-256 digits: low limbs are unsigned digits biased
    by −128 onto the int8 range; the top limb is the *arithmetic* shift
    remainder (sign-carrying, stored unbiased).  Exact round trip iff
    every element fits ``8·num_limbs`` signed bits — always true for
    ``num_limbs=4``; for fewer limbs it is the caller's contract (checked
    by ``fits_limbs``, measured per layer by the obs bit telemetry).
    """
    if not (1 <= num_limbs <= 4):
        raise ValueError(f"num_limbs must be in 1..4, got {num_limbs}")
    g = g.astype(jnp.int32)
    limbs = [
        ((g >> (_LIMB_BITS * k)) & (_LIMB_BASE - 1)) - _LIMB_BIAS
        for k in range(num_limbs - 1)
    ]
    limbs.append(g >> (_LIMB_BITS * (num_limbs - 1)))  # signed top limb
    return jnp.stack(limbs).astype(jnp.int8)


def unpack_limb_sums(limb_sums: jax.Array, num_shards: int) -> jax.Array:
    """Recombine per-limb int32 *sums* into the summed int32 tensor.

    ``limb_sums[k]`` is Σ over shards of the (biased) int8 limb *k*,
    accumulated in int32.  Linearity gives Σg = Σ_k 256^k·(limb-plane-k
    sum, bias restored); intermediate products may wrap mod 2³², which is
    harmless — int32 addition is exact mod 2³², and the true total fits
    int32 (the same no-overflow contract plain ``psum`` has).
    """
    num_limbs = limb_sums.shape[0]
    total = jnp.zeros_like(limb_sums[0])
    for k in range(num_limbs - 1):
        unbiased = limb_sums[k] + num_shards * _LIMB_BIAS
        total = total + (unbiased << (_LIMB_BITS * k))
    total = total + (limb_sums[num_limbs - 1] << (_LIMB_BITS * (num_limbs - 1)))
    return total


def fits_limbs(g: jax.Array, num_limbs: int) -> jax.Array:
    """Scalar bool: every element representable in ``8·num_limbs`` signed
    bits (the exactness precondition of a truncated-limb encoding)."""
    bound = 1 << (_LIMB_BITS * num_limbs - 1)
    g = g.astype(jnp.int32)
    return jnp.all((g >= -bound) & (g <= bound - 1))


def nitro_compressed_psum(int_grads, axis_name: str, *, num_limbs: int = 4):
    """Exact all-reduce of an int32 gradient pytree over int8 payloads.

    Per tensor: pack into int8 limb planes (the wire payload), lift each
    plane to int32 (carry headroom: 255·N ≪ 2³¹ for any real N), psum the
    planes, recombine.  Bitwise ≡ ``exact_integer_psum`` whenever every
    local element fits ``8·num_limbs`` signed bits — unconditionally for
    the default ``num_limbs=4``.  Unlike the EF float path there is no
    residual state to carry: the encoding is lossless, so compression
    composes with bitwise-deterministic data parallelism.
    """
    n = None

    def reduce_one(g: jax.Array) -> jax.Array:
        nonlocal n
        limbs = pack_int8_limbs(g, num_limbs)          # int8 on the wire
        lifted = limbs.astype(jnp.int32)
        summed = jax.lax.psum(lifted, axis_name)
        if n is None:
            from repro.parallel.collectives import axis_size

            n = axis_size(axis_name)
        return unpack_limb_sums(summed, n).astype(g.dtype)

    return jax.tree_util.tree_map(reduce_one, int_grads)


# ---------------------------------------------------------------------------
# FP path: EF-int8 quantisation (float gradients only — approximate)
# ---------------------------------------------------------------------------


class EFState(NamedTuple):
    """Error-feedback residual, same pytree structure as the gradients."""

    residual: dict


def ef_init(grads) -> EFState:
    return EFState(
        residual=jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads
        )
    )


def _quantize_one(g: jax.Array, r: jax.Array):
    """(int8 payload, pow2 scale, new residual) for one tensor."""
    gf = g.astype(jnp.float32) + r
    amax = jnp.max(jnp.abs(gf))
    shift = jnp.ceil(jnp.log2(jnp.maximum(amax / 127.0, 1e-30)))
    # ldexp, not exp2: XLA's exp2 approximation can land one ulp *below*
    # 2^k, which silently breaks the exactly-representable-scale property
    # (caught by the pow2 hypothesis test).
    scale = jnp.ldexp(jnp.float32(1.0), shift.astype(jnp.int32))
    q = jnp.clip(jnp.round(gf / scale), -127, 127)
    new_r = gf - q * scale
    return q.astype(jnp.int8), scale, new_r


def compress(grads, ef: EFState):
    """Quantise a gradient pytree to (int8, scale) pairs + new EF state."""
    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_r = jax.tree_util.tree_leaves(ef.residual)
    qs, scales, rs = [], [], []
    for g, r in zip(flat_g, flat_r):
        q, s, nr = _quantize_one(g, r)
        qs.append(q)
        scales.append(s)
        rs.append(nr)
    return (
        jax.tree_util.tree_unflatten(treedef, qs),
        jax.tree_util.tree_unflatten(treedef, scales),
        EFState(residual=jax.tree_util.tree_unflatten(treedef, rs)),
    )


def decompress(qgrads, scales):
    return jax.tree_util.tree_map(
        lambda q, s: q.astype(jnp.float32) * s, qgrads, scales
    )


def compressed_psum(grads, ef: EFState, axis_name: str):
    """EF-int8 all-reduce over ``axis_name`` (inside shard_map/pmap).

    int8 payloads are summed in int32 (no overflow for ≤ 2^24 replicas);
    per-tensor scales are maxed so every replica dequantises consistently.
    """
    q, s, ef = compress(grads, ef)
    s_max = jax.tree_util.tree_map(
        lambda x: jax.lax.pmax(x, axis_name), s
    )
    # requantise against the global scale so payload sums are consistent
    q = jax.tree_util.tree_map(
        lambda qq, ss, sm: jnp.clip(
            jnp.round(qq.astype(jnp.float32) * ss / sm), -127, 127
        ).astype(jnp.int32),
        q, s, s_max,
    )
    summed = jax.tree_util.tree_map(
        lambda x: jax.lax.psum(x, axis_name), q
    )
    return decompress(summed, s_max), ef
