"""Hand-scheduled collectives for compute/communication overlap.

XLA schedules its own all-reduces, but a *chunked ring* built from
``ppermute`` exposes the schedule to the compiler as N independent steps,
letting gradient synchronisation of layer *l* overlap the backward compute
of layer *l−1* (the classic Horovod-style overlap, expressed in
shard_map).  For NITRO-D the payloads are **int32 gradients**: integer
addition is associative, so the ring produces the *bitwise-identical*
result to ``psum`` at any device count — the data-parallel suite
(``tests/test_data_parallel.py``) enforces ring ≡ psum ≡ single-device as
an equality, not a tolerance.  Algorithms:

  * ``ring_all_reduce``      — reduce-scatter ring + all-gather ring,
    2·(N−1)/N · bytes on the wire per chip (bandwidth-optimal).
  * ``ring_reduce_scatter``  — first half only; rank *r* ends holding
    reduced chunk *r*, which composes with FSDP-style sharded optimisers
    (each chip updates its own shard) and with the by-rank
    ``ring_all_gather``.

Both operate on one tensor *inside* an active shard_map over ``axis_name``
(``jax.vmap(..., axis_name=...)`` also works and is how the unit tests
exercise N > 1 semantics without devices).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def axis_size(axis_name: str) -> int:
    """jax.lax.axis_size where available (jax ≥ 0.5); psum(1) fallback.

    Public version-compat shim — the ring schedules below and
    ``pipeline.py`` need the named-axis extent as a *static* int (it
    determines trip counts and permutations); use this, not jax.lax
    directly.
    """
    if hasattr(jax.lax, "axis_size"):
        return jax.lax.axis_size(axis_name)
    return int(jax.lax.psum(1, axis_name))


def _ring_perm(n: int):
    return [(i, (i + 1) % n) for i in range(n)]


def ring_reduce_scatter(x: jax.Array, axis_name: str) -> jax.Array:
    """Reduce-scatter via an (N−1)-step ppermute ring.

    x: identical-shape local tensor on every rank, first dim divisible by N.
    Returns this rank's reduced chunk (shape x.shape with dim0 / N): rank
    *r* holds chunk *r* — Σ over ranks of everyone's r-th chunk.

    Schedule: at step *i* rank *r* forwards slot ``r−1−i`` (which has
    accumulated ``i+1`` contributions) one hop down the ring and adds the
    incoming piece into slot ``r−2−i``; after N−1 steps slot *r* is the
    last one written and carries all N contributions.  (A schedule that
    ends with slot *r+1* complete — the other textbook variant — would
    break the by-rank reassembly in ``ring_all_reduce``.)
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    if x.shape[0] % n:
        raise ValueError(
            f"ring_reduce_scatter: leading dim {x.shape[0]} not divisible "
            f"by ring size {n}; pad first (ring_all_reduce does)"
        )
    idx = jax.lax.axis_index(axis_name)
    chunks = jnp.stack(jnp.split(x, n, axis=0))      # (N, chunk, ...)

    # unrolled loop: each step is an independent HLO op → overlappable
    acc = chunks
    for i in range(n - 1):
        send_slot = (idx - 1 - i) % n
        piece = jnp.take(acc, send_slot, axis=0, mode="wrap")
        piece = jax.lax.ppermute(piece, axis_name, _ring_perm(n))
        recv_slot = (idx - 2 - i) % n
        acc = acc.at[recv_slot].add(piece)
    return jnp.take(acc, idx, axis=0, mode="wrap")


def ring_all_gather(x: jax.Array, axis_name: str) -> jax.Array:
    """All-gather via an (N−1)-step ppermute ring; concatenates on dim0
    in rank order (rank r's tensor occupies rows [r·len, (r+1)·len))."""
    n = axis_size(axis_name)
    if n == 1:
        return x
    idx = jax.lax.axis_index(axis_name)
    out = jnp.zeros((n, *x.shape), x.dtype)
    out = out.at[idx].set(x)
    piece = x
    for i in range(n - 1):
        piece = jax.lax.ppermute(piece, axis_name, _ring_perm(n))
        src = (idx - i - 1) % n
        out = out.at[src].set(piece)
    return out.reshape(n * x.shape[0], *x.shape[1:])


def ring_all_reduce(x: jax.Array, axis_name: str) -> jax.Array:
    """Bandwidth-optimal ring all-reduce (reduce-scatter + all-gather).

    Pads dim0 up to a multiple of N (zero rows — additively inert), so any
    tensor shape reduces; bitwise ≡ ``psum`` for integer dtypes.
    """
    n = axis_size(axis_name)
    if n == 1:
        return x
    pad = (-x.shape[0]) % n
    xp = jnp.pad(x, [(0, pad)] + [(0, 0)] * (x.ndim - 1)) if pad else x
    reduced = ring_reduce_scatter(xp, axis_name)
    full = ring_all_gather(reduced, axis_name)
    return full[: x.shape[0]] if pad else full
