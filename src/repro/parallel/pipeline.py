"""Pipeline-parallel scaffolding — *not* wired into NITRO-D training.

NITRO-D has no inter-block gradient flow, so its natural model
parallelism is block-per-device LES (each local-loss block trains
independently), not pipelining — and the paper-scale CNNs (VGG11B is
< 40M params) fit a single device anyway.  Data parallelism is the wired
path (``repro.parallel.dp``).  This module keeps the generic GPipe-style
schedule machinery — a microbatched loop expressed with ``ppermute`` hops
between stage shards — so a future ``"stage"`` mesh axis (e.g. for a
block-pipelined LES variant) drops in without touching model code.

``pipeline_apply`` is backend-agnostic: with one stage it degrades to a
sequential scan over microbatches (unit-tested path); with S stages inside
a shard_map over the stage axis, each step computes the local stage and
permutes activations one hop down the ring — the standard bubble of
(S−1)/(M+S−1) applies.
"""

from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.parallel.collectives import axis_size


def split_microbatches(batch: jax.Array, num_micro: int) -> jax.Array:
    """(B, ...) → (M, B/M, ...)."""
    b = batch.shape[0]
    assert b % num_micro == 0, (b, num_micro)
    return batch.reshape(num_micro, b // num_micro, *batch.shape[1:])


def pipeline_apply(
    stage_fn: Callable[[int, jax.Array], jax.Array],
    x: jax.Array,
    *,
    num_stages: int,
    num_micro: int,
    axis_name: str | None = None,
) -> jax.Array:
    """Run ``num_stages`` sequential stage applications over microbatches.

    stage_fn(stage_idx, micro) → micro'.  Without ``axis_name`` (no stage
    axis in the mesh) this is the sequential reference schedule: correct
    semantics, zero parallelism — used by tests and as the fallback.  With
    ``axis_name`` inside shard_map, each rank applies its own stage and
    ppermutes the activation ring one hop per step (GPipe forward).
    """
    micros = split_microbatches(x, num_micro)

    if axis_name is None:
        def run_one(micro):
            for s in range(num_stages):
                micro = stage_fn(s, micro)
            return micro

        return jax.lax.map(run_one, micros).reshape(x.shape[0], *micros.shape[2:])

    # stage-axis schedule: S + M - 1 ticks, each rank active when its
    # stage has a microbatch in flight
    stage = jax.lax.axis_index(axis_name)
    n = axis_size(axis_name)
    perm = [(i, (i + 1) % n) for i in range(n)]

    m, mb = micros.shape[0], micros.shape[1]
    buf = jnp.zeros_like(micros[0])
    outs = jnp.zeros_like(micros)

    def tick(carry, t):
        buf, outs = carry
        # feed a new microbatch into stage 0 while any remain
        feed = jnp.where(
            (stage == 0) & (t < m),
            micros[jnp.minimum(t, m - 1)],
            buf,
        )
        worked = stage_fn(0, feed) if n == 1 else stage_fn(int(0), feed)  # noqa: B023
        # NOTE: per-rank stage_fn dispatch requires stage-indexed params
        # (stacked weights sliced by axis_index) — the caller's stage_fn
        # closes over them; here we only schedule.
        out_t = t - (n - 1)
        outs = jnp.where(
            (stage == n - 1) & (out_t >= 0) & (out_t < m),
            outs.at[jnp.clip(out_t, 0, m - 1)].set(worked),
            outs,
        )
        nxt = jax.lax.ppermute(worked, axis_name, perm)
        return (nxt, outs), None

    (buf, outs), _ = jax.lax.scan(tick, (buf, outs), jnp.arange(m + n - 1))
    return outs.reshape(x.shape[0], *micros.shape[2:])


def bubble_fraction(num_stages: int, num_micro: int) -> float:
    """GPipe bubble: (S−1) / (M + S − 1)."""
    return (num_stages - 1) / (num_micro + num_stages - 1)
