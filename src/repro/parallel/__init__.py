"""Distribution substrate for integer training.

``dp`` is the wired path: data-parallel ``les.train_step`` over a
``data`` mesh axis, bitwise-identical to single-device at any device
count (integer gradients sum exactly).  ``sharding`` maps logical axis
names to mesh axes, ``collectives`` provides the hand-scheduled ring
all-reduce, ``compress`` the exact int8-limb wire format (plus the
approximate EF path for float gradients).  ``pipeline`` is unwired
GPipe scaffolding.  See ``docs/PARALLEL.md``.
"""
