"""Distribution substrate: logical-axis sharding, collectives, compression."""
