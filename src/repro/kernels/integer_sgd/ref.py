"""Pure-jnp oracle for the fused IntegerSGD kernel — delegates to the
canonical Algorithm-1 implementation in ``repro.core.optimizer``."""

from __future__ import annotations

import jax

from repro.core import optimizer as opt


def integer_sgd_ref(
    w: jax.Array, g: jax.Array, gamma_inv, eta_inv
) -> jax.Array:
    state = opt.IntegerSGDState(
        gamma_inv=jax.numpy.asarray(gamma_inv, jax.numpy.int32),
        eta_inv=jax.numpy.asarray(eta_inv, jax.numpy.int32),
    )
    return opt.apply_update(w, g, state)
