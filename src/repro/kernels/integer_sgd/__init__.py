from repro.kernels.integer_sgd.integer_sgd import integer_sgd_update
from repro.kernels.integer_sgd.ops import apply_tree_fused
from repro.kernels.integer_sgd.ref import integer_sgd_ref

__all__ = ["integer_sgd_update", "integer_sgd_ref", "apply_tree_fused"]
