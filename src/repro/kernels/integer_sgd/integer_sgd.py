"""Fused IntegerSGD update Pallas kernel (paper Algorithm 1).

    W ← W − ( ⌊g/γ_inv⌋ + ⌊W/η_inv⌋ )

A memory-bound elementwise op: the fused kernel reads W and g once and
writes W once (3 HBM streams), where the naive lowering materialises the
two floor-division temporaries (5 streams) — a 1.67× traffic cut on the
optimiser step, which at LES's per-block update frequency is a measurable
slice of the training step's memory term.

γ_inv/η_inv arrive as scalars in SMEM so one compiled kernel serves every
(layer-group, schedule-step) combination — the lr schedule (γ_inv ×3 on
plateau) changes no executable.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

LANE = 128
DEFAULT_BLOCK_ROWS = 8  # (8, 128) native int32 VREG tile

# jax renamed TPUCompilerParams → CompilerParams; support both.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)


def integer_sgd_tile(w, g, gamma_inv, eta_inv):
    """One IntegerSGD step on in-register values — the shared epilogue body.

    Used both by the standalone kernel below and by the grad-kernel flush
    epilogues (``nitro_matmul._nitro_grad_w_opt_kernel``,
    ``nitro_conv._stream_grad_w_opt_kernel``), so fused ≡ standalone ≡ ref
    is one expression, not three. η_inv == 0 disables decay; floor division
    rounds toward −∞ (see ``core.optimizer.apply_update`` for the
    negative-weight asymmetry this implies).
    """
    delta = jnp.floor_divide(g, gamma_inv)
    decay = jnp.where(
        eta_inv != 0,
        jnp.floor_divide(w, jnp.maximum(eta_inv, 1)),
        jnp.zeros_like(w),
    )
    return w - (delta + decay)


def _integer_sgd_kernel(scalars_ref, w_ref, g_ref, out_ref):
    """scalars = [γ_inv, η_inv]; η_inv == 0 disables decay."""
    out_ref[...] = integer_sgd_tile(
        w_ref[...], g_ref[...], scalars_ref[0], scalars_ref[1]
    )


@functools.partial(jax.jit, static_argnames=("block_rows", "interpret"))
def integer_sgd_update(
    w: jax.Array,
    g: jax.Array,
    gamma_inv: jax.Array,
    eta_inv: jax.Array,
    *,
    block_rows: int = DEFAULT_BLOCK_ROWS,
    interpret: bool = False,
) -> jax.Array:
    """Apply one IntegerSGD step to a tensor of any shape.

    Flattens to (rows, 128) VPU lanes, pads the ragged tail, runs the fused
    kernel over a 1-D grid, and restores the original shape.
    """
    shape = w.shape
    n = w.size
    rows = -(-n // LANE)  # ceil
    pad = rows * LANE - n
    wf = jnp.pad(w.reshape(-1), (0, pad)).reshape(rows, LANE)
    gf = jnp.pad(g.reshape(-1), (0, pad)).reshape(rows, LANE)

    br = min(block_rows, rows)
    grid_rows = -(-rows // br)
    if grid_rows * br != rows:  # pad rows to a block multiple
        extra = grid_rows * br - rows
        wf = jnp.pad(wf, ((0, extra), (0, 0)))
        gf = jnp.pad(gf, ((0, extra), (0, 0)))

    scalars = jnp.stack(
        [jnp.asarray(gamma_inv, jnp.int32), jnp.asarray(eta_inv, jnp.int32)]
    )
    out = pl.pallas_call(
        _integer_sgd_kernel,
        grid=(grid_rows,),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
            pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((br, LANE), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(wf.shape, w.dtype),
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel",),
        ),
        interpret=interpret,
    )(scalars, wf, gf)
    return out.reshape(-1)[:n].reshape(shape)
