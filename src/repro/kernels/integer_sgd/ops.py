"""Jit'd wrapper: apply the fused IntegerSGD kernel across parameter trees.

The dispatcher mirrors ``nitro_matmul.ops``: ``backend=`` is the modern
knob (``pallas | interpret | reference | auto``); the historical
``use_kernel``/``interpret`` pair is kept as a deprecated alias with the
same contradictory-flag hardening ``_legacy_backend`` got in PR 5 —
``use_kernel=False`` + ``interpret=True`` raises instead of silently
dropping the interpreter request, and an explicit ``interpret=True`` with
``use_kernel`` unset selects the interpreter off-TPU instead of being
ignored.
"""

from __future__ import annotations

import warnings

import jax

from repro.core import numerics
from repro.core import optimizer as opt
from repro.kernels.integer_sgd.integer_sgd import integer_sgd_update
from repro.kernels.integer_sgd.ref import integer_sgd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _resolve(
    backend: str | None, use_kernel: bool | None, interpret: bool | None
) -> str:
    """Backend string from either the modern or the legacy knobs."""
    if backend is not None:
        if use_kernel is not None or interpret is not None:
            raise ValueError(
                "pass either backend= or the legacy use_kernel/interpret "
                "knobs, not both"
            )
        # lazy import: nitro_matmul.ops module-imports this package's
        # sibling kernels — resolving at call time keeps the import DAG
        # acyclic (see nitro_matmul.nitro_matmul's integer_sgd_tile import)
        from repro.kernels.nitro_matmul.ops import resolve_backend

        return resolve_backend(backend)
    if use_kernel is not None or interpret is not None:
        warnings.warn(
            "use_kernel/interpret are deprecated; use backend="
            "'pallas'|'interpret'|'reference'|'auto' instead",
            DeprecationWarning,
            stacklevel=3,
        )
    if use_kernel is False and interpret:
        raise ValueError(
            "contradictory legacy knobs: use_kernel=False disables the "
            "kernel but interpret=True requests the Pallas interpreter; "
            "pass backend='reference' or backend='interpret' instead"
        )
    if use_kernel is None:
        use_kernel = _on_tpu() or bool(interpret)
    if not use_kernel:
        return "reference"
    if interpret is None:
        interpret = not _on_tpu()
    return "interpret" if interpret else "pallas"


def apply_tree_fused(
    params, grads, state: opt.IntegerSGDState, *,
    backend: str | None = None,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """Drop-in replacement for ``optimizer.apply_tree`` using the kernel.

    Validates every leaf the way the jnp path (``opt.apply_update``) does
    — float leaves fail loudly here, not as silent float arithmetic inside
    a kernel whose contract is integer-only.
    """
    resolved = _resolve(backend, use_kernel, interpret)
    jax.tree_util.tree_map(
        lambda w: numerics.assert_int(w, "integer_sgd weight"), params
    )
    jax.tree_util.tree_map(
        lambda g: numerics.assert_int(g, "integer_sgd gradient"), grads
    )
    if resolved == "reference":
        return jax.tree_util.tree_map(
            lambda w, g: integer_sgd_ref(w, g, state.gamma_inv, state.eta_inv),
            params, grads,
        )
    return jax.tree_util.tree_map(
        lambda w, g: integer_sgd_update(
            w, g, state.gamma_inv, state.eta_inv,
            interpret=(resolved == "interpret"),
        ),
        params, grads,
    )
