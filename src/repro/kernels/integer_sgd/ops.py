"""Jit'd wrapper: apply the fused IntegerSGD kernel across parameter trees."""

from __future__ import annotations

import jax

from repro.core import optimizer as opt
from repro.kernels.integer_sgd.integer_sgd import integer_sgd_update
from repro.kernels.integer_sgd.ref import integer_sgd_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def apply_tree_fused(
    params, grads, state: opt.IntegerSGDState, *, use_kernel: bool | None = None,
    interpret: bool | None = None,
):
    """Drop-in replacement for ``optimizer.apply_tree`` using the kernel."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return jax.tree_util.tree_map(
            lambda w, g: integer_sgd_ref(w, g, state.gamma_inv, state.eta_inv),
            params, grads,
        )
    interp = (not _on_tpu()) if interpret is None else interpret
    return jax.tree_util.tree_map(
        lambda w, g: integer_sgd_update(
            w, g, state.gamma_inv, state.eta_inv, interpret=interp
        ),
        params, grads,
    )
