"""Pallas TPU kernels for the paper's compute hot-spots.

nitro_matmul/  fused int8 x int8 -> int32 matmul + NITRO scaling +
               NITRO-ReLU (one MXU+VPU pass; 5x less HBM traffic on the
               pre-activation tensor than the unfused reference), plus the
               true backward kernels nitro_matmul_grad_w / grad_x whose
               VMEM *prologue* applies the NITRO-ReLU derivative + STE to
               the incoming delta tiles before the gradient matmuls
nitro_conv/    streaming implicit-im2col conv: row bands DMA'd into a
               VMEM ring, patch blocks formed in-kernel (never the
               (N*H*W, K^2*C) HBM patch matrix; ~K^2 less input traffic),
               same scale/ReLU epilogue + optional fused 2x2 maxpool;
               conv fwd, training fwd (a, z*), and both conv gradients
               with the same fused ReLU-bwd delta prologue
integer_sgd/   fused IntegerSGD update (Algorithm 1; 3 HBM streams vs 5)
grad_ops.py    the unified backward dispatcher: linear_grads/conv_grads
               own the ReLU-bwd/STE step (fuse_bwd=True folds it into the
               kernel prologues; False is the unfused jnp escape hatch) —
               core.layers.{linear,conv}_backward and
               core.blocks.forward_layers_backward all route through it

Each package: <name>.py (pl.pallas_call + BlockSpec), ops.py (jit'd
wrapper; interpret mode on CPU), ref.py (pure-jnp oracle).  Attention is
deliberately NOT a kernel: the roofline reads FLOPs from the compiled HLO
and custom calls are opaque to the cost model (models/attention.py).
"""
