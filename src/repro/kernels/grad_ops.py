"""Unified backward dispatcher for the integer gradient paths.

Every hand-derived backward in ``repro.core`` funnels through this module,
the backward mirror of PR 2's forward dispatchers:

  * ``linear_grads`` — the two gradient matmuls of an IntegerLinear layer;
  * ``conv_grads``   — the two conv gradients (streamed or materialised);
  * ``linear_weight_update`` / ``conv_weight_update`` — grad_x plus the
    *updated weight*: the IntegerSGD step is applied in the grad_W
    kernels' flush (``fuse_opt``), so grad_W never materialises in HBM
    when its only consumer is the optimiser.

Both take the *raw* block gradient δ (after the jnp dropout/pool
backwards, which stay outside the kernels) plus the cached pre-ReLU
``z_star``, and own the NITRO-ReLU-derivative + scaling-STE step that
precedes the gradient matmuls:

``fuse_bwd=True`` (default)
    the ReLU-bwd/STE runs as a *prologue inside* the gradient kernels —
    each δ tile/band is masked in VMEM just before it enters the MXU, so
    the full-size post-ReLU-bwd δ tensor never round-trips through HBM
    (on the reference backend the oracle composes the same ops in jnp).

``fuse_bwd=False``
    the unfused escape hatch: ``activations.nitro_relu_backward`` +
    ``scaling.scale_backward`` materialise the masked δ, then the plain
    integer matmuls run — the historical composition, kept as the oracle.

``z_star=None`` selects the no-activation backward (learning/output
layers: scaling STE only, which is the identity) — plain integer matmuls
on any backend.  All combinations are bit-identical; the test-suite's
shared parity harness (``tests/_gradcheck.py``) sweeps them.

Backend vocabulary is ``nitro_matmul.ops.resolve_backend``'s
(``pallas | interpret | reference | auto``); ``conv_mode`` is
``nitro_conv.ops``'s (``stream | materialise``).
"""

from __future__ import annotations

import jax

from repro.core import optimizer as opt
from repro.core.numerics import int_matmul
from repro.kernels.autotune.tiles import TileConfig
from repro.kernels.nitro_conv import ops as conv_ops
from repro.kernels.nitro_matmul import ops as mm_ops
from repro.kernels.nitro_matmul.ref import masked_delta


def linear_grads(
    x: jax.Array,
    w: jax.Array,
    delta: jax.Array,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    backend: str = "auto",
    tiles: TileConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """IntegerLinear backward: returns ``(grad_x, grad_w)``.

    ``grad_w = xᵀ @ f(δ)`` and ``grad_x = f(δ) @ wᵀ`` where ``f`` is the
    NITRO-ReLU-bwd/STE when ``z_star`` is given (fused into the kernel
    prologues by default) and the identity otherwise.  ``tiles`` overrides
    the kernel tile sizes (``None`` → per-gradient autotune-cache lookup).
    """
    if z_star is not None and not fuse_bwd:
        delta = masked_delta(delta, z_star, alpha_inv)
        z_star = None
    if z_star is None:
        # No-activation backward (or the unfused escape hatch): two plain
        # integer matmuls — already a single XLA op each, nothing to fuse.
        return int_matmul(delta, w.T), int_matmul(x.T, delta)
    grad_w = mm_ops.grad_w_matmul(
        x, delta, z_star, alpha_inv=alpha_inv, backend=backend, tiles=tiles
    )
    grad_x = mm_ops.grad_x_matmul(
        delta, z_star, w, alpha_inv=alpha_inv, backend=backend, tiles=tiles
    )
    return grad_x, grad_w


def conv_grads(
    x: jax.Array,
    w: jax.Array,
    delta: jax.Array,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    backend: str = "auto",
    conv_mode: str = "stream",
    tiles: TileConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """IntegerConv2D backward: returns ``(grad_x, grad_w)``.

    Both gradients stream their patches (``conv_mode='stream'``) or fall
    back to explicit im2col (``'materialise'``); with ``z_star`` the
    ReLU-bwd/STE prologue masks the δ bands inside the streaming kernels,
    so conv blocks never materialise the post-ReLU-bwd δ in HBM at all.
    """
    if z_star is not None and (
        not fuse_bwd
        or conv_ops.resolve_conv_mode(conv_mode) == "materialise"
    ):
        # Unfused escape hatch — or materialise mode, whose explicit
        # im2col reads the full δ from HBM regardless (no fusion site):
        # pre-mask ONCE here rather than letting both conv gradients
        # repeat the jnp mask downstream.
        delta = masked_delta(delta, z_star, alpha_inv)
        z_star = None
    grad_w = conv_ops.conv_grad_w(
        x, delta, kernel_size=w.shape[0],
        z_star=z_star, alpha_inv=alpha_inv,
        backend=backend, conv_mode=conv_mode, tiles=tiles,
    )
    grad_x = conv_ops.conv_grad_x(
        delta, w,
        z_star=z_star, alpha_inv=alpha_inv,
        backend=backend, conv_mode=conv_mode, tiles=tiles,
    )
    return grad_x, grad_w


# ---------------------------------------------------------------------------
# Fused weight updates: grad_W + IntegerSGD in one kernel pass (fuse_opt)
# ---------------------------------------------------------------------------


def linear_weight_update(
    x: jax.Array,
    w: jax.Array,
    delta: jax.Array,
    opt_state: opt.IntegerSGDState,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    backend: str = "auto",
    tiles: TileConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """IntegerLinear backward + optimiser: returns ``(grad_x, w_new)``.

    The fused path runs ``grad_w_opt_matmul`` — the IntegerSGD update is
    the grad_W kernel's flush epilogue, so grad_W never exists in HBM.
    The escape hatches (``z_star=None`` or ``fuse_bwd=False``) compose
    the materialised gradient with ``optimizer.apply_update`` — bitwise
    identical, because integer floor-div over an order-exact int32
    accumulation is exact.
    """
    if z_star is None or not fuse_bwd:
        grad_x, grad_w = linear_grads(
            x, w, delta, z_star=z_star, alpha_inv=alpha_inv,
            fuse_bwd=fuse_bwd, backend=backend, tiles=tiles,
        )
        return grad_x, opt.apply_update(w, grad_w, opt_state)
    w_new = mm_ops.grad_w_opt_matmul(
        x, delta, z_star, w, opt_state.gamma_inv, opt_state.eta_inv,
        alpha_inv=alpha_inv, backend=backend, tiles=tiles,
    )
    grad_x = mm_ops.grad_x_matmul(
        delta, z_star, w, alpha_inv=alpha_inv, backend=backend, tiles=tiles
    )
    return grad_x, w_new


def conv_weight_update(
    x: jax.Array,
    w: jax.Array,
    delta: jax.Array,
    opt_state: opt.IntegerSGDState,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    backend: str = "auto",
    conv_mode: str = "stream",
    tiles: TileConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """IntegerConv2D backward + optimiser: returns ``(grad_x, w_new)``.

    Stream mode fuses the IntegerSGD step into the grad_W kernel's flush
    (``conv_grad_w_opt``); materialise mode — whose gradient is an HBM
    matmul result with no flush — takes the unfused escape hatch, as do
    ``fuse_bwd=False`` and ``z_star=None``.
    """
    if z_star is None or not fuse_bwd or (
        conv_ops.resolve_conv_mode(conv_mode) == "materialise"
    ):
        grad_x, grad_w = conv_grads(
            x, w, delta, z_star=z_star, alpha_inv=alpha_inv,
            fuse_bwd=fuse_bwd, backend=backend, conv_mode=conv_mode,
            tiles=tiles,
        )
        return grad_x, opt.apply_update(w, grad_w, opt_state)
    w_new = conv_ops.conv_grad_w_opt(
        x, delta, w, opt_state.gamma_inv, opt_state.eta_inv,
        kernel_size=w.shape[0], z_star=z_star, alpha_inv=alpha_inv,
        backend=backend, conv_mode=conv_mode, tiles=tiles,
    )
    grad_x = conv_ops.conv_grad_x(
        delta, w, z_star=z_star, alpha_inv=alpha_inv,
        backend=backend, conv_mode=conv_mode, tiles=tiles,
    )
    return grad_x, w_new
