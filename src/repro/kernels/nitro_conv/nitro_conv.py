"""Streaming implicit-im2col conv Pallas TPU kernel.

The materialised conv path (``layers.conv_im2col_operands`` + the fused
``nitro_matmul``) pays a hidden ~K²× input-bandwidth tax: the full
``(N·H·W, K²·C)`` patch matrix is written to HBM and read back before the
matmul starts.  This kernel never forms that matrix.  Instead it

  * grids over ``(image, output-row band, filter tile)``;
  * DMAs only the ``bh + K − 1`` input rows the band needs from HBM into a
    VMEM row ring (once per band — the halo rows shared by the K×K window
    travel over HBM a single time, not K² times);
  * builds the band's patch block *in VMEM* from K² overlapping row/column
    slices of the ring — implicit im2col, layout identical to
    ``core.layers.im2col`` so every path shares one flattened weight
    ``w.reshape(K²·C, F)``;
  * runs one MXU matmul per ``(band, filter-tile)`` with int32 accumulation
    and the NITRO scale / NITRO-ReLU epilogue on the VPU;
  * optionally folds a 2×2 max-pool into the epilogue, so pooled layers
    write ``H/2·W/2`` activations instead of ``H·W`` plus a separate jnp
    pool pass.

HBM bytes on the conv input:  materialised  ~(1 + 2·K²)·H·W·C
                              streaming     ~H·W·C   (each band's rows are
                              DMA'd once, at filter-tile 0, and the VMEM
                              ring is reused across the filter grid)

The kernel bodies share the scaffolding:

  ``_stream_conv_kernel``         activation only (+ optional fused pool) —
                                  the inference plan step;
  ``_stream_conv_fwd_kernel``     two outputs ``(a, z_star)`` — the training
                                  forward (z* is the LES backward's cache);
  ``_stream_grad_w_kernel``       Σ patch_bandᵀ·g_band accumulated in a VMEM
                                  scratch — the conv weight gradient;
  ``_stream_grad_w_fused_kernel`` the same with the NITRO-ReLU-bwd/STE
                                  prologue masking each δ band in VMEM;
  ``_stream_grad_x_kernel``       the conv input gradient as a streaming
                                  'full' correlation over *masked* δ rows —
                                  δ and z* rows are DMA'd per band and the
                                  prologue rewrites the δ ring in place.

Geometry (row-band size, H padding) is shared with the pure-jnp oracle via
``ref.conv_geometry`` so the Pallas and reference backends stream the same
bands.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import mu_int8
from repro.core.scaling import pow2_split
from repro.kernels.autotune.tiles import DEFAULT_TILES
from repro.kernels.nitro_conv.ref import DEFAULT_BH, conv_geometry, rot180_swap
from repro.kernels.integer_sgd.integer_sgd import integer_sgd_tile
from repro.kernels.nitro_matmul.nitro_matmul import (
    _CompilerParams,
    _relu_bwd_tile,
    _relu_tile,
    _scale_tile,
)

#: Filter-tile width (MXU lane dimension) — alias of the single definition
#: in ``kernels.autotune.tiles.DEFAULT_TILES``.
DEFAULT_BF = DEFAULT_TILES.bf


def _load_band(x_hbm, rows_ref, sem, n, band_idx, band_rows: int):
    """DMA one image's input-row band HBM → VMEM row ring."""
    copy = pltpu.make_async_copy(
        x_hbm.at[n, pl.ds(band_idx, band_rows)], rows_ref, sem
    )
    copy.start()
    copy.wait()


def _form_patches(rows_ref, patches_ref, *, k: int, bh: int, w_out: int, c: int):
    """Implicit im2col: K² overlapping slices of the row ring → patch block.

    ``patches[(r·W + w), (ki·K + kj)·C + c] = rows[r + ki, w + kj, c]`` —
    the ``core.layers.im2col`` layout, built from VMEM-resident rows.  The
    patch block takes the scratch's dtype: int32 normally, int8 on the
    int8-operand path (where the scratch is allocated int8 and the rows
    are already int8 — a quarter of the patch VMEM footprint).
    """
    for ki in range(k):
        for kj in range(k):
            seg = rows_ref[ki:ki + bh, kj:kj + w_out, :]
            patches_ref[:, (ki * k + kj) * c:(ki * k + kj + 1) * c] = (
                seg.reshape(bh * w_out, c).astype(patches_ref.dtype)
            )


def _band_matmul(patches_ref, w_ref, *, bh: int, w_out: int, bf: int,
                 int8_ops: bool = False):
    """One MXU pass: (bh·W, K²C) @ (K²C, bf) → int32 (bh, W, bf).

    ``int8_ops`` keeps both operands int8 (the MXU double-rate mode); the
    ``preferred_element_type`` accumulator is int32 either way, so the
    result is bit-identical.
    """
    w_tile = w_ref[...]
    if not int8_ops:
        w_tile = w_tile.astype(jnp.int32)
    z = jax.lax.dot_general(
        patches_ref[...], w_tile,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )
    return z.reshape(bh, w_out, bf)


def _maxpool_tile(a, *, bh: int, w_out: int):
    """Fused 2×2 stride-2 max-pool epilogue on a (bh, W, bf) VMEM tile."""
    w2 = w_out // 2
    a = a[:, : w2 * 2, :].reshape(bh, w2, 2, -1).max(axis=2)
    return a.reshape(bh // 2, 2, w2, -1).max(axis=1)


def _stream_conv_kernel(
    x_hbm, w_ref, out_ref, rows, patches, sem, *,
    k, bh, w_out, c, bf,
    sf_shift, sf_residual, alpha_inv, mu, apply_relu, pool, out_dtype,
    int8_ops=False,
):
    """Activation-only streaming conv step (the inference plan's layer)."""
    n, band, f = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(f == 0)  # rows + patches are reused across filter tiles
    def _stage_band():
        _load_band(x_hbm, rows, sem, n, band * bh, bh + k - 1)
        _form_patches(rows, patches, k=k, bh=bh, w_out=w_out, c=c)

    z = _band_matmul(patches, w_ref, bh=bh, w_out=w_out, bf=bf,
                     int8_ops=int8_ops)
    z = _scale_tile(z, sf_shift, sf_residual)
    if apply_relu:
        z = _relu_tile(z, alpha_inv, mu)
    if pool:
        z = _maxpool_tile(z, bh=bh, w_out=w_out)
    out_ref[0] = z.astype(out_dtype)


def _stream_conv_fwd_kernel(
    x_hbm, w_ref, a_ref, zstar_ref, rows, patches, sem, *,
    k, bh, w_out, c, bf,
    sf_shift, sf_residual, alpha_inv, mu, out_dtype,
):
    """Training-forward variant: ``(a, z_star)`` from one accumulation.

    Mirrors ``nitro_matmul_fwd``: the raw pre-activation ``z`` never leaves
    VMEM; the scaled ``z*`` (int32, the NITRO-ReLU/STE backward cache) and
    the activation are the only HBM writes.
    """
    n, band, f = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(f == 0)
    def _stage_band():
        _load_band(x_hbm, rows, sem, n, band * bh, bh + k - 1)
        _form_patches(rows, patches, k=k, bh=bh, w_out=w_out, c=c)

    z = _band_matmul(patches, w_ref, bh=bh, w_out=w_out, bf=bf)
    z_star = _scale_tile(z, sf_shift, sf_residual)
    zstar_ref[0] = z_star
    a_ref[0] = _relu_tile(z_star, alpha_inv, mu).astype(out_dtype)


def _grad_w_accumulate(
    x_hbm, g2d, out_ref, rows, patches, acc, sem, *,
    k, bh, w_out, c, n_steps, flush=None,
):
    """Shared grad_w body: acc += patch_bandᵀ @ g2d per (image, band).

    Grid is ``(filter tile, image, band)`` — the filter tile is outermost so
    the (K²C, bf) VMEM accumulator runs over every image/band before its
    single HBM write.  ``g2d`` is the (bh·W, bf) gradient band, already in
    VMEM registers (masked by the caller on the fused path).  ``flush``
    lets the caller transform the finished accumulator tile before the HBM
    write (the IntegerSGD epilogue); ``None`` writes the raw gradient.
    """
    n, band = pl.program_id(1), pl.program_id(2)
    step = n * pl.num_programs(2) + band

    @pl.when(step == 0)
    def _zero():
        acc[...] = jnp.zeros_like(acc)

    _load_band(x_hbm, rows, sem, n, band * bh, bh + k - 1)
    _form_patches(rows, patches, k=k, bh=bh, w_out=w_out, c=c)
    acc[...] += jax.lax.dot_general(
        patches[...], g2d,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(step == n_steps - 1)
    def _flush():
        out_ref[...] = acc[...] if flush is None else flush(acc[...])


def _stream_grad_w_kernel(
    x_hbm, g_ref, out_ref, rows, patches, acc, sem, *,
    k, bh, w_out, c, bf, n_steps,
):
    """Conv weight gradient, plain δ (the ReLU backward already applied)."""
    g2d = g_ref[0].reshape(bh * w_out, bf).astype(jnp.int32)
    _grad_w_accumulate(
        x_hbm, g2d, out_ref, rows, patches, acc, sem,
        k=k, bh=bh, w_out=w_out, c=c, n_steps=n_steps,
    )


def _stream_grad_w_fused_kernel(
    x_hbm, g_ref, z_ref, out_ref, rows, patches, acc, sem, *,
    k, bh, w_out, c, bf, n_steps, alpha_inv,
):
    """Conv weight gradient with the fused NITRO-ReLU-bwd/STE prologue.

    The δ band is masked against the matching ``z_star`` band in VMEM just
    before the MXU contraction — the post-ReLU-bwd δ never exists outside
    this (bh·W, bf) register tile.
    """
    g2d = _relu_bwd_tile(
        g_ref[0].reshape(bh * w_out, bf).astype(jnp.int32),
        z_ref[0].reshape(bh * w_out, bf),
        alpha_inv,
    )
    _grad_w_accumulate(
        x_hbm, g2d, out_ref, rows, patches, acc, sem,
        k=k, bh=bh, w_out=w_out, c=c, n_steps=n_steps,
    )


def _stream_grad_w_opt_kernel(
    scalars_ref, x_hbm, g_ref, z_ref, w_ref, out_ref, rows, patches, acc,
    sem, *, k, bh, w_out, c, bf, n_steps, alpha_inv,
):
    """Conv weight *update*: fused prologue + IntegerSGD flush epilogue.

    Accumulation matches ``_stream_grad_w_fused_kernel`` exactly; the last
    (image, band) step reads the flattened (K²C, bf) W tile and writes
    ``W − (⌊acc/γ_inv⌋ + ⌊W/η_inv⌋)`` — grad_W never reaches HBM.
    γ_inv/η_inv arrive in SMEM.
    """
    g2d = _relu_bwd_tile(
        g_ref[0].reshape(bh * w_out, bf).astype(jnp.int32),
        z_ref[0].reshape(bh * w_out, bf),
        alpha_inv,
    )
    _grad_w_accumulate(
        x_hbm, g2d, out_ref, rows, patches, acc, sem,
        k=k, bh=bh, w_out=w_out, c=c, n_steps=n_steps,
        flush=lambda a: integer_sgd_tile(
            w_ref[...], a, scalars_ref[0], scalars_ref[1]
        ),
    )


def _stream_grad_x_kernel(
    g_hbm, z_hbm, w_ref, out_ref, rows, zrows, patches, sem, zsem, *,
    k, bh, w_out, c, bf, alpha_inv,
):
    """Conv input gradient: streaming 'full' correlation over masked δ.

    Both the δ rows and the matching ``z_star`` rows are DMA'd into VMEM
    rings at filter-tile 0; the ReLU-bwd prologue rewrites the δ ring in
    place (the zero halo is preserved — relu_bwd(0, 0) = 0), patches are
    formed from the *masked* rows, and the rot180-swapped weight closes
    the correlation.  No scale/ReLU epilogue: sf = 1 for gradients.
    """
    n, band, f = pl.program_id(0), pl.program_id(1), pl.program_id(2)

    @pl.when(f == 0)  # masked rows + patches are reused across filter tiles
    def _stage_band():
        _load_band(g_hbm, rows, sem, n, band * bh, bh + k - 1)
        _load_band(z_hbm, zrows, zsem, n, band * bh, bh + k - 1)
        rows[...] = _relu_bwd_tile(
            rows[...].astype(jnp.int32), zrows[...], alpha_inv
        )
        _form_patches(rows, patches, k=k, bh=bh, w_out=w_out, c=c)

    z = _band_matmul(patches, w_ref, bh=bh, w_out=w_out, bf=bf)
    out_ref[0] = z.astype(jnp.int32)


def _pad_operands(x, w, bf, h_pad, p):
    """Zero-pad input (halo + band multiple) and the filter dim — exact for
    integer conv; garbage rows/filters are sliced away by the wrappers."""
    n, h, w_sp, c = x.shape
    k, f = w.shape[0], w.shape[-1]
    xp = jnp.pad(x, ((0, 0), (p, p + h_pad - h), (p, p), (0, 0)))
    f_pad = (-f) % bf
    w_flat = w.reshape(k * k * c, f)
    if f_pad:
        w_flat = jnp.pad(w_flat, ((0, 0), (0, f_pad)))
    return xp, w_flat, f + f_pad


def _conv_scratches(x, k, bh, w_sp, c, *, patch_dtype=jnp.int32):
    """The kernel's VMEM working set: row ring, patch block, DMA semaphore.

    ``patch_dtype=int8`` is the int8-operand path's patch block — 4× less
    patch VMEM, feeding the MXU's double-rate int8 mode.
    """
    return [
        pltpu.VMEM((bh + k - 1, w_sp + k - 1, c), x.dtype),
        pltpu.VMEM((bh * w_sp, k * k * c), patch_dtype),
        pltpu.SemaphoreType.DMA,
    ]


@functools.partial(
    jax.jit,
    static_argnames=(
        "sf", "alpha_inv", "apply_relu", "pool", "out_dtype",
        "bh", "bf", "operand_dtype", "interpret",
    ),
)
def stream_conv(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    pool: bool = False,
    out_dtype=jnp.int32,
    bh: int = DEFAULT_BH,
    bf: int = DEFAULT_BF,
    operand_dtype: str = "int32",
    interpret: bool = False,
) -> jax.Array:
    """Streaming fused 'same' conv: ``relu(⌊conv(x, w)/sf⌋)`` (+2×2 pool).

    x: (N,H,W,C) int, w: (K,K,C,F) int, K odd → (N,H,W,F) activations, or
    (N,H//2,W//2,F) with ``pool=True``.  Bit-exact with the materialised
    im2col + ``nitro_matmul`` path (+ separate pool) on every shape.

    ``operand_dtype='int8'`` keeps the VMEM row ring *and* the patch block
    int8 and issues int8×int8→int32 MXU dots — both operands must already
    be int8 (the dispatcher proves eligibility and narrows).
    """
    if operand_dtype == "int8" and not (
        x.dtype == jnp.int8 and w.dtype == jnp.int8
    ):
        raise ValueError(
            f"operand_dtype='int8' requires int8 operands, got "
            f"{x.dtype}/{w.dtype} (the dispatcher narrows eligible inputs)"
        )
    int8_ops = operand_dtype == "int8"
    n, h, w_sp, c = x.shape
    k, f = w.shape[0], w.shape[-1]
    if pool and (h < 2 or w_sp < 2):
        raise ValueError(f"2x2 pool epilogue needs H,W >= 2, got {h}x{w_sp}")
    bh_, h_pad, p = conv_geometry(h, k, bh, pool=pool)
    bf_ = min(bf, f)
    xp, w_flat, f_pad = _pad_operands(x, w, bf_, h_pad, p)

    shift, residual = pow2_split(sf)
    kernel = functools.partial(
        _stream_conv_kernel,
        k=k, bh=bh_, w_out=w_sp, c=c, bf=bf_,
        sf_shift=shift, sf_residual=residual, alpha_inv=alpha_inv,
        mu=mu_int8(alpha_inv) if apply_relu else 0,
        apply_relu=apply_relu, pool=pool, out_dtype=out_dtype,
        int8_ops=int8_ops,
    )
    oh, ow = (bh_ // 2, w_sp // 2) if pool else (bh_, w_sp)
    out = pl.pallas_call(
        kernel,
        grid=(n, h_pad // bh_, f_pad // bf_),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # rows DMA'd by the kernel
            pl.BlockSpec((k * k * c, bf_), lambda ni, bi, fi: (0, fi)),
        ],
        out_specs=pl.BlockSpec(
            (1, oh, ow, bf_), lambda ni, bi, fi: (ni, bi, 0, fi)
        ),
        out_shape=jax.ShapeDtypeStruct(
            (n, (h_pad // bh_) * oh, ow, f_pad), out_dtype
        ),
        scratch_shapes=_conv_scratches(
            x, k, bh_, w_sp, c,
            patch_dtype=jnp.int8 if int8_ops else jnp.int32,
        ),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, w_flat)
    return out[:, : (h // 2 if pool else h), :, :f]


@functools.partial(
    jax.jit,
    static_argnames=("sf", "alpha_inv", "out_dtype", "bh", "bf", "interpret"),
)
def stream_conv_fwd(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    out_dtype=jnp.int32,
    bh: int = DEFAULT_BH,
    bf: int = DEFAULT_BF,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Streaming *training* forward: ``(a, z_star)`` in one pass.

    The conv analogue of ``nitro_matmul_fwd`` — same two-output contract,
    minus the HBM patch matrix on the input side.
    """
    n, h, w_sp, c = x.shape
    k, f = w.shape[0], w.shape[-1]
    bh_, h_pad, p = conv_geometry(h, k, bh, pool=False)
    bf_ = min(bf, f)
    xp, w_flat, f_pad = _pad_operands(x, w, bf_, h_pad, p)

    shift, residual = pow2_split(sf)
    kernel = functools.partial(
        _stream_conv_fwd_kernel,
        k=k, bh=bh_, w_out=w_sp, c=c, bf=bf_,
        sf_shift=shift, sf_residual=residual, alpha_inv=alpha_inv,
        mu=mu_int8(alpha_inv), out_dtype=out_dtype,
    )
    out_spec = pl.BlockSpec(
        (1, bh_, w_sp, bf_), lambda ni, bi, fi: (ni, bi, 0, fi)
    )
    a, z_star = pl.pallas_call(
        kernel,
        grid=(n, h_pad // bh_, f_pad // bf_),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),
            pl.BlockSpec((k * k * c, bf_), lambda ni, bi, fi: (0, fi)),
        ],
        out_specs=[out_spec, out_spec],
        out_shape=[
            jax.ShapeDtypeStruct((n, h_pad, w_sp, f_pad), out_dtype),
            jax.ShapeDtypeStruct((n, h_pad, w_sp, f_pad), jnp.int32),
        ],
        scratch_shapes=_conv_scratches(x, k, bh_, w_sp, c),
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(xp, w_flat)
    return a[:, :h, :, :f], z_star[:, :h, :, :f]


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "alpha_inv", "bh", "bf", "interpret"),
)
def stream_conv_grad_w(
    x: jax.Array,
    grad_out: jax.Array,
    *,
    kernel_size: int,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    bh: int = DEFAULT_BH,
    bf: int = DEFAULT_BF,
    interpret: bool = False,
) -> jax.Array:
    """Streaming conv weight gradient: (N,H,W,C) × (N,H,W,F) → (K,K,C,F).

    Patch bands are formed in VMEM exactly as in the forward kernel and
    contracted against the matching gradient rows; the (K²C, bf) partial
    sums live in a VMEM accumulator until the last band.  int32 adds are
    order-exact, so the result matches ``im2colᵀ @ g`` bit-for-bit.

    With ``z_star`` (same shape as ``grad_out``) the NITRO-ReLU-bwd/STE
    prologue masks each δ band in VMEM before the contraction — the fused
    backward path; without it the δ is consumed as-is (the caller already
    applied the activation backward).
    """
    n, h, w_sp, c = x.shape
    k = kernel_size
    f = grad_out.shape[-1]
    bh_, h_pad, p = conv_geometry(h, k, bh, pool=False)
    bf_ = min(bf, f)
    xp = jnp.pad(x, ((0, 0), (p, p + h_pad - h), (p, p), (0, 0)))
    f_pad = (-f) % bf_
    g_pad = ((0, 0), (0, h_pad - h), (0, 0), (0, f_pad))
    gp = jnp.pad(grad_out, g_pad)

    n_bands = h_pad // bh_
    g_spec = pl.BlockSpec(
        (1, bh_, w_sp, bf_), lambda fi, ni, bi: (ni, bi, 0, fi)
    )
    operands = [xp, gp]
    in_specs = [pl.BlockSpec(memory_space=pltpu.ANY), g_spec]
    if z_star is None:
        kernel = functools.partial(
            _stream_grad_w_kernel,
            k=k, bh=bh_, w_out=w_sp, c=c, bf=bf_, n_steps=n * n_bands,
        )
    else:
        kernel = functools.partial(
            _stream_grad_w_fused_kernel,
            k=k, bh=bh_, w_out=w_sp, c=c, bf=bf_, n_steps=n * n_bands,
            alpha_inv=alpha_inv,
        )
        operands.append(jnp.pad(z_star.astype(jnp.int32), g_pad))
        in_specs.append(g_spec)
    out = pl.pallas_call(
        kernel,
        grid=((f + f_pad) // bf_, n, n_bands),
        in_specs=in_specs,
        out_specs=pl.BlockSpec((k * k * c, bf_), lambda fi, ni, bi: (0, fi)),
        out_shape=jax.ShapeDtypeStruct((k * k * c, f + f_pad), jnp.int32),
        scratch_shapes=_conv_scratches(x, k, bh_, w_sp, c)[:2] + [
            pltpu.VMEM((k * k * c, bf_), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(*operands)
    return out[:, :f].reshape(k, k, c, f)


@functools.partial(
    jax.jit,
    static_argnames=("kernel_size", "alpha_inv", "bh", "bf", "interpret"),
)
def stream_conv_grad_w_opt(
    x: jax.Array,
    grad_out: jax.Array,
    z_star: jax.Array,
    w: jax.Array,
    gamma_inv: jax.Array,
    eta_inv: jax.Array,
    *,
    kernel_size: int,
    alpha_inv: int = 10,
    bh: int = DEFAULT_BH,
    bf: int = DEFAULT_BF,
    interpret: bool = False,
) -> jax.Array:
    """Streaming conv weight *update*: grad_W stays in VMEM, IntegerSGD is
    applied in the flush, and the kernel returns W′ (K,K,C,F) directly.

    Same band geometry, padding, and accumulation order as the fused
    ``stream_conv_grad_w`` — bitwise-identical grad_W by construction —
    then the flush applies ``W − (⌊acc/γ_inv⌋ + ⌊W/η_inv⌋)`` per filter
    tile.  ``w`` rides in VMEM flattened to the (K²C, bf) output layout.
    Padded filter columns have acc = 0 and w = 0 → W′ = 0, sliced away.
    """
    n, h, w_sp, c = x.shape
    k = kernel_size
    f = grad_out.shape[-1]
    assert w.shape == (k, k, c, f), f"w shape {w.shape} != {(k, k, c, f)}"
    bh_, h_pad, p = conv_geometry(h, k, bh, pool=False)
    bf_ = min(bf, f)
    xp = jnp.pad(x, ((0, 0), (p, p + h_pad - h), (p, p), (0, 0)))
    f_pad = (-f) % bf_
    g_pad = ((0, 0), (0, h_pad - h), (0, 0), (0, f_pad))
    gp = jnp.pad(grad_out, g_pad)
    zp = jnp.pad(z_star.astype(jnp.int32), g_pad)
    w_flat = jnp.pad(w.reshape(k * k * c, f), ((0, 0), (0, f_pad)))

    n_bands = h_pad // bh_
    g_spec = pl.BlockSpec(
        (1, bh_, w_sp, bf_), lambda fi, ni, bi: (ni, bi, 0, fi)
    )
    w_spec = pl.BlockSpec((k * k * c, bf_), lambda fi, ni, bi: (0, fi))
    kernel = functools.partial(
        _stream_grad_w_opt_kernel,
        k=k, bh=bh_, w_out=w_sp, c=c, bf=bf_, n_steps=n * n_bands,
        alpha_inv=alpha_inv,
    )
    scalars = jnp.stack(
        [jnp.asarray(gamma_inv, jnp.int32), jnp.asarray(eta_inv, jnp.int32)]
    )
    out = pl.pallas_call(
        kernel,
        grid=((f + f_pad) // bf_, n, n_bands),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec(memory_space=pltpu.ANY),
            g_spec,
            g_spec,
            w_spec,
        ],
        out_specs=w_spec,
        out_shape=jax.ShapeDtypeStruct((k * k * c, f + f_pad), jnp.int32),
        scratch_shapes=_conv_scratches(x, k, bh_, w_sp, c)[:2] + [
            pltpu.VMEM((k * k * c, bf_), jnp.int32),
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(scalars, xp, gp, zp, w_flat)
    return out[:, :f].reshape(k, k, c, f)


@functools.partial(
    jax.jit,
    static_argnames=("alpha_inv", "bh", "bf", "interpret"),
)
def stream_conv_grad_x(
    delta: jax.Array,
    z_star: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    bh: int = DEFAULT_BH,
    bf: int = DEFAULT_BF,
    interpret: bool = False,
) -> jax.Array:
    """Streaming conv input gradient with the fused ReLU-bwd prologue.

    (N,H,W,F) δ × (N,H,W,F) z* × (K,K,C,F) weight → (N,H,W,C) int32: the
    'full' correlation of ``relu_bwd(z*, δ)`` with the rot180-swapped
    kernel, streamed exactly like the forward conv — δ *and* z* rows are
    DMA'd per band, masked in the VMEM ring, and the patch block is built
    from the masked rows.  The post-ReLU-bwd δ tensor never exists in HBM.

    (The unfused input gradient stays ``stream_conv(δ_masked, rot180_swap(w),
    sf=1, apply_relu=False)`` — this kernel is that conv plus the prologue.)
    """
    n, h, w_sp, f = delta.shape
    k, c = w.shape[0], w.shape[2]
    assert delta.shape == z_star.shape, "delta/z_star shape mismatch"
    w_rot = rot180_swap(w)  # (K, K, F, C)
    bh_, h_pad, p = conv_geometry(h, k, bh, pool=False)
    bc = min(bf, c)
    dp, w_flat, c_pad = _pad_operands(
        delta.astype(jnp.int32), w_rot, bc, h_pad, p
    )
    zp = jnp.pad(
        z_star.astype(jnp.int32),
        ((0, 0), (p, p + h_pad - h), (p, p), (0, 0)),
    )
    kernel = functools.partial(
        _stream_grad_x_kernel,
        k=k, bh=bh_, w_out=w_sp, c=f, bf=bc, alpha_inv=alpha_inv,
    )
    ring = pltpu.VMEM((bh_ + k - 1, w_sp + k - 1, f), jnp.int32)
    out = pl.pallas_call(
        kernel,
        grid=(n, h_pad // bh_, c_pad // bc),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.ANY),  # δ rows, DMA'd in-kernel
            pl.BlockSpec(memory_space=pltpu.ANY),  # z* rows, ditto
            pl.BlockSpec((k * k * f, bc), lambda ni, bi, fi: (0, fi)),
        ],
        out_specs=pl.BlockSpec(
            (1, bh_, w_sp, bc), lambda ni, bi, fi: (ni, bi, 0, fi)
        ),
        out_shape=jax.ShapeDtypeStruct((n, h_pad, w_sp, c_pad), jnp.int32),
        scratch_shapes=[
            ring,                                       # masked δ row ring
            ring,                                       # z* row ring
            pltpu.VMEM((bh_ * w_sp, k * k * f), jnp.int32),
            pltpu.SemaphoreType.DMA,
            pltpu.SemaphoreType.DMA,
        ],
        compiler_params=_CompilerParams(
            dimension_semantics=("arbitrary", "arbitrary", "arbitrary"),
        ),
        interpret=interpret,
    )(dp, zp, w_flat)
    return out[:, :h, :, :c]
