"""Public wrappers + mode/backend dispatch for the streaming conv kernel.

This module is the **single conv entry point** every consumer shares:

  * training forward — ``core.blocks.forward_layers`` calls
    ``fused_conv_fwd`` (activation *and* the cached pre-ReLU ``z_star``);
  * training backward — ``kernels.grad_ops`` (behind
    ``core.layers.conv_backward``) calls ``conv_grad_w`` / ``conv_grad_x``,
    passing the cached ``z_star`` so the NITRO-ReLU-bwd/STE prologue runs
    inside the gradient kernels;
  * inference — ``infer.plan`` calls ``fused_conv`` (activation only,
    optionally int8-narrowed, optionally with the fused 2×2 pool).

Two orthogonal static knobs:

``conv_mode``
  * ``'stream'``      — implicit im2col: row bands are staged through
                        VMEM (Pallas) or band-local patch blocks (jnp);
                        the ``(N·H·W, K²·C)`` patch matrix never exists.
  * ``'materialise'`` — the original path: ``conv_im2col_operands`` +
                        the fused ``nitro_matmul`` (+ separate jnp pool).
                        Kept as the bit-exact escape hatch/oracle,
                        mirroring ``fused=False`` one level up.

``backend`` (same vocabulary as ``nitro_matmul.ops``)
  * ``'pallas'``     — the real TPU kernel;
  * ``'interpret'``  — the same kernel through the Pallas interpreter;
  * ``'reference'``  — the pure-jnp streaming oracle from ``ref.py``;
  * ``'auto'``       — pallas on TPU, reference elsewhere.

Every (mode, backend) combination is bit-identical — integer arithmetic
makes the tiling/accumulation order irrelevant — and the tests sweep them
all against each other.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import conv_im2col_operands, im2col, window_view_2x2
from repro.core.numerics import int_matmul
from repro.kernels.autotune import state as autotune
from repro.kernels.autotune.tiles import TileConfig
from repro.kernels.nitro_conv import ref as conv_ref
from repro.kernels.nitro_conv.nitro_conv import (
    stream_conv,
    stream_conv_fwd,
    stream_conv_grad_w,
    stream_conv_grad_w_opt,
    stream_conv_grad_x,
)
from repro.kernels.nitro_matmul.ops import (
    _guard_int8,
    check_alpha_inv,
    resolve_backend,
    resolve_operand_dtype,
)
from repro.kernels.nitro_matmul.ref import masked_delta

CONV_MODES = ("stream", "materialise")


def _stream_tile_kw(tiles: TileConfig | None) -> dict:
    """bh/bf kwargs for the streaming kernels (defaults when untuned)."""
    return {} if tiles is None else dict(bh=tiles.bh, bf=tiles.bf)


def resolve_conv_mode(conv_mode: str) -> str:
    if conv_mode not in CONV_MODES:
        raise ValueError(
            f"unknown conv_mode {conv_mode!r}; one of {CONV_MODES}"
        )
    return conv_mode


# ---------------------------------------------------------------------------
# Forward entry points
# ---------------------------------------------------------------------------


def fused_conv(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    pool: bool = False,
    out_dtype=jnp.int32,
    backend: str = "auto",
    conv_mode: str = "stream",
    tiles: TileConfig | None = None,
    operand_dtype: str = "auto",
) -> jax.Array:
    """One fused conv+scale(+relu)(+2×2 pool) — the inference plan step.

    (N,H,W,C) int × (K,K,C,F) int → (N,H,W,F), or (N,H//2,W//2,F) when
    ``pool=True``.  On the streaming path the pool runs in the kernel
    epilogue; the materialised path pools with a separate jnp pass (its
    historical behaviour) — bit-identical either way.

    ``tiles``/``operand_dtype`` mirror ``fused_matmul``'s knobs: both are
    perf-only and bitwise result-invariant.  ``tiles=None`` consults the
    autotune cache under the conv's own key; a materialise-mode miss then
    falls through to the inner matmul's own resolution.
    """
    alpha_inv = check_alpha_inv(alpha_inv, apply_relu)
    backend = resolve_backend(backend)
    conv_mode = resolve_conv_mode(conv_mode)
    od = resolve_operand_dtype(operand_dtype, x, w)
    if od == "int8":
        x = _guard_int8(x, "x")
        w = _guard_int8(w, "w")
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "conv", (x.shape[0], x.shape[1], x.shape[2], x.shape[3],
                     w.shape[0], w.shape[-1]),
            dtype=f"{x.dtype},{w.dtype}", backend=backend,
            conv_mode=conv_mode,
        )
    if conv_mode == "materialise":
        from repro.kernels.nitro_matmul.ops import fused_matmul

        n, h, w_sp, _ = x.shape
        patches, w_flat = conv_im2col_operands(w, x)
        out = fused_matmul(
            patches, w_flat, sf=sf, alpha_inv=alpha_inv,
            apply_relu=apply_relu, out_dtype=out_dtype, backend=backend,
            tiles=tiles, operand_dtype=od,
        ).reshape(n, h, w_sp, w.shape[-1])
        return jnp.max(window_view_2x2(out), axis=3) if pool else out
    if backend == "reference":
        return conv_ref.stream_conv_ref(
            x, w, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            pool=pool, out_dtype=out_dtype,
            bh=None if tiles is None else tiles.bh, operand_dtype=od,
        )
    return stream_conv(
        x, w, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu, pool=pool,
        out_dtype=out_dtype, interpret=(backend == "interpret"),
        operand_dtype=od, **_stream_tile_kw(tiles),
    )


def fused_conv_fwd(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    backend: str = "auto",
    conv_mode: str = "stream",
    tiles: TileConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused conv *training* forward: ``(a, z_star)``, both (N,H,W,F).

    ``a`` keeps int32 (matching the unfused reference pipeline's dtype);
    ``z_star`` is the int32 pre-ReLU tensor ``forward_layers_backward``
    consumes for the NITRO-ReLU/STE backward.
    """
    alpha_inv = check_alpha_inv(alpha_inv, True)
    backend = resolve_backend(backend)
    conv_mode = resolve_conv_mode(conv_mode)
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "conv_fwd", (x.shape[0], x.shape[1], x.shape[2], x.shape[3],
                         w.shape[0], w.shape[-1]),
            dtype=f"{x.dtype},{w.dtype}", backend=backend,
            conv_mode=conv_mode,
        )
    if conv_mode == "materialise":
        from repro.kernels.nitro_matmul.ops import fused_matmul_fwd

        n, h, w_sp, _ = x.shape
        f = w.shape[-1]
        patches, w_flat = conv_im2col_operands(w, x)
        a2, z2 = fused_matmul_fwd(
            patches, w_flat, sf=sf, alpha_inv=alpha_inv, backend=backend,
            tiles=tiles,
        )
        return a2.reshape(n, h, w_sp, f), z2.reshape(n, h, w_sp, f)
    if backend == "reference":
        return conv_ref.stream_conv_fwd_ref(
            x, w, sf=sf, alpha_inv=alpha_inv,
            bh=None if tiles is None else tiles.bh,
        )
    return stream_conv_fwd(
        x, w, sf=sf, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"), **_stream_tile_kw(tiles),
    )


# ---------------------------------------------------------------------------
# Backward entry points (integer conv gradients)
# ---------------------------------------------------------------------------


def conv_grad_w(
    x: jax.Array,
    grad_out: jax.Array,
    *,
    kernel_size: int,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    backend: str = "auto",
    conv_mode: str = "stream",
    tiles: TileConfig | None = None,
) -> jax.Array:
    """Conv weight gradient: correlate input patches with ``grad_out``.

    (N,H,W,C) × (N,H,W,F) → (K,K,C,F) int32.  Streaming forms patch bands
    on the fly (VMEM accumulator in the kernel, band loop in the jnp
    oracle); materialise is the historical ``im2colᵀ @ g`` matmul.

    ``z_star`` (same shape as ``grad_out``) enables the fused backward:
    the NITRO-ReLU-derivative/STE prologue is applied to the δ bands in
    VMEM (stream) or as a jnp pre-mask (materialise — its patches live in
    HBM anyway, so there is no fusion site).  Without it the caller has
    already applied the activation backward.
    """
    backend = resolve_backend(backend)
    if z_star is not None:
        alpha_inv = check_alpha_inv(alpha_inv, True)
    conv_mode = resolve_conv_mode(conv_mode)
    if tiles is None and conv_mode != "materialise":
        tiles = autotune.resolve_tiles(
            "conv_grad_w",
            (x.shape[0], x.shape[1], x.shape[2], x.shape[3],
             kernel_size, grad_out.shape[-1]),
            dtype=f"{x.dtype},{grad_out.dtype}", backend=backend,
            conv_mode=conv_mode, fuse_bwd=z_star is not None,
        )
    if conv_mode == "materialise":
        if z_star is not None:
            grad_out = masked_delta(grad_out, z_star, alpha_inv)
        n, h, w_sp, c = x.shape
        f = grad_out.shape[-1]
        k = kernel_size
        patches = im2col(x, k, k // 2).reshape(n * h * w_sp, k * k * c)
        g_flat = grad_out.reshape(n * h * w_sp, f)
        return int_matmul(patches.T, g_flat).reshape(k, k, c, f)
    if backend == "reference":
        return conv_ref.stream_conv_grad_w_ref(
            x, grad_out, kernel_size=kernel_size,
            z_star=z_star, alpha_inv=alpha_inv,
            bh=None if tiles is None else tiles.bh,
        )
    return stream_conv_grad_w(
        x, grad_out, kernel_size=kernel_size,
        z_star=z_star, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"), **_stream_tile_kw(tiles),
    )


def conv_grad_w_opt(
    x: jax.Array,
    grad_out: jax.Array,
    w: jax.Array,
    gamma_inv: jax.Array,
    eta_inv: jax.Array,
    *,
    kernel_size: int,
    z_star: jax.Array,
    alpha_inv: int = 10,
    backend: str = "auto",
    conv_mode: str = "stream",
    tiles: TileConfig | None = None,
) -> jax.Array:
    """Conv weight *update*: ``conv_grad_w`` with IntegerSGD applied in the
    streaming kernel's flush — returns W′ (K,K,C,F), grad_W never in HBM.

    Stream-only: the materialise path's gradient is an HBM matmul result
    with no flush to fuse into — callers (``grad_ops.conv_weight_update``)
    take the unfused escape hatch there instead of calling this.
    ``z_star`` is required; a caller without it has pre-masked δ and no
    prologue, which is also the escape hatch's job.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    conv_mode = resolve_conv_mode(conv_mode)
    if conv_mode == "materialise":
        raise ValueError(
            "conv_grad_w_opt is stream-only: the materialise path has no "
            "kernel flush to fuse the optimiser into — compute conv_grad_w "
            "and apply optimizer.apply_update instead"
        )
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "conv_grad_w",
            (x.shape[0], x.shape[1], x.shape[2], x.shape[3],
             kernel_size, grad_out.shape[-1]),
            dtype=f"{x.dtype},{grad_out.dtype}", backend=backend,
            conv_mode=conv_mode, fuse_bwd=True, fuse_opt=True,
        )
    if backend == "reference":
        from repro.kernels.integer_sgd.ref import integer_sgd_ref

        grad_w = conv_ref.stream_conv_grad_w_ref(
            x, grad_out, kernel_size=kernel_size,
            z_star=z_star, alpha_inv=alpha_inv,
            bh=None if tiles is None else tiles.bh,
        )
        return integer_sgd_ref(w, grad_w, gamma_inv, eta_inv)
    return stream_conv_grad_w_opt(
        x, grad_out, z_star, w, gamma_inv, eta_inv,
        kernel_size=kernel_size, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"), **_stream_tile_kw(tiles),
    )


def conv_grad_x(
    grad_out: jax.Array,
    w: jax.Array,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    backend: str = "auto",
    conv_mode: str = "stream",
    tiles: TileConfig | None = None,
) -> jax.Array:
    """Conv input gradient: 'full' correlation of ``grad_out`` with the
    rotated kernel — one more conv, streamed the same way (unit scale, no
    activation).  (N,H,W,F) × (K,K,C,F) → (N,H,W,C) int32.

    With ``z_star`` the streaming kernel/oracle masks each δ band by the
    NITRO-ReLU derivative *before* patch formation (the fused backward);
    the materialise escape hatch pre-masks with jnp, since its im2col
    reads the full δ from HBM regardless.
    """
    backend = resolve_backend(backend)
    if z_star is not None:
        alpha_inv = check_alpha_inv(alpha_inv, True)
    conv_mode = resolve_conv_mode(conv_mode)
    if tiles is None and conv_mode != "materialise":
        tiles = autotune.resolve_tiles(
            "conv_grad_x",
            (grad_out.shape[0], grad_out.shape[1], grad_out.shape[2],
             grad_out.shape[3], w.shape[0], w.shape[2]),
            dtype=f"{grad_out.dtype},{w.dtype}", backend=backend,
            conv_mode=conv_mode, fuse_bwd=z_star is not None,
        )
    if conv_mode == "materialise":
        if z_star is not None:
            grad_out = masked_delta(grad_out, z_star, alpha_inv)
        n, h, w_sp, _ = grad_out.shape
        g_patches, w_rot_flat = conv_im2col_operands(conv_ref.rot180_swap(w), grad_out)
        return int_matmul(g_patches, w_rot_flat).reshape(n, h, w_sp, w.shape[2])
    if backend == "reference":
        return conv_ref.stream_conv_grad_x_ref(
            grad_out, w, z_star=z_star, alpha_inv=alpha_inv,
            bh=None if tiles is None else tiles.bh,
        )
    if z_star is not None:
        return stream_conv_grad_x(
            grad_out, z_star, w, alpha_inv=alpha_inv,
            interpret=(backend == "interpret"), **_stream_tile_kw(tiles),
        )
    return stream_conv(
        grad_out, conv_ref.rot180_swap(w), sf=1, apply_relu=False, pool=False,
        interpret=(backend == "interpret"), **_stream_tile_kw(tiles),
    )
