"""Pure-jnp oracle for the streaming implicit-im2col conv kernel.

Runs the *same algorithm* as the Pallas kernel — a loop over output-row
bands that forms a band-local patch block from overlapping row slices and
feeds one integer matmul — but in plain jnp, so the interpret/pallas
backends have an exhaustively testable reference off-TPU and the
``reference`` backend has a fast CPU implementation.

The defining property mirrors the kernel's: the full ``(N·H·W, K²·C)``
im2col patch matrix is **never materialised**.  Peak transient patch
storage is one row band, ``(N·bh·W, K²·C)`` — a ``bh/H`` fraction — while
every matmul keeps the exact shape of the materialised path's, so XLA CPU
executes the same GEMMs it would unfused (integer accumulation is
order-exact, so the results are bit-identical by construction, and the
test-suite asserts it anyway).

Patch layout matches ``core.layers.im2col`` — segment ``(ki, kj)`` at
channels ``[(ki·K + kj)·C, …)`` — so all paths share one flattened weight
operand: ``w.reshape(K²·C, F)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.activations import nitro_relu, nitro_relu_backward
from repro.core.layers import window_view_2x2
from repro.core.numerics import int_matmul
from repro.core.scaling import scale_forward
from repro.kernels.autotune.tiles import DEFAULT_TILES

#: Pallas row-band height (bounds the VMEM working set) — alias of the
#: single definition in ``kernels.autotune.tiles.DEFAULT_TILES``.
DEFAULT_BH = DEFAULT_TILES.bh
_MAX_AUTO_BH = 16    # auto band cap for the jnp oracle (CPU-tuned)


def conv_geometry(h: int, k: int, bh: int | None, *, pool: bool):
    """Shared row-band geometry: clamp ``bh``, pad H up to a band multiple.

    Returns ``(bh, h_pad, pad_lo=K//2)``.  ``bh=None`` auto-sizes the band
    to ``min(H//2, 16)`` — at least two bands per image, so every layer
    actually streams, with bands large enough that per-band overhead stays
    amortised on CPU.  ``bh`` is forced even when a 2×2 pool epilogue is
    fused so every band pools independently; padded rows beyond ``H`` only
    ever produce output rows the caller slices away.
    """
    if k % 2 == 0:
        raise ValueError(f"streaming conv requires an odd kernel, got K={k}")
    if bh is None:
        bh = min(h // 2, _MAX_AUTO_BH)
    bh = max(min(bh, h), 1)
    if pool and bh % 2:
        bh += 1
    h_pad = -(-h // bh) * bh
    return bh, h_pad, k // 2


def _band_patches(band: jax.Array, k: int, w_out: int) -> jax.Array:
    """(N, bh+2p, W+2p, C) row band → (N·bh·W, K²·C) patch block.

    The ``core.layers.im2col`` stack-of-shifts build, applied to one row
    band instead of the whole image — K² static slices of the band,
    stacked so the channel order is ``(ki·K + kj)·C + c``, identical to
    the materialised path's (one flattened weight layout serves both).
    """
    n = band.shape[0]
    c = band.shape[-1]
    bh = band.shape[1] - (k - 1)
    shifts = [
        band[:, ki:ki + bh, kj:kj + w_out, :]
        for ki in range(k) for kj in range(k)
    ]
    patches = jnp.stack(shifts, axis=3)  # (N, bh, W, K², C)
    return patches.reshape(n * bh * w_out, k * k * c)


def _stream_z_bands(
    x: jax.Array,
    w: jax.Array,
    bh: int,
    *,
    pool: bool,
    relu_bwd_z: jax.Array | None = None,
    relu_bwd_alpha_inv: int = 10,
    int8_ops: bool = False,
):
    """Yield raw int32 pre-activation bands ``z`` of shape (N, bh, W, F).

    The shared core of every streaming oracle entry point: pad once
    (input-sized, not K²×), then one band-local patch matmul per row band.

    ``relu_bwd_z`` activates the fused-backward prologue: each streamed
    row band of ``x`` (= the incoming δ in a grad_x computation) is masked
    by the NITRO-ReLU derivative against the matching ``z_star`` band
    *before* patch formation — like the kernel, the full-size
    post-ReLU-bwd δ never exists, only one masked band at a time.  The
    zero halo is preserved: ``relu_bwd(z*=0, δ=0) = 0``.
    """
    n, h, w_sp, c = x.shape
    k, f = w.shape[0], w.shape[-1]
    bh, h_pad, p = conv_geometry(h, k, bh, pool=pool)
    pad = ((0, 0), (p, p + h_pad - h), (p, p), (0, 0))
    xp = jnp.pad(x, pad)
    zp = None if relu_bwd_z is None else jnp.pad(relu_bwd_z, pad)
    # int8_ops: leave operands int8 — ``int_matmul`` accumulates int8
    # operands into int32 (``preferred_element_type``) bit-identically.
    w_flat = w.reshape(k * k * c, f)
    if not int8_ops:
        w_flat = w_flat.astype(jnp.int32)
    for t in range(h_pad // bh):
        band = xp[:, t * bh:t * bh + bh + 2 * p]
        if zp is not None:
            band = nitro_relu_backward(
                zp[:, t * bh:t * bh + bh + 2 * p], band, relu_bwd_alpha_inv
            )
        patches = _band_patches(band, k, w_sp)
        if not int8_ops:
            patches = patches.astype(jnp.int32)
        z = int_matmul(patches, w_flat)
        yield z.reshape(n, bh, w_sp, f)


def stream_conv_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    pool: bool = False,
    out_dtype=jnp.int32,
    bh: int | None = None,
    operand_dtype: str = "int32",
    relu_bwd_z: jax.Array | None = None,
    relu_bwd_alpha_inv: int = 10,
) -> jax.Array:
    """Streaming fused conv: scale(+relu)(+2×2 maxpool), activation only.

    (N,H,W,C) int × (K,K,C,F) int → (N,H,W,F) — or (N,H//2,W//2,F) with
    the fused pool epilogue.  Bit-exact with im2col + ``nitro_matmul_ref``
    (+ a separate pool pass) on every shape.

    The epilogue runs *per band* — the kernel's behaviour — so what gets
    joined at the end is only the final (pooled, narrowed) activation,
    never the int32 pre-activations.  ``relu_bwd_z`` enables the fused
    backward *prologue* instead (band-wise NITRO-ReLU-derivative masking
    of ``x``; see ``_stream_z_bands``) — the grad_x path.
    """
    if operand_dtype == "int8" and not (
        x.dtype == jnp.int8 and w.dtype == jnp.int8
    ):
        raise ValueError(
            f"operand_dtype='int8' requires int8 operands, got "
            f"{x.dtype}/{w.dtype}"
        )
    h = x.shape[1]
    outs = []
    for z in _stream_z_bands(
        x, w, bh, pool=pool,
        relu_bwd_z=relu_bwd_z, relu_bwd_alpha_inv=relu_bwd_alpha_inv,
        int8_ops=(operand_dtype == "int8"),
    ):
        a = scale_forward(z, sf)
        if apply_relu:
            a = nitro_relu(a, alpha_inv)
        if pool:
            a = jnp.max(window_view_2x2(a), axis=3)
        outs.append(a.astype(out_dtype))
    out = jnp.concatenate(outs, axis=1)
    return out[:, : h // 2] if pool else out[:, :h]


def stream_conv_fwd_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    out_dtype=jnp.int32,
    bh: int | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Streaming training forward: ``(a, z_star)``, both full resolution.

    ``z_star`` keeps int32 (the NITRO-ReLU/STE backward's cache dtype);
    matches ``nitro_matmul_fwd_ref`` over materialised patches bit-for-bit.
    """
    h = x.shape[1]
    # Backward needs z* at full resolution anyway, so join the raw bands
    # once and run scale/ReLU as whole-tensor ops — elementwise chains XLA
    # fuses with the consumer, instead of two per-band concats.
    z = jnp.concatenate(list(_stream_z_bands(x, w, bh, pool=False)), axis=1)
    z_star = scale_forward(z[:, :h], sf)
    a = nitro_relu(z_star, alpha_inv).astype(out_dtype)
    return a, z_star


def stream_conv_grad_w_ref(
    x: jax.Array,
    grad_out: jax.Array,
    *,
    kernel_size: int,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    bh: int | None = None,
) -> jax.Array:
    """Streaming weight gradient: Σ_bands patch_bandᵀ @ relu_bwd(g_band).

    (N,H,W,C) input × (N,H,W,F) grad → (K,K,C,F) int32.  Each band
    contributes one (K²·C, N·bh·W)·(N·bh·W, F) matmul; int32 accumulation
    across bands is order-exact, so this matches ``im2colᵀ @ g`` exactly.

    ``z_star`` enables the fused-backward prologue: each gradient band is
    masked by the NITRO-ReLU derivative (+ the identity STE) against the
    matching ``z_star`` band just before its matmul — band-local, like the
    kernel, so the full-size post-ReLU-bwd δ is never formed.
    """
    n, h, w_sp, c = x.shape
    k = kernel_size
    f = grad_out.shape[-1]
    bh, h_pad, p = conv_geometry(h, k, bh, pool=False)
    xp = jnp.pad(x, ((0, 0), (p, p + h_pad - h), (p, p), (0, 0)))
    g_pad = ((0, 0), (0, h_pad - h), (0, 0), (0, 0))
    gp = jnp.pad(grad_out, g_pad)
    zp = None if z_star is None else jnp.pad(z_star, g_pad)
    grad_w = jnp.zeros((k * k * c, f), jnp.int32)
    for t in range(h_pad // bh):
        band = xp[:, t * bh:t * bh + bh + 2 * p]
        patches = _band_patches(band, k, w_sp).astype(jnp.int32)
        g_band = gp[:, t * bh:t * bh + bh]
        if zp is not None:
            g_band = nitro_relu_backward(
                zp[:, t * bh:t * bh + bh], g_band, alpha_inv
            )
        g_band = g_band.reshape(n * bh * w_sp, f)
        grad_w = grad_w + jax.lax.dot_general(
            patches, g_band.astype(jnp.int32),
            dimension_numbers=(((0,), (0,)), ((), ())),
            preferred_element_type=jnp.int32,
        )
    return grad_w.reshape(k, k, c, f)


def rot180_swap(w: jax.Array) -> jax.Array:
    """(K,K,C,F) → (K,K,F,C): kernel rotated 180° with channels swapped —
    the weight of the 'full' correlation computing grad_x.  The single
    definition of this layout; the dispatcher imports it too."""
    return jnp.flip(w, axis=(0, 1)).transpose(0, 1, 3, 2)


def stream_conv_grad_x_ref(
    grad_out: jax.Array,
    w: jax.Array,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    bh: int | None = None,
) -> jax.Array:
    """Streaming input gradient: 'full' correlation with the rotated kernel.

    grad_x = conv(g, rot180(w) with in/out channels swapped) — the same
    streaming conv with a unit scale factor and no activation.  With
    ``z_star`` the NITRO-ReLU-derivative prologue masks each streamed δ
    band before patch formation (the fused backward path).
    """
    return stream_conv_ref(
        grad_out, rot180_swap(w), sf=1, apply_relu=False, pool=False, bh=bh,
        relu_bwd_z=z_star, relu_bwd_alpha_inv=alpha_inv,
    )
