from repro.kernels.nitro_conv.nitro_conv import (
    stream_conv,
    stream_conv_fwd,
    stream_conv_grad_w,
    stream_conv_grad_w_opt,
    stream_conv_grad_x,
)
from repro.kernels.nitro_conv.ops import (
    CONV_MODES,
    conv_grad_w,
    conv_grad_w_opt,
    conv_grad_x,
    fused_conv,
    fused_conv_fwd,
    resolve_conv_mode,
)
from repro.kernels.nitro_conv.ref import (
    stream_conv_fwd_ref,
    stream_conv_grad_w_ref,
    stream_conv_grad_x_ref,
    stream_conv_ref,
)

__all__ = [
    "CONV_MODES",
    "conv_grad_w",
    "conv_grad_w_opt",
    "conv_grad_x",
    "fused_conv",
    "fused_conv_fwd",
    "resolve_conv_mode",
    "stream_conv",
    "stream_conv_fwd",
    "stream_conv_fwd_ref",
    "stream_conv_grad_w",
    "stream_conv_grad_w_opt",
    "stream_conv_grad_w_ref",
    "stream_conv_grad_x",
    "stream_conv_grad_x_ref",
    "stream_conv_ref",
]
