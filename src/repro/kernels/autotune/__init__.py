"""Per-shape tile autotuning for the fused NITRO kernels.

``tiles``    TileConfig + the candidate search space (VMEM budget, MXU
             alignment) — also the single home of the default tile sizes
             every kernel signature references.
``measure``  the ABBA min-of-N paired timing harness (shared with
             ``benchmarks/``).
``cache``    the on-disk JSON cache: ``(op, shape, dtype, backend,
             conv_mode, fuse_bwd)`` keys + a build fingerprint; atomic
             writes, corruption-tolerant reads.
``state``    process-wide resolution: ``configure`` a cache, dispatchers
             call ``resolve_tiles`` per launch; cache hit/miss counters
             and the int8-path gauge hook a ``MetricRegistry``.
``search``   the measured tuner: candidates → bitwise parity gate → one
             paired-timing session → argmin → cache; plus whole-model
             drivers ``tune_plan`` / ``tune_training``.

Tile choice is *perf-only*: integer accumulation is order-exact, so any
accepted config produces bitwise-identical outputs (parity-gated at tune
time, property-tested in ``tests/test_autotune.py``).
"""

from repro.kernels.autotune.cache import (
    CACHE_FILENAME,
    TileCache,
    build_fingerprint,
    cache_key,
)
from repro.kernels.autotune.measure import time_fn, time_paired
from repro.kernels.autotune.search import (
    ParityError,
    plan_shapes,
    training_shapes,
    tune,
    tune_plan,
    tune_training,
)
from repro.kernels.autotune.state import (
    active_cache,
    configure,
    note_int8_path,
    resolve_tiles,
    set_metrics,
)
from repro.kernels.autotune.tiles import (
    DEFAULT_TILES,
    TileConfig,
    conv_candidates,
    matmul_candidates,
)

__all__ = [
    "CACHE_FILENAME",
    "DEFAULT_TILES",
    "ParityError",
    "TileCache",
    "TileConfig",
    "active_cache",
    "build_fingerprint",
    "cache_key",
    "configure",
    "conv_candidates",
    "matmul_candidates",
    "note_int8_path",
    "plan_shapes",
    "resolve_tiles",
    "set_metrics",
    "time_fn",
    "time_paired",
    "training_shapes",
    "tune",
    "tune_plan",
    "tune_training",
]
