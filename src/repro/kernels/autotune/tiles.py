"""Tile configurations and the per-shape candidate search space.

One ``TileConfig`` describes every tiling knob the fused kernels expose:

  * ``bm / bn / bk`` — the ``nitro_matmul`` family's output-row, output-col
    and contraction tile sizes (MXU-native 128 by default);
  * ``bh``           — the ``nitro_conv`` family's output-row band height
    (bounds the VMEM row ring + patch block);
  * ``bf``           — the conv filter-tile width (the MXU lane dimension).

This module is the **single definition of the defaults** that used to be
duplicated across the four ``nitro_matmul`` kernel signatures and the two
``nitro_conv`` modules — ``DEFAULT_BM/BN/BK``, ``DEFAULT_BH`` and
``DEFAULT_BF`` there are now aliases of ``DEFAULT_TILES``' fields, so the
autotuner, the dispatchers and the docs can never drift apart.

Candidate generation respects two hardware constraints (TPU, per the
Pallas guide):

  * **MXU alignment** — the lane (last) dimension of a VMEM tile wants a
    multiple of 128 (``bn``/``bk``/``bf``); sublane dimensions a multiple
    of 8 (``bm``).  Sub-aligned candidates appear only through clamping,
    i.e. when the problem dimension itself is smaller.
  * **VMEM budget** — a candidate whose working set (operand tiles with
    double buffering + accumulator/patch scratch) exceeds the budget is
    rejected before it is ever measured.  16 MiB/core is the physical
    VMEM; the default budget of 8 MiB leaves headroom for the compiler.

The module is a dependency leaf (stdlib only) so every kernel package can
import it without cycles.
"""

from __future__ import annotations

import dataclasses

#: Physical VMEM per TPU core is ~16 MiB; budget half of it for the
#: kernel working set so the compiler keeps room for spills/pipelining.
VMEM_BUDGET_BYTES = 8 * 1024 * 1024

MXU_LANE = 128     # lane (last-dim) tile granularity the MXU wants
SUBLANE = 8        # sublane granularity for int32 tiles


@dataclasses.dataclass(frozen=True)
class TileConfig:
    """One complete tiling choice for the fused kernel family."""

    bm: int = 128
    bn: int = 128
    bk: int = 128
    bh: int = 8
    bf: int = 128

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "TileConfig":
        fields = {f.name for f in dataclasses.fields(cls)}
        vals = {k: int(v) for k, v in d.items() if k in fields}
        cfg = cls(**vals)
        for f in dataclasses.fields(cls):
            if getattr(cfg, f.name) < 1:
                raise ValueError(f"tile {f.name} must be >= 1, got {cfg}")
        return cfg


#: The historical hand-picked defaults every kernel falls back to.
DEFAULT_TILES = TileConfig()


def matmul_vmem_bytes(bm: int, bn: int, bk: int, *, itemsize: int = 4) -> int:
    """Upper-bound VMEM working set of one ``nitro_matmul`` grid step:
    double-buffered x/w operand tiles + the int32 accumulator and output
    tile (``itemsize=1`` for the int8-operand path's input tiles)."""
    return 2 * (bm * bk + bk * bn) * itemsize + 2 * bm * bn * 4


def conv_vmem_bytes(
    bh: int, bf: int, *, h: int, w: int, c: int, k: int, itemsize: int = 4
) -> int:
    """Upper-bound VMEM working set of one ``nitro_conv`` band step: the
    input row ring, the band patch block, and double-buffered weight and
    output tiles."""
    ring = (bh + k - 1) * (w + k - 1) * c * itemsize
    patches = bh * w * k * k * c * itemsize
    return ring + patches + 2 * k * k * c * bf * 4 + 2 * bh * w * bf * 4


def _clamped(candidates, dim: int) -> list[int]:
    """Clamp candidate tile sizes to the problem dimension, dedup by the
    *effective* (clamped) value, keep ascending order."""
    seen: dict[int, None] = {}
    for v in candidates:
        seen.setdefault(max(1, min(v, dim)), None)
    return list(seen)


def matmul_candidates(
    m: int, k: int, n: int, *, budget: int = VMEM_BUDGET_BYTES,
    itemsize: int = 4,
) -> list[TileConfig]:
    """MXU-aligned, VMEM-feasible (bm, bn, bk) candidates for an (M,K)·(K,N)
    fused matmul.  The default config is always first, so a search whose
    winner is the argmin can never regress below the hand-picked tiles."""
    out = [DEFAULT_TILES]
    for bm in _clamped((32, 64, 128, 256), m):
        for bn in _clamped((128, 256), n):
            for bk in _clamped((128, 256, 512), k):
                cand = TileConfig(bm=bm, bn=bn, bk=bk)
                eff = (min(128, m), min(128, n), min(128, k))
                if (bm, bn, bk) == eff:
                    continue  # clamps to the default geometry — already in
                if matmul_vmem_bytes(bm, bn, bk, itemsize=itemsize) <= budget:
                    out.append(cand)
    return out


def conv_candidates(
    h: int, w: int, c: int, kernel_size: int, f: int,
    *, budget: int = VMEM_BUDGET_BYTES, itemsize: int = 4,
) -> list[TileConfig]:
    """VMEM-feasible (bh, bf) candidates for a streaming conv over
    (H, W, C) with K×K filters and F output channels.  ``bh`` varies the
    row-band height (the VMEM ring/patch working set), ``bf`` the
    MXU-lane filter tile.  The default config is always first."""
    out = [DEFAULT_TILES]
    k = kernel_size
    for bh in _clamped((2, 4, 8, 16, 32), h):
        for bf in _clamped((128, 256), f):
            cand = TileConfig(bh=bh, bf=bf)
            eff = (min(8, h), min(128, f))
            if (bh, bf) == eff:
                continue  # clamps to the default geometry — already in
            if conv_vmem_bytes(bh, bf, h=h, w=w, c=c, k=k,
                               itemsize=itemsize) <= budget:
                out.append(cand)
    return out
