"""On-disk JSON cache of tuned tile configurations.

One cache file holds every tuned entry for one build fingerprint::

    {
      "fingerprint": "repro=0.8.0|jax=0.4.xx|backend=cpu",
      "entries": {
        "matmul|256x1152x128|int8,int8|pallas|-|0|0": {"bm": 64, ...},
        ...
      }
    }

Design points:

* **Keyed** by ``(op, shape, dtype, backend, conv_mode, fuse_bwd,
  fuse_opt)`` —
  every axis that changes which kernel runs or how its grid is laid
  out.  Tile choice never changes *results* (integer accumulation is
  order-exact), only speed, so a stale entry is a perf bug at worst —
  but the **fingerprint** still invalidates the whole file when the
  repro version, jax version, or jax backend changes, because a timing
  measured under a different compiler is meaningless.
* **Corruption-safe**: an unreadable / wrong-shape / stale-fingerprint
  file loads as an empty cache (re-tune, don't crash).
* **Concurrent-writer-safe**: writes hold an exclusive ``flock`` on a
  sidecar ``<path>.lock`` (the cache file itself is replaced, so its fd
  cannot carry the lock) while they re-read the file, merge, write a
  temp file in the same directory, and ``os.replace`` it — atomic on
  POSIX.  Readers never observe a torn file; parallel writers — other
  threads *or* other processes — never lose each other's entries.
"""

from __future__ import annotations

import contextlib
import json
import os
import tempfile
import threading

try:
    import fcntl
except ImportError:  # non-POSIX: degrade to atomic-replace-only writes
    fcntl = None

from .tiles import TileConfig

CACHE_FILENAME = "tile_cache.json"


def build_fingerprint() -> str:
    """Identity of the code + compiler the cached timings were taken on."""
    import jax

    from repro.obs.metrics import REPRO_VERSION

    return (f"repro={REPRO_VERSION}|jax={jax.__version__}"
            f"|backend={jax.default_backend()}")


def cache_key(op: str, shape, dtype: str, backend: str,
              conv_mode: str = "", fuse_bwd: bool = False,
              fuse_opt: bool = False) -> str:
    """The canonical string key for one tuning problem."""
    dims = "x".join(str(int(d)) for d in shape)
    return (f"{op}|{dims}|{dtype}|{backend}|{conv_mode or '-'}"
            f"|{int(fuse_bwd)}|{int(fuse_opt)}")


class TileCache:
    """A (path-backed) mapping from cache keys to ``TileConfig``."""

    def __init__(self, path: str, *, fingerprint: str | None = None):
        path = os.fspath(path)
        if os.path.isdir(path) or path.endswith(os.sep):
            path = os.path.join(path, CACHE_FILENAME)
        self.path = path
        self.fingerprint = fingerprint or build_fingerprint()
        self._lock = threading.Lock()
        self._entries: dict[str, TileConfig] = self._load()

    # ---- persistence ------------------------------------------------------

    def _load(self) -> dict[str, TileConfig]:
        """Parse the file; anything unusable degrades to an empty cache."""
        try:
            with open(self.path) as f:
                payload = json.load(f)
            if payload.get("fingerprint") != self.fingerprint:
                return {}  # stale build — timings no longer trustworthy
            entries = payload["entries"]
            return {str(k): TileConfig.from_json(v)
                    for k, v in entries.items()}
        except (OSError, ValueError, KeyError, AttributeError, TypeError):
            return {}

    @contextlib.contextmanager
    def _file_lock(self):
        """Exclusive inter-process lock for read-merge-write cycles."""
        if fcntl is None:
            yield
            return
        os.makedirs(os.path.dirname(self.path) or ".", exist_ok=True)
        fd = os.open(self.path + ".lock", os.O_CREAT | os.O_RDWR, 0o644)
        try:
            fcntl.flock(fd, fcntl.LOCK_EX)
            yield
        finally:
            os.close(fd)  # closing releases the flock

    def _write(self) -> None:
        """Merge-with-disk then atomic-rename; caller holds ``self._lock``
        and ``_file_lock`` (so the disk state cannot move between the
        re-read and the replace)."""
        on_disk = self._load()
        on_disk.update(self._entries)
        self._entries = on_disk
        payload = {
            "fingerprint": self.fingerprint,
            "entries": {k: v.to_json()
                        for k, v in sorted(self._entries.items())},
        }
        d = os.path.dirname(self.path) or "."
        os.makedirs(d, exist_ok=True)
        fd, tmp = tempfile.mkstemp(prefix=".tile_cache.", dir=d)
        try:
            with os.fdopen(fd, "w") as f:
                json.dump(payload, f, indent=2, sort_keys=True)
                f.write("\n")
            os.replace(tmp, self.path)  # atomic publish
        except BaseException:
            try:
                os.unlink(tmp)
            finally:
                raise

    # ---- mapping API ------------------------------------------------------

    def get(self, key: str) -> TileConfig | None:
        with self._lock:
            return self._entries.get(key)

    def put(self, key: str, tiles: TileConfig) -> None:
        with self._lock, self._file_lock():
            self._entries[key] = tiles
            self._write()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def __contains__(self, key: str) -> bool:
        return self.get(key) is not None

    def keys(self) -> list[str]:
        with self._lock:
            return sorted(self._entries)
