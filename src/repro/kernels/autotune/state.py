"""Process-wide autotune state: the active cache and the metrics hooks.

The dispatchers (``nitro_matmul/ops.py``, ``nitro_conv/ops.py``) call
:func:`resolve_tiles` on every kernel launch they trace.  Resolution is
deliberately cheap and side-effect-free when tuning is off:

* no cache configured → ``None`` (kernels use their historical
  ``DEFAULT_TILES`` defaults, exactly as before this module existed);
* cache configured, key present → the tuned :class:`TileConfig`
  (+ ``kernel_tile_cache_hits_total``);
* cache configured, key absent → ``None`` fallback
  (+ ``kernel_tile_cache_misses_total``) — resolution never *tunes*;
  measurement happens only through :mod:`repro.kernels.autotune.search`.

Note on jit: dispatchers resolve tiles at **trace** time, so the
counters count trace-time resolutions, and a compiled plan bakes in
whatever the cache held when it was traced — tune before compiling.
"""

from __future__ import annotations

from .cache import TileCache, cache_key
from .tiles import TileConfig

_active_cache: TileCache | None = None
_metrics = None  # (hits_counter, misses_counter, int8_gauge) or None


def configure(cache: "TileCache | str | None") -> TileCache | None:
    """Install (or clear, with ``None``) the process-wide tile cache.

    Accepts a ready ``TileCache`` or a path (directory or file) to open
    one at.  Returns the installed cache for convenience.
    """
    global _active_cache
    if cache is None:
        _active_cache = None
    elif isinstance(cache, TileCache):
        _active_cache = cache
    else:
        _active_cache = TileCache(cache)
    return _active_cache


def active_cache() -> TileCache | None:
    return _active_cache


def set_metrics(registry) -> None:
    """Register the autotune metric families on a ``MetricRegistry``.

    Passing ``None`` detaches metrics (the default state).
    """
    global _metrics
    if registry is None:
        _metrics = None
        return
    _metrics = (
        registry.counter(
            "kernel_tile_cache_hits_total",
            "Tile resolutions served from the autotune cache"),
        registry.counter(
            "kernel_tile_cache_misses_total",
            "Tile resolutions that fell back to DEFAULT_TILES"),
        registry.gauge(
            "kernel_int8_path_active",
            "1 when a plan step issues int8-operand MXU dots, else 0",
            labels=("layer",)),
    )


def note_int8_path(layer: str, active: bool) -> None:
    """Record whether ``layer`` took the int8-operand path (gauge)."""
    if _metrics is not None:
        _metrics[2].labels(layer=str(layer)).set(int(active))


def resolve_tiles(op: str, shape, *, dtype: str, backend: str,
                  conv_mode: str = "",
                  fuse_bwd: bool = False,
                  fuse_opt: bool = False) -> TileConfig | None:
    """The tuned tiles for one problem, or ``None`` for the defaults."""
    cache = _active_cache
    if cache is None:
        return None
    tiles = cache.get(cache_key(op, shape, dtype, backend,
                                conv_mode, fuse_bwd, fuse_opt))
    if _metrics is not None:
        _metrics[0 if tiles is not None else 1].inc()
    return tiles
