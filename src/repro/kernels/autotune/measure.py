"""Contention-robust kernel timing (ABBA min-of-N paired harness).

This is the measurement substrate the autotuner *and* the benchmark
suite share — it moved here from ``benchmarks/common.py`` (which now
delegates) so that library code can time candidates without importing
the benchmark package.
"""

from __future__ import annotations

import time

import jax


def time_fn(fn, *args, iters: int = 10, warmup: int = 2, **kw) -> float:
    """Median wall time per call in microseconds (blocks on results)."""
    for _ in range(warmup):
        jax.block_until_ready(fn(*args, **kw))
    times = []
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(*args, **kw))
        times.append(time.perf_counter() - t0)
    times.sort()
    return times[len(times) // 2] * 1e6


def time_paired(fns: dict, *args, iters: int, **kw) -> dict:
    """Contention-robust paired timing: interleaved min-of-N per variant.

    This container's CPU swings ~2× with co-tenant load; timing each
    variant in its own block lets that drift masquerade as a speedup (or
    a regression).  Every round therefore times each variant once,
    back-to-back, alternating the order between rounds (ABBA) to cancel
    first-mover cache effects.  Per variant the *minimum* over rounds is
    reported — the timeit rationale: the minimum bounds the intrinsic
    cost, while co-tenant interference only ever inflates a sample.
    (All variants are jit-warmed before the first round.)
    """
    for fn in fns.values():  # jit warm-up
        jax.block_until_ready(fn(*args, **kw))
    names = list(fns)
    best = {m: float("inf") for m in names}
    for i in range(iters):
        for m in names if i % 2 == 0 else reversed(names):
            t0 = time.perf_counter()
            jax.block_until_ready(fns[m](*args, **kw))
            best[m] = min(best[m], (time.perf_counter() - t0) * 1e6)
    return best
