"""The measured tile search: candidates → parity gate → ABBA timing → cache.

``tune()`` solves one problem — one ``(op, shape, dtype, backend,
conv_mode, fuse_bwd)`` cache key:

1. build deterministic integer operands for the op (fixed PRNG seed —
   values don't affect timing, and integer kernels have no data-dependent
   cost);
2. enumerate VMEM-feasible candidates (``tiles.matmul_candidates`` /
   ``conv_candidates``) plus the *effective default* config — the tiles
   the dispatcher would use with no cache entry — so the winner can never
   be slower than the fallback;
3. **parity-gate**: run every candidate once and require bitwise equality
   with the reference-backend oracle (integer accumulation is order-exact,
   so any mismatch is a bug, not noise — ``ParityError``);
4. time all surviving variants in **one** ``measure.time_paired`` session
   (interleaved ABBA min-of-N), so the tuned-vs-default comparison is
   contention-robust and ``winner ≤ default`` holds by construction;
5. store the argmin in the cache (if one is given) and return
   ``(winner, measurements)``.

Ops vocabulary (shapes are the cache-key shapes):

====================  =========================  =========================
op                    shape                      dispatcher
====================  =========================  =========================
``matmul``            (M, K, N)                  ``fused_matmul``
``matmul_fwd``        (M, K, N)                  ``fused_matmul_fwd``
``matmul_grad_w``     (B, M, N)                  ``grad_w_matmul``
``matmul_grad_x``     (B, N, M)                  ``grad_x_matmul``
``conv[_fwd]``        (N, H, W, C, K, F)         ``fused_conv[_fwd]``
``conv_grad_w``       (N, H, W, C, K, F)         ``conv_grad_w``
``conv_grad_x``       (N, H, W, F, K, C)         ``conv_grad_x``
====================  =========================  =========================

Untunable combinations return ``(None, {})``: the reference matmul has no
tile knobs, and the materialise conv gradients are plain ``int_matmul``
calls.  ``tune_plan`` / ``tune_training`` enumerate a whole inference
plan / training config and tune every not-yet-cached problem.

Kernel dispatchers are imported lazily inside functions: the dispatchers
import :mod:`repro.kernels.autotune.state` at module level, so an eager
import here would be circular.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .cache import TileCache, cache_key
from .measure import time_paired
from .tiles import DEFAULT_TILES, TileConfig, conv_candidates, matmul_candidates

MATMUL_OPS = ("matmul", "matmul_fwd", "matmul_grad_w", "matmul_grad_x")
CONV_OPS = ("conv", "conv_fwd", "conv_grad_w", "conv_grad_x")
GRAD_OPS = ("matmul_grad_w", "matmul_grad_x", "conv_grad_w", "conv_grad_x")


class ParityError(AssertionError):
    """A candidate tile config changed kernel *results* — never acceptable."""


def _assert_parity(got, want, op: str, tiles) -> None:
    for g, w in zip(jax.tree_util.tree_leaves(got),
                    jax.tree_util.tree_leaves(want)):
        if g.shape != w.shape or not bool((g == w).all()):
            raise ParityError(
                f"{op}: tiles {tiles} changed the result — tile choice "
                f"must be bitwise-invariant"
            )


def _rand(key, shape, dtype) -> jax.Array:
    return jax.random.randint(key, shape, -63, 64, jnp.int32).astype(dtype)


def _operands(op: str, shape, dtype: str, seed: int):
    """Deterministic integer operands for one tuning problem."""
    x_dt, w_dt = (jnp.dtype(s) for s in dtype.split(","))
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    if op in ("matmul", "matmul_fwd"):
        m, k, n = shape
        return _rand(ks[0], (m, k), x_dt), _rand(ks[1], (k, n), w_dt)
    if op == "matmul_grad_w":
        b, m, n = shape  # x, delta, z_star
        return (_rand(ks[0], (b, m), x_dt), _rand(ks[1], (b, n), jnp.int32),
                _rand(ks[2], (b, n), jnp.int32))
    if op == "matmul_grad_x":
        b, n, m = shape  # delta, z_star, w
        return (_rand(ks[0], (b, n), jnp.int32),
                _rand(ks[1], (b, n), jnp.int32), _rand(ks[2], (m, n), w_dt))
    if op in ("conv", "conv_fwd"):
        n, h, w, c, k, f = shape
        return (_rand(ks[0], (n, h, w, c), x_dt),
                _rand(ks[1], (k, k, c, f), w_dt))
    if op == "conv_grad_w":
        n, h, w, c, k, f = shape  # x, delta, z_star (+k via shape)
        return (_rand(ks[0], (n, h, w, c), x_dt),
                _rand(ks[1], (n, h, w, f), jnp.int32),
                _rand(ks[2], (n, h, w, f), jnp.int32))
    if op == "conv_grad_x":
        n, h, w, f, k, c = shape  # delta, z_star, weight
        return (_rand(ks[0], (n, h, w, f), jnp.int32),
                _rand(ks[1], (n, h, w, f), jnp.int32),
                _rand(ks[2], (k, k, c, f), w_dt))
    raise ValueError(f"unknown op {op!r}; one of {MATMUL_OPS + CONV_OPS}")


def _build(op: str, operands, *, shape, backend: str, conv_mode: str,
           fuse_bwd: bool, tiles):
    """A zero-arg callable running one dispatcher variant (blocks on it)."""
    from repro.core.scaling import conv_scale_factor, linear_scale_factor
    from repro.kernels.nitro_conv import ops as conv_ops
    from repro.kernels.nitro_matmul import ops as mm_ops
    from repro.kernels.nitro_matmul.ref import masked_delta

    if op == "matmul":
        x, w = operands
        return lambda: mm_ops.fused_matmul(
            x, w, sf=linear_scale_factor(x.shape[-1]), backend=backend,
            tiles=tiles)
    if op == "matmul_fwd":
        x, w = operands
        return lambda: mm_ops.fused_matmul_fwd(
            x, w, sf=linear_scale_factor(x.shape[-1]), backend=backend,
            tiles=tiles)
    if op == "matmul_grad_w":
        x, delta, z_star = operands
        return lambda: mm_ops.grad_w_matmul(
            x, delta, z_star, backend=backend, tiles=tiles)
    if op == "matmul_grad_x":
        delta, z_star, w = operands
        return lambda: mm_ops.grad_x_matmul(
            delta, z_star, w, backend=backend, tiles=tiles)
    if op in ("conv", "conv_fwd"):
        x, w = operands
        sf = conv_scale_factor(w.shape[0], x.shape[-1])
        entry = conv_ops.fused_conv if op == "conv" else conv_ops.fused_conv_fwd
        return lambda: entry(
            x, w, sf=sf, backend=backend, conv_mode=conv_mode, tiles=tiles)
    if op == "conv_grad_w":
        x, delta, z_star = operands
        k = shape[4]
        if not fuse_bwd:
            delta, z_star = masked_delta(delta, z_star, 10), None
        return lambda: conv_ops.conv_grad_w(
            x, delta, kernel_size=k, z_star=z_star, backend=backend,
            conv_mode=conv_mode, tiles=tiles)
    delta, z_star, w = operands  # conv_grad_x
    if not fuse_bwd:
        delta, z_star = masked_delta(delta, z_star, 10), None
    return lambda: conv_ops.conv_grad_x(
        delta, w, z_star=z_star, backend=backend, conv_mode=conv_mode,
        tiles=tiles)


def _untunable(op: str, backend: str, conv_mode: str) -> bool:
    if op in MATMUL_OPS:
        return backend == "reference"  # pure jnp matmul: no tile knobs
    if conv_mode == "materialise":
        # Forward materialise routes through fused_matmul (tunable off the
        # reference backend); the gradients are plain int_matmul calls.
        return op in ("conv_grad_w", "conv_grad_x") or backend == "reference"
    return False


def _default_config(op: str, shape, backend: str, conv_mode: str) -> TileConfig:
    """The tiles the dispatcher uses when the cache has no entry.

    The reference streaming conv's untuned band height is
    ``conv_geometry``'s auto choice (``min(H//2, 16)``), not
    ``DEFAULT_TILES.bh`` — the probe must time what the fallback
    actually runs.
    """
    if op in CONV_OPS and conv_mode != "materialise" and backend == "reference":
        from repro.kernels.nitro_conv.ref import conv_geometry

        h, k = shape[1], shape[4]  # K sits at index 4 in both conv layouts
        bh, _, _ = conv_geometry(h, k, None, pool=False)
        return TileConfig(bh=bh)
    return DEFAULT_TILES


def _candidates(op: str, shape, dtype: str, backend: str,
                conv_mode: str) -> list[TileConfig]:
    itemsize = max(jnp.dtype(s).itemsize for s in dtype.split(","))
    if op in MATMUL_OPS:
        m, k, n = shape if op != "matmul_grad_w" else (
            shape[1], shape[0], shape[2])
        if op == "matmul_grad_x":
            m, k, n = shape[0], shape[2], shape[1]
        return matmul_candidates(m, k, n, itemsize=itemsize)
    if conv_mode == "materialise":  # inner fused_matmul over the patch matrix
        n, h, w, c, k, f = shape
        return matmul_candidates(n * h * w, k * k * c, f, itemsize=itemsize)
    if op == "conv_grad_x":
        n, h, w, f, k, c = shape
        return conv_candidates(h, w, f, k, c, itemsize=itemsize)
    n, h, w, c, k, f = shape
    cands = conv_candidates(h, w, c, k, f, itemsize=itemsize)
    if backend == "reference":
        # the jnp oracle only has the bh knob — dedup away the bf axis
        seen: dict[int, TileConfig] = {}
        for cfg in cands:
            seen.setdefault(cfg.bh, TileConfig(bh=cfg.bh))
        return list(seen.values())
    return cands


def tune(
    op: str,
    shape,
    *,
    dtype: str = "int32,int32",
    backend: str = "auto",
    conv_mode: str = "stream",
    fuse_bwd: bool | None = None,
    cache: TileCache | None = None,
    iters: int = 5,
    seed: int = 0,
) -> tuple[TileConfig | None, dict]:
    """Tune one problem; returns ``(winner, {config: best_us})``.

    ``(None, {})`` means the combination has no tile knobs (reference
    matmul, materialise conv gradients) — the fallback is already optimal.
    """
    from repro.kernels.nitro_matmul.ops import resolve_backend

    backend = resolve_backend(backend)
    conv_mode = conv_mode if op in CONV_OPS else ""
    if fuse_bwd is None:
        fuse_bwd = op in GRAD_OPS
    if _untunable(op, backend, conv_mode):
        return None, {}
    operands = _operands(op, shape, dtype, seed)

    configs: dict[TileConfig, object] = {}
    default = _default_config(op, shape, backend, conv_mode)
    for cfg in [default, *_candidates(op, shape, dtype, backend, conv_mode)]:
        if cfg not in configs:
            configs[cfg] = _build(
                op, operands, shape=shape, backend=backend,
                conv_mode=conv_mode, fuse_bwd=fuse_bwd, tiles=cfg)

    # Parity gate: every candidate must reproduce the reference oracle
    # bitwise before it is allowed into the timing pool.
    want = jax.block_until_ready(_build(
        op, operands, shape=shape, backend="reference",
        conv_mode=conv_mode, fuse_bwd=fuse_bwd, tiles=None)())
    for cfg, fn in configs.items():
        _assert_parity(jax.block_until_ready(fn()), want, op, cfg)

    times = time_paired(configs, iters=iters)
    winner = min(times, key=times.get)
    if cache is not None:
        cache.put(cache_key(op, shape, dtype, backend, conv_mode, fuse_bwd),
                  winner)
    return winner, times


# ---------------------------------------------------------------------------
# Whole-model drivers
# ---------------------------------------------------------------------------


def plan_shapes(plan, batch: int) -> list[dict]:
    """The tuning problems an ``ExecutionPlan`` resolves at trace time.

    Mirrors ``infer.plan._execute``'s shape/dtype flow: the network input
    enters as int32, each step's output dtype is its meta's, and linear
    steps flatten whatever spatial shape precedes them.
    """
    problems = []
    shape = tuple(int(d) for d in plan.input_shape)
    act_dt = "int32"
    for w, meta in zip(plan.weights, plan.metas):
        w_dt = str(w.dtype)
        if meta.kind == "conv":
            h, w_sp, c = shape
            k, f = meta.kernel_size, int(w.shape[-1])
            problems.append(dict(
                op="conv", shape=(batch, h, w_sp, c, k, f),
                dtype=f"{act_dt},{w_dt}", conv_mode=meta.conv_mode,
                fuse_bwd=False))
            shape = (h // 2, w_sp // 2, f) if meta.pool else (h, w_sp, f)
        else:
            feat = 1
            for d in shape:
                feat *= d
            problems.append(dict(
                op="matmul", shape=(batch, feat, int(w.shape[-1])),
                dtype=f"{act_dt},{w_dt}", conv_mode="", fuse_bwd=False))
            shape = (int(w.shape[-1]),)
        act_dt = meta.out_dtype
    return problems


def training_shapes(cfg, batch: int, *, conv_mode: str = "stream") -> list[dict]:
    """The fused fwd/bwd kernel problems one train step resolves.

    Enumerates each block's forward (``*_fwd``) and both gradient matmuls/
    convs — the kernel-backed hot path.  (Learning/output layers run plain
    ``int_matmul``; they have no tile knobs.)  Shape flow follows
    ``core.blocks.init_block``.
    """
    problems = []
    shape = tuple(int(d) for d in cfg.input_shape)
    for spec in cfg.blocks:
        if spec.kind == "conv":
            h, w_sp, c = shape
            k, f = spec.kernel_size, spec.out_features
            problems += [
                dict(op="conv_fwd", shape=(batch, h, w_sp, c, k, f),
                     dtype="int32,int32", conv_mode=conv_mode,
                     fuse_bwd=False),
                dict(op="conv_grad_w", shape=(batch, h, w_sp, c, k, f),
                     dtype="int32,int32", conv_mode=conv_mode, fuse_bwd=True),
                dict(op="conv_grad_x", shape=(batch, h, w_sp, f, k, c),
                     dtype="int32,int32", conv_mode=conv_mode, fuse_bwd=True),
            ]
            shape = (h // 2, w_sp // 2, f) if spec.pool else (h, w_sp, f)
        else:
            m = 1
            for d in shape:
                m *= d
            n = spec.out_features
            problems += [
                dict(op="matmul_fwd", shape=(batch, m, n),
                     dtype="int32,int32", conv_mode="", fuse_bwd=False),
                dict(op="matmul_grad_w", shape=(batch, m, n),
                     dtype="int32,int32", conv_mode="", fuse_bwd=True),
                dict(op="matmul_grad_x", shape=(batch, n, m),
                     dtype="int32,int32", conv_mode="", fuse_bwd=True),
            ]
            shape = (n,)
    return problems


def _tune_problems(problems, *, backend: str, cache: TileCache,
                   iters: int, seed: int) -> dict:
    from repro.kernels.nitro_matmul.ops import resolve_backend

    backend = resolve_backend(backend)
    tuned = {}
    for p in problems:
        key = cache_key(p["op"], p["shape"], p["dtype"], backend,
                        p["conv_mode"], p["fuse_bwd"])
        if key in cache:
            tuned[key] = cache.get(key)  # measurement-free: already tuned
            continue
        winner, _ = tune(
            p["op"], p["shape"], dtype=p["dtype"], backend=backend,
            conv_mode=p["conv_mode"], fuse_bwd=p["fuse_bwd"], cache=cache,
            iters=iters, seed=seed)
        if winner is not None:
            tuned[key] = winner
    return tuned


def tune_plan(plan, batch: int, *, cache: TileCache, iters: int = 3,
              seed: int = 0) -> dict:
    """Tune every not-yet-cached problem of one inference plan.

    Returns ``{cache_key: TileConfig}`` for the tunable problems.  Run
    *before* ``compile_plan`` traces — jit bakes in the tiles it resolves.
    """
    return _tune_problems(plan_shapes(plan, batch), backend=plan.backend,
                          cache=cache, iters=iters, seed=seed)


def tune_training(cfg, batch: int, *, cache: TileCache, backend: str = "auto",
                  conv_mode: str = "stream", iters: int = 3,
                  seed: int = 0) -> dict:
    """Tune every not-yet-cached fused fwd/bwd problem of one train config."""
    return _tune_problems(
        training_shapes(cfg, batch, conv_mode=conv_mode), backend=backend,
        cache=cache, iters=iters, seed=seed)
