"""Pure-jnp oracle for the fused NITRO matmul kernel.

Composes the three reference ops the kernel fuses — integer matmul, NITRO
Scaling Layer, NITRO-ReLU — exactly as `repro.core` defines them.  The
kernel must match this bit-for-bit on every shape/dtype swept by the tests.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.activations import nitro_relu, nitro_relu_backward
from repro.core.numerics import int_matmul
from repro.core.scaling import scale_backward, scale_forward


def nitro_matmul_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    operand_dtype: str = "int32",
) -> jax.Array:
    if operand_dtype == "int8":
        # int8-operand path: skip the int32 lift — ``int_matmul``'s
        # ``preferred_element_type=int32`` accumulates int8 operands into
        # the same int32 values bit-for-bit.
        if not (x.dtype == jnp.int8 and w.dtype == jnp.int8):
            raise ValueError(
                f"operand_dtype='int8' requires int8 operands, got "
                f"{x.dtype}/{w.dtype}"
            )
        z = int_matmul(x, w)
    else:
        z = int_matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    z_star = scale_forward(z, sf)
    if apply_relu:
        z_star = nitro_relu(z_star, alpha_inv)
    return z_star.astype(out_dtype)


def nitro_matmul_fwd_ref(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    out_dtype=jnp.int32,
) -> tuple[jax.Array, jax.Array]:
    """Training-forward oracle: ``(a, z_star)``, matching ``nitro_matmul_fwd``.

    ``z_star`` is always int32 — it is the tensor ``core.blocks`` caches for
    the NITRO-ReLU/STE backward, so its dtype must match ``scale_forward``.
    """
    z = int_matmul(x.astype(jnp.int32), w.astype(jnp.int32))
    z_star = scale_forward(z, sf)
    a = nitro_relu(z_star, alpha_inv)
    return a.astype(out_dtype), z_star


def masked_delta(delta: jax.Array, z_star: jax.Array, alpha_inv: int) -> jax.Array:
    """The backward prologue the grad kernels fuse, composed from the
    reference ops: NITRO-ReLU derivative then the scaling STE (identity).

    The single jnp definition of that composition — the grad oracles
    below, the conv dispatcher's materialise pre-mask and ``grad_ops``'s
    unfused escape hatch all share it, so the fused/unfused parity oracle
    cannot drift apart across modules.
    """
    return scale_backward(nitro_relu_backward(z_star, delta, alpha_inv))


def nitro_matmul_grad_w_ref(
    x: jax.Array,
    delta: jax.Array,
    z_star: jax.Array,
    *,
    alpha_inv: int = 10,
) -> jax.Array:
    """Weight-gradient oracle: ``xᵀ @ relu_bwd(z*, δ)`` — matches
    ``nitro_matmul_grad_w`` bit-for-bit (int32 accumulation is order-exact)."""
    g = masked_delta(delta.astype(jnp.int32), z_star, alpha_inv)
    return int_matmul(x.astype(jnp.int32).T, g)


def nitro_matmul_grad_x_ref(
    delta: jax.Array,
    z_star: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
) -> jax.Array:
    """Input-gradient oracle: ``relu_bwd(z*, δ) @ wᵀ`` — matches
    ``nitro_matmul_grad_x`` bit-for-bit."""
    g = masked_delta(delta.astype(jnp.int32), z_star, alpha_inv)
    return int_matmul(g, w.astype(jnp.int32).T)
