"""Fused NITRO matmul Pallas TPU kernel.

Computes, in one pass over VMEM tiles::

    z   = x @ w                      (int8/int32 inputs, int32 MXU accumulate)
    z*  = ⌊z / SF⌋                   (NITRO Scaling Layer)
    out = NITRO-ReLU(z*)             (optional, fused on the VPU)

This is the paper's per-layer hot loop (§3.2).  The reference NITRO-D
library materialises ``z`` (int32) in HBM, reads it back for the scaling
layer, and again for the activation — three HBM round-trips of the widest
tensor in the network.  Fusing them keeps ``z`` in a VMEM scratch
accumulator and writes only the int8 activation back to HBM:

    HBM bytes per layer:  unfused  M·N·(4+4+4+1)   →   fused  M·N·1 (+in/w)

TPU adaptation notes (DESIGN.md §2):
  * tiles are 128-aligned for the MXU systolic array; int8×int8→int32 is
    the MXU's double-rate integer mode (394 TOP/s on v5e vs 197 TF/s bf16);
  * ⌊z/SF⌋ is split as SF = residual·2^shift — the 2^shift part is an
    arithmetic right shift (exact floor semantics for two's-complement),
    the odd residual is one VPU integer divide;
  * grid is (M/bm, N/bn, K/bk) with K innermost ("arbitrary"), the
    canonical Pallas accumulation pattern.

The backward pass gets the same treatment: ``nitro_matmul_grad_w`` /
``nitro_matmul_grad_x`` are true backward kernels whose *prologue* applies
the NITRO-ReLU derivative (+ the scaling STE, which is the identity) to
each incoming δ tile in VMEM before the MXU gradient matmuls — the
post-ReLU-bwd δ tensor, which the unfused composition round-trips through
HBM once per local-loss block, never leaves VMEM.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import mu_int8, nitro_relu_backward
from repro.core.scaling import pow2_split
from repro.kernels.autotune.tiles import DEFAULT_TILES
from repro.kernels.integer_sgd.integer_sgd import integer_sgd_tile

# jax renamed TPUCompilerParams → CompilerParams; support both.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

# MXU-native tile sizes — aliases of the single definition in
# ``kernels.autotune.tiles.DEFAULT_TILES`` (shared with the conv kernel,
# the autotuner, and the docs).
DEFAULT_BM = DEFAULT_TILES.bm
DEFAULT_BN = DEFAULT_TILES.bn
DEFAULT_BK = DEFAULT_TILES.bk


def _scale_tile(z, sf_shift: int, sf_residual: int):
    """NITRO Scaling on a VMEM tile: ⌊z / (residual · 2^shift)⌋.

    Arithmetic right shift implements the power-of-two floor division
    exactly; composing the two floors is exact because both divisors are
    positive (⌊⌊z/a⌋/b⌋ = ⌊z/(ab)⌋).
    """
    if sf_shift:
        z = jax.lax.shift_right_arithmetic(z, sf_shift)
    if sf_residual != 1:
        z = jnp.floor_divide(z, sf_residual)
    return z


def _relu_tile(z, alpha_inv: int, mu: int):
    """NITRO-ReLU on a VMEM tile (VPU select/min/max/floor-div)."""
    neg = jnp.floor_divide(jnp.maximum(z, -127), alpha_inv)
    pos = jnp.minimum(z, 127)
    return jnp.where(z < 0, neg, pos) - mu


def _relu_bwd_tile(g, z, alpha_inv: int):
    """NITRO-ReLU derivative + STE on a VMEM δ tile (the backward prologue).

    Delegates to ``core.activations.nitro_relu_backward`` — pure traceable
    jnp (selects + one floor-div on the VPU), so the kernel prologue can
    never drift from the reference derivative.  The NITRO Scaling Layer's
    straight-through estimator is the identity, so fusing it adds no
    arithmetic — folding this prologue into the gradient matmuls is what
    keeps the post-ReLU-bwd δ tensor out of HBM entirely.
    """
    return nitro_relu_backward(z, g, alpha_inv)


def _accumulate_tile(x_ref, w_ref, acc_ref, *, int8_ops: bool = False):
    """Zero the VMEM accumulator at k == 0, then MXU-accumulate one
    (bm, bk)·(bk, bn) partial product — int32 accumulation.

    ``int8_ops=True`` is the int8-operand MXU fast path: the VMEM tiles
    stay int8 and the dot issues the MXU's double-rate
    ``int8×int8→int32`` mode via ``preferred_element_type`` — bit-exact
    with the lifted int32 dot, since the accumulator is int32 either way.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x, w = x_ref[...], w_ref[...]
    if not int8_ops:
        x, w = x.astype(jnp.int32), w.astype(jnp.int32)
    acc_ref[...] += jax.lax.dot_general(
        x,
        w,
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )


def _nitro_matmul_kernel(
    x_ref,
    w_ref,
    out_ref,
    acc_ref,
    *,
    n_k: int,
    sf_shift: int,
    sf_residual: int,
    alpha_inv: int,
    mu: int,
    apply_relu: bool,
    out_dtype,
    int8_ops: bool = False,
):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""
    _accumulate_tile(x_ref, w_ref, acc_ref, int8_ops=int8_ops)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        z = _scale_tile(acc_ref[...], sf_shift, sf_residual)
        if apply_relu:
            z = _relu_tile(z, alpha_inv, mu)
        out_ref[...] = z.astype(out_dtype)


def _nitro_matmul_fwd_kernel(
    x_ref,
    w_ref,
    a_ref,
    zstar_ref,
    acc_ref,
    *,
    n_k: int,
    sf_shift: int,
    sf_residual: int,
    alpha_inv: int,
    mu: int,
    out_dtype,
):
    """Training-forward variant: one accumulation pass, two outputs.

    Writes both the post-ReLU activation ``a`` (the block output) and the
    pre-ReLU scaled ``z*`` (the NITRO-ReLU/STE backward's only dependency
    on the forward pass) from the same VMEM accumulator — the unfused
    pipeline writes z (int32), z* (int32) and a (int32) to HBM; this
    writes a + z* and never materialises the raw pre-activation z.
    """
    _accumulate_tile(x_ref, w_ref, acc_ref)

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        z_star = _scale_tile(acc_ref[...], sf_shift, sf_residual)
        zstar_ref[...] = z_star
        a_ref[...] = _relu_tile(z_star, alpha_inv, mu).astype(out_dtype)


def _tile_geometry(x: jax.Array, w: jax.Array, bm: int, bn: int, bk: int):
    """Pad operands up to tile multiples (zero padding is exact for integer
    matmul); returns padded operands, clamped block sizes, and the grid."""
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    gm, gn, gk = x.shape[0] // bm_, w.shape[1] // bn_, x.shape[1] // bk_
    return x, w, (bm_, bn_, bk_), (gm, gn, gk)


def _launch(kernel, x, w, tiles, grid, *, out_dtypes, interpret):
    """Shared ``pallas_call`` scaffolding for both kernel variants.

    Everything that must stay in lockstep between the single-output and
    fused-forward kernels lives here — grid, BlockSpecs/index maps, the
    VMEM accumulator scratch, and dimension semantics.  The variants
    differ only in kernel body and the number of (bm, bn) outputs, given
    by ``out_dtypes``.
    """
    bm_, bn_, bk_ = tiles
    gm, gn, gk = grid
    out_specs = [
        pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)) for _ in out_dtypes
    ]
    out_shape = [
        jax.ShapeDtypeStruct((x.shape[0], w.shape[1]), dt) for dt in out_dtypes
    ]
    single = len(out_dtypes) == 1
    return pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=out_specs[0] if single else out_specs,
        out_shape=out_shape[0] if single else out_shape,
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)


@functools.partial(
    jax.jit,
    static_argnames=(
        "sf", "alpha_inv", "apply_relu", "out_dtype",
        "bm", "bn", "bk", "operand_dtype", "interpret",
    ),
)
def nitro_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    operand_dtype: str = "int32",
    interpret: bool = False,
) -> jax.Array:
    """Fused ``nitro_relu(⌊(x @ w)/sf⌋)`` for 2-D ``x`` (M,K) and ``w`` (K,N).

    Pads every dimension up to its tile multiple (zero padding is exact for
    integer matmul) and slices the result back.

    ``operand_dtype='int8'`` keeps the VMEM operand tiles int8 and issues
    ``int8×int8→int32`` MXU dots (the double-rate mode); both operands
    must already *be* int8 — narrowing/eligibility proofs live in the
    dispatcher (``ops.fused_matmul``).  Bit-exact with the int32 path.
    """
    if operand_dtype == "int8" and not (
        x.dtype == jnp.int8 and w.dtype == jnp.int8
    ):
        raise ValueError(
            f"operand_dtype='int8' requires int8 operands, got "
            f"{x.dtype}/{w.dtype} (the dispatcher narrows eligible inputs)"
        )
    m, n = x.shape[0], w.shape[1]
    x, w, (bm_, bn_, bk_), (gm, gn, gk) = _tile_geometry(x, w, bm, bn, bk)

    shift, residual = pow2_split(sf)
    kernel = functools.partial(
        _nitro_matmul_kernel,
        n_k=gk,
        sf_shift=shift,
        sf_residual=residual,
        alpha_inv=alpha_inv,
        mu=mu_int8(alpha_inv) if apply_relu else 0,
        apply_relu=apply_relu,
        out_dtype=out_dtype,
        int8_ops=(operand_dtype == "int8"),
    )
    out = _launch(
        kernel, x, w, (bm_, bn_, bk_), (gm, gn, gk),
        out_dtypes=[out_dtype], interpret=interpret,
    )
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=(
        "sf", "alpha_inv", "out_dtype", "bm", "bn", "bk", "interpret",
    ),
)
def nitro_matmul_fwd(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    out_dtype=jnp.int32,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> tuple[jax.Array, jax.Array]:
    """Fused *training* forward: returns ``(a, z_star)`` in one pass.

    ``a = nitro_relu(⌊(x @ w)/sf⌋)`` is the layer output; ``z_star`` is the
    int32 pre-ReLU scaled tensor the LES backward consumes (NITRO-ReLU
    segment selection + STE through the scaling layer).  Both come out of
    the same VMEM accumulator, so the raw int32 pre-activation ``z`` never
    touches HBM — the bandwidth win of the inference plan, extended to the
    train step.
    """
    m, n = x.shape[0], w.shape[1]
    x, w, (bm_, bn_, bk_), (gm, gn, gk) = _tile_geometry(x, w, bm, bn, bk)

    shift, residual = pow2_split(sf)
    kernel = functools.partial(
        _nitro_matmul_fwd_kernel,
        n_k=gk,
        sf_shift=shift,
        sf_residual=residual,
        alpha_inv=alpha_inv,
        mu=mu_int8(alpha_inv),
        out_dtype=out_dtype,
    )
    a, z_star = _launch(
        kernel, x, w, (bm_, bn_, bk_), (gm, gn, gk),
        out_dtypes=[out_dtype, jnp.int32], interpret=interpret,
    )
    return a[:m, :n], z_star[:m, :n]


# ---------------------------------------------------------------------------
# Backward kernels: gradient matmuls with the NITRO-ReLU-bwd/STE prologue
# ---------------------------------------------------------------------------


def _nitro_grad_w_kernel(x_ref, g_ref, z_ref, out_ref, acc_ref, *, n_k, alpha_inv):
    """One (bm, bn) grad_W tile: acc += x_tileᵀ @ relu_bwd(δ_tile).

    The prologue masks the incoming δ tile against the matching ``z_star``
    tile *in VMEM*, so the full-size post-ReLU-bwd δ never exists — each
    (bk, bn) δ tile is masked just before it enters the MXU.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = _relu_bwd_tile(g_ref[...].astype(jnp.int32), z_ref[...], alpha_inv)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.int32), g,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


def _nitro_grad_x_kernel(g_ref, z_ref, w_ref, out_ref, acc_ref, *, n_k, alpha_inv):
    """One (bm, bn) grad_x tile: acc += relu_bwd(δ_tile) @ w_tileᵀ.

    ``w`` is indexed in its natural (fan_in, fan_out) layout and transposed
    by the dot_general contraction dims — no wᵀ copy in HBM either.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = _relu_bwd_tile(g_ref[...].astype(jnp.int32), z_ref[...], alpha_inv)
    acc_ref[...] += jax.lax.dot_general(
        g, w_ref[...].astype(jnp.int32),
        dimension_numbers=(((1,), (1,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        out_ref[...] = acc_ref[...]


@functools.partial(
    jax.jit,
    static_argnames=("alpha_inv", "bm", "bn", "bk", "interpret"),
)
def nitro_matmul_grad_w(
    x: jax.Array,
    delta: jax.Array,
    z_star: jax.Array,
    *,
    alpha_inv: int = 10,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Fused weight gradient: ``xᵀ @ nitro_relu_backward(z_star, δ)``.

    x: (B, M) layer input, delta/z_star: (B, N) → (M, N) int32.  The grid
    is (M/bm, N/bn, B/bk) with the batch contraction innermost; the
    ReLU-bwd/STE prologue runs on each (bk, bn) δ tile in VMEM.  Zero
    padding is exact: padded δ and z* are both 0 and the prologue maps
    (δ=0, z*=0) → 0 (identity segment), contributing nothing.
    """
    b, m = x.shape
    b2, n = delta.shape
    assert b == b2, f"batch mismatch {b} vs {b2}"
    assert delta.shape == z_star.shape, "delta/z_star shape mismatch"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, b)
    pm, pn, pb = (-m) % bm_, (-n) % bn_, (-b) % bk_
    if pb or pm:
        x = jnp.pad(x, ((0, pb), (0, pm)))
    if pb or pn:
        delta = jnp.pad(delta, ((0, pb), (0, pn)))
        z_star = jnp.pad(z_star, ((0, pb), (0, pn)))
    gm, gn, gk = x.shape[1] // bm_, delta.shape[1] // bn_, x.shape[0] // bk_
    kernel = functools.partial(
        _nitro_grad_w_kernel, n_k=gk, alpha_inv=alpha_inv
    )
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bk_, bm_), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[1], delta.shape[1]), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, delta, z_star)
    return out[:m, :n]


def _nitro_grad_w_opt_kernel(
    scalars_ref, x_ref, g_ref, z_ref, w_ref, out_ref, acc_ref, *, n_k, alpha_inv
):
    """grad_W tile with the IntegerSGD epilogue fused into the flush.

    Accumulation is identical to ``_nitro_grad_w_kernel``; on the last
    k-step the flush reads the matching W tile and writes
    ``W − (⌊acc/γ_inv⌋ + ⌊W/η_inv⌋)`` instead of the raw gradient —
    grad_W never reaches HBM.  γ_inv/η_inv ride in SMEM like the
    standalone ``integer_sgd`` kernel's scalars.
    """

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    g = _relu_bwd_tile(g_ref[...].astype(jnp.int32), z_ref[...], alpha_inv)
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.int32), g,
        dimension_numbers=(((0,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _flush():
        out_ref[...] = integer_sgd_tile(
            w_ref[...], acc_ref[...], scalars_ref[0], scalars_ref[1]
        )


@functools.partial(
    jax.jit,
    static_argnames=("alpha_inv", "bm", "bn", "bk", "interpret"),
)
def nitro_matmul_grad_w_opt(
    x: jax.Array,
    delta: jax.Array,
    z_star: jax.Array,
    w: jax.Array,
    gamma_inv: jax.Array,
    eta_inv: jax.Array,
    *,
    alpha_inv: int = 10,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Fused weight *update*: one pass computes grad_W in VMEM and applies
    IntegerSGD in the flush, returning W′ directly.

    Same grid/padding as ``nitro_matmul_grad_w``; ``w`` (M, N) shares the
    output tiling.  Padding is exact through the epilogue too: a padded
    position has acc = 0 and w = 0, so W′ = 0 − (⌊0/γ⌋ + decay(0)) = 0,
    and the slice discards it.  3 HBM streams (x, δ/z*, W↔W′) versus 5+
    for the unfused composition (grad_W write + read, W read + write).
    """
    b, m = x.shape
    b2, n = delta.shape
    assert b == b2, f"batch mismatch {b} vs {b2}"
    assert delta.shape == z_star.shape, "delta/z_star shape mismatch"
    assert w.shape == (m, n), f"w shape {w.shape} != ({m}, {n})"
    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, b)
    pm, pn, pb = (-m) % bm_, (-n) % bn_, (-b) % bk_
    if pb or pm:
        x = jnp.pad(x, ((0, pb), (0, pm)))
    if pb or pn:
        delta = jnp.pad(delta, ((0, pb), (0, pn)))
        z_star = jnp.pad(z_star, ((0, pb), (0, pn)))
    if pm or pn:
        w = jnp.pad(w, ((0, pm), (0, pn)))
    gm, gn, gk = x.shape[1] // bm_, delta.shape[1] // bn_, x.shape[0] // bk_
    kernel = functools.partial(
        _nitro_grad_w_opt_kernel, n_k=gk, alpha_inv=alpha_inv
    )
    scalars = jnp.stack(
        [jnp.asarray(gamma_inv, jnp.int32), jnp.asarray(eta_inv, jnp.int32)]
    )
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec(memory_space=pltpu.SMEM),
            pl.BlockSpec((bk_, bm_), lambda i, j, kk: (kk, i)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
            pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct(w.shape, jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(scalars, x, delta, z_star, w)
    return out[:m, :n]


@functools.partial(
    jax.jit,
    static_argnames=("alpha_inv", "bm", "bn", "bk", "interpret"),
)
def nitro_matmul_grad_x(
    delta: jax.Array,
    z_star: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Fused input gradient: ``nitro_relu_backward(z_star, δ) @ wᵀ``.

    delta/z_star: (B, N), w: (M, N) natural layout → (B, M) int32.  Grid is
    (B/bm, M/bn, N/bk) contracting over the fan-out; the prologue masks
    each (bm, bk) δ tile in VMEM.  Padded fan-out columns have δ = z* = 0
    and w = 0, so the extra contraction terms vanish exactly.
    """
    b, n = delta.shape
    m, n2 = w.shape
    assert n == n2, f"fan-out mismatch {n} vs {n2}"
    assert delta.shape == z_star.shape, "delta/z_star shape mismatch"
    bm_, bn_, bk_ = min(bm, b), min(bn, m), min(bk, n)
    pb, pm, pn = (-b) % bm_, (-m) % bn_, (-n) % bk_
    if pb or pn:
        delta = jnp.pad(delta, ((0, pb), (0, pn)))
        z_star = jnp.pad(z_star, ((0, pb), (0, pn)))
    if pm or pn:
        w = jnp.pad(w, ((0, pm), (0, pn)))
    gm, gn, gk = delta.shape[0] // bm_, w.shape[0] // bn_, delta.shape[1] // bk_
    kernel = functools.partial(
        _nitro_grad_x_kernel, n_k=gk, alpha_inv=alpha_inv
    )
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bn_, bk_), lambda i, j, kk: (j, kk)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((delta.shape[0], w.shape[0]), jnp.int32),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(delta, z_star, w)
    return out[:b, :m]
