"""Fused NITRO matmul Pallas TPU kernel.

Computes, in one pass over VMEM tiles::

    z   = x @ w                      (int8/int32 inputs, int32 MXU accumulate)
    z*  = ⌊z / SF⌋                   (NITRO Scaling Layer)
    out = NITRO-ReLU(z*)             (optional, fused on the VPU)

This is the paper's per-layer hot loop (§3.2).  The reference NITRO-D
library materialises ``z`` (int32) in HBM, reads it back for the scaling
layer, and again for the activation — three HBM round-trips of the widest
tensor in the network.  Fusing them keeps ``z`` in a VMEM scratch
accumulator and writes only the int8 activation back to HBM:

    HBM bytes per layer:  unfused  M·N·(4+4+4+1)   →   fused  M·N·1 (+in/w)

TPU adaptation notes (DESIGN.md §2):
  * tiles are 128-aligned for the MXU systolic array; int8×int8→int32 is
    the MXU's double-rate integer mode (394 TOP/s on v5e vs 197 TF/s bf16);
  * ⌊z/SF⌋ is split as SF = residual·2^shift — the 2^shift part is an
    arithmetic right shift (exact floor semantics for two's-complement),
    the odd residual is one VPU integer divide;
  * grid is (M/bm, N/bn, K/bk) with K innermost ("arbitrary"), the
    canonical Pallas accumulation pattern.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.core.activations import mu_int8
from repro.core.scaling import pow2_split

# jax renamed TPUCompilerParams → CompilerParams; support both.
_CompilerParams = getattr(
    pltpu, "CompilerParams", getattr(pltpu, "TPUCompilerParams", None)
)

# MXU-native tile sizes.
DEFAULT_BM = 128
DEFAULT_BN = 128
DEFAULT_BK = 128


def _nitro_matmul_kernel(
    x_ref,
    w_ref,
    out_ref,
    acc_ref,
    *,
    n_k: int,
    sf_shift: int,
    sf_residual: int,
    alpha_inv: int,
    mu: int,
    apply_relu: bool,
    out_dtype,
):
    """One (bm, bn) output tile; accumulates over the K grid dimension."""

    @pl.when(pl.program_id(2) == 0)
    def _zero_acc():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    # MXU: integer dot with int32 accumulation.
    acc_ref[...] += jax.lax.dot_general(
        x_ref[...].astype(jnp.int32),
        w_ref[...].astype(jnp.int32),
        dimension_numbers=(((1,), (0,)), ((), ())),
        preferred_element_type=jnp.int32,
    )

    @pl.when(pl.program_id(2) == n_k - 1)
    def _epilogue():
        z = acc_ref[...]
        # NITRO Scaling: ⌊z / (residual · 2^shift)⌋.  Arithmetic right shift
        # implements the power-of-two floor division exactly.
        if sf_shift:
            z = jax.lax.shift_right_arithmetic(z, sf_shift)
        if sf_residual != 1:
            z = jnp.floor_divide(z, sf_residual)
        if apply_relu:
            neg = jnp.floor_divide(jnp.maximum(z, -127), alpha_inv)
            pos = jnp.minimum(z, 127)
            z = jnp.where(z < 0, neg, pos) - mu
        out_ref[...] = z.astype(out_dtype)


@functools.partial(
    jax.jit,
    static_argnames=(
        "sf", "alpha_inv", "apply_relu", "out_dtype",
        "bm", "bn", "bk", "interpret",
    ),
)
def nitro_matmul(
    x: jax.Array,
    w: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    bm: int = DEFAULT_BM,
    bn: int = DEFAULT_BN,
    bk: int = DEFAULT_BK,
    interpret: bool = False,
) -> jax.Array:
    """Fused ``nitro_relu(⌊(x @ w)/sf⌋)`` for 2-D ``x`` (M,K) and ``w`` (K,N).

    Pads every dimension up to its tile multiple (zero padding is exact for
    integer matmul) and slices the result back.
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch {k} vs {k2}"

    bm_, bn_, bk_ = min(bm, m), min(bn, n), min(bk, k)
    pm, pn, pk = (-m) % bm_, (-n) % bn_, (-k) % bk_
    if pm or pk:
        x = jnp.pad(x, ((0, pm), (0, pk)))
    if pk or pn:
        w = jnp.pad(w, ((0, pk), (0, pn)))
    gm, gn, gk = x.shape[0] // bm_, w.shape[1] // bn_, x.shape[1] // bk_

    shift, residual = pow2_split(sf)
    kernel = functools.partial(
        _nitro_matmul_kernel,
        n_k=gk,
        sf_shift=shift,
        sf_residual=residual,
        alpha_inv=alpha_inv,
        mu=mu_int8(alpha_inv) if apply_relu else 0,
        apply_relu=apply_relu,
        out_dtype=out_dtype,
    )
    out = pl.pallas_call(
        kernel,
        grid=(gm, gn, gk),
        in_specs=[
            pl.BlockSpec((bm_, bk_), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((bk_, bn_), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((bm_, bn_), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((x.shape[0], w.shape[1]), out_dtype),
        scratch_shapes=[pltpu.VMEM((bm_, bn_), jnp.int32)],
        compiler_params=_CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary"),
        ),
        interpret=interpret,
    )(x, w)
    return out[:m, :n]
