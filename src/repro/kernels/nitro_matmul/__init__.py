from repro.kernels.nitro_matmul.nitro_matmul import nitro_matmul
from repro.kernels.nitro_matmul.ops import nitro_conv2d, nitro_linear
from repro.kernels.nitro_matmul.ref import nitro_matmul_ref

__all__ = ["nitro_matmul", "nitro_matmul_ref", "nitro_linear", "nitro_conv2d"]
