from repro.kernels.nitro_matmul.nitro_matmul import (
    nitro_matmul,
    nitro_matmul_fwd,
    nitro_matmul_grad_w,
    nitro_matmul_grad_w_opt,
    nitro_matmul_grad_x,
)
from repro.kernels.nitro_matmul.ops import (
    BACKENDS,
    fused_matmul,
    fused_matmul_fwd,
    grad_w_matmul,
    grad_w_opt_matmul,
    grad_x_matmul,
    nitro_conv2d,
    nitro_linear,
    resolve_backend,
)
from repro.kernels.nitro_matmul.ref import (
    nitro_matmul_fwd_ref,
    nitro_matmul_grad_w_ref,
    nitro_matmul_grad_x_ref,
    nitro_matmul_ref,
)

__all__ = [
    "BACKENDS",
    "fused_matmul",
    "fused_matmul_fwd",
    "grad_w_matmul",
    "grad_w_opt_matmul",
    "grad_x_matmul",
    "nitro_matmul",
    "nitro_matmul_fwd",
    "nitro_matmul_fwd_ref",
    "nitro_matmul_grad_w",
    "nitro_matmul_grad_w_opt",
    "nitro_matmul_grad_w_ref",
    "nitro_matmul_grad_x",
    "nitro_matmul_grad_x_ref",
    "nitro_matmul_ref",
    "nitro_conv2d",
    "nitro_linear",
    "resolve_backend",
]
