"""Public wrappers + backend dispatch for the fused NITRO matmul kernel.

This module is the **single entry point** every matmul path shares:

  * training forward — ``core.blocks.forward_layers`` calls
    ``fused_matmul_fwd`` (returns the activation *and* the cached pre-ReLU
    ``z_star``);
  * training backward — ``kernels.grad_ops`` calls ``grad_w_matmul`` /
    ``grad_x_matmul`` (gradient matmuls whose VMEM prologue applies the
    NITRO-ReLU derivative + scaling STE to the δ tiles);
  * inference — ``infer.plan`` calls ``fused_matmul`` (activation only,
    optionally narrowed to int8 between layers).

Backend selection is centralised here (``resolve_backend``):

  * ``'pallas'``     — the real TPU kernel;
  * ``'interpret'``  — the same kernel through the Pallas interpreter
                       (bit-exact off-TPU; what the parity tests use);
  * ``'reference'``  — the pure-jnp oracle from ``ref.py`` (fast on CPU);
  * ``'auto'``       — pallas on TPU, reference elsewhere.

``nitro_linear`` / ``nitro_conv2d`` remain as drop-in fused replacements
for the reference layer pipeline (IntegerLinear/IntegerConv2D → NITRO
Scaling → NITRO-ReLU) with the legacy ``use_kernel``/``interpret`` knobs.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.layers import conv_im2col_operands
from repro.core.scaling import conv_scale_factor, linear_scale_factor
from repro.kernels.nitro_matmul.nitro_matmul import (
    nitro_matmul,
    nitro_matmul_fwd,
    nitro_matmul_grad_w,
    nitro_matmul_grad_x,
)
from repro.kernels.nitro_matmul.ref import (
    nitro_matmul_fwd_ref,
    nitro_matmul_grad_w_ref,
    nitro_matmul_grad_x_ref,
    nitro_matmul_ref,
)

BACKENDS = ("auto", "pallas", "interpret", "reference")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """Validate + resolve ``'auto'`` to a concrete backend for this host."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if _on_tpu() else "reference"
    return backend


def check_alpha_inv(alpha_inv: int, apply_relu: bool) -> int:
    """Validate the NITRO-ReLU leak divisor ``α_inv = ⌊1/α⌋``.

    ``alpha_inv`` divides the negative segment, so 0 would floor-divide by
    zero inside the kernel — historically it was silently coerced to 1
    (``alpha_inv or 1``); now it raises.  When ``apply_relu=False`` the
    value is unused and normalised to 1, so frozen no-activation layers
    (exported with ``alpha_inv=0``) neither fail nor fan out into
    spurious kernel recompilations.
    """
    if not apply_relu:
        return 1
    if int(alpha_inv) < 1:
        raise ValueError(
            f"alpha_inv must be a positive integer when apply_relu=True, "
            f"got {alpha_inv!r}"
        )
    return int(alpha_inv)


def fused_matmul(
    x2: jax.Array,
    w2: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    backend: str = "auto",
) -> jax.Array:
    """One fused matmul+scale(+relu) on 2-D operands — the inference step."""
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, apply_relu)
    if backend == "reference":
        return nitro_matmul_ref(
            x2, w2, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            out_dtype=out_dtype,
        )
    return nitro_matmul(
        x2, w2, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
        out_dtype=out_dtype, interpret=(backend == "interpret"),
    )


def fused_matmul_fwd(
    x2: jax.Array,
    w2: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    backend: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Fused training forward on 2-D operands: ``(a, z_star)``, both int32.

    ``a`` keeps int32 (not the inference plan's int8 narrowing) so the
    fused train step is bit- *and dtype*-identical to the unfused
    reference pipeline; ``z_star`` is what ``forward_layers_backward``
    consumes for the NITRO-ReLU/STE backward.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if backend == "reference":
        return nitro_matmul_fwd_ref(x2, w2, sf=sf, alpha_inv=alpha_inv)
    return nitro_matmul_fwd(
        x2, w2, sf=sf, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"),
    )


def grad_w_matmul(
    x2: jax.Array,
    delta2: jax.Array,
    z_star2: jax.Array,
    *,
    alpha_inv: int = 10,
    backend: str = "auto",
) -> jax.Array:
    """Fused backward weight matmul on 2-D operands.

    ``x2ᵀ @ relu_bwd(z*, δ)`` with the NITRO-ReLU-derivative/STE prologue
    applied to the δ tiles in VMEM (pallas/interpret) or composed from the
    reference ops (reference) — bit-identical either way.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if backend == "reference":
        return nitro_matmul_grad_w_ref(x2, delta2, z_star2, alpha_inv=alpha_inv)
    return nitro_matmul_grad_w(
        x2, delta2, z_star2, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"),
    )


def grad_x_matmul(
    delta2: jax.Array,
    z_star2: jax.Array,
    w2: jax.Array,
    *,
    alpha_inv: int = 10,
    backend: str = "auto",
) -> jax.Array:
    """Fused backward input matmul on 2-D operands.

    ``relu_bwd(z*, δ) @ w2ᵀ`` — the transpose happens via the kernel's
    contraction dims, and the prologue masks δ in VMEM exactly as
    ``grad_w_matmul`` does.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if backend == "reference":
        return nitro_matmul_grad_x_ref(delta2, z_star2, w2, alpha_inv=alpha_inv)
    return nitro_matmul_grad_x(
        delta2, z_star2, w2, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"),
    )


def _legacy_backend(use_kernel: bool | None, interpret: bool | None) -> str:
    """Map the historical ``use_kernel``/``interpret`` knobs to a backend.

    Both knobs are deprecated in favour of ``backend=``; passing either
    explicitly warns.  ``use_kernel=False`` with ``interpret=True`` is
    contradictory (no kernel to interpret) and raises instead of the
    historical behaviour of silently preferring ``use_kernel`` — and an
    explicit ``interpret=True`` with ``use_kernel`` unset now selects the
    interpreter instead of being silently dropped off-TPU.
    """
    if use_kernel is not None or interpret is not None:
        warnings.warn(
            "use_kernel/interpret are deprecated; use backend="
            "'pallas'|'interpret'|'reference'|'auto' instead",
            DeprecationWarning,
            stacklevel=3,
        )
    if use_kernel is False and interpret:
        raise ValueError(
            "contradictory legacy knobs: use_kernel=False disables the "
            "kernel but interpret=True requests the Pallas interpreter; "
            "pass backend='reference' or backend='interpret' instead"
        )
    if use_kernel is None:
        use_kernel = _on_tpu() or bool(interpret)
    if not use_kernel:
        return "reference"
    if interpret is None:
        interpret = not _on_tpu()
    return "interpret" if interpret else "pallas"


def nitro_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer linear layer: nitro_relu(⌊(x@w)/(2⁸·M)⌋).

    Accepts any leading batch dims on ``x``; contracts the last one.
    ``use_kernel=None`` auto-selects: Pallas on TPU, oracle on CPU (the
    tests exercise the kernel explicitly with ``interpret=True``).
    """
    m = x.shape[-1]
    lead = x.shape[:-1]
    out = fused_matmul(
        x.reshape(-1, m), w, sf=linear_scale_factor(m), alpha_inv=alpha_inv,
        apply_relu=apply_relu, out_dtype=out_dtype,
        backend=_legacy_backend(use_kernel, interpret),
    )
    return out.reshape(*lead, w.shape[-1])


def nitro_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer conv layer via im2col + the same fused matmul.

    x: (N,H,W,C) int, w: (K,K,C,F) int → (N,H,W,F) activations.
    im2col is pad+static-slices (layout work the TPU does in the XLA
    prologue); all FLOPs go through the fused MXU kernel.
    """
    k = w.shape[0]
    c_in = x.shape[-1]
    n, h, ww, _ = x.shape
    patches, w_flat = conv_im2col_operands(w, x)
    out = fused_matmul(
        patches, w_flat, sf=conv_scale_factor(k, c_in), alpha_inv=alpha_inv,
        apply_relu=apply_relu, out_dtype=out_dtype,
        backend=_legacy_backend(use_kernel, interpret),
    )
    return out.reshape(n, h, ww, w.shape[-1])
