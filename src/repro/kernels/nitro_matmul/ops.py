"""Public wrappers + backend dispatch for the fused NITRO matmul kernel.

This module is the **single entry point** both forward paths share:

  * training — ``core.blocks.forward_layers`` calls ``fused_matmul_fwd``
    (returns the activation *and* the cached pre-ReLU ``z_star``);
  * inference — ``infer.plan`` calls ``fused_matmul`` (activation only,
    optionally narrowed to int8 between layers).

Backend selection is centralised here (``resolve_backend``):

  * ``'pallas'``     — the real TPU kernel;
  * ``'interpret'``  — the same kernel through the Pallas interpreter
                       (bit-exact off-TPU; what the parity tests use);
  * ``'reference'``  — the pure-jnp oracle from ``ref.py`` (fast on CPU);
  * ``'auto'``       — pallas on TPU, reference elsewhere.

``nitro_linear`` / ``nitro_conv2d`` remain as drop-in fused replacements
for the reference layer pipeline (IntegerLinear/IntegerConv2D → NITRO
Scaling → NITRO-ReLU) with the legacy ``use_kernel``/``interpret`` knobs.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core.layers import conv_im2col_operands
from repro.core.scaling import conv_scale_factor, linear_scale_factor
from repro.kernels.nitro_matmul.nitro_matmul import nitro_matmul, nitro_matmul_fwd
from repro.kernels.nitro_matmul.ref import nitro_matmul_fwd_ref, nitro_matmul_ref

BACKENDS = ("auto", "pallas", "interpret", "reference")


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """Validate + resolve ``'auto'`` to a concrete backend for this host."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if _on_tpu() else "reference"
    return backend


def check_alpha_inv(alpha_inv: int, apply_relu: bool) -> int:
    """Validate the NITRO-ReLU leak divisor ``α_inv = ⌊1/α⌋``.

    ``alpha_inv`` divides the negative segment, so 0 would floor-divide by
    zero inside the kernel — historically it was silently coerced to 1
    (``alpha_inv or 1``); now it raises.  When ``apply_relu=False`` the
    value is unused and normalised to 1, so frozen no-activation layers
    (exported with ``alpha_inv=0``) neither fail nor fan out into
    spurious kernel recompilations.
    """
    if not apply_relu:
        return 1
    if int(alpha_inv) < 1:
        raise ValueError(
            f"alpha_inv must be a positive integer when apply_relu=True, "
            f"got {alpha_inv!r}"
        )
    return int(alpha_inv)


def fused_matmul(
    x2: jax.Array,
    w2: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    backend: str = "auto",
) -> jax.Array:
    """One fused matmul+scale(+relu) on 2-D operands — the inference step."""
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, apply_relu)
    if backend == "reference":
        return nitro_matmul_ref(
            x2, w2, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            out_dtype=out_dtype,
        )
    return nitro_matmul(
        x2, w2, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
        out_dtype=out_dtype, interpret=(backend == "interpret"),
    )


def fused_matmul_fwd(
    x2: jax.Array,
    w2: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    backend: str = "auto",
) -> tuple[jax.Array, jax.Array]:
    """Fused training forward on 2-D operands: ``(a, z_star)``, both int32.

    ``a`` keeps int32 (not the inference plan's int8 narrowing) so the
    fused train step is bit- *and dtype*-identical to the unfused
    reference pipeline; ``z_star`` is what ``forward_layers_backward``
    consumes for the NITRO-ReLU/STE backward.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if backend == "reference":
        return nitro_matmul_fwd_ref(x2, w2, sf=sf, alpha_inv=alpha_inv)
    return nitro_matmul_fwd(
        x2, w2, sf=sf, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"),
    )


def _legacy_backend(use_kernel: bool | None, interpret: bool | None) -> str:
    """Map the historical ``use_kernel``/``interpret`` knobs to a backend."""
    if use_kernel is None:
        use_kernel = _on_tpu()
    if not use_kernel:
        return "reference"
    if interpret is None:
        interpret = not _on_tpu()
    return "interpret" if interpret else "pallas"


def nitro_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer linear layer: nitro_relu(⌊(x@w)/(2⁸·M)⌋).

    Accepts any leading batch dims on ``x``; contracts the last one.
    ``use_kernel=None`` auto-selects: Pallas on TPU, oracle on CPU (the
    tests exercise the kernel explicitly with ``interpret=True``).
    """
    m = x.shape[-1]
    lead = x.shape[:-1]
    out = fused_matmul(
        x.reshape(-1, m), w, sf=linear_scale_factor(m), alpha_inv=alpha_inv,
        apply_relu=apply_relu, out_dtype=out_dtype,
        backend=_legacy_backend(use_kernel, interpret),
    )
    return out.reshape(*lead, w.shape[-1])


def nitro_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer conv layer via im2col + the same fused matmul.

    x: (N,H,W,C) int, w: (K,K,C,F) int → (N,H,W,F) activations.
    im2col is pad+static-slices (layout work the TPU does in the XLA
    prologue); all FLOPs go through the fused MXU kernel.
    """
    k = w.shape[0]
    c_in = x.shape[-1]
    n, h, ww, _ = x.shape
    patches, w_flat = conv_im2col_operands(w, x)
    out = fused_matmul(
        patches, w_flat, sf=conv_scale_factor(k, c_in), alpha_inv=alpha_inv,
        apply_relu=apply_relu, out_dtype=out_dtype,
        backend=_legacy_backend(use_kernel, interpret),
    )
    return out.reshape(n, h, ww, w.shape[-1])
