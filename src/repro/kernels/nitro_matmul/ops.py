"""Public jit'd wrappers around the fused NITRO matmul kernel.

``nitro_linear`` / ``nitro_conv2d`` are drop-in fused replacements for the
reference layer pipeline (IntegerLinear/IntegerConv2D → NITRO Scaling →
NITRO-ReLU).  On CPU (this container) they run the kernel in interpret
mode or fall back to the oracle; on TPU they emit the Pallas kernel.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.core.layers import im2col
from repro.core.scaling import conv_scale_factor, linear_scale_factor
from repro.kernels.nitro_matmul.nitro_matmul import nitro_matmul
from repro.kernels.nitro_matmul.ref import nitro_matmul_ref


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def nitro_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer linear layer: nitro_relu(⌊(x@w)/(2⁸·M)⌋).

    Accepts any leading batch dims on ``x``; contracts the last one.
    ``use_kernel=None`` auto-selects: Pallas on TPU, oracle on CPU (the
    tests exercise the kernel explicitly with ``interpret=True``).
    """
    m = x.shape[-1]
    sf = linear_scale_factor(m)
    lead = x.shape[:-1]
    x2 = x.reshape(-1, m)
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        out = nitro_matmul(
            x2, w, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            out_dtype=out_dtype,
            interpret=(not _on_tpu()) if interpret is None else interpret,
        )
    else:
        out = nitro_matmul_ref(
            x2, w, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            out_dtype=out_dtype,
        )
    return out.reshape(*lead, w.shape[-1])


def nitro_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer conv layer via im2col + the same fused matmul.

    x: (N,H,W,C) int, w: (K,K,C,F) int → (N,H,W,F) activations.
    im2col is pad+static-slices (layout work the TPU does in the XLA
    prologue); all FLOPs go through the fused MXU kernel.
    """
    k = w.shape[0]
    c_in = x.shape[-1]
    sf = conv_scale_factor(k, c_in)
    n, h, ww, _ = x.shape
    patches = im2col(x, k, k // 2).reshape(n * h * ww, k * k * c_in)
    w_flat = w.reshape(-1, w.shape[-1])
    if use_kernel is None:
        use_kernel = _on_tpu()
    if use_kernel:
        out = nitro_matmul(
            patches, w_flat, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            out_dtype=out_dtype,
            interpret=(not _on_tpu()) if interpret is None else interpret,
        )
    else:
        out = nitro_matmul_ref(
            patches, w_flat, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            out_dtype=out_dtype,
        )
    return out.reshape(n, h, ww, w.shape[-1])
