"""Public wrappers + backend dispatch for the fused NITRO matmul kernel.

This module is the **single entry point** every matmul path shares:

  * training forward — ``core.blocks.forward_layers`` calls
    ``fused_matmul_fwd`` (returns the activation *and* the cached pre-ReLU
    ``z_star``);
  * training backward — ``kernels.grad_ops`` calls ``grad_w_matmul`` /
    ``grad_x_matmul`` (gradient matmuls whose VMEM prologue applies the
    NITRO-ReLU derivative + scaling STE to the δ tiles);
  * inference — ``infer.plan`` calls ``fused_matmul`` (activation only,
    optionally narrowed to int8 between layers).

Backend selection is centralised here (``resolve_backend``):

  * ``'pallas'``     — the real TPU kernel;
  * ``'interpret'``  — the same kernel through the Pallas interpreter
                       (bit-exact off-TPU; what the parity tests use);
  * ``'reference'``  — the pure-jnp oracle from ``ref.py`` (fast on CPU);
  * ``'auto'``       — pallas on TPU, reference elsewhere.

``nitro_linear`` / ``nitro_conv2d`` remain as drop-in fused replacements
for the reference layer pipeline (IntegerLinear/IntegerConv2D → NITRO
Scaling → NITRO-ReLU) with the legacy ``use_kernel``/``interpret`` knobs.
"""

from __future__ import annotations

import warnings

import jax
import jax.numpy as jnp

from repro.core.layers import conv_im2col_operands
from repro.core.scaling import conv_scale_factor, linear_scale_factor
from repro.kernels.autotune import state as autotune
from repro.kernels.autotune.tiles import TileConfig
from repro.kernels.nitro_matmul.nitro_matmul import (
    nitro_matmul,
    nitro_matmul_fwd,
    nitro_matmul_grad_w,
    nitro_matmul_grad_w_opt,
    nitro_matmul_grad_x,
)
from repro.kernels.nitro_matmul.ref import (
    nitro_matmul_fwd_ref,
    nitro_matmul_grad_w_ref,
    nitro_matmul_grad_x_ref,
    nitro_matmul_ref,
)

BACKENDS = ("auto", "pallas", "interpret", "reference")

#: Operand-dtype policy for the MXU dots (inference entry points):
#:   * ``'auto'``  — int8 operands stay int8 (the double-rate MXU mode)
#:                   whenever *both* operands already are; anything else
#:                   lifts to int32.  Never changes results.
#:   * ``'int8'``  — force the int8 path: int8 operands pass through;
#:                   concrete non-int8 operands are guarded (telemetry
#:                   ``bit_width`` ≤ 7) and narrowed; traced non-int8
#:                   operands raise.
#:   * ``'int32'`` — the escape hatch: always lift (historical path).
OPERAND_DTYPES = ("auto", "int8", "int32")


def _guard_int8(arr: jax.Array, name: str) -> jax.Array:
    """Runtime guard for the forced-int8 path: prove fit, then narrow.

    int8 arrays pass through.  A *concrete* wider array is checked with
    the telemetry ``bit_width`` reduction (≤ 7 bits ⇒ values in
    [-127, 127] ⇒ exact int8) and narrowed; a traced wider array cannot
    be value-checked, so it raises — use ``operand_dtype='auto'`` (which
    keys off dtypes alone) under jit, or narrow before tracing.
    """
    if arr.dtype == jnp.int8:
        return arr
    if isinstance(arr, jax.core.Tracer):
        raise ValueError(
            f"operand_dtype='int8': operand {name!r} is a traced "
            f"{arr.dtype} array — int8 fit cannot be proven under jit; "
            f"pass int8 operands or use operand_dtype='auto'"
        )
    from repro.obs.telemetry import bit_width

    bits = int(bit_width(arr).max())
    if bits > 7:
        raise ValueError(
            f"operand_dtype='int8': operand {name!r} needs {bits} bits "
            f"(> 7) — values do not fit int8; use the int32 escape hatch"
        )
    return arr.astype(jnp.int8)


def resolve_operand_dtype(
    operand_dtype: str, x: jax.Array, w: jax.Array
) -> str:
    """Resolve the ``'auto'`` policy to a concrete ``'int8'``/``'int32'``."""
    if operand_dtype not in OPERAND_DTYPES:
        raise ValueError(
            f"unknown operand_dtype {operand_dtype!r}; one of {OPERAND_DTYPES}"
        )
    if operand_dtype == "auto":
        return (
            "int8"
            if x.dtype == jnp.int8 and w.dtype == jnp.int8
            else "int32"
        )
    return operand_dtype


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def resolve_backend(backend: str) -> str:
    """Validate + resolve ``'auto'`` to a concrete backend for this host."""
    if backend not in BACKENDS:
        raise ValueError(f"unknown backend {backend!r}; one of {BACKENDS}")
    if backend == "auto":
        return "pallas" if _on_tpu() else "reference"
    return backend


def check_alpha_inv(alpha_inv: int, apply_relu: bool) -> int:
    """Validate the NITRO-ReLU leak divisor ``α_inv = ⌊1/α⌋``.

    ``alpha_inv`` divides the negative segment, so 0 would floor-divide by
    zero inside the kernel — historically it was silently coerced to 1
    (``alpha_inv or 1``); now it raises.  When ``apply_relu=False`` the
    value is unused and normalised to 1, so frozen no-activation layers
    (exported with ``alpha_inv=0``) neither fail nor fan out into
    spurious kernel recompilations.
    """
    if not apply_relu:
        return 1
    if int(alpha_inv) < 1:
        raise ValueError(
            f"alpha_inv must be a positive integer when apply_relu=True, "
            f"got {alpha_inv!r}"
        )
    return int(alpha_inv)


def fused_matmul(
    x2: jax.Array,
    w2: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    backend: str = "auto",
    tiles: TileConfig | None = None,
    operand_dtype: str = "auto",
) -> jax.Array:
    """One fused matmul+scale(+relu) on 2-D operands — the inference step.

    ``tiles`` overrides the kernel tile sizes; ``None`` consults the
    process-wide autotune cache (``kernels.autotune``) and falls back to
    the defaults on a miss.  ``operand_dtype`` selects the MXU operand
    path (see ``OPERAND_DTYPES``) — both knobs are perf-only and bitwise
    result-invariant.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, apply_relu)
    od = resolve_operand_dtype(operand_dtype, x2, w2)
    if od == "int8":
        x2 = _guard_int8(x2, "x")
        w2 = _guard_int8(w2, "w")
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "matmul", (x2.shape[0], x2.shape[1], w2.shape[1]),
            dtype=f"{x2.dtype},{w2.dtype}", backend=backend,
        )
    if backend == "reference":
        return nitro_matmul_ref(
            x2, w2, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
            out_dtype=out_dtype, operand_dtype=od,
        )
    tile_kw = {} if tiles is None else dict(
        bm=tiles.bm, bn=tiles.bn, bk=tiles.bk
    )
    return nitro_matmul(
        x2, w2, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu,
        out_dtype=out_dtype, operand_dtype=od,
        interpret=(backend == "interpret"), **tile_kw,
    )


def fused_matmul_fwd(
    x2: jax.Array,
    w2: jax.Array,
    *,
    sf: int,
    alpha_inv: int = 10,
    backend: str = "auto",
    tiles: TileConfig | None = None,
) -> tuple[jax.Array, jax.Array]:
    """Fused training forward on 2-D operands: ``(a, z_star)``, both int32.

    ``a`` keeps int32 (not the inference plan's int8 narrowing) so the
    fused train step is bit- *and dtype*-identical to the unfused
    reference pipeline; ``z_star`` is what ``forward_layers_backward``
    consumes for the NITRO-ReLU/STE backward.  (Training entry points
    take ``tiles`` but not ``operand_dtype`` — train operands are int32
    by the dtype-identical contract.)
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "matmul_fwd", (x2.shape[0], x2.shape[1], w2.shape[1]),
            dtype=f"{x2.dtype},{w2.dtype}", backend=backend,
        )
    if backend == "reference":
        return nitro_matmul_fwd_ref(x2, w2, sf=sf, alpha_inv=alpha_inv)
    tile_kw = {} if tiles is None else dict(
        bm=tiles.bm, bn=tiles.bn, bk=tiles.bk
    )
    return nitro_matmul_fwd(
        x2, w2, sf=sf, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"), **tile_kw,
    )


def grad_w_matmul(
    x2: jax.Array,
    delta2: jax.Array,
    z_star2: jax.Array,
    *,
    alpha_inv: int = 10,
    backend: str = "auto",
    tiles: TileConfig | None = None,
) -> jax.Array:
    """Fused backward weight matmul on 2-D operands.

    ``x2ᵀ @ relu_bwd(z*, δ)`` with the NITRO-ReLU-derivative/STE prologue
    applied to the δ tiles in VMEM (pallas/interpret) or composed from the
    reference ops (reference) — bit-identical either way.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "matmul_grad_w", (x2.shape[0], x2.shape[1], delta2.shape[1]),
            dtype=f"{x2.dtype},{delta2.dtype}", backend=backend,
            fuse_bwd=True,
        )
    if backend == "reference":
        return nitro_matmul_grad_w_ref(x2, delta2, z_star2, alpha_inv=alpha_inv)
    tile_kw = {} if tiles is None else dict(
        bm=tiles.bm, bn=tiles.bn, bk=tiles.bk
    )
    return nitro_matmul_grad_w(
        x2, delta2, z_star2, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"), **tile_kw,
    )


def grad_w_opt_matmul(
    x2: jax.Array,
    delta2: jax.Array,
    z_star2: jax.Array,
    w2: jax.Array,
    gamma_inv: jax.Array,
    eta_inv: jax.Array,
    *,
    alpha_inv: int = 10,
    backend: str = "auto",
    tiles: TileConfig | None = None,
) -> jax.Array:
    """Fused backward weight *update* on 2-D operands — returns W′.

    pallas/interpret run ``nitro_matmul_grad_w_opt`` (IntegerSGD applied in
    the grad kernel's flush, grad_W never in HBM); reference composes the
    same two oracles the unfused path uses — bit-identical either way
    because integer floor-div over an order-exact int32 accumulation is
    exact.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "matmul_grad_w", (x2.shape[0], x2.shape[1], delta2.shape[1]),
            dtype=f"{x2.dtype},{delta2.dtype}", backend=backend,
            fuse_bwd=True, fuse_opt=True,
        )
    if backend == "reference":
        from repro.kernels.integer_sgd.ref import integer_sgd_ref

        grad_w = nitro_matmul_grad_w_ref(
            x2, delta2, z_star2, alpha_inv=alpha_inv
        )
        return integer_sgd_ref(w2, grad_w, gamma_inv, eta_inv)
    tile_kw = {} if tiles is None else dict(
        bm=tiles.bm, bn=tiles.bn, bk=tiles.bk
    )
    return nitro_matmul_grad_w_opt(
        x2, delta2, z_star2, w2, gamma_inv, eta_inv, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"), **tile_kw,
    )


def grad_x_matmul(
    delta2: jax.Array,
    z_star2: jax.Array,
    w2: jax.Array,
    *,
    alpha_inv: int = 10,
    backend: str = "auto",
    tiles: TileConfig | None = None,
) -> jax.Array:
    """Fused backward input matmul on 2-D operands.

    ``relu_bwd(z*, δ) @ w2ᵀ`` — the transpose happens via the kernel's
    contraction dims, and the prologue masks δ in VMEM exactly as
    ``grad_w_matmul`` does.
    """
    backend = resolve_backend(backend)
    alpha_inv = check_alpha_inv(alpha_inv, True)
    if tiles is None:
        tiles = autotune.resolve_tiles(
            "matmul_grad_x", (delta2.shape[0], delta2.shape[1], w2.shape[0]),
            dtype=f"{delta2.dtype},{w2.dtype}", backend=backend,
            fuse_bwd=True,
        )
    if backend == "reference":
        return nitro_matmul_grad_x_ref(delta2, z_star2, w2, alpha_inv=alpha_inv)
    tile_kw = {} if tiles is None else dict(
        bm=tiles.bm, bn=tiles.bn, bk=tiles.bk
    )
    return nitro_matmul_grad_x(
        delta2, z_star2, w2, alpha_inv=alpha_inv,
        interpret=(backend == "interpret"), **tile_kw,
    )


def _legacy_backend(use_kernel: bool | None, interpret: bool | None) -> str:
    """Map the historical ``use_kernel``/``interpret`` knobs to a backend.

    Both knobs are deprecated in favour of ``backend=``; passing either
    explicitly warns.  ``use_kernel=False`` with ``interpret=True`` is
    contradictory (no kernel to interpret) and raises instead of the
    historical behaviour of silently preferring ``use_kernel`` — and an
    explicit ``interpret=True`` with ``use_kernel`` unset now selects the
    interpreter instead of being silently dropped off-TPU.
    """
    if use_kernel is not None or interpret is not None:
        warnings.warn(
            "use_kernel/interpret are deprecated; use backend="
            "'pallas'|'interpret'|'reference'|'auto' instead",
            DeprecationWarning,
            stacklevel=3,
        )
    if use_kernel is False and interpret:
        raise ValueError(
            "contradictory legacy knobs: use_kernel=False disables the "
            "kernel but interpret=True requests the Pallas interpreter; "
            "pass backend='reference' or backend='interpret' instead"
        )
    if use_kernel is None:
        use_kernel = _on_tpu() or bool(interpret)
    if not use_kernel:
        return "reference"
    if interpret is None:
        interpret = not _on_tpu()
    return "interpret" if interpret else "pallas"


def nitro_linear(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer linear layer: nitro_relu(⌊(x@w)/(2⁸·M)⌋).

    Accepts any leading batch dims on ``x``; contracts the last one.
    ``use_kernel=None`` auto-selects: Pallas on TPU, oracle on CPU (the
    tests exercise the kernel explicitly with ``interpret=True``).
    """
    m = x.shape[-1]
    lead = x.shape[:-1]
    out = fused_matmul(
        x.reshape(-1, m), w, sf=linear_scale_factor(m), alpha_inv=alpha_inv,
        apply_relu=apply_relu, out_dtype=out_dtype,
        backend=_legacy_backend(use_kernel, interpret),
    )
    return out.reshape(*lead, w.shape[-1])


def nitro_conv2d(
    x: jax.Array,
    w: jax.Array,
    *,
    alpha_inv: int = 10,
    apply_relu: bool = True,
    out_dtype=jnp.int32,
    use_kernel: bool | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused integer conv layer via im2col + the same fused matmul.

    x: (N,H,W,C) int, w: (K,K,C,F) int → (N,H,W,F) activations.
    im2col is pad+static-slices (layout work the TPU does in the XLA
    prologue); all FLOPs go through the fused MXU kernel.
    """
    k = w.shape[0]
    c_in = x.shape[-1]
    n, h, ww, _ = x.shape
    patches, w_flat = conv_im2col_operands(w, x)
    out = fused_matmul(
        patches, w_flat, sf=conv_scale_factor(k, c_in), alpha_inv=alpha_inv,
        apply_relu=apply_relu, out_dtype=out_dtype,
        backend=_legacy_backend(use_kernel, interpret),
    )
    return out.reshape(n, h, ww, w.shape[-1])
