"""Compile a FrozenModel into a fused integer execution plan.

The plan lowers every layer onto the fused Pallas kernels: ``z`` lives in a
VMEM scratch accumulator and only the final activation is written back,
narrowed to int8 whenever the NITRO-ReLU output range fits (it always does
for α_inv ≥ 2 — the range is [⌊-127/α_inv⌋-μ, 127-μ]).  Training shares
the same kernel entry points (``kernels.nitro_matmul.ops`` /
``kernels.nitro_conv.ops``) via ``core.blocks.forward_layers``; inference
differs only in dropping the ``z_star`` cache and narrowing inter-layer
activations (see ``docs/ARCHITECTURE.md``).

    HBM traffic per layer:  unfused  M·N·(4+4+4) bytes  →  fused  M·N·1

Conv layers stream: the default ``conv_mode='stream'`` runs the implicit
im2col kernel — input rows are staged through VMEM and the
``(N·H·W, K²·C)`` patch matrix is never materialised (~K²× less conv-input
traffic) — with the 2×2 max-pool folded into the kernel epilogue for
``pool=True`` layers, so pooled convs write H/2·W/2 activations straight
away.  ``conv_mode='materialise'`` is the explicit-im2col escape hatch
(patch matrix + ``nitro_matmul`` + separate jnp pool), bit-exact with the
streaming path.

Backends (static at compile time):

  * ``'pallas'``     — the real TPU kernel;
  * ``'interpret'``  — the same kernel through the Pallas interpreter
                       (bit-exact off-TPU, used by the parity tests);
  * ``'reference'``  — pure-jnp composition (fast on CPU; the streaming
                       conv oracle runs the same row-band algorithm);
  * ``'auto'``       — pallas on TPU, reference elsewhere.

Every backend and conv mode is bit-exact with ``model.frozen_forward`` on
the same frozen params — asserted by tests/test_infer.py and
tests/test_conv_stream.py over the paper configs.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.activations import relu_fits_int8
from repro.core.numerics import INT_DTYPE
from repro.infer.export import FrozenModel
from repro.kernels.autotune import state as autotune_state
from repro.kernels.nitro_conv import ops as conv_ops
from repro.kernels.nitro_matmul import ops as nitro_ops
from repro.kernels.nitro_matmul.ops import BACKENDS  # noqa: F401 — re-export (historical public name)

#: Historical private name — the predicate now lives with the activation
#: maths in ``core.activations`` (the export/serving layers use it too).
_relu_fits_int8 = relu_fits_int8


class StepMeta(NamedTuple):
    """Static (hashable) description of one fused plan step."""

    kind: str           # 'conv' | 'linear' | 'output'
    sf: int
    alpha_inv: int
    apply_relu: bool
    pool: bool
    kernel_size: int    # conv only (0 otherwise)
    out_dtype: str      # 'int8' | 'int32' — inter-layer activation dtype
    conv_mode: str = "" # conv only: 'stream' | 'materialise'
    fused_pool: bool = False  # pool folded into the conv kernel epilogue
    operand_dtype: str = "int32"  # MXU operand path: 'int8' | 'int32'


def _fused(x2, w2, meta: StepMeta, backend: str):
    """One fused matmul+scale(+relu) on 2-D operands.

    Delegates to the kernel package's shared dispatcher — the same entry
    point ``core.blocks.forward_layers`` uses for the fused training
    forward, so train and infer execute one kernel implementation.
    """
    return nitro_ops.fused_matmul(
        x2, w2, sf=meta.sf, alpha_inv=meta.alpha_inv,
        apply_relu=meta.apply_relu, out_dtype=jnp.dtype(meta.out_dtype),
        backend=backend, operand_dtype=meta.operand_dtype,
    )


def _execute(weights, x, *, metas: tuple[StepMeta, ...], backend: str):
    a = jnp.asarray(x, INT_DTYPE)
    for w, meta in zip(weights, metas):
        if meta.kind == "conv":
            # 4-D in, 4-D out: the conv dispatcher owns patch formation
            # (implicit on the streaming path) and the pool epilogue —
            # no 2-D patch-matrix reshape at this level.
            a = conv_ops.fused_conv(
                a, w, sf=meta.sf, alpha_inv=meta.alpha_inv,
                apply_relu=meta.apply_relu, pool=meta.pool,
                out_dtype=jnp.dtype(meta.out_dtype),
                backend=backend, conv_mode=meta.conv_mode,
                operand_dtype=meta.operand_dtype,
            )
        else:  # 'linear' | 'output' — flatten anything spatial entering
            if a.ndim > 2:
                a = a.reshape(a.shape[0], -1)
            a = _fused(a, w, meta, backend)
    return a


class ExecutionPlan:
    """A FrozenModel lowered to fused kernel calls; jit-compiled per batch
    shape (serve with a fixed batch size to compile exactly once)."""

    def __init__(
        self,
        fm: FrozenModel,
        *,
        backend: str = "auto",
        conv_mode: str = "stream",
        operand_dtype: str = "auto",
    ):
        """``operand_dtype`` selects the MXU operand path per step:

        * ``'auto'``  — int8 wherever it is *provably* exact: the step's
          incoming activation is already int8-narrowed (previous layer's
          NITRO-ReLU range fit, per ``relu_fits_int8``) **and** the frozen
          weight is int8.  Everything else stays int32.
        * ``'int32'`` — the escape hatch: every step lifts to int32.
        * ``'int8'``  — force the fast path; raises if no step qualifies
          (so a misconfigured model cannot silently run all-int32).

        Bitwise result-invariant either way — int8×int8→int32 dots equal
        the lifted int32 dots exactly.
        """
        if operand_dtype not in nitro_ops.OPERAND_DTYPES:
            raise ValueError(
                f"unknown operand_dtype {operand_dtype!r}; "
                f"one of {nitro_ops.OPERAND_DTYPES}"
            )
        self.backend = nitro_ops.resolve_backend(backend)
        self.conv_mode = conv_ops.resolve_conv_mode(conv_mode)
        self.operand_dtype = operand_dtype
        self.input_shape = fm.input_shape
        self.num_classes = fm.num_classes
        self.name = fm.name
        metas = []
        act_dtype = "int32"  # _execute casts the network input to INT_DTYPE
        for i, layer in enumerate(fm.layers):
            out_dtype = (
                "int8"
                if layer.apply_relu and relu_fits_int8(layer.alpha_inv)
                else "int32"
            )
            is_conv = layer.kind == "conv"
            int8_ok = act_dtype == "int8" and str(layer.w.dtype) == "int8"
            step_od = (
                "int8" if int8_ok and operand_dtype != "int32" else "int32"
            )
            autotune_state.note_int8_path(
                f"{fm.name}/{i}", step_od == "int8"
            )
            metas.append(StepMeta(
                kind=layer.kind, sf=layer.sf, alpha_inv=layer.alpha_inv,
                apply_relu=layer.apply_relu, pool=layer.pool,
                kernel_size=layer.w.shape[0] if is_conv else 0,
                out_dtype=out_dtype,
                conv_mode=self.conv_mode if is_conv else "",
                fused_pool=bool(
                    is_conv and layer.pool and self.conv_mode == "stream"
                ),
                operand_dtype=step_od,
            ))
            act_dtype = out_dtype
        if operand_dtype == "int8" and not any(
            m.operand_dtype == "int8" for m in metas
        ):
            raise ValueError(
                "operand_dtype='int8': no step is int8-eligible (needs an "
                "int8-narrowed incoming activation AND an int8 weight); "
                "use 'auto' or the int32 escape hatch"
            )
        self.metas = tuple(metas)
        self.weights = [layer.w for layer in fm.layers]
        self._fn = jax.jit(functools.partial(
            _execute, metas=self.metas, backend=self.backend
        ))

    def logits(self, x) -> jax.Array:
        """(N, *input_shape) integer batch → (N, num_classes) int32 logits."""
        return self._fn(self.weights, x)

    __call__ = logits

    def predict(self, x) -> jax.Array:
        return jnp.argmax(self.logits(x), axis=-1)

    def summary(self) -> list[dict]:
        """Per-step introspection incl. per-sample HBM-traffic estimates.

        For conv steps both routes are estimated so the streaming delta is
        visible whatever mode the plan compiled with:

          * ``materialise`` — read the input, write *and* read back the
            (H·W, K²·C) im2col patch matrix, write the full activation,
            and (for pooled layers) round-trip it once more through the
            separate pool pass;
          * ``stream``      — read the input once, write the (pooled)
            activation; patches only ever exist as VMEM row bands.

        The ratio is ~K² on the conv-input term, which dominates wide
        layers.  Linear steps are identical under both modes.
        """
        rows = []
        shape = tuple(int(d) for d in self.input_shape)
        in_itemsize = 4  # _execute casts the network input to int32
        for w, meta in zip(self.weights, self.metas):
            out_itemsize = jnp.dtype(meta.out_dtype).itemsize
            if meta.kind == "conv":
                h, w_sp, c = shape
                k, f = meta.kernel_size, int(w.shape[-1])
                in_bytes = h * w_sp * c * in_itemsize
                patch_bytes = in_bytes * k * k
                full_out = h * w_sp * f * out_itemsize
                out_shape = (h // 2, w_sp // 2, f) if meta.pool else (h, w_sp, f)
                final_out = out_shape[0] * out_shape[1] * f * out_itemsize
                materialise = in_bytes + 2 * patch_bytes + full_out
                if meta.pool:
                    materialise += full_out + final_out
                stream = in_bytes + final_out  # pool fused ⇒ one write
                shape = out_shape
            else:
                feat = 1
                for d in shape:
                    feat *= d
                in_bytes = feat * in_itemsize
                out_bytes = int(w.shape[-1]) * out_itemsize
                materialise = stream = in_bytes + out_bytes
                shape = (int(w.shape[-1]),)
            rows.append({
                "kind": meta.kind,
                "weight_shape": tuple(int(d) for d in w.shape),
                "weight_dtype": str(w.dtype),
                "sf": meta.sf,
                "activation_dtype": meta.out_dtype,
                "operand_dtype": meta.operand_dtype,
                "pool": meta.pool,
                "conv_mode": meta.conv_mode or None,
                "fused_pool": meta.fused_pool,
                # per output element: unfused writes z(int32) + z*(int32) +
                # act(int32); fused writes only the narrowed activation
                "hbm_bytes_per_out_elem": {
                    "unfused": 12,
                    "fused": out_itemsize,
                },
                # per-sample traffic incl. im2col patches (conv): the
                # streaming-vs-materialise delta this plan's mode realises
                "hbm_per_sample_bytes": {
                    "materialise": int(materialise),
                    "stream": int(stream),
                },
                "stream_saving_ratio": round(materialise / stream, 2),
            })
            in_itemsize = out_itemsize
        return rows


def compile_plan(
    fm: FrozenModel,
    *,
    backend: str = "auto",
    conv_mode: str = "stream",
    operand_dtype: str = "auto",
) -> ExecutionPlan:
    """FrozenModel → jit-compiled fused ExecutionPlan."""
    return ExecutionPlan(
        fm, backend=backend, conv_mode=conv_mode, operand_dtype=operand_dtype
    )
