"""Compile a FrozenModel into a fused integer execution plan.

The plan lowers every layer onto the fused ``nitro_matmul`` Pallas kernel:
``z`` lives in a VMEM scratch accumulator and only the final activation is
written back, narrowed to int8 whenever the NITRO-ReLU output range fits
(it always does for α_inv ≥ 2 — the range is [⌊-127/α_inv⌋-μ, 127-μ]).
Training shares the same kernel entry point (``kernels.nitro_matmul.ops``)
via ``core.blocks.forward_layers``; inference differs only in dropping the
``z_star`` cache and narrowing inter-layer activations
(see ``docs/ARCHITECTURE.md``).

    HBM traffic per layer:  unfused  M·N·(4+4+4) bytes  →  fused  M·N·1

Conv layers go through the same kernel via im2col (pad + static slices —
layout work XLA folds into the kernel prologue); 2×2 max-pool and flatten
run as cheap jnp ops between fused matmuls.

Backends (static at compile time):

  * ``'pallas'``     — the real TPU kernel;
  * ``'interpret'``  — the same kernel through the Pallas interpreter
                       (bit-exact off-TPU, used by the parity tests);
  * ``'reference'``  — pure-jnp composition from ``core`` (fast on CPU);
  * ``'auto'``       — pallas on TPU, reference elsewhere.

Every backend is bit-exact with ``model.frozen_forward`` on the same
frozen params — asserted by tests/test_infer.py over the paper configs.
"""

from __future__ import annotations

import functools
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.activations import mu_int8
from repro.core.layers import _window_view, conv_im2col_operands
from repro.core.numerics import INT_DTYPE
from repro.infer.export import FrozenModel
from repro.kernels.nitro_matmul import ops as nitro_ops
from repro.kernels.nitro_matmul.ops import BACKENDS  # noqa: F401 — re-export (historical public name)


class StepMeta(NamedTuple):
    """Static (hashable) description of one fused plan step."""

    kind: str           # 'conv' | 'linear' | 'output'
    sf: int
    alpha_inv: int
    apply_relu: bool
    pool: bool
    kernel_size: int    # conv only (0 otherwise)
    out_dtype: str      # 'int8' | 'int32' — inter-layer activation dtype


def _relu_fits_int8(alpha_inv: int) -> bool:
    """NITRO-ReLU output range [⌊-127/α_inv⌋-μ, 127-μ] within int8?"""
    mu = mu_int8(alpha_inv)
    lo = (-127) // alpha_inv - mu
    hi = 127 - mu
    return -128 <= lo and hi <= 127


def _fused(x2, w2, meta: StepMeta, backend: str):
    """One fused matmul+scale(+relu) on 2-D operands.

    Delegates to the kernel package's shared dispatcher — the same entry
    point ``core.blocks.forward_layers`` uses for the fused training
    forward, so train and infer execute one kernel implementation.
    """
    return nitro_ops.fused_matmul(
        x2, w2, sf=meta.sf, alpha_inv=meta.alpha_inv,
        apply_relu=meta.apply_relu, out_dtype=jnp.dtype(meta.out_dtype),
        backend=backend,
    )


def _maxpool2x2(a: jax.Array) -> jax.Array:
    """Inference max-pool: window max only, no argmax routing cache."""
    return jnp.max(_window_view(a), axis=3)


def _execute(weights, x, *, metas: tuple[StepMeta, ...], backend: str):
    a = jnp.asarray(x, INT_DTYPE)
    for w, meta in zip(weights, metas):
        if meta.kind == "conv":
            n, h, ww, _ = a.shape
            patches, w_flat = conv_im2col_operands(w, a)
            out = _fused(patches, w_flat, meta, backend)
            a = out.reshape(n, h, ww, w.shape[-1])
            if meta.pool:
                a = _maxpool2x2(a)
        else:  # 'linear' | 'output' — flatten anything spatial entering
            if a.ndim > 2:
                a = a.reshape(a.shape[0], -1)
            a = _fused(a, w, meta, backend)
    return a


class ExecutionPlan:
    """A FrozenModel lowered to fused kernel calls; jit-compiled per batch
    shape (serve with a fixed batch size to compile exactly once)."""

    def __init__(self, fm: FrozenModel, *, backend: str = "auto"):
        self.backend = nitro_ops.resolve_backend(backend)
        self.input_shape = fm.input_shape
        self.num_classes = fm.num_classes
        self.name = fm.name
        metas = []
        for layer in fm.layers:
            out_dtype = (
                "int8"
                if layer.apply_relu and _relu_fits_int8(layer.alpha_inv)
                else "int32"
            )
            metas.append(StepMeta(
                kind=layer.kind, sf=layer.sf, alpha_inv=layer.alpha_inv,
                apply_relu=layer.apply_relu, pool=layer.pool,
                kernel_size=layer.w.shape[0] if layer.kind == "conv" else 0,
                out_dtype=out_dtype,
            ))
        self.metas = tuple(metas)
        self.weights = [layer.w for layer in fm.layers]
        self._fn = jax.jit(functools.partial(
            _execute, metas=self.metas, backend=self.backend
        ))

    def logits(self, x) -> jax.Array:
        """(N, *input_shape) integer batch → (N, num_classes) int32 logits."""
        return self._fn(self.weights, x)

    __call__ = logits

    def predict(self, x) -> jax.Array:
        return jnp.argmax(self.logits(x), axis=-1)

    def summary(self) -> list[dict]:
        """Per-step introspection incl. the fused-vs-unfused HBM estimate."""
        rows = []
        for w, meta in zip(self.weights, self.metas):
            rows.append({
                "kind": meta.kind,
                "weight_shape": tuple(int(d) for d in w.shape),
                "weight_dtype": str(w.dtype),
                "sf": meta.sf,
                "activation_dtype": meta.out_dtype,
                "pool": meta.pool,
                # per output element: unfused writes z(int32) + z*(int32) +
                # act(int32); fused writes only the narrowed activation
                "hbm_bytes_per_out_elem": {
                    "unfused": 12,
                    "fused": jnp.dtype(meta.out_dtype).itemsize,
                },
            })
        return rows


def compile_plan(fm: FrozenModel, *, backend: str = "auto") -> ExecutionPlan:
    """FrozenModel → jit-compiled fused ExecutionPlan."""
    return ExecutionPlan(fm, backend=backend)
