"""Freeze a trained NITRO-D model into an immutable inference artifact.

``freeze`` strips a ``les.TrainState`` down to what the deploy-time forward
actually needs:

  * the forward-layer weights of every block — the learning layers (each
    block's local classifier head) and both optimiser states are dropped,
    as the paper notes they are unused at inference (§E.3);
  * each weight narrowed to the smallest integer dtype that represents it
    losslessly (int8 for most trained NITRO-D layers, int16 above that) —
    narrowing is range-checked, never saturating, so frozen logits are
    bit-exact with the training-time ``model.frozen_forward``;
  * per-layer static metadata: NITRO scale factor (derived from the weight
    geometry exactly as ``core.scaling`` does), NITRO-ReLU α_inv, and the
    pooling flag — everything the plan compiler needs without the original
    ``NitroConfig``.

``quantization_report`` turns a FrozenModel into the paper's §4.4
bit-growth analysis: per-layer min/max, the exact two's-complement
bit-width the trained weights occupy, and a power-of-two magnitude
histogram.  ``save_frozen`` writes it as ``QUANT_REPORT.json`` alongside
the manifest (worked example in ``docs/ARCHITECTURE.md``).

On disk a frozen model is a ``train.checkpoint`` manifest directory (one
npy per weight, MANIFEST.json written last with fsync) whose ``extra``
field carries the topology — the same crash-safe format the trainer
already uses, so export inherits its fault-tolerance contract.
"""

from __future__ import annotations

import json
import os
import re
import shutil
from typing import NamedTuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import model as M
from repro.core.activations import relu_fits_int8
from repro.core.scaling import conv_scale_factor, linear_scale_factor
from repro.train import checkpoint as ckpt

FORMAT = "nitro-frozen-v1"


class FrozenLayer(NamedTuple):
    """One inference layer: fused matmul → scale → (optional) ReLU/pool."""

    kind: str            # 'conv' | 'linear' | 'output'
    w: jax.Array         # (K,K,C,F) conv / (M,N) linear — narrowest dtype
    sf: int              # NITRO scale factor for the producing matmul
    alpha_inv: int       # NITRO-ReLU leak (ignored when apply_relu=False)
    apply_relu: bool
    pool: bool           # MaxPool2D(2,2) after the activation (conv only)


class FrozenModel(NamedTuple):
    layers: tuple[FrozenLayer, ...]
    input_shape: tuple[int, ...]   # per-sample shape, e.g. (32,32,3)
    num_classes: int
    name: str

    def num_bytes(self) -> int:
        return sum(int(l.w.size) * l.w.dtype.itemsize for l in self.layers)


def _narrow(w: jax.Array) -> jax.Array:
    """Cast to the smallest integer dtype holding every value losslessly."""
    arr = np.asarray(jax.device_get(w))
    lo, hi = int(arr.min()), int(arr.max())
    for dt in (np.int8, np.int16):
        info = np.iinfo(dt)
        if info.min <= lo and hi <= info.max:
            return jnp.asarray(arr.astype(dt))
    return jnp.asarray(arr.astype(np.int32))


def _layer_sf(kind: str, w: jax.Array) -> int:
    """Scale factor from weight geometry — matches core.blocks exactly."""
    if kind == "conv":
        k, _, c_in, _ = w.shape
        return conv_scale_factor(k, c_in)
    return linear_scale_factor(w.shape[0])


def freeze(state_or_params, cfg: M.NitroConfig) -> FrozenModel:
    """TrainState (or raw params dict) + config → immutable FrozenModel."""
    params = getattr(state_or_params, "params", state_or_params)
    if len(params["blocks"]) != len(cfg.blocks):
        raise ValueError(
            f"params have {len(params['blocks'])} blocks, "
            f"config describes {len(cfg.blocks)}"
        )
    layers: list[FrozenLayer] = []
    for spec, p in zip(cfg.blocks, params["blocks"]):
        w = _narrow(p["fw"]["w"])
        layers.append(FrozenLayer(
            kind=spec.kind, w=w, sf=_layer_sf(spec.kind, w),
            alpha_inv=spec.alpha_inv, apply_relu=True,
            pool=bool(spec.pool and spec.kind == "conv"),
        ))
    w_out = _narrow(params["output"]["w"])
    layers.append(FrozenLayer(
        kind="output", w=w_out, sf=_layer_sf("output", w_out),
        alpha_inv=0, apply_relu=False, pool=False,
    ))
    return FrozenModel(
        layers=tuple(layers),
        input_shape=tuple(cfg.input_shape),
        num_classes=cfg.num_classes,
        name=cfg.name,
    )


# ---------------------------------------------------------------------------
# Quantisation report — per-layer bit-width/histogram (paper §4.4)
# ---------------------------------------------------------------------------

REPORT_FORMAT = "nitro-quant-report-v1"
REPORT_FILENAME = "QUANT_REPORT.json"


def _twos_complement_bits(lo: int, hi: int) -> int:
    """Smallest two's-complement width holding every value in [lo, hi]."""
    bits = 1
    while lo < -(1 << (bits - 1)) or hi > (1 << (bits - 1)) - 1:
        bits += 1
    return bits


def _magnitude_histogram(arr: np.ndarray) -> dict[str, int]:
    """Counts per power-of-two magnitude bucket.

    Bucket ``"0"`` counts exact zeros; bucket ``"b"`` (b ≥ 1) counts values
    with 2^(b-1) ≤ |v| < 2^b — i.e. values whose magnitude needs exactly
    ``b`` bits.  This is the paper's Fig.-style bit-growth view: the
    highest occupied bucket ``b`` puts the layer's two's-complement
    ``bit_width`` at ``b + 1`` (sign bit), or exactly ``b`` when the only
    magnitude-``b`` values are negative powers of two (e.g. [-8, 7] fits
    4 bits although |−8| occupies bucket 4).
    """
    mag = np.abs(arr.astype(np.int64))
    # |v| ≤ 2^31 ⇒ float64 log2 is exact enough for the integer floor
    bl = np.where(mag > 0, np.floor(np.log2(np.maximum(mag, 1))).astype(np.int64) + 1, 0)
    buckets, counts = np.unique(bl, return_counts=True)
    return {str(int(b)): int(c) for b, c in zip(buckets, counts)}


def quantization_report(fm: FrozenModel) -> dict:
    """Per-layer bit-width / histogram report for a FrozenModel.

    Pure metadata (JSON-serialisable) — the §4.4 bit-growth analysis of the
    exported weights: how many bits each layer actually occupies vs the
    dtype it was narrowed to, where the values concentrate, and the total
    artifact size vs a naive int32 export.
    """
    report_layers = []
    total_bytes = 0
    total_int32_bytes = 0
    max_bits = 0
    act_int8 = False  # the network input enters as int32
    for i, layer in enumerate(fm.layers):
        arr = np.asarray(jax.device_get(layer.w))
        lo, hi = int(arr.min()), int(arr.max())
        bits = _twos_complement_bits(lo, hi)
        max_bits = max(max_bits, bits)
        nbytes = int(arr.size) * arr.dtype.itemsize
        total_bytes += nbytes
        total_int32_bytes += int(arr.size) * 4
        # mirrors infer.plan's per-step operand_dtype='auto' decision:
        # int8 MXU operands are provably exact iff the incoming activation
        # was int8-narrowed AND the frozen weight narrowed to int8
        int8_eligible = act_int8 and arr.dtype == np.int8
        act_int8 = layer.apply_relu and relu_fits_int8(layer.alpha_inv)
        report_layers.append({
            "index": i,
            "kind": layer.kind,
            "shape": [int(d) for d in arr.shape],
            "dtype": str(arr.dtype),
            "sf": layer.sf,
            "alpha_inv": layer.alpha_inv,
            "params": int(arr.size),
            "bytes": nbytes,
            "min": lo,
            "max": hi,
            "zero_fraction": float((arr == 0).mean()),
            "bit_width": bits,
            "dtype_bits": arr.dtype.itemsize * 8,
            "int8_operand_eligible": bool(int8_eligible),
            "magnitude_histogram": _magnitude_histogram(arr.ravel()),
        })
    return {
        "format": REPORT_FORMAT,
        "name": fm.name,
        "num_layers": len(fm.layers),
        "num_int8_operand_eligible": sum(
            1 for l in report_layers if l["int8_operand_eligible"]
        ),
        "max_bit_width": max_bits,
        "total_bytes": total_bytes,
        "total_int32_bytes": total_int32_bytes,
        "compression_vs_int32": (
            total_int32_bytes / total_bytes if total_bytes else 1.0
        ),
        "layers": report_layers,
    }


# ---------------------------------------------------------------------------
# Persistence — train/checkpoint manifest format, topology in `extra`
# ---------------------------------------------------------------------------


def _topology(fm: FrozenModel) -> dict:
    return {
        "format": FORMAT,
        "name": fm.name,
        "input_shape": list(fm.input_shape),
        "num_classes": fm.num_classes,
        "layers": [
            {"kind": l.kind, "sf": l.sf, "alpha_inv": l.alpha_inv,
             "apply_relu": l.apply_relu, "pool": l.pool}
            for l in fm.layers
        ],
    }


def save_frozen(path: str, fm: FrozenModel, *, step: int | None = None,
                keep_last: int | None = None) -> str:
    """Write the frozen model as a COMPLETE manifest checkpoint.

    ``step=None`` auto-increments past the newest checkpoint already in
    ``path`` (0 for a fresh directory), so re-exporting a retrained model
    into the same directory *appends* a new version instead of clobbering
    the one currently being served — the on-disk half of the registry's
    hot-swap story: ``load_frozen(path)`` keeps returning the newest
    COMPLETE version, and a crashed export never corrupts it.

    Accumulated versions are kept until a save passes ``keep_last=N``,
    which prunes all but the N newest step directories after the new
    COMPLETE marker lands — a periodic re-export loop should pass it
    (or clean up out of band) or the directory grows one full weight
    copy per export.

    Also drops ``QUANT_REPORT.json`` (the per-layer bit-width/histogram
    report) next to the manifest — informational only, written after the
    COMPLETE marker so it never gates checkpoint validity.
    """
    if step is None:
        # scan the directories, not the LATEST marker: after a rollback
        # re-export (explicit lower step rewrote LATEST) incrementing
        # from LATEST would target — and ckpt.save would clobber — an
        # existing retained version
        existing = _step_numbers(path)
        step = max(existing) + 1 if existing else 0
    tree = [{"w": l.w} for l in fm.layers]
    step_dir = ckpt.save(path, step, tree, extra=_topology(fm))
    with open(os.path.join(step_dir, REPORT_FILENAME), "w") as f:
        json.dump(quantization_report(fm), f, indent=2)
    if keep_last is not None:
        prune_frozen(path, keep_last=keep_last)
    return step_dir


def prune_frozen(path: str, *, keep_last: int) -> list[int]:
    """Delete all but the ``keep_last`` newest checkpoint versions.

    The step the ``LATEST`` marker names is always kept even when it is
    not numerically newest (a rollback re-export with an explicit lower
    ``step`` rewrites ``LATEST``; pruning it would make the directory
    unloadable).  Returns the pruned step numbers.  Safe against a
    concurrent ``load_frozen(path)`` of the *latest* version; a reader
    pinning an old ``step`` races with its deletion, so prune from the
    single writer that owns the directory.
    """
    if keep_last < 1:
        raise ValueError("keep_last must be >= 1")
    latest = ckpt.latest_step(path)
    steps = _step_numbers(path)
    pruned = [s for s in steps[:-keep_last] if s != latest]
    for s in pruned:
        shutil.rmtree(os.path.join(path, f"step_{s:08d}"))
    return pruned


def _step_numbers(path: str) -> list[int]:
    """Ascending step numbers of every ``step_NNNNNNNN`` dir in ``path``."""
    if not os.path.isdir(path):
        return []
    return sorted(
        int(m.group(1))
        for name in os.listdir(path)
        if (m := re.fullmatch(r"step_(\d{8})", name))
    )


def load_frozen(path: str, *, step: int | None = None) -> FrozenModel:
    """Load a frozen model; validates format and restores exact weights.

    ``step=None`` loads the newest COMPLETE version; an explicit ``step``
    pins one (e.g. the registry rolling back a bad hot-swap).
    """
    if step is None:
        step = ckpt.latest_step(path)
    if step is None:
        raise FileNotFoundError(f"no COMPLETE frozen model in {path}")
    with open(os.path.join(path, f"step_{step:08d}", "MANIFEST.json")) as f:
        meta = json.load(f)["extra"]
    if meta.get("format") != FORMAT:
        raise ValueError(
            f"{path} is not a frozen NITRO model "
            f"(format={meta.get('format')!r}, expected {FORMAT!r})"
        )
    # structure-only template: restore fills in the real arrays by path
    tree_like = [{"w": np.zeros((), np.int8)} for _ in meta["layers"]]
    tree, _ = ckpt.restore(path, tree_like, step=step)
    layers = tuple(
        FrozenLayer(
            kind=lm["kind"], w=jnp.asarray(leaf["w"]), sf=int(lm["sf"]),
            alpha_inv=int(lm["alpha_inv"]), apply_relu=bool(lm["apply_relu"]),
            pool=bool(lm["pool"]),
        )
        for lm, leaf in zip(meta["layers"], tree)
    )
    return FrozenModel(
        layers=layers,
        input_shape=tuple(meta["input_shape"]),
        num_classes=int(meta["num_classes"]),
        name=meta["name"],
    )


# ---------------------------------------------------------------------------
# Fleet manifest — a directory of frozen models served as one unit
# ---------------------------------------------------------------------------

FLEET_FORMAT = "nitro-fleet-v1"
FLEET_FILENAME = "FLEET.json"


def save_fleet_manifest(
    root: str,
    models: dict[str, str],
    *,
    splits: dict[str, dict[str, float]] | None = None,
) -> str:
    """Write ``FLEET.json`` describing a multi-model serving fleet.

    ``models`` maps model-id → frozen-model directory (absolute, or
    relative to ``root`` — relative keeps the fleet relocatable).
    ``splits`` maps a routing alias → {model-id: weight} for A/B traffic
    splits; every arm must reference a model in ``models``.  The manifest
    is data only — ``serving.registry.ModelRegistry.from_manifest`` turns
    it into compiled plans, ``serving.fleet.Router.from_splits`` into
    routing arms.
    """
    _validate_fleet(models, splits or {})
    os.makedirs(root, exist_ok=True)
    payload = {
        "format": FLEET_FORMAT,
        "models": dict(models),
        "splits": {a: dict(w) for a, w in (splits or {}).items()},
    }
    path = os.path.join(root, FLEET_FILENAME)
    tmp = path + ".tmp"
    with open(tmp, "w") as f:
        json.dump(payload, f, indent=2)
        f.flush()
        os.fsync(f.fileno())
    os.replace(tmp, path)  # atomic: readers never see a torn manifest
    return path


def _validate_fleet(models: dict, splits: dict) -> None:
    """Shared manifest invariants — enforced on write AND read, so a
    hand-edited FLEET.json fails once at load, not per-request at serve
    time when traffic first hashes onto a broken arm."""
    if not models:
        raise ValueError("fleet manifest needs at least one model")
    for alias, arms in splits.items():
        missing = sorted(set(arms) - set(models))
        if missing:
            raise ValueError(
                f"split {alias!r} references unknown models: {missing}"
            )
        if alias in models:
            raise ValueError(f"split alias {alias!r} shadows a model id")


def load_fleet_manifest(root: str) -> dict:
    """Read and validate ``FLEET.json``; model paths resolved under root."""
    path = os.path.join(root, FLEET_FILENAME)
    if not os.path.exists(path):
        raise FileNotFoundError(f"no {FLEET_FILENAME} in {root}")
    with open(path) as f:
        meta = json.load(f)
    if meta.get("format") != FLEET_FORMAT:
        raise ValueError(
            f"{path} is not a fleet manifest "
            f"(format={meta.get('format')!r}, expected {FLEET_FORMAT!r})"
        )
    splits = meta.get("splits", {})
    _validate_fleet(meta["models"], splits)
    models = {
        mid: d if os.path.isabs(d) else os.path.join(root, d)
        for mid, d in meta["models"].items()
    }
    return {"models": models, "splits": splits}
