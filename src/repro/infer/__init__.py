"""repro.infer — integer-only CNN inference subsystem.

NITRO-D is integer-only for *both* training and inference; this package is
the inference half.  Pipeline::

    les.TrainState ──freeze──▶ FrozenModel ──compile_plan──▶ ExecutionPlan
                       │                                          │
                  save/load (manifest)                 fused nitro_matmul
                                                       (Pallas, one HBM
                                                        write per layer)

``FrozenModel`` (export.py) is the immutable deploy artifact: forward-layer
weights narrowed to the smallest lossless integer dtype, per-layer NITRO
scale factors, and topology metadata — learning layers are dropped (paper
§E.3: unused at inference).  ``ExecutionPlan`` (plan.py) lowers each layer
onto the fused ``nitro_matmul`` kernel (matmul + NITRO Scaling + NITRO-ReLU
in one VMEM pass) with a pure-``jnp`` reference backend for parity checks.
``serving.vision.VisionEngine`` batches concurrent requests over a plan.
"""

from repro.infer.export import (  # noqa: F401
    FrozenLayer,
    FrozenModel,
    freeze,
    load_fleet_manifest,
    load_frozen,
    prune_frozen,
    quantization_report,
    save_fleet_manifest,
    save_frozen,
)
from repro.infer.plan import ExecutionPlan, compile_plan  # noqa: F401
