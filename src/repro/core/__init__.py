"""NITRO-D core: native integer-only training of deep CNNs/MLPs.

The paper's primary contribution, implemented as composable JAX modules:

  numerics       integer arithmetic primitives (floor-div, int matmul, isqrt)
  scaling        NITRO Scaling Layer (SF = 2⁸·M / 2⁸·K²·C, STE backward)
  activations    NITRO-ReLU (4-segment integer LeakyReLU, mean-centred)
  layers         IntegerLinear / IntegerConv2D (+pool/dropout) with
                 hand-derived integer backward passes
  init           integer Kaiming initialisation
  preprocessing  MAD-based integer input normalisation, one-hot(32) targets
  losses         integer RSS loss
  optimizer      IntegerSGD + NITRO Amplification Factor
  blocks         integer local-loss blocks (forward + learning layers)
  model          NitroConfig / parameter containers
  les            the NITRO-D learning algorithm (train/eval steps)
  fp_baselines   FP LES and FP BP reference implementations
"""

from repro.core.activations import nitro_relu, nitro_relu_backward, mu_int8
from repro.core.blocks import BlockSpec
from repro.core.les import (
    TrainState,
    create_train_state,
    eval_step,
    reduce_lr_on_plateau,
    train_step,
)
from repro.core.model import NitroConfig, count_params, init_params, predict
from repro.core.optimizer import IntegerSGDState, amplification_factor

__all__ = [
    "BlockSpec",
    "IntegerSGDState",
    "NitroConfig",
    "TrainState",
    "amplification_factor",
    "count_params",
    "create_train_state",
    "eval_step",
    "init_params",
    "mu_int8",
    "nitro_relu",
    "nitro_relu_backward",
    "predict",
    "reduce_lr_on_plateau",
    "train_step",
]
