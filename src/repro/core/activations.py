"""NITRO-ReLU activation (paper §3.2).

An integer LeakyReLU over four segments::

    x < -127      : ⌊-127/α_inv⌋            - μ_int8     (saturated negative)
    -127 ≤ x < 0  : ⌊x/α_inv⌋               - μ_int8     (leaky slope 1/α_inv)
    0 ≤ x ≤ 127   : x                       - μ_int8     (identity)
    x > 127       : 127                     - μ_int8     (saturated positive)

with ``α_inv = ⌊1/α⌋ ∈ ℕ`` and ``μ_int8`` the (integer) mean of the four
segment means — subtracting it keeps the activations zero-centred, the
paper's integer-only stand-in for BatchNorm.

Backward: piecewise-linear derivative, kept integer — the incoming gradient
is floor-divided by ``α_inv`` on the leaky segment, passed through on the
identity segment, and zeroed on both saturated segments.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import numerics
from repro.core.numerics import ACT_MAX, ACT_MIN

DEFAULT_ALPHA_INV = 10  # α = 0.1 → α_inv = ⌊1/α⌋ = 10


def segment_means(alpha_inv: int) -> tuple[int, int, int, int]:
    """μ^i_int8 for segments i = 0..3 (paper §3.2), pure Python ints."""
    m0 = -127 // alpha_inv          # x < -127
    m1 = -127 // (2 * alpha_inv)    # -127 ≤ x ≤ 0
    m2 = 63                         # 0 < x ≤ 127
    m3 = 127                        # x > 127
    return m0, m1, m2, m3


def mu_int8(alpha_inv: int = DEFAULT_ALPHA_INV) -> int:
    """μ_int8 = integer mean of the four segment means."""
    m = segment_means(alpha_inv)
    return sum(m) // 4


def relu_fits_int8(alpha_inv: int = DEFAULT_ALPHA_INV) -> bool:
    """NITRO-ReLU output range [⌊-127/α_inv⌋-μ, 127-μ] within int8?

    The single eligibility predicate behind both inference-side int8
    decisions: inter-layer activation narrowing (``infer.plan``) and the
    int8-operand MXU fast path.  True for every α_inv ≥ 2; α_inv = 1 is
    the edge that does not fit — its segment means straddle zero so
    μ = -1, pushing the positive bound to 127 - (-1) = 128.
    """
    mu = mu_int8(alpha_inv)
    lo = (-127) // alpha_inv - mu
    hi = 127 - mu
    return -128 <= lo and hi <= 127


def nitro_relu(z_star: jax.Array, alpha_inv: int = DEFAULT_ALPHA_INV) -> jax.Array:
    """Forward NITRO-ReLU: integer in, integer out in [-127-μ, 127-μ]."""
    numerics.assert_int(z_star, "nitro_relu input")
    mu = mu_int8(alpha_inv)
    neg = numerics.floor_div(jnp.maximum(z_star, ACT_MIN), alpha_inv)
    pos = jnp.minimum(z_star, ACT_MAX)
    return jnp.where(z_star < 0, neg, pos) - mu


def nitro_relu_backward(
    z_star: jax.Array, grad_out: jax.Array, alpha_inv: int = DEFAULT_ALPHA_INV
) -> jax.Array:
    """Integer derivative of NITRO-ReLU w.r.t. its input.

    Segment derivatives: 0 (saturated) / 1/α_inv (leaky) / 1 (identity) /
    0 (saturated).  The 1/α_inv multiply is floor division, matching how the
    forward realises the slope.
    """
    numerics.assert_int(z_star, "nitro_relu_backward z")
    numerics.assert_int(grad_out, "nitro_relu_backward grad")
    leaky = numerics.floor_div(grad_out, alpha_inv)
    grad_in = jnp.where(z_star < 0, leaky, grad_out)
    saturated = (z_star < ACT_MIN) | (z_star > ACT_MAX)
    return jnp.where(saturated, jnp.zeros_like(grad_in), grad_in)
