"""Floating-point baselines the paper compares against (Tables 1–2):

  * **FP LES**  — the same local-loss block structure in float32, local MSE
    losses, SGD (Nøkland & Eidnes' algorithm restricted to the prediction
    loss, as NITRO-D uses it);
  * **FP BP**   — classic end-to-end backprop, cross-entropy + Adam.

Both reuse the `NitroConfig` topology so NITRO-D vs FP comparisons are
architecture-identical.  These are differentiable, so plain `jax.grad`.
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import model as M
from repro.core.blocks import BlockSpec

# ---------------------------------------------------------------------------
# Float forward pieces
# ---------------------------------------------------------------------------


def _conv(x, w):
    return jax.lax.conv_general_dilated(
        x, w, window_strides=(1, 1), padding="SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )


def _maxpool(x):
    return jax.lax.reduce_window(
        x, -jnp.inf, jax.lax.max, (1, 2, 2, 1), (1, 2, 2, 1), "VALID"
    )


def _avgpool_to(x, target):
    n, h, w, c = x.shape
    s = max(math.isqrt(max(target // c, 1)), 1)
    s = min(s, h, w)
    win = h // s
    xs = x[:, : s * win, : s * win, :].reshape(n, s, win, s, win, c)
    return xs.mean(axis=(2, 4)).reshape(n, -1)


def _leaky(x, alpha=0.1):
    return jnp.where(x >= 0, x, alpha * x)


def init_fp_params(key: jax.Array, cfg: M.NitroConfig) -> dict:
    """He-uniform float init mirroring the integer topology."""
    keys = jax.random.split(key, cfg.num_blocks + 1)
    params: dict = {"blocks": [], "output": None}
    shape = cfg.input_shape

    def he(k, shp, fan_in):
        b = math.sqrt(3.0) / math.sqrt(fan_in)
        return jax.random.uniform(k, shp, jnp.float32, -b, b)

    for spec, k in zip(cfg.blocks, keys[:-1]):
        kf, kl = jax.random.split(k)
        if spec.kind == "conv":
            h, w, c = shape
            fan = spec.kernel_size ** 2 * c
            fw = he(kf, (spec.kernel_size, spec.kernel_size, c, spec.out_features), fan)
            oh, ow = (h // 2, w // 2) if spec.pool else (h, w)
            shape = (oh, ow, spec.out_features)
            dummy = jnp.zeros((1, oh, ow, spec.out_features), jnp.float32)
            lr_in = _avgpool_to(dummy, spec.d_lr).shape[-1]
        else:
            m = 1
            for d in shape:  # linear blocks flatten whatever precedes them
                m *= d
            fw = he(kf, (m, spec.out_features), m)
            shape = (spec.out_features,)
            lr_in = spec.out_features
        lr = he(kl, (lr_in, cfg.num_classes), lr_in)
        params["blocks"].append({"fw": fw, "lr": lr})
    feat = 1
    for d in shape:
        feat *= d
    params["output"] = he(keys[-1], (feat, cfg.num_classes), feat)
    return params


def _block_forward(spec: BlockSpec, p: dict, a, *, key, train):
    if spec.kind == "conv":
        z = _conv(a, p["fw"])
    else:
        if a.ndim > 2:
            a = a.reshape(a.shape[0], -1)
        z = a @ p["fw"]
    a = _leaky(z)
    if spec.pool:
        a = _maxpool(a)
    if train and spec.dropout > 0.0 and key is not None:
        keep = 1.0 - spec.dropout
        a = a * jax.random.bernoulli(key, keep, a.shape) / keep
    return a


def _local_head(spec: BlockSpec, p: dict, a):
    feats = _avgpool_to(a, spec.d_lr) if spec.kind == "conv" else a
    return feats @ p["lr"]


def forward_fp(params, cfg: M.NitroConfig, x, *, train=False, key=None):
    """Float forward; returns (logits, per-block local logits)."""
    a = jnp.asarray(x, jnp.float32)
    keys = (
        list(jax.random.split(key, cfg.num_blocks))
        if (train and key is not None)
        else [None] * cfg.num_blocks
    )
    locals_ = []
    for spec, p, dk in zip(cfg.blocks, params["blocks"], keys):
        a = _block_forward(spec, p, a, key=dk, train=train)
        locals_.append((spec, p, a))
    flat = a.reshape(a.shape[0], -1)
    logits = flat @ params["output"]
    return logits, locals_


def _xent(logits, labels):
    logz = jax.nn.log_softmax(logits)
    return -jnp.mean(jnp.take_along_axis(logz, labels[:, None], axis=1))


def _mse_local(yl, labels, num_classes):
    y = jax.nn.one_hot(labels, num_classes)
    return jnp.mean((yl - y) ** 2)


def loss_bp(params, cfg, x, labels, key):
    logits, _ = forward_fp(params, cfg, x, train=True, key=key)
    return _xent(logits, labels)


def loss_les(params, cfg, x, labels, key):
    """LES: Σ local losses with stop-gradient between blocks + output loss."""
    a = jnp.asarray(x, jnp.float32)
    keys = list(jax.random.split(key, cfg.num_blocks))
    total = 0.0
    for spec, p, dk in zip(cfg.blocks, params["blocks"], keys):
        a = _block_forward(spec, p, a, key=dk, train=True)
        total = total + _mse_local(_local_head(spec, p, a), labels, cfg.num_classes)
        a = jax.lax.stop_gradient(a)  # confine gradients to the block
    flat = a.reshape(a.shape[0], -1)
    total = total + _xent(flat @ params["output"], labels)
    return total


# ---------------------------------------------------------------------------
# Adam (no optax in this container — 20-line implementation)
# ---------------------------------------------------------------------------


class AdamState(NamedTuple):
    mu: dict
    nu: dict
    count: jax.Array


def adam_init(params) -> AdamState:
    z = jax.tree_util.tree_map(jnp.zeros_like, params)
    return AdamState(mu=z, nu=jax.tree_util.tree_map(jnp.zeros_like, params),
                     count=jnp.zeros((), jnp.int32))


def adam_update(params, grads, state: AdamState, lr=1e-3, b1=0.9, b2=0.999, eps=1e-8):
    count = state.count + 1
    mu = jax.tree_util.tree_map(lambda m, g: b1 * m + (1 - b1) * g, state.mu, grads)
    nu = jax.tree_util.tree_map(lambda v, g: b2 * v + (1 - b2) * g * g, state.nu, grads)
    t = count.astype(jnp.float32)
    def upd(p, m, v):
        mhat = m / (1 - b1 ** t)
        vhat = v / (1 - b2 ** t)
        return p - lr * mhat / (jnp.sqrt(vhat) + eps)
    return jax.tree_util.tree_map(upd, params, mu, nu), AdamState(mu, nu, count)


def sgd_update(params, grads, lr=5e-4):
    return jax.tree_util.tree_map(lambda p, g: p - lr * g, params, grads)


def train_step_bp(params, opt_state, cfg, x, labels, key, lr=1e-3):
    loss, grads = jax.value_and_grad(loss_bp)(params, cfg, x, labels, key)
    params, opt_state = adam_update(params, grads, opt_state, lr=lr)
    return params, opt_state, loss


def train_step_les(params, cfg, x, labels, key, lr=5e-4):
    loss, grads = jax.value_and_grad(loss_les)(params, cfg, x, labels, key)
    return sgd_update(params, grads, lr=lr), loss


def accuracy_fp(params, cfg, x, labels):
    logits, _ = forward_fp(params, cfg, x, train=False)
    return jnp.sum(jnp.argmax(logits, -1) == labels)
