"""Integer local-loss blocks — the core NITRO-D architectural unit (§3.2).

Each block owns

  *forward layers*  : IntegerConv2D/IntegerLinear → NITRO Scaling →
                      NITRO-ReLU → [MaxPool2D] → [IntegerDropout]
  *learning layers* : [adaptive int avg-pool to d_lr] → flatten →
                      IntegerLinear(→ G) → NITRO Scaling   (produces ŷ_l)

During the backward pass gradients are *confined to the block*: the local
RSS gradient ∇L_l flows through the learning layers (updating them with
γ_inv^lr) and emerges as δ_l^fw = ∇L_l·W^{il,T} at the block output, then
flows through the forward layers (updating them with γ_inv^fw =
γ_inv^lr·AF).  Nothing crosses block boundaries — this is what bounds
integer bit-growth and makes blocks independently (= in parallel) trainable.

Every NITRO Scaling Layer is paired with its producing linear/conv layer:
the learning-layer and output-layer linears are scaled too (without a
ReLU), which is what keeps ŷ within the one-hot range and makes the
paper's b_∇L = 6 bit-width bound hold.
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp

from repro.core import activations, layers, numerics, scaling
from repro.core.losses import rss_grad
from repro.core.numerics import INT_DTYPE


def _nitro_ops():
    """Lazy import of the fused-matmul dispatcher.

    ``repro.core.__init__`` imports this module, and the kernel package
    imports ``repro.core`` leaf modules — a module-level import here would
    make ``import repro.kernels.nitro_matmul`` (as the first repro import
    of a process) circular.  Resolving at trace time breaks the cycle; the
    cost is one sys.modules lookup per traced layer.
    """
    from repro.kernels.nitro_matmul import ops

    return ops


def _conv_ops():
    """Lazy import of the conv dispatcher (same cycle-breaking rationale)."""
    from repro.kernels.nitro_conv import ops

    return ops


@dataclass(frozen=True)
class BlockSpec:
    """Static description of one integer local-loss block."""

    kind: str                 # 'conv' | 'linear'
    out_features: int         # conv filters or linear width
    pool: bool = False        # MaxPool2D(2,2) after the activation
    dropout: float = 0.0      # p_c / p_l
    d_lr: int = 4096          # learning-layer input feature budget (conv)
    alpha_inv: int = activations.DEFAULT_ALPHA_INV
    kernel_size: int = 3


# ---------------------------------------------------------------------------
# Parameter initialisation
# ---------------------------------------------------------------------------


def init_block(
    key: jax.Array,
    spec: BlockSpec,
    in_shape: tuple[int, ...],
    num_classes: int,
) -> tuple[dict, tuple[int, ...]]:
    """Init one block's params; returns (params, output shape w/o batch)."""
    k_fw, k_lr = jax.random.split(key)
    if spec.kind == "conv":
        h, w, c = in_shape
        fw = layers.conv_init(k_fw, c, spec.out_features, spec.kernel_size)
        oh, ow = (h // 2, w // 2) if spec.pool else (h, w)
        out_shape = (oh, ow, spec.out_features)
        # learning layers see the adaptive pool of the block output
        dummy = jnp.zeros((1, oh, ow, spec.out_features), INT_DTYPE)
        pooled, _ = layers.avgpool_to(dummy, spec.d_lr)
        lr_in = pooled.shape[1] * pooled.shape[2] * pooled.shape[3]
    elif spec.kind == "linear":
        m = 1
        for d in in_shape:  # linear blocks flatten whatever precedes them
            m *= d
        fw = layers.linear_init(k_fw, m, spec.out_features)
        out_shape = (spec.out_features,)
        lr_in = spec.out_features
    else:
        raise ValueError(f"unknown block kind {spec.kind!r}")
    lr = layers.linear_init(k_lr, lr_in, num_classes)
    return {"fw": fw, "lr": lr}, out_shape


# ---------------------------------------------------------------------------
# Forward layers
# ---------------------------------------------------------------------------


def forward_layers(
    params: dict,
    spec: BlockSpec,
    x: jax.Array,
    *,
    dropout_key: jax.Array | None = None,
    train: bool = True,
    fused: bool = True,
    backend: str = "auto",
    conv_mode: str = "stream",
    dp_axis: str | None = None,
    dp_shards: int = 1,
) -> tuple[jax.Array, dict]:
    """Run a block's forward layers; cache everything backward needs.

    ``fused=True`` (default) routes the matmul → NITRO Scaling → NITRO-ReLU
    pipeline through the fused kernel entry points the inference plan
    already uses: one VMEM pass emitting both the activation ``a`` and the
    pre-ReLU ``z_star`` the backward needs, instead of three HBM
    round-trips of the int32 pre-activation.  Conv blocks go through the
    ``nitro_conv`` dispatcher; ``conv_mode='stream'`` (default) forms
    im2col patches implicitly from row bands so the ``(N·H·W, K²·C)``
    patch matrix never touches HBM, ``'materialise'`` is the historical
    explicit-im2col route.  ``fused=False`` is the unfused reference
    composition — bit-exact with every fused variant (the tests enforce
    it), kept as the escape hatch/oracle.

    The cache contract is identical in all modes (``z_star`` + the
    layer input), so ``forward_layers_backward`` is unchanged.

    ``dp_axis``/``dp_shards`` (a shard_map axis name + its static size)
    make IntegerDropout draw the global-batch mask and slice this
    shard's rows — see ``layers.dropout_forward``; no other layer
    samples, so nothing else needs them.
    """
    cache: dict[str, Any] = {}
    if spec.kind == "conv":
        c_in = x.shape[-1]
        sf = scaling.conv_scale_factor(spec.kernel_size, c_in)
        if fused:
            numerics.assert_int(x, "conv input")
            a, cache["z_star"] = _conv_ops().fused_conv_fwd(
                x, params["fw"]["w"], sf=sf, alpha_inv=spec.alpha_inv,
                backend=backend, conv_mode=conv_mode,
            )
            cache["conv"] = layers.ConvCache(x=x)
        else:
            z, cache["conv"] = layers.conv_forward(params["fw"], x)
    else:
        if x.ndim > 2:  # flatten conv activations entering a linear block
            x, _ = layers.flatten_forward(x)
        sf = scaling.linear_scale_factor(x.shape[-1])
        if fused:
            numerics.assert_int(x, "linear input")
            a, cache["z_star"] = _nitro_ops().fused_matmul_fwd(
                x, params["fw"]["w"], sf=sf, alpha_inv=spec.alpha_inv,
                backend=backend,
            )
            cache["linear"] = x
        else:
            z, cache["linear"] = layers.linear_forward(params["fw"], x)
    if not fused:
        z_star = scaling.scale_forward(z, sf)
        cache["z_star"] = z_star
        a = activations.nitro_relu(z_star, spec.alpha_inv)
    if spec.pool:
        a, cache["pool"] = layers.maxpool_forward(a)
    if train and spec.dropout > 0.0:
        a, cache["dropout"] = layers.dropout_forward(
            dropout_key, a, spec.dropout, dp_axis=dp_axis, dp_shards=dp_shards,
        )
    # The block output (what feeds the next block) — a reference, not a
    # copy: ``repro.obs.telemetry`` reads its bit-occupancy when the step
    # runs with telemetry on; jit DCEs it otherwise.
    cache["act"] = a
    return a, cache


def forward_layers_backward(
    params: dict,
    spec: BlockSpec,
    cache: dict,
    delta_fw: jax.Array,
    *,
    conv_mode: str = "stream",
    backend: str = "auto",
    fuse_bwd: bool = True,
) -> dict:
    """Backward through the forward layers from δ_l^fw; returns weight grads.

    The input-gradient of the first layer is *not* propagated further —
    LES confines gradients to the block.  The dropout/pool backwards stay
    jnp; the NITRO-ReLU derivative + scaling STE that follow them are
    handed to the ``kernels.grad_ops`` dispatcher together with the cached
    ``z_star``: with ``fuse_bwd=True`` (default) they run as a prologue
    inside the gradient kernels, so the post-ReLU-bwd δ never round-trips
    through HBM; ``fuse_bwd=False`` is the unfused jnp escape hatch —
    bit-identical, test-enforced.  ``conv_mode`` selects how the conv
    gradients source their patches (streamed row bands vs explicit im2col).
    """
    g = delta_fw
    if "dropout" in cache:
        g = layers.dropout_backward(cache["dropout"], g)
    if "pool" in cache:
        g = layers.maxpool_backward(cache["pool"], g)
    if spec.kind == "conv":
        _, grads = layers.conv_backward(
            params["fw"], cache["conv"], g,
            z_star=cache["z_star"], alpha_inv=spec.alpha_inv,
            fuse_bwd=fuse_bwd, conv_mode=conv_mode, backend=backend,
        )
    else:
        _, grads = layers.linear_backward(
            params["fw"], cache["linear"], g,
            z_star=cache["z_star"], alpha_inv=spec.alpha_inv,
            fuse_bwd=fuse_bwd, backend=backend,
        )
    return grads


def forward_layers_update(
    params: dict,
    spec: BlockSpec,
    cache: dict,
    delta_fw: jax.Array,
    opt_state,
    *,
    conv_mode: str = "stream",
    backend: str = "auto",
    fuse_bwd: bool = True,
) -> dict:
    """``forward_layers_backward`` + IntegerSGD: returns updated fw params.

    Same jnp dropout/pool backwards, but the weight gradient is consumed
    where it is produced — the ``fuse_opt`` path applies the IntegerSGD
    step in the grad_W kernel's flush (``layers.conv_update`` /
    ``layers.linear_update``), so the full-size grad_W never reaches HBM.
    Bitwise identical to backward-then-``optimizer.apply_tree``.
    """
    g = delta_fw
    if "dropout" in cache:
        g = layers.dropout_backward(cache["dropout"], g)
    if "pool" in cache:
        g = layers.maxpool_backward(cache["pool"], g)
    if spec.kind == "conv":
        _, new_fw = layers.conv_update(
            params["fw"], cache["conv"], g, opt_state,
            z_star=cache["z_star"], alpha_inv=spec.alpha_inv,
            fuse_bwd=fuse_bwd, conv_mode=conv_mode, backend=backend,
        )
    else:
        _, new_fw = layers.linear_update(
            params["fw"], cache["linear"], g, opt_state,
            z_star=cache["z_star"], alpha_inv=spec.alpha_inv,
            fuse_bwd=fuse_bwd, backend=backend,
        )
    return new_fw


# ---------------------------------------------------------------------------
# Learning layers
# ---------------------------------------------------------------------------


def learning_layers(
    params: dict, spec: BlockSpec, a: jax.Array
) -> tuple[jax.Array, dict]:
    """ŷ_l = scale(pool·flatten(a_l) @ W^il); returns local prediction."""
    cache: dict[str, Any] = {}
    if spec.kind == "conv":
        a, cache["avgpool"] = layers.avgpool_to(a, spec.d_lr)
        a, cache["flat_shape"] = layers.flatten_forward(a)
    z, cache["linear"] = layers.linear_forward(params["lr"], a)
    sf = scaling.linear_scale_factor(a.shape[-1])
    y_hat = scaling.scale_forward(z, sf)
    return y_hat, cache


def learning_layers_backward(
    params: dict, spec: BlockSpec, cache: dict, grad_loss: jax.Array
) -> tuple[jax.Array, dict]:
    """Backward from ∇L_l; returns (δ_l^fw at the block output, lr grads)."""
    g = scaling.scale_backward(grad_loss)  # STE through the output scaling
    g, grads = layers.linear_backward(params["lr"], cache["linear"], g)
    if spec.kind == "conv":
        g = layers.flatten_backward(cache["flat_shape"], g)
        g = layers.avgpool_to_backward(cache["avgpool"], g)
    return g, grads


# ---------------------------------------------------------------------------
# Output layers (final classifier — trained with the global RSS gradient)
# ---------------------------------------------------------------------------


def init_output(key: jax.Array, in_features: int, num_classes: int) -> dict:
    return layers.linear_init(key, in_features, num_classes)


def output_forward(params: dict, a: jax.Array) -> tuple[jax.Array, dict]:
    cache: dict[str, Any] = {}
    if a.ndim > 2:
        a, cache["flat_shape"] = layers.flatten_forward(a)
    z, cache["linear"] = layers.linear_forward(params, a)
    sf = scaling.linear_scale_factor(a.shape[-1])
    return scaling.scale_forward(z, sf), cache


def output_backward(params: dict, cache: dict, grad_loss: jax.Array) -> dict:
    g = scaling.scale_backward(grad_loss)
    _, grads = layers.linear_backward(params, cache["linear"], g)
    return grads


def local_gradient(y_hat: jax.Array, y_onehot: jax.Array) -> jax.Array:
    """∇L_l = ŷ_l − y (RSS)."""
    return rss_grad(y_hat, y_onehot)
