"""Integer-only arithmetic primitives for NITRO-D.

Every operation in this module is closed over the integers: inputs and
outputs are integer JAX arrays and no floating-point intermediate is ever
materialised.  The paper's ``⌊·⌋`` is floor division (rounds toward −∞),
which is exactly ``jnp.floor_divide`` / Python's ``//`` — NOT C truncation.

The carrying dtype is int32 (XLA integer dot requires ≥32-bit accumulation);
logical bit-width invariants (int8 activations, int16 weights) are asserted
by the test-suite, not by the dtype system, mirroring the paper's §4.4
discussion.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

INT_DTYPE = jnp.int32
# Operational range of NITRO-ReLU / int8 activations (paper §3.2).
ACT_MIN = -127
ACT_MAX = 127


def to_int(x) -> jax.Array:
    """Cast to the carrying integer dtype (int32)."""
    return jnp.asarray(x, dtype=INT_DTYPE)


def floor_div(x: jax.Array, d) -> jax.Array:
    """Integer floor division ⌊x/d⌋ — rounds toward −∞ like the paper."""
    return jnp.floor_divide(x, d)


def int_matmul(a: jax.Array, w: jax.Array) -> jax.Array:
    """Integer matrix product with int32 accumulation.

    ``preferred_element_type=int32`` is the XLA contract for int8-style
    accumulate-in-int32 semantics; on TPU this hits the MXU integer mode.
    """
    return jax.lax.dot_general(
        a, w,
        dimension_numbers=(((a.ndim - 1,), (0,)), ((), ())),
        preferred_element_type=INT_DTYPE,
    )


def clip_act(x: jax.Array) -> jax.Array:
    """Clamp to the NITRO operational range [-127, 127]."""
    return jnp.clip(x, ACT_MIN, ACT_MAX)


def isqrt(n: jax.Array) -> jax.Array:
    """Integer square root ⌊√n⌋ via Newton iteration, pure integer.

    Used by the integer Kaiming initialiser (Appendix B.1).  Converges in
    ≤ 16 iterations for int32 inputs; we run a fixed 20 to stay jit-stable.
    """
    n = to_int(n)

    def body(_, x):
        # Newton step: x <- (x + n // x) // 2, guarded against x == 0.
        x_safe = jnp.maximum(x, 1)
        nxt = floor_div(x_safe + floor_div(n, x_safe), 2)
        return jnp.where(n > 0, jnp.minimum(x, nxt), 0)

    # start from above √n but below the int32-overflow edge: isqrt of any
    # int32 is ≤ 46340, so 46341 is a safe upper seed (x + n//x < 2³¹)
    x0 = jnp.clip(n, 1, 46341)
    out = jax.lax.fori_loop(0, 25, body, x0)
    return jnp.where(n > 0, out, 0)


def bitwidth_bound(x_bits: int, w_bits: int, fan_in: int) -> int:
    """Paper §3.2 upper bound: b_z = x_bits + w_bits - 1 + ceil(log2(fan_in))."""
    return x_bits + w_bits - 1 + max(int(fan_in - 1).bit_length(), 0)


def assert_int(x: jax.Array, name: str = "tensor") -> None:
    if not jnp.issubdtype(x.dtype, jnp.integer):
        raise TypeError(f"{name} must be integer, got {x.dtype}")
