"""IntegerSGD optimizer with integer weight decay (paper §3.3, Algorithm 1).

Update rule, entirely in ℤ::

    δ_t ← ⌊ ∇f_t(W_{t-1}) / γ_inv ⌋
    if η_inv ≠ 0:  δ_t ← δ_t + ⌊ W_{t-1} / η_inv ⌋
    W_t ← W_{t-1} − δ_t

``γ_inv = ⌊1/γ⌋`` and ``η_inv = γ_inv · λ_inv`` are the inverse learning /
composite decay rates.  The decay term is a *floor* division (rounds
toward −∞, matching ``jnp.floor_divide``), which makes its small-weight
behaviour asymmetric:

  * ``0 ≤ w < η_inv``      → ``⌊w/η_inv⌋ = 0``  — small positive weights
    are untouched;
  * ``−η_inv ≤ w < 0``     → ``⌊w/η_inv⌋ = −1`` — every small *negative*
    weight gets a constant +1 nudge per step (``w ← w + 1`` at zero
    gradient), an asymmetric pull toward zero that positive weights of
    the same magnitude don't get: at zero gradient a small negative
    weight climbs one unit per step until it reaches 0 and stays there,
    while a small positive weight never moves at all.

Algorithm 1 specifies exactly this floor arithmetic — the asymmetry is
the faithful integer semantics, not a bug — but it means decay is *not*
"zeroed for |w| < η_inv": that holds for the positive half only.  Pinned
by a hypothesis property test over negative weights
(``tests/test_integer_sgd.py``).

NITRO Amplification Factor: a block's *forward layers* receive the local
gradient amplified by the learning layers' matmul (bit-width
O(13 + log₂ G)).  AF = 2⁶·G normalises that amplification, so the effective
divisor for forward-layer updates is ``γ_inv^fw = γ_inv^lr · AF``.

    NOTE (paper deviation, recorded): the paper's text writes
    ``γ_inv^fw = γ_inv^lr / AF``, which for its own hyper-parameters
    (γ_inv = 512, G = 10 ⇒ AF = 640) floor-divides to zero and would make
    Algorithm 1 divide by zero.  The motivation (§3.3: the forward layers
    otherwise get "disproportionately large weight updates") and the AF
    bit-width derivation both require the forward-layer *effective learning
    rate* to shrink by AF, i.e. the inverse rate to grow:
    γ_inv^fw = γ_inv^lr × AF.  We implement that reading.
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import numerics
from repro.core.numerics import floor_div


def amplification_factor(num_classes: int) -> int:
    """AF = 2⁶ × G (paper §3.3)."""
    return (2 ** 6) * int(num_classes)


class IntegerSGDState(NamedTuple):
    """Mutable optimizer scalars, kept as int32 arrays so the lr schedule
    (÷3 on plateau, Appendix D) is a pure-integer in-graph update."""

    gamma_inv: jax.Array  # inverse learning rate (int32 scalar)
    eta_inv: jax.Array    # inverse composite decay rate (int32 scalar, 0 = off)


def init_state(gamma_inv: int, eta_inv: int = 0) -> IntegerSGDState:
    return IntegerSGDState(
        gamma_inv=jnp.asarray(gamma_inv, numerics.INT_DTYPE),
        eta_inv=jnp.asarray(eta_inv, numerics.INT_DTYPE),
    )


def apply_update(
    w: jax.Array, grad: jax.Array, state: IntegerSGDState
) -> jax.Array:
    """One Algorithm-1 step for a single weight tensor.

    Floor-division decay: zero for ``0 ≤ w < η_inv`` but −1 for
    ``−η_inv ≤ w < 0`` (the asymmetry documented in the module
    docstring); ``η_inv == 0`` disables decay entirely.
    """
    numerics.assert_int(w, "weights")
    numerics.assert_int(grad, "gradient")
    delta = floor_div(grad, state.gamma_inv)
    decay = jnp.where(
        state.eta_inv != 0,
        floor_div(w, jnp.maximum(state.eta_inv, 1)),
        jnp.zeros_like(w),
    )
    return w - (delta + decay)


def apply_tree(params, grads, state: IntegerSGDState):
    """Apply IntegerSGD across a whole parameter pytree."""
    return jax.tree_util.tree_map(
        lambda w, g: apply_update(w, g, state), params, grads
    )


def step_lr_schedule(state: IntegerSGDState, plateau: jax.Array) -> IntegerSGDState:
    """γ_inv ← γ_inv · 3 when the accuracy plateaus (integer analogue of the
    paper's 'reduce lr by 3× on plateau')."""
    new_gamma = jnp.where(plateau, state.gamma_inv * 3, state.gamma_inv)
    return state._replace(gamma_inv=new_gamma)
