"""Integer layers with hand-derived integer backward passes.

``jax.grad`` cannot differentiate integer computations, and NITRO-D's
learning rule is defined directly on integers — so every layer here exposes
an explicit ``forward`` (returning a cache) and ``backward`` (consuming it),
all closed over ℤ.  Layout is NHWC / (batch, features), weights are
(fan_in, fan_out) for linear and (K, K, C_in, C_out) for conv — the
TPU-native layouts.

Conv2D is realised as im2col + integer matmul: patch extraction followed by
an int8×int8→int32 ``dot_general``.  On TPU this is the idiomatic mapping of
convolution onto the MXU and lets the Pallas ``nitro_matmul`` kernel serve
conv and linear layers alike (see kernels/nitro_matmul).
"""

from __future__ import annotations

import math
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import numerics
from repro.core.init import integer_kaiming_uniform
from repro.core.numerics import floor_div, int_matmul, to_int

# ---------------------------------------------------------------------------
# Integer Linear
# ---------------------------------------------------------------------------


def linear_init(key: jax.Array, fan_in: int, fan_out: int) -> dict:
    """IntegerLinear params — no bias (Appendix B.1)."""
    return {"w": integer_kaiming_uniform(key, (fan_in, fan_out), fan_in)}


def linear_forward(params: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """z = x @ W with int32 accumulation. Cache = input activations."""
    numerics.assert_int(x, "linear input")
    return int_matmul(x, params["w"]), x


def linear_backward(
    params: dict,
    cache: jax.Array,
    grad_out: jax.Array,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    backend: str = "auto",
) -> tuple[jax.Array, dict]:
    """grad_x = g @ Wᵀ, grad_W = xᵀ @ g — both integer matmuls.

    Routed through the shared ``kernels.grad_ops`` dispatcher.  With
    ``z_star`` (the cached pre-ReLU tensor) the NITRO-ReLU-bwd/STE step
    runs as a prologue *inside* the gradient kernels (``fuse_bwd=True``,
    default) or as the unfused jnp composition (``fuse_bwd=False``) —
    bit-identical either way.  Learning/output layers pass no ``z_star``:
    their scaling STE backward is the identity.
    """
    from repro.kernels import grad_ops  # lazy: cycle-free (see blocks.py)

    grad_x, grad_w = grad_ops.linear_grads(
        cache, params["w"], grad_out,
        z_star=z_star, alpha_inv=alpha_inv, fuse_bwd=fuse_bwd,
        backend=backend,
    )
    return grad_x, {"w": grad_w}


def linear_update(
    params: dict,
    cache: jax.Array,
    grad_out: jax.Array,
    opt_state,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    backend: str = "auto",
) -> tuple[jax.Array, dict]:
    """``linear_backward`` + IntegerSGD in one pass: (grad_x, {'w': W′}).

    The weight update runs as the grad_W kernel's flush epilogue
    (``grad_ops.linear_weight_update``), so grad_W never reaches HBM —
    bitwise identical to ``linear_backward`` → ``optimizer.apply_update``.
    """
    from repro.kernels import grad_ops  # lazy: cycle-free (see blocks.py)

    grad_x, w_new = grad_ops.linear_weight_update(
        cache, params["w"], grad_out, opt_state,
        z_star=z_star, alpha_inv=alpha_inv, fuse_bwd=fuse_bwd,
        backend=backend,
    )
    return grad_x, {"w": w_new}


# ---------------------------------------------------------------------------
# Integer Conv2D (K×K, stride 1, 'same' padding) via im2col + matmul
# ---------------------------------------------------------------------------


def conv_init(key: jax.Array, in_channels: int, out_channels: int, kernel_size: int = 3) -> dict:
    fan_in = kernel_size * kernel_size * in_channels
    shape = (kernel_size, kernel_size, in_channels, out_channels)
    return {"w": integer_kaiming_uniform(key, shape, fan_in)}


def im2col(x: jax.Array, kernel_size: int, padding: int) -> jax.Array:
    """Extract K×K patches: (N,H,W,C) → (N,H,W,K·K·C), integer-safe.

    Built from pad + static slices (no gather, no float conv), so it lowers
    to cheap reshapes on any backend.
    """
    n, h, w, c = x.shape
    k = kernel_size
    xp = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    rows = []
    for i in range(k):
        for j in range(k):
            rows.append(jax.lax.dynamic_slice(xp, (0, i, j, 0), (n, h, w, c)))
    # (N,H,W,K*K,C) → (N,H,W,K*K*C); K*K ordering matches weight reshape.
    patches = jnp.stack(rows, axis=3)
    return patches.reshape(n, h, w, k * k * c)


def conv_im2col_operands(
    w: jax.Array, x: jax.Array
) -> tuple[jax.Array, jax.Array]:
    """Lower a 'same' conv to 2-D matmul operands.

    (N,H,W,C) input + (K,K,C,F) weight → (N·H·W, K²C) patches and (K²C, F)
    flattened weight.  Shared by the reference ``conv_forward``, the fused
    training forward in ``core.blocks``, and the inference plan — one
    definition of the patch/weight layout keeps all three bit-identical.
    """
    k = w.shape[0]
    n, h, ww, c = x.shape
    patches = im2col(x, k, k // 2).reshape(n * h * ww, k * k * c)
    return patches, w.reshape(-1, w.shape[-1])


class ConvCache(NamedTuple):
    x: jax.Array  # input activations (N,H,W,C)


def conv_forward(params: dict, x: jax.Array) -> tuple[jax.Array, ConvCache]:
    """z[n,h,w,f] = Σ_{i,j,c} x[n,h+i-p,w+j-p,c] · W[i,j,c,f] (int32)."""
    numerics.assert_int(x, "conv input")
    n, h, ww, _ = x.shape
    patches, w_flat = conv_im2col_operands(params["w"], x)
    z = int_matmul(patches, w_flat).reshape(n, h, ww, w_flat.shape[-1])
    return z, ConvCache(x=x)


def conv_backward(
    params: dict,
    cache: ConvCache,
    grad_out: jax.Array,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    conv_mode: str = "stream",
    backend: str = "auto",
) -> tuple[jax.Array, dict]:
    """Integer conv backward, routed through ``kernels.grad_ops``.

    grad_W : correlation of input patches with grad_out (im2colᵀ · g).
    grad_x : 'full' correlation of grad_out with the spatially-flipped,
             channel-transposed kernel — one more conv on the MXU path.

    ``conv_mode='stream'`` (default) feeds both matmuls with patches formed
    on the fly from row bands — the ``(N·H·W, K²·C)`` patch matrix is never
    materialised; ``'materialise'`` is the historical im2col formulation.
    With ``z_star`` the NITRO-ReLU-bwd/STE step is fused into the kernels'
    δ prologue (``fuse_bwd=True``) or applied as jnp pre-masking
    (``fuse_bwd=False``).  Integer accumulation is order-exact, so every
    combination agrees bit-for-bit.
    """
    from repro.kernels import grad_ops  # lazy: cycle-free

    grad_x, grad_w = grad_ops.conv_grads(
        cache.x, params["w"], grad_out,
        z_star=z_star, alpha_inv=alpha_inv, fuse_bwd=fuse_bwd,
        backend=backend, conv_mode=conv_mode,
    )
    return grad_x, {"w": grad_w}


def conv_update(
    params: dict,
    cache: ConvCache,
    grad_out: jax.Array,
    opt_state,
    *,
    z_star: jax.Array | None = None,
    alpha_inv: int = 10,
    fuse_bwd: bool = True,
    conv_mode: str = "stream",
    backend: str = "auto",
) -> tuple[jax.Array, dict]:
    """``conv_backward`` + IntegerSGD in one pass: (grad_x, {'w': W′}).

    Stream mode applies the update in the streaming grad_W kernel's flush
    (``grad_ops.conv_weight_update``); materialise mode composes the
    escape hatch.  Bitwise identical to ``conv_backward`` →
    ``optimizer.apply_update`` on every (mode, backend) combination.
    """
    from repro.kernels import grad_ops  # lazy: cycle-free

    grad_x, w_new = grad_ops.conv_weight_update(
        cache.x, params["w"], grad_out, opt_state,
        z_star=z_star, alpha_inv=alpha_inv, fuse_bwd=fuse_bwd,
        backend=backend, conv_mode=conv_mode,
    )
    return grad_x, {"w": w_new}


# ---------------------------------------------------------------------------
# MaxPool2D (2×2, stride 2) — integer max with argmax routing on backward
# ---------------------------------------------------------------------------


class PoolCache(NamedTuple):
    onehot: jax.Array  # (N,h,w,4,C) one-hot of the argmax inside each window
    in_shape: tuple[int, int, int, int]


def window_view_2x2(x: jax.Array) -> jax.Array:
    """(N,H,W,C) → (N,H//2,W//2,4,C), cropping odd trailing rows/cols
    (floor pooling, matching framework semantics for odd sizes).

    The shared definition of 2×2/stride-2 window extraction: ``maxpool``
    here, the inference plan's cacheless pool, and the streaming-conv
    oracle's pool epilogue all reduce over axis 3 of this view, so pooling
    semantics (including odd-edge cropping) are defined exactly once.
    """
    n, h, w, c = x.shape
    h2, w2 = h // 2, w // 2
    x = x[:, : h2 * 2, : w2 * 2, :]
    x = x.reshape(n, h2, 2, w2, 2, c).transpose(0, 1, 3, 2, 4, 5)
    return x.reshape(n, h2, w2, 4, c)


def maxpool_forward(x: jax.Array) -> tuple[jax.Array, PoolCache]:
    numerics.assert_int(x, "maxpool input")
    win = window_view_2x2(x)
    idx = jnp.argmax(win, axis=3)
    onehot = (idx[:, :, :, None, :] == jnp.arange(4)[None, None, None, :, None])
    out = jnp.max(win, axis=3)
    return out, PoolCache(onehot=onehot.astype(numerics.INT_DTYPE), in_shape=x.shape)


def maxpool_backward(cache: PoolCache, grad_out: jax.Array) -> jax.Array:
    """Route gradient to the (first) max position of each 2×2 window."""
    n, h, w, c = cache.in_shape
    h2, w2 = h // 2, w // 2
    g = grad_out[:, :, :, None, :] * cache.onehot  # (N,h2,w2,4,C)
    g = g.reshape(n, h2, w2, 2, 2, c).transpose(0, 1, 3, 2, 4, 5)
    g = g.reshape(n, h2 * 2, w2 * 2, c)
    if (h2 * 2, w2 * 2) != (h, w):  # repad cropped odd edges with zeros
        g = jnp.pad(g, ((0, 0), (0, h - h2 * 2), (0, w - w2 * 2), (0, 0)))
    return g


# ---------------------------------------------------------------------------
# Adaptive integer average pooling (learning-layer dimensionality reduction)
# ---------------------------------------------------------------------------


class AvgPoolCache(NamedTuple):
    in_shape: tuple[int, int, int, int]
    window: int
    target: int


def avgpool_to(x: jax.Array, target: int) -> tuple[jax.Array, AvgPoolCache]:
    """Integer adaptive average pool (N,H,W,C) → (N,s,s,C).

    ``s`` is the largest grid with s²·C ≤ d_lr (the learning layers' input
    budget).  Mean is Σ // count; backward is STE replication (no division) —
    the NITRO Amplification Factor analysis accounts only for the learning
    layers' matmul, so pooling must not re-scale the backward signal.
    """
    n, h, w, c = x.shape
    s = max(math.isqrt(max(target // c, 1)), 1)
    s = min(s, h, w)
    window = h // s
    xs = x[:, : s * window, : s * window, :]
    xs = xs.reshape(n, s, window, s, window, c)
    # int32 is safe: window sums are ≤ 127·window² « 2³¹ for any real config.
    total = jnp.sum(xs, axis=(2, 4), dtype=numerics.INT_DTYPE)
    out = floor_div(total, window * window)
    return out, AvgPoolCache(in_shape=x.shape, window=window, target=s)


def avgpool_to_backward(cache: AvgPoolCache, grad_out: jax.Array) -> jax.Array:
    """STE unpool: replicate each pooled grad across its window, zero-pad."""
    n, h, w, c = cache.in_shape
    s, window = cache.target, cache.window
    g = jnp.broadcast_to(
        grad_out[:, :, None, :, None, :], (n, s, window, s, window, c)
    ).reshape(n, s * window, s * window, c)
    pad_h, pad_w = h - s * window, w - s * window
    if pad_h or pad_w:
        g = jnp.pad(g, ((0, 0), (0, pad_h), (0, pad_w), (0, 0)))
    return g


# ---------------------------------------------------------------------------
# Integer inverted dropout
# ---------------------------------------------------------------------------

_DROPOUT_FP_BITS = 8  # fixed-point denominator 2^8 for the 1/(1-p) rescale


class DropoutCache(NamedTuple):
    mask: jax.Array
    q: int


def dropout_forward(
    key: jax.Array, x: jax.Array, rate: float,
    *, dp_axis: str | None = None, dp_shards: int = 1,
) -> tuple[jax.Array, DropoutCache]:
    """Integer inverted dropout.

    The float 1/(1−p) rescale becomes a fixed-point multiply-then-shift:
    q = round(256/(1−p)); out = (x·mask·q) >> 8.  Expectation is preserved to
    <0.4 % while staying in ℤ.  rate == 0 is the identity.

    Dropout is the one sampled operation in the training step, so it is
    also the one place batch sharding could break bitwise determinism:
    ``jax.random.bits(key, (B/n, ...))`` on a shard is *not* a row-slice
    of ``bits(key, (B, ...))`` on the full batch.  Under data parallelism
    (``dp_axis`` names the active shard_map axis, ``dp_shards`` its
    static size) every shard therefore draws the **global-batch** mask
    from the shared key and slices its own row block by
    ``lax.axis_index`` — identical masks to the single-device run at any
    device count, test-enforced.  The redundant per-shard mask generation
    is threefry on uint32, a negligible slice of step cost.
    """
    if rate <= 0.0:
        return x, DropoutCache(mask=jnp.ones((), numerics.INT_DTYPE), q=1 << _DROPOUT_FP_BITS)
    keep = 1.0 - rate
    q = int(round((1 << _DROPOUT_FP_BITS) / keep))
    # Integer Bernoulli: uniform uint32 bits < ⌊keep·2³²⌋ — keeps the whole
    # training step free of float ops (the jaxpr is asserted float-free).
    threshold = jnp.uint32(min(int(keep * (1 << 32)), (1 << 32) - 1))
    if dp_axis is not None and dp_shards > 1:
        local_b = x.shape[0]
        bits = jax.random.bits(
            key, (local_b * dp_shards, *x.shape[1:]), jnp.uint32
        )
        start = jax.lax.axis_index(dp_axis) * local_b
        bits = jax.lax.dynamic_slice_in_dim(bits, start, local_b, axis=0)
    else:
        bits = jax.random.bits(key, x.shape, jnp.uint32)
    mask = (bits < threshold).astype(numerics.INT_DTYPE)
    out = floor_div(x * mask * q, 1 << _DROPOUT_FP_BITS)
    return out, DropoutCache(mask=mask, q=q)


def dropout_backward(cache: DropoutCache, grad_out: jax.Array) -> jax.Array:
    return floor_div(grad_out * cache.mask * cache.q, 1 << _DROPOUT_FP_BITS)


# ---------------------------------------------------------------------------
# Flatten
# ---------------------------------------------------------------------------


def flatten_forward(x: jax.Array) -> tuple[jax.Array, tuple[int, ...]]:
    return x.reshape(x.shape[0], -1), x.shape


def flatten_backward(in_shape: tuple[int, ...], grad_out: jax.Array) -> jax.Array:
    return grad_out.reshape(in_shape)
