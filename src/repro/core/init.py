"""Integer Kaiming initialisation (paper Appendix B.1).

Weights are drawn from a discrete uniform U(-b, b) with

    b = ⌊ 128 · 1732 / (√fan_in · 1000) ⌋

where √fan_in is computed with integer-only arithmetic (Newton isqrt) and
1732/1000 approximates √3.  Biases are disabled throughout NITRO-D: the
NITRO Scaling Layer's floor division truncates their additive contribution
to (near) zero, so they are omitted entirely.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import numerics


def kaiming_bound(fan_in: int) -> int:
    """b = ⌊128·1732 / (isqrt(fan_in)·1000)⌋, pure integer."""
    root = int(numerics.isqrt(jnp.asarray(fan_in)))
    root = max(root, 1)
    return max((128 * 1732) // (root * 1000), 1)


def integer_kaiming_uniform(
    key: jax.Array, shape: tuple[int, ...], fan_in: int
) -> jax.Array:
    """Discrete uniform U(-b, b) integer weights (inclusive bounds)."""
    b = kaiming_bound(fan_in)
    return jax.random.randint(
        key, shape, minval=-b, maxval=b + 1, dtype=numerics.INT_DTYPE
    )
