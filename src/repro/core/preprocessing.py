"""Integer-only data pre-processing (paper Appendix B.2).

Transforms an integer dataset X into X̂ with mean ≈ 0 and std ≈ 64 using the
Mean Absolute Deviation (MAD) as the integer-friendly dispersion measure:

    μ_int = ⌊ Σ x_i / N ⌋
    ω_int = ⌊ Σ |x_i − μ_int| / N ⌋
    x̂_i   = ⌊ (x_i − μ_int) · 51 / ω_int ⌋        (51 = ⌊64·0.8⌋)

For Gaussian data ω ≈ 0.8σ, so dividing by ω and multiplying by 51 lands σ
at ~64, putting ~95 % of values inside the int8 / NITRO-ReLU range
[-127, 127].
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import numerics

MAD_TARGET_MULTIPLIER = 51  # ⌊64 × 0.8⌋


def integer_statistics(x) -> tuple[int, int]:
    """(μ_int, ω_int) over the whole dataset, integer arithmetic only.

    Runs host-side in numpy int64 (dataset-level sums overflow int32 and JAX
    x64 is disabled); this is a one-time data-pipeline step, still pure ℤ.
    """
    xi = np.asarray(x)
    if not np.issubdtype(xi.dtype, np.integer):
        raise TypeError(f"preprocess input must be integer, got {xi.dtype}")
    n = xi.size
    mu = int(np.sum(xi, dtype=np.int64) // n)
    omega = int(np.sum(np.abs(xi.astype(np.int64) - mu)) // n)
    return mu, omega


def normalize(x: jax.Array, mu: jax.Array | int, omega: jax.Array | int) -> jax.Array:
    """x̂ = ⌊(x − μ)·51 / ω⌋ with ω clamped ≥ 1."""
    omega = jnp.maximum(jnp.asarray(omega, numerics.INT_DTYPE), 1)
    centred = numerics.to_int(x) - numerics.to_int(mu)
    return numerics.floor_div(centred * MAD_TARGET_MULTIPLIER, omega)


def preprocess(x: jax.Array) -> jax.Array:
    """Full pipeline: compute dataset statistics then normalise."""
    mu, omega = integer_statistics(x)
    return normalize(x, mu, omega)
