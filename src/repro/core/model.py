"""NITRO-D model container: a stack of integer local-loss blocks + output
layers, described by a static config and a parameter pytree.

The same container expresses every paper architecture (MLP 1–4, VGG8B,
VGG11B) and anything in between; `repro/configs/paper.py` instantiates the
exact Appendix-C tables.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from repro.core import blocks as B
from repro.core.numerics import INT_DTYPE


@dataclass(frozen=True)
class NitroConfig:
    """Static NITRO-D architecture + optimiser hyper-parameters."""

    blocks: tuple[B.BlockSpec, ...]
    input_shape: tuple[int, ...]      # per-sample shape, e.g. (32,32,3) / (784,)
    num_classes: int
    gamma_inv: int = 512              # γ_inv (learning layers / output layers)
    eta_fw: int = 0                   # η_inv^fw  (0 = no decay)
    eta_lr: int = 0                   # η_inv^lr
    name: str = "nitro-d"

    @property
    def num_blocks(self) -> int:
        return len(self.blocks)


def init_params(key: jax.Array, cfg: NitroConfig) -> dict:
    """Initialise every block + the output layers (integer Kaiming)."""
    keys = jax.random.split(key, cfg.num_blocks + 1)
    params: dict = {"blocks": [], "output": None}
    shape = cfg.input_shape
    for spec, k in zip(cfg.blocks, keys[:-1]):
        p, shape = B.init_block(k, spec, shape, cfg.num_classes)
        params["blocks"].append(p)
    feat = 1
    for d in shape:
        feat *= d
    params["output"] = B.init_output(keys[-1], feat, cfg.num_classes)
    return params


def forward(
    params: dict,
    cfg: NitroConfig,
    x: jax.Array,
    *,
    train: bool = False,
    key: jax.Array | None = None,
    fused: bool = True,
    backend: str = "auto",
    conv_mode: str = "stream",
    dp_axis: str | None = None,
    dp_shards: int = 1,
) -> tuple[jax.Array, list[jax.Array], list[dict], dict]:
    """Full forward pass.

    Returns (ŷ, block activations a_1..a_L, forward caches, output cache).
    Inference callers only use ŷ; the LES trainer consumes the rest.

    ``fused`` selects the block-layer implementation: the fused kernel
    entry points shared with the inference plan (default), or the unfused
    matmul → scale → relu reference composition.  ``conv_mode`` picks the
    fused conv route: ``'stream'`` (implicit im2col, no HBM patch matrix)
    or ``'materialise'`` (explicit im2col escape hatch).  All combinations
    are bit-exact with each other, test-enforced.  (The backward mirror —
    the ``fuse_bwd`` δ-path knob — lives on ``les.train_step``, which
    threads the same ``backend``/``conv_mode`` into the gradient
    dispatcher ``kernels.grad_ops``.)

    ``dp_axis``/``dp_shards`` describe an enclosing data-parallel
    shard_map context; they only affect IntegerDropout (global-batch
    mask, sliced per shard — see ``layers.dropout_forward``).
    """
    a = jnp.asarray(x, INT_DTYPE)
    acts: list[jax.Array] = []
    caches: list[dict] = []
    if train and key is not None:
        drop_keys = list(jax.random.split(key, cfg.num_blocks))
    else:
        drop_keys = [None] * cfg.num_blocks
    for spec, p, dk in zip(cfg.blocks, params["blocks"], drop_keys):
        a, cache = B.forward_layers(
            p, spec, a, dropout_key=dk, train=train,
            fused=fused, backend=backend, conv_mode=conv_mode,
            dp_axis=dp_axis, dp_shards=dp_shards,
        )
        acts.append(a)
        caches.append(cache)
    y_hat, out_cache = B.output_forward(params["output"], a)
    return y_hat, acts, caches, out_cache


def frozen_forward(params: dict, cfg: NitroConfig, x: jax.Array) -> jax.Array:
    """Inference logits on frozen params (train=False, no caches used).

    The single source of truth for the deploy-time forward: ``les.eval_step``,
    ``predict`` and the ``repro.infer`` parity reference all route through it,
    so the fused inference plan has exactly one oracle to match bit-for-bit.
    Deliberately runs the *unfused* reference composition — it must stay an
    independent oracle for the fused kernel paths (train and infer alike).
    """
    y_hat, _, _, _ = forward(params, cfg, x, train=False, fused=False)
    return y_hat


def predict(params: dict, cfg: NitroConfig, x: jax.Array) -> jax.Array:
    """Inference-only path (learning layers unused — paper §E.3)."""
    return jnp.argmax(frozen_forward(params, cfg, x), axis=-1)


def count_params(params) -> int:
    return sum(int(p.size) for p in jax.tree_util.tree_leaves(params))
