"""The NITRO-D learning algorithm (paper §3.3) — integer-only LES training.

One training step:

  1. forward through every block's *forward layers* and the *output layers*;
  2. output layers: ∇L_o = ŷ − y → IntegerSGD update (γ_inv^lr, η_inv^lr);
  3. per block (independently — XLA schedules these concurrently, the LES
     block-parallelism the paper highlights):
       a. learning layers on a_l → ŷ_l;
       b. ∇L_l = ŷ_l − y → learning-layer update (γ_inv^lr, η_inv^lr);
       c. δ_l^fw from the learning-layer backward → forward-layer update
          (γ_inv^fw = γ_inv^lr·AF — NITRO Amplification Factor, η_inv^fw).

No gradient crosses a block boundary.  Everything below is integer: the
whole step jit-compiles to an integer-only XLA program (verifiable — the
test-suite asserts no float dtype appears in the jaxpr).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import blocks as B
from repro.core import model as M
from repro.core import optimizer as opt
from repro.core.losses import ONE_HOT_VALUE, one_hot_int, rss_grad, rss_loss
from repro.core.numerics import INT_DTYPE


class TrainState(NamedTuple):
    params: dict
    opt_lr: opt.IntegerSGDState   # learning + output layers
    opt_fw: opt.IntegerSGDState   # forward layers (γ amplified by AF)
    step: jax.Array


def create_train_state(key: jax.Array, cfg: M.NitroConfig) -> TrainState:
    params = M.init_params(key, cfg)
    af = opt.amplification_factor(cfg.num_classes)
    return TrainState(
        params=params,
        opt_lr=opt.init_state(cfg.gamma_inv, cfg.eta_lr),
        opt_fw=opt.init_state(cfg.gamma_inv * af, cfg.eta_fw),
        step=jnp.zeros((), INT_DTYPE),
    )


class StepGrads(NamedTuple):
    """Raw integer gradients of one step, pre-optimiser.

    Same structure as ``TrainState.params``: ``blocks`` is a tuple of
    ``{"fw": ..., "lr": ...}`` gradient dicts, ``output`` the output-layer
    gradient dict.  This is the pytree a data-parallel step all-reduces
    between ``compute_gradients`` and ``apply_gradients`` — int32
    summation is exact and order-invariant, so the reduction point is
    also the bitwise-determinism point (see ``repro.parallel.dp``).
    """

    blocks: tuple
    output: dict


class StepAux(NamedTuple):
    """Non-gradient byproducts of ``compute_gradients`` that the
    telemetry readout consumes (jit DCEs them otherwise)."""

    fw_caches: tuple


class StepMetrics(NamedTuple):
    loss: jax.Array          # integer RSS of the output layers
    correct: jax.Array       # # correct top-1 predictions in the batch
    local_losses: jax.Array  # per-block integer RSS (L,)

    def scaled_loss(self, batch_size: int) -> float:
        """Display-only per-sample loss in one-hot units: loss / (B·32²).

        The raw integer RSS grows with the batch size and the squared
        one-hot magnitude (Appendix B.2's 32), which makes progress
        lines hard to eyeball across configs.  This divides both out —
        a *host-side float convenience only*: it must be called on a
        concrete (already-computed) metric outside the jitted step, so
        the training jaxpr stays float-free (calling it on a tracer
        raises, by design).
        """
        return float(self.loss) / (float(batch_size) * ONE_HOT_VALUE ** 2)


def compute_gradients(
    state: TrainState,
    cfg: M.NitroConfig,
    x: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    *,
    fused: bool = True,
    fuse_bwd: bool = True,
    backend: str = "auto",
    conv_mode: str = "stream",
    dp_axis: str | None = None,
    dp_shards: int = 1,
) -> tuple[StepGrads, StepMetrics, StepAux]:
    """Forward + backward over a batch — raw gradients, no parameter update.

    This is the first half of ``train_step``, split out so a data-parallel
    step (``repro.parallel.dp``) can all-reduce the integer gradients
    between gradient computation and the IntegerSGD update.  The returned
    ``StepGrads``/``StepMetrics`` are *sums over the batch this call saw*:
    summing them across batch shards (exact int32 addition) reproduces
    the full-batch values bit-for-bit, which is what makes integer data
    parallelism bitwise-deterministic at any device count.

    ``dp_axis``/``dp_shards`` describe the data-parallel context this
    call runs in (a ``shard_map`` axis name and its size).  They exist
    solely so IntegerDropout draws the *global-batch* mask and slices
    this shard's rows — the one sampled operation whose per-shard
    evaluation would otherwise diverge from the single-device run.
    Outside shard_map leave them at their defaults.
    """
    params = state.params
    y = one_hot_int(labels, cfg.num_classes)

    # ---- forward ----------------------------------------------------------
    y_hat, acts, fw_caches, out_cache = M.forward(
        params, cfg, x, train=True, key=key, fused=fused, backend=backend,
        conv_mode=conv_mode, dp_axis=dp_axis, dp_shards=dp_shards,
    )

    # ---- output layers ----------------------------------------------------
    grad_o = rss_grad(y_hat, y)
    out_grads = B.output_backward(params["output"], out_cache, grad_o)

    # ---- per-block local gradients (independent → parallel) ---------------
    block_grads = []
    local_losses = []
    for spec, p, a_l, fw_cache in zip(
        cfg.blocks, params["blocks"], acts, fw_caches
    ):
        y_hat_l, lr_cache = B.learning_layers(p, spec, a_l)
        grad_l = B.local_gradient(y_hat_l, y)
        local_losses.append(rss_loss(y_hat_l, y))
        delta_fw, lr_grads = B.learning_layers_backward(p, spec, lr_cache, grad_l)
        fw_grads = B.forward_layers_backward(
            p, spec, fw_cache, delta_fw,
            conv_mode=conv_mode, backend=backend, fuse_bwd=fuse_bwd,
        )
        block_grads.append({"fw": fw_grads, "lr": lr_grads})

    grads = StepGrads(blocks=tuple(block_grads), output=out_grads)
    metrics = StepMetrics(
        loss=rss_loss(y_hat, y),
        correct=jnp.sum(jnp.argmax(y_hat, axis=-1) == labels),
        local_losses=jnp.stack(local_losses),
    )
    return grads, metrics, StepAux(fw_caches=tuple(fw_caches))


def apply_gradients(
    state: TrainState,
    grads: StepGrads,
    *,
    fuse_opt: bool = False,
    backend: str = "auto",
) -> TrainState:
    """IntegerSGD update of every parameter group from raw gradients.

    The second half of ``train_step``: deterministic given (state, grads),
    so two replicas holding identical state and identical (all-reduced)
    gradients step to bitwise-identical new states.

    ``fuse_opt=True`` routes the update through the standalone fused
    IntegerSGD kernel (``kernels.integer_sgd.apply_tree_fused`` — W and g
    read once, W′ written once) instead of the jnp ``opt.apply_tree`` —
    bitwise identical.  This is the data-parallel step's fused path: DP
    must materialise the gradient for the all-reduce, so it cannot use
    the grad-kernel flush epilogue, but the post-reduce update still
    avoids the floor-division temporaries' HBM round-trips.  ``backend``
    is only consulted when ``fuse_opt`` is set.
    """
    if fuse_opt:
        # lazy import: core must not import kernels at module scope
        from repro.kernels.integer_sgd.ops import apply_tree_fused

        def _apply(p, g, s):
            return apply_tree_fused(p, g, s, backend=backend)
    else:
        def _apply(p, g, s):
            return opt.apply_tree(p, g, s)

    new_blocks = [
        {
            "fw": _apply(p["fw"], g["fw"], state.opt_fw),
            "lr": _apply(p["lr"], g["lr"], state.opt_lr),
        }
        for p, g in zip(state.params["blocks"], grads.blocks)
    ]
    new_output = _apply(state.params["output"], grads.output, state.opt_lr)
    new_params = {"blocks": new_blocks, "output": new_output}
    return state._replace(params=new_params, step=state.step + 1)


def _fused_opt_step(
    state: TrainState,
    cfg: M.NitroConfig,
    x: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    *,
    fused: bool,
    fuse_bwd: bool,
    backend: str,
    conv_mode: str,
) -> tuple[TrainState, StepMetrics]:
    """The monolithic fast path behind ``train_step(fuse_opt=True)``.

    Bypasses the ``compute_gradients``/``apply_gradients`` split: each
    block's forward-layer weight gradient is consumed *inside* the grad_W
    kernel whose flush applies the IntegerSGD update
    (``blocks.forward_layers_update``), so the full-size grad_W never
    materialises in HBM.  The learning/output layers keep the jnp update —
    their gradients are small (d_lr × classes) and their backward has no
    Pallas flush to fuse into.  Bitwise identical to the split
    composition: integer floor-div over an order-exact int32 accumulation
    is exact, so fused ≡ unfused is provable (and test-enforced).
    """
    params = state.params
    y = one_hot_int(labels, cfg.num_classes)

    y_hat, acts, fw_caches, out_cache = M.forward(
        params, cfg, x, train=True, key=key, fused=fused, backend=backend,
        conv_mode=conv_mode,
    )

    grad_o = rss_grad(y_hat, y)
    out_grads = B.output_backward(params["output"], out_cache, grad_o)
    new_output = opt.apply_tree(params["output"], out_grads, state.opt_lr)

    new_blocks = []
    local_losses = []
    for spec, p, a_l, fw_cache in zip(
        cfg.blocks, params["blocks"], acts, fw_caches
    ):
        y_hat_l, lr_cache = B.learning_layers(p, spec, a_l)
        grad_l = B.local_gradient(y_hat_l, y)
        local_losses.append(rss_loss(y_hat_l, y))
        delta_fw, lr_grads = B.learning_layers_backward(p, spec, lr_cache, grad_l)
        new_fw = B.forward_layers_update(
            p, spec, fw_cache, delta_fw, state.opt_fw,
            conv_mode=conv_mode, backend=backend, fuse_bwd=fuse_bwd,
        )
        new_lr = opt.apply_tree(p["lr"], lr_grads, state.opt_lr)
        new_blocks.append({"fw": new_fw, "lr": new_lr})

    metrics = StepMetrics(
        loss=rss_loss(y_hat, y),
        correct=jnp.sum(jnp.argmax(y_hat, axis=-1) == labels),
        local_losses=jnp.stack(local_losses),
    )
    new_params = {"blocks": new_blocks, "output": new_output}
    return state._replace(params=new_params, step=state.step + 1), metrics


def train_step(
    state: TrainState,
    cfg: M.NitroConfig,
    x: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    *,
    fused: bool = True,
    fuse_bwd: bool = True,
    fuse_opt: bool = False,
    backend: str = "auto",
    conv_mode: str = "stream",
    telemetry: bool = False,
):
    """One integer-only NITRO-D step over a batch. jit-able (cfg static).

    Composes ``compute_gradients`` (forward + backward → raw integer
    gradients) with ``apply_gradients`` (IntegerSGD update) — the split
    exists so the data-parallel step in ``repro.parallel.dp`` can
    all-reduce the gradients in between; this single-device composition
    is bitwise identical to the pre-split monolithic step.

    The forward pass runs on the fused kernels by default (the same entry
    points the inference plan compiles to); ``fused=False`` is the unfused
    reference escape hatch, bit-exact with the fused step.  The backward
    is fused too: ``fuse_bwd=True`` (default) folds the NITRO-ReLU
    derivative + scaling STE into the gradient kernels' δ prologue via
    ``kernels.grad_ops``; ``fuse_bwd=False`` is the unfused jnp δ path —
    both bit-exact with each other.  ``conv_mode`` selects the conv data
    path for the fused forward *and* the conv gradients: ``'stream'``
    (implicit im2col — default) or ``'materialise'`` (explicit HBM patch
    matrices, the historical route).

    ``fuse_opt=True`` takes the monolithic fast path (``_fused_opt_step``):
    the IntegerSGD update of each forward-layer weight runs as the grad_W
    kernel's *flush epilogue*, so grad_W never materialises in HBM —
    3 HBM streams per weight update instead of 5+.  Bitwise identical to
    the split composition (test-enforced).  The split survives where the
    materialised gradient has another consumer: data parallelism (the
    all-reduce — ``parallel.dp`` applies the standalone fused kernel
    post-reduce instead) and ``telemetry=True`` (the readout inspects the
    fw gradients), which therefore falls back to the split path here.

    ``telemetry=True`` returns ``(state, metrics, telem)`` where
    ``telem`` is the integer-only numerics-telemetry pytree of
    ``repro.obs.telemetry`` (per-layer bit-occupancy/saturation, dead
    units, optimiser scalars).  Telemetry is a pure readout added as an
    extra jit output: the returned ``TrainState`` trajectory is bitwise
    identical with it on or off, and the whole jaxpr stays float-free —
    both test-enforced.
    """
    if fuse_opt and not telemetry:
        return _fused_opt_step(
            state, cfg, x, labels, key,
            fused=fused, fuse_bwd=fuse_bwd, backend=backend,
            conv_mode=conv_mode,
        )
    grads, metrics, aux = compute_gradients(
        state, cfg, x, labels, key,
        fused=fused, fuse_bwd=fuse_bwd, backend=backend, conv_mode=conv_mode,
    )
    new_state = apply_gradients(state, grads)
    if telemetry:
        # lazy import: obs is an optional read-only layer over the core
        from repro.obs import telemetry as T

        telem = T.collect_train_telemetry(
            cfg, new_state.params, aux.fw_caches,
            [g["fw"] for g in grads.blocks], grads.output,
            state.opt_lr, state.opt_fw,
        )
        return new_state, metrics, telem
    return new_state, metrics


def eval_step(
    state: TrainState, cfg: M.NitroConfig, x: jax.Array, labels: jax.Array
) -> jax.Array:
    """# correct predictions (integer) over a batch."""
    y_hat = M.frozen_forward(state.params, cfg, x)
    return jnp.sum(jnp.argmax(y_hat, axis=-1) == labels)


def reduce_lr_on_plateau(state: TrainState, plateau) -> TrainState:
    """Apply the ÷3 schedule to both optimiser groups (γ_inv ×3)."""
    plateau = jnp.asarray(plateau)
    return state._replace(
        opt_lr=opt.step_lr_schedule(state.opt_lr, plateau),
        opt_fw=opt.step_lr_schedule(state.opt_fw, plateau),
    )
