"""The NITRO-D learning algorithm (paper §3.3) — integer-only LES training.

One training step:

  1. forward through every block's *forward layers* and the *output layers*;
  2. output layers: ∇L_o = ŷ − y → IntegerSGD update (γ_inv^lr, η_inv^lr);
  3. per block (independently — XLA schedules these concurrently, the LES
     block-parallelism the paper highlights):
       a. learning layers on a_l → ŷ_l;
       b. ∇L_l = ŷ_l − y → learning-layer update (γ_inv^lr, η_inv^lr);
       c. δ_l^fw from the learning-layer backward → forward-layer update
          (γ_inv^fw = γ_inv^lr·AF — NITRO Amplification Factor, η_inv^fw).

No gradient crosses a block boundary.  Everything below is integer: the
whole step jit-compiles to an integer-only XLA program (verifiable — the
test-suite asserts no float dtype appears in the jaxpr).
"""

from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core import blocks as B
from repro.core import model as M
from repro.core import optimizer as opt
from repro.core.losses import ONE_HOT_VALUE, one_hot_int, rss_grad, rss_loss
from repro.core.numerics import INT_DTYPE


class TrainState(NamedTuple):
    params: dict
    opt_lr: opt.IntegerSGDState   # learning + output layers
    opt_fw: opt.IntegerSGDState   # forward layers (γ amplified by AF)
    step: jax.Array


def create_train_state(key: jax.Array, cfg: M.NitroConfig) -> TrainState:
    params = M.init_params(key, cfg)
    af = opt.amplification_factor(cfg.num_classes)
    return TrainState(
        params=params,
        opt_lr=opt.init_state(cfg.gamma_inv, cfg.eta_lr),
        opt_fw=opt.init_state(cfg.gamma_inv * af, cfg.eta_fw),
        step=jnp.zeros((), INT_DTYPE),
    )


class StepMetrics(NamedTuple):
    loss: jax.Array          # integer RSS of the output layers
    correct: jax.Array       # # correct top-1 predictions in the batch
    local_losses: jax.Array  # per-block integer RSS (L,)

    def scaled_loss(self, batch_size: int) -> float:
        """Display-only per-sample loss in one-hot units: loss / (B·32²).

        The raw integer RSS grows with the batch size and the squared
        one-hot magnitude (Appendix B.2's 32), which makes progress
        lines hard to eyeball across configs.  This divides both out —
        a *host-side float convenience only*: it must be called on a
        concrete (already-computed) metric outside the jitted step, so
        the training jaxpr stays float-free (calling it on a tracer
        raises, by design).
        """
        return float(self.loss) / (float(batch_size) * ONE_HOT_VALUE ** 2)


def train_step(
    state: TrainState,
    cfg: M.NitroConfig,
    x: jax.Array,
    labels: jax.Array,
    key: jax.Array,
    *,
    fused: bool = True,
    fuse_bwd: bool = True,
    backend: str = "auto",
    conv_mode: str = "stream",
    telemetry: bool = False,
):
    """One integer-only NITRO-D step over a batch. jit-able (cfg static).

    The forward pass runs on the fused kernels by default (the same entry
    points the inference plan compiles to); ``fused=False`` is the unfused
    reference escape hatch, bit-exact with the fused step.  The backward
    is fused too: ``fuse_bwd=True`` (default) folds the NITRO-ReLU
    derivative + scaling STE into the gradient kernels' δ prologue via
    ``kernels.grad_ops``; ``fuse_bwd=False`` is the unfused jnp δ path —
    both bit-exact with each other.  ``conv_mode`` selects the conv data
    path for the fused forward *and* the conv gradients: ``'stream'``
    (implicit im2col — default) or ``'materialise'`` (explicit HBM patch
    matrices, the historical route).

    ``telemetry=True`` returns ``(state, metrics, telem)`` where
    ``telem`` is the integer-only numerics-telemetry pytree of
    ``repro.obs.telemetry`` (per-layer bit-occupancy/saturation, dead
    units, optimiser scalars).  Telemetry is a pure readout added as an
    extra jit output: the returned ``TrainState`` trajectory is bitwise
    identical with it on or off, and the whole jaxpr stays float-free —
    both test-enforced.
    """
    params = state.params
    y = one_hot_int(labels, cfg.num_classes)

    # ---- forward ----------------------------------------------------------
    y_hat, acts, fw_caches, out_cache = M.forward(
        params, cfg, x, train=True, key=key, fused=fused, backend=backend,
        conv_mode=conv_mode,
    )

    # ---- output layers ----------------------------------------------------
    grad_o = rss_grad(y_hat, y)
    out_grads = B.output_backward(params["output"], out_cache, grad_o)
    new_output = opt.apply_tree(params["output"], out_grads, state.opt_lr)

    # ---- per-block local training (independent → parallel) ----------------
    new_blocks = []
    local_losses = []
    fw_grads_all = []  # retained for the telemetry readout (DCE'd otherwise)
    for spec, p, a_l, fw_cache in zip(
        cfg.blocks, params["blocks"], acts, fw_caches
    ):
        y_hat_l, lr_cache = B.learning_layers(p, spec, a_l)
        grad_l = B.local_gradient(y_hat_l, y)
        local_losses.append(rss_loss(y_hat_l, y))
        delta_fw, lr_grads = B.learning_layers_backward(p, spec, lr_cache, grad_l)
        fw_grads = B.forward_layers_backward(
            p, spec, fw_cache, delta_fw,
            conv_mode=conv_mode, backend=backend, fuse_bwd=fuse_bwd,
        )
        fw_grads_all.append(fw_grads)
        new_blocks.append(
            {
                "fw": opt.apply_tree(p["fw"], fw_grads, state.opt_fw),
                "lr": opt.apply_tree(p["lr"], lr_grads, state.opt_lr),
            }
        )

    new_params = {"blocks": new_blocks, "output": new_output}
    metrics = StepMetrics(
        loss=rss_loss(y_hat, y),
        correct=jnp.sum(jnp.argmax(y_hat, axis=-1) == labels),
        local_losses=jnp.stack(local_losses),
    )
    new_state = state._replace(params=new_params, step=state.step + 1)
    if telemetry:
        # lazy import: obs is an optional read-only layer over the core
        from repro.obs import telemetry as T

        telem = T.collect_train_telemetry(
            cfg, new_params, fw_caches, fw_grads_all, out_grads,
            state.opt_lr, state.opt_fw,
        )
        return new_state, metrics, telem
    return new_state, metrics


def eval_step(
    state: TrainState, cfg: M.NitroConfig, x: jax.Array, labels: jax.Array
) -> jax.Array:
    """# correct predictions (integer) over a batch."""
    y_hat = M.frozen_forward(state.params, cfg, x)
    return jnp.sum(jnp.argmax(y_hat, axis=-1) == labels)


def reduce_lr_on_plateau(state: TrainState, plateau) -> TrainState:
    """Apply the ÷3 schedule to both optimiser groups (γ_inv ×3)."""
    plateau = jnp.asarray(plateau)
    return state._replace(
        opt_lr=opt.step_lr_schedule(state.opt_lr, plateau),
        opt_fw=opt.step_lr_schedule(state.opt_fw, plateau),
    )
