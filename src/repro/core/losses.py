"""Integer RSS loss (paper §3.3, Eq. 1).

    L_l  = ½ (ŷ_l − y)²          (reported, integer)
    ∇L_l = ŷ_l − y               (used for training)

``y`` is the paper's custom one-hot: zeros with the true-class entry set to
32 (Appendix B.2) — integer head-room so the gradient is not constrained to
{−1, 0, 1}.  The largest one-hot value (32) needs 6 bits, which is what the
NITRO Amplification Factor's bit-width analysis assumes.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import numerics

ONE_HOT_VALUE = 32  # Appendix B.2


def one_hot_int(labels: jax.Array, num_classes: int) -> jax.Array:
    """One-hot encode with value 32 at the true class, integer dtype."""
    eye = (labels[..., None] == jnp.arange(num_classes)).astype(numerics.INT_DTYPE)
    return eye * ONE_HOT_VALUE


def rss_loss(y_hat: jax.Array, y: jax.Array) -> jax.Array:
    """Integer loss value Σ ⌊(ŷ−y)²/2⌋ summed over the batch (reporting)."""
    numerics.assert_int(y_hat, "rss y_hat")
    diff = y_hat - y
    return jnp.sum(numerics.floor_div(diff * diff, 2))


def rss_grad(y_hat: jax.Array, y: jax.Array) -> jax.Array:
    """∇L = ŷ − y, elementwise integer subtraction."""
    numerics.assert_int(y_hat, "rss y_hat")
    numerics.assert_int(y, "rss y")
    return y_hat - y
