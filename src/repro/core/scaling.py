"""NITRO Scaling Layer (paper §3.2).

Rescales integer pre-activations ``z_l`` into the NITRO-ReLU operational
range by floor division with a statically-known scaling factor::

    z*_l = ⌊ z_l / SF_l ⌋
    SF_l = 2^8 · M_{l-1}          (linear layers)
    SF_l = 2^8 · K²_{l-1} · C_{l-1}  (conv layers)

Backward is the straight-through estimator: the gradient passes unchanged
(uniform scaling does not alter the direction of the activation vector).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.core import numerics


def linear_scale_factor(fan_in: int) -> int:
    """SF for an Integer Linear layer with ``fan_in`` input features."""
    return (2 ** 8) * int(fan_in)


def conv_scale_factor(kernel_size: int, in_channels: int) -> int:
    """SF for an Integer Conv2D layer (K×K kernel, C input channels)."""
    return (2 ** 8) * int(kernel_size) ** 2 * int(in_channels)


def scale_forward(z: jax.Array, sf: int) -> jax.Array:
    """z* = ⌊z / SF⌋ — pure integer floor division."""
    numerics.assert_int(z, "pre-activations")
    return numerics.floor_div(z, jnp.asarray(sf, dtype=z.dtype))


def scale_backward(grad_out: jax.Array) -> jax.Array:
    """Straight-through estimator: δ^{ic} = δ^{sl} (paper §3.2)."""
    return grad_out


def pow2_split(sf: int) -> tuple[int, int]:
    """Split SF into (shift, residual) with SF = residual << shift.

    TPU adaptation: floor-div by the power-of-two component is an arithmetic
    right shift on the VPU; only the residual needs an integer divide.  Used
    by the Pallas kernel; the reference path divides directly.
    """
    shift = 0
    while sf % 2 == 0 and sf > 1:
        sf //= 2
        shift += 1
    return shift, sf
