"""repro.obs — observability spine: telemetry, metrics, tracing.

telemetry.py  jit-safe integer-only in-graph reductions computed
              alongside ``les.train_step(telemetry=True)``: per-layer
              bit-occupancy histograms, saturation counts, NITRO-ReLU
              dead units, optimiser-scalar evolution — bitwise-neutral
              to the training trajectory (test-enforced)
metrics.py    thread-safe MetricRegistry (counters/gauges/histograms,
              Prometheus-text + JSONL exposition, HTTP scrape server)
              — the spine ``serving.stats.EngineStats`` is built on
trace.py      monotonic-clock span tracer with thread-local nesting,
              JSONL export, optional jax.profiler bridge — wrapped
              around train-step phases and the FleetEngine batch
              lifecycle

Metric catalogue and how-to: docs/OBSERVABILITY.md.
"""

from repro.obs.metrics import (
    MetricError,
    MetricRegistry,
    MetricsServer,
    latency_summary_ms,
    percentile,
    start_metrics_server,
)
from repro.obs.trace import NULL_TRACER, Span, Tracer

__all__ = [
    "MetricError",
    "MetricRegistry",
    "MetricsServer",
    "NULL_TRACER",
    "Span",
    "Tracer",
    "latency_summary_ms",
    "percentile",
    "start_metrics_server",
]
