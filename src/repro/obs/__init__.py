"""repro.obs — observability spine: telemetry, metrics, tracing.

telemetry.py  jit-safe integer-only in-graph reductions computed
              alongside ``les.train_step(telemetry=True)``: per-layer
              bit-occupancy histograms, saturation counts, NITRO-ReLU
              dead units, optimiser-scalar evolution — bitwise-neutral
              to the training trajectory (test-enforced)
metrics.py    thread-safe MetricRegistry (counters/gauges/histograms,
              Prometheus-text + JSONL exposition, HTTP scrape server)
              — the spine ``serving.stats.EngineStats`` is built on
trace.py      monotonic-clock span tracer with thread-local nesting,
              JSONL export, optional jax.profiler bridge — wrapped
              around train-step phases and the FleetEngine batch
              lifecycle
health.py     training-health rule engine over the telemetry records:
              saturation trends, int32 headroom early warning, dead-unit
              growth, optimiser-scalar stall — windowed, hysteretic,
              edge-triggered alerts fanned out to sinks and
              ``obs_alerts_total`` counters; online in launch/train.py
              or offline over any metrics.jsonl (``scan_jsonl``)

Metric catalogue, alert-rule catalogue and how-to: docs/OBSERVABILITY.md.
"""

from repro.obs.health import (
    SEVERITIES,
    Alert,
    DeadUnitGrowthRule,
    DpCompressFitRule,
    HeadroomRule,
    HealthMonitor,
    OptimizerStallRule,
    Rule,
    SaturationTrendRule,
    default_rules,
    jsonl_sink,
    print_sink,
    scan_jsonl,
)
from repro.obs.metrics import (
    REPRO_VERSION,
    MetricError,
    MetricRegistry,
    MetricsServer,
    latency_summary_ms,
    percentile,
    register_build_info,
    start_metrics_server,
)
from repro.obs.trace import NULL_TRACER, Span, Tracer

__all__ = [
    "Alert",
    "DeadUnitGrowthRule",
    "DpCompressFitRule",
    "HeadroomRule",
    "HealthMonitor",
    "MetricError",
    "MetricRegistry",
    "MetricsServer",
    "NULL_TRACER",
    "OptimizerStallRule",
    "REPRO_VERSION",
    "Rule",
    "SEVERITIES",
    "SaturationTrendRule",
    "Span",
    "Tracer",
    "default_rules",
    "jsonl_sink",
    "latency_summary_ms",
    "percentile",
    "print_sink",
    "register_build_info",
    "scan_jsonl",
    "start_metrics_server",
]
