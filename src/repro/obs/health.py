"""Training-health rule engine: the *actionable* layer over telemetry.

``repro.obs.telemetry`` measures the integer envelopes NITRO-D training
must stay inside (bit occupancy, saturation, dead units, optimiser
scalars) — this module *watches* them.  An integer-only run that starts
saturating its int32 accumulators, or whose blocks are dying, fails
silently: the step keeps executing, the loss keeps printing, and the
budget burns (NITI, Wang et al. 2020, documents exactly this overflow
failure mode).  ``HealthMonitor`` turns the per-step telemetry records
into **alerts** the moment the trend is visible, online in the
``launch/train.py`` loop or offline over any ``metrics.jsonl``
(``scan_jsonl`` — the ``obs_top --once`` post-mortem path).

Design:

  * a **rule** holds per-signal sliding windows (windows advance per
    *sampled* step — the unit the telemetry cadence actually delivers)
    and fires **edge-triggered** alerts with hysteresis: a rule that
    fired stays *active* (visible in ``active_alerts()`` / the
    dashboard) without re-firing every step, and re-arms only when its
    clear condition — strictly below the fire condition — holds, so a
    signal oscillating around the threshold cannot ring the bell once
    per sample;
  * alerts carry a severity from :data:`SEVERITIES`; a rule whose
    condition *escalates* (warning → critical) while active fires
    again at the higher severity;
  * **sinks** are plain callables ``sink(alert)`` (see ``print_sink`` /
    ``jsonl_sink``); with a ``MetricRegistry`` attached the monitor
    additionally emits ``obs_alerts_total{rule,severity}`` counters,
    per-tensor ``obs_headroom_bits{layer,tensor}`` gauges (bits left
    before int32 overflow — the early-warning signal), and the
    ``dp_grad_fits_int16`` gauge (limb sufficiency of the compressed
    data-parallel reducer).

The rule catalogue (signal, window, threshold, rationale) is documented
in ``docs/OBSERVABILITY.md``.  None of this touches the training graph:
the monitor is a pure consumer of the host-side records, so the
telemetry-invariance guarantees (bitwise-identical trajectory,
float-free jaxpr) are untouched by construction.
"""

from __future__ import annotations

import json
from collections import deque
from dataclasses import dataclass
from typing import Callable, Iterable

from repro.obs.metrics import MetricRegistry

#: Alert severities, least to most severe.
SEVERITIES = ("info", "warning", "critical")

#: int32 magnitude bits — headroom is measured against this.
INT32_BITS = 31

#: Tensor-record keys a telemetry layer row may carry.
TENSOR_KEYS = ("weight", "grad", "z_star", "act")


def _severity_rank(severity: str) -> int:
    try:
        return SEVERITIES.index(severity)
    except ValueError:
        raise ValueError(
            f"unknown severity {severity!r}; expected one of {SEVERITIES}"
        ) from None


@dataclass(frozen=True)
class Alert:
    """One fired health alert (immutable, JSON-ready via ``to_json``)."""

    rule: str
    severity: str
    step: int
    layer: str      # "" for run-wide signals (optimiser scalars, DP)
    signal: str     # e.g. "act.sat_int8_frac"
    value: float
    threshold: float
    message: str

    def to_json(self) -> dict:
        return {
            "rule": self.rule, "severity": self.severity, "step": self.step,
            "layer": self.layer, "signal": self.signal, "value": self.value,
            "threshold": self.threshold, "message": self.message,
        }

    def format(self) -> str:
        where = f" {self.layer}" if self.layer else ""
        return (f"[{self.severity.upper()}] step {self.step}{where} "
                f"{self.rule}: {self.message}")


# ---------------------------------------------------------------------------
# Rule base: per-key windows + edge-triggered hysteresis
# ---------------------------------------------------------------------------


class Rule:
    """One health rule: windowed state per signal key, hysteresis state.

    Subclasses implement ``observe(step, rows)`` returning newly-fired
    alerts; ``rows`` is one sampled step's telemetry, keyed by layer.
    The base class owns the window buffers (``push``) and the
    active-alert state machine (``update``): a key transitions
    inactive → active when its fire condition holds (alert emitted),
    stays active silently while neither fires-higher nor clears, emits
    again only on severity escalation, and re-arms when the rule's
    clear condition holds.
    """

    name = "rule"
    severity = "warning"

    def __init__(self, *, window: int = 1):
        if window < 1:
            raise ValueError(f"{self.name}: window must be >= 1")
        self.window = window
        self._windows: dict[tuple, deque] = {}
        self.active: dict[tuple, Alert] = {}

    def push(self, key: tuple, value: float) -> deque:
        """Append one sample to ``key``'s window; returns the window."""
        win = self._windows.get(key)
        if win is None:
            win = self._windows[key] = deque(maxlen=self.window)
        win.append(value)
        return win

    def update(self, key: tuple, *, firing: bool, cleared: bool,
               alert: Callable[[], Alert]) -> Alert | None:
        """Advance one key's hysteresis state; returns a new alert or None.

        ``firing``/``cleared`` are this step's fire/clear conditions
        (clear must be *stricter than* not-firing for real hysteresis).
        ``alert`` is called lazily, only when something is emitted.
        """
        current = self.active.get(key)
        if current is None:
            if firing:
                fired = alert()
                self.active[key] = fired
                return fired
            return None
        if firing:
            fired = alert()
            if _severity_rank(fired.severity) > _severity_rank(
                    current.severity):
                self.active[key] = fired  # escalation re-fires
                return fired
            return None  # still firing at same severity: stay silent
        if cleared:
            del self.active[key]
        return None

    def observe(self, step: int, rows: dict[str, dict]) -> list[Alert]:
        raise NotImplementedError


def _is_monotone_growth(vals: Iterable[float]) -> bool:
    """Nondecreasing over the full window with a strictly positive net."""
    vals = list(vals)
    return (all(b >= a for a, b in zip(vals, vals[1:]))
            and vals[-1] > vals[0])


class SaturationTrendRule(Rule):
    """Saturation-fraction watchdog with a rising-trend early warning.

    Watches one saturation field (``sat_int8_frac`` or
    ``sat_int32_frac``) of the given tensors on every layer.  Fires when
    the latest value exceeds ``fire``, **or** — the trend detector —
    when the window is full, the values grew monotonically across it,
    and the latest already exceeds ``trend_fire`` (default ``fire/2``):
    a signal climbing steadily through half the budget is an overflow
    in the making even before it crosses the hard line.  Clears only at
    or below ``clear``.
    """

    def __init__(self, *, field: str = "sat_int8_frac",
                 tensors: tuple[str, ...] = ("act", "z_star"),
                 fire: float = 0.25, clear: float | None = None,
                 trend_fire: float | None = None,
                 window: int = 8, severity: str = "warning",
                 name: str | None = None):
        super().__init__(window=window)
        _severity_rank(severity)
        self.field = field
        self.tensors = tuple(tensors)
        self.fire = fire
        self.clear = fire / 2 if clear is None else clear
        self.trend_fire = fire / 2 if trend_fire is None else trend_fire
        self.severity = severity
        self.name = name or f"saturation[{field}]"

    def observe(self, step: int, rows: dict[str, dict]) -> list[Alert]:
        fired = []
        for layer, row in rows.items():
            for tensor in self.tensors:
                rec = row.get(tensor)
                if not isinstance(rec, dict) or self.field not in rec:
                    continue
                key = (layer, tensor)
                win = self.push(key, float(rec[self.field]))
                latest = win[-1]
                over = latest > self.fire
                trending = (len(win) == self.window
                            and _is_monotone_growth(win)
                            and latest > self.trend_fire)

                def alert(latest=latest, layer=layer, tensor=tensor,
                          over=over):
                    kind = ("above threshold" if over
                            else f"rising monotonically over the last "
                                 f"{self.window} samples")
                    return Alert(
                        rule=self.name, severity=self.severity, step=step,
                        layer=layer, signal=f"{tensor}.{self.field}",
                        value=latest, threshold=self.fire,
                        message=(f"{tensor} {self.field} = {latest:.4f} "
                                 f"{kind} (fire > {self.fire:g}, "
                                 f"clear <= {self.clear:g})"),
                    )

                out = self.update(key, firing=over or trending,
                                  cleared=latest <= self.clear, alert=alert)
                if out is not None:
                    fired.append(out)
        return fired


class HeadroomRule(Rule):
    """Bit-occupancy overflow early warning: int32 headroom in bits.

    ``headroom = 31 − msb`` of a tensor's occupied bit envelope — the
    number of doublings left before the int32 carrying dtype overflows.
    Warning at ``<= warn_bits``, escalating to critical at
    ``<= critical_bits`` (an escalation re-fires); clears only at
    ``>= clear_bits`` so a tensor breathing around the boundary does
    not flap.  The per-tensor gauge (``obs_headroom_bits``) is set by
    the monitor for every tensor every step regardless of alerts.
    """

    name = "headroom"

    def __init__(self, *, tensors: tuple[str, ...] = ("grad", "weight",
                                                      "z_star", "act"),
                 warn_bits: int = 4, critical_bits: int = 2,
                 clear_bits: int = 6):
        super().__init__(window=1)
        if not critical_bits <= warn_bits <= clear_bits:
            raise ValueError("need critical_bits <= warn_bits <= clear_bits")
        self.tensors = tuple(tensors)
        self.warn_bits = warn_bits
        self.critical_bits = critical_bits
        self.clear_bits = clear_bits

    def observe(self, step: int, rows: dict[str, dict]) -> list[Alert]:
        fired = []
        for layer, row in rows.items():
            for tensor in self.tensors:
                rec = row.get(tensor)
                if not isinstance(rec, dict) or "msb" not in rec:
                    continue
                headroom = INT32_BITS - int(rec["msb"])
                key = (layer, tensor)
                severity = ("critical" if headroom <= self.critical_bits
                            else "warning")
                threshold = (self.critical_bits
                             if severity == "critical" else self.warn_bits)

                def alert(headroom=headroom, layer=layer, tensor=tensor,
                          severity=severity, threshold=threshold, rec=rec):
                    return Alert(
                        rule=self.name, severity=severity, step=step,
                        layer=layer, signal=f"{tensor}.headroom_bits",
                        value=float(headroom), threshold=float(threshold),
                        message=(f"{tensor} has {headroom} bits of int32 "
                                 f"headroom (msb {rec['msb']}/{INT32_BITS}, "
                                 f"max|x| {rec.get('max_abs')}) — "
                                 f"{'overflow imminent' if severity == 'critical' else 'approaching overflow'}"),
                    )

                out = self.update(key, firing=headroom <= self.warn_bits,
                                  cleared=headroom >= self.clear_bits,
                                  alert=alert)
                if out is not None:
                    fired.append(out)
        return fired


class DeadUnitGrowthRule(Rule):
    """Monotone dead-unit growth (dying-block detector).

    Watches each block's ``dead_frac`` (pre-activations in NITRO-ReLU's
    zero-derivative segments).  Fires a warning when the fraction grew
    monotonically across a full window by at least ``min_growth`` —
    the trajectory signature of a block drifting dead — escalating to
    critical once the fraction passes ``ceiling`` (the block is
    effectively untrainable).  Clears when growth has stopped *and*
    the fraction is back under ``ceiling``.
    """

    name = "dead_units"

    def __init__(self, *, window: int = 6, min_growth: float = 0.05,
                 ceiling: float = 0.9):
        super().__init__(window=window)
        self.min_growth = min_growth
        self.ceiling = ceiling

    def observe(self, step: int, rows: dict[str, dict]) -> list[Alert]:
        fired = []
        for layer, row in rows.items():
            if "dead_frac" not in row:
                continue
            key = (layer,)
            win = self.push(key, float(row["dead_frac"]))
            latest = win[-1]
            growing = (len(win) == self.window
                       and _is_monotone_growth(win)
                       and latest - win[0] >= self.min_growth)
            ceiled = latest >= self.ceiling
            severity = "critical" if ceiled else "warning"

            def alert(latest=latest, layer=layer, win=win, ceiled=ceiled,
                      severity=severity):
                if ceiled:
                    msg = (f"dead_frac = {latest:.3f} >= ceiling "
                           f"{self.ceiling:g} — block effectively dead")
                else:
                    msg = (f"dead_frac grew {win[0]:.3f} -> {latest:.3f} "
                           f"monotonically over {self.window} samples "
                           f"(>= {self.min_growth:g} net growth)")
                return Alert(
                    rule=self.name, severity=severity, step=step,
                    layer=layer, signal="dead_frac", value=latest,
                    threshold=self.ceiling if ceiled else self.min_growth,
                    message=msg,
                )

            out = self.update(key, firing=growing or ceiled,
                              cleared=not growing and not ceiled,
                              alert=alert)
            if out is not None:
                fired.append(out)
        return fired


class OptimizerStallRule(Rule):
    """Optimiser-scalar stall: the ÷3-on-plateau schedule ran away.

    The IntegerSGD scalars divide the update (``eta_inv``) and the
    gradient (``gamma_inv``); once one exceeds ``max_scalar`` the
    integer floor-division quantises most updates to zero — training
    silently stalls while steps keep executing.  Edge-triggered per
    scalar; the schedule is monotone, so a fired alert effectively
    stays active for the rest of the run (clear exists for symmetry
    and for restored-from-checkpoint runs).
    """

    name = "opt_scalar_stall"

    def __init__(self, *, max_scalar: int = 1 << 20,
                 fields: tuple[str, ...] = ("eta_inv_lr", "eta_inv_fw",
                                            "gamma_inv_lr", "gamma_inv_fw")):
        super().__init__(window=1)
        self.max_scalar = max_scalar
        self.fields = tuple(fields)

    def observe(self, step: int, rows: dict[str, dict]) -> list[Alert]:
        opt = rows.get("_opt")
        if not opt:
            return []
        fired = []
        for f in self.fields:
            if f not in opt:
                continue
            value = int(opt[f])
            key = (f,)

            def alert(value=value, f=f):
                return Alert(
                    rule=self.name, severity="warning", step=step,
                    layer="", signal=f"opt.{f}", value=float(value),
                    threshold=float(self.max_scalar),
                    message=(f"{f} = {value} >= {self.max_scalar} — "
                             f"integer updates quantise to zero "
                             f"(effective step size underflow)"),
                )

            out = self.update(key, firing=value >= self.max_scalar,
                              cleared=value < self.max_scalar, alert=alert)
            if out is not None:
                fired.append(out)
        return fired


class DpCompressFitRule(Rule):
    """Compressed-reducer limb sufficiency (data-parallel runs only).

    ``parallel.dp`` records ``grad_fits_int16`` — whether every
    shard-local gradient element round-trips the 2-limb (int16) wire
    encoding.  A 0 means a ``dp_reduce="compress"`` run at
    ``num_limbs=2`` would be *lossy*: fire a warning so the operator
    sees it instead of assuming it.
    """

    name = "dp_compress_fit"

    def __init__(self):
        super().__init__(window=1)

    def observe(self, step: int, rows: dict[str, dict]) -> list[Alert]:
        dp = rows.get("_dp")
        if not dp or "grad_fits_int16" not in dp:
            return []
        fits = int(dp["grad_fits_int16"])
        key = ("grad_fits_int16",)

        def alert():
            return Alert(
                rule=self.name, severity="warning", step=step, layer="",
                signal="dp.grad_fits_int16", value=float(fits),
                threshold=1.0,
                message=("shard-local gradients no longer fit int16 "
                         "limbs — a 2-limb compressed all-reduce would "
                         "be lossy (use num_limbs>=3 or psum/ring)"),
            )

        out = self.update(key, firing=fits == 0, cleared=fits == 1,
                          alert=alert)
        return [out] if out is not None else []


def default_rules() -> list[Rule]:
    """The standing rule set ``launch/train.py`` arms (catalogued in
    docs/OBSERVABILITY.md — thresholds there, rationale here in code)."""
    return [
        # any int32-tail occupancy is one doubling from overflow: critical
        SaturationTrendRule(field="sat_int32_frac",
                            tensors=("weight", "grad", "z_star", "act"),
                            fire=0.0, clear=0.0, trend_fire=0.0,
                            window=4, severity="critical",
                            name="saturation[int32]"),
        # int8 activation-range pressure: warn at 25%, trend-warn from 12.5%
        SaturationTrendRule(field="sat_int8_frac", tensors=("act",),
                            fire=0.25, window=8, severity="warning",
                            name="saturation[int8]"),
        HeadroomRule(),
        DeadUnitGrowthRule(),
        OptimizerStallRule(),
        DpCompressFitRule(),
    ]


# ---------------------------------------------------------------------------
# Sinks
# ---------------------------------------------------------------------------


def print_sink(alert: Alert) -> None:
    """Print one alert line (the train CLI's default sink)."""
    print(f"[alert] {alert.format()}")


def jsonl_sink(path: str) -> Callable[[Alert], None]:
    """A sink appending one JSON line per alert to ``path``."""

    def sink(alert: Alert) -> None:
        with open(path, "a") as f:
            f.write(json.dumps(alert.to_json(), sort_keys=True) + "\n")

    return sink


# ---------------------------------------------------------------------------
# Monitor
# ---------------------------------------------------------------------------


def group_steps(records: Iterable[dict]) -> list[tuple[int, dict[str, dict]]]:
    """Flat telemetry rows → ``[(step, {layer: row})]`` in file order.

    Rows for one step are contiguous in ``metrics.jsonl`` (the writer
    appends one sampled step at a time), so grouping is a single pass;
    out-of-order steps simply start a new group — the monitor never
    reorders history behind the run's back.
    """
    grouped: list[tuple[int, dict[str, dict]]] = []
    for rec in records:
        step = int(rec.get("step", -1))
        layer = str(rec.get("layer", ""))
        if not grouped or grouped[-1][0] != step:
            grouped.append((step, {}))
        grouped[-1][1][layer] = rec
    return grouped


class HealthMonitor:
    """Runs a rule set over telemetry records; fans alerts out to sinks.

    Online: call ``observe_records(records)`` with each sampled step's
    rows (what ``launch/train.py`` does).  Offline: ``scan_jsonl`` over
    a finished run's ``metrics.jsonl``.  With ``registry=`` attached the
    monitor also maintains the health gauges/counters (see module
    docstring) so a live scrape shows the same state the dashboard
    renders.
    """

    def __init__(self, rules: Iterable[Rule] | None = None, *,
                 registry: MetricRegistry | None = None,
                 sinks: Iterable[Callable[[Alert], None]] = ()):
        self.rules = list(rules) if rules is not None else default_rules()
        self.sinks = list(sinks)
        self.registry = registry
        self.alerts: list[Alert] = []
        self.steps_observed = 0
        if registry is not None:
            self._alerts_total = registry.counter(
                "obs_alerts_total", "health alerts fired",
                labels=("rule", "severity"))
            self._active_gauge = registry.gauge(
                "obs_alerts_active", "health alerts currently active",
                labels=("rule",))
            self._headroom_gauge = registry.gauge(
                "obs_headroom_bits",
                "bits left before int32 overflow, per tensor",
                labels=("layer", "tensor"))
            self._dp_fits_gauge = registry.gauge(
                "dp_grad_fits_int16",
                "1 when every shard-local gradient fits 2 int8 limbs")
        else:
            self._alerts_total = None
            self._active_gauge = None
            self._headroom_gauge = None
            self._dp_fits_gauge = None

    # ---- feeding ----------------------------------------------------------

    def observe_records(self, records: Iterable[dict]) -> list[Alert]:
        """Feed telemetry rows (one or many steps); returns new alerts."""
        fired: list[Alert] = []
        for step, rows in group_steps(records):
            fired.extend(self._observe_step(step, rows))
        return fired

    def _observe_step(self, step: int, rows: dict[str, dict]) -> list[Alert]:
        self.steps_observed += 1
        self._update_gauges(rows)
        fired: list[Alert] = []
        for rule in self.rules:
            for alert in rule.observe(step, rows):
                fired.append(alert)
                self.alerts.append(alert)
                if self._alerts_total is not None:
                    self._alerts_total.labels(
                        rule=alert.rule, severity=alert.severity).inc()
                for sink in self.sinks:
                    sink(alert)
            if self._active_gauge is not None:
                self._active_gauge.labels(rule=rule.name).set(
                    len(rule.active))
        return fired

    def _update_gauges(self, rows: dict[str, dict]) -> None:
        if self._headroom_gauge is not None:
            for layer, row in rows.items():
                for tensor in TENSOR_KEYS:
                    rec = row.get(tensor)
                    if isinstance(rec, dict) and "msb" in rec:
                        self._headroom_gauge.labels(
                            layer=layer, tensor=tensor,
                        ).set(INT32_BITS - int(rec["msb"]))
        dp = rows.get("_dp")
        if (self._dp_fits_gauge is not None and dp
                and "grad_fits_int16" in dp):
            self._dp_fits_gauge.set(int(dp["grad_fits_int16"]))

    # ---- reading ----------------------------------------------------------

    def active_alerts(self) -> list[Alert]:
        """Currently-active alerts, most severe first (stable otherwise)."""
        active = [a for rule in self.rules for a in rule.active.values()]
        return sorted(active,
                      key=lambda a: (-_severity_rank(a.severity), a.rule,
                                     a.layer, a.signal))

    def summary(self) -> dict:
        """JSON-ready roll-up: fired counts by severity + active alerts."""
        by_severity = {s: 0 for s in SEVERITIES}
        for a in self.alerts:
            by_severity[a.severity] += 1
        return {
            "steps_observed": self.steps_observed,
            "alerts_fired": len(self.alerts),
            "by_severity": by_severity,
            "active": [a.to_json() for a in self.active_alerts()],
        }


def scan_jsonl(path: str, *, rules: Iterable[Rule] | None = None,
               registry: MetricRegistry | None = None,
               sinks: Iterable[Callable[[Alert], None]] = (),
               ) -> HealthMonitor:
    """Replay a finished run's ``metrics.jsonl`` through a fresh monitor.

    The offline twin of the in-loop wiring: same rules, same windows,
    same alerts — what ``obs_top --once`` and the CI alert smoke use.
    """
    monitor = HealthMonitor(rules, registry=registry, sinks=sinks)
    with open(path) as f:
        records = [json.loads(line) for line in f if line.strip()]
    monitor.observe_records(records)
    return monitor
