"""Integer-numerics telemetry: jit-safe in-graph reductions for NITRO-D.

NITRO-D's whole claim is that training stays inside integer bounds — this
module makes those bounds *observable*.  Every reduction here is closed
over ℤ (the test-suite asserts the telemetry jaxpr is float-free) and is
a pure readout of tensors the training step already computes, so a
telemetry-enabled ``les.train_step`` produces a **bitwise-identical**
``TrainState`` trajectory to a telemetry-off one (test-enforced on both
paper CNN configs).

Per tensor (weights, gradients, pre-activations) we record:

  * **bit-occupancy histogram** — counts of ``ceil(log2(|x|+1))``, i.e.
    the minimal two's-complement magnitude bit-width of each element,
    computed with ``lax.clz`` (no float log).  Bucket ``b`` holds the
    elements needing exactly ``b`` bits, ``b = 0..32`` (bucket 32 exists
    only for INT32_MIN).  This is the WAGE/NITI-style diagnostic: the
    occupied-bucket envelope shows how much of the int32 carrying dtype a
    layer actually uses, and whether it is drifting toward overflow;
  * **saturation counts** vs the int8 activation bound (|x| > 127 ⇔
    ≥ 8 bits) and vs the int32 headroom watermark (≥ 31 bits ⇔
    |x| ≥ 2³⁰ — one more doubling overflows);
  * **max |x|** — the scalar envelope.

Per block we additionally record the **NITRO-ReLU dead-unit count** (the
pre-activations in the two saturated segments, where the backward
derivative is zero) and the evolving optimiser scalars (``gamma_inv`` /
``eta_inv`` for both groups — the ÷3-on-plateau schedule is visible
here).

Host-side, ``to_records`` flattens one step's telemetry pytree into
JSON-ready dicts (floats allowed *there* — only the in-graph computation
must stay integer) and ``append_jsonl`` streams them to the
``metrics.jsonl`` that ``launch/train.py --telemetry-every N`` writes.
``docs/OBSERVABILITY.md`` documents how to read the output.
"""

from __future__ import annotations

import json
import os
from typing import NamedTuple

import jax
import jax.numpy as jnp

from repro.core.numerics import ACT_MAX, ACT_MIN, INT_DTYPE

# Buckets 0..32: bit-width of any int32 value (32 only for INT32_MIN).
NUM_BIT_BUCKETS = 33
# |x| > 127 needs ≥ 8 magnitude bits — outside the int8 activation range.
INT8_SAT_BITS = 8
# ≥ 31 bits ⇔ |x| ≥ 2³⁰: one doubling away from int32 overflow.
INT32_SAT_BITS = 31


class TensorTelemetry(NamedTuple):
    """Integer summary of one tensor (all fields int32 arrays)."""

    bit_hist: jax.Array   # (NUM_BIT_BUCKETS,) bit-occupancy counts
    sat_int8: jax.Array   # scalar: # elements with |x| > 127
    sat_int32: jax.Array  # scalar: # elements with |x| >= 2**30
    max_abs: jax.Array    # scalar: max |x| (INT32_MAX if INT32_MIN present)


def bit_width(x: jax.Array) -> jax.Array:
    """Elementwise ``ceil(log2(|x|+1))`` == ``|x|.bit_length()``, in ℤ.

    Uses count-leading-zeros (``lax.clz``) on the magnitude — no float
    log anywhere.  ``INT32_MIN`` (whose magnitude overflows ``abs``) is
    special-cased to the full 32 bits.
    """
    v = jnp.asarray(x, INT_DTYPE)
    info = jnp.iinfo(INT_DTYPE)
    mag = jnp.where(v == info.min, info.max, jnp.abs(v))
    bits = (info.bits - jax.lax.clz(mag)).astype(INT_DTYPE)
    return jnp.where(v == info.min, jnp.asarray(info.bits, INT_DTYPE), bits)


def _bit_histogram(bits: jax.Array) -> jax.Array:
    """``hist[k] = #{i : bits_i == k}`` for k = 0..NUM_BIT_BUCKETS-1.

    A ``lax.scan`` of 33 vectorised equality-count reductions over an
    int8 copy of the bit-widths, NOT a per-element scatter-add: XLA's
    CPU scatter serialises the n updates (~7× the cost of this on
    full-size activations, measured in benchmarks/obs_overhead.py), and
    broadcasting an (n, 33) one-hot is memory-bound; 33 narrow passes
    over int8 data keep the telemetry step within the <3%-at-default-
    sampling overhead budget.
    """
    bits8 = bits.astype(jnp.int8)  # 0..32 fits; 4× less traffic per pass
    buckets = jnp.arange(NUM_BIT_BUCKETS, dtype=jnp.int8)

    def count(carry, k):
        return carry, jnp.sum(bits8 == k, dtype=INT_DTYPE)

    _, hist = jax.lax.scan(count, None, buckets)
    return hist


def bit_occupancy(x: jax.Array) -> jax.Array:
    """Bit-occupancy histogram: (NUM_BIT_BUCKETS,) int32 counts."""
    return _bit_histogram(bit_width(x).ravel())


def tensor_telemetry(x: jax.Array) -> TensorTelemetry:
    """All integer summaries of one tensor; saturation counts fall out
    of the histogram tail (bits ≥ 8 ⇔ |x| > 127, bits ≥ 31 ⇔ |x| ≥ 2³⁰)."""
    hist = _bit_histogram(bit_width(x).ravel())
    info = jnp.iinfo(INT_DTYPE)
    v = jnp.asarray(x, INT_DTYPE).ravel()
    mag = jnp.where(v == info.min, info.max, jnp.abs(v))
    return TensorTelemetry(
        bit_hist=hist,
        sat_int8=jnp.sum(hist[INT8_SAT_BITS:], dtype=INT_DTYPE),
        sat_int32=jnp.sum(hist[INT32_SAT_BITS:], dtype=INT_DTYPE),
        max_abs=jnp.max(mag),
    )


def relu_dead_count(z_star: jax.Array) -> jax.Array:
    """# pre-activations in NITRO-ReLU's saturated (zero-derivative)
    segments — the units this step's block-local gradient cannot move."""
    dead = (z_star < ACT_MIN) | (z_star > ACT_MAX)
    return jnp.sum(dead, dtype=INT_DTYPE)


def collect_train_telemetry(
    cfg, new_params: dict, fw_caches: list, fw_grads: list,
    out_grads: dict, opt_lr, opt_fw,
) -> dict:
    """One training step's full telemetry pytree (all leaves integer).

    Reads the *post-update* weights (the state the trajectory carries),
    the raw forward-layer weight gradients (pre ``γ_inv`` floor-div — the
    widest integers in the step), and the cached pre-ReLU ``z_star``
    pre-activations.  Called by ``les.train_step(telemetry=True)``; the
    result is an extra jit output, so collecting it cannot perturb the
    training computation.
    """
    blocks = []
    for spec, p, cache, grads in zip(
        cfg.blocks, new_params["blocks"], fw_caches, fw_grads
    ):
        z_star = cache["z_star"]
        blocks.append({
            "weight": tensor_telemetry(p["fw"]["w"]),
            "grad": tensor_telemetry(grads["w"]),
            "z_star": tensor_telemetry(z_star),
            "act": tensor_telemetry(cache["act"]),
            "dead": relu_dead_count(z_star),
        })
    return {
        "blocks": blocks,
        "output": {
            "weight": tensor_telemetry(new_params["output"]["w"]),
            "grad": tensor_telemetry(out_grads["w"]),
        },
        "opt": {
            "gamma_inv_lr": opt_lr.gamma_inv,
            "eta_inv_lr": opt_lr.eta_inv,
            "gamma_inv_fw": opt_fw.gamma_inv,
            "eta_inv_fw": opt_fw.eta_inv,
        },
    }


# ---------------------------------------------------------------------------
# Host-side flattening (floats allowed from here on)
# ---------------------------------------------------------------------------


def _tensor_record(tt: TensorTelemetry) -> dict:
    hist = [int(c) for c in jax.device_get(tt.bit_hist)]
    total = sum(hist)
    occupied = [b for b, c in enumerate(hist) if c]
    return {
        "bit_hist": hist,
        "total": total,
        "msb": occupied[-1] if occupied else 0,
        "max_abs": int(tt.max_abs),
        "sat_int8": int(tt.sat_int8),
        "sat_int32": int(tt.sat_int32),
        "sat_int8_frac": int(tt.sat_int8) / total if total else 0.0,
        "sat_int32_frac": int(tt.sat_int32) / total if total else 0.0,
    }


def to_records(telem: dict, *, cfg, step: int) -> list[dict]:
    """Flatten one step's telemetry pytree into JSON-ready row dicts.

    One row per block (weights/grads/pre-activations + dead fraction +
    the static ``alpha_inv``), one for the output layers, one ``_opt``
    row with the evolving optimiser scalars, and — on data-parallel
    runs — a ``_dp`` row (shard count + compressed-reducer limb fit).
    """
    records = []
    for i, (spec, bt) in enumerate(zip(cfg.blocks, telem["blocks"])):
        z = _tensor_record(bt["z_star"])
        dead = int(bt["dead"])
        records.append({
            "step": int(step),
            "layer": f"block{i}",
            "kind": spec.kind,
            "alpha_inv": int(spec.alpha_inv),
            "weight": _tensor_record(bt["weight"]),
            "grad": _tensor_record(bt["grad"]),
            "z_star": z,
            "act": _tensor_record(bt["act"]),
            "dead": dead,
            "dead_frac": dead / z["total"] if z["total"] else 0.0,
        })
    records.append({
        "step": int(step),
        "layer": "output",
        "kind": "linear",
        "weight": _tensor_record(telem["output"]["weight"]),
        "grad": _tensor_record(telem["output"]["grad"]),
    })
    records.append({
        "step": int(step),
        "layer": "_opt",
        **{k: int(v) for k, v in telem["opt"].items()},
    })
    if "dp" in telem:  # data-parallel runs only (see parallel.dp)
        records.append({
            "step": int(step),
            "layer": "_dp",
            **{k: int(v) for k, v in telem["dp"].items()},
        })
    return records


def append_jsonl(path: str, records: list[dict]) -> None:
    """Append one JSON line per record (the ``metrics.jsonl`` format).

    Creates the parent directory if needed — the default path sits next
    to checkpoints that may not have been written yet at the first
    sampled step.
    """
    parent = os.path.dirname(path)
    if parent:
        os.makedirs(parent, exist_ok=True)
    with open(path, "a") as f:
        for rec in records:
            f.write(json.dumps(rec, sort_keys=True) + "\n")
