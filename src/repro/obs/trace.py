"""Lightweight span tracing for train + serve hot paths.

A ``Tracer`` records named spans on the monotonic clock
(``time.monotonic_ns`` — immune to wall-clock steps) with thread-local
nesting: a span opened inside another span on the same thread carries
its ``parent_id``, so an exported trace reconstructs the call tree —
e.g. one ``fleet.batch`` span containing ``assemble`` → ``dispatch`` →
``fetch`` → ``deliver`` children, or a ``train.step`` span containing a
``checkpoint`` child.

Design points:

  * **bounded** — spans land in a ``deque(maxlen=capacity)``; a
    long-lived engine never grows host memory per batch.  ``recorded``
    counts everything ever finished, so ``recorded - len(snapshot())``
    is the number of evicted (oldest) spans;
  * **thread-safe** — each thread keeps its own nesting stack
    (``threading.local``), the finished-span buffer is lock-protected;
  * **cheap when off** — ``NULL_TRACER`` is a no-op stand-in with the
    same surface, so instrumented code reads
    ``self.tracer.span("assemble")`` unconditionally;
  * **profiler bridge** — ``annotate=True`` additionally wraps each span
    in ``jax.profiler.TraceAnnotation`` (when available), making the
    spans visible inside an XLA profile without a second instrumentation
    pass.

``export_jsonl`` writes one span per line (ns integers, start-ordered)
for offline analysis; ``docs/OBSERVABILITY.md`` shows how to read it.
"""

from __future__ import annotations

import json
import threading
import time
from collections import deque
from contextlib import contextmanager, nullcontext
from typing import Any, NamedTuple


class Span(NamedTuple):
    """One finished span (times in ns on the monotonic clock)."""

    name: str
    t_start_ns: int
    t_end_ns: int
    span_id: int
    parent_id: int | None
    thread: str
    attrs: dict[str, Any]

    @property
    def duration_ns(self) -> int:
        return self.t_end_ns - self.t_start_ns


def _trace_annotation_cls():
    """``jax.profiler.TraceAnnotation`` when importable, else None."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation
    except Exception:  # pragma: no cover — jax always present in this repo
        return None


class Tracer:
    """Records nested spans; export with ``snapshot()``/``export_jsonl``."""

    def __init__(self, *, capacity: int = 65536, annotate: bool = False):
        self._lock = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=capacity)
        self._local = threading.local()
        self._next_id = 1
        self.recorded = 0  # total spans ever finished (incl. evicted)
        self._annotation = _trace_annotation_cls() if annotate else None

    def _stack(self) -> list[int]:
        stack = getattr(self._local, "stack", None)
        if stack is None:
            stack = self._local.stack = []
        return stack

    @contextmanager
    def span(self, name: str, **attrs):
        """Context manager recording one span around its body.

        The span is recorded even when the body raises — a failing batch
        still shows up in the trace, with its true duration.
        """
        stack = self._stack()
        parent = stack[-1] if stack else None
        with self._lock:
            span_id = self._next_id
            self._next_id += 1
        stack.append(span_id)
        bridge = (self._annotation(name) if self._annotation is not None
                  else nullcontext())
        t0 = time.monotonic_ns()
        try:
            with bridge:
                yield span_id
        finally:
            t1 = time.monotonic_ns()
            stack.pop()
            with self._lock:
                self._spans.append(Span(
                    name=name, t_start_ns=t0, t_end_ns=t1, span_id=span_id,
                    parent_id=parent,
                    thread=threading.current_thread().name, attrs=attrs,
                ))
                self.recorded += 1

    def event(self, name: str, **attrs) -> None:
        """Record an instantaneous (zero-duration) span."""
        with self.span(name, **attrs):
            pass

    def snapshot(self) -> list[Span]:
        """The retained spans, oldest first (a consistent copy)."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def export_jsonl(self, path: str) -> int:
        """Write one JSON line per span, start-ordered; returns the count."""
        spans = sorted(self.snapshot(), key=lambda s: s.t_start_ns)
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps({
                    "name": s.name,
                    "t_start_ns": s.t_start_ns,
                    "t_end_ns": s.t_end_ns,
                    "duration_ns": s.duration_ns,
                    "span_id": s.span_id,
                    "parent_id": s.parent_id,
                    "thread": s.thread,
                    "attrs": s.attrs,
                }, sort_keys=True) + "\n")
        return len(spans)


class _NullTracer:
    """No-op stand-in: same surface as ``Tracer``, near-zero cost.

    Instrumented hot paths hold a tracer unconditionally
    (``tracer = tracer or NULL_TRACER``) instead of branching at every
    phase.
    """

    recorded = 0

    def span(self, name: str, **attrs):
        return nullcontext(0)

    def event(self, name: str, **attrs) -> None:
        pass

    def snapshot(self) -> list[Span]:
        return []

    def clear(self) -> None:
        pass

    def export_jsonl(self, path: str) -> int:
        with open(path, "w"):
            pass
        return 0


NULL_TRACER = _NullTracer()
