"""Lightweight span tracing for train + serve hot paths.

A ``Tracer`` records named spans on the monotonic clock
(``time.monotonic_ns`` — immune to wall-clock steps) with thread-local
nesting: a span opened inside another span on the same thread carries
its ``parent_id``, so an exported trace reconstructs the call tree —
e.g. one ``fleet.batch`` span containing ``assemble`` → ``dispatch`` →
``fetch`` → ``deliver`` children, or a ``train.step`` span containing a
``checkpoint`` child.

Design points:

  * **bounded** — spans land in a ``deque(maxlen=capacity)``; a
    long-lived engine never grows host memory per batch.  ``recorded``
    counts everything ever finished, so ``recorded - len(snapshot())``
    is the number of evicted (oldest) spans;
  * **thread-safe** — each thread keeps its own nesting stack
    (``threading.local``), the finished-span buffer is lock-protected;
  * **cheap when off** — ``NULL_TRACER`` is a no-op stand-in with the
    same surface, so instrumented code reads
    ``self.tracer.span("assemble")`` unconditionally;
  * **cheap when on** — a span is a small ``__slots__`` context manager
    (no ``@contextmanager`` generator machinery), ids come from an
    atomic counter instead of a lock round-trip, the per-thread name is
    cached, and attr-less spans share one empty dict.  Hot paths
    pre-bind the span name once (``bound = tracer.bind("fleet.fetch")``,
    then ``with bound(model=...)``) so the per-call cost is one object
    allocation + two clock reads + one lock acquisition at exit —
    what lets the fleet batch loop trace every phase inside the <3%
    overhead budget (``BENCH_obs.json``);
  * **profiler bridge** — ``annotate=True`` additionally wraps each span
    in ``jax.profiler.TraceAnnotation`` (when available), making the
    spans visible inside an XLA profile without a second instrumentation
    pass.

``export_jsonl`` writes one span per line (ns integers, start-ordered)
for offline analysis; ``docs/OBSERVABILITY.md`` shows how to read it.
"""

from __future__ import annotations

import itertools
import json
import threading
import time
from collections import deque
from contextlib import nullcontext
from typing import Any, NamedTuple


class Span(NamedTuple):
    """One finished span (times in ns on the monotonic clock)."""

    name: str
    t_start_ns: int
    t_end_ns: int
    span_id: int
    parent_id: int | None
    thread: str
    attrs: dict[str, Any]

    @property
    def duration_ns(self) -> int:
        return self.t_end_ns - self.t_start_ns


def _trace_annotation_cls():
    """``jax.profiler.TraceAnnotation`` when importable, else None."""
    try:
        from jax.profiler import TraceAnnotation
        return TraceAnnotation
    except Exception:  # pragma: no cover — jax always present in this repo
        return None


# Shared by every attr-less span: allocating a fresh dict per span was a
# measurable slice of the fleet batch loop's tracing overhead.  Treat as
# immutable (Span.attrs aliases it).
_EMPTY_ATTRS: dict[str, Any] = {}


class _ThreadState(threading.local):
    """Per-thread nesting stack + cached thread name.

    ``threading.current_thread().name`` costs a dict lookup and an
    attribute walk per call; spans close often enough that caching it
    per thread is worth the subclassed-local dance.
    """

    def __init__(self):
        self.stack: list[int] = []
        self.name: str = threading.current_thread().name


class _SpanHandle:
    """One in-flight span: a plain ``__slots__`` context manager.

    Replaces the historical ``@contextmanager`` generator — generator
    frames, ``next()`` dispatch and the try/finally trampoline cost
    ~10× this object's allocation on the fleet batch hot path.  The
    span is recorded even when the body raises — a failing batch still
    shows up in the trace, with its true duration.
    """

    __slots__ = ("_tracer", "_name", "_attrs", "_parent", "_span_id",
                 "_t0", "_bridge")

    def __init__(self, tracer: "Tracer", name: str, attrs: dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs

    def __enter__(self) -> int:
        tracer = self._tracer
        stack = tracer._state.stack
        self._parent = stack[-1] if stack else None
        self._span_id = span_id = next(tracer._ids)
        stack.append(span_id)
        if tracer._annotation is not None:
            self._bridge = tracer._annotation(self._name)
            self._bridge.__enter__()
        else:
            self._bridge = None
        self._t0 = time.monotonic_ns()
        return span_id

    def __exit__(self, exc_type, exc, tb) -> bool:
        t1 = time.monotonic_ns()
        tracer = self._tracer
        state = tracer._state
        state.stack.pop()
        if self._bridge is not None:
            self._bridge.__exit__(exc_type, exc, tb)
        with tracer._lock:
            tracer._spans.append(Span(
                name=self._name, t_start_ns=self._t0, t_end_ns=t1,
                span_id=self._span_id, parent_id=self._parent,
                thread=state.name, attrs=self._attrs,
            ))
            tracer.recorded += 1
        return False


class _BoundSpan:
    """A span factory with the name pre-bound (``tracer.bind(name)``).

    Calling it returns a fresh ``_SpanHandle`` — per-call state cannot
    be shared, nesting and concurrent use of the same name must work —
    but the name lookup, kwargs plumbing, and (for attr-less calls) the
    attrs dict are paid once at bind time instead of per span.
    """

    __slots__ = ("_tracer", "_name")

    def __init__(self, tracer: "Tracer", name: str):
        self._tracer = tracer
        self._name = name

    def __call__(self, **attrs) -> _SpanHandle:
        return _SpanHandle(self._tracer, self._name,
                           attrs if attrs else _EMPTY_ATTRS)


class Tracer:
    """Records nested spans; export with ``snapshot()``/``export_jsonl``."""

    def __init__(self, *, capacity: int = 65536, annotate: bool = False):
        self._lock = threading.Lock()
        self._spans: deque[Span] = deque(maxlen=capacity)
        self._state = _ThreadState()
        self._ids = itertools.count(1)  # CPython next() is atomic
        self.recorded = 0  # total spans ever finished (incl. evicted)
        self._annotation = _trace_annotation_cls() if annotate else None

    def span(self, name: str, **attrs) -> _SpanHandle:
        """Context manager recording one span around its body."""
        return _SpanHandle(self, name, attrs if attrs else _EMPTY_ATTRS)

    def bind(self, name: str) -> _BoundSpan:
        """Pre-bind ``name``: hot paths call the result as ``bound(**attrs)``."""
        return _BoundSpan(self, name)

    def event(self, name: str, **attrs) -> None:
        """Record an instantaneous (zero-duration) span."""
        with self.span(name, **attrs):
            pass

    def snapshot(self) -> list[Span]:
        """The retained spans, oldest first (a consistent copy)."""
        with self._lock:
            return list(self._spans)

    def clear(self) -> None:
        with self._lock:
            self._spans.clear()

    def export_jsonl(self, path: str) -> int:
        """Write one JSON line per span, start-ordered; returns the count."""
        spans = sorted(self.snapshot(), key=lambda s: s.t_start_ns)
        with open(path, "w") as f:
            for s in spans:
                f.write(json.dumps({
                    "name": s.name,
                    "t_start_ns": s.t_start_ns,
                    "t_end_ns": s.t_end_ns,
                    "duration_ns": s.duration_ns,
                    "span_id": s.span_id,
                    "parent_id": s.parent_id,
                    "thread": s.thread,
                    "attrs": s.attrs,
                }, sort_keys=True) + "\n")
        return len(spans)


class _NullTracer:
    """No-op stand-in: same surface as ``Tracer``, near-zero cost.

    Instrumented hot paths hold a tracer unconditionally
    (``tracer = tracer or NULL_TRACER``) instead of branching at every
    phase.
    """

    recorded = 0

    # one reusable, reentrant no-op CM: nullcontext carries no per-entry
    # state, so sharing a single instance is safe and allocation-free
    _NULL_CM = nullcontext(0)

    def span(self, name: str, **attrs):
        return self._NULL_CM

    def bind(self, name: str):
        return self._null_bound

    @staticmethod
    def _null_bound(**attrs):
        return _NullTracer._NULL_CM

    def event(self, name: str, **attrs) -> None:
        pass

    def snapshot(self) -> list[Span]:
        return []

    def clear(self) -> None:
        pass

    def export_jsonl(self, path: str) -> int:
        with open(path, "w"):
            pass
        return 0


NULL_TRACER = _NullTracer()
