"""Unified metrics registry: counters / gauges / histograms, one spine.

Every host-side signal in the repo — serving engine counters, queue
depths, batch fill, hot-swap events, benchmark summaries — lands in one
thread-safe ``MetricRegistry`` and leaves through two expositions:

  * ``prometheus_text()`` — the Prometheus text format (served over HTTP
    by ``MetricsServer`` for ``serve_vision --metrics-port``);
  * ``json_snapshot()`` / ``write_jsonl()`` — JSON for files and tests,
    with ``parse_jsonl()`` as the verified inverse (round-trip tested).

Metric families follow the Prometheus model: a family has a name, a
kind, and a fixed tuple of label names; ``family.labels(model="a")``
returns (creating on first use) the child carrying one label-value
combination.  Families without labels proxy their operations straight to
a default child, so ``registry.counter("x").inc()`` just works.

All mutation and reading happens under one registry-wide re-entrant
lock.  That makes multi-metric updates atomic for free: a caller that
holds ``registry.lock`` across several ``inc``/``observe`` calls (as
``serving.stats.EngineStats.record_batch`` does) can never be observed
half-applied by a concurrent ``snapshot()``.  Contention is per *batch*,
not per request — negligible next to a device launch.

This module also owns the nearest-rank percentile helpers the serving
stack reports (``serving.stats`` re-exports them): the q-th percentile
of n samples is the ``max(ceil(q·n), 1)``-th smallest — exact at the
``q=1.0`` and small-n boundaries.
"""

from __future__ import annotations

import bisect
import json
import math
import threading
import time
from collections import deque
from http.server import BaseHTTPRequestHandler, ThreadingHTTPServer

#: Repo version reported by ``repro_build_info`` (``src/repro`` is a
#: namespace package, so the constant lives here, on the obs spine).
REPRO_VERSION = "0.8.0"

# Stamped at first import — the closest observable to process start
# without a psutil dependency; good to well under a second, which is
# all an uptime panel needs.
_PROCESS_START_S = time.time()

# Percentiles every serving surface reports, as (label, quantile).
PERCENTILES = (("p50", 0.50), ("p90", 0.90), ("p95", 0.95), ("p99", 0.99))

# Default histogram bucket upper bounds, in seconds (latency-shaped).
DEFAULT_BUCKETS = (
    0.001, 0.0025, 0.005, 0.01, 0.025, 0.05, 0.1, 0.25, 0.5, 1.0, 2.5,
)


def percentile(sorted_vals, q: float):
    """Nearest-rank percentile of an ascending-sorted sequence.

    The q-th percentile of n samples is the ``max(ceil(q·n), 1)``-th
    smallest value (0.0 on an empty sequence).  Note the former
    floor-rank implementation was off by one whenever ``q·n`` was an
    integer — e.g. the median of 4 samples returned the 3rd.
    """
    n = len(sorted_vals)
    if not n:
        return 0.0
    rank = min(max(math.ceil(q * n), 1), n)
    return sorted_vals[rank - 1]


def latency_summary_ms(latencies_s) -> dict[str, float]:
    """Unsorted per-request latencies in seconds → {p50,p90,p95,p99} in ms."""
    lats = sorted(latencies_s)
    return {label: percentile(lats, q) * 1e3 for label, q in PERCENTILES}


class MetricError(ValueError):
    """Metric registration/usage conflict (name, kind, or labels)."""


def _check_name(name: str) -> None:
    if not name or not all(c.isalnum() or c in "_:" for c in name):
        raise MetricError(f"bad metric name {name!r}")


def _escape_label(value: str) -> str:
    return (value.replace("\\", r"\\").replace('"', r"\"")
            .replace("\n", r"\n"))


def _format_labels(names, values) -> str:
    if not names:
        return ""
    inner = ",".join(f'{n}="{_escape_label(v)}"'
                     for n, v in zip(names, values))
    return "{" + inner + "}"


# ---------------------------------------------------------------------------
# Children — one label-value combination of a family
# ---------------------------------------------------------------------------


class _CounterChild:
    def __init__(self, lock):
        self._lock = lock
        self._value = 0

    def inc(self, n=1):
        if n < 0:
            raise MetricError("counters only go up (use a gauge)")
        with self._lock:
            self._value += n

    @property
    def value(self):
        with self._lock:
            return self._value


class _GaugeChild:
    def __init__(self, lock):
        self._lock = lock
        self._value = 0

    def set(self, v):
        with self._lock:
            self._value = v

    def inc(self, n=1):
        with self._lock:
            self._value += n

    def dec(self, n=1):
        self.inc(-n)

    @property
    def value(self):
        with self._lock:
            return self._value


class _HistogramChild:
    """Cumulative buckets + sum/count, plus a bounded sample window.

    The window is what serving snapshots compute nearest-rank
    percentiles from (Prometheus quantiles are server-side; our JSON
    views want them inline) — bounded so a long-lived engine never grows
    host memory per observation.
    """

    def __init__(self, lock, bounds, window: int):
        self._lock = lock
        self.bounds = bounds
        self._bucket_counts = [0] * (len(bounds) + 1)  # +1: the +Inf bucket
        self._sum = 0.0
        self._count = 0
        self.window: deque = deque(maxlen=window)

    def observe(self, v):
        v = float(v)
        with self._lock:
            self._bucket_counts[bisect.bisect_left(self.bounds, v)] += 1
            self._sum += v
            self._count += 1
            self.window.append(v)

    @property
    def count(self):
        with self._lock:
            return self._count

    @property
    def sum(self):
        with self._lock:
            return self._sum

    def cumulative_buckets(self) -> list[tuple[float, int]]:
        """[(upper_bound, cumulative_count)], ending with (+inf, count)."""
        with self._lock:
            counts = list(self._bucket_counts)
        acc, out = 0, []
        for ub, c in zip((*self.bounds, math.inf), counts):
            acc += c
            out.append((ub, acc))
        return out

    def percentiles(self) -> dict[str, float]:
        """Nearest-rank percentiles over the bounded sample window."""
        with self._lock:
            vals = sorted(self.window)
        return {label: percentile(vals, q) for label, q in PERCENTILES}


_CHILD_TYPES = {"counter": _CounterChild, "gauge": _GaugeChild,
                "histogram": _HistogramChild}


# ---------------------------------------------------------------------------
# Families
# ---------------------------------------------------------------------------


class _Family:
    """One named metric family: fixed kind + label names, many children."""

    def __init__(self, registry: "MetricRegistry", kind: str, name: str,
                 help: str, label_names: tuple[str, ...], **child_kw):
        self._registry = registry
        self._lock = registry.lock
        self._child_kw = child_kw
        self.kind = kind
        self.name = name
        self.help = help
        self.label_names = tuple(label_names)
        self._children: dict[tuple[str, ...], object] = {}

    def labels(self, **kv):
        """The child for one label-value combination (created on first use)."""
        if sorted(kv) != sorted(self.label_names):
            raise MetricError(
                f"{self.name}: labels {sorted(kv)} != declared "
                f"{sorted(self.label_names)}"
            )
        key = tuple(str(kv[n]) for n in self.label_names)
        with self._lock:
            child = self._children.get(key)
            if child is None:
                child = _CHILD_TYPES[self.kind](self._lock, **self._child_kw)
                self._children[key] = child
        return child

    def _default(self):
        if self.label_names:
            raise MetricError(
                f"{self.name} declares labels {self.label_names}; "
                f"use .labels(...)"
            )
        return self.labels()

    # Label-less convenience proxies.
    def inc(self, n=1):
        self._default().inc(n)

    def dec(self, n=1):
        self._default().dec(n)

    def set(self, v):
        self._default().set(v)

    def observe(self, v):
        self._default().observe(v)

    @property
    def value(self):
        return self._default().value

    def children(self) -> list[tuple[tuple[str, ...], object]]:
        with self._lock:
            return sorted(self._children.items())

    # ---- exposition -------------------------------------------------------

    def prometheus_lines(self) -> list[str]:
        lines = []
        if self.help:
            lines.append(f"# HELP {self.name} {self.help}")
        lines.append(f"# TYPE {self.name} {self.kind}")
        for values, child in self.children():
            lbl = _format_labels(self.label_names, values)
            if self.kind == "histogram":
                for ub, cum in child.cumulative_buckets():
                    le = "+Inf" if math.isinf(ub) else repr(ub)
                    blbl = _format_labels((*self.label_names, "le"),
                                          (*values, le))
                    lines.append(f"{self.name}_bucket{blbl} {cum}")
                lines.append(f"{self.name}_sum{lbl} {child.sum}")
                lines.append(f"{self.name}_count{lbl} {child.count}")
            else:
                lines.append(f"{self.name}{lbl} {child.value}")
        return lines

    def json_sample(self, values, child) -> dict:
        sample = {"labels": dict(zip(self.label_names, values))}
        if self.kind == "histogram":
            sample.update(
                count=child.count, sum=child.sum,
                buckets=[[ub if not math.isinf(ub) else "+Inf", cum]
                         for ub, cum in child.cumulative_buckets()],
            )
        else:
            sample["value"] = child.value
        return sample

    def to_json(self) -> dict:
        return {
            "name": self.name,
            "kind": self.kind,
            "help": self.help,
            "label_names": list(self.label_names),
            "samples": [self.json_sample(v, c) for v, c in self.children()],
        }


class Counter(_Family):
    pass


class Gauge(_Family):
    pass


class Histogram(_Family):
    pass


_FAMILY_TYPES = {"counter": Counter, "gauge": Gauge, "histogram": Histogram}


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------


class MetricRegistry:
    """Thread-safe name → metric-family table with pluggable exposition."""

    def __init__(self):
        self.lock = threading.RLock()
        self._families: dict[str, _Family] = {}

    def _get_or_create(self, kind: str, name: str, help: str,
                       labels, **child_kw) -> _Family:
        _check_name(name)
        labels = tuple(labels)
        with self.lock:
            fam = self._families.get(name)
            if fam is None:
                fam = _FAMILY_TYPES[kind](self, kind, name, help, labels,
                                          **child_kw)
                self._families[name] = fam
                return fam
        # Re-registration is idempotent only for an identical declaration.
        if fam.kind != kind or fam.label_names != labels:
            raise MetricError(
                f"metric {name!r} already registered as {fam.kind}"
                f"{fam.label_names}, requested {kind}{labels}"
            )
        if child_kw and fam._child_kw != child_kw:
            raise MetricError(
                f"metric {name!r} re-registered with different options"
            )
        return fam

    def counter(self, name: str, help: str = "", labels=()) -> Counter:
        return self._get_or_create("counter", name, help, labels)

    def gauge(self, name: str, help: str = "", labels=()) -> Gauge:
        return self._get_or_create("gauge", name, help, labels)

    def histogram(self, name: str, help: str = "", labels=(),
                  buckets=DEFAULT_BUCKETS, window: int = 1024) -> Histogram:
        bounds = tuple(sorted(float(b) for b in buckets))
        if not bounds:
            raise MetricError(f"histogram {name!r} needs at least one bucket")
        return self._get_or_create("histogram", name, help, labels,
                                   bounds=bounds, window=window)

    def families(self) -> list[_Family]:
        with self.lock:
            return [self._families[n] for n in sorted(self._families)]

    def __contains__(self, name: str) -> bool:
        with self.lock:
            return name in self._families

    # ---- exposition -------------------------------------------------------

    def prometheus_text(self) -> str:
        lines = []
        for fam in self.families():
            lines.extend(fam.prometheus_lines())
        return "\n".join(lines) + "\n"

    def json_snapshot(self) -> dict:
        return {fam.name: fam.to_json() for fam in self.families()}

    def write_jsonl(self, path: str) -> None:
        """One JSON line per family — ``parse_jsonl`` is the inverse."""
        with open(path, "w") as f:
            for fam in self.families():
                f.write(json.dumps(fam.to_json(), sort_keys=True) + "\n")

    @staticmethod
    def parse_jsonl(text: str) -> dict:
        """Parse ``write_jsonl`` output back into a ``json_snapshot`` dict."""
        out = {}
        for line in text.splitlines():
            if line.strip():
                fam = json.loads(line)
                out[fam["name"]] = fam
        return out


def register_build_info(registry: MetricRegistry, *,
                        backend: str = "unknown",
                        version: str = REPRO_VERSION) -> None:
    """Register the standard process-identity metrics on ``registry``.

    ``repro_build_info{version,backend} 1`` — the Prometheus *info*
    idiom: a constant-1 gauge whose labels carry the identity, so
    dashboards can join any series against "which build/backend answered
    this scrape".  ``process_start_time_seconds`` (unix epoch) gives
    uptime for free as ``time() - process_start_time_seconds``.  Both
    are idempotent; every scrape surface (``serve_vision``,
    ``launch/train --metrics-port``) calls this before serving.
    """
    registry.gauge(
        "repro_build_info",
        "constant 1; labels carry the repo version and device backend",
        labels=("version", "backend"),
    ).labels(version=version, backend=backend).set(1)
    registry.gauge(
        "process_start_time_seconds",
        "unix time this process imported repro.obs.metrics",
    ).set(_PROCESS_START_S)


# ---------------------------------------------------------------------------
# HTTP exposition (Prometheus scrape endpoint)
# ---------------------------------------------------------------------------


class MetricsServer:
    """Tiny threaded HTTP server exposing one registry.

    ``GET /metrics`` → Prometheus text; ``GET /metrics.json`` → the JSON
    snapshot; ``GET /healthz`` → ``ok`` (a liveness probe that answers
    while the worker thread still schedules requests — what container
    orchestration and the obs_top dashboard poll).  ``port=0`` binds an
    ephemeral port (read it back from ``.port`` — what the tests and
    ``--metrics-port 0`` use).
    """

    def __init__(self, registry: MetricRegistry, *, port: int = 0,
                 host: str = "127.0.0.1"):
        self.registry = registry

        server = self

        class Handler(BaseHTTPRequestHandler):
            def do_GET(self):  # noqa: N802 — BaseHTTPRequestHandler API
                if self.path == "/metrics":
                    body = server.registry.prometheus_text().encode()
                    ctype = "text/plain; version=0.0.4; charset=utf-8"
                elif self.path == "/metrics.json":
                    body = json.dumps(server.registry.json_snapshot(),
                                      sort_keys=True).encode()
                    ctype = "application/json"
                elif self.path == "/healthz":
                    body = b"ok\n"
                    ctype = "text/plain; charset=utf-8"
                else:
                    self.send_error(404, "unknown path (try /metrics)")
                    return
                self.send_response(200)
                self.send_header("Content-Type", ctype)
                self.send_header("Content-Length", str(len(body)))
                self.end_headers()
                self.wfile.write(body)

            def log_message(self, *a):  # silence per-request stderr spam
                pass

        self._httpd = ThreadingHTTPServer((host, port), Handler)
        self.host = host
        self.port = self._httpd.server_address[1]
        self._thread = threading.Thread(target=self._httpd.serve_forever,
                                        daemon=True)
        self._thread.start()

    @property
    def url(self) -> str:
        return f"http://{self.host}:{self.port}/metrics"

    def close(self) -> None:
        self._httpd.shutdown()
        self._httpd.server_close()
        self._thread.join()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()


def start_metrics_server(registry: MetricRegistry, *, port: int = 0,
                         host: str = "127.0.0.1") -> MetricsServer:
    """Start serving ``registry`` on ``host:port`` (0 = ephemeral)."""
    return MetricsServer(registry, port=port, host=host)
