"""End-to-end driver: train a (reduced) VGG8B with NITRO-D for a few
hundred steps, with checkpoint/restart, straggler monitoring and
integer-numerics telemetry — the full production train loop on the
paper's flagship architecture.

    PYTHONPATH=src python examples/train_vgg8b.py [--steps 300] [--scale 0.25]

``--scale 1.0`` builds the paper's exact VGG8B (128..512 filters); the
default 0.25 fits a few hundred CPU steps in minutes.  Restarting the
script resumes from the checkpoint — kill it mid-run to see recovery.
Every 50th step additionally records per-layer bit-occupancy /
saturation telemetry to ``metrics.jsonl`` next to the checkpoints
(``--telemetry-every 0`` to disable; see docs/OBSERVABILITY.md for how
to read it) — the training trajectory is bitwise identical either way.
"""

import argparse

from repro.launch.train import train_nitro


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--scale", type=float, default=0.25)
    ap.add_argument("--ckpt-dir", default="/tmp/nitro_vgg8b_ckpt")
    ap.add_argument("--telemetry-every", type=int, default=50)
    args = ap.parse_args()

    result = train_nitro(
        "vgg8b", steps=args.steps, batch=args.batch,
        ckpt_dir=args.ckpt_dir, dataset="tiles32", scale=args.scale,
        telemetry_every=args.telemetry_every,
    )
    if "scaled_loss" in result:
        print(f"final scaled loss {result['scaled_loss']:.4f} "
              f"(per-sample RSS in one-hot units)")


if __name__ == "__main__":
    main()
