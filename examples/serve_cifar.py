"""Train-then-serve: the full NITRO-D integer lifecycle on one CNN.

    PYTHONPATH=src python examples/serve_cifar.py [--steps 60] [--scale 0.125]

1. trains a reduced VGG8B with the integer-only LES trainer on the
   CIFAR-shaped synthetic set (tiles32);
2. freezes the TrainState into a FrozenModel and round-trips it through
   the on-disk manifest format;
3. compiles the fused inference ExecutionPlan and serves the test set
   through the batched VisionEngine from several concurrent client
   threads;
4. checks the engine's predictions are bit-identical to the training-time
   ``model.predict`` on the same frozen params.
"""

import argparse
import functools
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_paper_config
from repro.core import les
from repro.core import model as M
from repro.data import synthetic
from repro.infer import compile_plan, freeze, load_frozen, save_frozen
from repro.serving.vision import VisionEngine


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--scale", type=float, default=0.125)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--serve-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # ---- 1. integer-only training ----------------------------------------
    ds = synthetic.make_image_dataset("tiles32", n_train=2048, n_test=256,
                                      seed=args.seed)
    cfg = get_paper_config("vgg8b", scale=args.scale,
                           input_shape=ds.input_shape)
    state = les.create_train_state(jax.random.PRNGKey(args.seed), cfg)
    step_fn = jax.jit(functools.partial(les.train_step, cfg=cfg))
    it = 0
    while it < args.steps:
        for x, y in synthetic.batches(ds.x_train, ds.y_train, args.batch,
                                      seed=it):
            if it >= args.steps:
                break
            state, metrics = step_fn(
                state, x=jnp.asarray(x), labels=jnp.asarray(y),
                key=jax.random.PRNGKey(it),
            )
            if it % 20 == 0:
                print(f"[train] step {it:4d} loss={int(metrics.loss)} "
                      f"correct={int(metrics.correct)}/{args.batch}")
            it += 1

    # ---- 2. freeze + manifest round-trip ---------------------------------
    with tempfile.TemporaryDirectory() as export_dir:
        save_frozen(export_dir, freeze(state, cfg))
        fm = load_frozen(export_dir)
    print(f"[export] frozen {fm.name}: {len(fm.layers)} layers, "
          f"{fm.num_bytes()} weight bytes")

    # ---- 3. fused plan + batched engine, concurrent clients --------------
    plan = compile_plan(fm)
    images = list(ds.x_test)
    labels_true = ds.y_test
    predictions = np.full(len(images), -1, np.int64)

    with VisionEngine(plan, batch_size=args.serve_batch,
                      max_wait_ms=3.0) as engine:
        engine.classify(images[:1])  # compile outside the clock

        def client(worker: int):
            for i in range(worker, len(images), args.clients):
                predictions[i] = engine.submit(images[i]).result().label

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(w,))
                   for w in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        stats = engine.stats

    acc = float(np.mean(predictions == labels_true))
    print(f"[serve] {len(images)} requests from {args.clients} clients in "
          f"{wall:.3f}s ({len(images) / wall:.1f} req/s), "
          f"{stats.batches} batches, fill {stats.avg_batch_fill:.2f}")
    print(f"[serve] test accuracy {acc:.4f}")

    # ---- 4. parity: engine ≡ training-time predict -----------------------
    want = np.asarray(M.predict(state.params, cfg,
                                jnp.asarray(np.stack(images))))
    mismatches = int(np.sum(predictions != want))
    assert mismatches == 0, f"{mismatches} fused/unfused prediction mismatches"
    print("[parity] fused engine predictions bit-identical to "
          "model.predict ✓")


if __name__ == "__main__":
    main()
