"""Train-then-serve: the full NITRO-D integer lifecycle on one CNN fleet.

    PYTHONPATH=src python examples/serve_cifar.py [--steps 60] [--scale 0.125]

1. trains a reduced VGG8B with the integer-only LES trainer on the
   CIFAR-shaped synthetic set (tiles32), freezing a **mid-training
   snapshot** on the way — two checkpoints of one architecture, the
   canonical A/B pair (prod vs candidate);
2. exports both through the on-disk manifest format and a ``FLEET.json``
   fleet manifest, then loads everything back through ``ModelRegistry``;
3. serves the test set through the continuous-batching ``FleetEngine``
   behind a 90/10 A/B ``Router`` split from several concurrent client
   threads — deterministic request-id hashing decides each request's arm;
4. checks every served prediction is bit-identical to the training-time
   ``model.predict`` *of the arm that answered it*, and reports per-arm
   accuracy + stats;
5. hot-swaps the candidate arm to the final checkpoint under its stable
   model id and shows the swap taking effect on live traffic.
"""

import argparse
import functools
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_paper_config
from repro.core import les
from repro.core import model as M
from repro.data import synthetic
from repro.infer import freeze, load_frozen, save_fleet_manifest, save_frozen
from repro.serving import (
    FleetEngine,
    ModelRegistry,
    Router,
    fleet_snapshot_delta,
)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=64)
    ap.add_argument("--scale", type=float, default=0.125)
    ap.add_argument("--clients", type=int, default=4)
    ap.add_argument("--serve-batch", type=int, default=32)
    ap.add_argument("--seed", type=int, default=0)
    args = ap.parse_args()

    # ---- 1. integer-only training, snapshotting the A/B candidate --------
    ds = synthetic.make_image_dataset("tiles32", n_train=2048, n_test=256,
                                      seed=args.seed)
    cfg = get_paper_config("vgg8b", scale=args.scale,
                           input_shape=ds.input_shape)
    state = les.create_train_state(jax.random.PRNGKey(args.seed), cfg)
    step_fn = jax.jit(functools.partial(les.train_step, cfg=cfg))
    snapshot_at = max(1, args.steps // 2)
    mid_state = state
    it = 0
    while it < args.steps:
        for x, y in synthetic.batches(ds.x_train, ds.y_train, args.batch,
                                      seed=it):
            if it >= args.steps:
                break
            state, metrics = step_fn(
                state, x=jnp.asarray(x), labels=jnp.asarray(y),
                key=jax.random.PRNGKey(it),
            )
            if it % 20 == 0:
                print(f"[train] step {it:4d} loss={int(metrics.loss)} "
                      f"correct={int(metrics.correct)}/{args.batch}")
            it += 1
            if it == snapshot_at:
                mid_state = state  # the "candidate" arm: half-trained
    print(f"[train] prod = step {args.steps}, candidate = step {snapshot_at}")

    # ---- 2. export both arms + fleet manifest, reload via the registry ---
    splits = {"split": {"prod": 0.9, "candidate": 0.1}}
    with tempfile.TemporaryDirectory() as fleet_dir:
        save_frozen(f"{fleet_dir}/prod", freeze(state, cfg))
        save_frozen(f"{fleet_dir}/candidate", freeze(mid_state, cfg))
        save_fleet_manifest(fleet_dir,
                            {"prod": "prod", "candidate": "candidate"},
                            splits=splits)
        registry = ModelRegistry.from_manifest(fleet_dir)
        fm_prod = load_frozen(f"{fleet_dir}/prod")
    print(f"[export] fleet {registry.ids()}: {len(fm_prod.layers)} layers, "
          f"{fm_prod.num_bytes()} weight bytes/arm")

    # ---- 3. A/B serve through the router, concurrent clients -------------
    router = Router(splits)
    images = list(ds.x_test)
    labels_true = ds.y_test
    predictions = np.full(len(images), -1, np.int64)
    arms = [router.resolve("split", f"req-{i}") for i in range(len(images))]

    with FleetEngine(registry, batch_size=args.serve_batch,
                     router=router) as engine:
        engine.classify(images[:1], model="prod")  # compile outside the clock
        engine.classify(images[:1], model="candidate")
        pre = engine.snapshot()

        def client(worker: int):
            for i in range(worker, len(images), args.clients):
                predictions[i] = engine.submit(
                    images[i], model="split", request_id=f"req-{i}",
                ).result().label

        t0 = time.perf_counter()
        threads = [threading.Thread(target=client, args=(w,))
                   for w in range(args.clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        wall = time.perf_counter() - t0
        # delta vs the post-warmup snapshot: report only the timed serving
        snapshot = fleet_snapshot_delta(pre, engine.snapshot())

        # ---- 4. per-arm parity + accuracy --------------------------------
        want = {
            "prod": np.asarray(M.predict(
                state.params, cfg, jnp.asarray(np.stack(images)))),
            "candidate": np.asarray(M.predict(
                mid_state.params, cfg, jnp.asarray(np.stack(images)))),
        }
        mismatches = sum(
            int(predictions[i] != want[arm][i])
            for i, arm in enumerate(arms)
        )
        assert mismatches == 0, \
            f"{mismatches} fleet/model.predict prediction mismatches"
        fleet = snapshot["fleet"]
        print(f"[serve] {len(images)} requests from {args.clients} clients "
              f"in {wall:.3f}s ({len(images) / wall:.1f} req/s), "
              f"{fleet['batches']} batches, "
              f"fill {fleet['avg_batch_fill']:.2f}")
        for arm in ("prod", "candidate"):
            idx = [i for i, a in enumerate(arms) if a == arm]
            acc = float(np.mean(predictions[idx] == labels_true[idx]))
            print(f"[serve]   {arm}: {len(idx)} requests "
                  f"({len(idx) / len(images):.0%} of traffic), "
                  f"accuracy {acc:.4f}")
        print("[parity] every answer bit-identical to its arm's "
              "model.predict ✓")

        # ---- 5. hot-swap the candidate to the final checkpoint -----------
        entry = registry.swap("candidate", freeze(state, cfg))
        swapped = [engine.submit(img, model="candidate").result().label
                   for img in images[:32]]
        np.testing.assert_array_equal(swapped, want["prod"][:32])
        print(f"[swap] candidate -> final checkpoint "
              f"(version {entry.version}); live traffic now matches prod ✓")


if __name__ == "__main__":
    main()
