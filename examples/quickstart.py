"""Quickstart: native integer-only training of a small CNN (NITRO-D).

Runs in ~1 minute on CPU.  Demonstrates the paper's core claims live:
  1. the entire train step is integer-only (asserted from the jaxpr);
  2. accuracy climbs well above chance with no float anywhere;
  3. trained weights stay within int16 (paper §E.3).

    PYTHONPATH=src python examples/quickstart.py
"""

import functools

import jax
import jax.numpy as jnp

from repro.core import les, model
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig
from repro.data import synthetic


def main():
    ds = synthetic.make_image_dataset("tiles32", n_train=2048, n_test=512)
    cfg = NitroConfig(
        blocks=(
            BlockSpec("conv", 32, pool=True, d_lr=512),
            BlockSpec("conv", 64, pool=True, d_lr=512),
            BlockSpec("linear", 128),
        ),
        input_shape=ds.input_shape,
        num_classes=ds.num_classes,
        gamma_inv=512, eta_fw=25000, eta_lr=5000,
        name="quickstart-cnn",
    )
    state = les.create_train_state(jax.random.PRNGKey(0), cfg)
    print(f"model: {model.count_params(state.params):,} integer parameters")

    step = jax.jit(functools.partial(les.train_step, cfg=cfg))

    # 1. prove the step is integer-only
    jaxpr = jax.make_jaxpr(functools.partial(les.train_step, cfg=cfg))(
        state, x=jnp.asarray(ds.x_train[:8]), labels=jnp.asarray(ds.y_train[:8]),
        key=jax.random.PRNGKey(0),
    )
    n_float = sum(
        1 for eqn in jaxpr.jaxpr.eqns
        for v in list(eqn.invars) + list(eqn.outvars)
        if hasattr(getattr(v, "aval", None), "dtype")
        and "float" in str(v.aval.dtype)
    )
    print(f"float values in the compiled train step: {n_float} (expected 0)")
    assert n_float == 0

    # 2. train
    k = 0
    for epoch in range(6):
        correct = total = 0
        for x, y in synthetic.batches(ds.x_train, ds.y_train, 64, seed=epoch):
            state, m = step(state, x=jnp.asarray(x), labels=jnp.asarray(y),
                            key=jax.random.PRNGKey(k)); k += 1
            correct += int(m.correct); total += 64
        test_c = 0
        for i in range(0, 512, 64):
            test_c += int(les.eval_step(
                state, cfg, jnp.asarray(ds.x_test[i:i+64]),
                jnp.asarray(ds.y_test[i:i+64])))
        print(f"epoch {epoch}: train {correct/total:.3f}  test {test_c/512:.3f}")

    # 3. weight range (paper §E.3: int16 suffices)
    mx = max(int(jnp.abs(p).max()) for p in jax.tree_util.tree_leaves(state.params))
    print(f"max |weight| after training: {mx}  (int16 bound: 32767)")
    assert mx < 2**15


if __name__ == "__main__":
    main()
