"""Beyond-paper: the NITRO-D learning algorithm (LES local-loss groups)
applied to a transformer LM, next to standard BP — the technique hook the
framework exposes for every assigned architecture (``les_groups``).

Gradients are confined per layer-group (stop_gradient boundaries), exactly
like the paper's integer local-loss blocks: no cross-group backward
dependency → group backwards overlap downstream forwards at scale.

    PYTHONPATH=src python examples/les_transformer.py [--steps 60]
"""

import argparse
from dataclasses import replace

import jax
import jax.numpy as jnp

from repro.configs import get_smoke_config
from repro.data.loader import synthetic_lm_generator
from repro.launch.mesh import make_test_mesh
from repro.parallel.sharding import train_rules
from repro.train import trainer


def run(cfg, label, steps, batch, seq):
    mesh = make_test_mesh(1, 1)
    rules = trainer.resolved_rules(cfg, train_rules(False))
    gen = synthetic_lm_generator(cfg.vocab_size, seq, batch)
    step_fn = trainer.build_train_step(
        cfg, mesh, rules, shapes={"tokens": (batch, seq), "labels": (batch, seq)},
        donate=False,
    )
    state = trainer.init_state(jax.random.PRNGKey(0), cfg)
    first = last = None
    for it in range(steps):
        b = gen(it)
        state, m = step_fn(state, {k: jnp.asarray(v) for k, v in b.items()})
        if first is None:
            first = float(m["ce"])
        last = float(m["ce"])
        if it % 20 == 0:
            print(f"  [{label}] step {it:3d} ce={last:.4f}")
    print(f"  [{label}] ce {first:.4f} → {last:.4f}")
    return first, last


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=16)
    ap.add_argument("--seq", type=int, default=64)
    args = ap.parse_args()

    base = replace(get_smoke_config("llama3.2-1b"), num_layers=4)

    print("BP baseline (end-to-end backprop):")
    run(base, "bp", args.steps, args.batch, args.seq)

    print("LES mode (2 local-loss groups, gradients confined per group):")
    les_cfg = replace(base, les_groups=2)
    _, les_last = run(les_cfg, "les", args.steps, args.batch, args.seq)

    print("Both modes train; LES removes the cross-group backward chain "
          "(see EXPERIMENTS.md §Perf for the overlap effect at scale).")


if __name__ == "__main__":
    main()
