"""Serve a small LM with batched requests: prefill + greedy decode through
the same cache/sharding machinery the decode_32k dry-run cells compile.

    PYTHONPATH=src python examples/serve_lm.py [--arch llama3.2-1b]
"""

import argparse

import jax

from repro.configs import get_smoke_config
from repro.models import transformer as T
from repro.serving.engine import Engine, Request


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-1b")
    ap.add_argument("--batch", type=int, default=4)
    args = ap.parse_args()

    cfg = get_smoke_config(args.arch)
    params = T.init_params(jax.random.PRNGKey(0), cfg)
    engine = Engine(cfg, params, max_seq=128)

    rng_prompts = [
        [1, 5, 9, 13], [2, 4, 8], [3, 3, 3, 3, 3], [7, 11],
    ][: args.batch]
    requests = [Request(prompt=p, max_new_tokens=12) for p in rng_prompts]
    out = engine.generate(requests)
    for i, r in enumerate(out):
        print(f"request {i}: prompt={r.prompt} → generated={r.generated}")
    assert all(len(r.generated) == 12 for r in out)
    print("served", len(out), "requests to completion")


if __name__ == "__main__":
    main()
