"""Re-run the HLO analyzer over cached .hlo.gz files (no recompilation) and
rewrite the roofline section of each dry-run JSON.

    PYTHONPATH=src python tools/reanalyze.py [results/dryrun]
"""

import glob
import gzip
import json
import os
import sys

from repro.configs import get_config
from repro.launch.hlo_analysis import analyze, roofline_terms


def reanalyze(json_path: str) -> bool:
    hlo_path = json_path.replace(".json", ".hlo.gz")
    if not os.path.exists(hlo_path):
        return False
    with open(json_path) as f:
        r = json.load(f)
    if r.get("skipped"):
        return False
    with gzip.open(hlo_path, "rt") as f:
        text = f.read()
    costs = analyze(text)
    terms = roofline_terms(costs)

    cfg = get_config(r["arch"])
    n_active = cfg.active_param_count()
    tokens = r["batch"] * (r["seq"] if r["kind"] != "decode" else 1)
    fl_per_tok = 6 if r["kind"] == "train" else 2
    model_flops = fl_per_tok * n_active * tokens
    hlo_global = sum(terms["flops_by_dtype"].values()) * r["chips"]
    terms["model_flops"] = model_flops
    terms["model_over_hlo_flops"] = model_flops / hlo_global if hlo_global else 0.0
    terms["roofline_bound_s"] = max(
        terms["compute_s"], terms["memory_s"], terms["collective_s"]
    )
    useful_s = (model_flops / r["chips"]) / 197e12
    terms["roofline_fraction"] = (
        useful_s / terms["roofline_bound_s"] if terms["roofline_bound_s"] else 0.0
    )
    r["roofline"] = terms
    with open(json_path, "w") as f:
        json.dump(r, f, indent=1)
    return True


def main():
    d = sys.argv[1] if len(sys.argv) > 1 else "results/dryrun"
    n = 0
    for p in sorted(glob.glob(os.path.join(d, "*.json"))):
        if reanalyze(p):
            n += 1
    print(f"re-analyzed {n} cells")


if __name__ == "__main__":
    main()
