#!/usr/bin/env python
"""Line-coverage gate for ``src/repro/{core,kernels,obs,parallel}``.

``tools/ci_check.sh`` prefers **pytest-cov** (see requirements-dev.txt)
when it is importable:

    python -m pytest -q -m "not slow" \
        --cov=repro.core --cov=repro.kernels --cov=repro.obs \
        --cov=repro.parallel --cov-fail-under=<floor>

This script is the dependency-free fallback for containers where
pytest-cov cannot be installed (this repo's CI image has no network
access): it measures line coverage of the gated packages with a scoped
``sys.settrace`` — line events are enabled only for frames whose code
lives in a gated file, so the rest of the suite pays one dict lookup per
function call — runs pytest in-process, and enforces the same floor.

    python tools/cov_gate.py --fail-under 80 [--report] -- -x -q -m "not slow"

``--pkg repro/core`` (repeatable) overrides the gated package set;
overlapping specs (e.g. ``repro`` plus ``repro/core``) are deduplicated
at the file level, so a file is never counted twice in the aggregate.

Executable lines are derived from the compiled code objects
(``co_lines`` over the module's nested code-object tree), so the
denominator is stable across runs; the number tracks pytest-cov's to
within a couple of points (docstring/``pragma`` handling differs — pin
the floor with a small margin when switching tools).
"""

from __future__ import annotations

import argparse
import os
import sys
import threading
from collections import defaultdict

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
DEFAULT_PKGS = ("repro/core", "repro/kernels", "repro/obs", "repro/parallel")


def gated_files(pkgs=DEFAULT_PKGS) -> list[str]:
    """Every .py under the gated packages, deduplicated.

    ``pkgs`` are src/-relative package dirs; the set() collapses files
    reachable through several overlapping specs so the aggregate never
    double-counts a line.
    """
    files: set[str] = set()
    for pkg in pkgs:
        d = os.path.join(ROOT, "src", *pkg.split("/"))
        if not os.path.isdir(d):
            raise SystemExit(f"[cov_gate] no such package dir: {d}")
        for dirpath, _, names in os.walk(d):
            files.update(
                os.path.join(dirpath, n) for n in names if n.endswith(".py")
            )
    return sorted(files)


def executable_lines(path: str) -> set[int]:
    """Line numbers carrying bytecode, over the nested code-object tree."""
    with open(path, "r") as f:
        code = compile(f.read(), path, "exec")
    lines: set[int] = set()
    stack = [code]
    while stack:
        co = stack.pop()
        lines.update(ln for _, _, ln in co.co_lines() if ln is not None)
        stack.extend(c for c in co.co_consts if hasattr(c, "co_lines"))
    return lines


class Tracer:
    """Scoped line tracer: line events only inside the gated files."""

    def __init__(self, targets: set[str]):
        self.targets = targets
        self.executed: dict[str, set[int]] = defaultdict(set)
        # raw co_filename → canonical path if gated, else None; lines are
        # always recorded under the canonical path so a module imported
        # through a non-canonical sys.path entry still reports correctly.
        self._canonical: dict[str, str | None] = {}
        self._locals: dict[str, object] = {}

    def _local_for(self, canon: str):
        tracer = self._locals.get(canon)
        if tracer is None:
            lines = self.executed[canon]

            def tracer(frame, event, arg):
                if event == "line":
                    lines.add(frame.f_lineno)
                return tracer

            self._locals[canon] = tracer
        return tracer

    def __call__(self, frame, event, arg):
        if event != "call":
            return None
        fname = frame.f_code.co_filename
        canon = self._canonical.get(fname, False)
        if canon is False:
            abspath = os.path.abspath(fname)
            canon = abspath if abspath in self.targets else None
            self._canonical[fname] = canon
        if canon is None:
            return None
        self.executed[canon].add(frame.f_lineno)  # the def/call line
        return self._local_for(canon)

    def install(self):
        threading.settrace(self)
        sys.settrace(self)

    def uninstall(self):
        sys.settrace(None)
        threading.settrace(None)


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--fail-under", type=float, required=True,
                    help="minimum aggregate line coverage percent")
    ap.add_argument("--report", action="store_true",
                    help="print the per-file table even on success")
    ap.add_argument("--pkg", action="append", metavar="REL",
                    help="src/-relative package dir to gate (repeatable; "
                         f"default: {' '.join(DEFAULT_PKGS)})")
    ap.add_argument("pytest_args", nargs="*",
                    help="arguments forwarded to pytest (after --)")
    args = ap.parse_args(argv)

    # dict.fromkeys: dedupe repeated --pkg specs, keep the given order
    pkgs = tuple(dict.fromkeys(args.pkg or DEFAULT_PKGS))
    files = gated_files(pkgs)
    targets = {os.path.abspath(f) for f in files}
    executable = {f: executable_lines(f) for f in files}

    sys.path.insert(0, os.path.join(ROOT, "src"))
    os.chdir(ROOT)

    import pytest  # after path setup, before the tracer goes live

    tracer = Tracer(targets)
    tracer.install()
    try:
        status = pytest.main(args.pytest_args or ["-x", "-q", "-m", "not slow"])
    finally:
        tracer.uninstall()
    if status != 0:
        print(f"[cov_gate] pytest failed (exit {status}); no coverage verdict")
        return int(status)

    total_exec = total_cov = 0
    rows = []
    for f in files:
        exe = executable[f]
        cov = tracer.executed.get(os.path.abspath(f), set()) & exe
        total_exec += len(exe)
        total_cov += len(cov)
        pct = 100.0 * len(cov) / len(exe) if exe else 100.0
        rows.append((os.path.relpath(f, ROOT), len(cov), len(exe), pct))

    pct_total = 100.0 * total_cov / total_exec if total_exec else 100.0
    failed = pct_total < args.fail_under
    if args.report or failed:
        width = max(len(r[0]) for r in rows)
        for name, cov, exe, pct in rows:
            print(f"[cov_gate] {name:<{width}}  {cov:>5}/{exe:<5}  {pct:6.1f}%")
    print(f"[cov_gate] TOTAL {'+'.join('src/' + p for p in pkgs)}: "
          f"{total_cov}/{total_exec} lines = {pct_total:.1f}% "
          f"(floor {args.fail_under:.1f}%)")
    if failed:
        print("[cov_gate] FAIL: coverage fell below the floor")
        return 2
    print("[cov_gate] OK")
    return 0


if __name__ == "__main__":
    sys.exit(main())
