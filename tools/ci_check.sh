#!/usr/bin/env bash
# Quick CI gate: the tier-1 test command (minus slow integration tests)
# run under a line-coverage floor for src/repro/{core,kernels,obs,parallel},
# plus kernel / fused-training / autotune / fleet-serving / observability /
# data-parallel benchmark smokes, a BENCH_*.json schema gate, obs_top and
# alert-engine smokes over the checked-in fixtures, a serve-CLI smoke
# (with a live /metrics endpoint), and a docs link check.  Run from
# anywhere.
#
#   tools/ci_check.sh          # quick gate
#   FULL=1 tools/ci_check.sh   # include slow integration tests (tier-1 exact)
#
# Coverage: pytest-cov when installed (requirements-dev.txt); otherwise
# the dependency-free tools/cov_gate.py fallback (scoped sys.settrace —
# roughly 2x the plain suite time, the price of a no-network container).
# Floor pinned at 97: measured 98.6% on 2026-07-29 (cov_gate over the
# quick set); the margin absorbs pytest-cov/cov_gate line-accounting
# differences, not real regressions.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

COV_FLOOR="${COV_FLOOR:-97}"

python tools/check_docs_links.py

if [[ "${FULL:-0}" == "1" ]]; then
    python -m pytest -x -q
elif python -c "import pytest_cov" 2>/dev/null; then
    python -m pytest -x -q -m "not slow" \
        --cov=repro.core --cov=repro.kernels --cov=repro.obs \
        --cov=repro.parallel \
        --cov-fail-under="$COV_FLOOR"
else
    python tools/cov_gate.py --fail-under "$COV_FLOOR" -- -x -q -m "not slow"
fi

python -m benchmarks.run --quick --only kernel
python -m benchmarks.train_step --smoke
python -m benchmarks.conv_stream --smoke
python -m benchmarks.autotune_gain --smoke
python -m benchmarks.serve_fleet --smoke
python -m benchmarks.obs_overhead --smoke
python -m benchmarks.dp_scaling --smoke
# the smokes above just (re)wrote BENCH_*.json — pin their shape
python tools/check_bench_schema.py
# dashboard post-mortem mode over the checked-in fixtures
python -m repro.launch.obs_top --metrics tests/data/obs_top_metrics.jsonl \
    --fleet-json tests/data/obs_top_fleet.json --once > /dev/null
# alert engine offline over the same fixture: must fire on the seeded
# headroom/saturation/dp regressions
python - <<'EOF'
from repro.obs.health import scan_jsonl
m = scan_jsonl("tests/data/obs_top_metrics.jsonl")
assert m.steps_observed == 3, m.steps_observed
assert m.summary()["alerts_fired"] >= 3, m.summary()
EOF
python -m repro.launch.serve_vision --train-steps 0 --scale 0.0625 \
    --backend reference --requests 24 --batch 8 --metrics-port 0
echo "[ci_check] OK"
