#!/usr/bin/env bash
# Quick CI gate: the tier-1 test command (minus slow integration tests)
# plus kernel / fused-training / fleet-serving benchmark smokes, a
# serve-CLI smoke, and a docs link check.  Run from anywhere; ~a few
# minutes on CPU.
#
#   tools/ci_check.sh          # quick gate
#   FULL=1 tools/ci_check.sh   # include slow integration tests (tier-1 exact)
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

python tools/check_docs_links.py

if [[ "${FULL:-0}" == "1" ]]; then
    python -m pytest -x -q
else
    python -m pytest -x -q -m "not slow"
fi

python -m benchmarks.run --quick --only kernel
python -m benchmarks.train_step --smoke
python -m benchmarks.conv_stream --smoke
python -m benchmarks.serve_fleet --smoke
python -m repro.launch.serve_vision --train-steps 0 --scale 0.0625 \
    --backend reference --requests 24 --batch 8
echo "[ci_check] OK"
