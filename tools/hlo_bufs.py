"""Diagnostic: largest tensors in a dry-run cell's compiled HLO.

    PYTHONPATH=src python tools/hlo_bufs.py <arch> <shape> [threshold_mb]
"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"
import re
import sys

from repro.launch.dryrun import lower_cell

DT = {"f32": 4, "bf16": 2, "s32": 4, "u32": 4, "s8": 1, "pred": 1,
      "f16": 2, "u16": 2, "s16": 2, "u8": 1, "s64": 8, "u64": 8}


def main():
    arch, shape = sys.argv[1], sys.argv[2]
    thresh = float(sys.argv[3]) * 1e6 if len(sys.argv) > 3 else 200e6
    compiled, info = lower_cell(arch, shape, multi_pod=False)
    print({k: info[k] for k in ("arch", "shape", "compile_s")})
    ma = compiled.memory_analysis()
    print(f"args={ma.argument_size_in_bytes/2**30:.2f} out={ma.output_size_in_bytes/2**30:.2f} "
          f"temp={ma.temp_size_in_bytes/2**30:.2f} alias={ma.alias_size_in_bytes/2**30:.2f} GiB")
    txt = compiled.as_text()
    sizes = {}
    for m in re.finditer(r"%[\w.\-]+ = (\w+)\[([\d,]+)\]", txt):
        dt, dims = m.groups()
        if dt not in DT:
            continue
        n = 1
        for d in dims.split(","):
            n *= int(d)
        b = n * DT[dt]
        if b > thresh:
            key = f"{dt}[{dims}]"
            sizes.setdefault(key, [0, b])[0] += 1
    for k, (c, b) in sorted(sizes.items(), key=lambda kv: -kv[1][1])[:25]:
        print(f"{b/2**30:8.2f} GiB  x{c:4d}  {k}")


if __name__ == "__main__":
    main()
