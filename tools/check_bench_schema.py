#!/usr/bin/env python
"""Validate the machine-readable ``BENCH_*.json`` benchmark artifacts.

Every benchmark emits a JSON payload next to its stdout CSV; downstream
tooling (dashboards, regression diffs, the PR driver) reads those files
blind — so their shape is a contract.  This gate pins it:

  * **common**: an object with a non-empty ``benchmark`` string and a
    non-empty ``results`` list of objects;
  * **honesty invariant**: any ``bit_exact`` field must be ``true`` —
    a benchmark must never report timings for two computations that
    disagree.  (``meets_target`` is shape-checked but not value-checked:
    it reports a *timing* outcome, which machine contention can
    legitimately flip — a schema gate must stay deterministic);
  * **per-file**: the ``benchmark`` name matches the emitting module,
    ``BENCH_obs.json`` carries both overhead rows (train telemetry +
    fleet tracing), ``BENCH_serve.json`` carries the per-arm p99-vs-SLO
    roll-up with at least one configured SLO exercised, and
    ``BENCH_train.json`` carries the fused-opt rows: ``us_per_step``
    with both the ``fused_opt`` and ``unfused`` variants, the structural
    ``hbm_streams_per_weight_update`` counts (fused strictly fewer), and
    a ``fused_opt_no_worse_than_unfused`` bool (shape-checked only —
    a timing outcome, like ``meets_target``).

Usage (CI runs it after the benchmark smokes, from the repo root)::

    python tools/check_bench_schema.py            # all BENCH_*.json present
    python tools/check_bench_schema.py BENCH_obs.json   # specific files
"""

from __future__ import annotations

import glob
import json
import sys

#: file name → expected ``benchmark`` field of the emitting module.
EXPECTED_NAMES = {
    "BENCH_autotune.json": "autotune_gain",
    "BENCH_conv.json": "conv_stream",
    "BENCH_infer.json": "serve_infer",
    "BENCH_obs.json": "obs_overhead",
    "BENCH_parallel.json": "dp_scaling",
    "BENCH_serve.json": "serve_fleet",
    "BENCH_train.json": "train_step",
}


class SchemaError(Exception):
    pass


def _require(cond: bool, path: str, msg: str) -> None:
    if not cond:
        raise SchemaError(f"{path}: {msg}")


def _walk_honesty(path: str, node, where: str = "$") -> None:
    """``bit_exact`` must be True and ``meets_target`` a bool, anywhere."""
    if isinstance(node, dict):
        if "bit_exact" in node:
            _require(node["bit_exact"] is True, path,
                     f"{where}.bit_exact is {node['bit_exact']!r}, "
                     f"expected true")
        if "meets_target" in node:
            _require(isinstance(node["meets_target"], bool), path,
                     f"{where}.meets_target is not a bool")
        for k, v in node.items():
            _walk_honesty(path, v, f"{where}.{k}")
    elif isinstance(node, list):
        for i, v in enumerate(node):
            _walk_honesty(path, v, f"{where}[{i}]")


def _check_slo_block(path: str, slo: dict, where: str) -> None:
    _require(isinstance(slo.get("p99_ms"), (int, float)), path,
             f"{where}.p99_ms missing or non-numeric")
    if slo.get("slo_ms") is not None:
        for key in ("p99_slack_ms", "slo_violations", "violation_frac",
                    "meets_slo"):
            _require(key in slo, path, f"{where}.{key} missing (an arm "
                     f"with an SLO must report the full roll-up)")


def check_serve(path: str, payload: dict) -> None:
    slos_exercised = 0
    for i, result in enumerate(payload["results"]):
        runs = result.get("runs")
        _require(isinstance(runs, list) and runs, path,
                 f"results[{i}].runs missing or empty")
        for j, run in enumerate(runs):
            where = f"results[{i}].runs[{j}]"
            for key in ("scheduler", "requests", "latency_ms"):
                _require(key in run, path, f"{where}.{key} missing")
            if isinstance(run.get("slo"), dict):
                _check_slo_block(path, run["slo"], f"{where}.slo")
                slos_exercised += run["slo"].get("slo_ms") is not None
            for arm, slo in (run.get("arms") or {}).items():
                _check_slo_block(path, slo, f"{where}.arms[{arm}]")
                slos_exercised += slo.get("slo_ms") is not None
    _require(slos_exercised > 0, path,
             "no run exercised a configured SLO (every slo_ms is null)")


def check_obs(path: str, payload: dict) -> None:
    kinds = {r.get("kind") for r in payload["results"]}
    _require({"train_telemetry", "fleet_tracing"} <= kinds, path,
             f"expected both overhead rows, found kinds {sorted(kinds)}")
    for i, result in enumerate(payload["results"]):
        _require("meets_target" in result, path,
                 f"results[{i}].meets_target missing")


def check_autotune(path: str, payload: dict) -> None:
    """Tile-search results are *structurally* no-worse-than-default (the
    winner is the argmin of one paired session that includes the default),
    so that claim is value-checked; int8-vs-int32 outcomes are timing
    results and only shape-checked, like ``meets_target``."""
    for i, result in enumerate(payload["results"]):
        tiles = result.get("tiles")
        _require(isinstance(tiles, list) and tiles, path,
                 f"results[{i}].tiles missing or empty")
        for j, row in enumerate(tiles):
            where = f"results[{i}].tiles[{j}]"
            for key in ("op", "shape", "default_us", "tuned_us", "winner"):
                _require(key in row, path, f"{where}.{key} missing")
            _require(row.get("tuned_no_worse_than_default") is True, path,
                     f"{where}: tuned_us {row['tuned_us']} > default_us "
                     f"{row['default_us']} — the argmin must include the "
                     f"default probe")
        _require(result.get("tuned_no_worse_everywhere") is True, path,
                 f"results[{i}].tuned_no_worse_everywhere is not true")
        cache = result.get("cache")
        _require(isinstance(cache, dict), path, f"results[{i}].cache missing")
        _require(cache.get("second_resolution_measurement_free") is True,
                 path, f"results[{i}]: a warm cache must resolve every "
                 f"tuned problem measurement-free")
        _require(cache.get("second_resolution_hits") == len(tiles), path,
                 f"results[{i}]: {cache.get('second_resolution_hits')} "
                 f"cache hits != {len(tiles)} tuned problems")
        int8 = result.get("int8_layers")
        _require(isinstance(int8, list), path,
                 f"results[{i}].int8_layers missing")
        _require(len(int8) == result.get("int8_eligible_steps"), path,
                 f"results[{i}]: {len(int8)} int8 rows != "
                 f"{result.get('int8_eligible_steps')} eligible steps")
        for j, row in enumerate(int8):
            where = f"results[{i}].int8_layers[{j}]"
            for key in ("int8_us", "int32_us", "alpha_inv"):
                _require(isinstance(row.get(key), (int, float)), path,
                         f"{where}.{key} missing or non-numeric")
            _require(isinstance(row.get("int8_wins"), bool), path,
                     f"{where}.int8_wins is not a bool")


def check_train(path: str, payload: dict) -> None:
    """The fused-IntegerSGD rows: timings for both the fused-opt and the
    split-update step, the structural HBM-stream counts (a claim about
    the kernel dataflow, so value-checked: fused must stream strictly
    less), and the no-worse bool (a timing outcome — shape-checked
    only)."""
    for i, result in enumerate(payload["results"]):
        where = f"results[{i}]"
        us = result.get("us_per_step")
        _require(isinstance(us, dict), path, f"{where}.us_per_step missing")
        for variant in ("fused_opt", "unfused"):
            _require(isinstance(us.get(variant), (int, float)), path,
                     f"{where}.us_per_step[{variant!r}] missing or "
                     f"non-numeric")
        streams = result.get("hbm_streams_per_weight_update")
        _require(isinstance(streams, dict), path,
                 f"{where}.hbm_streams_per_weight_update missing")
        for key in ("fused_opt", "unfused_opt"):
            _require(isinstance(streams.get(key), int), path,
                     f"{where}.hbm_streams_per_weight_update[{key!r}] "
                     f"missing or non-integer")
        _require(streams["fused_opt"] < streams["unfused_opt"], path,
                 f"{where}: fused_opt streams {streams['fused_opt']} not "
                 f"< unfused_opt streams {streams['unfused_opt']} — the "
                 f"epilogue exists to remove the grad_W round-trip")
        _require(isinstance(result.get("fused_opt_no_worse_than_unfused"),
                            bool), path,
                 f"{where}.fused_opt_no_worse_than_unfused is not a bool")


def check_file(path: str) -> None:
    with open(path) as f:
        payload = json.load(f)
    _require(isinstance(payload, dict), path, "top level is not an object")
    name = payload.get("benchmark")
    _require(isinstance(name, str) and name, path,
             "missing non-empty 'benchmark' string")
    expected = EXPECTED_NAMES.get(path.rsplit("/", 1)[-1])
    if expected is not None:
        _require(name == expected, path,
                 f"benchmark {name!r} != expected {expected!r}")
    results = payload.get("results")
    _require(isinstance(results, list) and results, path,
             "missing non-empty 'results' list")
    _require(all(isinstance(r, dict) for r in results), path,
             "every results[] entry must be an object")
    _walk_honesty(path, payload)
    if name == "serve_fleet":
        check_serve(path, payload)
    elif name == "obs_overhead":
        check_obs(path, payload)
    elif name == "autotune_gain":
        check_autotune(path, payload)
    elif name == "train_step":
        check_train(path, payload)


def main(argv: list[str]) -> int:
    paths = argv or sorted(glob.glob("BENCH_*.json"))
    if not paths:
        print("check_bench_schema: no BENCH_*.json found "
              "(run the benchmarks first)", file=sys.stderr)
        return 1
    failures = 0
    for path in paths:
        try:
            check_file(path)
        except (OSError, json.JSONDecodeError, SchemaError) as e:
            print(f"check_bench_schema: FAIL {e}", file=sys.stderr)
            failures += 1
        else:
            print(f"check_bench_schema: ok {path}")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))
