#!/usr/bin/env python3
"""Docs link checker: fail on broken relative links in README.md and docs/.

Scans markdown inline links ``[text](target)`` in README.md and every
``docs/*.md``.  External schemes (http/https/mailto) are skipped;
everything else is resolved relative to the file it appears in and must
exist in the working tree.  Fragments are validated too: for a link into
a markdown file (``page.md#section`` or in-page ``#section``), the
fragment must match the GitHub-style slug of a heading in the target
file.  Exit 0 = all links OK.

    python tools/check_docs_links.py
"""

from __future__ import annotations

import re
import sys
from pathlib import Path

LINK_RE = re.compile(r"\[[^\]]*\]\(<?([^)\s>]+)>?(?:\s+\"[^\"]*\")?\)")
HEADING_RE = re.compile(r"^#{1,6}\s+(.*)$")
SKIP_PREFIXES = ("http://", "https://", "mailto:")


def _slugify(heading: str) -> str:
    """GitHub anchor slug: lowercase, drop punctuation, spaces → hyphens."""
    text = heading.strip().lower()
    text = re.sub(r"[`*_]", "", text)         # inline markdown markers
    text = re.sub(r"[^\w\- ]", "", text)      # punctuation (keeps unicode \w)
    return text.replace(" ", "-")


def _anchors(md: Path, cache: dict[Path, set[str]]) -> set[str]:
    if md not in cache:
        cache[md] = {
            _slugify(m.group(1))
            for line in md.read_text().splitlines()
            if (m := HEADING_RE.match(line))
        }
    return cache[md]


def check_file(md: Path, root: Path, anchor_cache: dict) -> list[str]:
    errors = []
    for lineno, line in enumerate(md.read_text().splitlines(), 1):
        for m in LINK_RE.finditer(line):
            target = m.group(1)
            if target.startswith(SKIP_PREFIXES):
                continue
            path, _, fragment = target.partition("#")
            dest = md if not path else (md.parent / path)
            if not dest.exists():
                errors.append(
                    f"{md.relative_to(root)}:{lineno}: broken link -> {target}"
                )
                continue
            if fragment and dest.suffix == ".md":
                if fragment not in _anchors(dest, anchor_cache):
                    errors.append(
                        f"{md.relative_to(root)}:{lineno}: "
                        f"broken anchor -> {target}"
                    )
    return errors


def main() -> int:
    root = Path(__file__).resolve().parent.parent
    files = [root / "README.md", *sorted((root / "docs").glob("*.md"))]
    files = [f for f in files if f.exists()]
    anchor_cache: dict[Path, set[str]] = {}
    errors = []
    for f in files:
        errors.extend(check_file(f, root, anchor_cache))
    for e in errors:
        print(e, file=sys.stderr)
    print(f"[check_docs_links] {len(files)} files, "
          f"{len(errors)} broken links")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
