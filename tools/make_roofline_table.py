"""Generate the EXPERIMENTS.md §Roofline markdown table from dry-run JSONs.

    PYTHONPATH=src python tools/make_roofline_table.py [pod1|pod2]
"""

import glob
import json
import sys

SHAPE_ORDER = ["train_4k", "prefill_32k", "decode_32k", "long_500k"]


def main():
    pod = sys.argv[1] if len(sys.argv) > 1 else "pod1"
    rows = []
    for f in sorted(glob.glob(f"results/dryrun/*_{pod}.json")):
        rows.append(json.load(open(f)))
    rows.sort(key=lambda r: (r["arch"], SHAPE_ORDER.index(r["shape"])))

    print(f"| arch | shape | compute_s | memory_s | collective_s | dominant "
          f"| model/HLO flops | roofline frac | mem GiB (XLA / analytic) | fits |")
    print("|---|---|---|---|---|---|---|---|---|---|")
    for r in rows:
        if r.get("skipped"):
            print(f"| {r['arch']} | {r['shape']} | — | — | — | SKIP | — | — | — | "
                  f"n/a ({r['reason'][:48]}…) |")
            continue
        t, m = r["roofline"], r["memory"]
        fits = "✓" if m.get("analytic_fits_16gib", m["fits_16gib_hbm"]) else "✗"
        print(
            f"| {r['arch']} | {r['shape']} | {t['compute_s']:.2f} | "
            f"{t['memory_s']:.2f} | {t['collective_s']:.2f} | {t['dominant']} | "
            f"{t['model_over_hlo_flops']:.3f} | {t['roofline_fraction']:.4f} | "
            f"{m['live_gib']:.1f} / {m.get('analytic_live_gib', float('nan')):.1f} | {fits} |"
        )


if __name__ == "__main__":
    main()
