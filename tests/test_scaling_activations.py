"""NITRO Scaling Layer + NITRO-ReLU: paper-exactness and range invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import activations, scaling
from repro.core.numerics import ACT_MAX, ACT_MIN


class TestScalingFactor:
    def test_linear_formula(self):
        # SF_l = 2^8 × M_{l-1}
        assert scaling.linear_scale_factor(1024) == 256 * 1024

    def test_conv_formula(self):
        # SF_l = 2^8 × K² × C
        assert scaling.conv_scale_factor(3, 128) == 256 * 9 * 128

    @given(st.integers(1, 4096))
    @settings(max_examples=100, deadline=None)
    def test_worst_case_output_in_range(self, fan_in):
        """8-bit acts × 8-bit weights × fan_in summed, then scaled, always
        lands inside the NITRO-ReLU operational range [-127, 127]."""
        sf = scaling.linear_scale_factor(fan_in)
        z_max = jnp.int32(127 * 127 * fan_in)
        z_min = -z_max
        assert int(scaling.scale_forward(z_max, sf)) <= ACT_MAX
        assert int(scaling.scale_forward(z_min, sf)) >= ACT_MIN

    def test_backward_is_ste(self):
        g = jnp.arange(-5, 5, dtype=jnp.int32)
        np.testing.assert_array_equal(
            np.asarray(scaling.scale_backward(g)), np.asarray(g)
        )

    def test_pow2_split(self):
        shift, residual = scaling.pow2_split(scaling.conv_scale_factor(3, 128))
        assert (residual << shift) == 256 * 9 * 128
        assert residual % 2 == 1


class TestNitroRelu:
    def test_segment_means_paper_formulas(self):
        a_inv = 10
        m0, m1, m2, m3 = activations.segment_means(a_inv)
        assert m0 == -127 // a_inv
        assert m1 == -127 // (2 * a_inv)
        assert (m2, m3) == (63, 127)

    def test_forward_segments(self):
        a_inv = 10
        mu = activations.mu_int8(a_inv)
        x = jnp.asarray([-500, -127, -60, 0, 64, 127, 500], jnp.int32)
        y = np.asarray(activations.nitro_relu(x, a_inv))
        # saturated negative: ⌊-127/10⌋ = -13
        assert y[0] == -13 - mu
        assert y[1] == -13 - mu
        assert y[2] == (-60 // 10) - mu
        assert y[3] == 0 - mu
        assert y[4] == 64 - mu
        assert y[5] == 127 - mu
        assert y[6] == 127 - mu  # saturated positive

    @given(st.lists(st.integers(-(2**20), 2**20), min_size=1, max_size=64))
    @settings(max_examples=200, deadline=None)
    def test_output_bounded(self, xs):
        """Output always within [-127-μ, 127-μ] ⊂ int8-representable span."""
        a_inv = 10
        mu = activations.mu_int8(a_inv)
        y = np.asarray(activations.nitro_relu(jnp.asarray(xs, jnp.int32), a_inv))
        assert y.min() >= -127 // a_inv - mu - 1
        assert y.max() <= 127 - mu
        assert np.abs(y).max() <= 127  # fits int8

    def test_backward_zero_on_saturation(self):
        z = jnp.asarray([-500, -50, 50, 500], jnp.int32)
        g = jnp.full((4,), 100, jnp.int32)
        gi = np.asarray(activations.nitro_relu_backward(z, g, 10))
        assert gi[0] == 0          # below -127: saturated
        assert gi[1] == 100 // 10  # leaky segment
        assert gi[2] == 100        # identity segment
        assert gi[3] == 0          # above 127: saturated

    @given(st.integers(2, 100))
    @settings(max_examples=50, deadline=None)
    def test_zero_centering(self, a_inv):
        """μ_int8 equals the integer mean of the four segment means."""
        mu = activations.mu_int8(a_inv)
        assert mu == sum(activations.segment_means(a_inv)) // 4
