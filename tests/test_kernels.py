"""Pallas kernel validation: interpret-mode kernel vs pure-jnp oracle,
swept over shapes, dtypes, scale factors and tile sizes."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _gradcheck import assert_bitwise_equal
from repro.core.scaling import conv_scale_factor, linear_scale_factor
from repro.kernels.integer_sgd.integer_sgd import integer_sgd_update
from repro.kernels.integer_sgd.ref import integer_sgd_ref
from repro.kernels.nitro_matmul import ops
from repro.kernels.nitro_matmul.nitro_matmul import nitro_matmul
from repro.kernels.nitro_matmul.ref import nitro_matmul_ref


class TestNitroMatmulKernel:
    @pytest.mark.parametrize("m,k,n", [
        (1, 1, 1), (7, 13, 5), (64, 64, 64), (128, 128, 128),
        (130, 200, 90), (256, 384, 128), (33, 257, 65),
    ])
    def test_shape_sweep_matches_ref(self, m, k, n):
        rng = np.random.default_rng(m * 1000 + k * 10 + n)
        x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int32)
        sf = linear_scale_factor(k)
        got = nitro_matmul(x, w, sf=sf, interpret=True, bm=32, bn=32, bk=64)
        want = nitro_matmul_ref(x, w, sf=sf)
        assert_bitwise_equal(got, want)

    @pytest.mark.parametrize("in_dtype", [jnp.int8, jnp.int32])
    @pytest.mark.parametrize("out_dtype", [jnp.int8, jnp.int32])
    def test_dtype_sweep(self, in_dtype, out_dtype):
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-127, 128, (48, 96)), in_dtype)
        w = jnp.asarray(rng.integers(-127, 128, (96, 32)), in_dtype)
        sf = linear_scale_factor(96)
        got = nitro_matmul(x, w, sf=sf, out_dtype=out_dtype, interpret=True)
        want = nitro_matmul_ref(x, w, sf=sf, out_dtype=out_dtype)
        assert got.dtype == out_dtype
        assert_bitwise_equal(got, want)

    @pytest.mark.parametrize("apply_relu", [True, False])
    @pytest.mark.parametrize("alpha_inv", [3, 10, 100])
    def test_epilogue_variants(self, apply_relu, alpha_inv):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.integers(-127, 128, (32, 64)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (64, 32)), jnp.int32)
        sf = linear_scale_factor(64)
        got = nitro_matmul(
            x, w, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu, interpret=True
        )
        want = nitro_matmul_ref(x, w, sf=sf, alpha_inv=alpha_inv, apply_relu=apply_relu)
        assert_bitwise_equal(got, want)

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
    def test_tile_size_sweep(self, bm, bn, bk):
        """Result must be invariant to BlockSpec tiling."""
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.integers(-127, 128, (100, 100)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (100, 100)), jnp.int32)
        sf = linear_scale_factor(100)
        got = nitro_matmul(x, w, sf=sf, bm=bm, bn=bn, bk=bk, interpret=True)
        want = nitro_matmul_ref(x, w, sf=sf)
        assert_bitwise_equal(got, want)

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=20, deadline=None)
    def test_property_random_shapes(self, seed):
        rng = np.random.default_rng(seed)
        m, k, n = rng.integers(1, 80, 3)
        x = jnp.asarray(rng.integers(-127, 128, (m, k)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (k, n)), jnp.int32)
        sf = linear_scale_factor(int(k))
        got = nitro_matmul(x, w, sf=sf, interpret=True, bm=32, bn=32, bk=32)
        want = nitro_matmul_ref(x, w, sf=sf)
        assert_bitwise_equal(got, want)

    def test_output_range_fits_int8(self):
        """Fused scale+relu output always fits int8 — the contract that lets
        the kernel write int8 activations back to HBM."""
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.integers(-127, 128, (64, 128)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (128, 64)), jnp.int32)
        out = nitro_matmul(x, w, sf=linear_scale_factor(128), interpret=True)
        assert int(jnp.abs(out).max()) <= 127


class TestNitroOps:
    def test_nitro_linear_matches_layer_pipeline(self):
        """ops.nitro_linear(kernel) ≡ Linear → Scaling → NITRO-ReLU refs."""
        rng = np.random.default_rng(4)
        x = jnp.asarray(rng.integers(-127, 128, (4, 10, 48)), jnp.int32)
        w = jnp.asarray(rng.integers(-60, 61, (48, 24)), jnp.int32)
        with pytest.warns(DeprecationWarning, match="use_kernel"):
            got = ops.nitro_linear(x, w, use_kernel=True, interpret=True)
        want = nitro_matmul_ref(
            x.reshape(-1, 48), w, sf=linear_scale_factor(48)
        ).reshape(4, 10, 24)
        assert_bitwise_equal(got, want)

    def test_nitro_conv2d_matches_reference_block(self):
        """Fused conv path ≡ conv_forward → scale → relu from repro.core."""
        from repro.core import activations, layers, scaling

        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.integers(-127, 128, (2, 6, 6, 3)), jnp.int32)
        w = jnp.asarray(rng.integers(-50, 51, (3, 3, 3, 8)), jnp.int32)
        with pytest.warns(DeprecationWarning, match="use_kernel"):
            got = ops.nitro_conv2d(x, w, use_kernel=True, interpret=True)
        z, _ = layers.conv_forward({"w": w}, x)
        want = activations.nitro_relu(
            scaling.scale_forward(z, scaling.conv_scale_factor(3, 3)), 10
        )
        assert_bitwise_equal(got, want)


class TestLegacyBackendKnobs:
    """The deprecated ``use_kernel``/``interpret`` mapping (bugfix
    satellite): explicit use warns, contradictions raise instead of
    silently preferring one knob, and an explicit ``interpret=True`` is
    honoured off-TPU instead of being dropped."""

    def test_legacy_knobs_warn(self):
        rng = np.random.default_rng(6)
        x = jnp.asarray(rng.integers(-127, 128, (4, 16)), jnp.int32)
        w = jnp.asarray(rng.integers(-40, 41, (16, 8)), jnp.int32)
        with pytest.warns(DeprecationWarning, match="deprecated"):
            ops.nitro_linear(x, w, use_kernel=False)
        with pytest.warns(DeprecationWarning, match="deprecated"):
            ops._legacy_backend(None, False)

    def test_defaults_do_not_warn(self):
        """The knob-free path must stay silent — only explicit legacy use
        pays the warning."""
        import warnings

        rng = np.random.default_rng(7)
        x = jnp.asarray(rng.integers(-127, 128, (4, 16)), jnp.int32)
        w = jnp.asarray(rng.integers(-40, 41, (16, 8)), jnp.int32)
        with warnings.catch_warnings():
            warnings.simplefilter("error", DeprecationWarning)
            ops.nitro_linear(x, w)

    def test_contradictory_knobs_raise(self):
        """use_kernel=False + interpret=True has no meaning: there is no
        kernel to interpret.  Historically the kernel knob silently won."""
        rng = np.random.default_rng(8)
        x = jnp.asarray(rng.integers(-127, 128, (4, 16)), jnp.int32)
        w = jnp.asarray(rng.integers(-40, 41, (16, 8)), jnp.int32)
        with pytest.warns(DeprecationWarning):
            with pytest.raises(ValueError, match="contradictory"):
                ops.nitro_linear(x, w, use_kernel=False, interpret=True)
            with pytest.raises(ValueError, match="contradictory"):
                ops.nitro_conv2d(
                    jnp.zeros((1, 4, 4, 2), jnp.int32),
                    jnp.zeros((3, 3, 2, 2), jnp.int32),
                    use_kernel=False, interpret=True,
                )

    def test_mapping_table(self):
        """The full legacy → backend table, including the fixed row:
        interpret=True with use_kernel unset selects the interpreter
        (previously it resolved to 'reference' off-TPU, silently)."""
        import warnings

        on_tpu = jax.default_backend() == "tpu"
        with warnings.catch_warnings():
            warnings.simplefilter("ignore", DeprecationWarning)
            assert ops._legacy_backend(True, True) == "interpret"
            assert ops._legacy_backend(True, False) == "pallas"
            assert ops._legacy_backend(False, None) == "reference"
            assert ops._legacy_backend(False, False) == "reference"
            assert ops._legacy_backend(None, True) == "interpret"
            assert ops._legacy_backend(None, None) == (
                "pallas" if on_tpu else "reference"
            )


class TestIntegerSGDKernel:
    @pytest.mark.parametrize("shape", [(1,), (127,), (128,), (1000,), (8, 128), (3, 3, 2, 5)])
    def test_shape_sweep(self, shape):
        rng = np.random.default_rng(sum(shape))
        w = jnp.asarray(rng.integers(-30000, 30000, shape), jnp.int32)
        g = jnp.asarray(rng.integers(-(2**20), 2**20, shape), jnp.int32)
        got = integer_sgd_update(w, g, 512, 3000, interpret=True)
        want = integer_sgd_ref(w, g, 512, 3000)
        assert_bitwise_equal(got, want)

    @pytest.mark.parametrize("gamma,eta", [(1, 0), (512, 0), (512, 3000), (4096, 28000)])
    def test_hyperparameter_sweep(self, gamma, eta):
        rng = np.random.default_rng(gamma + eta)
        w = jnp.asarray(rng.integers(-(2**15), 2**15, (300,)), jnp.int32)
        g = jnp.asarray(rng.integers(-(2**24), 2**24, (300,)), jnp.int32)
        got = integer_sgd_update(w, g, gamma, eta, interpret=True)
        want = integer_sgd_ref(w, g, gamma, eta)
        assert_bitwise_equal(got, want)

    def test_scalars_are_runtime_values(self):
        """One compiled kernel must serve different γ/η (SMEM scalars) —
        the ×3 lr schedule cannot trigger recompilation."""
        w = jnp.zeros((256,), jnp.int32) + 9000
        g = jnp.zeros((256,), jnp.int32) + 51200
        a = integer_sgd_update(w, g, jnp.int32(512), jnp.int32(3000), interpret=True)
        b = integer_sgd_update(w, g, jnp.int32(1536), jnp.int32(3000), interpret=True)
        assert int(a[0]) == 9000 - 100 - 3
        assert int(b[0]) == 9000 - 33 - 3
