"""repro.serving.fleet: registry, router, continuous-batching scheduler.

The serving control plane's contracts, on handcrafted tiny FrozenModels
(reference backend — compiles in milliseconds, so the concurrency tests
can afford many submissions):

  * bit-exactness — fleet-routed logits ≡ standalone VisionEngine ≡ the
    raw ExecutionPlan (the acceptance bar: routing must be a pure
    traffic-control layer, never a numerics layer);
  * registry — hot-swap atomicity under concurrent submission (every
    future resolves; every answer is the old or the new checkpoint's,
    never a blend), shared pad buffers, eviction;
  * scheduler — per-model FIFO ordering, bounded-queue backpressure,
    weighted round-robin fairness, drain-on-close;
  * router — deterministic request-id hashing, split fractions;
  * manifest — FLEET.json round-trip + frozen checkpoint versioning.
"""

import os
import tempfile
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.scaling import linear_scale_factor
from repro.infer import (
    compile_plan,
    load_fleet_manifest,
    load_frozen,
    prune_frozen,
    save_fleet_manifest,
    save_frozen,
)
from repro.infer.export import FrozenLayer, FrozenModel
from repro.serving import (
    EngineStats,
    FleetEngine,
    ModelRegistry,
    Router,
    VisionEngine,
    latency_summary_ms,
    parse_split,
    percentile,
)

IN_DIM, HIDDEN, CLASSES = 8, 16, 10


def tiny_model(seed: int, in_dim: int = IN_DIM, name: str | None = None):
    """Two-layer integer MLP FrozenModel — small enough to compile in ms."""
    rng = np.random.default_rng(seed)
    w1 = jnp.asarray(rng.integers(-20, 21, (in_dim, HIDDEN)), jnp.int8)
    w2 = jnp.asarray(rng.integers(-20, 21, (HIDDEN, CLASSES)), jnp.int8)
    return FrozenModel(
        layers=(
            FrozenLayer("linear", w1, linear_scale_factor(in_dim),
                        alpha_inv=2, apply_relu=True, pool=False),
            FrozenLayer("output", w2, linear_scale_factor(HIDDEN),
                        alpha_inv=0, apply_relu=False, pool=False),
        ),
        input_shape=(in_dim,),
        num_classes=CLASSES,
        name=name or f"tiny-{seed}",
    )


def images(n: int, seed: int = 7, in_dim: int = IN_DIM):
    rng = np.random.default_rng(seed)
    return [rng.integers(-127, 128, (in_dim,)).astype(np.int32)
            for _ in range(n)]


def reference_registry(**models) -> ModelRegistry:
    reg = ModelRegistry(backend="reference")
    for mid, fm in models.items():
        reg.register(mid, fm)
    return reg


class GatedPlan:
    """Plan wrapper whose logits block until released — makes queue state
    deterministic in the scheduler tests (the worker parks inside the
    launch while the test arranges queues)."""

    def __init__(self, plan):
        self._plan = plan
        self.gate = threading.Event()
        self.calls = []  # batches seen, in launch order
        self.input_shape = plan.input_shape
        self.num_classes = plan.num_classes
        self.name = plan.name
        self.backend = plan.backend

    def logits(self, x):
        self.gate.wait()
        self.calls.append(np.asarray(x))
        return self._plan.logits(x)


# ---------------------------------------------------------------------------
# stats
# ---------------------------------------------------------------------------


class TestStats:
    def test_percentile_nearest_rank(self):
        vals = [1.0, 2.0, 3.0, 4.0]
        assert percentile([], 0.5) == 0.0
        assert percentile(vals, 0.0) == 1.0
        # nearest-rank: the ceil(q·n)-th smallest — the median of 4 is
        # the 2nd value (the former floor-rank version returned the 3rd)
        assert percentile(vals, 0.5) == 2.0
        assert percentile(vals, 0.99) == 4.0
        assert percentile(vals, 1.0) == 4.0

    def test_latency_summary_keys_and_units(self):
        out = latency_summary_ms([0.001, 0.002, 0.003])
        assert set(out) == {"p50", "p90", "p95", "p99"}
        assert out["p99"] == pytest.approx(3.0)

    def test_snapshot_consistent_under_concurrent_writes(self):
        stats = EngineStats()
        n_threads, n_batches = 4, 200

        def writer():
            for _ in range(n_batches):
                stats.record_batch(3, 1, 0.01)

        threads = [threading.Thread(target=writer) for _ in range(n_threads)]
        for t in threads:
            t.start()
        while any(t.is_alive() for t in threads):
            snap = stats.snapshot()
            # a snapshot never observes a half-applied batch
            assert snap["requests"] == 3 * snap["batches"]
            assert snap["padded_slots"] == snap["batches"]
        for t in threads:
            t.join()
        snap = stats.snapshot()
        assert snap["batches"] == n_threads * n_batches
        assert snap["avg_batch_fill"] == pytest.approx(0.75)
        assert "p99" in snap["batch_latency_ms"]


# ---------------------------------------------------------------------------
# registry
# ---------------------------------------------------------------------------


class TestModelRegistry:
    def test_register_get_evict(self):
        reg = reference_registry(a=tiny_model(0), b=tiny_model(1))
        assert reg.ids() == ["a", "b"]
        assert "a" in reg and len(reg) == 2
        assert reg.get("a").plan.name == "tiny-0"
        with pytest.raises(ValueError, match="already registered"):
            reg.register("a", tiny_model(2))
        reg.evict("a")
        assert "a" not in reg
        with pytest.raises(KeyError, match="unknown model id"):
            reg.get("a")
        with pytest.raises(KeyError):
            reg.evict("a")

    def test_shared_pad_buffer_per_input_shape(self):
        reg = reference_registry(a=tiny_model(0), b=tiny_model(1))
        reg.register("c", tiny_model(2, in_dim=4))
        pad_ab = reg.pad_buffer(reg.get("a").input_shape)
        assert pad_ab is reg.pad_buffer(reg.get("b").input_shape)
        assert pad_ab is not reg.pad_buffer(reg.get("c").input_shape)
        assert not pad_ab.flags.writeable  # shared: must stay zero
        assert pad_ab.shape == (IN_DIM,)

    def test_swap_bumps_version_keeps_stats_rejects_shape_change(self):
        reg = reference_registry(a=tiny_model(0))
        entry = reg.get("a")
        entry.stats.record_batch(4, 0, 0.01)
        old_plan = entry.plan
        swapped = reg.swap("a", tiny_model(5))
        assert swapped is entry  # stable identity
        assert entry.version == 1 and entry.plan is not old_plan
        assert entry.stats.snapshot()["requests"] == 4  # stats survive
        with pytest.raises(ValueError, match="input shape"):
            reg.swap("a", tiny_model(6, in_dim=4))
        with pytest.raises(KeyError):
            reg.swap("nope", tiny_model(7))

    def test_snapshot_shape(self):
        reg = reference_registry(a=tiny_model(0))
        snap = reg.snapshot()
        assert snap["a"]["version"] == 0
        assert snap["a"]["model"] == "tiny-0"
        assert snap["a"]["requests"] == 0


# ---------------------------------------------------------------------------
# router
# ---------------------------------------------------------------------------


class TestRouter:
    def test_concrete_id_passthrough(self):
        assert Router().resolve("prod", "r1") == "prod"

    def test_deterministic_assignment(self):
        router = Router({"split": {"a": 0.5, "b": 0.5}})
        arms = [router.resolve("split", f"req-{i}") for i in range(64)]
        again = [router.resolve("split", f"req-{i}") for i in range(64)]
        assert arms == again
        assert set(arms) == {"a", "b"}

    def test_split_fractions_converge(self):
        router = Router({"split": {"a": 0.9, "b": 0.1}})
        n = 4000
        hits = sum(router.resolve("split", f"id-{i}") == "b"
                   for i in range(n))
        assert 0.07 < hits / n < 0.13

    def test_weights_normalised(self):
        r1 = Router({"s": {"a": 9.0, "b": 1.0}})
        r2 = Router({"s": {"a": 0.9, "b": 0.1}})
        ids = [f"x{i}" for i in range(256)]
        assert [r1.resolve("s", i) for i in ids] == \
            [r2.resolve("s", i) for i in ids]

    def test_parse_split(self):
        assert parse_split("a=0.9,b=0.1") == {"a": 0.9, "b": 0.1}
        with pytest.raises(ValueError):
            parse_split("a0.9")

    def test_invalid_splits_rejected(self):
        with pytest.raises(ValueError, match="no arms"):
            Router({"s": {}})
        with pytest.raises(ValueError, match="sum > 0"):
            Router({"s": {"a": 0.0}})
        with pytest.raises(ValueError, match="negative"):
            Router({"s": {"a": 2.0, "b": -1.0}})


# ---------------------------------------------------------------------------
# fleet engine — numerics
# ---------------------------------------------------------------------------


class TestFleetNumerics:
    def test_fleet_bit_exact_with_vision_engine_and_plan(self):
        """Acceptance: routing is traffic control, never numerics."""
        fm = tiny_model(0)
        reg = reference_registry(m=fm)
        plan = compile_plan(fm, backend="reference")
        imgs = images(37)

        with FleetEngine(reg, batch_size=8) as eng:
            fleet = np.stack([eng.submit(i, model="m").result().logits
                              for i in [np.asarray(x) for x in imgs]])
        with VisionEngine(plan, batch_size=8) as ve:
            vision = np.stack([f.result().logits
                               for f in [ve.submit(i) for i in imgs]])
        direct = np.asarray(jax.device_get(plan.logits(np.stack(imgs))))

        np.testing.assert_array_equal(fleet, vision)
        np.testing.assert_array_equal(fleet, direct)

    def test_no_cross_model_answer_leakage(self):
        """Interleaved traffic to two models: each answer comes from the
        model the request was submitted to."""
        fm_a, fm_b = tiny_model(0), tiny_model(1)
        reg = reference_registry(a=fm_a, b=fm_b)
        imgs = images(48)
        want = {
            mid: np.asarray(jax.device_get(
                compile_plan(fm, backend="reference").logits(np.stack(imgs))))
            for mid, fm in (("a", fm_a), ("b", fm_b))
        }
        with FleetEngine(reg, batch_size=4) as eng:
            futs = [(i, mid, eng.submit(imgs[i], model=mid))
                    for i in range(len(imgs))
                    for mid in ("a", "b")]
            for i, mid, fut in futs:
                np.testing.assert_array_equal(fut.result().logits,
                                              want[mid][i])

    def test_split_routes_and_labels(self):
        fm_a, fm_b = tiny_model(0), tiny_model(1)
        reg = reference_registry(a=fm_a, b=fm_b)
        router = Router({"split": {"a": 0.5, "b": 0.5}})
        imgs = images(32)
        want = {
            mid: np.asarray(jax.device_get(
                compile_plan(fm, backend="reference").logits(np.stack(imgs))))
            for mid, fm in (("a", fm_a), ("b", fm_b))
        }
        with FleetEngine(reg, batch_size=8, router=router) as eng:
            for i, img in enumerate(imgs):
                rid = f"req-{i}"
                arm = router.resolve("split", rid)
                got = eng.submit(img, model="split", request_id=rid).result()
                np.testing.assert_array_equal(got.logits, want[arm][i])
        # both arms actually saw traffic
        snap = reg.snapshot()
        assert snap["a"]["requests"] > 0 and snap["b"]["requests"] > 0
        assert snap["a"]["requests"] + snap["b"]["requests"] == len(imgs)


# ---------------------------------------------------------------------------
# fleet engine — scheduler behaviour
# ---------------------------------------------------------------------------


class TestFleetScheduler:
    def test_per_model_fifo_ordering(self):
        """Results resolve in submit order within each model (single worker,
        FIFO queues, batches finish in launch order)."""
        reg = reference_registry(a=tiny_model(0), b=tiny_model(1))
        order = {"a": [], "b": []}
        with FleetEngine(reg, batch_size=4) as eng:
            futs = []
            for i in range(40):
                mid = "a" if i % 2 == 0 else "b"
                fut = eng.submit(images(1, seed=i)[0], model=mid)
                fut.add_done_callback(
                    lambda f, mid=mid, i=i: order[mid].append(i))
                futs.append(fut)
            for f in futs:
                f.result()
        assert order["a"] == sorted(order["a"])
        assert order["b"] == sorted(order["b"])

    def test_backpressure_blocks_submit_until_drain(self):
        fm = tiny_model(0)
        reg = reference_registry(m=fm)
        gated = GatedPlan(reg.get("m").plan)
        reg.get("m").plan = gated
        depth = 2
        with FleetEngine(reg, batch_size=1, queue_depth=depth) as eng:
            imgs = images(depth + 3)
            # first submit is popped into flight; next `depth` fill the queue
            futs = [eng.submit(i, model="m") for i in imgs[:depth + 1]]
            blocked_fut = []
            blocker = threading.Thread(
                target=lambda: blocked_fut.append(
                    eng.submit(imgs[depth + 1], model="m")))
            blocker.start()
            blocker.join(timeout=0.3)
            assert blocker.is_alive(), "submit should block on a full queue"
            gated.gate.set()  # release the device; queue drains
            blocker.join(timeout=10)
            assert not blocker.is_alive()
            for f in futs + blocked_fut:
                assert f.result().logits.shape == (CLASSES,)

    def test_weighted_round_robin_shares_the_worker(self):
        fm_a, fm_b = tiny_model(0), tiny_model(1)
        reg = reference_registry(a=fm_a, b=fm_b)
        gated = GatedPlan(reg.get("a").plan)
        reg.get("a").plan = gated
        resolved = []
        with FleetEngine(reg, batch_size=1,
                         weights={"a": 3.0, "b": 1.0}) as eng:
            imgs = images(1)
            futs = []

            def track(mid):
                fut = eng.submit(imgs[0], model=mid)
                fut.add_done_callback(lambda f, mid=mid: resolved.append(mid))
                futs.append(fut)

            track("a")  # parked in flight behind the gate
            time.sleep(0.05)  # let the worker pick it up
            for _ in range(8):
                track("a")
            for _ in range(8):
                track("b")
            gated.gate.set()
            for f in futs:
                f.result()
        # smooth WRR at 3:1 — the first post-release picks go a,a,b,a
        assert resolved[1:5].count("b") == 1, resolved
        assert resolved.count("a") == 9 and resolved.count("b") == 8

    def test_idle_coalescing_merges_co_arriving_requests(self):
        """From idle, near-simultaneous submits share one padded launch
        instead of the first arrival triggering a one-item batch."""
        reg = reference_registry(m=tiny_model(0))
        with FleetEngine(reg, batch_size=8, coalesce_ms=200.0) as eng:
            eng.classify(images(1), model="m")  # compile outside the window
            imgs = images(4)
            futs = []
            barrier = threading.Barrier(len(imgs))

            def submitter(img):
                barrier.wait()
                futs.append(eng.submit(img, model="m"))

            threads = [threading.Thread(target=submitter, args=(i,))
                       for i in imgs]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            for f in list(futs):
                f.result()
            snap = eng.stats.snapshot()
        assert snap["batches"] == 2  # warmup + ONE coalesced batch
        assert snap["requests"] == 5

    def test_sustained_full_batches_do_not_starve_a_sparse_model(self):
        """Anti-starvation: while one model sustains full batches, a
        partial queue on another model is served within ~two flights (a
        head older than the in-flight dispatch becomes eligible)."""
        reg = reference_registry(hot=tiny_model(0), cold=tiny_model(1))

        class SlowPlan(GatedPlan):
            def logits(self, x):
                time.sleep(0.02)  # stretch each hot flight
                return self._plan.logits(x)

        hot_plan = SlowPlan(reg.get("hot").plan)
        hot_plan.gate.set()
        reg.get("hot").plan = hot_plan
        n_hot = 40
        with FleetEngine(reg, batch_size=2, queue_depth=n_hot) as eng:
            # compile both plans outside the measurement — a cold jit
            # compile (~0.5 s) would swamp the scheduling latency
            eng.classify(images(1, seed=9), model="hot")
            eng.classify(images(1, seed=9), model="cold")
            hot_futs = [eng.submit(i, model="hot")
                        for i in images(n_hot, seed=3)]
            time.sleep(0.05)  # let the hot pipeline get into flight
            t0 = time.perf_counter()
            cold = eng.submit(images(1, seed=4)[0], model="cold")
            cold.result(timeout=30)
            cold_latency = time.perf_counter() - t0
            for f in hot_futs:
                f.result(timeout=30)
        # without the aging valve, cold waits out the whole hot backlog
        # (~20 batches x 20 ms); with it, ~two flights
        assert cold_latency < 0.2, f"cold starved for {cold_latency:.3f}s"

    def test_close_drains_queued_work(self):
        reg = reference_registry(m=tiny_model(0))
        gated = GatedPlan(reg.get("m").plan)
        reg.get("m").plan = gated
        eng = FleetEngine(reg, batch_size=4)
        futs = [eng.submit(i, model="m") for i in images(10)]
        gated.gate.set()
        eng.close()  # must resolve everything queued before returning
        assert all(f.done() for f in futs)
        with pytest.raises(RuntimeError, match="closed"):
            eng.submit(images(1)[0], model="m")

    def test_submit_validation(self):
        reg = reference_registry(m=tiny_model(0))
        with FleetEngine(reg, batch_size=4) as eng:
            with pytest.raises(KeyError, match="unknown model id"):
                eng.submit(images(1)[0], model="ghost")
            with pytest.raises(ValueError, match="input shape"):
                eng.submit(np.zeros((3,), np.int32), model="m")

    def test_evicted_model_fails_queued_futures(self):
        reg = reference_registry(busy=tiny_model(0), victim=tiny_model(1))
        gated = GatedPlan(reg.get("busy").plan)
        reg.get("busy").plan = gated
        with FleetEngine(reg, batch_size=1) as eng:
            hold = eng.submit(images(1)[0], model="busy")  # parks the worker
            time.sleep(0.05)
            doomed = [eng.submit(i, model="victim") for i in images(3)]
            reg.evict("victim")
            gated.gate.set()
            hold.result()
            for f in doomed:
                with pytest.raises(RuntimeError, match="evicted"):
                    f.result(timeout=10)
            # scheduler state of the evicted model is garbage-collected
            # once its queue drains and the worker next goes idle
            eng.submit(images(1)[0], model="busy").result(timeout=10)
            deadline = time.perf_counter() + 5
            while ("victim" in eng._queues
                   and time.perf_counter() < deadline):
                time.sleep(0.01)
                eng.submit(images(1)[0], model="busy").result(timeout=10)
            assert "victim" not in eng._queues

    def test_cancelled_future_does_not_kill_the_worker(self):
        """A client cancelling a queued future (client-side timeout) must
        not wedge the engine: delivering to a cancelled future would raise
        InvalidStateError in the only worker thread."""
        reg = reference_registry(m=tiny_model(0))
        gated = GatedPlan(reg.get("m").plan)
        reg.get("m").plan = gated
        with FleetEngine(reg, batch_size=2) as eng:
            hold = eng.submit(images(1)[0], model="m")  # parks the worker
            time.sleep(0.05)
            queued = [eng.submit(i, model="m") for i in images(4, seed=1)]
            assert queued[1].cancel() and queued[2].cancel()
            gated.gate.set()
            hold.result(timeout=10)
            for f in (queued[0], queued[3]):  # engine still serves
                assert f.result(timeout=10).logits.shape == (CLASSES,)
            assert queued[1].cancelled() and queued[2].cancelled()
            late = eng.submit(images(1, seed=2)[0], model="m")
            assert late.result(timeout=10).logits.shape == (CLASSES,)

    def test_plan_failure_surfaces_on_futures_and_engine_survives(self):
        reg = reference_registry(m=tiny_model(0))

        class BoomPlan(GatedPlan):
            def logits(self, x):
                raise RuntimeError("boom")

        good_plan = reg.get("m").plan
        reg.get("m").plan = BoomPlan(good_plan)
        with FleetEngine(reg, batch_size=2) as eng:
            bad = eng.submit(images(1)[0], model="m")
            with pytest.raises(RuntimeError, match="boom"):
                bad.result(timeout=10)
            reg.get("m").plan = good_plan  # "hot-swap" back to a good plan
            ok = eng.submit(images(1)[0], model="m")
            assert ok.result(timeout=10).logits.shape == (CLASSES,)


# ---------------------------------------------------------------------------
# hot-swap under fire
# ---------------------------------------------------------------------------


class TestHotSwapConcurrency:
    def test_swap_under_concurrent_submit_resolves_everything(self):
        """Clients hammer one model id while checkpoints hot-swap beneath
        them: every future must resolve, and every answer must equal the
        old or the new checkpoint's logits for that image — never a torn
        mixture."""
        fm_v0, fm_v1 = tiny_model(0), tiny_model(1)
        reg = reference_registry(prod=fm_v0)
        imgs = images(24)
        want = {
            v: np.asarray(jax.device_get(
                compile_plan(fm, backend="reference").logits(np.stack(imgs))))
            for v, fm in ((0, fm_v0), (1, fm_v1))
        }
        n_clients, per_client = 3, 40
        results = [[] for _ in range(n_clients)]
        stop_swapping = threading.Event()

        def swapper():
            version = 0
            while not stop_swapping.is_set():
                version ^= 1
                reg.swap("prod", (fm_v0, fm_v1)[version])
                time.sleep(0.002)

        def client(w):
            for k in range(per_client):
                i = (w * per_client + k) % len(imgs)
                logits = engine.submit(
                    imgs[i], model="prod").result(timeout=30).logits
                results[w].append((i, logits))

        with FleetEngine(reg, batch_size=4) as engine:
            sw = threading.Thread(target=swapper)
            clients = [threading.Thread(target=client, args=(w,))
                       for w in range(n_clients)]
            sw.start()
            for t in clients:
                t.start()
            for t in clients:
                t.join()
            stop_swapping.set()
            sw.join()

        checked = 0
        for w in range(n_clients):
            assert len(results[w]) == per_client  # every future resolved
            for i, logits in results[w]:
                ok = (np.array_equal(logits, want[0][i])
                      or np.array_equal(logits, want[1][i]))
                assert ok, f"torn logits for image {i}"
                checked += 1
        assert checked == n_clients * per_client
        assert reg.get("prod").version > 0  # swaps actually happened


# ---------------------------------------------------------------------------
# manifests + checkpoint versioning
# ---------------------------------------------------------------------------


class TestFleetManifest:
    def test_round_trip_and_relative_paths(self):
        with tempfile.TemporaryDirectory() as root:
            save_frozen(f"{root}/a", tiny_model(0))
            save_frozen(f"{root}/b", tiny_model(1))
            save_fleet_manifest(root, {"a": "a", "b": "b"},
                                splits={"s": {"a": 0.5, "b": 0.5}})
            manifest = load_fleet_manifest(root)
            assert manifest["splits"] == {"s": {"a": 0.5, "b": 0.5}}
            reg = ModelRegistry.from_manifest(root, backend="reference")
            assert reg.ids() == ["a", "b"]
            assert reg.get("a").plan.name == "tiny-0"

    def test_manifest_validation(self):
        with tempfile.TemporaryDirectory() as root:
            with pytest.raises(ValueError, match="at least one model"):
                save_fleet_manifest(root, {})
            with pytest.raises(ValueError, match="unknown models"):
                save_fleet_manifest(root, {"a": "a"},
                                    splits={"s": {"ghost": 1.0}})
            with pytest.raises(ValueError, match="shadows"):
                save_fleet_manifest(root, {"a": "a"},
                                    splits={"a": {"a": 1.0}})
            with pytest.raises(FileNotFoundError):
                load_fleet_manifest(root)

    def test_hand_edited_manifest_rejected_at_load(self):
        """The invariants hold on READ too — a hand-edited FLEET.json
        with a broken split fails at load, not per-request at serve."""
        import json as _json

        with tempfile.TemporaryDirectory() as root:
            save_frozen(f"{root}/a", tiny_model(0))
            save_fleet_manifest(root, {"a": "a"})
            path = f"{root}/FLEET.json"
            with open(path) as f:
                meta = _json.load(f)
            meta["splits"] = {"s": {"ghost": 1.0}}
            with open(path, "w") as f:
                _json.dump(meta, f)
            with pytest.raises(ValueError, match="unknown models"):
                load_fleet_manifest(root)

    def test_save_frozen_appends_versions_and_pins_steps(self):
        fm0, fm1 = tiny_model(0), tiny_model(1)
        with tempfile.TemporaryDirectory() as d:
            save_frozen(d, fm0)
            save_frozen(d, fm1)  # auto-increments: does not clobber v0
            latest = load_frozen(d)
            pinned0 = load_frozen(d, step=0)
            np.testing.assert_array_equal(
                np.asarray(latest.layers[0].w), np.asarray(fm1.layers[0].w))
            np.testing.assert_array_equal(
                np.asarray(pinned0.layers[0].w), np.asarray(fm0.layers[0].w))

    def test_prune_keeps_newest_versions(self):
        with tempfile.TemporaryDirectory() as d:
            for seed in range(4):
                save_frozen(d, tiny_model(seed))
            save_frozen(d, tiny_model(4), keep_last=2)  # prunes 0..2
            assert sorted(
                n for n in os.listdir(d) if n.startswith("step_")
            ) == ["step_00000003", "step_00000004"]
            latest = load_frozen(d)  # newest survives and still loads
            np.testing.assert_array_equal(
                np.asarray(latest.layers[0].w),
                np.asarray(tiny_model(4).layers[0].w))
            with pytest.raises(ValueError, match="keep_last"):
                prune_frozen(d, keep_last=0)

    def test_auto_save_after_rollback_does_not_clobber(self):
        """Auto-increment must step past the numerically newest directory,
        not past LATEST — after a rollback re-export those differ, and
        incrementing from LATEST would overwrite a retained version."""
        with tempfile.TemporaryDirectory() as d:
            for seed in range(3):
                save_frozen(d, tiny_model(seed))   # steps 0, 1, 2
            save_frozen(d, tiny_model(9), step=1)  # rollback: LATEST -> 1
            save_frozen(d, tiny_model(3))          # auto: 3, NOT 2
            np.testing.assert_array_equal(  # step 2 survived the auto save
                np.asarray(load_frozen(d, step=2).layers[0].w),
                np.asarray(tiny_model(2).layers[0].w))
            np.testing.assert_array_equal(  # and LATEST now names step 3
                np.asarray(load_frozen(d).layers[0].w),
                np.asarray(tiny_model(3).layers[0].w))

    def test_prune_never_deletes_the_step_latest_names(self):
        """A rollback re-export rewrites LATEST to a lower step; pruning
        must keep that step even though it is not numerically newest."""
        with tempfile.TemporaryDirectory() as d:
            save_frozen(d, tiny_model(0), step=5)
            save_frozen(d, tiny_model(1), step=3)  # rollback: LATEST -> 3
            pruned = prune_frozen(d, keep_last=1)
            assert pruned == []  # 5 is newest, 3 is LATEST: both kept
            rolled_back = load_frozen(d)
            np.testing.assert_array_equal(
                np.asarray(rolled_back.layers[0].w),
                np.asarray(tiny_model(1).layers[0].w))


# ---------------------------------------------------------------------------
# registry-routed serving on a real paper config (slow)
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestFleetPaperConfig:
    def test_registry_routed_bit_exact_on_vgg8b(self):
        from repro.configs import paper
        from repro.core import les
        from repro.infer import freeze

        cfg = paper.get("vgg8b", scale=0.0625)
        state = les.create_train_state(jax.random.PRNGKey(3), cfg)
        fm = freeze(state, cfg)
        plan = compile_plan(fm, backend="reference")
        reg = ModelRegistry(backend="reference")
        reg.register("prod", fm)
        rng = np.random.default_rng(11)
        imgs = [rng.integers(-127, 128, cfg.input_shape).astype(np.int32)
                for _ in range(24)]

        with FleetEngine(reg, batch_size=8) as eng:
            fleet = np.stack([eng.submit(i, model="prod").result().logits
                              for i in imgs])
        with VisionEngine(plan, batch_size=8) as ve:
            vision = np.stack([f.result().logits
                               for f in [ve.submit(i) for i in imgs]])
        np.testing.assert_array_equal(fleet, vision)


# ---------------------------------------------------------------------------
# SLO attribution
# ---------------------------------------------------------------------------


class TestSlo:
    def test_slo_validation_and_units(self):
        from repro.serving import Slo

        slo = Slo(deadline_ms=50.0)
        assert slo.deadline_s == 0.05
        assert slo.slack_s(0.04) == pytest.approx(0.01)
        assert slo.slack_s(0.06) == pytest.approx(-0.01)
        with pytest.raises(ValueError, match="deadline"):
            Slo(deadline_ms=0)
        with pytest.raises(ValueError, match="deadline"):
            Slo(deadline_ms=-5)

    def test_slo_summary_with_and_without_objective(self):
        from repro.serving import Slo, slo_summary

        # nearest-rank p99 of 100 samples = the 99th smallest
        lats = [0.010] * 97 + [0.080] * 3
        out = slo_summary(lats, Slo(deadline_ms=50.0))
        assert out["p99_ms"] == pytest.approx(80.0)
        assert out["slo_ms"] == 50.0
        assert out["p99_slack_ms"] == pytest.approx(-30.0)
        assert out["slo_violations"] == 3
        assert out["violation_frac"] == pytest.approx(0.03)
        assert out["meets_slo"] is False
        ok = slo_summary([0.001] * 10, Slo(deadline_ms=50.0))
        assert ok["meets_slo"] is True and ok["slo_violations"] == 0
        bare = slo_summary(lats, None)
        assert bare["slo_ms"] is None and "meets_slo" not in bare

    def test_registry_threads_slo_through_lifecycle(self):
        from repro.serving import Slo

        reg = ModelRegistry(backend="reference")
        slo = Slo(deadline_ms=25.0)
        entry = reg.register("prod", tiny_model(0), slo=slo)
        assert entry.slo is slo
        # hot-swap keeps the objective: it belongs to the stable id
        reg.swap("prod", tiny_model(1))
        assert reg.get("prod").slo is slo
        assert reg.snapshot()["prod"]["slo_ms"] == 25.0
        reg.set_slo("prod", None)
        assert reg.get("prod").slo is None
        assert reg.snapshot()["prod"]["slo_ms"] is None

    def test_fleet_attributes_deadline_slack_per_request(self):
        from repro.obs.metrics import MetricRegistry
        from repro.serving import Slo

        metrics = MetricRegistry()
        reg = ModelRegistry(backend="reference", metrics=metrics)
        # generous deadline: every request must make it → 0 violations
        reg.register("prod", tiny_model(0), slo=Slo(deadline_ms=10_000.0))
        reg.register("free", tiny_model(1))  # no SLO: must not be counted
        with FleetEngine(reg, batch_size=4) as engine:
            futs = [engine.submit(img, model="prod") for img in images(12)]
            futs += [engine.submit(img, model="free") for img in images(4)]
            for f in futs:
                f.result()
            snap = engine.snapshot()
        assert snap["slo"] == {"prod": {
            "requests": 12, "violations": 0, "violation_frac": 0.0}}
        from repro.serving.stats import SLACK_BUCKETS
        hist = metrics.histogram(
            "serve_request_deadline_seconds", labels=("model",),
            buckets=SLACK_BUCKETS).labels(model="prod")
        assert hist.count == 12
        assert all(s > 0 for s in hist.window)  # slack, and all positive
        assert metrics.counter(
            "serve_slo_violations_total", labels=("model",),
        ).labels(model="prod").value == 0
        assert metrics.gauge(
            "serve_slo_deadline_seconds", labels=("model",),
        ).labels(model="prod").value == 10.0

    def test_fleet_counts_violations_against_tight_deadline(self):
        from repro.serving import Slo

        reg = ModelRegistry(backend="reference")
        # 1 µs deadline: physically unmeetable → everything violates
        reg.register("prod", tiny_model(0), slo=Slo(deadline_ms=0.001))
        with FleetEngine(reg, batch_size=4) as engine:
            for f in [engine.submit(img, model="prod")
                      for img in images(8)]:
                f.result()
            slo_snap = engine.slo_snapshot()
        assert slo_snap["prod"]["requests"] == 8
        assert slo_snap["prod"]["violations"] == 8
        assert slo_snap["prod"]["violation_frac"] == 1.0

    def test_no_slo_means_no_attribution(self):
        reg = ModelRegistry(backend="reference")
        reg.register("prod", tiny_model(0))
        with FleetEngine(reg, batch_size=4) as engine:
            for f in [engine.submit(img, model="prod")
                      for img in images(4)]:
                f.result()
            assert engine.slo_snapshot() == {}
            assert engine.snapshot()["slo"] == {}
