"""Kernel autotuner: persistent tile cache, the search, tile-invariance
of every dispatcher, and the int8-operand MXU path.

The load-bearing invariant mirrors the kernel suites': integer
accumulation is order-exact, so *any* accepted tile configuration — and
either operand path — must be bitwise identical.  Tiling and operand
dtype are perf knobs only; these tests enforce that they can never
change a result.
"""

import json
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from _gradcheck import assert_bitwise_equal
from repro.core.activations import relu_fits_int8
from repro.core.scaling import conv_scale_factor, linear_scale_factor
from repro.kernels import autotune
from repro.kernels.autotune import (
    DEFAULT_TILES,
    TileCache,
    TileConfig,
    build_fingerprint,
    cache_key,
    configure,
    conv_candidates,
    matmul_candidates,
    plan_shapes,
    resolve_tiles,
    set_metrics,
    training_shapes,
    tune,
    tune_plan,
)
from repro.kernels.autotune.tiles import conv_vmem_bytes, matmul_vmem_bytes
from repro.kernels.grad_ops import conv_grads, linear_grads
from repro.kernels.nitro_conv.ops import fused_conv, fused_conv_fwd
from repro.kernels.nitro_matmul.ops import (
    fused_matmul,
    fused_matmul_fwd,
    resolve_operand_dtype,
)


@pytest.fixture(autouse=True)
def _no_process_cache():
    """Tests must not observe (or leak) a process-wide autotune state."""
    configure(None)
    set_metrics(None)
    yield
    configure(None)
    set_metrics(None)


def _rand(shape, dtype=jnp.int32, lo=-63, hi=64, seed=0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.integers(lo, hi, shape), dtype)


def _tiny_cfg():
    """One conv + one linear block at 8x8 — the benchmark smoke topology."""
    from repro.core.blocks import BlockSpec
    from repro.core.model import NitroConfig

    return NitroConfig(
        blocks=(BlockSpec("conv", 8, pool=True, d_lr=64),
                BlockSpec("linear", 16)),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        name="tiny-smoke",
    )


# ---------------------------------------------------------------------------
# TileConfig + candidate generation
# ---------------------------------------------------------------------------


class TestTileConfig:
    def test_json_round_trip(self):
        cfg = TileConfig(bm=32, bn=256, bk=512, bh=4, bf=256)
        assert TileConfig.from_json(cfg.to_json()) == cfg

    def test_from_json_ignores_unknown_fields(self):
        assert TileConfig.from_json(
            {"bm": 64, "future_knob": 7}) == TileConfig(bm=64)

    def test_from_json_rejects_non_positive(self):
        with pytest.raises(ValueError):
            TileConfig.from_json({"bm": 0})

    def test_candidates_respect_vmem_budget(self):
        for cfg in matmul_candidates(4096, 4096, 4096):
            assert matmul_vmem_bytes(cfg.bm, cfg.bn, cfg.bk) \
                <= autotune.tiles.VMEM_BUDGET_BYTES
        for cfg in conv_candidates(64, 64, 256, 3, 256):
            assert conv_vmem_bytes(cfg.bh, cfg.bf, h=64, w=64, c=256, k=3) \
                <= autotune.tiles.VMEM_BUDGET_BYTES

    def test_default_probes_first(self):
        assert matmul_candidates(512, 512, 512)[0] == DEFAULT_TILES
        assert conv_candidates(32, 32, 64, 3, 64)[0] == DEFAULT_TILES


# ---------------------------------------------------------------------------
# Persistent cache
# ---------------------------------------------------------------------------


class TestTileCache:
    def test_round_trip(self, tmp_path):
        cache = TileCache(str(tmp_path))
        key = cache_key("matmul", (64, 96, 128), "int32,int32", "interpret")
        cache.put(key, TileConfig(bm=32))
        assert TileCache(str(tmp_path)).get(key) == TileConfig(bm=32)

    def test_corrupt_file_treated_as_empty(self, tmp_path):
        path = tmp_path / "tile_cache.json"
        path.write_text("{not json")
        cache = TileCache(str(path))
        assert len(cache) == 0
        cache.put("k", DEFAULT_TILES)  # and it recovers by rewriting
        assert TileCache(str(path)).get("k") == DEFAULT_TILES

    def test_stale_fingerprint_invalidates(self, tmp_path):
        path = str(tmp_path / "tile_cache.json")
        old = TileCache(path, fingerprint="repro=0.0|jax=old|backend=cpu")
        old.put("k", TileConfig(bm=32))
        fresh = TileCache(path)  # real fingerprint differs
        assert len(fresh) == 0
        assert "k" not in fresh

    def test_fingerprint_preserved_on_disk(self, tmp_path):
        path = str(tmp_path / "tile_cache.json")
        TileCache(path).put("k", DEFAULT_TILES)
        on_disk = json.loads(open(path).read())
        assert on_disk["fingerprint"] == build_fingerprint()

    def test_concurrent_writers_lose_no_entry(self, tmp_path):
        path = str(tmp_path / "tile_cache.json")

        def write(i):
            TileCache(path).put(f"k{i}", TileConfig(bm=32 + i))

        threads = [threading.Thread(target=write, args=(i,))
                   for i in range(8)]
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        # atomic rename + read-merge-write: every writer's entry survives
        final = TileCache(path)
        for i in range(8):
            assert final.get(f"k{i}") == TileConfig(bm=32 + i)

    def test_resolve_tiles_none_without_cache(self):
        assert resolve_tiles("matmul", (8, 8, 8), dtype="int32,int32",
                             backend="interpret") is None

    def test_resolve_tiles_hit_and_miss_counters(self, tmp_path):
        from repro.obs.metrics import MetricRegistry

        cache = TileCache(str(tmp_path))
        key = cache_key("matmul", (8, 16, 8), "int32,int32", "interpret")
        cache.put(key, TileConfig(bm=32))
        reg = MetricRegistry()
        set_metrics(reg)
        configure(cache)
        hit = resolve_tiles("matmul", (8, 16, 8), dtype="int32,int32",
                            backend="interpret")
        miss = resolve_tiles("matmul", (9, 9, 9), dtype="int32,int32",
                             backend="interpret")
        assert hit == TileConfig(bm=32) and miss is None
        snap = reg.json_snapshot()
        assert snap["kernel_tile_cache_hits_total"]["samples"][0]["value"] == 1
        assert snap["kernel_tile_cache_misses_total"]["samples"][0]["value"] == 1


# ---------------------------------------------------------------------------
# The search
# ---------------------------------------------------------------------------


class TestTune:
    def test_tune_matmul_caches_winner(self, tmp_path):
        cache = TileCache(str(tmp_path))
        winner, times = tune("matmul", (32, 64, 128), backend="interpret",
                             cache=cache, iters=1)
        assert winner in times and times[winner] == min(times.values())
        key = cache_key("matmul", (32, 64, 128), "int32,int32", "interpret")
        assert cache.get(key) == winner

    def test_tuned_never_worse_than_default_in_session(self):
        # the default probes in the SAME paired session, so the argmin is
        # <= the default's time by construction
        winner, times = tune("conv", (1, 8, 8, 3, 3, 8),
                             backend="reference", iters=1)
        assert times[winner] == min(times.values())

    def test_untunable_combinations_return_none(self):
        assert tune("matmul", (8, 8, 8), backend="reference") == (None, {})
        assert tune("conv_grad_w", (1, 8, 8, 3, 3, 8), backend="reference",
                    conv_mode="materialise") == (None, {})

    @pytest.mark.parametrize("op,shape", [
        ("matmul_fwd", (16, 32, 16)),
        ("matmul_grad_w", (16, 32, 16)),
        ("matmul_grad_x", (16, 32, 16)),
        ("conv_fwd", (1, 8, 8, 3, 3, 8)),
        ("conv_grad_w", (1, 8, 8, 3, 3, 8)),
        ("conv_grad_x", (1, 8, 8, 8, 3, 3)),
    ])
    def test_training_ops_tune_parity_gated(self, op, shape):
        # interpret backend: the real kernels run under every candidate,
        # and tune() itself asserts bitwise parity vs the reference oracle
        winner, times = tune(op, shape, backend="interpret", iters=1)
        assert winner in times

    def test_whole_model_shape_walkers(self):
        from repro.core import les
        from repro.infer.export import freeze
        from repro.infer.plan import compile_plan

        cfg = _tiny_cfg()
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        plan = compile_plan(freeze(state, cfg), backend="reference")
        probs = plan_shapes(plan, 4)
        assert len(probs) == len(plan.metas)
        assert probs[0]["op"] == "conv" and probs[0]["shape"][0] == 4
        train_probs = training_shapes(cfg, 4)
        # conv block: fwd + grad_w + grad_x; linear blocks: fwd + grads
        ops_seen = {p["op"] for p in train_probs}
        assert {"conv_fwd", "conv_grad_w", "conv_grad_x",
                "matmul_fwd", "matmul_grad_w", "matmul_grad_x"} <= ops_seen

    def test_tune_plan_second_call_measurement_free(self, tmp_path):
        from repro.core import les
        from repro.infer.export import freeze
        from repro.infer.plan import compile_plan

        cfg = _tiny_cfg()
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        plan = compile_plan(freeze(state, cfg), backend="reference")
        cache = TileCache(str(tmp_path))
        first = tune_plan(plan, 4, cache=cache, iters=1)
        outcomes = []
        orig = autotune.search.tune

        def spy(*a, **kw):
            out = orig(*a, **kw)
            outcomes.append(out)
            return out

        autotune.search.tune, second = spy, None
        try:
            second = tune_plan(plan, 4, cache=cache, iters=1)
        finally:
            autotune.search.tune = orig
        assert second == first
        # every tunable key is served from the cache; only untunable
        # problems reach tune(), and those return without measuring
        assert all(out == (None, {}) for out in outcomes)


# ---------------------------------------------------------------------------
# Bitwise tile-invariance of the dispatchers (the defining property)
# ---------------------------------------------------------------------------

@st.composite
def tile_cfgs(draw):
    return TileConfig(
        bm=draw(st.sampled_from([8, 32, 128, 256])),
        bn=draw(st.sampled_from([32, 128, 256])),
        bk=draw(st.sampled_from([32, 128, 512])),
        bh=draw(st.sampled_from([1, 2, 3, 8, 32])),
        bf=draw(st.sampled_from([32, 128, 256])),
    )


class TestTileInvariance:
    @given(tile_cfgs())
    @settings(max_examples=8, deadline=None)
    def test_fused_matmul_any_tiles(self, tiles):
        x, w = _rand((33, 96), seed=1), _rand((96, 64), seed=2)
        sf = linear_scale_factor(96)
        want = fused_matmul(x, w, sf=sf, backend="reference")
        got = fused_matmul(x, w, sf=sf, backend="interpret", tiles=tiles)
        assert_bitwise_equal(got, want)

    @given(tile_cfgs())
    @settings(max_examples=6, deadline=None)
    def test_fused_conv_any_tiles(self, tiles):
        x, w = _rand((2, 12, 12, 3), seed=3), _rand((3, 3, 3, 16), seed=4)
        sf = conv_scale_factor(3, 3)
        want = fused_conv(x, w, sf=sf, pool=True, backend="reference")
        for backend in ("reference", "interpret"):
            got = fused_conv(x, w, sf=sf, pool=True, backend=backend,
                             tiles=tiles)
            assert_bitwise_equal(got, want)

    @given(tile_cfgs())
    @settings(max_examples=4, deadline=None)
    def test_training_fwd_bwd_any_tiles(self, tiles):
        x, w = _rand((2, 8, 8, 3), seed=5), _rand((3, 3, 3, 8), seed=6)
        delta = _rand((2, 8, 8, 8), seed=7)
        sf = conv_scale_factor(3, 3)
        a_ref, z_ref = fused_conv_fwd(x, w, sf=sf, backend="reference")
        a, z = fused_conv_fwd(x, w, sf=sf, backend="interpret", tiles=tiles)
        assert_bitwise_equal(a, a_ref)
        assert_bitwise_equal(z, z_ref)
        gx_ref, gw_ref = conv_grads(x, w, delta, z_star=z_ref,
                                    backend="reference")
        gx, gw = conv_grads(x, w, delta, z_star=z_ref, backend="interpret",
                            tiles=tiles)
        assert_bitwise_equal(gx, gx_ref)
        assert_bitwise_equal(gw, gw_ref)

    def test_linear_grads_tiles(self):
        x, w = _rand((16, 48), seed=8), _rand((48, 32), seed=9)
        delta = _rand((16, 32), seed=10)
        _, z = fused_matmul_fwd(x, w, sf=linear_scale_factor(48),
                                backend="reference")
        want = linear_grads(x, w, delta, z_star=z, backend="reference")
        got = linear_grads(x, w, delta, z_star=z, backend="interpret",
                           tiles=TileConfig(bm=8, bn=32, bk=256))
        for g, r in zip(got, want):
            assert_bitwise_equal(g, r)

    def test_plan_logits_tile_invariant_via_cache(self, tmp_path):
        from repro.core import les, model as M
        from repro.infer.export import freeze
        from repro.infer.plan import compile_plan

        cfg = _tiny_cfg()
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        fm = freeze(state, cfg)
        x = _rand((4, 8, 8, 3), lo=-127, hi=128, seed=11)
        want = M.frozen_forward(state.params, cfg, x)
        cache = TileCache(str(tmp_path))
        plan = compile_plan(fm, backend="reference")
        for p in plan_shapes(plan, 4):
            if p["op"] == "conv":  # force a non-default band height
                cache.put(cache_key(p["op"], p["shape"], p["dtype"],
                                    "reference", p["conv_mode"],
                                    p["fuse_bwd"]),
                          TileConfig(bh=3))
        configure(cache)
        tuned_plan = compile_plan(fm, backend="reference")
        assert_bitwise_equal(tuned_plan.logits(x), want)


# ---------------------------------------------------------------------------
# int8-operand MXU path
# ---------------------------------------------------------------------------


class TestInt8OperandPath:
    def test_resolve_operand_dtype(self):
        x8, w8 = _rand((4, 8), jnp.int8), _rand((8, 4), jnp.int8)
        x32 = _rand((4, 8))
        assert resolve_operand_dtype("auto", x8, w8) == "int8"
        assert resolve_operand_dtype("auto", x32, w8) == "int32"
        assert resolve_operand_dtype("int32", x8, w8) == "int32"
        with pytest.raises(ValueError):
            resolve_operand_dtype("int4", x8, w8)

    @pytest.mark.parametrize("backend", ["reference", "interpret"])
    def test_matmul_int8_parity(self, backend):
        x8 = _rand((32, 96), jnp.int8, -127, 128, seed=12)
        w8 = _rand((96, 64), jnp.int8, -127, 128, seed=13)
        sf = linear_scale_factor(96)
        want = fused_matmul(x8.astype(jnp.int32), w8.astype(jnp.int32),
                            sf=sf, backend="reference")
        got = fused_matmul(x8, w8, sf=sf, backend=backend,
                           operand_dtype="int8")
        assert_bitwise_equal(got, want)

    @pytest.mark.parametrize("backend", ["reference", "interpret"])
    @pytest.mark.parametrize("conv_mode", ["stream", "materialise"])
    def test_conv_int8_parity(self, backend, conv_mode):
        x8 = _rand((2, 12, 12, 3), jnp.int8, -127, 128, seed=14)
        w8 = _rand((3, 3, 3, 16), jnp.int8, -16, 16, seed=15)
        sf = conv_scale_factor(3, 3)
        want = fused_conv(x8.astype(jnp.int32), w8.astype(jnp.int32),
                          sf=sf, pool=True, backend="reference")
        got = fused_conv(x8, w8, sf=sf, pool=True, backend=backend,
                         conv_mode=conv_mode, operand_dtype="int8")
        assert_bitwise_equal(got, want)

    def test_guard_narrows_concrete_fit(self):
        # int32-stored values that provably fit int8 are narrowed
        x = _rand((8, 16), jnp.int32, -100, 101, seed=16)
        w = _rand((16, 8), jnp.int32, -100, 101, seed=17)
        got = fused_matmul(x, w, sf=16, operand_dtype="int8",
                           backend="reference")
        want = fused_matmul(x, w, sf=16, backend="reference")
        assert_bitwise_equal(got, want)

    def test_guard_rejects_wide_values(self):
        x = jnp.full((4, 8), 1000, jnp.int32)
        w = _rand((8, 4), seed=18)
        with pytest.raises(ValueError, match="do not fit int8"):
            fused_matmul(x, w, sf=16, operand_dtype="int8",
                         backend="reference")

    def test_guard_rejects_traced_wide_operands(self):
        x, w = _rand((4, 8)), _rand((8, 4))

        @jax.jit
        def f(x, w):
            return fused_matmul(x, w, sf=16, operand_dtype="int8",
                                backend="reference")

        with pytest.raises(ValueError, match="traced"):
            f(x, w)

    def test_alpha_inv_one_edge_not_eligible(self):
        # α_inv = 1 is the NITRO-ReLU range that does NOT fit int8 —
        # the plan must keep such activations (and operands) int32
        assert not relu_fits_int8(1)
        assert all(relu_fits_int8(a) for a in (2, 3, 10, 100))


class TestPlanOperandDtype:
    def _plan_parts(self):
        from repro.core import les
        from repro.infer.export import freeze

        cfg = _tiny_cfg()
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        return state, cfg, freeze(state, cfg)

    def test_auto_selects_int8_and_matches_int32(self):
        from repro.core import model as M
        from repro.infer.plan import compile_plan

        state, cfg, fm = self._plan_parts()
        plan = compile_plan(fm, backend="reference", operand_dtype="auto")
        # first step's input is int32 (the raw image) — never eligible
        assert plan.metas[0].operand_dtype == "int32"
        assert any(m.operand_dtype == "int8" for m in plan.metas)
        assert all(r["operand_dtype"] in ("int8", "int32")
                   for r in plan.summary())
        x = _rand((4, 8, 8, 3), lo=-127, hi=128, seed=19)
        want = M.frozen_forward(state.params, cfg, x)
        assert_bitwise_equal(plan.logits(x), want)
        escape = compile_plan(fm, backend="reference",
                              operand_dtype="int32")
        assert all(m.operand_dtype == "int32" for m in escape.metas)
        assert_bitwise_equal(escape.logits(x), want)

    def test_force_int8_raises_when_nothing_eligible(self):
        from repro.core import les
        from repro.core.blocks import BlockSpec
        from repro.core.model import NitroConfig
        from repro.infer.export import freeze
        from repro.infer.plan import compile_plan

        # α_inv=1 everywhere: no activation narrows to int8, so no step
        # can prove the int8 operand fit
        cfg = NitroConfig(
            blocks=(BlockSpec("linear", 16, alpha_inv=1),),
            input_shape=(24,), num_classes=10, gamma_inv=512,
            name="no-int8")
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        fm = freeze(state, cfg)
        with pytest.raises(ValueError, match="no step is int8-eligible"):
            compile_plan(fm, operand_dtype="int8")
        compile_plan(fm, operand_dtype="auto")  # auto degrades gracefully

    def test_int8_gauge_per_step(self):
        from repro.infer.plan import compile_plan
        from repro.obs.metrics import MetricRegistry

        _, _, fm = self._plan_parts()
        reg = MetricRegistry()
        set_metrics(reg)
        plan = compile_plan(fm, backend="reference")
        samples = reg.json_snapshot()["kernel_int8_path_active"]["samples"]
        by_layer = {s["labels"]["layer"]: s["value"] for s in samples}
        assert by_layer == {
            f"{fm.name}/{i}": int(m.operand_dtype == "int8")
            for i, m in enumerate(plan.metas)
        }

    def test_quant_report_eligibility_matches_plan(self):
        from repro.infer.export import quantization_report
        from repro.infer.plan import compile_plan

        _, _, fm = self._plan_parts()
        plan = compile_plan(fm, backend="reference")
        report = quantization_report(fm)
        got = [l["int8_operand_eligible"] for l in report["layers"]]
        assert got == [m.operand_dtype == "int8" for m in plan.metas]
        assert report["num_int8_operand_eligible"] == sum(got)
