"""Observability-layer tests: telemetry, metrics registry, tracing.

The load-bearing guarantee is the telemetry guard: running
``les.train_step`` with ``telemetry=True`` must produce a
**bitwise-identical** training trajectory to telemetry-off (it is a pure
readout added as an extra jit output) and the telemetry-enabled jaxpr
must stay float-free — asserted here on the paper CNN configs.  The
registry/tracer halves are plain host-side concurrency + serialisation
tests: consistent snapshots under concurrent writers, Prometheus/JSONL
round-trips, span nesting on the monotonic clock.
"""

from __future__ import annotations

import functools
import json
import threading
import urllib.error
import urllib.request

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _gradcheck import assert_bitwise_equal, assert_jaxpr_integer_only
from repro.configs import paper
from repro.core import les
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig
from repro.core.numerics import ACT_MAX, ACT_MIN
from repro.obs import telemetry as T
from repro.obs.metrics import (
    MetricError,
    MetricRegistry,
    latency_summary_ms,
    percentile,
    start_metrics_server,
)
from repro.obs.trace import NULL_TRACER, Tracer
from repro.serving.stats import (
    EngineStats,
    fleet_snapshot_delta,
    snapshot_delta,
)

INT32_MIN = np.iinfo(np.int32).min
INT32_MAX = np.iinfo(np.int32).max


def tiny_cfg():
    return NitroConfig(
        blocks=(BlockSpec("conv", 8, pool=True, d_lr=64),
                BlockSpec("linear", 16)),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        name="tiny-obs",
    )


def _batch(cfg, n, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (n, *cfg.input_shape)), jnp.int32)
    y = jnp.asarray(rng.integers(0, cfg.num_classes, n), jnp.int32)
    return x, y


# ---------------------------------------------------------------------------
# telemetry: integer reductions
# ---------------------------------------------------------------------------


class TestBitWidth:
    @pytest.mark.parametrize("value,bits", [
        (0, 0), (1, 1), (-1, 1), (2, 2), (3, 2), (127, 7), (-127, 7),
        (128, 8), (255, 8), (256, 9), (2**30 - 1, 30), (2**30, 31),
        (INT32_MAX, 31), (INT32_MIN, 32), (INT32_MIN + 1, 31),
    ])
    def test_matches_bit_length(self, value, bits):
        got = int(T.bit_width(jnp.asarray([value], jnp.int32))[0])
        assert got == bits
        if value != INT32_MIN:  # python int has no two's-complement edge
            assert got == abs(value).bit_length()

    def test_random_matches_python_bit_length(self):
        rng = np.random.default_rng(0)
        vals = rng.integers(INT32_MIN, INT32_MAX, 4096, dtype=np.int64,
                            endpoint=True).astype(np.int32)
        got = np.asarray(T.bit_width(jnp.asarray(vals)))
        want = np.array([32 if v == INT32_MIN else int(abs(int(v)).bit_length())
                         for v in vals], np.int32)
        np.testing.assert_array_equal(got, want)

    def test_occupancy_is_a_histogram(self):
        rng = np.random.default_rng(1)
        vals = rng.integers(-10**6, 10**6, (64, 33), dtype=np.int64).astype(np.int32)
        hist = np.asarray(T.bit_occupancy(jnp.asarray(vals)))
        assert hist.shape == (T.NUM_BIT_BUCKETS,)
        assert hist.sum() == vals.size
        bits = np.array([int(abs(int(v)).bit_length()) for v in vals.ravel()])
        np.testing.assert_array_equal(
            hist, np.bincount(bits, minlength=T.NUM_BIT_BUCKETS))

    def test_tensor_telemetry_saturation_and_max(self):
        vals = jnp.asarray([0, 1, -127, 127, 128, -129, 2**30, INT32_MIN],
                           jnp.int32)
        tt = T.tensor_telemetry(vals)
        assert int(tt.bit_hist.sum()) == 8
        # |x| > 127: 128, -129, 2**30, INT32_MIN
        assert int(tt.sat_int8) == 4
        # |x| >= 2**30: 2**30, INT32_MIN
        assert int(tt.sat_int32) == 2
        assert int(tt.max_abs) == INT32_MAX  # INT32_MIN maps to the max mag
        for leaf in tt:
            assert "int" in str(leaf.dtype)

    def test_relu_dead_count(self):
        z = jnp.asarray([ACT_MIN - 1, ACT_MIN, 0, ACT_MAX, ACT_MAX + 1],
                        jnp.int32)
        assert int(T.relu_dead_count(z)) == 2


class TestTelemetryGuard:
    """Telemetry on vs off: bitwise-identical trajectory, float-free."""

    def _run_guard(self, cfg, batch, steps):
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        x, labels = _batch(cfg, batch)
        plain = jax.jit(functools.partial(les.train_step, cfg=cfg))
        instrumented = jax.jit(
            functools.partial(les.train_step, cfg=cfg, telemetry=True))
        s_a = s_b = state
        for i in range(steps):
            key = jax.random.PRNGKey(100 + i)
            s_a, m_a = plain(s_a, x=x, labels=labels, key=key)
            s_b, m_b, telem = instrumented(s_b, x=x, labels=labels, key=key)
        assert_bitwise_equal(s_b, s_a, err_msg=f"telemetry broke {cfg.name}")
        assert_bitwise_equal(m_b, m_a)
        for leaf in jax.tree_util.tree_leaves(telem):
            assert "int" in str(np.asarray(leaf).dtype), "float telemetry leaf"
        return state, x, labels, telem

    def test_tiny_multi_step_bitwise_identical(self):
        self._run_guard(tiny_cfg(), batch=8, steps=3)

    def test_tiny_jaxpr_integer_only(self):
        cfg = tiny_cfg()
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        x, labels = _batch(cfg, 4)
        jaxpr = jax.make_jaxpr(
            functools.partial(les.train_step, cfg=cfg, telemetry=True)
        )(state, x=x, labels=labels, key=jax.random.PRNGKey(1))
        assert_jaxpr_integer_only(jaxpr.jaxpr)

    def test_vgg8b_paper_config(self):
        cfg = paper.get("vgg8b", scale=0.0625)
        state, x, labels, _ = self._run_guard(cfg, batch=4, steps=2)
        jaxpr = jax.make_jaxpr(
            functools.partial(les.train_step, cfg=cfg, telemetry=True)
        )(state, x=x, labels=labels, key=jax.random.PRNGKey(1))
        assert_jaxpr_integer_only(jaxpr.jaxpr)

    @pytest.mark.slow
    def test_vgg11b_paper_config(self):
        cfg = paper.get("vgg11b", scale=0.0625)
        state, x, labels, _ = self._run_guard(cfg, batch=4, steps=2)
        jaxpr = jax.make_jaxpr(
            functools.partial(les.train_step, cfg=cfg, telemetry=True)
        )(state, x=x, labels=labels, key=jax.random.PRNGKey(1))
        assert_jaxpr_integer_only(jaxpr.jaxpr)


class TestRecords:
    def _telem(self, cfg, batch=4):
        state = les.create_train_state(jax.random.PRNGKey(0), cfg)
        x, labels = _batch(cfg, batch)
        step = jax.jit(functools.partial(les.train_step, cfg=cfg,
                                         telemetry=True))
        _, _, telem = step(state, x=x, labels=labels,
                           key=jax.random.PRNGKey(1))
        return telem

    def test_to_records_shape(self):
        cfg = tiny_cfg()
        records = T.to_records(self._telem(cfg), cfg=cfg, step=7)
        layers = [r["layer"] for r in records]
        assert layers == ["block0", "block1", "output", "_opt"]
        for rec in records[:2]:
            assert rec["step"] == 7
            z = rec["z_star"]
            assert sum(z["bit_hist"]) == z["total"]
            assert 0.0 <= rec["dead_frac"] <= 1.0
            assert rec["dead"] == pytest.approx(
                rec["dead_frac"] * z["total"])
            assert z["msb"] <= 32 and z["max_abs"] >= 0
            assert 0.0 <= z["sat_int8_frac"] <= 1.0
            assert rec["alpha_inv"] == cfg.blocks[0].alpha_inv
        assert "grad" in records[2] and "weight" in records[2]
        opt = records[3]
        for k in ("gamma_inv_lr", "eta_inv_lr", "gamma_inv_fw", "eta_inv_fw"):
            assert isinstance(opt[k], int)

    def test_append_jsonl_appends(self, tmp_path):
        cfg = tiny_cfg()
        records = T.to_records(self._telem(cfg), cfg=cfg, step=0)
        path = str(tmp_path / "metrics.jsonl")
        T.append_jsonl(path, records)
        T.append_jsonl(path, records)  # append, not truncate
        with open(path) as f:
            lines = [json.loads(ln) for ln in f]
        assert len(lines) == 2 * len(records)
        assert lines[0]["layer"] == "block0"

    def test_append_jsonl_creates_parent_dir(self, tmp_path):
        # the default telemetry path sits in a ckpt dir that may not
        # exist yet at the first sampled step
        path = str(tmp_path / "ckpts" / "metrics.jsonl")
        T.append_jsonl(path, [{"step": 0}])
        with open(path) as f:
            assert json.loads(f.read()) == {"step": 0}


class TestScaledLoss:
    def test_scaled_loss_units(self):
        from repro.core.losses import ONE_HOT_VALUE
        m = les.StepMetrics(loss=jnp.asarray(2 * ONE_HOT_VALUE ** 2),
                            correct=jnp.asarray(0),
                            local_losses=jnp.zeros(1, jnp.int32))
        assert m.scaled_loss(2) == pytest.approx(1.0)
        assert m.scaled_loss(4) == pytest.approx(0.5)


# ---------------------------------------------------------------------------
# percentile helpers: boundary behaviour (the historical off-by-one)
# ---------------------------------------------------------------------------


class TestPercentileEdges:
    def test_empty_and_single(self):
        assert percentile([], 0.5) == 0.0
        assert percentile([], 1.0) == 0.0
        for q in (0.0, 0.5, 0.99, 1.0):
            assert percentile([42.0], q) == 42.0

    def test_exact_rank_boundaries(self):
        vals = [1.0, 2.0, 3.0, 4.0]
        # q*n integral was the buggy case: floor-rank returned rank+1
        assert percentile(vals, 0.25) == 1.0
        assert percentile(vals, 0.5) == 2.0
        assert percentile(vals, 0.75) == 3.0
        assert percentile(vals, 1.0) == 4.0
        assert percentile(vals, 0.51) == 3.0

    def test_nearest_rank_invariant(self):
        rng = np.random.default_rng(0)
        for n in (1, 2, 3, 5, 10, 100):
            vals = sorted(rng.uniform(0, 1, n).tolist())
            for q in (0.0, 0.01, 0.25, 0.5, 0.9, 0.99, 1.0):
                p = percentile(vals, q)
                assert p in vals
                # nearest-rank definition: the ceil(q·n)-th smallest
                import math
                rank = min(max(math.ceil(q * n), 1), n)
                assert p == vals[rank - 1]

    def test_latency_summary_edge_cases(self):
        assert latency_summary_ms([]) == {
            "p50": 0.0, "p90": 0.0, "p95": 0.0, "p99": 0.0}
        out = latency_summary_ms([0.005])
        assert all(v == pytest.approx(5.0) for v in out.values())
        out = latency_summary_ms([0.002, 0.001])  # unsorted input
        assert out["p50"] == pytest.approx(1.0)
        assert out["p99"] == pytest.approx(2.0)

    def test_snapshot_delta_identity_and_zero(self):
        stats = EngineStats()
        pre = stats.snapshot()
        assert snapshot_delta(pre, pre) == {
            "requests": 0, "batches": 0, "padded_slots": 0,
            "avg_batch_fill": 0.0}
        stats.record_batch(3, 1, 0.01)
        post = stats.snapshot()
        d = snapshot_delta(pre, post)
        assert d["requests"] == 3 and d["batches"] == 1
        assert d["avg_batch_fill"] == pytest.approx(0.75)

    def test_fleet_snapshot_delta_new_model(self):
        empty = {"requests": 0, "batches": 0, "padded_slots": 0,
                 "avg_batch_fill": 0.0}
        pre = {"fleet": empty, "models": {}}
        post = {"fleet": {**empty, "requests": 2, "batches": 1},
                "models": {"late": {**empty, "requests": 2, "batches": 1}}}
        d = fleet_snapshot_delta(pre, post)
        assert d["models"]["late"]["requests"] == 2  # deltaed against zero


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------


class TestMetricRegistry:
    def test_counter_gauge_histogram_basics(self):
        reg = MetricRegistry()
        c = reg.counter("x_total", "a counter")
        c.inc()
        c.inc(4)
        assert c.value == 5
        with pytest.raises(MetricError):
            c.inc(-1)
        g = reg.gauge("depth")
        g.set(7)
        g.inc(2)
        g.dec()
        assert g.value == 8
        h = reg.histogram("lat_seconds", buckets=(0.1, 1.0))
        for v in (0.05, 0.5, 2.0):
            h.observe(v)
        child = h.labels()
        assert child.count == 3
        assert child.sum == pytest.approx(2.55)
        assert child.cumulative_buckets() == [(0.1, 1), (1.0, 2),
                                              (float("inf"), 3)]
        assert child.percentiles()["p50"] == 0.5
        assert "x_total" in reg and "nope" not in reg

    def test_labels_and_conflicts(self):
        reg = MetricRegistry()
        fam = reg.counter("req_total", "by model", labels=("model",))
        fam.labels(model="a").inc(2)
        fam.labels(model="b").inc()
        assert fam.labels(model="a").value == 2
        with pytest.raises(MetricError):
            fam.labels(wrong="a")
        with pytest.raises(MetricError):
            fam.inc()  # label-less proxy on a labelled family
        # identical re-registration is idempotent, conflicts raise
        assert reg.counter("req_total", labels=("model",)) is fam
        with pytest.raises(MetricError):
            reg.gauge("req_total")
        with pytest.raises(MetricError):
            reg.counter("req_total", labels=("other",))
        with pytest.raises(MetricError):
            reg.counter("bad name!")
        with pytest.raises(MetricError):
            reg.histogram("empty_buckets", buckets=())
        reg.histogram("h", buckets=(1.0,), window=8)
        with pytest.raises(MetricError):
            reg.histogram("h", buckets=(2.0,), window=8)

    def test_histogram_window_is_bounded(self):
        reg = MetricRegistry()
        h = reg.histogram("w_seconds", buckets=(1.0,), window=4).labels()
        for i in range(10):
            h.observe(float(i))
        assert list(h.window) == [6.0, 7.0, 8.0, 9.0]
        assert h.count == 10  # cumulative count is not windowed

    def test_prometheus_text_format(self):
        reg = MetricRegistry()
        reg.counter("req_total", "requests", labels=("model",)) \
            .labels(model='a"b\\c\nd').inc(3)
        reg.histogram("lat_seconds", "latency", buckets=(0.5,)).observe(0.1)
        text = reg.prometheus_text()
        assert "# HELP req_total requests" in text
        assert "# TYPE req_total counter" in text
        assert r'req_total{model="a\"b\\c\nd"} 3' in text
        assert "# TYPE lat_seconds histogram" in text
        assert 'lat_seconds_bucket{le="0.5"} 1' in text
        assert 'lat_seconds_bucket{le="+Inf"} 1' in text
        assert "lat_seconds_sum 0.1" in text
        assert "lat_seconds_count 1" in text
        assert text.endswith("\n")

    def test_jsonl_round_trip(self, tmp_path):
        reg = MetricRegistry()
        reg.counter("a_total", "help a", labels=("m",)).labels(m="x").inc(2)
        reg.gauge("b").set(-3)
        reg.histogram("c_seconds", buckets=(1.0,)).observe(0.5)
        path = str(tmp_path / "metrics.jsonl")
        reg.write_jsonl(path)
        with open(path) as f:
            parsed = MetricRegistry.parse_jsonl(f.read())
        assert parsed == reg.json_snapshot()
        assert parsed["a_total"]["samples"][0] == {
            "labels": {"m": "x"}, "value": 2}
        assert parsed["c_seconds"]["samples"][0]["count"] == 1

    def test_thread_safety_under_concurrent_writers(self):
        reg = MetricRegistry()
        c = reg.counter("n_total")
        h = reg.histogram("h_seconds", buckets=(0.5,), window=100_000)
        n_threads, n_iters = 8, 500

        def writer(tid):
            for i in range(n_iters):
                c.inc()
                h.observe(0.25)

        threads = [threading.Thread(target=writer, args=(t,))
                   for t in range(n_threads)]
        for t in threads:
            t.start()
        # concurrent readers must always see a parseable exposition
        for _ in range(20):
            assert "n_total" in reg.prometheus_text()
            reg.json_snapshot()
        for t in threads:
            t.join()
        assert c.value == n_threads * n_iters
        assert h.labels().count == n_threads * n_iters
        assert h.labels().cumulative_buckets()[0][1] == n_threads * n_iters


class TestEngineStatsShared:
    def test_labels_require_registry(self):
        with pytest.raises(ValueError):
            EngineStats(labels={"model": "a"})

    def test_shared_registry_children(self):
        reg = MetricRegistry()
        a = EngineStats(registry=reg, labels={"model": "a"})
        b = EngineStats(registry=reg, labels={"model": "b"})
        a.record_batch(3, 1, 0.010)
        b.record_batch(2, 2, 0.020)
        assert a.requests == 3 and b.requests == 2
        assert a.avg_batch_fill == pytest.approx(0.75)
        assert list(a.batch_latency_s) == [0.010]
        text = reg.prometheus_text()
        assert 'serve_requests_total{model="a"} 3' in text
        assert 'serve_requests_total{model="b"} 2' in text
        snap = a.snapshot()
        assert snap["batches"] == 1
        assert snap["batch_latency_ms"]["p50"] == pytest.approx(10.0)


class TestMetricsServer:
    def test_http_exposition(self):
        reg = MetricRegistry()
        reg.counter("hits_total").inc(5)
        with start_metrics_server(reg, port=0) as server:
            assert server.port != 0
            text = urllib.request.urlopen(server.url, timeout=5).read().decode()
            assert "hits_total 5" in text
            js = urllib.request.urlopen(
                server.url + ".json", timeout=5).read().decode()
            assert json.loads(js)["hits_total"]["samples"][0]["value"] == 5
            with pytest.raises(urllib.error.HTTPError):
                urllib.request.urlopen(
                    f"http://{server.host}:{server.port}/nope", timeout=5)

    def test_scrape_sees_live_updates(self):
        reg = MetricRegistry()
        c = reg.counter("live_total")
        with start_metrics_server(reg) as server:
            for want in (1, 2):
                c.inc()
                text = urllib.request.urlopen(server.url,
                                              timeout=5).read().decode()
                assert f"live_total {want}" in text


# ---------------------------------------------------------------------------
# tracer
# ---------------------------------------------------------------------------


class TestTracer:
    def test_nesting_and_monotonic_clock(self):
        tr = Tracer()
        with tr.span("outer", phase="a") as outer_id:
            with tr.span("inner") as inner_id:
                pass
        spans = {s.name: s for s in tr.snapshot()}
        assert spans["inner"].parent_id == outer_id
        assert spans["outer"].parent_id is None
        assert spans["inner"].span_id == inner_id
        assert spans["outer"].attrs == {"phase": "a"}
        for s in spans.values():
            assert s.t_end_ns >= s.t_start_ns >= 0
        # inner nests strictly inside outer on the same clock
        assert spans["outer"].t_start_ns <= spans["inner"].t_start_ns
        assert spans["inner"].t_end_ns <= spans["outer"].t_end_ns
        assert tr.recorded == 2

    def test_span_recorded_on_exception(self):
        tr = Tracer()
        with pytest.raises(RuntimeError):
            with tr.span("failing"):
                raise RuntimeError("boom")
        assert [s.name for s in tr.snapshot()] == ["failing"]
        # the stack unwound: a new span is a root again
        with tr.span("after"):
            pass
        assert tr.snapshot()[-1].parent_id is None

    def test_threads_get_independent_stacks(self):
        tr = Tracer()
        done = threading.Event()

        def worker():
            with tr.span("worker-span"):
                done.wait(5)

        t = threading.Thread(target=worker, name="obs-worker")
        t.start()
        with tr.span("main-span"):
            pass
        done.set()
        t.join()
        spans = {s.name: s for s in tr.snapshot()}
        # neither thread parents the other's span
        assert spans["main-span"].parent_id is None
        assert spans["worker-span"].parent_id is None
        assert spans["worker-span"].thread == "obs-worker"

    def test_capacity_and_event_and_clear(self):
        tr = Tracer(capacity=3)
        for i in range(5):
            tr.event("e", i=i)
        spans = tr.snapshot()
        assert len(spans) == 3 and tr.recorded == 5
        assert [s.attrs["i"] for s in spans] == [2, 3, 4]  # oldest evicted
        tr.clear()
        assert tr.snapshot() == [] and tr.recorded == 5

    def test_export_jsonl_round_trip(self, tmp_path):
        tr = Tracer()
        with tr.span("a"):
            with tr.span("b", n=3):
                pass
        path = str(tmp_path / "trace.jsonl")
        assert tr.export_jsonl(path) == 2
        with open(path) as f:
            rows = [json.loads(ln) for ln in f]
        assert [r["name"] for r in rows] == ["a", "b"]  # start-ordered
        assert rows[1]["parent_id"] == rows[0]["span_id"]
        assert rows[1]["attrs"] == {"n": 3}
        assert rows[0]["duration_ns"] == (
            rows[0]["t_end_ns"] - rows[0]["t_start_ns"])

    def test_profiler_bridge(self):
        tr = Tracer(annotate=True)  # jax.profiler importable in this repo
        with tr.span("annotated"):
            pass
        assert tr.snapshot()[0].name == "annotated"

    def test_null_tracer_surface(self, tmp_path):
        with NULL_TRACER.span("x", a=1) as sid:
            assert sid == 0
        NULL_TRACER.event("y")
        assert NULL_TRACER.snapshot() == []
        NULL_TRACER.clear()
        path = str(tmp_path / "empty.jsonl")
        assert NULL_TRACER.export_jsonl(path) == 0
        with open(path) as f:
            assert f.read() == ""
        assert NULL_TRACER.recorded == 0


# ---------------------------------------------------------------------------
# serving integration: metrics-enabled registry + fleet
# ---------------------------------------------------------------------------


class TestServingMetrics:
    def _frozen(self, cfg, seed=0):
        from repro.infer import freeze
        state = les.create_train_state(jax.random.PRNGKey(seed), cfg)
        return freeze(state, cfg)

    def test_registry_lifecycle_metrics(self):
        from repro.serving import ModelRegistry
        cfg = tiny_cfg()
        reg = MetricRegistry()
        registry = ModelRegistry(metrics=reg)
        registry.register("m", self._frozen(cfg))
        registry.swap("m", self._frozen(cfg, seed=1))
        text = reg.prometheus_text()
        assert 'serve_model_swaps_total{model="m"} 1' in text
        assert 'serve_model_version{model="m"} 1' in text
        assert 'serve_model_events_total{event="register",model="m"} 1' in text
        assert 'serve_model_events_total{event="swap",model="m"} 1' in text
        registry.evict("m")
        assert ('serve_model_events_total{event="evict",model="m"} 1'
                in reg.prometheus_text())

    def test_fleet_queue_depth_and_batch_fill(self):
        from repro.serving import FleetEngine, ModelRegistry
        cfg = tiny_cfg()
        reg = MetricRegistry()
        registry = ModelRegistry(metrics=reg)
        registry.register("m", self._frozen(cfg))
        tracer = Tracer()
        rng = np.random.default_rng(0)
        imgs = [rng.integers(-127, 128, cfg.input_shape).astype(np.int32)
                for _ in range(6)]
        # fleet inherits the registry's metrics without an explicit arg
        with FleetEngine(registry, batch_size=4, tracer=tracer) as engine:
            assert engine.metrics is reg
            engine.classify(imgs, model="m")
        text = reg.prometheus_text()
        assert 'serve_requests_total{model="m"} 6' in text
        assert 'serve_requests_total{model="_fleet"} 6' in text
        assert 'serve_queue_depth{model="m"} 0' in text  # drained
        fill = reg.json_snapshot()["serve_batch_fill"]["samples"][0]
        assert fill["count"] >= 2  # 6 requests through batch_size 4
        names = {s.name for s in tracer.snapshot()}
        assert {"fleet.assemble", "fleet.dispatch",
                "fleet.fetch", "fleet.deliver"} <= names
        models = {s.attrs.get("model") for s in tracer.snapshot()}
        assert models == {"m"}


# ---------------------------------------------------------------------------
# CLI integration (slow: jit-compiles a real plan / training step)
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestCliIntegration:
    def test_serve_cli_metrics_endpoint(self, monkeypatch, capsys, tmp_path):
        from repro.launch import serve_vision
        trace_path = str(tmp_path / "serve_trace.jsonl")
        monkeypatch.setattr("sys.argv", [
            "serve_vision", "--train-steps", "0", "--scale", "0.0625",
            "--backend", "reference", "--requests", "12", "--batch", "4",
            "--metrics-port", "0", "--trace-out", trace_path,
        ])
        serve_vision.main()
        out = capsys.readouterr().out
        # the CLI scraped its own /metrics endpoint over HTTP
        assert "[metrics] Prometheus text at http://127.0.0.1:" in out
        assert "[metrics] scraped" in out
        assert "serve_requests_total" in out
        assert "serve_queue_depth" in out
        with open(trace_path) as f:
            rows = [json.loads(ln) for ln in f]
        assert any(r["name"] == "fleet.dispatch" for r in rows)

    def test_train_cli_telemetry_jsonl(self, tmp_path):
        from repro.launch.train import train_nitro
        telem_path = str(tmp_path / "metrics.jsonl")
        trace_path = str(tmp_path / "trace.jsonl")
        result = train_nitro(
            "vgg8b", steps=4, batch=8, ckpt_dir=None, dataset="tiles32",
            scale=0.0625, telemetry_every=2, telemetry_out=telem_path,
            trace_out=trace_path,
        )
        assert result["steps"] == 4
        assert "scaled_loss" in result
        with open(telem_path) as f:
            rows = [json.loads(ln) for ln in f]
        steps = sorted({r["step"] for r in rows})
        assert steps == [0, 2]  # sampled every 2nd step
        layers = {r["layer"] for r in rows}
        assert "_opt" in layers and "output" in layers
        with open(trace_path) as f:
            names = [json.loads(ln)["name"] for ln in f]
        assert names.count("train.step") == 4
        assert "train.eval" in names


class TestBuildInfoAndHealthz:
    def test_register_build_info_is_idempotent(self):
        from repro.obs.metrics import REPRO_VERSION, register_build_info

        reg = MetricRegistry()
        register_build_info(reg, backend="cpu")
        register_build_info(reg, backend="cpu")  # safe to call again
        info = reg.gauge("repro_build_info",
                         labels=("version", "backend"))
        assert info.labels(version=REPRO_VERSION, backend="cpu").value == 1
        start = reg.gauge("process_start_time_seconds").value
        import time
        assert 0 < start <= time.time()
        text = reg.prometheus_text()
        assert f'repro_build_info{{version="{REPRO_VERSION}"' in text

    def test_healthz_endpoint(self):
        reg = MetricRegistry()
        with start_metrics_server(reg) as server:
            base = f"http://{server.host}:{server.port}"
            with urllib.request.urlopen(f"{base}/healthz") as resp:
                assert resp.status == 200
                assert resp.read() == b"ok\n"
            # and the scrape paths still answer alongside it
            with urllib.request.urlopen(f"{base}/metrics.json") as resp:
                assert resp.status == 200


class TestTracerBind:
    def test_bound_span_is_equivalent_to_span(self):
        tracer = Tracer()
        bound = tracer.bind("hot.path")
        with bound(step=1):
            pass
        with bound():  # empty attrs share one dict, must not leak attrs
            pass
        with tracer.span("hot.path", step=3):
            pass
        spans = tracer.snapshot()
        assert [s.name for s in spans] == ["hot.path"] * 3
        assert spans[0].attrs == {"step": 1}
        assert spans[1].attrs == {}
        assert spans[2].attrs == {"step": 3}

    def test_bound_span_nests_like_span(self):
        tracer = Tracer()
        inner = tracer.bind("inner")
        with tracer.span("outer") as outer_id:
            with inner() as inner_id:
                pass
        by_name = {s.name: s for s in tracer.snapshot()}
        assert by_name["inner"].parent_id == outer_id
        assert by_name["inner"].span_id == inner_id
        assert by_name["outer"].parent_id is None

    def test_null_tracer_bind_is_free(self):
        bound = NULL_TRACER.bind("x")
        with bound(step=1) as span_id:
            assert span_id == 0
        assert NULL_TRACER.snapshot() == []


class TestTrainCliHealth:
    def test_train_cli_metrics_port_and_alerts(self, tmp_path, capsys):
        from repro.launch.train import train_nitro

        alerts_path = str(tmp_path / "alerts.jsonl")
        result = train_nitro(
            "mlp1", steps=4, batch=8, ckpt_dir=None, dataset="tiles32",
            scale=0.05, telemetry_every=2,
            telemetry_out=str(tmp_path / "metrics.jsonl"),
            metrics_port=0, alerts_out=alerts_path,
        )
        assert "health" in result
        assert result["health"]["steps_observed"] == 2  # sampled steps
        assert result["straggler_events"] >= 0
        out = capsys.readouterr().out
        assert "[metrics] serving http://127.0.0.1:" in out
