"""Numerical correctness of the model-zoo building blocks:

 * flash attention (masked / triangle / SWA) vs a naive softmax oracle;
 * decode_attention vs full attention at the last position;
 * RWKV6 chunked GLA vs the naive token-by-token recurrence;
 * RG-LRU associative scan vs a Python loop;
 * prefill→decode consistency (decode after prefill ≡ full forward);
 * M-RoPE vs plain RoPE equivalence on a single position stream.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import rglru as G
from repro.models import rwkv as R
from repro.models.attention import decode_attention, flash_attention
from repro.models.common import apply_mrope, apply_rope


def naive_attention(q, k, v, causal=True, window=None):
    """(B,G,P,S,D) oracle with explicit masks, fp32."""
    b, g, p, s, d = q.shape
    scale = 1.0 / np.sqrt(d)
    s_mat = jnp.einsum("bgpqd,bgkd->bgpqk", q.astype(jnp.float32) * scale,
                       k.astype(jnp.float32))
    qp = jnp.arange(s)[:, None]
    kp = jnp.arange(k.shape[2])[None, :]
    mask = jnp.ones((s, k.shape[2]), bool)
    if causal:
        mask &= qp >= kp
    if window is not None:
        mask &= (qp - kp) < window
    s_mat = jnp.where(mask, s_mat, -1e30)
    w = jax.nn.softmax(s_mat, axis=-1)
    return jnp.einsum("bgpqk,bgkd->bgpqd", w, v.astype(jnp.float32))


def rand_qkv(seed, b=2, g=2, p=2, s=64, d=8, s_kv=None):
    rng = np.random.default_rng(seed)
    s_kv = s_kv or s
    q = jnp.asarray(rng.normal(0, 1, (b, g, p, s, d)), jnp.float32)
    k = jnp.asarray(rng.normal(0, 1, (b, g, s_kv, d)), jnp.float32)
    v = jnp.asarray(rng.normal(0, 1, (b, g, s_kv, d)), jnp.float32)
    return q, k, v


class TestFlashAttention:
    @pytest.mark.parametrize("blocks", [(16, 16), (32, 64), (64, 32)])
    def test_masked_matches_naive(self, blocks):
        q, k, v = rand_qkv(0)
        got = flash_attention(q, k, v, causal=True, q_block=blocks[0], kv_block=blocks[1], compute_dtype="f32")
        want = naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_triangle_matches_naive(self):
        q, k, v = rand_qkv(1)
        got = flash_attention(
            q, k, v, causal=True, q_block=16, kv_block=16,
            causal_mode="triangle", compute_dtype="f32",
        )
        want = naive_attention(q, k, v, causal=True)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    @pytest.mark.parametrize("window", [16, 24, 64])
    def test_sliding_window_matches_naive(self, window):
        q, k, v = rand_qkv(2)
        got = flash_attention(q, k, v, causal=True, window=window, q_block=16, kv_block=16, compute_dtype="f32")
        want = naive_attention(q, k, v, causal=True, window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_non_causal_cross_shape(self):
        q, k, v = rand_qkv(3, s=32, s_kv=48)
        got = flash_attention(q, k, v, causal=False, q_block=16, kv_block=16, compute_dtype="f32")
        want = naive_attention(q, k, v, causal=False)
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-5)

    def test_decode_matches_full_last_position(self):
        q, k, v = rand_qkv(4, s=32)
        t = 31
        full = naive_attention(q, k, v, causal=True)[:, :, :, t]
        # cache layout (B, S, G, D)
        kc = jnp.moveaxis(k, 1, 2)
        vc = jnp.moveaxis(v, 1, 2)
        got = decode_attention(q[:, :, :, t], kc, vc, jnp.asarray(t))
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=3e-2)

    def test_decode_ring_window(self):
        """Ring-buffered SWA cache: only the last `window` positions count."""
        q, k, v = rand_qkv(5, s=32)
        window, t = 8, 31
        full = naive_attention(q, k, v, causal=True, window=window)[:, :, :, t]
        s_cache = window
        slots = (jnp.arange(32) % s_cache)
        kc = jnp.zeros((2, s_cache, 2, 8)).at[:, slots[-s_cache:]].set(
            jnp.moveaxis(k, 1, 2)[:, -s_cache:]
        )
        vc = jnp.zeros((2, s_cache, 2, 8)).at[:, slots[-s_cache:]].set(
            jnp.moveaxis(v, 1, 2)[:, -s_cache:]
        )
        got = decode_attention(q[:, :, :, t], kc, vc, jnp.asarray(t), window=window)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), atol=3e-2)


class TestFlashBackward:
    @pytest.mark.parametrize("mode,window", [
        ("masked", None), ("triangle", None), ("masked", 24),
    ])
    def test_custom_vjp_matches_autodiff_of_naive(self, mode, window):
        """The FlashAttention-2 backward must equal jax.grad of the naive
        softmax attention (fp32 compute for exactness)."""
        q, k, v = rand_qkv(7, b=1, g=2, p=2, s=48, d=8)

        def f_flash(q, k, v):
            return jnp.sum(flash_attention(
                q, k, v, causal=True, window=window, q_block=16, kv_block=16,
                causal_mode=mode, compute_dtype="f32",
            ) ** 2)

        def f_naive(q, k, v):
            return jnp.sum(naive_attention(q, k, v, causal=True, window=window) ** 2)

        g_flash = jax.grad(f_flash, argnums=(0, 1, 2))(q, k, v)
        g_naive = jax.grad(f_naive, argnums=(0, 1, 2))(q, k, v)
        for a, b in zip(g_flash, g_naive):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b), rtol=2e-4, atol=2e-4
            )

    def test_bf16_compute_close_to_f32(self):
        q, k, v = rand_qkv(8, s=32)
        a = flash_attention(q, k, v, causal=True, compute_dtype="bf16")
        b = flash_attention(q, k, v, causal=True, compute_dtype="f32")
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=5e-2)


class TestRwkvChunked:
    def _naive(self, r, k, v, w, u):
        """Token-by-token oracle: out_t = rᵀ(S_{t-1} + diag(u) k vᵀ)."""
        b, h, s, d = r.shape
        S = np.zeros((b, h, d, d))
        outs = []
        for t in range(s):
            kv = np.einsum("bhd,bhv->bhdv", k[:, :, t], v[:, :, t])
            outs.append(np.einsum("bhd,bhdv->bhv", r[:, :, t], S + u[..., None] * kv))
            S = S * w[:, :, t][..., None] + kv
        return np.stack(outs, axis=2), S

    @pytest.mark.parametrize("s", [8, 64, 128])
    def test_chunked_matches_naive(self, s):
        rng = np.random.default_rng(0)
        b, h, d = 2, 3, 8
        r = rng.normal(0, 1, (b, h, s, d))
        k = rng.normal(0, 1, (b, h, s, d))
        v = rng.normal(0, 1, (b, h, s, d))
        lw = -np.exp(rng.normal(-2, 0.5, (b, h, s, d)))  # log w ∈ (-, 0)
        u = rng.normal(0, 0.5, (1, h, 1, d))

        want, s_want = self._naive(r, k, v, np.exp(lw), u[:, :, 0])

        n_chunks = max(s // R.CHUNK, 1)
        ck = s // n_chunks
        args = tuple(
            jnp.asarray(t.reshape(b, h, n_chunks, ck, d).transpose(2, 0, 1, 3, 4))
            for t in (r, k, v, lw)
        )
        s_fin, outs = jax.lax.scan(
            lambda c, xs: R._wkv_chunk(c, xs, jnp.asarray(u)),
            jnp.zeros((b, h, d, d)), args,
        )
        got = np.asarray(outs.transpose(1, 2, 0, 3, 4).reshape(b, h, s, d))
        np.testing.assert_allclose(got, want, rtol=2e-4, atol=2e-4)
        np.testing.assert_allclose(np.asarray(s_fin), s_want, rtol=2e-4, atol=2e-4)

    def test_decode_step_matches_train_forward(self):
        """rwkv_layer decode over tokens 1-by-1 ≡ full-sequence forward."""
        from repro.configs import get_smoke_config

        cfg = get_smoke_config("rwkv6-3b")
        p = R.init_rwkv_layer(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(1)
        b, s = 2, 12
        x = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)

        full, _ = R.rwkv_layer(p, cfg, x, R.init_rwkv_state(cfg, b))

        st = R.init_rwkv_state(cfg, b)
        st = st._replace(
            x_prev_tm=st.x_prev_tm.astype(jnp.float32),
            x_prev_cm=st.x_prev_cm.astype(jnp.float32),
        )
        outs = []
        for t in range(s):
            o, st = R.rwkv_layer(p, cfg, x[:, t], st, decode=True)
            outs.append(o)
        got = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(full), rtol=5e-3, atol=5e-3
        )


class TestRglru:
    def test_assoc_scan_matches_loop(self):
        rng = np.random.default_rng(0)
        b, s, w = 2, 16, 8
        from repro.models.config import ModelConfig

        cfg = ModelConfig(
            name="t", family="hybrid", num_layers=1, d_model=16, num_heads=2,
            num_kv_heads=1, d_ff=32, vocab_size=64, lru_width=w, remat=False,
        )
        # use a tiny block count compatible with w
        p = {
            "gate_x": jnp.asarray(rng.normal(0, 0.5, (G.N_GATE_BLOCKS, w // G.N_GATE_BLOCKS, w // G.N_GATE_BLOCKS))
                                  if w % G.N_GATE_BLOCKS == 0 else rng.normal(0, 0.5, (1, w, w))),
            "gate_a": jnp.asarray(rng.normal(0, 0.5, (1, w, w))),
            "lam": jnp.asarray(rng.normal(1, 0.2, (w,))),
        }
        p["gate_x"] = jnp.asarray(rng.normal(0, 0.5, (1, w, w)))
        x = jnp.asarray(rng.normal(0, 1, (b, s, w)), jnp.float32)
        h0 = jnp.asarray(rng.normal(0, 1, (b, w)), jnp.float32)

        h_scan, h_last = G.rglru_scan(p, x, h0)

        a, bb = G._gates(p, x)
        h = np.asarray(h0)
        outs = []
        for t in range(s):
            h = np.asarray(a[:, t]) * h + np.asarray(bb[:, t])
            outs.append(h.copy())
        want = np.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(h_scan), want, rtol=1e-5, atol=1e-5)
        np.testing.assert_allclose(np.asarray(h_last), want[:, -1], rtol=1e-5, atol=1e-5)

    def test_decode_matches_scan(self):
        from repro.configs import get_smoke_config

        cfg = get_smoke_config("recurrentgemma-9b")
        key = jax.random.PRNGKey(0)
        p = G.init_rglru_layer(key, cfg)
        rng = np.random.default_rng(2)
        b, s = 2, 6
        x = jnp.asarray(rng.normal(0, 1, (b, s, cfg.d_model)), jnp.float32)
        full, _ = G.rglru_block(p, cfg, x, G.init_rglru_state(cfg, b))
        st = G.init_rglru_state(cfg, b)
        st = st._replace(conv=st.conv.astype(jnp.float32))
        outs = []
        for t in range(s):
            o, st = G.rglru_block(p, cfg, x[:, t], st, decode=True)
            outs.append(o)
        got = jnp.stack(outs, axis=1)
        np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=5e-3, atol=5e-3)


class TestRope:
    def test_mrope_on_single_stream_equals_rope(self):
        """With t=h=w position streams equal, M-RoPE ≡ RoPE."""
        rng = np.random.default_rng(0)
        b, s, h, d = 2, 16, 4, 16
        x = jnp.asarray(rng.normal(0, 1, (b, s, h, d)), jnp.float32)
        pos = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        pos3 = jnp.broadcast_to(pos[None], (3, b, s))
        a = apply_rope(x, pos, 10_000.0)
        bb = apply_mrope(x, pos3, 10_000.0, (2, 3, 3))
        np.testing.assert_allclose(np.asarray(a), np.asarray(bb), atol=1e-5)

    def test_rope_preserves_norm(self):
        rng = np.random.default_rng(1)
        x = jnp.asarray(rng.normal(0, 1, (1, 8, 2, 16)), jnp.float32)
        pos = jnp.arange(8)[None]
        y = apply_rope(x, pos, 10_000.0)
        np.testing.assert_allclose(
            np.linalg.norm(np.asarray(x), axis=-1),
            np.linalg.norm(np.asarray(y), axis=-1),
            rtol=1e-5,
        )


class TestPrefillDecodeConsistency:
    @pytest.mark.parametrize("arch", ["llama3.2-1b", "h2o-danube-1.8b"])
    def test_decode_continues_prefill(self, arch):
        """logits(decode step s | prefill[0:s]) ≡ logits(full forward)[s]."""
        from repro.configs import get_smoke_config
        from repro.models import lm
        from repro.models import transformer as T
        from dataclasses import replace

        cfg = replace(get_smoke_config(arch), dtype=jnp.float32)
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(3)
        b, s = 2, 16
        tokens = jnp.asarray(rng.integers(0, cfg.vocab_size, (b, s + 1)), jnp.int32)

        # full forward over s+1 tokens
        x = lm._embed(params, cfg, tokens)
        pos = lm._positions(cfg, b, s + 1)
        h, _, _ = lm.run_stack(params, cfg, x, pos)
        h = T.rms_norm(h, params["final_ln"])
        want_prefill = lm._logits(params, cfg, h[:, s - 1, :])  # after 0..s-1
        want_decode = lm._logits(params, cfg, h[:, s, :])       # after 0..s

        cache = T.init_cache(cfg, batch=b, max_seq=32)
        got_prefill, cache = lm.prefill(params, cfg, {"tokens": tokens[:, :s]}, cache)
        np.testing.assert_allclose(
            np.asarray(got_prefill), np.asarray(want_prefill), rtol=2e-3, atol=2e-3
        )
        # decode consumes token s at position t=s using the prefilled cache
        # (bf16 matmul inputs in decode_attention → loose-ish tolerance)
        got_decode, _ = lm.decode_step(params, cfg, tokens[:, s], cache)
        np.testing.assert_allclose(
            np.asarray(got_decode), np.asarray(want_decode), rtol=2e-2, atol=2e-2
        )
