"""Test-suite bootstrap.

Two jobs:

  1. make the property tests collect everywhere: when the real
     ``hypothesis`` package is unavailable (this container has no network
     access to install it) the deterministic fallback in
     ``tests/_compat/hypothesis`` is put on ``sys.path`` — same decorator
     API, boundary-biased pseudo-random example generation, no shrinking;
  2. register the ``slow`` marker so long-running integration tests (the
     serving engine end-to-end) can be excluded from quick CI runs with
     ``-m "not slow"`` (see tools/ci_check.sh) while still running under
     the full tier-1 command.
"""

from __future__ import annotations

import importlib.util
import os
import sys

if importlib.util.find_spec("hypothesis") is None:
    sys.path.insert(0, os.path.join(os.path.dirname(__file__), "_compat"))


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: long-running integration test; excluded by tools/ci_check.sh "
        "quick runs via -m 'not slow'",
    )
