"""repro.infer: export round-trip, fused-plan bit-exactness, engine e2e.

The acceptance bar: the fused inference plan (Pallas kernel in interpret
mode off-TPU) must be *bit-exact* with the training-time
``model.frozen_forward`` on identical frozen params — swept over the paper
CNN configs — and the VisionEngine must serve a concurrent workload
end-to-end with identical predictions.
"""

import tempfile
import threading

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import paper
from repro.core import activations, layers, les, scaling
from repro.core import model as M
from repro.infer import compile_plan, freeze, load_frozen, save_frozen
from repro.infer.plan import _relu_fits_int8
from repro.kernels.nitro_matmul.nitro_matmul import nitro_matmul


def _trained_ish_state(cfg, seed=0):
    """Random-init state (init draws from the trained weight range)."""
    return les.create_train_state(jax.random.PRNGKey(seed), cfg)


# ---------------------------------------------------------------------------
# Satellite: fused kernel vs the *unfused layer composition* from core
# ---------------------------------------------------------------------------


class TestFusedVsUnfusedLayers:
    @pytest.mark.parametrize("m,k_dim,n", [
        (32, 64, 16),     # tile-aligned-ish
        (33, 257, 65),    # non-tile-multiple everything
        (1, 7, 3),        # degenerate small
        (130, 100, 90),   # just past one tile
    ])
    def test_linear_pipeline_parity(self, m, k_dim, n):
        """nitro_matmul(interpret) ≡ linear_forward → scale → NITRO-ReLU."""
        rng = np.random.default_rng(m + k_dim + n)
        x = jnp.asarray(rng.integers(-127, 128, (m, k_dim)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (k_dim, n)), jnp.int32)
        sf = scaling.linear_scale_factor(k_dim)
        got = nitro_matmul(x, w, sf=sf, interpret=True, bm=32, bn=32, bk=32)
        z, _ = layers.linear_forward({"w": w}, x)
        want = activations.nitro_relu(scaling.scale_forward(z, sf))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    @pytest.mark.parametrize("h,w_sp,c,f,ksz", [
        (6, 6, 3, 8, 3),      # small odd spatial
        (5, 7, 2, 4, 3),      # non-square, non-tile
        (8, 8, 4, 8, 5),      # 5×5 kernel
        (3, 3, 1, 2, 1),      # 1×1 conv
    ])
    def test_conv_pipeline_parity(self, h, w_sp, c, f, ksz):
        """im2col + fused kernel ≡ conv_forward → scale → NITRO-ReLU."""
        rng = np.random.default_rng(h * 100 + w_sp * 10 + c + f + ksz)
        x = jnp.asarray(rng.integers(-127, 128, (2, h, w_sp, c)), jnp.int32)
        wk = jnp.asarray(rng.integers(-80, 81, (ksz, ksz, c, f)), jnp.int32)
        sf = scaling.conv_scale_factor(ksz, c)
        patches = layers.im2col(x, ksz, ksz // 2).reshape(-1, ksz * ksz * c)
        got = nitro_matmul(
            patches, wk.reshape(-1, f), sf=sf, interpret=True,
            bm=32, bn=32, bk=32,
        ).reshape(2, h, w_sp, f)
        z, _ = layers.conv_forward({"w": wk}, x)
        want = activations.nitro_relu(scaling.scale_forward(z, sf))
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_int8_activation_narrowing_is_lossless(self):
        """The plan's int8 inter-layer dtype only triggers when the
        NITRO-ReLU output range provably fits."""
        assert _relu_fits_int8(10) and _relu_fits_int8(3)
        assert not _relu_fits_int8(1)  # range [-126, 128] — must stay int32


# ---------------------------------------------------------------------------
# Tentpole: frozen export round-trip + plan bit-exactness on paper configs
# ---------------------------------------------------------------------------


class TestFrozenExport:
    def test_freeze_drops_learning_layers_and_narrows(self):
        cfg = paper.get("vgg8b", scale=0.0625)
        state = _trained_ish_state(cfg)
        fm = freeze(state, cfg)
        # blocks + output layer, nothing else
        assert len(fm.layers) == cfg.num_blocks + 1
        assert fm.layers[-1].kind == "output"
        assert not fm.layers[-1].apply_relu
        # every weight kept losslessly in a narrowed dtype
        for layer, p in zip(fm.layers[:-1], state.params["blocks"]):
            np.testing.assert_array_equal(
                np.asarray(layer.w, dtype=np.int64),
                np.asarray(p["fw"]["w"], dtype=np.int64),
            )
            assert layer.w.dtype in (jnp.int8, jnp.int16, jnp.int32)
        # frozen artifact is far smaller than the train-state weights
        train_bytes = sum(
            int(p.size) * 4 for p in jax.tree_util.tree_leaves(state.params)
        )
        assert fm.num_bytes() < train_bytes // 2

    def test_save_load_roundtrip_exact(self):
        cfg = paper.get("vgg8b", scale=0.0625)
        fm = freeze(_trained_ish_state(cfg), cfg)
        with tempfile.TemporaryDirectory() as d:
            save_frozen(d, fm)
            fm2 = load_frozen(d)
        assert fm2.input_shape == fm.input_shape
        assert fm2.num_classes == fm.num_classes
        for a, b in zip(fm.layers, fm2.layers):
            assert (a.kind, a.sf, a.alpha_inv, a.apply_relu, a.pool) == \
                   (b.kind, b.sf, b.alpha_inv, b.apply_relu, b.pool)
            assert a.w.dtype == b.w.dtype
            np.testing.assert_array_equal(np.asarray(a.w), np.asarray(b.w))

    def test_load_rejects_non_frozen_checkpoint(self):
        from repro.train import checkpoint as ckpt

        with tempfile.TemporaryDirectory() as d:
            ckpt.save(d, 0, {"w": jnp.zeros((3,), jnp.int32)})
            with pytest.raises(ValueError, match="not a frozen"):
                load_frozen(d)


class TestQuantizationReport:
    """Satellite: per-layer bit-width/histogram report (paper §4.4)."""

    def test_report_structure_and_counts(self):
        import json

        from repro.infer import quantization_report

        cfg = paper.get("vgg8b", scale=0.0625)
        fm = freeze(_trained_ish_state(cfg), cfg)
        report = quantization_report(fm)
        assert report["format"] == "nitro-quant-report-v1"
        assert report["num_layers"] == len(fm.layers)
        json.dumps(report)  # must be a pure-JSON artifact
        for row, layer in zip(report["layers"], fm.layers):
            w = np.asarray(layer.w, dtype=np.int64)
            assert row["min"] == int(w.min()) and row["max"] == int(w.max())
            # histogram covers every weight exactly once
            assert sum(row["magnitude_histogram"].values()) == w.size
            # declared bit-width actually holds the observed range...
            lo, hi = -(2 ** (row["bit_width"] - 1)), 2 ** (row["bit_width"] - 1) - 1
            assert lo <= row["min"] and row["max"] <= hi
            # ...and fits inside the narrowed storage dtype
            assert row["bit_width"] <= row["dtype_bits"]

    def test_report_bit_width_is_tight(self):
        from repro.infer.export import FrozenLayer, FrozenModel, quantization_report

        w = jnp.asarray([[-5, 3], [7, 0]], jnp.int8)  # range needs 4 bits
        fm = FrozenModel(
            layers=(FrozenLayer("linear", w, sf=512, alpha_inv=10,
                                apply_relu=True, pool=False),),
            input_shape=(2,), num_classes=2, name="stub",
        )
        row = quantization_report(fm)["layers"][0]
        assert row["bit_width"] == 4
        assert row["magnitude_histogram"] == {"0": 1, "2": 1, "3": 2}
        assert row["zero_fraction"] == 0.25

    def test_save_frozen_writes_report(self):
        import json
        import os

        cfg = paper.get("vgg8b", scale=0.0625)
        fm = freeze(_trained_ish_state(cfg), cfg)
        with tempfile.TemporaryDirectory() as d:
            step_dir = save_frozen(d, fm)
            report_path = os.path.join(step_dir, "QUANT_REPORT.json")
            assert os.path.exists(report_path)
            with open(report_path) as f:
                report = json.load(f)
            assert report["num_layers"] == len(fm.layers)
            # the report rides along without breaking the load path
            fm2 = load_frozen(d)
            assert len(fm2.layers) == len(fm.layers)


class TestPlanBitExactness:
    @pytest.mark.parametrize("arch", ["vgg8b", "vgg11b"])
    @pytest.mark.parametrize("backend", ["reference", "interpret"])
    def test_plan_matches_frozen_forward(self, arch, backend):
        """Acceptance criterion: fused plan ≡ M.forward(train=False) logits
        on identical frozen params for the paper CNN configs."""
        cfg = paper.get(arch, scale=0.0625)
        state = _trained_ish_state(cfg, seed=7)
        rng = np.random.default_rng(11)
        x = jnp.asarray(
            rng.integers(-127, 128, (4, *cfg.input_shape)), jnp.int32
        )
        want = M.frozen_forward(state.params, cfg, x)
        plan = compile_plan(freeze(state, cfg), backend=backend)
        got = plan.logits(x)
        assert got.dtype == want.dtype
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_plan_matches_on_mlp(self):
        """Linear-only paper config goes through the same fused path."""
        cfg = paper.get("mlp1", scale=0.25)
        state = _trained_ish_state(cfg, seed=3)
        rng = np.random.default_rng(5)
        x = jnp.asarray(rng.integers(-127, 128, (8, 784)), jnp.int32)
        want = M.frozen_forward(state.params, cfg, x)
        got = compile_plan(freeze(state, cfg), backend="reference").logits(x)
        np.testing.assert_array_equal(np.asarray(got), np.asarray(want))

    def test_predict_consistency_across_batch_shapes(self):
        """jit per-batch-shape caching returns identical rows."""
        cfg = paper.get("vgg8b", scale=0.0625)
        state = _trained_ish_state(cfg)
        plan = compile_plan(freeze(state, cfg), backend="reference")
        rng = np.random.default_rng(0)
        x = jnp.asarray(
            rng.integers(-127, 128, (8, *cfg.input_shape)), jnp.int32
        )
        full = np.asarray(plan.logits(x))
        half = np.asarray(plan.logits(x[:3]))
        np.testing.assert_array_equal(half, full[:3])


# ---------------------------------------------------------------------------
# Engine integration (excluded from quick CI via the slow marker)
# ---------------------------------------------------------------------------


@pytest.mark.slow
class TestVisionEngineIntegration:
    def test_concurrent_clients_bit_exact_and_stats(self):
        from repro.serving.vision import VisionEngine

        cfg = paper.get("vgg8b", scale=0.0625)
        state = _trained_ish_state(cfg, seed=2)
        plan = compile_plan(freeze(state, cfg), backend="reference")
        rng = np.random.default_rng(9)
        images = [
            rng.integers(-127, 128, cfg.input_shape).astype(np.int32)
            for _ in range(48)
        ]
        predictions = np.full(len(images), -1, np.int64)

        with VisionEngine(plan, batch_size=16, max_wait_ms=2.0) as engine:
            def client(worker, n_workers=3):
                for i in range(worker, len(images), n_workers):
                    predictions[i] = engine.submit(images[i]).result().label

            threads = [threading.Thread(target=client, args=(w,))
                       for w in range(3)]
            for t in threads:
                t.start()
            for t in threads:
                t.join()
            stats = engine.stats

        want = np.asarray(
            M.predict(state.params, cfg, jnp.asarray(np.stack(images)))
        )
        np.testing.assert_array_equal(predictions, want)
        assert stats.requests == len(images)
        assert stats.batches >= 1

    def test_submit_after_close_raises_and_shape_validated(self):
        from repro.serving.vision import VisionEngine

        cfg = paper.get("vgg8b", scale=0.0625)
        plan = compile_plan(
            freeze(_trained_ish_state(cfg), cfg), backend="reference"
        )
        engine = VisionEngine(plan, batch_size=4, max_wait_ms=1.0)
        with pytest.raises(ValueError, match="shape"):
            engine.submit(np.zeros((8, 8, 3), np.int32))
        engine.close()
        with pytest.raises(RuntimeError, match="closed"):
            engine.submit(np.zeros(cfg.input_shape, np.int32))
