"""Shared gradient-parity harness for the kernel test-suite.

One place for the assertions and fixtures the parity tests used to
duplicate across ``test_kernels.py``, ``test_fused_training.py`` and
``test_conv_stream.py``:

  * ``assert_bitwise_equal`` — pytree-aware *exact* equality, dtype
    included (every kernel claim in this repo is equality, not tolerance);
  * backend fixtures — ``kernel_backend`` sweeps every backend runnable on
    this host (``pallas`` joins the sweep on TPU), ``backend_pair`` yields
    every unordered backend pairing for A-vs-B parity tests;
  * jaxpr helpers — recursive eqn iteration (optionally skipping Pallas
    kernel bodies), aval-shape collection, the integer-only scan, and the
    primitive/shape query the backward structural tests use.

Import what you need directly (the file is underscore-prefixed so pytest
does not collect it):

    from _gradcheck import assert_bitwise_equal, backend_pair  # noqa: F401
"""

from __future__ import annotations

import itertools

import jax
import numpy as np
import pytest

# Backends runnable on this host: the Pallas interpreter and the jnp
# oracle run everywhere; the real kernel joins the sweep on TPU.
AVAILABLE_BACKENDS = ("reference", "interpret") + (
    ("pallas",) if jax.default_backend() == "tpu" else ()
)
BACKEND_PAIRS = tuple(itertools.combinations(AVAILABLE_BACKENDS, 2))


@pytest.fixture(params=AVAILABLE_BACKENDS)
def kernel_backend(request):
    """Every backend the dispatcher can run on this host."""
    return request.param


@pytest.fixture(params=BACKEND_PAIRS, ids=lambda p: f"{p[0]}-vs-{p[1]}")
def backend_pair(request):
    """Every unordered pair of runnable backends, for A-vs-B parity."""
    return request.param


def assert_bitwise_equal(got, want, *, err_msg: str = "") -> None:
    """Exact equality for arrays or pytrees of arrays, dtype included.

    The single parity assertion of the suite: values must match
    bit-for-bit AND carry the same dtype (a silently-widened int8 would
    pass a value-only comparison while breaking the HBM-traffic claim).
    """
    got_leaves, got_tree = jax.tree_util.tree_flatten(got)
    want_leaves, want_tree = jax.tree_util.tree_flatten(want)
    assert got_tree == want_tree, (
        f"pytree structure mismatch: {got_tree} vs {want_tree} {err_msg}"
    )
    for i, (g, w) in enumerate(zip(got_leaves, want_leaves)):
        g, w = np.asarray(g), np.asarray(w)
        assert g.dtype == w.dtype, (
            f"dtype mismatch at leaf {i}: {g.dtype} vs {w.dtype} {err_msg}"
        )
        np.testing.assert_array_equal(g, w, err_msg=f"leaf {i} {err_msg}")


# ---------------------------------------------------------------------------
# jaxpr structure helpers
# ---------------------------------------------------------------------------


def iter_eqns(jaxpr, *, skip_pallas: bool = False):
    """Yield every eqn, descending into sub-jaxprs carried in eqn params
    (pjit, cond, scan — and the Pallas kernel body inside ``pallas_call``
    unless ``skip_pallas``, which the structural tests use to reason about
    what exists *outside* VMEM)."""
    for eqn in jaxpr.eqns:
        yield eqn
        if skip_pallas and eqn.primitive.name == "pallas_call":
            continue
        for param in eqn.params.values():
            items = param if isinstance(param, (tuple, list)) else [param]
            for item in items:
                if isinstance(item, jax.core.ClosedJaxpr):
                    yield from iter_eqns(item.jaxpr, skip_pallas=skip_pallas)
                elif isinstance(item, jax.core.Jaxpr):
                    yield from iter_eqns(item, skip_pallas=skip_pallas)


def collect_aval_shapes(jaxpr, shapes=None, *, skip_pallas: bool = False):
    """Every intermediate aval shape in the program (a set of tuples)."""
    if shapes is None:
        shapes = set()
    for eqn in iter_eqns(jaxpr, skip_pallas=skip_pallas):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "shape"):
                shapes.add(tuple(int(d) for d in aval.shape))
    return shapes


def assert_jaxpr_integer_only(jaxpr) -> None:
    """No float dtype anywhere — descending into Pallas kernel bodies."""
    for eqn in iter_eqns(jaxpr):
        for v in list(eqn.invars) + list(eqn.outvars):
            aval = getattr(v, "aval", None)
            if aval is not None and hasattr(aval, "dtype"):
                assert "float" not in str(aval.dtype), f"float op: {eqn}"


def eqn_output_shapes(jaxpr, prim_names, *, skip_pallas: bool = True):
    """Output shapes of every eqn whose primitive is in ``prim_names``,
    by default looking only *outside* Pallas kernel bodies — i.e. at what
    a program materialises in HBM rather than in VMEM tiles."""
    shapes = []
    for eqn in iter_eqns(jaxpr, skip_pallas=skip_pallas):
        if eqn.primitive.name in prim_names:
            for v in eqn.outvars:
                aval = getattr(v, "aval", None)
                if aval is not None and hasattr(aval, "shape"):
                    shapes.append(tuple(int(d) for d in aval.shape))
    return shapes
