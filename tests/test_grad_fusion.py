"""Fused NITRO-ReLU-backward/STE gradient path: kernel contract + parity.

The tentpole guarantee: folding the NITRO-ReLU derivative and the scaling
STE into the gradient kernels' δ prologue changes *nothing* numerically —
weight gradients, input gradients and post-step parameters are bit-
identical with the unfused jnp composition, on both paper CNN configs,
for every backend runnable on this host and both conv data paths.  On
top of parity, the fused backward is held to its structural property: the
full-size post-ReLU-bwd δ tensor never appears outside a Pallas kernel
body in the traced program, and the whole fused step stays float-free.

All parity assertions go through the shared harness in
``tests/_gradcheck.py``.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _gradcheck import (  # noqa: F401  (fixtures)
    AVAILABLE_BACKENDS,
    assert_bitwise_equal,
    assert_jaxpr_integer_only,
    backend_pair,
    eqn_output_shapes,
    kernel_backend,
)
from repro.configs import paper
from repro.core import blocks as B
from repro.core import les, model as M
from repro.core.activations import nitro_relu_backward
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig
from repro.core.numerics import int_matmul
from repro.kernels import grad_ops
from repro.kernels.nitro_matmul import (
    grad_w_matmul,
    grad_x_matmul,
    nitro_matmul_grad_w,
    nitro_matmul_grad_w_ref,
    nitro_matmul_grad_x,
    nitro_matmul_grad_x_ref,
)
from repro.kernels.nitro_conv import (
    conv_grad_w,
    conv_grad_x,
    stream_conv_grad_w,
    stream_conv_grad_w_ref,
    stream_conv_grad_x,
    stream_conv_grad_x_ref,
)


def _linear_case(b, m, n, seed=0):
    """Random (x, delta, z_star, w) for a linear backward; z* spans all
    four NITRO-ReLU segments (±300 straddles the ±127 saturation)."""
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (b, m)), jnp.int32)
    delta = jnp.asarray(rng.integers(-63, 64, (b, n)), jnp.int32)
    z_star = jnp.asarray(rng.integers(-300, 301, (b, n)), jnp.int32)
    w = jnp.asarray(rng.integers(-40, 41, (m, n)), jnp.int32)
    return x, delta, z_star, w


def _conv_case(n, h, w_sp, c, f, k, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.integers(-127, 128, (n, h, w_sp, c)), jnp.int32)
    delta = jnp.asarray(rng.integers(-63, 64, (n, h, w_sp, f)), jnp.int32)
    z_star = jnp.asarray(rng.integers(-300, 301, (n, h, w_sp, f)), jnp.int32)
    w = jnp.asarray(rng.integers(-40, 41, (k, k, c, f)), jnp.int32)
    return x, delta, z_star, w


# ---------------------------------------------------------------------------
# Kernel-level: the grad-matmul prologue contract
# ---------------------------------------------------------------------------


class TestGradMatmulKernels:
    @pytest.mark.parametrize("b,m,n", [
        (1, 1, 1), (7, 13, 5), (64, 64, 64), (128, 128, 128),
        (33, 257, 65), (130, 100, 90),
    ])
    def test_shape_sweep_matches_ref(self, b, m, n):
        """Fused grad kernels (interpret) ≡ jnp mask + matmul oracles on
        aligned, ragged and degenerate shapes."""
        x, delta, z_star, w = _linear_case(b, m, n, seed=b + m + n)
        gw = nitro_matmul_grad_w(x, delta, z_star, interpret=True,
                                 bm=32, bn=32, bk=32)
        gx = nitro_matmul_grad_x(delta, z_star, w, interpret=True,
                                 bm=32, bn=32, bk=32)
        assert_bitwise_equal(gw, nitro_matmul_grad_w_ref(x, delta, z_star))
        assert_bitwise_equal(gx, nitro_matmul_grad_x_ref(delta, z_star, w))

    @pytest.mark.parametrize("alpha_inv", [1, 3, 10, 100])
    def test_alpha_sweep(self, alpha_inv):
        x, delta, z_star, w = _linear_case(20, 30, 17, seed=alpha_inv)
        gw = nitro_matmul_grad_w(x, delta, z_star, alpha_inv=alpha_inv,
                                 interpret=True, bm=16, bn=16, bk=16)
        gx = nitro_matmul_grad_x(delta, z_star, w, alpha_inv=alpha_inv,
                                 interpret=True, bm=16, bn=16, bk=16)
        assert_bitwise_equal(
            gw, nitro_matmul_grad_w_ref(x, delta, z_star, alpha_inv=alpha_inv)
        )
        assert_bitwise_equal(
            gx, nitro_matmul_grad_x_ref(delta, z_star, w, alpha_inv=alpha_inv)
        )

    @pytest.mark.parametrize("bm,bn,bk", [(8, 8, 8), (16, 32, 64), (128, 128, 128)])
    def test_tile_size_sweep(self, bm, bn, bk):
        """Result must be invariant to BlockSpec tiling — the masked δ
        padding contract (δ = z* = 0 → 0) holds on every grid."""
        x, delta, z_star, w = _linear_case(100, 100, 100, seed=bm + bn)
        gw = nitro_matmul_grad_w(x, delta, z_star, interpret=True,
                                 bm=bm, bn=bn, bk=bk)
        gx = nitro_matmul_grad_x(delta, z_star, w, interpret=True,
                                 bm=bm, bn=bn, bk=bk)
        assert_bitwise_equal(gw, nitro_matmul_grad_w_ref(x, delta, z_star))
        assert_bitwise_equal(gx, nitro_matmul_grad_x_ref(delta, z_star, w))

    def test_ref_oracle_is_the_unfused_composition(self):
        """The ref oracles ARE relu_bwd → STE → plain matmul, pinned here
        so the kernel tests above transitively anchor to core ops."""
        x, delta, z_star, w = _linear_case(9, 11, 7, seed=3)
        g = nitro_relu_backward(z_star, delta, 10)
        assert_bitwise_equal(
            nitro_matmul_grad_w_ref(x, delta, z_star), int_matmul(x.T, g)
        )
        assert_bitwise_equal(
            nitro_matmul_grad_x_ref(delta, z_star, w), int_matmul(g, w.T)
        )

    def test_dispatcher_backends_agree(self, backend_pair):
        a, b = backend_pair
        x, delta, z_star, w = _linear_case(17, 50, 9, seed=5)
        assert_bitwise_equal(
            grad_w_matmul(x, delta, z_star, backend=a),
            grad_w_matmul(x, delta, z_star, backend=b),
            err_msg=f"grad_w {a} vs {b}",
        )
        assert_bitwise_equal(
            grad_x_matmul(delta, z_star, w, backend=a),
            grad_x_matmul(delta, z_star, w, backend=b),
            err_msg=f"grad_x {a} vs {b}",
        )

    def test_alpha_inv_zero_raises(self):
        x, delta, z_star, w = _linear_case(4, 4, 4)
        with pytest.raises(ValueError, match="alpha_inv"):
            grad_w_matmul(x, delta, z_star, alpha_inv=0)
        with pytest.raises(ValueError, match="alpha_inv"):
            grad_x_matmul(delta, z_star, w, alpha_inv=0)


# ---------------------------------------------------------------------------
# Conv kernels: streamed gradients with the δ-band prologue
# ---------------------------------------------------------------------------


class TestConvGradKernels:
    SHAPES = [
        (2, 8, 8, 3, 8),      # even, multi-band
        (1, 5, 7, 2, 4),      # odd H and W
        (2, 7, 5, 3, 8),      # odd the other way
        (2, 9, 9, 2, 130),    # F past one filter tile
    ]

    @pytest.mark.parametrize("k", [3, 5])
    @pytest.mark.parametrize("n,h,w_sp,c,f", SHAPES)
    def test_grad_w_fused_kernel(self, n, h, w_sp, c, f, k):
        x, delta, z_star, _ = _conv_case(n, h, w_sp, c, f, k, seed=h + f)
        got = stream_conv_grad_w(x, delta, kernel_size=k, z_star=z_star,
                                 interpret=True)
        want = stream_conv_grad_w_ref(x, delta, kernel_size=k, z_star=z_star)
        assert_bitwise_equal(got, want)

    @pytest.mark.parametrize("k", [3, 5])
    @pytest.mark.parametrize("n,h,w_sp,c,f", SHAPES)
    def test_grad_x_fused_kernel(self, n, h, w_sp, c, f, k):
        _, delta, z_star, w = _conv_case(n, h, w_sp, c, f, k, seed=h * 2 + f)
        got = stream_conv_grad_x(delta, z_star, w, interpret=True)
        want = stream_conv_grad_x_ref(delta, w, z_star=z_star)
        assert_bitwise_equal(got, want)

    @pytest.mark.parametrize("bh,bf", [(2, 4), (3, 8), (8, 128)])
    def test_tile_size_sweep(self, bh, bf):
        """Band height / filter tiling must not change the masked result."""
        x, delta, z_star, w = _conv_case(2, 7, 6, 3, 12, 3, seed=bh * 10 + bf)
        gw = stream_conv_grad_w(x, delta, kernel_size=3, z_star=z_star,
                                bh=bh, bf=bf, interpret=True)
        gx = stream_conv_grad_x(delta, z_star, w, bh=bh, bf=bf, interpret=True)
        assert_bitwise_equal(
            gw, stream_conv_grad_w_ref(x, delta, kernel_size=3, z_star=z_star)
        )
        assert_bitwise_equal(
            gx, stream_conv_grad_x_ref(delta, w, z_star=z_star)
        )

    def test_masked_oracles_equal_premasked_unfused(self):
        """The band-masked streaming oracles ≡ jnp pre-mask + the historical
        unfused gradient routes (the defining identity of the fusion)."""
        x, delta, z_star, w = _conv_case(2, 6, 5, 3, 4, 3, seed=9)
        g = nitro_relu_backward(z_star, delta, 10)
        assert_bitwise_equal(
            stream_conv_grad_w_ref(x, delta, kernel_size=3, z_star=z_star),
            stream_conv_grad_w_ref(x, g, kernel_size=3),
        )
        assert_bitwise_equal(
            stream_conv_grad_x_ref(delta, w, z_star=z_star),
            stream_conv_grad_x_ref(g, w),
        )

    def test_dispatcher_modes_and_backends_agree(self, backend_pair):
        a, b = backend_pair
        x, delta, z_star, w = _conv_case(2, 6, 6, 3, 8, 3, seed=11)
        for mode in ("stream", "materialise"):
            assert_bitwise_equal(
                conv_grad_w(x, delta, kernel_size=3, z_star=z_star,
                            backend=a, conv_mode=mode),
                conv_grad_w(x, delta, kernel_size=3, z_star=z_star,
                            backend=b, conv_mode=mode),
                err_msg=f"grad_w {mode} {a} vs {b}",
            )
            assert_bitwise_equal(
                conv_grad_x(delta, w, z_star=z_star,
                            backend=a, conv_mode=mode),
                conv_grad_x(delta, w, z_star=z_star,
                            backend=b, conv_mode=mode),
                err_msg=f"grad_x {mode} {a} vs {b}",
            )


# ---------------------------------------------------------------------------
# grad_ops dispatcher: fused ≡ unfused on every route
# ---------------------------------------------------------------------------


class TestGradOpsDispatcher:
    def test_linear_fused_vs_unfused(self, kernel_backend):
        x, delta, z_star, w = _linear_case(12, 40, 24, seed=1)
        fused = grad_ops.linear_grads(
            x, w, delta, z_star=z_star, fuse_bwd=True, backend=kernel_backend
        )
        unfused = grad_ops.linear_grads(
            x, w, delta, z_star=z_star, fuse_bwd=False, backend=kernel_backend
        )
        assert_bitwise_equal(fused, unfused, err_msg=kernel_backend)

    @pytest.mark.parametrize("conv_mode", ["stream", "materialise"])
    def test_conv_fused_vs_unfused(self, kernel_backend, conv_mode):
        x, delta, z_star, w = _conv_case(2, 8, 6, 3, 8, 3, seed=2)
        fused = grad_ops.conv_grads(
            x, w, delta, z_star=z_star, fuse_bwd=True,
            backend=kernel_backend, conv_mode=conv_mode,
        )
        unfused = grad_ops.conv_grads(
            x, w, delta, z_star=z_star, fuse_bwd=False,
            backend=kernel_backend, conv_mode=conv_mode,
        )
        assert_bitwise_equal(fused, unfused,
                             err_msg=f"{kernel_backend}/{conv_mode}")

    def test_no_activation_path_is_plain_matmuls(self):
        """z_star=None (learning/output layers: STE only) must reproduce
        the historical plain integer matmuls exactly."""
        x, delta, _, w = _linear_case(9, 20, 10, seed=4)
        gx, gw = grad_ops.linear_grads(x, w, delta)
        assert_bitwise_equal(gx, int_matmul(delta, w.T))
        assert_bitwise_equal(gw, int_matmul(x.T, delta))


# ---------------------------------------------------------------------------
# Block- and train-step-level parity on the paper configs
# ---------------------------------------------------------------------------


def _block_cases(cfg, batch, seed=7):
    """Forward the paper config once (fused, auto) and yield per-block
    (spec, params, cache, delta) backward inputs with a synthetic δ."""
    state = les.create_train_state(jax.random.PRNGKey(seed), cfg)
    rng = np.random.default_rng(seed + 1)
    x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                    jnp.int32)
    _, acts, caches, _ = M.forward(state.params, cfg, x, train=False)
    for spec, p, a, cache in zip(cfg.blocks, state.params["blocks"], acts,
                                 caches):
        delta = jnp.asarray(rng.integers(-63, 64, a.shape), jnp.int32)
        yield spec, p, cache, delta


class TestForwardLayersBackwardParity:
    @pytest.mark.parametrize("arch", ["vgg8b", "vgg11b"])
    def test_fused_backward_bit_exact_on_paper_cnn(self, arch, kernel_backend):
        """Acceptance criterion: fuse_bwd=True ≡ fuse_bwd=False through
        every block of both paper CNNs, on every runnable backend."""
        cfg = paper.get(arch, scale=0.0625)
        for i, (spec, p, cache, delta) in enumerate(_block_cases(cfg, 2)):
            fused = B.forward_layers_backward(
                p, spec, cache, delta, backend=kernel_backend, fuse_bwd=True
            )
            unfused = B.forward_layers_backward(
                p, spec, cache, delta, backend=kernel_backend, fuse_bwd=False
            )
            assert_bitwise_equal(
                fused, unfused, err_msg=f"{arch} block {i} {kernel_backend}"
            )

    def test_pool_and_dropout_precede_the_fused_prologue(self):
        """Blocks with pool + dropout: the jnp pool/dropout backwards stay
        outside the kernels and compose identically on both δ paths."""
        spec = BlockSpec("conv", 12, pool=True, dropout=0.2, d_lr=128)
        cfg = NitroConfig(blocks=(spec,), input_shape=(8, 8, 3),
                          num_classes=10)
        p = M.init_params(jax.random.PRNGKey(0), cfg)["blocks"][0]
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.integers(-127, 128, (3, 8, 8, 3)), jnp.int32)
        _, cache = B.forward_layers(
            p, spec, x, train=True, dropout_key=jax.random.PRNGKey(5)
        )
        delta = jnp.asarray(rng.integers(-63, 64, (3, 4, 4, 12)), jnp.int32)
        fused = B.forward_layers_backward(p, spec, cache, delta,
                                          fuse_bwd=True)
        unfused = B.forward_layers_backward(p, spec, cache, delta,
                                            fuse_bwd=False)
        assert_bitwise_equal(fused, unfused)


class TestTrainStepBackwardParity:
    @staticmethod
    def _step_args(cfg, batch, seed=4):
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(seed)
        x = jnp.asarray(rng.integers(-127, 128, (batch, *cfg.input_shape)),
                        jnp.int32)
        y = jnp.asarray(rng.integers(0, cfg.num_classes, batch), jnp.int32)
        return st, x, y, jax.random.PRNGKey(9)

    @pytest.mark.parametrize("arch,batch", [("vgg8b", 8), ("vgg11b", 4)])
    def test_fused_bwd_step_bit_exact(self, arch, batch):
        cfg = paper.get(arch, scale=0.0625)
        st, x, y, key = self._step_args(cfg, batch)
        stepped = {
            fb: jax.jit(functools.partial(les.train_step, cfg=cfg,
                                          fuse_bwd=fb))(st, x=x, labels=y,
                                                        key=key)
            for fb in (True, False)
        }
        assert_bitwise_equal(stepped[True][0].params, stepped[False][0].params,
                             err_msg=arch)
        assert int(stepped[True][1].loss) == int(stepped[False][1].loss)
        assert_bitwise_equal(stepped[True][1].local_losses,
                             stepped[False][1].local_losses)

    def test_fused_bwd_step_interpret_backend(self):
        """The actual Pallas grad-kernel bodies, off-TPU, end to end."""
        cfg = paper.get("vgg8b", scale=0.0625)
        st, x, y, key = self._step_args(cfg, 4)
        got = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fuse_bwd=True, backend="interpret"
        ))(st, x=x, labels=y, key=key)
        want = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fuse_bwd=False
        ))(st, x=x, labels=y, key=key)
        assert_bitwise_equal(got[0].params, want[0].params)

    def test_multi_step_training_stays_exact(self):
        """Divergence compounds: several fused-bwd steps ≡ unfused-δ steps."""
        cfg = NitroConfig(
            blocks=(BlockSpec("conv", 16, pool=True, d_lr=256),
                    BlockSpec("linear", 64)),
            input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
            eta_fw=20000, eta_lr=5000,
        )
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-127, 128, (16, 8, 8, 3)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 10, 16), jnp.int32)
        st_f = st_u = les.create_train_state(jax.random.PRNGKey(0), cfg)
        step_f = jax.jit(functools.partial(les.train_step, cfg=cfg,
                                           fuse_bwd=True))
        step_u = jax.jit(functools.partial(les.train_step, cfg=cfg,
                                           fuse_bwd=False))
        for i in range(8):
            k = jax.random.PRNGKey(i)
            st_f, _ = step_f(st_f, x=x, labels=y, key=k)
            st_u, _ = step_u(st_u, x=x, labels=y, key=k)
        assert_bitwise_equal(st_f.params, st_u.params)


# ---------------------------------------------------------------------------
# Structural: the fused backward never materialises the post-ReLU-bwd δ
# ---------------------------------------------------------------------------


# The primitives that betray a jnp nitro_relu_backward at full tensor
# size: the two `jnp.where` selects and the floor-division remainder.
_MASK_PRIMS = ("select_n", "rem")


def _structural_cfg():
    """Conv + linear blocks, no dropout, widths chosen so the z* shapes
    collide with nothing else in the program (dropout's fixed-point
    floor-div would otherwise share the linear z* shape)."""
    return NitroConfig(
        blocks=(BlockSpec("conv", 16, pool=True, d_lr=256),
                BlockSpec("linear", 48)),
        input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
        eta_fw=12000, eta_lr=3000,
    )


def _zstar_shapes(cfg, batch):
    """Full-size z*/post-ReLU-bwd δ shapes of every block."""
    h, w, _ = cfg.input_shape
    conv_spec, linear_spec = cfg.blocks
    return {
        (batch, h, w, conv_spec.out_features),
        (batch, linear_spec.out_features),
    }


class TestBackwardStructure:
    @pytest.mark.parametrize("fuse_bwd,backend", [
        (True, "auto"),        # the default train path
        (True, "interpret"),   # the actual grad-kernel bodies, off-TPU
        (False, "auto"),       # unfused δ escape hatch
    ])
    def test_fused_bwd_step_is_integer_only(self, fuse_bwd, backend):
        """Acceptance criterion: the fused-backward train step is float-free
        end-to-end, descending into every Pallas kernel body."""
        cfg = NitroConfig(
            blocks=(BlockSpec("conv", 16, pool=True, d_lr=256, dropout=0.1),
                    BlockSpec("linear", 64, dropout=0.1)),
            input_shape=(8, 8, 3), num_classes=10, gamma_inv=512,
            eta_fw=12000, eta_lr=3000,
        )
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-127, 128, (8, 8, 8, 3)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 10, 8), jnp.int32)
        jaxpr = jax.make_jaxpr(functools.partial(
            les.train_step, cfg=cfg, fuse_bwd=fuse_bwd, backend=backend
        ))(st, x=x, labels=y, key=jax.random.PRNGKey(1))
        assert_jaxpr_integer_only(jaxpr.jaxpr)

    def test_no_full_size_post_relu_bwd_delta(self):
        """Acceptance criterion: in the fused step, no ReLU-backward op
        (select/rem) produces a full-size z*-shaped tensor anywhere outside
        a Pallas kernel body — the masked δ exists only as VMEM tiles.  The
        unfused step (sanity) does materialise it."""
        cfg = _structural_cfg()
        batch = 6
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-127, 128, (batch, 8, 8, 3)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 10, batch), jnp.int32)
        zstar_shapes = _zstar_shapes(cfg, batch)

        def mask_shapes(fuse_bwd):
            jaxpr = jax.make_jaxpr(functools.partial(
                les.train_step, cfg=cfg, fuse_bwd=fuse_bwd,
                backend="interpret",
            ))(st, x=x, labels=y, key=jax.random.PRNGKey(1))
            return set(
                eqn_output_shapes(jaxpr.jaxpr, _MASK_PRIMS, skip_pallas=True)
            )

        assert not (mask_shapes(True) & zstar_shapes), (
            "fused backward materialised a full-size post-ReLU-bwd δ"
        )
        assert mask_shapes(False) & zstar_shapes, (
            "sanity: the unfused δ path should materialise the masked δ"
        )

    def test_forward_fusion_also_holds(self):
        """The same scan proves the *forward* ReLU stays in-kernel too —
        the fused step has no full-size z*-producing select at all."""
        cfg = _structural_cfg()
        batch = 6
        st = les.create_train_state(jax.random.PRNGKey(0), cfg)
        x = jnp.zeros((batch, 8, 8, 3), jnp.int32)
        y = jnp.zeros((batch,), jnp.int32)
        jaxpr = jax.make_jaxpr(functools.partial(
            les.train_step, cfg=cfg, fused=False, fuse_bwd=True,
            backend="interpret",
        ))(st, x=x, labels=y, key=jax.random.PRNGKey(1))
        # unfused *forward* still materialises z*-shaped selects (sanity
        # that the discriminator sees forward activations as well)
        shapes = set(
            eqn_output_shapes(jaxpr.jaxpr, _MASK_PRIMS, skip_pallas=True)
        )
        assert shapes & _zstar_shapes(cfg, batch)
