"""Fused training forward: kernel contract + bit-exactness vs the unfused path.

The tentpole guarantee: routing ``forward_layers`` / ``train_step`` through
the fused ``nitro_matmul`` entry point changes *nothing* numerically — the
activation ``a``, the cached pre-ReLU ``z_star``, and the post-step
parameters are all bit-identical with the unfused matmul → NITRO Scaling →
NITRO-ReLU reference composition, on the paper CNN configs, for every
backend the dispatcher can select off-TPU.
"""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _gradcheck import assert_bitwise_equal
from repro.configs import paper
from repro.core import blocks as B
from repro.core import les, model as M
from repro.core.blocks import BlockSpec
from repro.core.model import NitroConfig
from repro.core.scaling import linear_scale_factor
from repro.kernels.nitro_matmul import (
    fused_matmul_fwd,
    nitro_matmul_fwd,
    nitro_matmul_fwd_ref,
    resolve_backend,
)


def _state(cfg, seed=0):
    return les.create_train_state(jax.random.PRNGKey(seed), cfg)


def tiny_cnn_cfg(**kw):
    return NitroConfig(
        blocks=(
            BlockSpec("conv", 16, pool=True, d_lr=256),
            BlockSpec("linear", 64),
        ),
        input_shape=(8, 8, 3),
        num_classes=10,
        gamma_inv=512,
        **kw,
    )


# ---------------------------------------------------------------------------
# Kernel-level: the (a, z_star) fused-forward contract
# ---------------------------------------------------------------------------


class TestFusedForwardKernel:
    @pytest.mark.parametrize("m,k_dim,n", [
        (32, 64, 16),     # tile-aligned-ish
        (33, 257, 65),    # non-tile-multiple everything
        (1, 7, 3),        # degenerate small
        (130, 100, 90),   # just past one tile
    ])
    def test_fwd_kernel_matches_ref(self, m, k_dim, n):
        """nitro_matmul_fwd(interpret) ≡ (nitro_relu(z*), z*) from the refs."""
        rng = np.random.default_rng(m + k_dim + n)
        x = jnp.asarray(rng.integers(-127, 128, (m, k_dim)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (k_dim, n)), jnp.int32)
        sf = linear_scale_factor(k_dim)
        a_k, z_k = nitro_matmul_fwd(
            x, w, sf=sf, interpret=True, bm=32, bn=32, bk=32
        )
        a_r, z_r = nitro_matmul_fwd_ref(x, w, sf=sf)
        # dtype equality included: z_star must keep the int32 dtype
        # scale_forward produces — it is cached for the ReLU/STE backward.
        assert_bitwise_equal((a_k, z_k), (a_r, z_r))
        assert z_k.dtype == jnp.int32

    def test_kernels_first_import_order(self):
        """``import repro.kernels.nitro_matmul`` as a process's first repro
        import must not be circular (core.blocks lazy-imports the kernel
        dispatcher precisely to keep this order legal)."""
        import os
        import subprocess
        import sys

        env = dict(os.environ)
        root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
        env["PYTHONPATH"] = os.path.join(root, "src")
        proc = subprocess.run(
            [sys.executable, "-c",
             "import repro.kernels.nitro_matmul as k; k.fused_matmul_fwd"],
            env=env, capture_output=True, text=True,
        )
        assert proc.returncode == 0, proc.stderr

    def test_dispatcher_resolves_auto_off_tpu(self):
        assert resolve_backend("auto") in ("pallas", "reference")
        assert resolve_backend("interpret") == "interpret"
        with pytest.raises(ValueError, match="unknown backend"):
            resolve_backend("cuda")

    def test_dispatcher_backends_agree(self):
        rng = np.random.default_rng(3)
        x = jnp.asarray(rng.integers(-127, 128, (17, 50)), jnp.int32)
        w = jnp.asarray(rng.integers(-127, 128, (50, 9)), jnp.int32)
        sf = linear_scale_factor(50)
        a_ref, z_ref = fused_matmul_fwd(x, w, sf=sf, backend="reference")
        a_int, z_int = fused_matmul_fwd(x, w, sf=sf, backend="interpret")
        assert_bitwise_equal((a_ref, z_ref), (a_int, z_int))


# ---------------------------------------------------------------------------
# Block/model-level: fused forward_layers ≡ unfused on the paper configs
# ---------------------------------------------------------------------------


class TestForwardLayersParity:
    @pytest.mark.parametrize("arch", ["vgg8b", "vgg11b"])
    def test_fused_forward_bit_exact_on_paper_cnn(self, arch):
        """Acceptance criterion: fused ≡ unfused forward (activations AND
        the cached z_star) through every block of the paper CNN configs."""
        cfg = paper.get(arch, scale=0.0625)
        state = _state(cfg, seed=7)
        rng = np.random.default_rng(11)
        x = jnp.asarray(
            rng.integers(-127, 128, (4, *cfg.input_shape)), jnp.int32
        )
        y_f, acts_f, caches_f, _ = M.forward(
            state.params, cfg, x, train=False, fused=True
        )
        y_u, acts_u, caches_u, _ = M.forward(
            state.params, cfg, x, train=False, fused=False
        )
        assert_bitwise_equal(y_f, y_u)
        for af, au, cf, cu in zip(acts_f, acts_u, caches_f, caches_u):
            assert_bitwise_equal(af, au)
            assert_bitwise_equal(cf["z_star"], cu["z_star"])

    def test_fused_interpret_backend_matches_on_single_block(self):
        """The Pallas kernel (interpret mode) slots into forward_layers."""
        spec = BlockSpec("conv", 12, pool=True, d_lr=128)
        cfg = NitroConfig(blocks=(spec,), input_shape=(6, 6, 3),
                          num_classes=10)
        p = M.init_params(jax.random.PRNGKey(0), cfg)["blocks"][0]
        rng = np.random.default_rng(2)
        x = jnp.asarray(rng.integers(-127, 128, (3, 6, 6, 3)), jnp.int32)
        a_i, c_i = B.forward_layers(p, spec, x, train=False,
                                    fused=True, backend="interpret")
        a_u, c_u = B.forward_layers(p, spec, x, train=False, fused=False)
        assert_bitwise_equal(a_i, a_u)
        assert_bitwise_equal(c_i["z_star"], c_u["z_star"])

    def test_cache_contract_identical(self):
        """Backward consumes the same cache keys whichever forward ran."""
        spec = BlockSpec("linear", 32)
        cfg = NitroConfig(blocks=(spec,), input_shape=(20,), num_classes=10)
        p = M.init_params(jax.random.PRNGKey(1), cfg)["blocks"][0]
        x = jnp.asarray(
            np.random.default_rng(0).integers(-127, 128, (5, 20)), jnp.int32
        )
        _, c_f = B.forward_layers(p, spec, x, train=False, fused=True)
        _, c_u = B.forward_layers(p, spec, x, train=False, fused=False)
        assert set(c_f) == set(c_u)
        assert_bitwise_equal(c_f["linear"], c_u["linear"])


# ---------------------------------------------------------------------------
# Train-step-level: one fused step ≡ one unfused step, params and metrics
# ---------------------------------------------------------------------------


class TestTrainStepParity:
    @pytest.mark.parametrize("cfg_fn", [
        lambda: tiny_cnn_cfg(eta_fw=12000, eta_lr=3000),
        lambda: paper.get("vgg8b", scale=0.0625),
    ])
    def test_fused_step_bit_exact(self, cfg_fn):
        cfg = cfg_fn()
        st = _state(cfg)
        rng = np.random.default_rng(4)
        x = jnp.asarray(
            rng.integers(-127, 128, (8, *cfg.input_shape)), jnp.int32
        )
        y = jnp.asarray(rng.integers(0, cfg.num_classes, 8), jnp.int32)
        key = jax.random.PRNGKey(9)
        st_f, m_f = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fused=True))(st, x=x, labels=y, key=key)
        st_u, m_u = jax.jit(functools.partial(
            les.train_step, cfg=cfg, fused=False))(st, x=x, labels=y, key=key)
        assert_bitwise_equal(st_f.params, st_u.params)
        assert int(m_f.loss) == int(m_u.loss)
        assert_bitwise_equal(m_f.local_losses, m_u.local_losses)

    def test_fused_multi_step_training_stays_exact(self):
        """Divergence can compound: run several steps and compare params."""
        cfg = tiny_cnn_cfg(eta_fw=20000, eta_lr=5000)
        rng = np.random.default_rng(0)
        x = jnp.asarray(rng.integers(-127, 128, (16, 8, 8, 3)), jnp.int32)
        y = jnp.asarray(rng.integers(0, 10, 16), jnp.int32)
        st_f = st_u = _state(cfg)
        step_f = jax.jit(functools.partial(les.train_step, cfg=cfg, fused=True))
        step_u = jax.jit(functools.partial(les.train_step, cfg=cfg, fused=False))
        for i in range(10):
            k = jax.random.PRNGKey(i)
            st_f, _ = step_f(st_f, x=x, labels=y, key=k)
            st_u, _ = step_u(st_u, x=x, labels=y, key=k)
        assert_bitwise_equal(st_f.params, st_u.params)
