"""IntegerSGD (Algorithm 1), NITRO Amplification Factor, integer Kaiming."""

import jax
import jax.numpy as jnp
import numpy as np
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import init as init_mod
from repro.core import optimizer as opt


class TestIntegerSGD:
    def test_algorithm1_no_decay(self):
        state = opt.init_state(gamma_inv=512, eta_inv=0)
        w = jnp.asarray([1000, -1000], jnp.int32)
        g = jnp.asarray([5120, -5120], jnp.int32)
        w2 = opt.apply_update(w, g, state)
        np.testing.assert_array_equal(np.asarray(w2), [1000 - 10, -1000 + 10])

    def test_decay_threshold_behaviour(self):
        """Paper §3.3: only weights with |w| ≥ η_inv are penalised."""
        state = opt.init_state(gamma_inv=512, eta_inv=3000)
        w = jnp.asarray([2999, 3000, -3000, -6001], jnp.int32)
        g = jnp.zeros((4,), jnp.int32)
        w2 = np.asarray(opt.apply_update(w, g, state))
        assert w2[0] == 2999          # |w| < η: ⌊2999/3000⌋ = 0 → untouched
        assert w2[1] == 3000 - 1      # ⌊3000/3000⌋ = 1
        assert w2[2] == -3000 + 1     # ⌊-3000/3000⌋ = -1 → +1 (floor semantics)
        assert w2[3] == -6001 + 3     # ⌊-6001/3000⌋ = -3

    @given(
        st.integers(-(2**15), 2**15), st.integers(-(2**20), 2**20),
        st.integers(1, 2**12), st.integers(0, 2**14),
    )
    @settings(max_examples=200, deadline=None)
    def test_matches_python_reference(self, w, g, gamma, eta):
        state = opt.init_state(gamma, eta)
        got = int(opt.apply_update(jnp.int32(w), jnp.int32(g), state))
        delta = g // gamma
        if eta != 0:
            delta += w // eta
        assert got == w - delta

    def test_lr_schedule_triples_gamma_inv(self):
        state = opt.init_state(512, 0)
        state = opt.step_lr_schedule(state, jnp.asarray(True))
        assert int(state.gamma_inv) == 1536
        state = opt.step_lr_schedule(state, jnp.asarray(False))
        assert int(state.gamma_inv) == 1536

    def test_amplification_factor(self):
        # AF = 2^6 × G
        assert opt.amplification_factor(10) == 640
        assert opt.amplification_factor(1000) == 64000


class TestIntegerKaiming:
    def test_bound_formula(self):
        # b = ⌊128·1732/(⌊√fan_in⌋·1000)⌋
        assert init_mod.kaiming_bound(784) == (128 * 1732) // (28 * 1000)
        assert init_mod.kaiming_bound(1024) == (128 * 1732) // (32 * 1000)

    @given(st.integers(1, 2**16))
    @settings(max_examples=100, deadline=None)
    def test_bound_always_positive(self, fan_in):
        assert init_mod.kaiming_bound(fan_in) >= 1

    def test_support_and_dtype(self):
        key = jax.random.PRNGKey(0)
        w = init_mod.integer_kaiming_uniform(key, (1000,), fan_in=64)
        b = init_mod.kaiming_bound(64)
        assert w.dtype == jnp.int32
        assert int(w.min()) >= -b and int(w.max()) <= b
        # both extremes actually reachable (inclusive uniform)
        assert int(w.min()) == -b and int(w.max()) == b
