"""End-to-end integration: sharded LM trainer, serving engine, checkpoint
round-trips through the trainer, fault-tolerant loop behaviour."""

import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from dataclasses import replace

from repro.configs import get_smoke_config
from repro.data.loader import synthetic_lm_generator
from repro.launch.mesh import make_test_mesh
from repro.models import transformer as T
from repro.parallel.sharding import serve_rules, train_rules
from repro.train import checkpoint as ckpt
from repro.train import trainer


@pytest.fixture(scope="module")
def llama_setup():
    cfg = get_smoke_config("llama3.2-1b")
    mesh = make_test_mesh(1, 1)
    rules = trainer.resolved_rules(cfg, train_rules(False))
    return cfg, mesh, rules


class TestShardedTrainStep:
    def test_loss_decreases(self, llama_setup):
        cfg, mesh, rules = llama_setup
        b, s = 8, 32
        gen = synthetic_lm_generator(cfg.vocab_size, s, b)
        fn = trainer.build_train_step(
            cfg, mesh, rules, shapes={"tokens": (b, s), "labels": (b, s)},
            donate=False,
        )
        state = trainer.init_state(jax.random.PRNGKey(0), cfg)
        losses = []
        for i in range(25):
            batch = {k: jnp.asarray(v) for k, v in gen(0).items()}  # memorise
            state, m = fn(state, batch)
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]
        assert np.isfinite(losses).all()

    def test_grad_norm_and_lr_reported(self, llama_setup):
        cfg, mesh, rules = llama_setup
        b, s = 4, 16
        gen = synthetic_lm_generator(cfg.vocab_size, s, b)
        fn = trainer.build_train_step(
            cfg, mesh, rules, shapes={"tokens": (b, s), "labels": (b, s)},
            donate=False,
        )
        state = trainer.init_state(jax.random.PRNGKey(0), cfg)
        _, m = fn(state, {k: jnp.asarray(v) for k, v in gen(0).items()})
        assert float(m["grad_norm"]) > 0
        assert 0 <= float(m["lr"]) <= cfg.learning_rate

    def test_checkpoint_restart_reproduces_training(self, llama_setup, tmp_path):
        """Train 4 steps = train 2 + checkpoint + restore + train 2."""
        cfg, mesh, rules = llama_setup
        b, s = 4, 16
        gen = synthetic_lm_generator(cfg.vocab_size, s, b)
        fn = trainer.build_train_step(
            cfg, mesh, rules, shapes={"tokens": (b, s), "labels": (b, s)},
            donate=False,
        )

        def batches(i):
            return {k: jnp.asarray(v) for k, v in gen(i).items()}

        state = trainer.init_state(jax.random.PRNGKey(0), cfg)
        for i in range(4):
            state, _ = fn(state, batches(i))
        direct = state

        state2 = trainer.init_state(jax.random.PRNGKey(0), cfg)
        for i in range(2):
            state2, _ = fn(state2, batches(i))
        ckpt.save(str(tmp_path), 2, state2)
        restored, step = ckpt.restore(str(tmp_path), state2)
        assert step == 2
        for i in range(2, 4):
            restored, _ = fn(restored, batches(i))

        for a, b_ in zip(jax.tree_util.tree_leaves(direct[0]),
                         jax.tree_util.tree_leaves(restored[0])):
            np.testing.assert_allclose(
                np.asarray(a, np.float32), np.asarray(b_, np.float32),
                rtol=1e-6, atol=1e-6,
            )

    def test_les_groups_trains(self):
        cfg = replace(get_smoke_config("llama3.2-1b"), num_layers=4, les_groups=2)
        mesh = make_test_mesh(1, 1)
        rules = trainer.resolved_rules(cfg, train_rules(False))
        b, s = 4, 16
        gen = synthetic_lm_generator(cfg.vocab_size, s, b)
        fn = trainer.build_train_step(
            cfg, mesh, rules, shapes={"tokens": (b, s), "labels": (b, s)},
            donate=False,
        )
        state = trainer.init_state(jax.random.PRNGKey(0), cfg)
        losses = []
        for i in range(15):
            state, m = fn(state, {k: jnp.asarray(v) for k, v in gen(0).items()})
            losses.append(float(m["loss"]))
        assert losses[-1] < losses[0]


class TestServingEngine:
    def test_batched_generation(self):
        from repro.serving.engine import Engine, Request

        cfg = get_smoke_config("h2o-danube-1.8b")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        engine = Engine(cfg, params, max_seq=64)
        reqs = [Request(prompt=[1, 2, 3], max_new_tokens=5),
                Request(prompt=[4, 5], max_new_tokens=5)]
        out = engine.generate(reqs)
        assert all(len(r.generated) == 5 for r in out)
        assert all(0 <= t < cfg.vocab_size for r in out for t in r.generated)

    def test_greedy_deterministic(self):
        from repro.serving.engine import Engine, Request

        cfg = get_smoke_config("llama3.2-1b")
        params = T.init_params(jax.random.PRNGKey(0), cfg)
        engine = Engine(cfg, params, max_seq=64)
        a = engine.generate([Request(prompt=[7, 8, 9], max_new_tokens=6)])
        b = engine.generate([Request(prompt=[7, 8, 9], max_new_tokens=6)])
        assert a[0].generated == b[0].generated


class TestDryRunMachinery:
    def test_cell_applicability_table(self):
        from repro.configs import get_config
        from repro.launch import shapes as S

        total = applicable = 0
        for arch in ("qwen3-32b", "rwkv6-3b", "h2o-danube-1.8b"):
            cfg = get_config(arch)
            for c in S.all_cells(cfg):
                total += 1
                applicable += int(c.applicable)
        assert total == 12
        # qwen3 skips long_500k; rwkv + h2o run it
        assert applicable == 11

    def test_input_specs_no_allocation(self):
        from repro.configs import get_config
        from repro.launch import shapes as S

        cfg = get_config("qwen2-vl-72b")  # 72B params — must not allocate
        specs = S.train_batch_specs(cfg, 256, 4096)
        for v in specs.values():
            assert isinstance(v, jax.ShapeDtypeStruct)
        cache = S.abstract_cache(cfg, 128, 32768)
        for leaf in jax.tree_util.tree_leaves(cache):
            assert isinstance(leaf, jax.ShapeDtypeStruct)

    def test_hlo_analyzer_on_known_program(self):
        from repro.launch.hlo_analysis import analyze

        def f(x, ws):
            def body(c, w):
                return jnp.tanh(c @ w), None
            out, _ = jax.lax.scan(body, x, ws)
            return out

        comp = jax.jit(f).lower(
            jax.ShapeDtypeStruct((64, 64), jnp.float32),
            jax.ShapeDtypeStruct((4, 64, 64), jnp.float32),
        ).compile()
        costs = analyze(comp.as_text())
        assert costs.flops["f32"] == 4 * 2 * 64**3  # scan trips counted

    def test_make_rules_modes(self):
        from repro.configs import get_config
        from repro.launch.dryrun import make_rules

        cfg = get_config("rwkv6-3b")
        train = make_rules(cfg, mode="train", multi_pod=False, batch=256)
        assert train["batch"] == ("data", "model")  # dp_only
        serve = make_rules(cfg, mode="serve", multi_pod=False, batch=128)
        assert serve["batch"] == ("data",)
        single = make_rules(cfg, mode="serve", multi_pod=False, batch=1)
        assert single["batch"] is None  # long_500k: nothing to shard
