"""Integer layers: forwards vs naive oracles, backwards vs float autodiff.

Integer gradients are exact integer computations; when inputs are small
enough that every product/sum is exactly representable in float32, the
integer backward must equal ``jax.grad`` of the equivalent float function.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

from repro.core import layers


def _rand_int(rng, shape, lo=-9, hi=10):
    return rng.integers(lo, hi, shape).astype(np.int32)


class TestLinear:
    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=25, deadline=None)
    def test_backward_matches_float_autodiff(self, seed):
        rng = np.random.default_rng(seed)
        x = _rand_int(rng, (4, 6))
        w = _rand_int(rng, (6, 3))
        g = _rand_int(rng, (4, 3))
        params = {"w": jnp.asarray(w)}
        _, cache = layers.linear_forward(params, jnp.asarray(x))
        gx, gw = layers.linear_backward(params, cache, jnp.asarray(g))

        f = lambda xf, wf: jnp.sum(xf @ wf * g.astype(jnp.float32))
        gxf, gwf = jax.grad(f, argnums=(0, 1))(
            x.astype(np.float32), w.astype(np.float32)
        )
        np.testing.assert_array_equal(np.asarray(gx), np.asarray(gxf).astype(np.int32))
        np.testing.assert_array_equal(np.asarray(gw["w"]), np.asarray(gwf).astype(np.int32))


class TestConv2D:
    def _naive_conv(self, x, w):
        n, h, ww, c = x.shape
        k, _, _, f = w.shape
        pad = k // 2
        xp = np.pad(x, ((0, 0), (pad, pad), (pad, pad), (0, 0)))
        out = np.zeros((n, h, ww, f), np.int64)
        for i in range(k):
            for j in range(k):
                out += np.einsum(
                    "nhwc,cf->nhwf",
                    xp[:, i : i + h, j : j + ww, :].astype(np.int64),
                    w[i, j].astype(np.int64),
                )
        return out.astype(np.int32)

    @pytest.mark.parametrize("k", [1, 3, 5])
    def test_forward_matches_naive(self, k):
        rng = np.random.default_rng(0)
        x = _rand_int(rng, (2, 6, 6, 3), -127, 128)
        w = _rand_int(rng, (k, k, 3, 4), -50, 51)
        z, _ = layers.conv_forward({"w": jnp.asarray(w)}, jnp.asarray(x))
        np.testing.assert_array_equal(np.asarray(z), self._naive_conv(x, w))

    @given(st.integers(0, 2**31 - 1))
    @settings(max_examples=10, deadline=None)
    def test_backward_matches_float_autodiff(self, seed):
        rng = np.random.default_rng(seed)
        x = _rand_int(rng, (2, 5, 5, 2))
        w = _rand_int(rng, (3, 3, 2, 3))
        g = _rand_int(rng, (2, 5, 5, 3))
        params = {"w": jnp.asarray(w)}
        _, cache = layers.conv_forward(params, jnp.asarray(x))
        gx, gw = layers.conv_backward(params, cache, jnp.asarray(g))

        def f(xf, wf):
            z = jax.lax.conv_general_dilated(
                xf, wf, (1, 1), "SAME", dimension_numbers=("NHWC", "HWIO", "NHWC")
            )
            return jnp.sum(z * g.astype(jnp.float32))

        gxf, gwf = jax.grad(f, argnums=(0, 1))(
            x.astype(np.float32), w.astype(np.float32)
        )
        np.testing.assert_array_equal(np.asarray(gx), np.asarray(gxf).astype(np.int32))
        np.testing.assert_array_equal(np.asarray(gw["w"]), np.asarray(gwf).astype(np.int32))


class TestMaxPool:
    def test_forward(self):
        x = jnp.asarray(np.arange(16).reshape(1, 4, 4, 1), jnp.int32)
        y, _ = layers.maxpool_forward(x)
        np.testing.assert_array_equal(
            np.asarray(y).squeeze(), np.array([[5, 7], [13, 15]])
        )

    def test_backward_routes_to_argmax(self):
        x = jnp.asarray(np.arange(16).reshape(1, 4, 4, 1), jnp.int32)
        _, cache = layers.maxpool_forward(x)
        g = jnp.asarray([[[[10], [20]], [[30], [40]]]], jnp.int32)
        gx = np.asarray(layers.maxpool_backward(cache, g)).squeeze()
        assert gx[1, 1] == 10 and gx[1, 3] == 20
        assert gx[3, 1] == 30 and gx[3, 3] == 40
        assert gx.sum() == 100  # gradient mass preserved

    def test_odd_sizes_floor_pooled(self):
        x = jnp.asarray(np.arange(49).reshape(1, 7, 7, 1), jnp.int32)
        y, cache = layers.maxpool_forward(x)
        assert y.shape == (1, 3, 3, 1)
        g = jnp.ones((1, 3, 3, 1), jnp.int32)
        gx = layers.maxpool_backward(cache, g)
        assert gx.shape == x.shape  # cropped edge repadded with zeros


class TestAvgPoolTo:
    def test_integer_mean(self):
        x = jnp.full((1, 4, 4, 2), 7, jnp.int32)
        y, cache = layers.avgpool_to(x, target=8)  # s = isqrt(8//2) = 2
        assert y.shape == (1, 2, 2, 2)
        assert int(y[0, 0, 0, 0]) == 7  # 7·4 // 4

    def test_backward_is_ste_replication(self):
        x = jnp.zeros((1, 4, 4, 2), jnp.int32)
        _, cache = layers.avgpool_to(x, target=8)
        g = jnp.full((1, 2, 2, 2), 5, jnp.int32)
        gx = np.asarray(layers.avgpool_to_backward(cache, g))
        assert gx.shape == (1, 4, 4, 2)
        assert (gx == 5).all()  # replicated, not divided


class TestDropout:
    def test_zero_rate_is_identity(self):
        x = jnp.arange(10, dtype=jnp.int32)
        y, _ = layers.dropout_forward(jax.random.PRNGKey(0), x, 0.0)
        np.testing.assert_array_equal(np.asarray(y), np.asarray(x))

    def test_expectation_preserved(self):
        x = jnp.full((20000,), 100, jnp.int32)
        y, _ = layers.dropout_forward(jax.random.PRNGKey(0), x, 0.25)
        mean = float(jnp.mean(y.astype(jnp.float32)))
        assert abs(mean - 100.0) < 2.5  # inverted-dropout rescale works

    def test_mask_shared_by_backward(self):
        x = jnp.full((1000,), 64, jnp.int32)
        y, cache = layers.dropout_forward(jax.random.PRNGKey(1), x, 0.5)
        g = layers.dropout_backward(cache, jnp.full((1000,), 64, jnp.int32))
        np.testing.assert_array_equal(np.asarray(y == 0), np.asarray(g == 0))

    def test_integer_only(self):
        """The dropout jaxpr must contain no float op (integer Bernoulli)."""
        jaxpr = jax.make_jaxpr(
            lambda k, x: layers.dropout_forward(k, x, 0.3)[0]
        )(jax.random.PRNGKey(0), jnp.ones((8,), jnp.int32))
        for eqn in jaxpr.eqns:
            for v in list(eqn.invars) + list(eqn.outvars):
                aval = getattr(v, "aval", None)
                if aval is not None:
                    assert "float" not in str(aval.dtype)
